#!/usr/bin/env python3
"""Summarize a `dts-telemetry-v1` NDJSON dump (stdlib only).

Usage:
    python3 python/telemetry_report.py tele.ndjson [--out report.md]

Input: the file written by `dts simulate|policy --telemetry PATH` —
one JSON object per line (see docs/OBSERVABILITY.md):

  * a meta line   {"format": "dts-telemetry-v1", "command": ...}
  * span lines    {"kind": "span", "label", "dataset", "replans",
                   "refresh_s", "heuristic_s", "bookkeep_s", "wall_s"}
  * counter lines {"kind": "counter", "key", "value"}
  * hist lines    {"kind": "hist", "key", "count", "sum", "bins": [...]}

Output (stdout, or --out as GitHub-flavored markdown):

  * the **phase table** — per span (dataset x controller cell group)
    the replan count and the refresh / heuristic / bookkeeping split of
    the replan wall time, with per-phase percentages of the wall total;
  * the **counter table** in canonical key order;
  * **histogram percentiles** (p50/p90/p99/max) estimated from the
    log2 bins: bin 0 holds the exact value 0, bin k (1..=40) the
    half-open range [2^(k-1), 2^k), and the last bin is the +Inf
    overflow bucket.  A percentile is reported as its bin's inclusive
    upper edge — an upper bound, exact to the bin resolution.

The phase sums are also reconciled: refresh + heuristic + bookkeep
must match wall_s per span (tolerance 1e-6 relative); a mismatch means
a phase was double- or un-counted and the script exits 2.
"""

from __future__ import annotations

import argparse
import json
import sys

HIST_BINS = 42  # keep in sync with rust/src/telemetry/mod.rs

# nanosecond-valued histograms get human-readable percentile units
WALL_KEYS = {"replan_wall_ns", "refresh_wall_ns", "heuristic_wall_ns",
             "bookkeep_wall_ns", "serve_request_ns"}


def upper_edge(b: int) -> float:
    """Inclusive upper edge of bin `b` (+inf for the overflow bucket)."""
    if b == 0:
        return 0.0
    if b < HIST_BINS - 1:
        return float((1 << b) - 1)
    return float("inf")


def percentile_edge(bins: list[int], q: float) -> float:
    """Upper-bound estimate of quantile `q` from cumulative bin counts."""
    total = sum(bins)
    if total == 0:
        return 0.0
    target = q * total
    cum = 0
    for b, n in enumerate(bins):
        cum += n
        if cum >= target and n > 0 or cum >= total:
            return upper_edge(b)
    return upper_edge(HIST_BINS - 1)


def max_edge(bins: list[int]) -> float:
    for b in range(len(bins) - 1, -1, -1):
        if bins[b] > 0:
            return upper_edge(b)
    return 0.0


def fmt_val(key: str, v: float) -> str:
    """Render a percentile edge: ns histograms as engineering time."""
    if v == float("inf"):
        return "+Inf"
    if key not in WALL_KEYS:
        return f"{int(v)}"
    if v >= 1e9:
        return f"{v / 1e9:.2f}s"
    if v >= 1e6:
        return f"{v / 1e6:.2f}ms"
    if v >= 1e3:
        return f"{v / 1e3:.2f}us"
    return f"{int(v)}ns"


def fmt_s(v: float) -> str:
    return f"{v * 1e3:.3f}"


def table(headers: list[str], rows: list[list[str]]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(r) + " |")
    return "\n".join(out)


def parse(path: str):
    meta, spans, counters, hists = None, [], [], []
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{ln}: bad JSON line: {e}")
            if obj.get("format"):
                meta = obj
            elif obj.get("kind") == "span":
                spans.append(obj)
            elif obj.get("kind") == "counter":
                counters.append(obj)
            elif obj.get("kind") == "hist":
                hists.append(obj)
    if meta is None or meta.get("format") != "dts-telemetry-v1":
        raise SystemExit(f"{path}: not a dts-telemetry-v1 document")
    return meta, spans, counters, hists


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("ndjson", help="telemetry NDJSON from --telemetry")
    ap.add_argument("--out", help="write the markdown report here "
                                  "instead of stdout")
    args = ap.parse_args()

    meta, spans, counters, hists = parse(args.ndjson)
    parts = [f"# dts telemetry report — `{meta.get('command', '?')}`", ""]

    # ---- phase table ------------------------------------------------
    parts.append("## Replan phase decomposition (ms, % of wall)")
    parts.append("")
    rows, bad = [], []
    for s in spans:
        wall = float(s.get("wall_s", 0.0))
        phases = [float(s.get(k, 0.0))
                  for k in ("refresh_s", "heuristic_s", "bookkeep_s")]
        if abs(sum(phases) - wall) > 1e-9 + 1e-6 * abs(wall):
            bad.append(f"{s.get('dataset')}/{s.get('label')}: "
                       f"phases {sum(phases)} vs wall {wall}")
        pct = [f"{p / wall * 100:.1f}%" if wall > 0 else "-" for p in phases]
        rows.append([
            str(s.get("dataset", "?")), str(s.get("label", "?")),
            str(s.get("replans", 0)),
            f"{fmt_s(phases[0])} ({pct[0]})",
            f"{fmt_s(phases[1])} ({pct[1]})",
            f"{fmt_s(phases[2])} ({pct[2]})",
            fmt_s(wall),
        ])
    if rows:
        parts.append(table(
            ["dataset", "cell", "replans", "refresh", "heuristic",
             "bookkeep", "wall"], rows))
    else:
        parts.append("*(no span lines)*")
    parts.append("")

    # ---- counters ---------------------------------------------------
    parts.append("## Counters")
    parts.append("")
    parts.append(table(
        ["key", "value"],
        [[str(c.get("key", "?")), str(int(c.get("value", 0)))]
         for c in counters]))
    parts.append("")

    # ---- histogram percentiles -------------------------------------
    parts.append("## Histogram percentiles (log2-binned upper bounds)")
    parts.append("")
    hrows = []
    for h in hists:
        key = str(h.get("key", "?"))
        bins = [int(b) for b in h.get("bins", [])]
        count = int(h.get("count", 0))
        mean = (float(h.get("sum", 0)) / count) if count else 0.0
        hrows.append([
            key, str(count), fmt_val(key, mean),
            fmt_val(key, percentile_edge(bins, 0.50)),
            fmt_val(key, percentile_edge(bins, 0.90)),
            fmt_val(key, percentile_edge(bins, 0.99)),
            fmt_val(key, max_edge(bins)),
        ])
    parts.append(table(
        ["key", "count", "mean", "p50", "p90", "p99", "max"], hrows))
    parts.append("")

    report = "\n".join(parts)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(report + "\n")
        print(f"[telemetry-report] wrote {args.out}")
    else:
        print(report)

    if bad:
        print("[telemetry-report] PHASE RECONCILIATION FAILED:",
              file=sys.stderr)
        for b in bad:
            print(f"  {b}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
