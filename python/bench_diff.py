#!/usr/bin/env python3
"""Diff a fresh BENCH_hotpath.json against a committed baseline.

Usage:
    python3 python/bench_diff.py BENCH_baseline.json BENCH_hotpath.json \
        [--warn-pct 20] [--fail-ratio 2.0]

Each file maps bench-row name -> {"mean": s, "min": s, "max": s,
"allocs": n} (see rust/benches/perf_hotpath.rs).  For every row present
in both files the script compares the fresh mean against the baseline
mean:

  * ratio >= --fail-ratio (default 2.0x)  -> FAIL (exit 1)
  * ratio >= 1 + --warn-pct/100 (def 20%) -> WARN (exit 0)

Speedups, new rows and removed rows are reported informationally.
`allocs` regressions (a zero-alloc row that started allocating) are
warned about but never fail: the column is populated only by
`--features alloc-count` builds, so a 0 may simply mean "not measured".

A missing baseline file is not an error: benches are environment
-specific, so a fresh clone has no baseline until a toolchain-equipped
run commits one (see docs/PERF.md).  The script prints a note and exits
0 so the CI perf-smoke job stays green until then.

Rows with sub-microsecond baseline means are skipped — at that scale
timer jitter swamps any real regression.
"""

from __future__ import annotations

import argparse
import json
import sys

MIN_MEAN_S = 1e-6  # ignore rows faster than this: pure timer noise


def load(path: str) -> dict:
    with open(path) as fh:
        data = json.load(fh)
    if not isinstance(data, dict):
        raise SystemExit(f"{path}: expected a JSON object of bench rows")
    return data


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("fresh", help="freshly generated BENCH_hotpath.json")
    ap.add_argument("--warn-pct", type=float, default=20.0,
                    help="warn when the mean regresses by this percent")
    ap.add_argument("--fail-ratio", type=float, default=2.0,
                    help="fail when fresh/baseline mean reaches this ratio")
    args = ap.parse_args()

    try:
        base = load(args.baseline)
    except FileNotFoundError:
        print(f"[bench-diff] no baseline at {args.baseline} — nothing to "
              "compare (commit one from a toolchain-equipped run to arm "
              "this gate)")
        return 0
    fresh = load(args.fresh)

    warn_ratio = 1.0 + args.warn_pct / 100.0
    failures: list[str] = []
    warnings: list[str] = []

    for name in sorted(base):
        if name not in fresh:
            print(f"[bench-diff] removed row: {name}")
            continue
        b, f = base[name], fresh[name]
        b_mean, f_mean = float(b.get("mean", 0.0)), float(f.get("mean", 0.0))
        if b_mean < MIN_MEAN_S:
            continue
        ratio = f_mean / b_mean
        line = f"{name}: {b_mean:.6f}s -> {f_mean:.6f}s ({ratio:.2f}x)"
        if ratio >= args.fail_ratio:
            failures.append(line)
        elif ratio >= warn_ratio:
            warnings.append(line)
        b_allocs = int(b.get("allocs", 0))
        f_allocs = int(f.get("allocs", 0))
        if b_allocs == 0 and f_allocs > 0:
            warnings.append(f"{name}: allocs 0 -> {f_allocs} (zero-alloc row "
                            "started allocating?)")

    for name in sorted(set(fresh) - set(base)):
        print(f"[bench-diff] new row (no baseline): {name}")

    for line in warnings:
        print(f"[bench-diff] WARN {line}")
    for line in failures:
        print(f"[bench-diff] FAIL {line}")

    if failures:
        print(f"[bench-diff] {len(failures)} row(s) regressed "
              f">= {args.fail_ratio:.1f}x vs {args.baseline}")
        return 1
    n = len([k for k in base if k in fresh])
    print(f"[bench-diff] OK: {n} shared row(s), {len(warnings)} warning(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
