"""L2 correctness: rank fixed points vs an independent topological oracle.

Validates both the kernel *and* the fixed-point formulation on random DAGs,
including padding semantics (exactly what the Rust runtime feeds the
compiled artifact).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref
from compile.kernels.maxplus import NEG


def random_dag(rng, n_real, p_edge=0.3, wmax=50.0, cmax=20.0):
    """Random DAG on [0, n_real): edges only i -> j with i < j (acyclic)."""
    edges = []
    for i in range(n_real):
        for j in range(i + 1, n_real):
            if rng.random() < p_edge:
                edges.append((i, j, float(rng.uniform(0.1, cmax))))
    w = rng.uniform(0.1, wmax, n_real)
    return edges, w


def pad_problem(edges, w, n_pad):
    """Pad to bucket size: w = 0, no edges for padded tasks."""
    m = np.full((n_pad, n_pad), NEG, dtype=np.float32)
    for u, v, c in edges:
        m[u, v] = c
    wp = np.zeros(n_pad, dtype=np.float32)
    wp[: len(w)] = w
    return m, wp


def dag_height(edges, n):
    children = [[] for _ in range(n)]
    for u, v, _ in edges:
        children[u].append(v)
    memo = {}

    def h(t):
        if t in memo:
            return memo[t]
        memo[t] = 1 + max((h(c) for c in children[t]), default=0)
        return memo[t]

    return max((h(t) for t in range(n)), default=1)


@pytest.mark.parametrize("n_real,bucket", [(5, 32), (20, 32), (30, 32), (50, 64), (100, 128)])
def test_upward_rank_matches_topo_oracle(n_real, bucket):
    rng = np.random.default_rng(n_real)
    edges, w = random_dag(rng, n_real)
    m, wp = pad_problem(edges, w, bucket)
    depth = dag_height(edges, n_real)
    got = np.asarray(model.upward_rank(jnp.array(m), jnp.array(wp), depth))
    want = ref.upward_rank_topo_ref(edges, w)
    np.testing.assert_allclose(got[:n_real], want, rtol=1e-4)
    # padded tasks: rank exactly 0 (w = 0, no edges)
    np.testing.assert_allclose(got[n_real:], 0.0, atol=1e-6)


@pytest.mark.parametrize("n_real,bucket", [(5, 32), (30, 32), (50, 64)])
def test_downward_rank_matches_topo_oracle(n_real, bucket):
    rng = np.random.default_rng(500 + n_real)
    edges, w = random_dag(rng, n_real)
    m, wp = pad_problem(edges, w, bucket)
    depth = dag_height(edges, n_real)
    got = np.asarray(model.downward_rank(jnp.array(m), jnp.array(wp), depth))
    want = ref.downward_rank_topo_ref(edges, w)
    np.testing.assert_allclose(got[:n_real], want, rtol=1e-4)


def test_ranks_combined_consistent_with_parts():
    rng = np.random.default_rng(42)
    edges, w = random_dag(rng, 24)
    m, wp = pad_problem(edges, w, 32)
    depth = 32  # over-iterate: fixed point must be stable
    up, down = model.ranks_combined(jnp.array(m), jnp.array(wp), depth)
    up1 = model.upward_rank(jnp.array(m), jnp.array(wp), depth)
    down1 = model.downward_rank(jnp.array(m), jnp.array(wp), depth)
    np.testing.assert_allclose(np.asarray(up), np.asarray(up1))
    np.testing.assert_allclose(np.asarray(down), np.asarray(down1))


def test_over_iteration_is_stable():
    """Iterating past the DAG height must not change the fixed point."""
    rng = np.random.default_rng(3)
    edges, w = random_dag(rng, 20)
    m, wp = pad_problem(edges, w, 32)
    h = dag_height(edges, 20)
    r_h = np.asarray(model.upward_rank(jnp.array(m), jnp.array(wp), h))
    r_2h = np.asarray(model.upward_rank(jnp.array(m), jnp.array(wp), 2 * h + 3))
    np.testing.assert_allclose(r_h, r_2h, rtol=1e-6)


def test_chain_rank_is_suffix_sum():
    """Chain DAG: rank_u(i) = sum_{j>=i} w(j) + sum of comm costs after i."""
    n = 10
    w = np.arange(1.0, n + 1.0)
    edges = [(i, i + 1, 2.0) for i in range(n - 1)]
    m, wp = pad_problem(edges, w, 32)
    got = np.asarray(model.upward_rank(jnp.array(m), jnp.array(wp), n))
    want = np.array(
        [w[i:].sum() + 2.0 * (n - 1 - i) for i in range(n)]
    )
    np.testing.assert_allclose(got[:n], want, rtol=1e-5)


def test_cpop_priority_constant_on_critical_path():
    """up(t) + down(t) is constant along the critical path of a chain."""
    n = 6
    w = np.full(n, 3.0)
    edges = [(i, i + 1, 1.0) for i in range(n - 1)]
    m, wp = pad_problem(edges, w, 32)
    up, down = model.ranks_combined(jnp.array(m), jnp.array(wp), n)
    pri = np.asarray(up)[:n] + np.asarray(down)[:n]
    np.testing.assert_allclose(pri, pri[0], rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 28),
    p=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_upward_rank_hypothesis(n, p, seed):
    rng = np.random.default_rng(seed)
    edges, w = random_dag(rng, n, p_edge=p)
    m, wp = pad_problem(edges, w, 32)
    got = np.asarray(model.upward_rank(jnp.array(m), jnp.array(wp), 32))
    want = ref.upward_rank_topo_ref(edges, w)
    np.testing.assert_allclose(got[:n], want, rtol=1e-4, atol=1e-3)
