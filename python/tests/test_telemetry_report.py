"""Stdlib-only tests for python/telemetry_report.py (no pytest/numpy/jax
needed — run directly: `python3 python/tests/test_telemetry_report.py`)."""

import json
import os
import subprocess
import sys
import tempfile
import unittest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
SCRIPT = os.path.join(REPO, "python", "telemetry_report.py")
sys.path.insert(0, os.path.join(REPO, "python"))

import telemetry_report as tr  # noqa: E402


def ndjson(span_overrides=None):
    """A minimal valid dts-telemetry-v1 document."""
    span = {
        "kind": "span", "label": "sim L3@0.25", "dataset": "synthetic",
        "replans": 12, "refresh_s": 0.001, "heuristic_s": 0.002,
        "bookkeep_s": 0.0005, "wall_s": 0.0035,
    }
    span.update(span_overrides or {})
    bins = [0] * tr.HIST_BINS
    bins[0], bins[3], bins[41] = 2, 5, 1
    lines = [
        {"format": "dts-telemetry-v1", "command": "simulate"},
        span,
        {"kind": "counter", "key": "replans", "value": 12},
        {"kind": "counter", "key": "eft_placements", "value": 340},
        {"kind": "hist", "key": "cone_size", "count": 8, "sum": 42,
         "bins": bins},
    ]
    return "\n".join(json.dumps(x) for x in lines) + "\n"


def run_script(text):
    with tempfile.NamedTemporaryFile("w", suffix=".ndjson",
                                     delete=False) as fh:
        fh.write(text)
        path = fh.name
    try:
        return subprocess.run([sys.executable, SCRIPT, path],
                              capture_output=True, text=True)
    finally:
        os.unlink(path)


class BinEdges(unittest.TestCase):
    def test_edges_match_rust_binning(self):
        # bin 0 = exact zero; bin k upper edge 2^k - 1; last bin +Inf —
        # keep in sync with Histogram::upper_edge in telemetry/mod.rs.
        self.assertEqual(tr.upper_edge(0), 0.0)
        self.assertEqual(tr.upper_edge(1), 1.0)
        self.assertEqual(tr.upper_edge(5), 31.0)
        self.assertEqual(tr.upper_edge(tr.HIST_BINS - 1), float("inf"))

    def test_percentiles_are_upper_bounds(self):
        bins = [0] * tr.HIST_BINS
        bins[2] = 9   # values in [2, 4)
        bins[10] = 1  # one outlier in [512, 1024)
        self.assertEqual(tr.percentile_edge(bins, 0.50), 3.0)
        self.assertEqual(tr.percentile_edge(bins, 0.99), 1023.0)
        self.assertEqual(tr.max_edge(bins), 1023.0)
        self.assertEqual(tr.percentile_edge([0] * tr.HIST_BINS, 0.5), 0.0)


class Report(unittest.TestCase):
    def test_good_document_renders_phase_table(self):
        r = run_script(ndjson())
        self.assertEqual(r.returncode, 0, r.stderr)
        self.assertIn("Replan phase decomposition", r.stdout)
        self.assertIn("synthetic", r.stdout)
        self.assertIn("eft_placements", r.stdout)
        self.assertIn("cone_size", r.stdout)
        self.assertIn("+Inf", r.stdout)  # overflow bucket max

    def test_phase_mismatch_exits_2(self):
        r = run_script(ndjson({"wall_s": 9.0}))
        self.assertEqual(r.returncode, 2)
        self.assertIn("PHASE RECONCILIATION FAILED", r.stderr)

    def test_wrong_format_rejected(self):
        r = run_script('{"format": "something-else"}\n')
        self.assertNotEqual(r.returncode, 0)
        self.assertIn("not a dts-telemetry-v1", r.stderr)


if __name__ == "__main__":
    unittest.main()
