"""AOT path: lowered HLO text is well-formed and numerically faithful.

Executes the *same* HLO text the Rust runtime loads (via the Python XLA
client) and checks it against the oracle — a full rehearsal of the
artifact round trip without leaving pytest.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref
from compile.kernels.maxplus import NEG

from .test_model import random_dag, pad_problem, dag_height


@pytest.mark.parametrize("n", [32, 64])
def test_rank_hlo_text_parses_and_mentions_params(n):
    text = aot.lower_ranks(n)
    assert "HloModule" in text
    assert f"f32[{n},{n}]" in text
    assert "while" in text  # the depth-bounded fixed point


@pytest.mark.parametrize("p,v", [(64, 8), (64, 16)])
def test_eft_hlo_text_parses(p, v):
    text = aot.lower_eft(p, v)
    assert "HloModule" in text
    assert f"f32[{p},{v}]" in text


def test_rank_artifact_executes_correctly_via_hlo_text():
    """Round-trip: lower -> HLO text -> parse -> compile -> execute."""
    n = 32
    text = aot.lower_ranks(n)
    comp = xc._xla.hlo_module_from_text(text)
    # jitted reference through the normal jax path
    rng = np.random.default_rng(11)
    edges, w = random_dag(rng, 20)
    m, wp = pad_problem(edges, w, n)
    depth = np.int32(dag_height(edges, 20))

    backend = jax.devices("cpu")[0].client
    mlir = xc._xla.mlir.xla_computation_to_mlir_module(
        xc.XlaComputation(comp.as_serialized_hlo_module_proto())
    )
    devs = xc._xla.DeviceList(tuple(backend.devices()[:1]))
    exe = backend.compile_and_load(mlir, devs)
    out = exe.execute_sharded([jnp.array(m), jnp.array(wp), jnp.array(depth)])
    arrs = out.disassemble_into_single_device_arrays()
    up = np.asarray(arrs[0][0])
    down = np.asarray(arrs[1][0])
    np.testing.assert_allclose(
        up[:20], ref.upward_rank_topo_ref(edges, w), rtol=1e-4
    )
    np.testing.assert_allclose(
        down[:20], ref.downward_rank_topo_ref(edges, w), rtol=1e-4
    )


def test_manifest_written(tmp_path):
    """aot.main writes every bucket + manifest (small bucket set via argv)."""
    import sys
    import json as jsonlib

    argv = sys.argv
    sys.argv = ["aot.py", "--out-dir", str(tmp_path)]
    # shrink buckets for test speed
    old_rank, old_eft = aot.RANK_BUCKETS, aot.EFT_BUCKETS
    aot.RANK_BUCKETS, aot.EFT_BUCKETS = (32,), ((64, 8),)
    try:
        aot.main()
    finally:
        sys.argv = argv
        aot.RANK_BUCKETS, aot.EFT_BUCKETS = old_rank, old_eft
    man = jsonlib.loads((tmp_path / "manifest.json").read_text())
    assert man["ranks"] == [{"n": 32, "file": "ranks_n32.hlo.txt"}]
    assert (tmp_path / "ranks_n32.hlo.txt").exists()
    assert (tmp_path / "eft_p64_v8.hlo.txt").exists()
