"""L1 correctness: all-pairs max-plus matmul / longest-path kernel vs the
numpy oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.appairs import allpairs_longest, maxplus_matmul
from compile.kernels import ref
from compile.kernels.maxplus import NEG


@pytest.mark.parametrize("n", [4, 16, 64, 128])
def test_matmul_matches_bruteforce(n):
    rng = np.random.default_rng(n)
    a = rng.uniform(-10, 10, (n, n)).astype(np.float32)
    b = rng.uniform(-10, 10, (n, n)).astype(np.float32)
    got = np.asarray(maxplus_matmul(jnp.array(a), jnp.array(b)))
    want = np.array([[np.max(a[i, :] + b[:, j]) for j in range(n)] for i in range(n)])
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("block", [16, 32, 64])
def test_matmul_block_invariance(block):
    n = 64
    rng = np.random.default_rng(7)
    a = rng.uniform(-5, 5, (n, n)).astype(np.float32)
    b = rng.uniform(-5, 5, (n, n)).astype(np.float32)
    base = np.asarray(maxplus_matmul(jnp.array(a), jnp.array(b), block=n))
    got = np.asarray(maxplus_matmul(jnp.array(a), jnp.array(b), block=block))
    np.testing.assert_allclose(got, base, rtol=1e-6)


def random_dag_matrix(rng, n_real, n_pad, p_edge=0.3):
    m = np.full((n_pad, n_pad), NEG, dtype=np.float32)
    for i in range(n_real):
        for j in range(i + 1, n_real):
            if rng.random() < p_edge:
                m[i, j] = rng.uniform(0.1, 10.0)
    return m


@pytest.mark.parametrize("n_real,bucket", [(6, 16), (20, 32), (40, 64)])
def test_allpairs_matches_oracle(n_real, bucket):
    rng = np.random.default_rng(n_real)
    m = random_dag_matrix(rng, n_real, bucket)
    squarings = int(np.ceil(np.log2(bucket)))
    got = np.asarray(allpairs_longest(jnp.array(m), squarings))
    want = ref.allpairs_longest_ref(m.astype(np.float64))
    # compare only finite (reachable) entries; unreachable stay hugely neg
    finite = want > NEG / 2
    np.testing.assert_allclose(got[finite], want[finite], rtol=1e-5)
    assert np.all(got[~finite] <= NEG / 4)


def test_allpairs_chain_exact():
    n = 32
    m = np.full((n, n), NEG, dtype=np.float32)
    for i in range(n - 1):
        m[i, i + 1] = 2.0
    d = np.asarray(allpairs_longest(jnp.array(m), 5))
    for i in range(n):
        for j in range(i, n):
            assert abs(d[i, j] - 2.0 * (j - i)) < 1e-4
    assert np.all(np.diag(d) == 0.0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([8, 12, 16]))
def test_allpairs_hypothesis(seed, n):
    rng = np.random.default_rng(seed)
    m = random_dag_matrix(rng, n, 16, p_edge=0.4)
    got = np.asarray(allpairs_longest(jnp.array(m), 4))
    want = ref.allpairs_longest_ref(m.astype(np.float64))
    finite = want > NEG / 2
    np.testing.assert_allclose(got[finite], want[finite], rtol=1e-4, atol=1e-3)
