"""L1 correctness: Pallas kernels vs pure-numpy oracles.

This is the core correctness signal for the compiled artifacts: everything
the Rust runtime executes lowers through these kernels.  Hypothesis sweeps
shapes and values; fixed seeds keep the suite deterministic.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.maxplus import maxplus_matvec, NEG
from compile.kernels.eft import batch_eft
from compile.kernels import ref


def rand_cost_matrix(rng, n, p_edge=0.3, lo=0.1, hi=100.0):
    """Random DAG-ish cost matrix: finite entries with prob p, else NEG."""
    m = np.full((n, n), NEG, dtype=np.float32)
    mask = rng.random((n, n)) < p_edge
    m[mask] = rng.uniform(lo, hi, mask.sum()).astype(np.float32)
    return m


# ---------------------------------------------------------------- max-plus


@pytest.mark.parametrize("n", [4, 16, 32, 64, 128, 256])
def test_maxplus_matches_ref_dense(n):
    rng = np.random.default_rng(n)
    m = rng.uniform(-50, 50, (n, n)).astype(np.float32)
    x = rng.uniform(-50, 50, n).astype(np.float32)
    got = np.asarray(maxplus_matvec(jnp.array(m), jnp.array(x)))
    want = ref.maxplus_matvec_ref(m, x)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("n", [32, 128, 256])
def test_maxplus_matches_ref_sparse(n):
    rng = np.random.default_rng(1000 + n)
    m = rand_cost_matrix(rng, n, p_edge=0.1)
    x = rng.uniform(0, 100, n).astype(np.float32)
    got = np.asarray(maxplus_matvec(jnp.array(m), jnp.array(x)))
    want = ref.maxplus_matvec_ref(m, x)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_maxplus_empty_row_yields_neg():
    """A row with no finite entry must lose to the 0-clamp downstream."""
    n = 32
    m = np.full((n, n), NEG, dtype=np.float32)
    x = np.zeros(n, dtype=np.float32)
    got = np.asarray(maxplus_matvec(jnp.array(m), jnp.array(x)))
    assert np.all(got <= NEG / 2)


@pytest.mark.parametrize("block", [16, 32, 64, 128])
def test_maxplus_block_size_invariance(block):
    """Tiling must not change the result."""
    n = 128
    rng = np.random.default_rng(7)
    m = rand_cost_matrix(rng, n)
    x = rng.uniform(0, 10, n).astype(np.float32)
    want = ref.maxplus_matvec_ref(m, x)
    got = np.asarray(maxplus_matvec(jnp.array(m), jnp.array(x), block=block))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32, 64]),
    p=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxplus_hypothesis_sweep(n, p, seed):
    rng = np.random.default_rng(seed)
    m = rand_cost_matrix(rng, n, p_edge=p)
    x = rng.uniform(-1e3, 1e3, n).astype(np.float32)
    got = np.asarray(maxplus_matvec(jnp.array(m), jnp.array(x)))
    want = ref.maxplus_matvec_ref(m, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


# -------------------------------------------------------------------- EFT


@pytest.mark.parametrize("p,v", [(1, 4), (4, 8), (64, 8), (64, 16), (64, 32)])
def test_batch_eft_matches_ref(p, v):
    rng = np.random.default_rng(p * 100 + v)
    finish = rng.uniform(0, 50, p).astype(np.float32)
    comm = rng.uniform(0, 20, (p, v)).astype(np.float32)
    exec_t = rng.uniform(0.1, 30, v).astype(np.float32)
    avail = rng.uniform(0, 60, v).astype(np.float32)
    arrival = np.array([rng.uniform(0, 40)], dtype=np.float32)
    got = np.asarray(
        batch_eft(
            jnp.array(finish), jnp.array(comm), jnp.array(exec_t),
            jnp.array(avail), jnp.array(arrival),
        )
    )
    want = ref.batch_eft_ref(finish, comm, exec_t, avail, float(arrival[0]))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_batch_eft_padded_parents_ignored():
    """Padded parent slots (finish = NEG) must not influence the result."""
    rng = np.random.default_rng(0)
    v = 8
    finish_real = rng.uniform(0, 50, 3).astype(np.float32)
    comm_real = rng.uniform(0, 20, (3, v)).astype(np.float32)
    exec_t = rng.uniform(0.1, 30, v).astype(np.float32)
    avail = rng.uniform(0, 60, v).astype(np.float32)
    arrival = np.array([5.0], dtype=np.float32)

    finish_pad = np.full(64, NEG, dtype=np.float32)
    finish_pad[:3] = finish_real
    comm_pad = np.zeros((64, v), dtype=np.float32)
    comm_pad[:3] = comm_real

    got = np.asarray(
        batch_eft(
            jnp.array(finish_pad), jnp.array(comm_pad), jnp.array(exec_t),
            jnp.array(avail), jnp.array(arrival),
        )
    )
    want = ref.batch_eft_ref(finish_real, comm_real, exec_t, avail, 5.0)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_batch_eft_no_parents_uses_arrival_and_avail():
    v = 8
    finish = np.full(64, NEG, dtype=np.float32)
    comm = np.zeros((64, v), dtype=np.float32)
    exec_t = np.ones(v, dtype=np.float32)
    avail = np.arange(v, dtype=np.float32)
    arrival = np.array([3.0], dtype=np.float32)
    got = np.asarray(
        batch_eft(
            jnp.array(finish), jnp.array(comm), jnp.array(exec_t),
            jnp.array(avail), jnp.array(arrival),
        )
    )
    want = np.maximum(avail, 3.0) + 1.0
    np.testing.assert_allclose(got, want, rtol=1e-6)
