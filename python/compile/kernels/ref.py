"""Pure-jnp/numpy oracles for the Pallas kernels and the L2 model.

Everything here is written in the most obvious way possible (loops where
loops are clearest) — this file is the correctness ground truth that both
the Pallas kernels (pytest, build time) and the Rust native implementations
(parity fixtures) are checked against.
"""

import numpy as np

NEG = -1e30


def maxplus_matvec_ref(m: np.ndarray, x: np.ndarray) -> np.ndarray:
    """y[t] = max_c (m[t, c] + x[c]) — dense tropical matvec."""
    return np.max(m + x[None, :], axis=1)


def upward_rank_ref(m: np.ndarray, w: np.ndarray, depth: int) -> np.ndarray:
    """Fixed-point upward rank: r = w + max(0, maxplus(m, r)), iterated.

    ``m[t, c]`` is the (average) communication cost of edge t->c, ``NEG``
    where no edge; ``w`` the average execution cost.  ``depth`` iterations
    suffice for any DAG of height <= depth.
    """
    r = w.astype(np.float64).copy()
    for _ in range(depth):
        r = w + np.maximum(maxplus_matvec_ref(m, r), 0.0)
    return r


def downward_rank_ref(m: np.ndarray, w: np.ndarray, depth: int) -> np.ndarray:
    """Fixed-point downward rank over the transposed matrix.

    rank_d(t) = max_p ( rank_d(p) + w(p) + m[p, t] ), 0 for roots.
    """
    d = np.zeros_like(w, dtype=np.float64)
    mt = m.T
    for _ in range(depth):
        d = np.maximum(maxplus_matvec_ref(mt, d + w), 0.0)
    return d


def upward_rank_topo_ref(edges, w) -> np.ndarray:
    """Independent oracle: recursive-topological upward rank (no matrices).

    ``edges``: list of (u, v, cost).  Validates the fixed-point formulation
    itself, not just the kernel.
    """
    n = len(w)
    children = [[] for _ in range(n)]
    for u, v, c in edges:
        children[u].append((v, c))
    rank = [None] * n

    def rec(t):
        if rank[t] is not None:
            return rank[t]
        best = 0.0
        for c, cost in children[t]:
            best = max(best, cost + rec(c))
        rank[t] = w[t] + best
        return rank[t]

    for t in range(n):
        rec(t)
    return np.array(rank)


def downward_rank_topo_ref(edges, w) -> np.ndarray:
    """Independent oracle for the downward rank."""
    n = len(w)
    parents = [[] for _ in range(n)]
    for u, v, c in edges:
        parents[v].append((u, c))
    rank = [None] * n

    def rec(t):
        if rank[t] is not None:
            return rank[t]
        best = 0.0
        for p, cost in parents[t]:
            best = max(best, rec(p) + w[p] + cost)
        rank[t] = best
        return rank[t]

    for t in range(n):
        rec(t)
    return np.array(rank)


def batch_eft_ref(parent_finish, comm, exec_time, avail, arrival) -> np.ndarray:
    """Loop-form EFT oracle (see kernels/eft.py for the semantics)."""
    p, v = comm.shape
    out = np.zeros(v)
    for j in range(v):
        ready = arrival
        ready = max(ready, avail[j])
        for i in range(p):
            ready = max(ready, parent_finish[i] + comm[i, j])
        out[j] = ready + exec_time[j]
    return out


def allpairs_longest_ref(m: np.ndarray) -> np.ndarray:
    """All-pairs longest path oracle (repeated relaxation, O(N^4) worst).

    ``m[i, j]``: edge weight or NEG.  Diagonal of the result is 0.
    """
    n = m.shape[0]
    d = m.copy().astype(np.float64)
    for i in range(n):
        d[i, i] = max(d[i, i], 0.0)
    for _ in range(n):
        nd = d.copy()
        for i in range(n):
            nd[i] = np.maximum(nd[i], np.max(d[i][:, None] + d, axis=0))
        if np.allclose(nd, d):
            break
        d = nd
    return d
