"""Layer-1 Pallas kernel: tiled max-plus (tropical) matrix-vector product.

The upward/downward rank computation used by every list scheduler in the
paper (HEFT's ``rank_u``, CPOP's ``rank_u + rank_d``) is a fixed point of

    y[t] = max_c ( M[t, c] + x[c] )

over the DAG's average-cost matrix ``M`` (``-BIG`` where no edge).  This is
structurally a matmul with ``(+, x)`` replaced by ``(max, +)``, so we tile
it exactly like a TPU matmul: the grid walks ``(task-tile, child-tile)``
blocks, each ``(BLK_T, BLK_C)`` tile of ``M`` is streamed into VMEM once,
and a running maximum accumulates into the output tile.  On a real TPU the
``(max, +)`` contraction runs on the VPU (the MXU is ``(+, x)``-only); the
HBM<->VMEM schedule expressed by the BlockSpecs is unchanged.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO that
any backend (including the Rust PJRT CPU client) runs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# "minus infinity" for tropical algebra.  A true -inf poisons the padding
# lanes through (-inf + x) arithmetic; -1e30 survives additions with any
# realistic cost and still loses every max().
NEG = -1e30

# Default VMEM tile.  128 matches the TPU lane width; a (128, 128) f32 tile
# is 64 KiB, far under the ~16 MiB VMEM budget even with double-buffering.
DEFAULT_BLOCK = 128


def _maxplus_matvec_kernel(m_ref, x_ref, o_ref):
    """One (BLK_T, BLK_C) tile: o[t] = max(o[t], max_c(m[t,c] + x[c]))."""
    j = pl.program_id(1)
    partial = jnp.max(m_ref[...] + x_ref[...][None, :], axis=1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = jnp.maximum(o_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("block",))
def maxplus_matvec(m, x, *, block: int = DEFAULT_BLOCK):
    """Tropical matvec ``y[t] = max_c (m[t, c] + x[c])`` via Pallas.

    ``m``: (N, N) f32 cost matrix, ``NEG`` where no edge.
    ``x``: (N,) f32.
    Returns (N,) f32; rows with no finite entry yield ``<= NEG/2`` (caller
    clamps).  N must be a multiple of ``block`` or smaller than it.
    """
    n = m.shape[0]
    blk = min(block, n)
    assert n % blk == 0, f"N={n} not a multiple of block={blk}"
    grid = (n // blk, n // blk)
    return pl.pallas_call(
        _maxplus_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, blk), lambda i, j: (i, j)),
            pl.BlockSpec((blk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(m, x)
