"""Layer-1 Pallas kernel: batched earliest-finish-time (EFT) evaluation.

For one ready task ``t`` the list schedulers evaluate, over every node
``v`` of the heterogeneous network:

    ready[v] = max( arrival, avail[v], max_p( finish[p] + comm[p, v] ) )
    eft[v]   = ready[v] + exec[v]

where ``p`` ranges over the scheduled parents of ``t``, ``comm[p, v]`` is
the data-transfer time from parent ``p``'s node to ``v`` (0 on the same
node), ``avail[v]`` is when node ``v`` becomes free, and ``exec[v] =
c(t)/s(v)``.  This is the *append-at-end* EFT used by the MCT inner loop of
MinMin/MaxMin (the insertion-based variant needs a gap search and stays on
the Rust side).

Layout is (parents x nodes) so the node axis sits on the minor dimension —
on a TPU that is the 128-wide VPU lane axis; the parent reduction runs
in-register.  ``interpret=True`` for CPU-PJRT executability (see
``maxplus.py``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .maxplus import NEG


def _eft_kernel(finish_ref, comm_ref, exec_ref, avail_ref, arrival_ref, o_ref):
    # data-ready time per node: max over parents of finish[p] + comm[p, v].
    # Padded parent slots carry finish = NEG, so they lose every max.
    ready_data = jnp.max(finish_ref[...][:, None] + comm_ref[...], axis=0)
    start = jnp.maximum(
        jnp.maximum(ready_data, avail_ref[...]), arrival_ref[0]
    )
    o_ref[...] = start + exec_ref[...]


@jax.jit
def batch_eft(parent_finish, comm, exec_time, avail, arrival):
    """EFT of one task on every node, vectorized over the node axis.

    parent_finish: (P,) f32, ``NEG`` in padded slots.
    comm:          (P, V) f32 transfer times (anything in padded rows).
    exec_time:     (V,) f32 execution times c(t)/s(v).
    avail:         (V,) f32 node-free times.
    arrival:       (1,) f32 the owning graph's arrival time.
    Returns (V,) f32 earliest finish times.
    """
    p, v = comm.shape
    return pl.pallas_call(
        _eft_kernel,
        out_shape=jax.ShapeDtypeStruct((v,), jnp.float32),
        interpret=True,
    )(parent_finish, comm, exec_time, avail, arrival)
