"""Layer-1 Pallas kernel: tiled max-plus matrix-matrix product, used for
the all-pairs longest-path (critical-path) matrix.

``D[i, j]`` = length of the longest weighted path from task i to task j
(edge weight = mean comm cost + target's mean exec cost), computed by
repeated tropical squaring: ``D_{2k} = D_k (max,+) D_k``.  ``log2(N)``
squarings close any DAG of ≤ N vertices.  The coordinator's *slack
analysis* tool consumes this matrix (distance to every sink vs the
critical path pins each task's scheduling slack).

Tiling mirrors a TPU matmul: grid (i-tile, j-tile, k-tile) with the
k-axis innermost, accumulating a running max into the output tile in
VMEM.  ``interpret=True`` as everywhere (CPU PJRT cannot run Mosaic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .maxplus import NEG

DEFAULT_BLOCK = 64


def _maxplus_matmul_kernel(a_ref, b_ref, o_ref):
    """One (BI, BJ) output tile: o = max(o, max_k(a[:, k] + b[k, :]))."""
    k = pl.program_id(2)
    # (BI, BK) + (BK, BJ) → (BI, BK, BJ) reduced over K
    partial = jnp.max(a_ref[...][:, :, None] + b_ref[...][None, :, :], axis=1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k > 0)
    def _acc():
        o_ref[...] = jnp.maximum(o_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("block",))
def maxplus_matmul(a, b, *, block: int = DEFAULT_BLOCK):
    """Tropical matmul ``c[i,j] = max_k (a[i,k] + b[k,j])`` via Pallas."""
    n = a.shape[0]
    blk = min(block, n)
    assert n % blk == 0, f"N={n} not a multiple of block={blk}"
    g = n // blk
    return pl.pallas_call(
        _maxplus_matmul_kernel,
        grid=(g, g, g),
        in_specs=[
            pl.BlockSpec((blk, blk), lambda i, j, k: (i, k)),
            pl.BlockSpec((blk, blk), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((blk, blk), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, b)


def allpairs_longest(m, n_squarings):
    """All-pairs longest path by repeated tropical squaring.

    ``m``: (N, N) edge-weight matrix, NEG where no edge; the result has
    0 on the diagonal (empty path) and NEG where unreachable.
    """
    n = m.shape[0]
    eye = jnp.where(
        jnp.eye(n, dtype=bool), 0.0, jnp.float32(NEG)
    )
    d = jnp.maximum(m, eye)  # paths of length <= 1

    def body(_, d):
        return maxplus_matmul(d, d)

    return jax.lax.fori_loop(0, n_squarings, body, d)
