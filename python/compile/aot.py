"""AOT lowering: JAX (L2 + L1) -> HLO *text* artifacts for the Rust runtime.

HLO text — NOT ``lowered.compile()`` output and NOT a serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 emits protos
with 64-bit instruction ids which the published ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Artifacts (one per size bucket; the Rust runtime pads to the smallest
fitting bucket):

  ranks_n{N}.hlo.txt   (m: f32[N,N], w: f32[N], depth: i32) -> (up, down)
  eft_p{P}_v{V}.hlo.txt (finish: f32[P], comm: f32[P,V], exec: f32[V],
                         avail: f32[V], arrival: f32[1]) -> f32[V]

Run via ``make artifacts`` (no-op when outputs are newer than inputs);
Python never runs after this point.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.appairs import allpairs_longest

RANK_BUCKETS = (32, 64, 128, 256)
EFT_BUCKETS = ((64, 8), (64, 16), (64, 32))
ALLPAIRS_BUCKETS = (32, 64, 128)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_ranks(n: int) -> str:
    spec_m = jax.ShapeDtypeStruct((n, n), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((n,), jnp.float32)
    spec_d = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(model.ranks_combined).lower(spec_m, spec_w, spec_d)
    return to_hlo_text(lowered)


def lower_allpairs(n: int) -> str:
    import math

    spec_m = jax.ShapeDtypeStruct((n, n), jnp.float32)
    squarings = int(math.ceil(math.log2(n)))
    lowered = jax.jit(
        lambda m: allpairs_longest(m, squarings)
    ).lower(spec_m)
    return to_hlo_text(lowered)


def lower_eft(p: int, v: int) -> str:
    sf = jax.ShapeDtypeStruct((p,), jnp.float32)
    sc = jax.ShapeDtypeStruct((p, v), jnp.float32)
    sv = jax.ShapeDtypeStruct((v,), jnp.float32)
    sa = jax.ShapeDtypeStruct((1,), jnp.float32)
    lowered = jax.jit(model.batch_eft).lower(sf, sc, sv, sv, sa)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="also touch this sentinel path")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "ranks": [],
        "eft": [],
        "allpairs": [],
        "format": "hlo-text",
        "neg": -1e30,
    }

    for n in RANK_BUCKETS:
        path = os.path.join(args.out_dir, f"ranks_n{n}.hlo.txt")
        text = lower_ranks(n)
        with open(path, "w") as f:
            f.write(text)
        manifest["ranks"].append({"n": n, "file": os.path.basename(path)})
        print(f"wrote {path} ({len(text)} chars)")

    for p, v in EFT_BUCKETS:
        path = os.path.join(args.out_dir, f"eft_p{p}_v{v}.hlo.txt")
        text = lower_eft(p, v)
        with open(path, "w") as f:
            f.write(text)
        manifest["eft"].append({"p": p, "v": v, "file": os.path.basename(path)})
        print(f"wrote {path} ({len(text)} chars)")

    for n in ALLPAIRS_BUCKETS:
        path = os.path.join(args.out_dir, f"allpairs_n{n}.hlo.txt")
        text = lower_allpairs(n)
        with open(path, "w") as f:
            f.write(text)
        manifest["allpairs"].append({"n": n, "file": os.path.basename(path)})
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")

    if args.out:
        # Makefile sentinel compatibility: ensure the named target exists.
        if not os.path.exists(args.out):
            with open(args.out, "w") as f:
                f.write("see manifest.json\n")


if __name__ == "__main__":
    main()
