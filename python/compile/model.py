"""Layer-2 JAX model: the schedulers' numeric hot-spot as one compute graph.

The paper's list schedulers (HEFT, CPOP) spend their priority phase on the
upward/downward *rank* fixed point over the DAG's average-cost matrix, and
their assignment phase on batched EFT evaluations.  This module expresses
both as jitted JAX functions calling the Layer-1 Pallas kernels, so that
``aot.py`` can lower a single HLO program per size bucket for the Rust
coordinator to execute via PJRT.

Conventions (shared with the Rust runtime — see rust/src/runtime/):
  * All matrices are padded to the bucket size N.  Padded tasks have
    ``w = 0`` and no edges (`M` rows/cols = NEG), which makes their ranks
    exactly 0 and leaves real ranks untouched.
  * ``depth`` is passed as an i32 operand so the while-loop runs only as
    many max-plus sweeps as the DAG's height (not N) — the caller knows the
    height from its own topological sort.
"""

import jax
import jax.numpy as jnp

from .kernels.maxplus import maxplus_matvec, NEG
from .kernels.eft import batch_eft  # noqa: F401  (re-exported entry point)


def upward_rank(m, w, depth):
    """HEFT priority: r(t) = w(t) + max_c ( m[t,c] + r(c) ), sinks r = w.

    Fixed point from ``r0 = w`` — after k sweeps every task of height <= k
    holds its final value, so ``depth`` sweeps converge any padded DAG.
    """

    def body(_, r):
        return w + jnp.maximum(maxplus_matvec(m, r), 0.0)

    return jax.lax.fori_loop(0, depth, body, w)


def downward_rank(m, w, depth):
    """CPOP's second component: d(t) = max_p ( d(p) + w(p) + m[p,t] ).

    Roots have d = 0.  Runs the same max-plus kernel on the transposed
    matrix; the transpose is materialized once outside the loop so XLA
    hoists it out of the while body.
    """
    mt = m.T

    def body(_, d):
        return jnp.maximum(maxplus_matvec(mt, d + w), 0.0)

    return jax.lax.fori_loop(0, depth, body, jnp.zeros_like(w))


def ranks_combined(m, w, depth):
    """One artifact serving both HEFT (up) and CPOP (up + down).

    Returns ``(rank_up, rank_down)``; CPOP's priority is their sum, and its
    critical-path value is ``max_t rank_up(t)`` over entry tasks — both are
    cheap reductions the Rust side performs on the returned vectors.
    """
    return upward_rank(m, w, depth), downward_rank(m, w, depth)
