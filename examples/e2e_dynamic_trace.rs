//! END-TO-END driver: the full system on a realistic mixed trace.
//!
//! Builds a 120-graph workload interleaving all three §VI dataset
//! families (synthetic, RIoTBench pipelines, WFCommons workflows) with
//! Poisson arrivals on a 6-node heterogeneous network, then runs the
//! complete 30-variant scheduler grid — with the XLA/PJRT-compiled
//! Pallas rank artifacts on the HEFT/CPOP hot path when available —
//! §II-validates and replay-checks every schedule, and reports the
//! paper's headline comparisons.  Results are recorded in
//! EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_dynamic_trace
//! ```

use std::rc::Rc;
use std::time::Instant;

use dts::coordinator::{paper_grid, Coordinator, DynamicProblem, Policy};
use dts::metrics::Metric;
use dts::network::Network;
use dts::prng::Xoshiro256pp;
use dts::report;
use dts::runtime::{XlaRanks, XlaRuntime};
use dts::schedule::validate;
use dts::schedulers::{Cpop, Heft, Scheduler, SchedulerKind};
use dts::sim::replay;
use dts::stats::mean;
use dts::workloads::{arrivals_for, riotbench, synthetic, wfcommons, DEFAULT_LOAD};

fn main() {
    let t_start = Instant::now();
    let seed = 2026;
    let mut rng = Xoshiro256pp::seed_from_u64(seed);

    // ---- the trace: 120 graphs, three families interleaved -------------
    let mut graphs = Vec::new();
    graphs.extend(synthetic::generate(48, &mut rng));
    graphs.extend(riotbench::generate(48, &mut rng));
    graphs.extend(wfcommons::generate(24, &mut rng));
    rng.shuffle(&mut graphs);

    let network = Network::default_eval(&mut rng);
    let arrivals = arrivals_for(&graphs, &network, &mut rng, DEFAULT_LOAD);
    let problem = DynamicProblem::new(
        network,
        arrivals.into_iter().zip(graphs).collect(),
    );
    println!(
        "trace: {} graphs / {} tasks on {} nodes, arrivals over [0, {:.0}]",
        problem.graphs.len(),
        problem.total_tasks(),
        problem.network.n_nodes(),
        problem.graphs.last().unwrap().0
    );

    // ---- optional XLA acceleration for HEFT/CPOP ranks ------------------
    let xla = XlaRuntime::load("artifacts").ok().map(Rc::new);
    println!(
        "xla runtime: {}",
        if xla.is_some() { "loaded (HEFT/CPOP ranks via PJRT)" } else { "unavailable — native ranks" }
    );

    // ---- the 30-variant grid -------------------------------------------
    let mut rows: Vec<(String, dts::metrics::MetricRow)> = Vec::new();
    for v in paper_grid() {
        let sched: Box<dyn Scheduler> = match (&xla, v.kind) {
            (Some(rt), SchedulerKind::Heft) => Box::new(Heft::new(XlaRanks::new(rt.clone()))),
            (Some(rt), SchedulerKind::Cpop) => Box::new(Cpop::new(XlaRanks::new(rt.clone()))),
            _ => v.kind.make(seed),
        };
        let mut c = Coordinator::new(v.policy, sched);
        let res = c.run(&problem);
        let viol = validate(&res.schedule, &problem.graphs, &problem.network);
        assert!(viol.is_empty(), "{}: {:?}", v.label(), &viol[..viol.len().min(2)]);
        let rep = replay(&res.schedule, &problem.graphs, &problem.network);
        assert!(rep.errors.is_empty(), "{}: {:?}", v.label(), &rep.errors[..rep.errors.len().min(2)]);
        let m = res.metrics(&problem);
        println!(
            "  {:<12} makespan {:>8}  mean-mk {:>8}  flow {:>8}  util {:>6}  rt {:>8.3}s",
            v.label(),
            report::fmt(m.total_makespan),
            report::fmt(m.mean_makespan),
            report::fmt(m.mean_flowtime),
            report::fmt(m.mean_utilization),
            m.runtime_s,
        );
        rows.push((v.label(), m));
    }

    // ---- headline analysis ----------------------------------------------
    let get = |label: &str, m: Metric| {
        rows.iter()
            .find(|(l, _)| l == label)
            .map(|(_, r)| r.get(m))
            .unwrap()
    };
    let p_mk = get("P-HEFT", Metric::TotalMakespan);
    let np_mk = get("NP-HEFT", Metric::TotalMakespan);
    let k5_mk = get("5P-HEFT", Metric::TotalMakespan);
    let p_ft = get("P-HEFT", Metric::MeanFlowtime);
    let np_ft = get("NP-HEFT", Metric::MeanFlowtime);
    let k5_ft = get("5P-HEFT", Metric::MeanFlowtime);
    let p_rt = get("P-HEFT", Metric::Runtime);
    let np_rt = get("NP-HEFT", Metric::Runtime);
    let k5_rt = get("5P-HEFT", Metric::Runtime);

    println!("\n=== headline (paper §VII) ===");
    println!("makespan  NP/P = {:.3}   5P/P = {:.3}  (moderate preemption ≈ full)", np_mk / p_mk, k5_mk / p_mk);
    println!("flowtime  P/NP = {:.3}   5P/NP = {:.3} (moderate preemption keeps fairness)", p_ft / np_ft, k5_ft / np_ft);
    println!("runtime   P/NP = {:.3}   5P/NP = {:.3} (moderate preemption keeps speed)", p_rt / np_rt, k5_rt / np_rt);

    // average utilization of informed schedulers
    let util: Vec<f64> = rows
        .iter()
        .filter(|(l, _)| l.contains("HEFT") || l.contains("CPOP"))
        .map(|(_, m)| m.mean_utilization)
        .collect();
    println!("mean utilization over HEFT/CPOP variants: {:.3}", mean(&util));
    println!("\ncompleted in {:.1}s — all 30 schedules valid.", t_start.elapsed().as_secs_f64());
}
