//! The paper's Fig 1 story, reproduced end to end: small tasks from an
//! earlier graph block a later graph's huge root under non-preemptive
//! scheduling; full preemption fixes the makespan but hurts fairness;
//! Last-5 gets both.
//!
//! ```sh
//! cargo run --release --example adversarial_preemption
//! ```

use dts::coordinator::{Coordinator, DynamicProblem, Policy};
use dts::graph::Gid;
use dts::metrics::Metric;
use dts::report;
use dts::schedulers::SchedulerKind;
use dts::workloads::Dataset;

fn ascii_gantt(problem: &DynamicProblem, res: &dts::coordinator::DynamicResult, width: usize) {
    let span = res.metrics(problem).total_makespan.max(1e-9);
    for v in 0..problem.network.n_nodes() {
        let mut row = vec![b'.'; width];
        for (gid, a) in res.schedule.iter() {
            if a.node != v {
                continue;
            }
            let s = ((a.start / span) * width as f64) as usize;
            let e = (((a.finish / span) * width as f64) as usize).min(width);
            let ch = b'A' + (gid.graph as u8 % 26);
            for c in row.iter_mut().take(e).skip(s.min(width)) {
                *c = ch;
            }
        }
        println!("  node {v}: {}", String::from_utf8_lossy(&row));
    }
}

fn main() {
    // small adversarial trace: each letter in the gantt is one graph;
    // graphs are heavy-root out-trees (§VI.D, CCR 0.2)
    let problem = Dataset::Adversarial.instance(8, 7);
    println!(
        "adversarial trace: {} graphs / {} tasks on {} nodes\n",
        problem.graphs.len(),
        problem.total_tasks(),
        problem.network.n_nodes()
    );

    let mut summary = Vec::new();
    for policy in [Policy::Preemptive, Policy::LastK(5), Policy::NonPreemptive] {
        let mut c = Coordinator::new(policy, SchedulerKind::Heft.make(0));
        let res = c.run(&problem);
        let m = res.metrics(&problem);
        println!("=== {}  (cf. Fig 1) ===", c.label());
        ascii_gantt(&problem, &res, 100);
        println!(
            "  makespan {:>8}   mean-makespan {:>8}   flowtime {:>8}   util {:>6}\n",
            report::fmt(m.total_makespan),
            report::fmt(m.mean_makespan),
            report::fmt(m.mean_flowtime),
            report::fmt(m.mean_utilization),
        );
        summary.push((c.label(), m));
    }

    // the §VII adversarial claims, on this instance
    let g = |label: &str, metric: Metric| {
        summary
            .iter()
            .find(|(l, _)| l.starts_with(label))
            .map(|(_, m)| m.get(metric))
            .unwrap()
    };
    println!("NP/P makespan ratio : {:.2}× (paper: ≈1.6×)",
        g("NP-HEFT", Metric::TotalMakespan) / g("P-HEFT", Metric::TotalMakespan));
    println!("5P vs P makespan    : {:.2}×",
        g("5P-HEFT", Metric::TotalMakespan) / g("P-HEFT", Metric::TotalMakespan));
    println!("5P vs NP flowtime   : {:.2}×",
        g("5P-HEFT", Metric::MeanFlowtime) / g("NP-HEFT", Metric::MeanFlowtime));

    // show one concrete blocking root: the last graph's root start per policy
    let last = problem.graphs.len() - 1;
    for policy in [Policy::Preemptive, Policy::NonPreemptive] {
        let mut c = Coordinator::new(policy, SchedulerKind::Heft.make(0));
        let res = c.run(&problem);
        let root = res.schedule.get(Gid::new(last, 0)).unwrap();
        println!(
            "{}: last graph's heavy root runs [{:.1}, {:.1}] on node {}",
            c.label(),
            root.start,
            root.finish,
            root.node
        );
    }
}
