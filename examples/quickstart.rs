//! Quickstart: build two task graphs by hand, a small heterogeneous
//! network, and run a Last-5 preemptive HEFT coordinator over their
//! arrivals.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dts::coordinator::{Coordinator, DynamicProblem, Policy};
use dts::graph::{Gid, GraphBuilder};
use dts::network::Network;
use dts::schedule::validate;
use dts::schedulers::SchedulerKind;

fn main() {
    // --- a 4-task diamond that arrives at t = 0 -------------------------
    let mut b = GraphBuilder::new("etl_job");
    let ingest = b.task(8.0); //   ingest
    let clean = b.task(4.0); //   /      \
    let enrich = b.task(6.0); //  clean  enrich
    let publish = b.task(2.0); //   \      /
    b.edge(ingest, clean, 3.0) //   publish
        .edge(ingest, enrich, 5.0)
        .edge(clean, publish, 1.0)
        .edge(enrich, publish, 1.0);
    let g0 = b.build().expect("valid DAG");

    // --- a 3-task chain that arrives at t = 2 ---------------------------
    let mut b = GraphBuilder::new("report_job");
    let q = b.task(3.0);
    let agg = b.task(5.0);
    let render = b.task(2.0);
    b.edge(q, agg, 2.0).edge(agg, render, 2.0);
    let g1 = b.build().expect("valid DAG");

    // --- 3 nodes: one fast, two slow; links of strength 2 ---------------
    let network = Network::new(
        vec![2.0, 1.0, 1.0],
        vec![
            0.0, 2.0, 2.0, //
            2.0, 0.0, 2.0, //
            2.0, 2.0, 0.0,
        ],
    );

    let problem = DynamicProblem::new(network, vec![(0.0, g0), (2.0, g1)]);

    // --- Last-5 preemptive HEFT -----------------------------------------
    let mut coordinator = Coordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0));
    println!("running {} ...\n", coordinator.label());
    let result = coordinator.run(&problem);

    // print the schedule graph-by-graph
    for (gi, (arrival, g)) in problem.graphs.iter().enumerate() {
        println!("graph {} ({}), arrived at t={arrival}:", gi, g.name());
        for t in 0..g.n_tasks() {
            let a = result.schedule.get(Gid::new(gi, t)).unwrap();
            println!(
                "  task {t}: node {}  [{:.2}, {:.2}]",
                a.node, a.start, a.finish
            );
        }
    }

    // metrics + §II validation
    let m = result.metrics(&problem);
    println!("\ntotal makespan   : {:.2}", m.total_makespan);
    println!("mean makespan    : {:.2}", m.mean_makespan);
    println!("mean flowtime    : {:.2}", m.mean_flowtime);
    println!("mean utilization : {:.3}", m.mean_utilization);
    let violations = validate(&result.schedule, &problem.graphs, &problem.network);
    println!("§II violations   : {}", violations.len());
    assert!(violations.is_empty());
    println!("\nOK — see examples/e2e_dynamic_trace.rs for the full system.");
}
