//! The three-layer path in isolation: load the AOT-compiled JAX+Pallas
//! rank artifact (L1 kernel → L2 fixed point → HLO text), execute it via
//! the PJRT CPU client from Rust (L3), check parity against the native
//! provider, and time both.
//!
//! ```sh
//! make artifacts && cargo run --release --example xla_accelerated_ranking
//! ```

use std::rc::Rc;
use std::time::Instant;

use dts::coordinator::{Coordinator, Policy};
use dts::network::Network;
use dts::prng::Xoshiro256pp;
use dts::runtime::{XlaRanks, XlaRuntime};
use dts::schedulers::{Heft, NativeRanks, RankProvider, SchedulerKind};
use dts::workloads::Dataset;

fn main() {
    let rt = match XlaRuntime::load("artifacts") {
        Ok(rt) => Rc::new(rt),
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!(
        "loaded {} with rank buckets {:?}\n",
        rt.artifacts_dir().display(),
        rt.rank_buckets()
    );

    // ---- parity on a real composite problem ----------------------------
    let prob = Dataset::Synthetic.instance(20, 5);
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let net = Network::default_eval(&mut rng);

    // build one large composite via a preemptive run's biggest event
    let mut c = Coordinator::new(Policy::Preemptive, SchedulerKind::Heft.make(0));
    let res = c.run(&prob);
    let peak = res.events.iter().map(|e| e.n_pending).max().unwrap();
    println!("peak composite size in a P-HEFT run over 20 graphs: {peak} tasks");

    // parity + timing on random problems across bucket sizes
    for &n in &[24usize, 60, 120, 250] {
        let mut tasks = Vec::new();
        for i in 0..n {
            tasks.push(dts::schedulers::PTask {
                gid: dts::graph::Gid::new(0, i),
                cost: rng.uniform(1.0, 40.0),
                ready: 0.0,
                preds: Vec::new(),
                succs: Vec::new(),
            });
        }
        for i in 0..n {
            for j in (i + 1)..n.min(i + 16) {
                if rng.next_f64() < 0.2 {
                    let d = rng.uniform(0.5, 10.0);
                    tasks[i].succs.push((j, d));
                    tasks[j].preds.push(dts::schedulers::Pred::Pending { idx: i, data: d });
                }
            }
        }
        let problem = dts::schedulers::Problem { tasks };

        let t0 = Instant::now();
        let native = NativeRanks.ranks(&problem, &net);
        let dt_native = t0.elapsed();

        let mut xr = XlaRanks::new(rt.clone());
        let t0 = Instant::now();
        let xla = xr.ranks(&problem, &net);
        let dt_xla = t0.elapsed();

        let max_rel = (0..n)
            .map(|i| (native.up[i] - xla.up[i]).abs() / (1.0 + native.up[i].abs()))
            .fold(0.0f64, f64::max);
        println!(
            "n={n:>4}: native {:>9.1?}  xla {:>9.1?}  max rel err {:.2e}  (bucket {})",
            dt_native,
            dt_xla,
            max_rel,
            rt.rank_bucket(n).unwrap()
        );
        assert!(max_rel < 1e-4);
    }

    // ---- full coordinator with the XLA provider -------------------------
    let mut c = Coordinator::new(
        Policy::LastK(5),
        Box::new(Heft::new(XlaRanks::new(rt.clone()))),
    );
    let t0 = Instant::now();
    let res = c.run(&prob);
    let m = res.metrics(&prob);
    println!(
        "\n5P-HEFT[xla] over 20 graphs: makespan {:.1}, {} events in {:.2?}",
        m.total_makespan,
        res.events.len(),
        t0.elapsed()
    );
    println!("note: on this CPU testbed the PJRT dispatch dominates small problems —");
    println!("      see EXPERIMENTS.md §Perf for the measured crossover analysis.");
}
