//! IoT streaming scenario (the workload class the paper's introduction
//! motivates): RIoTBench pipelines arriving at a high rate onto a small
//! edge cluster.  Compares responsiveness (mean makespan), fairness
//! (mean flowtime) and throughput proxy (total makespan) across the
//! preemption axis for HEFT and MinMin.
//!
//! ```sh
//! cargo run --release --example iot_pipeline
//! ```

use dts::coordinator::{Coordinator, DynamicProblem, Policy};
use dts::network::Network;
use dts::prng::Xoshiro256pp;
use dts::report;
use dts::schedulers::SchedulerKind;
use dts::stats::TruncatedGaussian;
use dts::workloads::{arrivals_for, riotbench};

fn main() {
    let mut rng = Xoshiro256pp::seed_from_u64(42);

    // edge cluster: 4 constrained nodes, one beefier gateway
    let speed_dist = TruncatedGaussian::new(0.8, 0.2, 0.4, 1.2);
    let link_dist = TruncatedGaussian::new(0.8, 0.3, 0.3, 1.5);
    let mut net = Network::generate(5, &speed_dist, &link_dist, &mut rng);
    // hand the gateway more speed by regenerating until node 0 is fastest
    while (1..5).any(|v| net.speed(v) > net.speed(0)) {
        net = Network::generate(5, &speed_dist, &link_dist, &mut rng);
    }

    // 80 pipelines at high arrival rate (load 0.3 → heavy overlap)
    let pipelines = riotbench::generate(80, &mut rng);
    let arrivals = arrivals_for(&pipelines, &net, &mut rng, 0.3);
    let problem = DynamicProblem::new(net, arrivals.into_iter().zip(pipelines).collect());
    println!(
        "IoT trace: {} pipelines / {} operators on {} edge nodes (gateway speed {:.2})\n",
        problem.graphs.len(),
        problem.total_tasks(),
        problem.network.n_nodes(),
        problem.network.speed(0),
    );

    println!(
        "{:<12} {:>10} {:>14} {:>12} {:>8} {:>10}",
        "variant", "makespan", "mean-makespan", "flowtime", "util", "sched-ms"
    );
    for kind in [SchedulerKind::Heft, SchedulerKind::MinMin] {
        for policy in [
            Policy::NonPreemptive,
            Policy::LastK(2),
            Policy::LastK(5),
            Policy::LastK(10),
            Policy::Preemptive,
        ] {
            let mut c = Coordinator::new(policy, kind.make(0));
            let res = c.run(&problem);
            let m = res.metrics(&problem);
            println!(
                "{:<12} {:>10} {:>14} {:>12} {:>8} {:>10.1}",
                c.label(),
                report::fmt(m.total_makespan),
                report::fmt(m.mean_makespan),
                report::fmt(m.mean_flowtime),
                report::fmt(m.mean_utilization),
                m.runtime_s * 1e3,
            );
        }
        println!();
    }

    println!("reading: NP keeps pipelines compact (low flowtime);");
    println!("         moderate K recovers most of P's makespan without P's flowtime cost.");
}
