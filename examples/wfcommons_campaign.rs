//! Scientific-workflow campaign (§VI.C workloads): a 50-workflow
//! WFCommons mix on a mid-size cluster, focusing on CPOP — the paper's
//! second list heuristic — across the preemption axis, with per-workflow
//! response statistics.
//!
//! ```sh
//! cargo run --release --example wfcommons_campaign
//! ```

use dts::coordinator::{Coordinator, Policy};
use dts::graph::Gid;
use dts::report;
use dts::schedulers::SchedulerKind;
use dts::stats::{mean, median, std_dev};
use dts::workloads::Dataset;

fn main() {
    let problem = Dataset::WfCommons.instance(50, 11);
    println!(
        "campaign: {} workflows / {} tasks on {} nodes\n",
        problem.graphs.len(),
        problem.total_tasks(),
        problem.network.n_nodes()
    );

    for policy in [
        Policy::NonPreemptive,
        Policy::LastK(5),
        Policy::LastK(20),
        Policy::Preemptive,
    ] {
        let mut c = Coordinator::new(policy, SchedulerKind::Cpop.make(0));
        let res = c.run(&problem);
        let m = res.metrics(&problem);

        // per-workflow response times (finish - arrival)
        let responses: Vec<f64> = problem
            .graphs
            .iter()
            .enumerate()
            .map(|(gi, (arrival, g))| {
                (0..g.n_tasks())
                    .map(|t| res.schedule.get(Gid::new(gi, t)).unwrap().finish)
                    .fold(f64::NEG_INFINITY, f64::max)
                    - arrival
            })
            .collect();

        println!("=== {} ===", c.label());
        println!(
            "  campaign makespan {:>9}   utilization {:>6}   sched runtime {:>8.1} ms",
            report::fmt(m.total_makespan),
            report::fmt(m.mean_utilization),
            m.runtime_s * 1e3
        );
        println!(
            "  workflow response: mean {:>9}  median {:>9}  std {:>9}  worst {:>9}",
            report::fmt(mean(&responses)),
            report::fmt(median(&responses)),
            report::fmt(std_dev(&responses)),
            report::fmt(responses.iter().cloned().fold(f64::NEG_INFINITY, f64::max)),
        );

        // fairness tail: how many workflows waited > 2× median?
        let med = median(&responses);
        let tail = responses.iter().filter(|&&r| r > 2.0 * med).count();
        println!("  workflows delayed >2× median: {tail}/{}\n", responses.len());
    }

    println!("reading: WFCommons' long critical paths shrink the NP↔P gap (cf. §VII.A),");
    println!("         and moderate preemption trims the response-time tail.");
}
