//! Preemption policy engine tour: drive the reactive runtime with the
//! stock controllers (fixed Last-K, token-budgeted, AIMD-adaptive,
//! cooldown-wrapped), sweep the joint k × θ × budget grid on one
//! dataset, and plug in a hand-written custom controller — the
//! parsimonious-preemption experiment of the ROADMAP in ~100 lines.
//!
//! Run with: `cargo run --example policy_engine`

use dts::coordinator::Policy;
use dts::experiments::{run_policy_sweep_parallel, PolicyScenario, PolicySweepConfig};
use dts::metrics::Metric;
use dts::policy::{Decision, FinishObservation, PolicySpec, PreemptionPolicy, Scope};
use dts::schedulers::SchedulerKind;
use dts::sim::{Reaction, ReactiveCoordinator, SimConfig};
use dts::workloads::Dataset;

/// A custom controller: *one* full-width replan the first time a task
/// runs more than double its estimate, then silence — the "panic
/// button" a production operator might wire up.
struct PanicOnce {
    fired: bool,
}

impl PreemptionPolicy for PanicOnce {
    fn label(&self) -> String {
        "panic-once".to_string()
    }

    fn on_finish(&mut self, obs: &FinishObservation) -> Decision {
        if !self.fired && obs.is_straggler(1.0) {
            self.fired = true;
            Decision::Reschedule(Scope::last_k(obs.arrived))
        } else {
            Decision::Hold
        }
    }
}

fn main() {
    // --- 1. the joint k × θ × budget sweep (what `dts policy` runs) ---
    let noise = 0.35;
    let mut scenarios = vec![PolicyScenario {
        noise_std: noise,
        spec: PolicySpec::None,
    }];
    for k in [1, 3, 5] {
        scenarios.push(PolicyScenario {
            noise_std: noise,
            spec: PolicySpec::FixedLastK { k, threshold: 0.25 },
        });
        scenarios.push(PolicyScenario {
            noise_std: noise,
            spec: PolicySpec::Budgeted {
                k,
                threshold: 0.25,
                rate: 0.02,
                burst: 4.0,
            },
        });
    }
    scenarios.push(PolicyScenario {
        noise_std: noise,
        spec: PolicySpec::AdaptiveK {
            k0: 1,
            k_max: 10,
            threshold: 0.25,
            target_stretch: 1.5,
        },
    });
    scenarios.push(PolicyScenario {
        noise_std: noise,
        spec: PolicySpec::Cooldown {
            cooldown: 25.0,
            inner: Box::new(PolicySpec::FixedLastK {
                k: 3,
                threshold: 0.25,
            }),
        },
    });

    let cfg = PolicySweepConfig {
        dataset: Dataset::Synthetic,
        n_graphs: 20,
        trials: 2,
        seed: 7,
        load: dts::workloads::DEFAULT_LOAD,
        variant: dts::coordinator::Variant::parse("5P-HEFT").unwrap(),
        scenario: dts::workloads::Scenario::default(),
        scenarios,
    };
    let result = run_policy_sweep_parallel(&cfg, 4);
    println!("## k × θ × budget sweep — synthetic, 5P-HEFT, σ{noise}\n");
    println!("{}", result.summary_table());

    // the parsimonious-preemption reading: how much of the uncapped
    // controller's makespan win does a small budget keep?
    let find = |needle: &str| {
        result
            .labels
            .iter()
            .position(|l| l.contains(needle))
            .unwrap()
    };
    let mk = |si: usize| result.realized_mean(si, Metric::TotalMakespan);
    let (none, full, budget) = (mk(find("none")), mk(find("L3@")), mk(find("B3@")));
    println!(
        "makespan: no-reaction {:.1}, uncapped L3 {:.1}, budgeted B3 {:.1} \
         (budget keeps {:.0}% of the win)",
        none,
        full,
        budget,
        if none > full {
            100.0 * (none - budget) / (none - full)
        } else {
            100.0
        }
    );

    // --- 2. a custom controller through the same runtime ---
    let prob = Dataset::RiotBench.instance(12, 3);
    let sim_cfg = SimConfig {
        noise_std: noise,
        noise_seed: 11,
        reaction: Reaction::None,
        record_frozen: false,
        full_refresh: false,
    };
    let mut rc = ReactiveCoordinator::with_policy(
        Policy::LastK(5),
        SchedulerKind::Heft.make(0),
        sim_cfg,
        Box::new(PanicOnce { fired: false }),
    );
    println!("\n## custom controller: {}", rc.label());
    let res = rc.run(&prob);
    let cost = res.preemption_cost();
    println!(
        "realized makespan {:.1}; {} replans ({} straggler), {} tasks reverted, \
         {:.3} ms replanning",
        res.metrics(&prob).total_makespan,
        cost.replans,
        cost.straggler_replans,
        cost.reverted_tasks,
        cost.replan_wall_s * 1e3
    );
}
