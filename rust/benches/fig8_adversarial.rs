//! Fig 8 — the adversarial instance, all five metrics (a–e).
//!
//! Checks and prints the paper's §VII headline: NP-HEFT's total makespan
//! is ≈1.6× P-HEFT's, while partially preemptive variants sit near P on
//! makespan/utilization and near NP on flowtime/runtime.

#[path = "util/mod.rs"]
mod util;

use dts::metrics::Metric;
use dts::workloads::Dataset;

fn main() {
    let r = util::sweep(Dataset::Adversarial);
    util::print_figure("Fig 8a — Normalized Total Makespan", &r, Metric::TotalMakespan);
    util::print_figure("Fig 8b — Normalized Mean Makespan", &r, Metric::MeanMakespan);
    util::print_figure("Fig 8c — Normalized Mean Flowtime", &r, Metric::MeanFlowtime);
    util::print_figure("Fig 8d — Normalized Runtime", &r, Metric::Runtime);
    util::print_figure("Fig 8e — Utilization", &r, Metric::Utilization);

    // headline ratio of §VII.A
    let p = r.value_of("P-HEFT", Metric::TotalMakespan).unwrap();
    let np = r.value_of("NP-HEFT", Metric::TotalMakespan).unwrap();
    println!(
        "\nheadline: NP-HEFT / P-HEFT total makespan = {:.2}× (paper: ≈1.6×)",
        np / p
    );
}
