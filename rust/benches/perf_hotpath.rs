//! §Perf micro-benchmarks of the L3 scheduling hot paths: full dynamic
//! runs per heuristic/policy, one-shot composite scheduling, the
//! insertion gap-finder, and the parallel sweep harness.  These are the
//! numbers tracked in EXPERIMENTS.md §Perf.
//!
//! Besides the human-readable table, the run writes
//! `BENCH_hotpath.json` (override the path with `DTS_BENCH_JSON`):
//! `{ "<bench name>": {"mean": s, "min": s, "max": s}, ... }` — all
//! values in seconds — so successive PRs have a machine-readable perf
//! trajectory to diff against.
//!
//! `DTS_BENCH_SCALE=quick` (default; the CI bench smoke) keeps the
//! `scale …` row at a ~1k-task composite; `DTS_BENCH_SCALE=paper` runs
//! it at the ~10k-task production size.  See docs/PERF.md for how to
//! read the `refresh`/`scale` rows.

#[path = "util/mod.rs"]
mod util;

use dts::config::ExperimentConfig;
use dts::coordinator::{run_reference, Coordinator, Policy, Variant};
use dts::experiments::run_sweep_parallel;
use dts::federation::FederatedCoordinator;
use dts::graph::Gid;
use dts::json;
use dts::policy::PolicySpec;
use dts::schedule::{Slot, Timelines};
use dts::schedulers::SchedulerKind;
use dts::sim::{Reaction, ReactiveCoordinator, SimConfig};
use dts::workloads::Dataset;

/// Collected (name, mean, min, max, allocs) rows for the JSON dump.
/// `allocs` is the heap-allocation count of one measured run (the
/// §Layout observability column) — it reads 0 unless the bench is built
/// with `--features alloc-count`, which registers the counting
/// allocator from `dts::alloc_count`.
struct Recorder {
    rows: Vec<(String, f64, f64, f64, u64)>,
}

impl Recorder {
    fn new() -> Self {
        Self { rows: Vec::new() }
    }

    fn report(&mut self, name: &str, mean: f64, min: f64, max: f64) {
        self.report_allocs(name, mean, min, max, 0);
    }

    fn report_allocs(&mut self, name: &str, mean: f64, min: f64, max: f64, allocs: u64) {
        util::report(name, mean, min, max);
        if allocs > 0 {
            eprintln!("    allocs/run: {allocs}");
        }
        self.rows.push((name.to_string(), mean, min, max, allocs));
    }

    fn to_json(&self) -> json::Value {
        json::obj(
            self.rows
                .iter()
                .map(|(name, mean, min, max, allocs)| {
                    (
                        name.as_str(),
                        json::obj(vec![
                            ("mean", json::num(*mean)),
                            ("min", json::num(*min)),
                            ("max", json::num(*max)),
                            ("allocs", json::num(*allocs as f64)),
                        ]),
                    )
                })
                .collect(),
        )
    }
}

fn main() {
    let mut rec = Recorder::new();

    // 1. end-to-end dynamic runs, 100-graph synthetic (the paper's size)
    let prob = Dataset::Synthetic.instance(100, 1);
    for kind in SchedulerKind::ALL {
        for policy in [Policy::NonPreemptive, Policy::LastK(5), Policy::Preemptive] {
            let (mean, min, max) = util::time_it(1, 3, || {
                let mut c = Coordinator::new(policy, kind.make(0));
                std::hint::black_box(c.run(&prob));
            });
            rec.report(
                &format!("dynamic {}-{} synthetic×100", policy.label(), kind.name()),
                mean,
                min,
                max,
            );
        }
    }

    // 1b. reactive runtime end-to-end (§Reactive rows): realized
    // durations under σ=0.3 noise, straggler-triggered Last-K
    // rescheduling vs the no-reaction baseline.  Tracks the full event
    // loop + belief refresh + in-place replans.
    for (name, reaction) in [
        ("no-reaction", Reaction::None),
        (
            "L3@0.25",
            Reaction::LastK {
                k: 3,
                threshold: 0.25,
            },
        ),
    ] {
        let cfg = SimConfig {
            noise_std: 0.3,
            noise_seed: 1,
            reaction,
            record_frozen: false,
            full_refresh: false,
            faults: dts::sim::FaultConfig::NONE,
        };
        let (mean, min, max) = util::time_it(1, 3, || {
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
            std::hint::black_box(rc.run(&prob));
        });
        let allocs = {
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
            rc.run(&prob).replan_allocs
        };
        rec.report_allocs(
            &format!("reactive 5P-HEFT σ0.3 {name} synthetic×100"),
            mean,
            min,
            max,
            allocs,
        );
    }

    // 1b'. belief-refresh A/B (§Refresh): the same reactive L3@0.25 run
    // under the full-plan refresh oracle vs the incremental dirty-cone
    // refresh — the pair isolates the per-replan belief-refresh cost
    // (both are bit-identical, so any delta is pure refresh work).
    for (name, full) in [("full", true), ("incremental", false)] {
        let cfg = SimConfig {
            noise_std: 0.3,
            noise_seed: 1,
            reaction: Reaction::LastK {
                k: 3,
                threshold: 0.25,
            },
            record_frozen: false,
            full_refresh: full,
            faults: dts::sim::FaultConfig::NONE,
        };
        let (mean, min, max) = util::time_it(1, 3, || {
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
            std::hint::black_box(rc.run(&prob));
        });
        let allocs = {
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
            rc.run(&prob).replan_allocs
        };
        rec.report_allocs(
            &format!("refresh σ0.3 {name} 5P-HEFT L3@0.25 synthetic×100"),
            mean,
            min,
            max,
            allocs,
        );
    }

    // 1b''. production-scale composite (§Scale): the 10⁴-task reactive
    // sweep cell the dirty-cone refresh unlocks — ~1200 synthetic graphs
    // ≈ 10k tasks at paper scale (DTS_BENCH_SCALE=paper), a 10× reduced
    // ~1k-task instance at the default quick scale so the CI bench smoke
    // stays fast.  Compare against the `refresh σ0.3 incremental` row to
    // read how the per-replan cost grows with composite size.
    {
        let (label, n_graphs) = if util::scale() == "paper" {
            ("10k", 1200)
        } else {
            ("1k (quick)", 120)
        };
        let big = Dataset::Synthetic.instance(n_graphs, 1);
        eprintln!(
            "[bench] scale row: {} graphs, {} tasks ({} scale)",
            big.graphs.len(),
            big.total_tasks(),
            util::scale()
        );
        let cfg = SimConfig {
            noise_std: 0.3,
            noise_seed: 1,
            reaction: Reaction::LastK {
                k: 3,
                threshold: 0.25,
            },
            record_frozen: false,
            full_refresh: false,
            faults: dts::sim::FaultConfig::NONE,
        };
        let (mean, min, max) = util::time_it(0, 1, || {
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
            std::hint::black_box(rc.run(&big));
        });
        let allocs = {
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
            rc.run(&big).replan_allocs
        };
        rec.report_allocs(
            &format!("scale {label} 5P-HEFT σ0.3 L3@0.25"),
            mean,
            min,
            max,
            allocs,
        );

        // 1b'''(a). federated-sharding A/B (§Federation): the same
        // composite under the monolithic coordinator wrapped as one
        // shard vs a 4-shard federation (admission + 4 shard-local
        // coordinators over 4 worker threads).  The shard-1 row pays
        // only the partition/admit/merge wrapper (it is bit-identical
        // to the `scale` row above — pinned by rust/tests/federation.rs),
        // so the shard-4 delta reads as pure federation win: shard-local
        // replans over 4× smaller beliefs, run in parallel.
        for shards in [1usize, 4] {
            let fed = FederatedCoordinator::new(
                Policy::LastK(5),
                SchedulerKind::Heft,
                0,
                cfg,
                shards,
            )
            .with_jobs(4);
            let (mean, min, max) = util::time_it(0, 1, || {
                std::hint::black_box(fed.run(&big));
            });
            rec.report(
                &format!("shard {shards} 5P-HEFT σ0.3 L3@0.25 scale {label}"),
                mean,
                min,
                max,
            );
        }

        // 1b''''. the 10⁶-task federated composite (§Federation, paper
        // scale only): ~120k synthetic graphs ≈ 1M tasks — far past what
        // one global belief can replan interactively — split across 4
        // clusters.  Quick scale skips it (minutes of wall time).
        if util::scale() == "paper" {
            let huge = Dataset::Synthetic.instance(120_000, 1);
            eprintln!(
                "[bench] 1M row: {} graphs, {} tasks",
                huge.graphs.len(),
                huge.total_tasks()
            );
            let fed =
                FederatedCoordinator::new(Policy::LastK(5), SchedulerKind::Heft, 0, cfg, 4)
                    .with_jobs(4);
            let (mean, min, max) = util::time_it(0, 1, || {
                std::hint::black_box(fed.run(&huge));
            });
            rec.report("scale 1M shard 4 5P-HEFT σ0.3 L3@0.25", mean, min, max);
        }
    }

    // 1b'''. memory-layout A/B (§Layout): the retained AoS/map reference
    // coordinator — fresh composite `Problem` allocation and
    // FxHashMap-keyed schedule per arrival — vs the production
    // CSR/SoA/dense-id workspace path.  Both produce bit-identical
    // schedules (pinned by rust/tests/layout_dense.rs), so the time and
    // `allocs` deltas are pure memory-layout work.  Build with
    // `--features alloc-count` to populate the allocs column.
    for (name, soa) in [("aos-ref", false), ("soa", true)] {
        let run_once = || {
            if soa {
                let mut c = Coordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0));
                std::hint::black_box(c.run(&prob).schedule.n_assigned())
            } else {
                let (schedule, _) =
                    run_reference(Policy::LastK(5), SchedulerKind::Heft.make(0), &prob);
                std::hint::black_box(schedule.n_assigned())
            }
        };
        let (mean, min, max) = util::time_it(1, 3, || {
            run_once();
        });
        let a0 = dts::alloc_count::alloc_count();
        run_once();
        let allocs = dts::alloc_count::alloc_count() - a0;
        rec.report_allocs(
            &format!("layout {name} 5P-HEFT synthetic×100"),
            mean,
            min,
            max,
            allocs,
        );
    }

    // 1c. policy-engine rows (§Policy): the adaptive controllers pay for
    // per-finish decision hooks + per-graph stretch observations on top
    // of the event loop — compare against the matching `reactive
    // 5P-HEFT σ0.3 L3@0.25` row to read the engine's overhead, and
    // against each other to read the budget/adaptation cost.
    for spec in [
        PolicySpec::FixedLastK {
            k: 3,
            threshold: 0.25,
        },
        PolicySpec::AdaptiveK {
            k0: 3,
            k_max: 20,
            threshold: 0.25,
            target_stretch: 2.0,
        },
        PolicySpec::Budgeted {
            k: 3,
            threshold: 0.25,
            rate: 1.0,
            burst: 4.0,
        },
    ] {
        let cfg = SimConfig {
            noise_std: 0.3,
            noise_seed: 1,
            reaction: Reaction::None,
            record_frozen: false,
            full_refresh: false,
            faults: dts::sim::FaultConfig::NONE,
        };
        let label = spec.label();
        let (mean, min, max) = util::time_it(1, 3, || {
            let mut rc = ReactiveCoordinator::with_policy(
                Policy::LastK(5),
                SchedulerKind::Heft.make(0),
                cfg,
                spec.make(),
            );
            std::hint::black_box(rc.run(&prob));
        });
        rec.report(
            &format!("policy 5P-HEFT σ0.3 {label} synthetic×100"),
            mean,
            min,
            max,
        );
    }

    // 1d. deadline-scenario row: the urgency-scoped DeadlineAware
    // controller on a weighted + deadline-laden instance — compare
    // against the `policy … L3@0.25` row to read the price of ranking
    // graphs by belief slack at every straggler replan.
    {
        use dts::workloads::{DeadlineModel, Scenario, WeightModel, DEFAULT_LOAD};
        let scen = Scenario {
            weights: WeightModel::HeavyTail { alpha: 1.5 },
            deadlines: DeadlineModel::CritPathSlack { slack: 2.0 },
            arrivals: Default::default(),
        };
        let dprob = Dataset::Synthetic.instance_scenario(100, 1, DEFAULT_LOAD, None, &scen);
        let spec = PolicySpec::DeadlineAware {
            k: 3,
            threshold: 0.25,
        };
        let cfg = SimConfig {
            noise_std: 0.3,
            noise_seed: 1,
            reaction: Reaction::None,
            record_frozen: false,
            full_refresh: false,
            faults: dts::sim::FaultConfig::NONE,
        };
        let label = spec.label();
        let (mean, min, max) = util::time_it(1, 3, || {
            let mut rc = ReactiveCoordinator::with_policy(
                Policy::LastK(5),
                SchedulerKind::Heft.make(0),
                cfg,
                spec.make(),
            );
            std::hint::black_box(rc.run(&dprob));
        });
        rec.report(
            &format!("policy 5P-HEFT σ0.3 {label} w+d synthetic×100"),
            mean,
            min,
            max,
        );
    }

    // 2. the biggest single composite problem a preemptive run sees
    let (mean, min, max) = util::time_it(1, 5, || {
        let mut c = Coordinator::new(Policy::Preemptive, SchedulerKind::Heft.make(0));
        let res = c.run(&prob);
        std::hint::black_box(res.events.iter().map(|e| e.n_pending).max());
    });
    rec.report("peak-composite probe (P-HEFT)", mean, min, max);

    // 3. insertion gap-finder on a long timeline
    let mut tl = Timelines::new(1);
    for i in 0..2000 {
        let t = i as f64 * 10.0;
        tl.insert(0, Slot { start: t, finish: t + 6.0, gid: Gid::new(0, i) });
    }
    let (mean, min, max) = util::time_it(10, 50, || {
        // worst case: a task too big for every interior gap
        std::hint::black_box(tl.earliest_start(0, 0.0, 7.0));
    });
    rec.report("earliest_start scan (2000 slots, no fit)", mean, min, max);

    let (mean, min, max) = util::time_it(10, 50, || {
        std::hint::black_box(tl.earliest_start(0, 9500.0, 3.0));
    });
    rec.report("earliest_start scan (ready mid-timeline)", mean, min, max);

    // 4. slot removal by binary search on the known start (the Last-K /
    // preemptive revert hot path).  Each probe removes and re-inserts the
    // same slot, so the timeline is invariant across iterations and the
    // timed loop contains no clone — it isolates lookup + shift, the two
    // costs a revert actually pays.
    let mut t2 = tl.clone();
    let (mean, min, max) = util::time_it(5, 30, || {
        for i in (0..2000).step_by(4) {
            let start = i as f64 * 10.0;
            std::hint::black_box(t2.remove_at(0, Gid::new(0, i), start));
            t2.insert(0, Slot { start, finish: start + 6.0, gid: Gid::new(0, i) });
        }
    });
    rec.report("remove_at+reinsert 500 of 2000 slots", mean, min, max);

    // 4b. telemetry primitive ops (§Observability): one counter bump +
    // one histogram record per iteration — the per-event price of the
    // registry on the hot paths.  Allocation-free by design (pinned by
    // the `recording_is_allocation_free` unit test); this row tracks
    // the time cost.
    {
        use dts::telemetry::{self, Counter, Hist};
        telemetry::reset();
        let (mean, min, max) = util::time_it(10, 50, || {
            for i in 0..1000u64 {
                telemetry::counter_inc(Counter::EftPlacements);
                telemetry::hist_record(Hist::ConeSize, i);
            }
        });
        telemetry::reset();
        rec.report("telemetry 1k counter+hist records", mean, min, max);

        // the same reactive run with recording disabled — compare to the
        // `reactive 5P-HEFT σ0.3 L3@0.25` row above to read the total
        // enabled-path overhead (should be noise: the sites are branches
        // on a thread-local bool)
        let cfg = SimConfig {
            noise_std: 0.3,
            noise_seed: 1,
            reaction: Reaction::LastK {
                k: 3,
                threshold: 0.25,
            },
            record_frozen: false,
            full_refresh: false,
            faults: dts::sim::FaultConfig::NONE,
        };
        telemetry::set_enabled(false);
        let (mean, min, max) = util::time_it(1, 3, || {
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
            std::hint::black_box(rc.run(&prob));
        });
        telemetry::set_enabled(true);
        rec.report(
            "reactive 5P-HEFT σ0.3 L3@0.25 telemetry-off synthetic×100",
            mean,
            min,
            max,
        );
    }

    // 5. parallel sweep harness scaling (same cells, 1 vs 4 workers)
    let sweep_cfg = ExperimentConfig {
        dataset: Dataset::Synthetic,
        n_graphs: 30,
        trials: 4,
        seed: 7,
        load: dts::workloads::DEFAULT_LOAD,
        variants: ["NP-HEFT", "5P-HEFT", "P-HEFT", "P-CPOP", "P-MinMin"]
            .iter()
            .map(|l| Variant::parse(l).unwrap())
            .collect(),
    };
    for jobs in [1usize, 4] {
        let (mean, min, max) = util::time_it(0, 2, || {
            std::hint::black_box(run_sweep_parallel(&sweep_cfg, jobs));
        });
        rec.report(
            &format!("run_sweep synthetic×30 (5 variants, jobs={jobs})"),
            mean,
            min,
            max,
        );
    }

    let path = std::env::var("DTS_BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    match std::fs::write(&path, format!("{}\n", rec.to_json())) {
        Ok(()) => eprintln!("[bench] wrote {path}"),
        Err(e) => eprintln!("[bench] cannot write {path}: {e}"),
    }
}
