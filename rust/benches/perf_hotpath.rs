//! §Perf micro-benchmarks of the L3 scheduling hot paths: full dynamic
//! runs per heuristic/policy, one-shot composite scheduling, and the
//! insertion gap-finder.  These are the numbers tracked in
//! EXPERIMENTS.md §Perf.

#[path = "util/mod.rs"]
mod util;

use dts::coordinator::{Coordinator, Policy};
use dts::graph::Gid;
use dts::schedule::{Slot, Timelines};
use dts::schedulers::SchedulerKind;
use dts::workloads::Dataset;

fn main() {
    // 1. end-to-end dynamic runs, 100-graph synthetic (the paper's size)
    let prob = Dataset::Synthetic.instance(100, 1);
    for kind in SchedulerKind::ALL {
        for policy in [Policy::NonPreemptive, Policy::LastK(5), Policy::Preemptive] {
            let (mean, min, max) = util::time_it(1, 3, || {
                let mut c = Coordinator::new(policy, kind.make(0));
                std::hint::black_box(c.run(&prob));
            });
            util::report(
                &format!("dynamic {}-{} synthetic×100", policy.label(), kind.name()),
                mean,
                min,
                max,
            );
        }
    }

    // 2. the biggest single composite problem a preemptive run sees
    let (mean, min, max) = util::time_it(1, 5, || {
        let mut c = Coordinator::new(Policy::Preemptive, SchedulerKind::Heft.make(0));
        let res = c.run(&prob);
        std::hint::black_box(res.events.iter().map(|e| e.n_pending).max());
    });
    util::report("peak-composite probe (P-HEFT)", mean, min, max);

    // 3. insertion gap-finder on a long timeline
    let mut tl = Timelines::new(1);
    for i in 0..2000 {
        let t = i as f64 * 10.0;
        tl.insert(0, Slot { start: t, finish: t + 6.0, gid: Gid::new(0, i) });
    }
    let (mean, min, max) = util::time_it(10, 50, || {
        // worst case: a task too big for every interior gap
        std::hint::black_box(tl.earliest_start(0, 0.0, 7.0));
    });
    util::report("earliest_start scan (2000 slots, no fit)", mean, min, max);

    let (mean, min, max) = util::time_it(10, 50, || {
        std::hint::black_box(tl.earliest_start(0, 9500.0, 3.0));
    });
    util::report("earliest_start scan (ready mid-timeline)", mean, min, max);
}
