//! §Perf: XLA-compiled Pallas rank fixed point vs the native Rust DP,
//! across bucket sizes — quantifies the PJRT dispatch overhead and the
//! crossover (if any) on this CPU testbed.
//!
//! Requires `make artifacts`; prints SKIP when absent.

#[path = "util/mod.rs"]
mod util;

use std::rc::Rc;

use dts::network::Network;
use dts::prng::Xoshiro256pp;
use dts::runtime::{XlaRanks, XlaRuntime};
use dts::schedulers::{NativeRanks, PTask, Pred, Problem, RankProvider};

fn random_problem(n: usize, seed: u64) -> Problem {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut tasks: Vec<PTask> = (0..n)
        .map(|i| PTask {
            gid: dts::graph::Gid::new(0, i),
            cost: rng.uniform(1.0, 50.0),
            ready: 0.0,
            preds: Vec::new(),
            succs: Vec::new(),
        })
        .collect();
    for i in 0..n {
        for j in (i + 1)..(n.min(i + 24)) {
            if rng.next_f64() < 0.2 {
                let d = rng.uniform(0.5, 10.0);
                tasks[i].succs.push((j, d));
                tasks[j].preds.push(Pred::Pending { idx: i, data: d });
            }
        }
    }
    Problem::from_tasks(tasks)
}

fn main() {
    let rt = match XlaRuntime::load("artifacts") {
        Ok(rt) => Rc::new(rt),
        Err(e) => {
            eprintln!("SKIP perf_rank_xla: {e}");
            return;
        }
    };
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let net = Network::default_eval(&mut rng);

    for &n in &[16usize, 32, 64, 128, 200, 256] {
        let prob = random_problem(n, n as u64);

        let (mean_n, min_n, max_n) = util::time_it(3, 20, || {
            std::hint::black_box(NativeRanks.ranks(&prob, &net));
        });
        util::report(&format!("native ranks n={n}"), mean_n, min_n, max_n);

        let mut xr = XlaRanks::new(rt.clone());
        let (mean_x, min_x, max_x) = util::time_it(3, 20, || {
            std::hint::black_box(xr.ranks(&prob, &net));
        });
        util::report(&format!("xla    ranks n={n}"), mean_x, min_x, max_x);
        println!(
            "{:<44} xla/native = {:.1}×\n",
            format!("ratio n={n}"),
            mean_x / mean_n
        );
    }
}
