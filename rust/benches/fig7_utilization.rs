//! Fig 7 — Utilization (synthetic / RIoTBench / WFCommons).
//!
//! Regenerates the paper's figure as a sorted table per dataset.  Scale
//! via DTS_BENCH_SCALE=paper for the full §VI instance sizes.

#[path = "util/mod.rs"]
mod util;

use dts::metrics::Metric;
use dts::workloads::Dataset;

fn main() {
    for dataset in [Dataset::Synthetic, Dataset::RiotBench, Dataset::WfCommons] {
        let r = util::sweep(dataset);
        util::print_figure("Fig 7 — Utilization", &r, Metric::Utilization);
    }
}
