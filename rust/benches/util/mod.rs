//! Shared mini-bench harness for the figure benches (criterion is not in
//! the offline vendored set).  Provides timed repetition with warmup and
//! the standard header/footer the figure benches print.

use std::time::Instant;

use dts::config::ExperimentConfig;
use dts::experiments::{run_sweep, SweepResult};
use dts::metrics::Metric;
use dts::workloads::Dataset;

/// Scale of a bench run, controlled by env:
/// * `DTS_BENCH_SCALE=quick` — reduced instances (CI-speed, default)
/// * `DTS_BENCH_SCALE=paper` — the paper's §VI instance sizes
pub fn scale() -> &'static str {
    match std::env::var("DTS_BENCH_SCALE").as_deref() {
        Ok("paper") => "paper",
        _ => "quick",
    }
}

/// Sweep config at the requested scale with the full 30-variant grid.
pub fn figure_config(dataset: Dataset) -> ExperimentConfig {
    if scale() == "paper" {
        ExperimentConfig::paper_default(dataset)
    } else {
        ExperimentConfig {
            n_graphs: match dataset {
                Dataset::WfCommons => 20,
                _ => 30,
            },
            trials: 3,
            ..ExperimentConfig::paper_default(dataset)
        }
    }
}

/// Run a sweep with a progress line per trial.
pub fn sweep(dataset: Dataset) -> SweepResult {
    let cfg = figure_config(dataset);
    eprintln!(
        "[bench] {} sweep: {} graphs × {} variants × {} trials ({} scale)",
        dataset.name(),
        cfg.n_graphs,
        cfg.variants.len(),
        cfg.trials,
        scale()
    );
    let t0 = Instant::now();
    let r = run_sweep(&cfg);
    eprintln!("[bench] {} done in {:.1}s", dataset.name(), t0.elapsed().as_secs_f64());
    r
}

/// Print the figure table for one metric.
pub fn print_figure(title: &str, r: &SweepResult, metric: Metric) {
    println!("\n### {title} — {} ({})\n", r.config.dataset.name(), scale());
    println!("{}", r.figure_table(metric));
}

/// Timed micro-benchmark: `iters` timed runs after `warmup` runs.
/// Returns (mean_s, min_s, max_s).
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    (mean, min, max)
}

/// Standard per-bench report line.
pub fn report(name: &str, mean_s: f64, min_s: f64, max_s: f64) {
    println!(
        "{name:<44} mean {:>10.3} ms   min {:>10.3} ms   max {:>10.3} ms",
        mean_s * 1e3,
        min_s * 1e3,
        max_s * 1e3
    );
}
