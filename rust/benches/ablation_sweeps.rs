//! Ablations of the design choices DESIGN.md calls out:
//!
//! * **K sweep** — the Last-K parameter from 0 (≡NP) to ∞ (≡P): where do
//!   the makespan/fairness/runtime curves cross? (the paper's central
//!   trade-off, §VII)
//! * **Load sweep** — offered load (arrival-rate) sensitivity: §VII.C
//!   notes the flowtime ordering holds "even at higher arrival rates".
//! * **CCR sweep** — §VII.E: "Higher CCR values tend to reduce
//!   utilization, as communication costs discourage task distribution."
//! * **Insertion vs append EFT** — value of the insertion-based gap
//!   search inside HEFT's placement loop.

#[path = "util/mod.rs"]
mod util;

use dts::coordinator::{Coordinator, Policy};
use dts::metrics::Metric;
use dts::schedulers::SchedulerKind;
use dts::stats::mean;
use dts::workloads::Dataset;

fn run(policy: Policy, prob: &dts::coordinator::DynamicProblem) -> dts::metrics::MetricRow {
    let mut c = Coordinator::new(policy, SchedulerKind::Heft.make(0));
    let res = c.run(prob);
    res.metrics(prob)
}

fn k_sweep() {
    println!("\n### Ablation: Last-K sweep (HEFT, synthetic, 3 seeds)\n");
    println!(
        "{:<8} {:>18} {:>16} {:>14} {:>12}",
        "K", "total makespan", "mean makespan", "flowtime", "runtime ms"
    );
    let probs: Vec<_> = (0..3).map(|s| Dataset::Synthetic.instance(60, 400 + s)).collect();
    for (label, policy) in [
        ("0 (NP)", Policy::NonPreemptive),
        ("1", Policy::LastK(1)),
        ("2", Policy::LastK(2)),
        ("5", Policy::LastK(5)),
        ("10", Policy::LastK(10)),
        ("20", Policy::LastK(20)),
        ("50", Policy::LastK(50)),
        ("inf (P)", Policy::Preemptive),
    ] {
        let rows: Vec<_> = probs.iter().map(|p| run(policy, p)).collect();
        println!(
            "{:<8} {:>18.1} {:>16.1} {:>14.1} {:>12.2}",
            label,
            mean(&rows.iter().map(|r| r.total_makespan).collect::<Vec<_>>()),
            mean(&rows.iter().map(|r| r.mean_makespan).collect::<Vec<_>>()),
            mean(&rows.iter().map(|r| r.mean_flowtime).collect::<Vec<_>>()),
            mean(&rows.iter().map(|r| r.runtime_s).collect::<Vec<_>>()) * 1e3,
        );
    }
}

fn load_sweep() {
    println!("\n### Ablation: offered-load sweep (HEFT, synthetic)\n");
    println!(
        "{:<8} {:>14} {:>14} {:>16} {:>16}",
        "load", "NP flowtime", "P flowtime", "NP mean-mkspan", "P mean-mkspan"
    );
    for &load in &[0.15, 0.3, 0.5, 0.8, 1.2] {
        let prob = Dataset::Synthetic.instance_opts(60, 500, load, None);
        let np = run(Policy::NonPreemptive, &prob);
        let p = run(Policy::Preemptive, &prob);
        println!(
            "{:<8} {:>14.1} {:>14.1} {:>16.1} {:>16.1}",
            load, np.mean_flowtime, p.mean_flowtime, np.mean_makespan, p.mean_makespan
        );
    }
}

fn ccr_sweep() {
    println!("\n### Ablation: CCR sweep (HEFT, synthetic) — §VII.E claim\n");
    println!("{:<8} {:>14} {:>14}", "CCR", "NP util", "P util");
    for &ccr in &[0.1, 0.2, 0.5, 1.0, 2.0, 5.0] {
        let utils: Vec<(f64, f64)> = (0..3)
            .map(|s| {
                let prob =
                    Dataset::Synthetic.instance_opts(40, 600 + s, 0.5, Some(ccr));
                (
                    run(Policy::NonPreemptive, &prob).mean_utilization,
                    run(Policy::Preemptive, &prob).mean_utilization,
                )
            })
            .collect();
        println!(
            "{:<8} {:>14.3} {:>14.3}",
            ccr,
            mean(&utils.iter().map(|u| u.0).collect::<Vec<_>>()),
            mean(&utils.iter().map(|u| u.1).collect::<Vec<_>>()),
        );
    }
}

fn insertion_vs_append() {
    // HEFT with the insertion gap search (the shipped implementation)
    // against a hypothetical append-only placement, emulated by timing
    // how much of the makespan benefit comes from gaps: we measure gap
    // occupancy on NP runs (how many slots start strictly before the
    // previous slot on their node finished being the tail).
    println!("\n### Ablation: insertion-based gap fill utilisation\n");
    for dataset in [Dataset::Synthetic, Dataset::Adversarial] {
        let prob = dataset.instance(40, 700);
        let mut c = Coordinator::new(Policy::NonPreemptive, SchedulerKind::Heft.make(0));
        let res = c.run(&prob);
        // count slots that were placed into interior gaps: slot whose
        // successor-by-time on the node existed before it was placed —
        // approximated post-hoc: a slot is "gap-filled" if some later-
        // arriving graph's task sits earlier on the node's timeline than
        // an earlier-arriving graph's task.
        let mut gap_filled = 0usize;
        let mut total = 0usize;
        for v in 0..prob.network.n_nodes() {
            let gids = res.schedule.timelines().slot_gids(v);
            total += gids.len();
            for w in gids.windows(2) {
                if w[0].graph > w[1].graph {
                    gap_filled += 1;
                }
            }
        }
        println!(
            "{:<12} slots {:>5}, inversions (later graph placed earlier): {:>5} ({:.1}%)",
            dataset.name(),
            total,
            gap_filled,
            100.0 * gap_filled as f64 / total.max(1) as f64
        );
    }
    let _ = Metric::ALL; // keep the import meaningful for future metrics
}

fn robustness_sweep() {
    // extension experiment: how brittle is each policy's plan when true
    // execution times deviate from the estimates (realized/planned
    // makespan under multiplicative truncated-Gaussian noise)?
    println!("\n### Ablation: plan robustness under execution-time noise\n");
    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>10}",
        "noise σ", "NP", "2P", "5P", "P"
    );
    let prob = Dataset::Synthetic.instance(40, 800);
    let plans: Vec<(&str, dts::schedule::Schedule)> = [
        ("NP", Policy::NonPreemptive),
        ("2P", Policy::LastK(2)),
        ("5P", Policy::LastK(5)),
        ("P", Policy::Preemptive),
    ]
    .into_iter()
    .map(|(l, pol)| {
        let mut c = Coordinator::new(pol, SchedulerKind::Heft.make(0));
        (l, c.run(&prob).schedule)
    })
    .collect();
    for &noise in &[0.0, 0.1, 0.2, 0.4] {
        let mut row = format!("{:<10}", noise);
        for (_, planned) in &plans {
            let vals: Vec<f64> = (0..5)
                .map(|s| dts::robustness::degradation(planned, &prob, noise, s))
                .collect();
            row += &format!(" {:>10.3}", mean(&vals));
        }
        println!("{row}");
    }
}

fn main() {
    k_sweep();
    load_sweep();
    ccr_sweep();
    insertion_vs_append();
    robustness_sweep();
}
