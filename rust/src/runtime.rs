//! PJRT runtime: loads the AOT-compiled JAX+Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them from the Rust scheduling hot path.  Python never runs here.
//!
//! Two artifact families (see `python/compile/aot.py`):
//! * `ranks_n{N}` — the max-plus fixed point producing HEFT's upward and
//!   CPOP's downward ranks in one call, at size buckets N ∈ {32..256};
//! * `eft_p{P}_v{V}` — batched append-at-end EFT of one task across all
//!   nodes.
//!
//! [`XlaRanks`] adapts the rank artifact to the [`RankProvider`] strategy
//! interface, padding each composite problem into the smallest fitting
//! bucket (larger problems fall back to the native provider — correctness
//! never depends on the artifacts).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::Value;
use crate::network::Network;
use crate::schedulers::common::topo_order;
use crate::schedulers::{NativeRanks, Problem, RankProvider, Ranks};

/// Tropical "minus infinity" — must match `python/compile/kernels/maxplus.py`.
pub const NEG: f32 = -1e30;

/// A compiled rank executable for one size bucket.
struct RankExe {
    n: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// A compiled EFT executable for one (parents, nodes) bucket.
struct EftExe {
    p: usize,
    v: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT CPU client plus every compiled artifact.
pub struct XlaRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    ranks: BTreeMap<usize, RankExe>,
    efts: BTreeMap<usize, EftExe>,
    allpairs: BTreeMap<usize, RankExe>,
    dir: PathBuf,
}

impl XlaRuntime {
    /// Load and compile every artifact listed in `artifacts/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest =
            Value::from_str(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;

        let client = xla::PjRtClient::cpu()?;
        let mut ranks = BTreeMap::new();
        for entry in manifest
            .get("ranks")
            .and_then(|v| v.as_array())
            .ok_or_else(|| anyhow!("manifest missing 'ranks'"))?
        {
            let n = entry
                .get("n")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("rank entry missing n"))?;
            let file = entry
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("rank entry missing file"))?;
            let exe = compile_hlo(&client, &dir.join(file))?;
            ranks.insert(n, RankExe { n, exe });
        }

        let mut efts = BTreeMap::new();
        for entry in manifest
            .get("eft")
            .and_then(|v| v.as_array())
            .ok_or_else(|| anyhow!("manifest missing 'eft'"))?
        {
            let p = entry.get("p").and_then(|v| v.as_usize()).unwrap_or(0);
            let v = entry.get("v").and_then(|v| v.as_usize()).unwrap_or(0);
            let file = entry
                .get("file")
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("eft entry missing file"))?;
            let exe = compile_hlo(&client, &dir.join(file))?;
            efts.insert(v, EftExe { p, v, exe });
        }

        let mut allpairs = BTreeMap::new();
        if let Some(entries) = manifest.get("allpairs").and_then(|v| v.as_array()) {
            for entry in entries {
                let n = entry
                    .get("n")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("allpairs entry missing n"))?;
                let file = entry
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("allpairs entry missing file"))?;
                let exe = compile_hlo(&client, &dir.join(file))?;
                allpairs.insert(n, RankExe { n, exe });
            }
        }

        Ok(Self {
            client,
            ranks,
            efts,
            allpairs,
            dir,
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    pub fn rank_buckets(&self) -> Vec<usize> {
        self.ranks.keys().copied().collect()
    }

    /// Smallest rank bucket that fits `n` tasks.
    pub fn rank_bucket(&self, n: usize) -> Option<usize> {
        self.ranks.range(n..).next().map(|(k, _)| *k)
    }

    /// Execute the rank artifact: `m` is the bucket-padded row-major
    /// max-plus cost matrix, `w` the padded mean execution costs, `depth`
    /// the fixed-point iteration count.  Returns (up, down), still padded.
    pub fn ranks_padded(
        &self,
        bucket: usize,
        m: &[f32],
        w: &[f32],
        depth: i32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let rexe = self
            .ranks
            .get(&bucket)
            .ok_or_else(|| anyhow!("no rank bucket {bucket}"))?;
        let n = rexe.n as i64;
        debug_assert_eq!(m.len(), (n * n) as usize);
        debug_assert_eq!(w.len(), n as usize);
        let m_lit = xla::Literal::vec1(m).reshape(&[n, n])?;
        let w_lit = xla::Literal::vec1(w);
        let d_lit = xla::Literal::scalar(depth);
        let result = rexe.exe.execute::<xla::Literal>(&[m_lit, w_lit, d_lit])?[0][0]
            .to_literal_sync()?;
        let (up, down) = result.to_tuple2()?;
        Ok((up.to_vec::<f32>()?, down.to_vec::<f32>()?))
    }

    /// Smallest all-pairs bucket that fits `n` tasks.
    pub fn allpairs_bucket(&self, n: usize) -> Option<usize> {
        self.allpairs.range(n..).next().map(|(k, _)| *k)
    }

    /// Execute the all-pairs longest-path artifact on a bucket-padded
    /// edge-weight matrix; returns the padded distance matrix (row-major).
    pub fn allpairs_padded(&self, bucket: usize, m: &[f32]) -> Result<Vec<f32>> {
        let exe = self
            .allpairs
            .get(&bucket)
            .ok_or_else(|| anyhow!("no allpairs bucket {bucket}"))?;
        let n = exe.n as i64;
        debug_assert_eq!(m.len(), (n * n) as usize);
        let m_lit = xla::Literal::vec1(m).reshape(&[n, n])?;
        let result = exe.exe.execute::<xla::Literal>(&[m_lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Smallest EFT node-bucket that fits `v` nodes; returns (p, v).
    pub fn eft_bucket(&self, v: usize) -> Option<(usize, usize)> {
        self.efts.range(v..).next().map(|(_, e)| (e.p, e.v))
    }

    /// Execute the batched-EFT artifact (padded shapes).
    pub fn batch_eft_padded(
        &self,
        v_bucket: usize,
        parent_finish: &[f32],
        comm: &[f32],
        exec_time: &[f32],
        avail: &[f32],
        arrival: f32,
    ) -> Result<Vec<f32>> {
        let e = self
            .efts
            .get(&v_bucket)
            .ok_or_else(|| anyhow!("no eft bucket v={v_bucket}"))?;
        let (p, v) = (e.p as i64, e.v as i64);
        debug_assert_eq!(parent_finish.len(), p as usize);
        debug_assert_eq!(comm.len(), (p * v) as usize);
        let f_lit = xla::Literal::vec1(parent_finish);
        let c_lit = xla::Literal::vec1(comm).reshape(&[p, v])?;
        let x_lit = xla::Literal::vec1(exec_time);
        let a_lit = xla::Literal::vec1(avail);
        let r_lit = xla::Literal::vec1(&[arrival]);
        let result = e
            .exe
            .execute::<xla::Literal>(&[f_lit, c_lit, x_lit, a_lit, r_lit])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

fn compile_hlo(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// [`RankProvider`] backed by the compiled artifact, with transparent
/// fallback to [`NativeRanks`] for problems larger than every bucket.
///
/// Holds the runtime behind an `Rc` so schedulers built around it satisfy
/// the `'static` bound of `Box<dyn Scheduler>` while sharing one compiled
/// artifact set.
pub struct XlaRanks {
    rt: std::rc::Rc<XlaRuntime>,
    /// statistics: how many calls went through XLA vs native fallback
    pub xla_calls: usize,
    pub native_calls: usize,
}

impl XlaRanks {
    pub fn new(rt: std::rc::Rc<XlaRuntime>) -> Self {
        Self {
            rt,
            xla_calls: 0,
            native_calls: 0,
        }
    }
}

impl RankProvider for XlaRanks {
    fn provider_name(&self) -> &'static str {
        "xla"
    }

    fn ranks(&mut self, prob: &Problem, net: &Network) -> Ranks {
        let n = prob.n_tasks();
        let Some(bucket) = self.rt.rank_bucket(n) else {
            self.native_calls += 1;
            return NativeRanks.ranks(prob, net);
        };

        // Pad the composite problem into the bucket: padded tasks carry
        // w = 0 and no edges, so their ranks are exactly 0 (tested on the
        // Python side in test_model.py) and real ranks are untouched.
        let inv_speed = net.mean_inv_speed() as f32;
        let inv_link = net.mean_inv_link() as f32;
        let mut m = vec![NEG; bucket * bucket];
        let mut w = vec![0f32; bucket];
        for (i, t) in prob.tasks.iter().enumerate() {
            w[i] = t.cost as f32 * inv_speed;
            for &(c, data) in &t.succs {
                m[i * bucket + c] = data as f32 * inv_link;
            }
        }
        // fixed-point iteration count = composite height
        let depth = composite_height(prob) as i32;

        match self.rt.ranks_padded(bucket, &m, &w, depth) {
            Ok((up, down)) => {
                self.xla_calls += 1;
                Ranks {
                    up: up[..n].iter().map(|&x| x as f64).collect(),
                    down: down[..n].iter().map(|&x| x as f64).collect(),
                }
            }
            Err(_) => {
                self.native_calls += 1;
                NativeRanks.ranks(prob, net)
            }
        }
    }
}

/// Height (longest path, in vertices) of the pending composite graph.
pub fn composite_height(prob: &Problem) -> usize {
    let order = topo_order(prob);
    let mut h = vec![1usize; prob.n_tasks()];
    for &t in order.iter().rev() {
        for &(c, _) in &prob.tasks[t].succs {
            h[t] = h[t].max(1 + h[c]);
        }
    }
    h.into_iter().max().unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedulers::testutil::problem_from_graph;

    // Full PJRT round-trip tests live in rust/tests/integration_runtime.rs
    // (they require `make artifacts` to have run); here we cover the pure
    // helpers.

    #[test]
    fn composite_height_of_chain_and_fan() {
        let mut b = GraphBuilder::new("chain");
        let t0 = b.task(1.0);
        let t1 = b.task(1.0);
        let t2 = b.task(1.0);
        b.edge(t0, t1, 0.0).edge(t1, t2, 0.0);
        let p = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        assert_eq!(composite_height(&p), 3);

        let mut b = GraphBuilder::new("fan");
        let r = b.task(1.0);
        for _ in 0..5 {
            let t = b.task(1.0);
            b.edge(r, t, 0.0);
        }
        let p = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        assert_eq!(composite_height(&p), 2);
    }

    #[test]
    fn load_missing_dir_is_a_clean_error() {
        let err = match XlaRuntime::load("/nonexistent/artifacts") {
            Err(e) => e,
            Ok(_) => panic!("load of missing dir must fail"),
        };
        assert!(err.to_string().contains("manifest.json"));
    }
}
