//! Allocation-accounting harness (§Perf, PR 6).
//!
//! A thin counting wrapper around the system allocator, registered as
//! the `#[global_allocator]` **only** in the crate's own unit-test build
//! (`cfg(test)`) or when the `alloc-count` feature is enabled (used by
//! `cargo bench --features alloc-count` to populate the `allocs` column
//! of `BENCH_hotpath.json`).  Plain release builds keep the untouched
//! system allocator.
//!
//! The counter is thread-local, so parallel test threads don't pollute
//! each other's deltas: the zero-allocation steady-state pin
//! (`coordinator::tests::workspace_steady_state_allocates_nothing`)
//! measures exactly the allocations of its own thread.
//!
//! Usage: snapshot [`alloc_count()`] before and after the region of
//! interest; the difference is the number of `alloc`/`realloc`/
//! `alloc_zeroed` calls made by this thread (deallocations are not
//! counted — a steady-state region that frees but never allocates is
//! already in trouble elsewhere).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Number of heap allocations made by the current thread so far (0 if
/// the counting allocator is not registered — i.e. outside `cfg(test)`
/// builds and builds without the `alloc-count` feature).
pub fn alloc_count() -> u64 {
    ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

#[inline]
fn bump() {
    // try_with: TLS may be unavailable during thread teardown; losing a
    // count there is fine (nothing measures across teardown).
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// Counting pass-through over [`System`].
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

#[cfg(any(test, feature = "alloc-count"))]
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_this_threads_allocations() {
        let before = alloc_count();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = alloc_count();
        assert!(after > before, "allocation was counted");
        drop(v);
        assert_eq!(alloc_count(), after, "dealloc not counted");
    }

    #[test]
    fn no_alloc_region_measures_zero() {
        let mut v: Vec<u64> = Vec::with_capacity(8);
        let before = alloc_count();
        for i in 0..8 {
            v.push(i); // within capacity: no allocation
        }
        let after = alloc_count();
        assert_eq!(after - before, 0);
    }
}
