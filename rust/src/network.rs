//! The compute-node network `N = (V, E)`: a complete undirected graph with
//! node speeds `s(v)` and link communication strengths `s(v, v')`
//! (related-machines model, §II of the paper).
//!
//! Execution time of task `t` on node `v` is `c(t) / s(v)`; transfer time
//! of dependency `(t, t')` placed on `(v, v')` is `c(t,t') / s(v,v')`,
//! and **zero** when `v == v'` (local data movement is free, as in SAGA /
//! HEFT conventions).

use crate::prng::Xoshiro256pp;
use crate::stats::TruncatedGaussian;

/// Immutable heterogeneous network.
#[derive(Clone, Debug)]
pub struct Network {
    speed: Vec<f64>,
    /// flattened `n x n` link strength matrix; diagonal unused.
    link: Vec<f64>,
}

impl Network {
    /// Build from explicit speeds and a symmetric link matrix.
    pub fn new(speed: Vec<f64>, link: Vec<f64>) -> Self {
        let n = speed.len();
        assert_eq!(link.len(), n * n, "link matrix must be n*n");
        for &s in &speed {
            assert!(s > 0.0, "node speed must be positive");
        }
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    let (a, b) = (link[i * n + j], link[j * n + i]);
                    assert!(a > 0.0, "link strength must be positive");
                    assert!((a - b).abs() < 1e-12, "link matrix must be symmetric");
                }
            }
        }
        Self { speed, link }
    }

    /// Homogeneous network: every node speed 1, every link strength 1.
    pub fn homogeneous(n: usize) -> Self {
        Self {
            speed: vec![1.0; n],
            link: vec![1.0; n * n],
        }
    }

    /// The paper's generator: speeds and link rates from single truncated
    /// Gaussians (§VI.A).
    pub fn generate(
        n: usize,
        speed_dist: &TruncatedGaussian,
        link_dist: &TruncatedGaussian,
        rng: &mut Xoshiro256pp,
    ) -> Self {
        let speed: Vec<f64> = (0..n).map(|_| speed_dist.sample(rng)).collect();
        let mut link = vec![0.0; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let s = link_dist.sample(rng);
                link[i * n + j] = s;
                link[j * n + i] = s;
            }
        }
        Self { speed, link }
    }

    /// Default evaluation network: 6 nodes, speeds ~ TG(1.0, 0.3 | 0.4..2)
    /// and links ~ TG(1.0, 0.3 | 0.4..2), seeded.
    pub fn default_eval(rng: &mut Xoshiro256pp) -> Self {
        let d = TruncatedGaussian::new(1.0, 0.3, 0.4, 2.0);
        Self::generate(6, &d, &d, rng)
    }

    pub fn n_nodes(&self) -> usize {
        self.speed.len()
    }

    pub fn speed(&self, v: usize) -> f64 {
        self.speed[v]
    }

    pub fn link(&self, u: usize, v: usize) -> f64 {
        self.link[u * self.n_nodes() + v]
    }

    /// Execution time `c(t) / s(v)`.
    #[inline]
    pub fn exec_time(&self, cost: f64, v: usize) -> f64 {
        cost / self.speed[v]
    }

    /// Transfer time `c(t,t') / s(v,v')`; 0 if co-located.
    #[inline]
    pub fn comm_time(&self, data: f64, u: usize, v: usize) -> f64 {
        if u == v {
            0.0
        } else {
            data / self.link[u * self.speed.len() + v]
        }
    }

    /// Row `u` of the link-strength matrix: `comm_time(data, u, v)` is
    /// `data / row[v]` for `v != u`.  §Perf: the EFT inner loops hold a
    /// parent's row across all candidate nodes, turning the per-(parent,
    /// node) lookup into a plain slice index.
    #[inline]
    pub fn comm_row(&self, u: usize) -> &[f64] {
        let n = self.speed.len();
        &self.link[u * n..(u + 1) * n]
    }

    /// Mean execution time of a `cost` across all nodes — the `w̄(t)` used
    /// by rank computations.
    pub fn mean_exec_time(&self, cost: f64) -> f64 {
        let inv: f64 = self.speed.iter().map(|s| 1.0 / s).sum();
        cost * inv / self.speed.len() as f64
    }

    /// Mean transfer time of `data` across all ordered distinct pairs —
    /// the `c̄(e)` used by rank computations.
    pub fn mean_comm_time(&self, data: f64) -> f64 {
        let n = self.n_nodes();
        if n < 2 {
            return 0.0;
        }
        let mut acc = 0.0;
        for u in 0..n {
            for v in 0..n {
                if u != v {
                    acc += data / self.link(u, v);
                }
            }
        }
        acc / (n * (n - 1)) as f64
    }

    /// The induced sub-network over `nodes` (order preserved): speeds
    /// and link strengths are copied **verbatim**, so every
    /// [`Network::exec_time`]/[`Network::comm_time`] a scheduler reads
    /// on the sub-network is bit-identical to the value the full
    /// network reports for the corresponding global nodes — a schedule
    /// computed on the sub-network replays exactly on the full network
    /// after index remapping.  Passing every node in order reproduces
    /// `self` exactly.  The federation layer ([`crate::federation`])
    /// uses this to hand each shard its cluster's slice of the pool.
    ///
    /// Panics if `nodes` is empty or repeats a node (a repeated node
    /// would produce a zero off-diagonal link, which `Network::new`
    /// rejects).
    pub fn subnetwork(&self, nodes: &[usize]) -> Network {
        let speed: Vec<f64> = nodes.iter().map(|&v| self.speed[v]).collect();
        let n = nodes.len();
        let mut link = vec![0.0; n * n];
        for (i, &u) in nodes.iter().enumerate() {
            for (j, &v) in nodes.iter().enumerate() {
                if i != j {
                    link[i * n + j] = self.link(u, v);
                }
            }
        }
        Network::new(speed, link)
    }

    /// Mean of 1/s(v) — cached by hot paths to avoid recomputation.
    pub fn mean_inv_speed(&self) -> f64 {
        self.speed.iter().map(|s| 1.0 / s).sum::<f64>() / self.speed.len() as f64
    }

    /// Mean of 1/s(u,v) over ordered distinct pairs.
    pub fn mean_inv_link(&self) -> f64 {
        let n = self.n_nodes();
        if n < 2 {
            return 0.0;
        }
        let mut acc = 0.0;
        for u in 0..n {
            for v in 0..n {
                if u != v {
                    acc += 1.0 / self.link(u, v);
                }
            }
        }
        acc / (n * (n - 1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        // 2 nodes: speeds 1 and 2; link strength 4.
        Network::new(vec![1.0, 2.0], vec![0.0, 4.0, 4.0, 0.0])
    }

    #[test]
    fn exec_and_comm_times() {
        let n = tiny();
        assert_eq!(n.exec_time(8.0, 0), 8.0);
        assert_eq!(n.exec_time(8.0, 1), 4.0);
        assert_eq!(n.comm_time(8.0, 0, 1), 2.0);
        assert_eq!(n.comm_time(8.0, 1, 0), 2.0);
        assert_eq!(n.comm_time(8.0, 1, 1), 0.0, "co-located transfer is free");
    }

    #[test]
    fn mean_times() {
        let n = tiny();
        // mean exec of cost 8: (8/1 + 8/2)/2 = 6
        assert!((n.mean_exec_time(8.0) - 6.0).abs() < 1e-12);
        // mean comm of data 8 over both ordered pairs: 2
        assert!((n.mean_comm_time(8.0) - 2.0).abs() < 1e-12);
        assert!((n.mean_inv_speed() - 0.75).abs() < 1e-12);
        assert!((n.mean_inv_link() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn comm_row_matches_comm_time() {
        let n = tiny();
        let row0 = n.comm_row(0);
        assert_eq!(row0.len(), 2);
        assert_eq!(8.0 / row0[1], n.comm_time(8.0, 0, 1));
        let row1 = n.comm_row(1);
        assert_eq!(8.0 / row1[0], n.comm_time(8.0, 1, 0));
    }

    #[test]
    fn homogeneous_network() {
        let n = Network::homogeneous(4);
        assert_eq!(n.n_nodes(), 4);
        assert_eq!(n.exec_time(3.0, 2), 3.0);
        assert_eq!(n.comm_time(3.0, 0, 3), 3.0);
    }

    #[test]
    fn generate_respects_bounds_and_symmetry() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let d = TruncatedGaussian::new(1.0, 0.5, 0.2, 3.0);
        let n = Network::generate(8, &d, &d, &mut rng);
        assert_eq!(n.n_nodes(), 8);
        for v in 0..8 {
            assert!((0.2..=3.0).contains(&n.speed(v)));
        }
        for u in 0..8 {
            for v in 0..8 {
                if u != v {
                    assert_eq!(n.link(u, v), n.link(v, u));
                    assert!((0.2..=3.0).contains(&n.link(u, v)));
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "symmetric")]
    fn rejects_asymmetric_links() {
        Network::new(vec![1.0, 1.0], vec![0.0, 2.0, 3.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_zero_speed() {
        Network::new(vec![0.0], vec![0.0]);
    }

    #[test]
    fn subnetwork_identity_and_subset() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let d = TruncatedGaussian::new(1.0, 0.5, 0.2, 3.0);
        let net = Network::generate(6, &d, &d, &mut rng);
        // identity: every node in order reproduces the network bit-exactly
        let all: Vec<usize> = (0..6).collect();
        let id = net.subnetwork(&all);
        for v in 0..6 {
            assert_eq!(id.speed(v).to_bits(), net.speed(v).to_bits());
            for u in 0..6 {
                if u != v {
                    assert_eq!(id.link(u, v).to_bits(), net.link(u, v).to_bits());
                }
            }
        }
        // subset: exec/comm times match the global nodes verbatim
        let nodes = [4usize, 1, 5];
        let sub = net.subnetwork(&nodes);
        assert_eq!(sub.n_nodes(), 3);
        for (i, &u) in nodes.iter().enumerate() {
            assert_eq!(sub.exec_time(7.0, i).to_bits(), net.exec_time(7.0, u).to_bits());
            for (j, &v) in nodes.iter().enumerate() {
                assert_eq!(
                    sub.comm_time(7.0, i, j).to_bits(),
                    net.comm_time(7.0, u, v).to_bits()
                );
            }
        }
    }

    #[test]
    fn single_node_network_mean_comm_zero() {
        let n = Network::new(vec![2.0], vec![0.0]);
        assert_eq!(n.mean_comm_time(10.0), 0.0);
        assert_eq!(n.mean_inv_link(), 0.0);
    }
}
