//! Statistical distributions and summaries used by the workload and
//! network generators (§VI of the paper) and the experiment harness.
//!
//! The paper samples task/edge weights from a **5-component truncated
//! Gaussian mixture** and node speeds / link rates from **single truncated
//! Gaussians**; both are implemented here on top of [`crate::prng`].

use crate::prng::Xoshiro256pp;

/// Gaussian truncated to `[lo, hi]`, sampled by rejection with a
/// clamp fallback after a bounded number of attempts (keeps worst-case
/// draws O(1) even for pathological bounds).
#[derive(Clone, Debug)]
pub struct TruncatedGaussian {
    pub mean: f64,
    pub std: f64,
    pub lo: f64,
    pub hi: f64,
}

impl TruncatedGaussian {
    pub fn new(mean: f64, std: f64, lo: f64, hi: f64) -> Self {
        assert!(lo < hi, "empty truncation interval [{lo}, {hi}]");
        assert!(std >= 0.0);
        Self { mean, std, lo, hi }
    }

    pub fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        if self.std == 0.0 {
            return self.mean.clamp(self.lo, self.hi);
        }
        for _ in 0..64 {
            let x = self.mean + self.std * rng.normal();
            if x >= self.lo && x <= self.hi {
                return x;
            }
        }
        // Pathological truncation (mass far outside [lo, hi]): fall back
        // to a uniform draw inside the interval rather than spinning.
        rng.uniform(self.lo, self.hi)
    }
}

/// Mixture of truncated Gaussians with arbitrary component weights.
#[derive(Clone, Debug)]
pub struct GaussianMixture {
    pub weights: Vec<f64>,
    pub components: Vec<TruncatedGaussian>,
}

impl GaussianMixture {
    pub fn new(weights: Vec<f64>, components: Vec<TruncatedGaussian>) -> Self {
        assert_eq!(weights.len(), components.len());
        assert!(!weights.is_empty());
        Self { weights, components }
    }

    /// The paper's workload prior: 5 components spread over `[lo, hi]`,
    /// equal weights, per-component std = span / 10.
    pub fn five_component(lo: f64, hi: f64) -> Self {
        let span = hi - lo;
        let comps = (0..5)
            .map(|i| {
                let mean = lo + span * (0.1 + 0.2 * i as f64);
                TruncatedGaussian::new(mean, span / 10.0, lo, hi)
            })
            .collect();
        Self::new(vec![1.0; 5], comps)
    }

    pub fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        let k = rng.weighted_index(&self.weights);
        self.components[k].sample(rng)
    }
}

/// Poisson arrival process: returns `n` sorted arrival times starting at 0
/// with exponential inter-arrival times of the given `rate`.
pub fn poisson_arrivals(rng: &mut Xoshiro256pp, n: usize, rate: f64) -> Vec<f64> {
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        if i > 0 {
            t += rng.exponential(rate);
        }
        out.push(t);
    }
    out
}

// ------------------------------------------------------------- summaries

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (0 for empty input).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// min/max over a slice (NaN-free inputs assumed).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
            (lo.min(x), hi.max(x))
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(42)
    }

    #[test]
    fn truncated_gaussian_respects_bounds() {
        let d = TruncatedGaussian::new(5.0, 3.0, 1.0, 8.0);
        let mut r = rng();
        for _ in 0..10_000 {
            let x = d.sample(&mut r);
            assert!((1.0..=8.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn truncated_gaussian_mean_close_when_untruncated() {
        let d = TruncatedGaussian::new(10.0, 1.0, 0.0, 20.0);
        let mut r = rng();
        let xs: Vec<f64> = (0..50_000).map(|_| d.sample(&mut r)).collect();
        assert!((mean(&xs) - 10.0).abs() < 0.05);
    }

    #[test]
    fn truncated_gaussian_pathological_bounds_terminate() {
        // Mean 12 sigma away from the window: rejection will fail; the
        // clamp fallback must still return something inside.
        let d = TruncatedGaussian::new(100.0, 1.0, 0.0, 1.0);
        let mut r = rng();
        for _ in 0..100 {
            let x = d.sample(&mut r);
            assert!((0.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn zero_std_is_clamped_mean() {
        let d = TruncatedGaussian::new(50.0, 0.0, 0.0, 10.0);
        assert_eq!(d.sample(&mut rng()), 10.0);
    }

    #[test]
    fn mixture_five_component_covers_interval() {
        let m = GaussianMixture::five_component(0.0, 100.0);
        let mut r = rng();
        let xs: Vec<f64> = (0..20_000).map(|_| m.sample(&mut r)).collect();
        let (lo, hi) = min_max(&xs);
        assert!(lo >= 0.0 && hi <= 100.0);
        // all five modes visited: bucket into 5 and check occupancy
        let mut buckets = [0usize; 5];
        for x in &xs {
            buckets[(x / 20.0).min(4.0) as usize] += 1;
        }
        for b in buckets {
            assert!(b > 1000, "bucket underpopulated: {buckets:?}");
        }
    }

    #[test]
    fn mixture_weights_respected() {
        let comps = vec![
            TruncatedGaussian::new(0.0, 0.01, -1.0, 1.0),
            TruncatedGaussian::new(10.0, 0.01, 9.0, 11.0),
        ];
        let m = GaussianMixture::new(vec![1.0, 4.0], comps);
        let mut r = rng();
        let far = (0..50_000)
            .filter(|_| m.sample(&mut r) > 5.0)
            .count() as f64;
        assert!((far / 50_000.0 - 0.8).abs() < 0.02);
    }

    #[test]
    fn poisson_arrivals_sorted_and_mean_gap() {
        let mut r = rng();
        let arr = poisson_arrivals(&mut r, 10_000, 0.5);
        assert_eq!(arr[0], 0.0);
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        let gaps: Vec<f64> = arr.windows(2).map(|w| w[1] - w[0]).collect();
        assert!((mean(&gaps) - 2.0).abs() < 0.1);
    }

    #[test]
    fn summary_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((std_dev(&xs) - 1.2909944487358056).abs() < 1e-12);
        assert_eq!(min_max(&xs), (1.0, 4.0));
    }
}
