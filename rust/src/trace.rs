//! Run traces: lossless JSON export/import of a dynamic run — the
//! problem's arrival trace, every event's preemption record, and the
//! final schedule.  Enables offline analysis, regression pinning
//! ("golden traces"), and sharing runs between machines.
//!
//! Two formats exist: `dts-trace-v1` records a **planned** run of the
//! static coordinator; `dts-sim-trace-v1` records a **realized** run of
//! the reactive runtime simulator — the timestamped arrival/start/
//! finish/replan event log plus the realized schedule.

use crate::coordinator::{DynamicProblem, DynamicResult, EventLog};
use crate::graph::Gid;
use crate::json::{self, Value};
use crate::schedule::{Assignment, Schedule};
use crate::sim::{SimLogEntry, SimLogKind, SimResult};

/// Graph summaries shared by both trace formats.  Scenario-axis fields
/// (importance weight, deadline) are emitted only when non-default, so
/// default-scenario traces stay byte-identical to pre-scenario ones.
fn graphs_json(problem: &DynamicProblem) -> Value {
    json::arr(
        problem
            .graphs
            .iter()
            .map(|(arrival, g)| {
                let mut fields = vec![
                    ("name", json::s(g.name())),
                    ("arrival", json::num(*arrival)),
                    ("n_tasks", json::num(g.n_tasks() as f64)),
                ];
                if g.weight() != 1.0 {
                    fields.push(("weight", json::num(g.weight())));
                }
                if let Some(d) = g.deadline() {
                    fields.push(("deadline", json::num(d)));
                }
                json::obj(fields)
            })
            .collect(),
    )
}

/// Gid-sorted assignment dump shared by both trace formats.
fn assignments_json(schedule: &Schedule) -> Value {
    let mut slots: Vec<(Gid, Assignment)> = schedule.iter().map(|(g, a)| (*g, *a)).collect();
    slots.sort_by_key(|(g, _)| *g);
    json::arr(
        slots
            .into_iter()
            .map(|(gid, a)| {
                json::obj(vec![
                    ("graph", json::num(gid.graph as f64)),
                    ("task", json::num(gid.task as f64)),
                    ("node", json::num(a.node as f64)),
                    ("start", json::num(a.start)),
                    ("finish", json::num(a.finish)),
                ])
            })
            .collect(),
    )
}

/// Serialize a finished run (problem shape + events + schedule).
pub fn to_json(problem: &DynamicProblem, result: &DynamicResult) -> Value {
    let events = result
        .events
        .iter()
        .map(|e| {
            json::obj(vec![
                ("graph", json::num(e.graph_idx as f64)),
                ("time", json::num(e.time)),
                ("pending", json::num(e.n_pending as f64)),
                ("reverted", json::num(e.n_reverted as f64)),
                ("runtime_s", json::num(e.sched_runtime_s)),
            ])
        })
        .collect();
    json::obj(vec![
        ("format", json::s("dts-trace-v1")),
        ("n_nodes", json::num(problem.network.n_nodes() as f64)),
        ("graphs", graphs_json(problem)),
        ("events", json::arr(events)),
        ("assignments", assignments_json(&result.schedule)),
        ("sched_runtime_s", json::num(result.sched_runtime_s)),
    ])
}

/// Serialize one realized-log entry exactly as `sim_to_json` embeds it
/// in the trace `events` array.  `dts serve` emits each decision line
/// through this same function, which is what makes the server's
/// decision stream byte-identical to the offline trace's event log
/// (pinned by `rust/tests/serve_replay.rs` and the CI serve-smoke
/// diff).
pub fn sim_event_json(e: &SimLogEntry) -> Value {
    let mut fields = vec![("time", json::num(e.time))];
    match e.kind {
        SimLogKind::Arrival { graph } => {
            fields.push(("kind", json::s("arrival")));
            fields.push(("graph", json::num(graph as f64)));
        }
        SimLogKind::Start { gid, node } => {
            fields.push(("kind", json::s("start")));
            fields.push(("graph", json::num(gid.graph as f64)));
            fields.push(("task", json::num(gid.task as f64)));
            fields.push(("node", json::num(node as f64)));
        }
        SimLogKind::Finish { gid, node, lateness } => {
            fields.push(("kind", json::s("finish")));
            fields.push(("graph", json::num(gid.graph as f64)));
            fields.push(("task", json::num(gid.task as f64)));
            fields.push(("node", json::num(node as f64)));
            fields.push(("lateness", json::num(lateness)));
        }
        SimLogKind::Replan {
            straggler,
            n_reverted,
            n_pending,
        } => {
            fields.push(("kind", json::s("replan")));
            fields.push(("straggler", Value::Bool(straggler)));
            fields.push(("reverted", json::num(n_reverted as f64)));
            fields.push(("pending", json::num(n_pending as f64)));
        }
        // the three fault kinds are logged only on fault-injected runs
        // ([`crate::sim::faults`]), so default traces never carry them —
        // the zero-fault byte-identity pin
        SimLogKind::NodeDown { node, wasted } => {
            fields.push(("kind", json::s("node_down")));
            fields.push(("node", json::num(node as f64)));
            fields.push(("wasted", json::num(wasted)));
        }
        SimLogKind::NodeUp { node, downtime } => {
            fields.push(("kind", json::s("node_up")));
            fields.push(("node", json::num(node as f64)));
            fields.push(("downtime", json::num(downtime)));
        }
        SimLogKind::Kill { gid, node, wasted } => {
            fields.push(("kind", json::s("kill")));
            fields.push(("graph", json::num(gid.graph as f64)));
            fields.push(("task", json::num(gid.task as f64)));
            fields.push(("node", json::num(node as f64)));
            fields.push(("wasted", json::num(wasted)));
        }
    }
    json::obj(fields)
}

/// Serialize a reactive simulated run: the realized-event log (arrivals,
/// observed starts/finishes with lateness, replans) plus the realized
/// schedule.
pub fn sim_to_json(problem: &DynamicProblem, result: &SimResult) -> Value {
    let events = result.log.iter().map(sim_event_json).collect();
    let mut fields = vec![
        ("format", json::s("dts-sim-trace-v1")),
        ("n_nodes", json::num(problem.network.n_nodes() as f64)),
        ("graphs", graphs_json(problem)),
        ("events", json::arr(events)),
        ("assignments", assignments_json(&result.schedule)),
        ("n_replans", json::num(result.n_replans() as f64)),
        (
            "n_straggler_replans",
            json::num(result.n_straggler_replans() as f64),
        ),
        ("n_reverted", json::num(result.n_reverted_total() as f64)),
        ("sched_runtime_s", json::num(result.sched_runtime_s)),
        ("replan_wall_s", json::num(result.replan_wall_s)),
        ("refresh_wall_s", json::num(result.refresh_wall_s)),
        ("bookkeep_wall_s", json::num(result.bookkeep_wall_s)),
    ];
    // fault summary only on fault-injected runs: a zero-fault trace is
    // byte-identical to one produced before faults existed
    if result.faults_enabled {
        fields.push(("n_failure_replans", json::num(result.n_failure_replans() as f64)));
        fields.push(("n_killed", json::num(result.n_killed as f64)));
        fields.push(("n_reexecuted", json::num(result.n_reexecuted as f64)));
        fields.push(("wasted_work_s", json::num(result.wasted_work_s)));
        fields.push((
            "mean_recovery_latency",
            json::num(result.mean_recovery_latency()),
        ));
    }
    json::obj(fields)
}

/// A parsed realized-run trace (realized schedule + event/replan counts;
/// the full log stays in the JSON for offline tooling).
#[derive(Debug, Clone)]
pub struct SimTrace {
    pub n_nodes: usize,
    pub schedule: Schedule,
    pub n_events: usize,
    pub n_replans: usize,
    pub n_straggler_replans: usize,
    /// tasks reverted across all replans (preemption-cost accounting)
    pub n_reverted: usize,
    pub sched_runtime_s: f64,
    /// total wall time of whole replan passes (0.0 in pre-PR-3 traces)
    pub replan_wall_s: f64,
    /// belief-refresh phase of `replan_wall_s` (0.0 in pre-PR-8 traces)
    pub refresh_wall_s: f64,
    /// bookkeeping phase of `replan_wall_s` (0.0 in pre-PR-8 traces);
    /// the heuristic phase is `sched_runtime_s` itself
    pub bookkeep_wall_s: f64,
}

/// Parse a `dts-sim-trace-v1` document.
pub fn sim_from_json(v: &Value) -> Result<SimTrace, String> {
    if v.get("format").and_then(|f| f.as_str()) != Some("dts-sim-trace-v1") {
        return Err("not a dts-sim-trace-v1 document".into());
    }
    let n_nodes = v
        .get("n_nodes")
        .and_then(|x| x.as_usize())
        .ok_or("missing n_nodes")?;
    let schedule = parse_assignments(v, n_nodes)?;
    let n_events = v
        .get("events")
        .and_then(|x| x.as_array())
        .ok_or("missing events")?
        .len();
    Ok(SimTrace {
        n_nodes,
        schedule,
        n_events,
        n_replans: v.get("n_replans").and_then(|x| x.as_usize()).unwrap_or(0),
        n_straggler_replans: v
            .get("n_straggler_replans")
            .and_then(|x| x.as_usize())
            .unwrap_or(0),
        n_reverted: v.get("n_reverted").and_then(|x| x.as_usize()).unwrap_or(0),
        sched_runtime_s: v
            .get("sched_runtime_s")
            .and_then(|x| x.as_f64())
            .unwrap_or(0.0),
        replan_wall_s: v
            .get("replan_wall_s")
            .and_then(|x| x.as_f64())
            .unwrap_or(0.0),
        refresh_wall_s: v
            .get("refresh_wall_s")
            .and_then(|x| x.as_f64())
            .unwrap_or(0.0),
        bookkeep_wall_s: v
            .get("bookkeep_wall_s")
            .and_then(|x| x.as_f64())
            .unwrap_or(0.0),
    })
}

/// Parse the shared `assignments` array into a schedule, rejecting (as
/// `Err`, never a panic) out-of-range nodes and duplicate tasks that a
/// corrupted or hand-edited trace could carry.
fn parse_assignments(v: &Value, n_nodes: usize) -> Result<Schedule, String> {
    let mut schedule = Schedule::new(n_nodes);
    for a in v
        .get("assignments")
        .and_then(|x| x.as_array())
        .ok_or("missing assignments")?
    {
        let get = |k: &str| a.get(k).and_then(|x| x.as_f64()).ok_or(format!("bad {k}"));
        let node_f = get("node")?;
        if !(node_f >= 0.0 && node_f < n_nodes as f64) {
            return Err(format!("assignment node {node_f} out of range 0..{n_nodes}"));
        }
        let gid = Gid::new(get("graph")? as usize, get("task")? as usize);
        if schedule.get(gid).is_some() {
            return Err(format!("duplicate assignment for {gid}"));
        }
        schedule.assign(
            gid,
            Assignment {
                node: node_f as usize,
                start: get("start")?,
                finish: get("finish")?,
            },
        );
    }
    Ok(schedule)
}

/// A parsed trace (schedule + events; graph summaries only — weights are
/// regenerable from the seed, so traces stay compact).
#[derive(Debug, Clone)]
pub struct Trace {
    pub n_nodes: usize,
    pub schedule: Schedule,
    pub events: Vec<EventLog>,
    pub sched_runtime_s: f64,
    pub graph_names: Vec<String>,
}

/// Parse a trace back from JSON.
pub fn from_json(v: &Value) -> Result<Trace, String> {
    if v.get("format").and_then(|f| f.as_str()) != Some("dts-trace-v1") {
        return Err("not a dts-trace-v1 document".into());
    }
    let n_nodes = v
        .get("n_nodes")
        .and_then(|x| x.as_usize())
        .ok_or("missing n_nodes")?;
    let schedule = parse_assignments(v, n_nodes)?;
    let mut events = Vec::new();
    for e in v
        .get("events")
        .and_then(|x| x.as_array())
        .ok_or("missing events")?
    {
        let get = |k: &str| e.get(k).and_then(|x| x.as_f64()).ok_or(format!("bad {k}"));
        events.push(EventLog {
            graph_idx: get("graph")? as usize,
            time: get("time")?,
            n_pending: get("pending")? as usize,
            n_reverted: get("reverted")? as usize,
            sched_runtime_s: get("runtime_s")?,
        });
    }
    let graph_names = v
        .get("graphs")
        .and_then(|x| x.as_array())
        .ok_or("missing graphs")?
        .iter()
        .map(|g| {
            g.get("name")
                .and_then(|n| n.as_str())
                .unwrap_or("?")
                .to_string()
        })
        .collect();
    Ok(Trace {
        n_nodes,
        schedule,
        events,
        sched_runtime_s: v
            .get("sched_runtime_s")
            .and_then(|x| x.as_f64())
            .unwrap_or(0.0),
        graph_names,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, Policy};
    use crate::schedulers::SchedulerKind;
    use crate::workloads::Dataset;

    fn run() -> (DynamicProblem, DynamicResult) {
        let prob = Dataset::RiotBench.instance(5, 9);
        let mut c = Coordinator::new(Policy::LastK(2), SchedulerKind::Cpop.make(0));
        let res = c.run(&prob);
        (prob, res)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let (prob, res) = run();
        let v = to_json(&prob, &res);
        // through text and back
        let text = v.to_string();
        let parsed = Value::from_str(&text).unwrap();
        let trace = from_json(&parsed).unwrap();

        assert_eq!(trace.n_nodes, prob.network.n_nodes());
        assert_eq!(trace.events.len(), res.events.len());
        assert_eq!(trace.schedule.n_assigned(), res.schedule.n_assigned());
        assert_eq!(trace.graph_names.len(), prob.graphs.len());
        for (gid, a) in res.schedule.iter() {
            assert_eq!(trace.schedule.get(*gid), Some(a));
        }
        assert!((trace.sched_runtime_s - res.sched_runtime_s).abs() < 1e-12);
    }

    #[test]
    fn rejects_wrong_format() {
        let v = Value::from_str(r#"{"format": "something-else"}"#).unwrap();
        assert!(from_json(&v).is_err());
        assert!(sim_from_json(&v).is_err());
        // the two formats are not interchangeable
        let (prob, res) = run();
        assert!(sim_from_json(&to_json(&prob, &res)).is_err());
    }

    #[test]
    fn malformed_assignments_are_errors_not_panics() {
        // node index beyond n_nodes
        let v = Value::from_str(
            r#"{"format":"dts-sim-trace-v1","n_nodes":1,"events":[],
                "assignments":[{"graph":0,"task":0,"node":5,"start":0,"finish":1}]}"#,
        )
        .unwrap();
        assert!(sim_from_json(&v).unwrap_err().contains("out of range"));
        // duplicate (graph, task)
        let v = Value::from_str(
            r#"{"format":"dts-trace-v1","n_nodes":1,"events":[],"graphs":[],
                "assignments":[{"graph":0,"task":0,"node":0,"start":0,"finish":1},
                               {"graph":0,"task":0,"node":0,"start":2,"finish":3}]}"#,
        )
        .unwrap();
        assert!(from_json(&v).unwrap_err().contains("duplicate"));
        // negative node
        let v = Value::from_str(
            r#"{"format":"dts-sim-trace-v1","n_nodes":2,"events":[],
                "assignments":[{"graph":0,"task":0,"node":-1,"start":0,"finish":1}]}"#,
        )
        .unwrap();
        assert!(sim_from_json(&v).unwrap_err().contains("out of range"));
    }

    fn sim_run() -> (DynamicProblem, crate::sim::SimResult) {
        use crate::coordinator::Policy;
        use crate::sim::{Reaction, ReactiveCoordinator, SimConfig};
        let prob = Dataset::Synthetic.instance(6, 13);
        let cfg = SimConfig {
            noise_std: 0.4,
            noise_seed: 2,
            reaction: Reaction::LastK {
                k: 2,
                threshold: 0.15,
            },
            record_frozen: false,
            full_refresh: false,
            faults: crate::sim::FaultConfig::NONE,
        };
        let mut rc =
            ReactiveCoordinator::new(Policy::LastK(3), SchedulerKind::Heft.make(0), cfg);
        let res = rc.run(&prob);
        (prob, res)
    }

    #[test]
    fn sim_trace_roundtrips_bit_exactly() {
        let (prob, res) = sim_run();
        let text = sim_to_json(&prob, &res).to_string();
        let trace = sim_from_json(&Value::from_str(&text).unwrap()).unwrap();
        assert_eq!(trace.n_nodes, prob.network.n_nodes());
        assert_eq!(trace.schedule.n_assigned(), res.schedule.n_assigned());
        assert_eq!(trace.n_events, res.log.len());
        assert_eq!(trace.n_replans, res.n_replans());
        assert_eq!(trace.n_straggler_replans, res.n_straggler_replans());
        assert_eq!(trace.n_reverted, res.n_reverted_total());
        assert!((trace.replan_wall_s - res.replan_wall_s).abs() < 1e-9);
        assert!((trace.refresh_wall_s - res.refresh_wall_s).abs() < 1e-9);
        assert!((trace.bookkeep_wall_s - res.bookkeep_wall_s).abs() < 1e-9);
        for (gid, a) in res.schedule.iter() {
            assert_eq!(trace.schedule.get(*gid), Some(a), "{gid}");
        }
        // realized metrics recomputed from the parsed trace match the
        // live run bit-exactly
        use crate::metrics;
        let live = metrics::total_makespan(&res.schedule, &prob.graphs);
        let parsed = metrics::total_makespan(&trace.schedule, &prob.graphs);
        assert_eq!(live.to_bits(), parsed.to_bits());
    }

    #[test]
    fn sim_trace_event_log_serializes_every_kind() {
        let (prob, res) = sim_run();
        let v = sim_to_json(&prob, &res);
        let events = v.get("events").and_then(|x| x.as_array()).unwrap();
        let kind_of = |e: &Value| e.get("kind").and_then(|k| k.as_str()).unwrap().to_string();
        let kinds: std::collections::HashSet<String> = events.iter().map(kind_of).collect();
        assert!(kinds.contains("arrival"));
        assert!(kinds.contains("start"));
        assert!(kinds.contains("finish"));
        assert!(kinds.contains("replan"));
        // starts + finishes cover the whole workload
        let n_starts = events.iter().filter(|e| kind_of(e) == "start").count();
        let n_fin = events.iter().filter(|e| kind_of(e) == "finish").count();
        assert_eq!(n_starts, prob.total_tasks());
        assert_eq!(n_fin, prob.total_tasks());
    }

    #[test]
    fn scenario_fields_appear_only_when_non_default() {
        let (prob, res) = run();
        let v = to_json(&prob, &res);
        let graphs = v.get("graphs").and_then(|x| x.as_array()).unwrap();
        for g in graphs {
            assert!(g.get("weight").is_none(), "unit weight must be omitted");
            assert!(g.get("deadline").is_none(), "absent deadline must be omitted");
        }
        // stamp a weight and a deadline on the first graph and re-dump
        let mut prob2 = prob.clone();
        prob2.graphs[0].1.set_weight(3.0);
        prob2.graphs[0].1.set_deadline(123.0);
        let v2 = to_json(&prob2, &res);
        let graphs2 = v2.get("graphs").and_then(|x| x.as_array()).unwrap();
        assert_eq!(graphs2[0].get("weight").and_then(|w| w.as_f64()), Some(3.0));
        assert_eq!(
            graphs2[0].get("deadline").and_then(|d| d.as_f64()),
            Some(123.0)
        );
        assert!(graphs2[1].get("weight").is_none());
        // the parser is lenient: the enriched document still round-trips
        let trace = from_json(&Value::from_str(&v2.to_string()).unwrap()).unwrap();
        assert_eq!(trace.schedule.n_assigned(), res.schedule.n_assigned());
    }

    #[test]
    fn trace_metrics_match_original() {
        use crate::metrics;
        let (prob, res) = run();
        let trace = from_json(&to_json(&prob, &res)).unwrap();
        let a = metrics::total_makespan(&res.schedule, &prob.graphs);
        let b = metrics::total_makespan(&trace.schedule, &prob.graphs);
        assert_eq!(a, b);
    }
}
