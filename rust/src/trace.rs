//! Run traces: lossless JSON export/import of a dynamic run — the
//! problem's arrival trace, every event's preemption record, and the
//! final schedule.  Enables offline analysis, regression pinning
//! ("golden traces"), and sharing runs between machines.

use crate::coordinator::{DynamicProblem, DynamicResult, EventLog};
use crate::graph::Gid;
use crate::json::{self, Value};
use crate::schedule::{Assignment, Schedule};

/// Serialize a finished run (problem shape + events + schedule).
pub fn to_json(problem: &DynamicProblem, result: &DynamicResult) -> Value {
    let graphs = problem
        .graphs
        .iter()
        .map(|(arrival, g)| {
            json::obj(vec![
                ("name", json::s(g.name())),
                ("arrival", json::num(*arrival)),
                ("n_tasks", json::num(g.n_tasks() as f64)),
            ])
        })
        .collect();
    let events = result
        .events
        .iter()
        .map(|e| {
            json::obj(vec![
                ("graph", json::num(e.graph_idx as f64)),
                ("time", json::num(e.time)),
                ("pending", json::num(e.n_pending as f64)),
                ("reverted", json::num(e.n_reverted as f64)),
                ("runtime_s", json::num(e.sched_runtime_s)),
            ])
        })
        .collect();
    let mut slots: Vec<(Gid, Assignment)> =
        result.schedule.iter().map(|(g, a)| (*g, *a)).collect();
    slots.sort_by_key(|(g, _)| *g);
    let assignments = slots
        .into_iter()
        .map(|(gid, a)| {
            json::obj(vec![
                ("graph", json::num(gid.graph as f64)),
                ("task", json::num(gid.task as f64)),
                ("node", json::num(a.node as f64)),
                ("start", json::num(a.start)),
                ("finish", json::num(a.finish)),
            ])
        })
        .collect();
    json::obj(vec![
        ("format", json::s("dts-trace-v1")),
        ("n_nodes", json::num(problem.network.n_nodes() as f64)),
        ("graphs", json::arr(graphs)),
        ("events", json::arr(events)),
        ("assignments", json::arr(assignments)),
        ("sched_runtime_s", json::num(result.sched_runtime_s)),
    ])
}

/// A parsed trace (schedule + events; graph summaries only — weights are
/// regenerable from the seed, so traces stay compact).
#[derive(Debug, Clone)]
pub struct Trace {
    pub n_nodes: usize,
    pub schedule: Schedule,
    pub events: Vec<EventLog>,
    pub sched_runtime_s: f64,
    pub graph_names: Vec<String>,
}

/// Parse a trace back from JSON.
pub fn from_json(v: &Value) -> Result<Trace, String> {
    if v.get("format").and_then(|f| f.as_str()) != Some("dts-trace-v1") {
        return Err("not a dts-trace-v1 document".into());
    }
    let n_nodes = v
        .get("n_nodes")
        .and_then(|x| x.as_usize())
        .ok_or("missing n_nodes")?;
    let mut schedule = Schedule::new(n_nodes);
    for a in v
        .get("assignments")
        .and_then(|x| x.as_array())
        .ok_or("missing assignments")?
    {
        let get = |k: &str| a.get(k).and_then(|x| x.as_f64()).ok_or(format!("bad {k}"));
        schedule.assign(
            Gid::new(get("graph")? as usize, get("task")? as usize),
            Assignment {
                node: get("node")? as usize,
                start: get("start")?,
                finish: get("finish")?,
            },
        );
    }
    let mut events = Vec::new();
    for e in v
        .get("events")
        .and_then(|x| x.as_array())
        .ok_or("missing events")?
    {
        let get = |k: &str| e.get(k).and_then(|x| x.as_f64()).ok_or(format!("bad {k}"));
        events.push(EventLog {
            graph_idx: get("graph")? as usize,
            time: get("time")?,
            n_pending: get("pending")? as usize,
            n_reverted: get("reverted")? as usize,
            sched_runtime_s: get("runtime_s")?,
        });
    }
    let graph_names = v
        .get("graphs")
        .and_then(|x| x.as_array())
        .ok_or("missing graphs")?
        .iter()
        .map(|g| {
            g.get("name")
                .and_then(|n| n.as_str())
                .unwrap_or("?")
                .to_string()
        })
        .collect();
    Ok(Trace {
        n_nodes,
        schedule,
        events,
        sched_runtime_s: v
            .get("sched_runtime_s")
            .and_then(|x| x.as_f64())
            .unwrap_or(0.0),
        graph_names,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, Policy};
    use crate::schedulers::SchedulerKind;
    use crate::workloads::Dataset;

    fn run() -> (DynamicProblem, DynamicResult) {
        let prob = Dataset::RiotBench.instance(5, 9);
        let mut c = Coordinator::new(Policy::LastK(2), SchedulerKind::Cpop.make(0));
        let res = c.run(&prob);
        (prob, res)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let (prob, res) = run();
        let v = to_json(&prob, &res);
        // through text and back
        let text = v.to_string();
        let parsed = Value::from_str(&text).unwrap();
        let trace = from_json(&parsed).unwrap();

        assert_eq!(trace.n_nodes, prob.network.n_nodes());
        assert_eq!(trace.events.len(), res.events.len());
        assert_eq!(trace.schedule.n_assigned(), res.schedule.n_assigned());
        assert_eq!(trace.graph_names.len(), prob.graphs.len());
        for (gid, a) in res.schedule.iter() {
            assert_eq!(trace.schedule.get(*gid), Some(a));
        }
        assert!((trace.sched_runtime_s - res.sched_runtime_s).abs() < 1e-12);
    }

    #[test]
    fn rejects_wrong_format() {
        let v = Value::from_str(r#"{"format": "something-else"}"#).unwrap();
        assert!(from_json(&v).is_err());
    }

    #[test]
    fn trace_metrics_match_original() {
        use crate::metrics;
        let (prob, res) = run();
        let trace = from_json(&to_json(&prob, &res)).unwrap();
        let a = metrics::total_makespan(&res.schedule, &prob.graphs);
        let b = metrics::total_makespan(&trace.schedule, &prob.graphs);
        assert_eq!(a, b);
    }
}
