//! The `dts-serve-v1` wire protocol: NDJSON request parsing and the
//! structured response/error line builders.
//!
//! One JSON object per line in both directions.  Requests are either an
//! **op object** (`{"op":"arrive","graph":3}`, `{"op":"run"}`, …) or a
//! whole recorded `dts-sim-trace-v1` document on a single line (replay
//! ingestion).  Every response line carries a `"kind"` discriminator;
//! the decision stream (kinds `arrival`/`start`/`finish`/`replan`) is
//! byte-identical to the offline trace's `events` array entries
//! ([`crate::trace::sim_event_json`]), which is what lets the CI
//! serve-smoke job diff the two with `cmp`.
//!
//! **Hardening contract** (pinned by `rust/tests/serve_ingest.rs`):
//! parsing never panics, every malformed line maps to exactly one
//! [`Reject`] with a stable `code`, and a rejected line leaves server
//! state untouched.  The full schema is documented in `docs/SERVE.md`.

use crate::json::{self, Value};

/// Protocol format tag carried by the hello line.
pub const FORMAT: &str = "dts-serve-v1";

/// A parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Admit one graph of the server's instance into the pending epoch.
    Arrive { graph: usize },
    /// A whole `dts-sim-trace-v1` document: validate it against the
    /// server's instance and admit every graph (replay ingestion).
    Trace(Value),
    /// Run the pending epoch to completion on the virtual clock,
    /// streaming decisions out.
    Run,
    /// Journal a `dts-serve-snapshot-v1` document to the configured
    /// snapshot path.
    Snapshot,
    /// One-line JSON snapshot of the telemetry registry + server state.
    Stats,
    /// Arm a crash/restart fault model for every epoch run after this
    /// line (`mtbf`/`mttr` in simulated seconds; optional `seed`
    /// defaults to [`crate::sim::DEFAULT_FAULT_SEED`]).  Parameter
    /// *validity* (positive, finite) is checked server-side against
    /// [`crate::sim::FaultModel::validate`] → `code:"range"`.
    Inject {
        mtbf: f64,
        mttr: f64,
        seed: Option<u64>,
    },
    /// Hard stop *without* drain — the crash-simulation half of the
    /// snapshot/restore workflow.
    Quit,
    /// Graceful drain: flush the pending epoch, emit the final summary
    /// and bye lines, then exit.
    Shutdown,
}

/// A structured rejection: stable machine code + human reason.  Becomes
/// one `{"kind":"error",…}` line; documented codes in `docs/SERVE.md`.
#[derive(Clone, Debug, PartialEq)]
pub struct Reject {
    /// `parse` | `shape` | `op` | `field` | `range` | `duplicate` |
    /// `trace` | `snapshot`
    pub code: &'static str,
    pub reason: String,
}

impl Reject {
    pub fn new(code: &'static str, reason: impl Into<String>) -> Reject {
        Reject {
            code,
            reason: reason.into(),
        }
    }
}

/// Parse one request line (already non-empty and trimmed).  Pure
/// syntax/shape validation — instance-dependent checks (graph range,
/// duplicates, trace/instance agreement) live on the server, which owns
/// the instance.
pub fn parse_request(line: &str) -> Result<Request, Reject> {
    let v = Value::from_str(line).map_err(|e| Reject::new("parse", e.to_string()))?;
    if v.as_object().is_none() {
        return Err(Reject::new("shape", "request must be a JSON object"));
    }
    if let Some(fmt) = v.get("format") {
        return match fmt.as_str() {
            Some("dts-sim-trace-v1") => Ok(Request::Trace(v)),
            Some(other) => Err(Reject::new(
                "shape",
                format!("unsupported document format {other:?}"),
            )),
            None => Err(Reject::new("shape", "\"format\" must be a string")),
        };
    }
    let op = match v.get("op") {
        Some(op) => op
            .as_str()
            .ok_or_else(|| Reject::new("shape", "\"op\" must be a string"))?,
        None => return Err(Reject::new("shape", "missing \"op\" (or \"format\")")),
    };
    match op {
        "arrive" => {
            let graph = v
                .get("graph")
                .ok_or_else(|| Reject::new("field", "arrive: missing \"graph\""))?;
            Ok(Request::Arrive {
                graph: graph_index(graph)?,
            })
        }
        "run" => Ok(Request::Run),
        "inject" => {
            let mtbf = float_field(&v, "inject", "mtbf")?;
            let mttr = float_field(&v, "inject", "mttr")?;
            let seed = match v.get("seed") {
                None => None,
                Some(s) => Some(seed_value(s)?),
            };
            Ok(Request::Inject { mtbf, mttr, seed })
        }
        "snapshot" => Ok(Request::Snapshot),
        "stats" => Ok(Request::Stats),
        "quit" => Ok(Request::Quit),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(Reject::new("op", format!("unknown op {other:?}"))),
    }
}

/// A required numeric field (shape check only — range/validity checks
/// are the server's, which owns the fault model).
fn float_field(v: &Value, op: &str, name: &str) -> Result<f64, Reject> {
    v.get(name)
        .ok_or_else(|| Reject::new("field", format!("{op}: missing \"{name}\"")))?
        .as_f64()
        .ok_or_else(|| Reject::new("field", format!("{op}: \"{name}\" must be a number")))
}

/// A seed must be a non-negative integer-valued JSON number.
fn seed_value(v: &Value) -> Result<u64, Reject> {
    let x = v
        .as_f64()
        .ok_or_else(|| Reject::new("field", "\"seed\" must be a number"))?;
    if !x.is_finite() || x.fract() != 0.0 || x < 0.0 || x >= u64::MAX as f64 {
        return Err(Reject::new(
            "field",
            format!("\"seed\" must be a non-negative integer, got {x}"),
        ));
    }
    Ok(x as u64)
}

/// A graph id must be a non-negative integer-valued JSON number (no
/// floats, no strings, no `-1`), small enough to index a `Vec`.
fn graph_index(v: &Value) -> Result<usize, Reject> {
    let x = v
        .as_f64()
        .ok_or_else(|| Reject::new("field", "\"graph\" must be a number"))?;
    if !x.is_finite() || x.fract() != 0.0 || x < 0.0 || x >= u32::MAX as f64 {
        return Err(Reject::new(
            "field",
            format!("\"graph\" must be a non-negative integer, got {x}"),
        ));
    }
    Ok(x as usize)
}

/// The `{"kind":"error",…}` record a rejected line produces.  `line` is
/// the 1-based request-line number within the session (snapshot-carried,
/// so numbering continues across a restore).
pub fn error_line(line_no: u64, rej: &Reject) -> String {
    json::obj(vec![
        ("kind", json::s("error")),
        ("line", json::num(line_no as f64)),
        ("code", json::s(rej.code)),
        ("reason", json::s(&rej.reason)),
    ])
    .to_string()
}

/// Fuzz entry point (`--features fuzz`): feeding arbitrary bytes through
/// the request parser must never panic — invalid UTF-8 and garbage both
/// land in `Err`.  A libFuzzer harness would call this from its
/// `fuzz_target!` body; the ingest property suite drives it with a
/// deterministic byte generator in the meantime.
#[cfg(feature = "fuzz")]
pub fn fuzz_ingest_line(data: &[u8]) {
    if let Ok(s) = std::str::from_utf8(data) {
        let _ = parse_request(s.trim());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_op() {
        assert_eq!(
            parse_request(r#"{"op":"arrive","graph":3}"#).unwrap(),
            Request::Arrive { graph: 3 }
        );
        assert_eq!(parse_request(r#"{"op":"run"}"#).unwrap(), Request::Run);
        assert_eq!(
            parse_request(r#"{"op":"snapshot"}"#).unwrap(),
            Request::Snapshot
        );
        assert_eq!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert_eq!(
            parse_request(r#"{"op":"inject","mtbf":50,"mttr":5}"#).unwrap(),
            Request::Inject {
                mtbf: 50.0,
                mttr: 5.0,
                seed: None
            }
        );
        assert_eq!(
            parse_request(r#"{"op":"inject","mtbf":50,"mttr":5,"seed":7}"#).unwrap(),
            Request::Inject {
                mtbf: 50.0,
                mttr: 5.0,
                seed: Some(7)
            }
        );
        assert_eq!(parse_request(r#"{"op":"quit"}"#).unwrap(), Request::Quit);
        assert_eq!(
            parse_request(r#"{"op":"shutdown"}"#).unwrap(),
            Request::Shutdown
        );
    }

    #[test]
    fn trace_documents_route_by_format() {
        let doc = r#"{"format":"dts-sim-trace-v1","n_nodes":2}"#;
        assert!(matches!(
            parse_request(doc).unwrap(),
            Request::Trace(_)
        ));
        assert_eq!(
            parse_request(r#"{"format":"dts-trace-v1"}"#).unwrap_err().code,
            "shape"
        );
    }

    #[test]
    fn rejects_carry_stable_codes() {
        for (line, code) in [
            ("{", "parse"),
            ("not json", "parse"),
            ("[1,2]", "shape"),
            ("42", "shape"),
            (r#"{"graph":1}"#, "shape"),
            (r#"{"op":7}"#, "shape"),
            (r#"{"op":"frobnicate"}"#, "op"),
            (r#"{"op":"arrive"}"#, "field"),
            (r#"{"op":"arrive","graph":"3"}"#, "field"),
            (r#"{"op":"arrive","graph":1.5}"#, "field"),
            (r#"{"op":"arrive","graph":-1}"#, "field"),
            (r#"{"op":"arrive","graph":1e300}"#, "field"),
            (r#"{"op":"inject"}"#, "field"),
            (r#"{"op":"inject","mtbf":50}"#, "field"),
            (r#"{"op":"inject","mtbf":"50","mttr":5}"#, "field"),
            (r#"{"op":"inject","mtbf":50,"mttr":5,"seed":-1}"#, "field"),
            (r#"{"op":"inject","mtbf":50,"mttr":5,"seed":1.5}"#, "field"),
            (r#"{"format":17}"#, "shape"),
        ] {
            let rej = parse_request(line).unwrap_err();
            assert_eq!(rej.code, code, "line {line:?} → {rej:?}");
        }
    }

    #[test]
    fn error_lines_are_single_json_objects() {
        let l = error_line(9, &Reject::new("parse", "bad \"thing\""));
        let v = Value::from_str(&l).unwrap();
        assert_eq!(v.get("kind").and_then(|k| k.as_str()), Some("error"));
        assert_eq!(v.get("line").and_then(|k| k.as_usize()), Some(9));
        assert_eq!(v.get("code").and_then(|k| k.as_str()), Some("parse"));
        assert!(!l.contains('\n'));
    }
}
