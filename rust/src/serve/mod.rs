//! `dts serve` — a long-lived streaming scheduler daemon (the
//! millions-of-users front door of ROADMAP direction 1).
//!
//! The server wraps the reactive runtime in an NDJSON request/response
//! loop: graph-arrival requests come in on stdin (or a TCP socket via
//! `--listen addr:port`), dispatch/replan/finish decisions stream out,
//! and the state journal snapshots to disk for kill/restore recovery.
//! Protocol schemas live in `docs/SERVE.md`; the wire parsing in
//! [`protocol`], the journal format in [`snapshot`].
//!
//! ## The replay bit-identity guarantee
//!
//! The offline sim is *one client of the same runtime*: feeding a
//! recorded `dts-sim-trace-v1` document (or the equivalent `arrive`
//! ops) followed by `{"op":"run"}` reproduces the offline
//! `dts simulate` cell **bit-exactly** — the decision stream is
//! byte-identical to the trace's `events` array (both sides serialize
//! through [`crate::trace::sim_event_json`]), and the epoch summary
//! carries the same 18-metric block to the bit.  This holds because the
//! server regenerates the identical instance
//! (`dataset.instance_scenario(n_graphs, seed, load, …)`) and builds
//! the identical coordinator (`noise_seed = seed ^ 0xA11CE`, scheduler
//! seed `seed ^ 0x5EED`) the offline harness builds
//! ([`crate::experiments`]'s `run_sim_cell`).  Pinned by
//! `rust/tests/serve_replay.rs` and the CI `serve-smoke` byte-diff.
//!
//! ## Epochs (virtual-clock batches)
//!
//! Arrivals accumulate in a **pending** set; `{"op":"run"}` (or the
//! EOF/shutdown drain) closes the batch and runs it as one *epoch*: a
//! discrete-event simulation over the pending graphs at their recorded
//! arrival times, streamed out as decision lines plus a summary.  An
//! epoch over the full instance reproduces the offline run bit-exactly;
//! a partial epoch is its own closed virtual-clock world (noise is
//! keyed by epoch-local graph index, exactly as a smaller offline
//! instance would be).  Controller state (AIMD windows, budget tokens)
//! is epoch-scoped: each epoch builds a fresh coordinator, which is
//! precisely what makes the journal snapshot/restore exact — no
//! coordinator internals ever need serializing.
//!
//! ## Drain and crash semantics
//!
//! EOF on stdin and `{"op":"shutdown"}` drain gracefully: the pending
//! epoch is flushed (decisions + 18-metric summary), a final snapshot
//! is journaled, telemetry exports, and a `bye` line closes the
//! session.  `{"op":"quit"}` is the *crash simulation*: exit
//! immediately, no drain, no extra snapshot — restore then resumes from
//! the last journaled state and continues bit-identically to an
//! uninterrupted session (`rust/tests/serve_snapshot.rs`).  The
//! zero-dependency build has no signal-handler facility, so SIGTERM is
//! not caught: the periodic journal (`--snapshot-every N`) is the
//! recovery story for hard kills, and EOF/`shutdown` are the graceful
//! paths (docs/SERVE.md).
//!
//! ## Per-request latency accounting
//!
//! Every handled request line runs under a
//! [`Hist::ServeRequestNs`] span; `serve_requests` / `serve_errors` /
//! `serve_arrivals` / `serve_snapshots` counters land in the same
//! registry as the replan-phase spans, export through `--telemetry`
//! (one [`CellSpan`] per epoch), and answer `{"op":"stats"}` inline.

pub mod protocol;
pub mod snapshot;

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpListener;

use crate::coordinator::{DynamicProblem, Variant};
use crate::experiments::metric_row_json;
use crate::federation::FederatedCoordinator;
use crate::json::{self, Value};
use crate::metrics::MetricRow;
use crate::policy::PolicySpec;
use crate::sim::{
    Reaction, ReactiveCoordinator, SimConfig, SimLogEntry, SimLogKind, SimResult,
};
use crate::telemetry::{self, export::CellSpan, Counter, Hist, Span};
use crate::trace;
use crate::workloads::{Dataset, Scenario};

pub use protocol::{error_line, parse_request, Reject, Request, FORMAT};

/// How the server reacts to stragglers: the built-in
/// [`Reaction`] trigger (mirrors `dts simulate`) or a
/// [`PolicySpec`] controller (mirrors `dts policy`; fresh instance per
/// epoch and per shard).
#[derive(Clone, Debug)]
pub enum Controller {
    Reaction(Reaction),
    Spec(PolicySpec),
}

impl Controller {
    pub fn label(&self) -> String {
        match self {
            Controller::Reaction(r) => r.label(),
            Controller::Spec(s) => s.label(),
        }
    }
}

/// Everything that shapes the instance and the coordinator — the
/// server-side half of the replay bit-identity contract.  Two servers
/// with equal configs are interchangeable; the snapshot journal embeds
/// this block and restore refuses a mismatch.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub dataset: Dataset,
    pub n_graphs: usize,
    pub seed: u64,
    pub variant: Variant,
    pub noise_std: f64,
    pub controller: Controller,
    /// 1 = monolithic [`ReactiveCoordinator`]; >1 = [`FederatedCoordinator`]
    pub shards: usize,
    /// shard fan-out threads (federated only; bit-identical at any value)
    pub jobs: usize,
    pub load: f64,
    pub scenario: Scenario,
    /// Fault injection (CLI `--mtbf/--mttr/--fault-seed`, or a
    /// mid-session `{"op":"inject"}`).  Part of the restore contract:
    /// the snapshot config block embeds it whenever enabled, so
    /// `--restore` refuses a journal whose fault model differs from the
    /// CLI-resolved one.
    pub faults: crate::sim::FaultConfig,
}

impl ServeConfig {
    /// The identical [`SimConfig`] the offline harness builds for this
    /// cell (`noise_seed = seed ^ 0xA11CE` — the replay contract).
    pub fn sim_config(&self) -> SimConfig {
        SimConfig {
            noise_std: self.noise_std,
            noise_seed: self.seed ^ 0xA11CE,
            reaction: match &self.controller {
                Controller::Reaction(r) => *r,
                Controller::Spec(_) => Reaction::None,
            },
            record_frozen: false,
            full_refresh: false,
            faults: self.faults,
        }
    }

    /// Session label, matching the epoch coordinator's own label
    /// (`5P-HEFT σ0.30 L3@0.25`, `S4 …` when federated).
    pub fn label(&self) -> String {
        let core = format!(
            "{} σ{:.2} {}",
            self.variant.label(),
            self.noise_std,
            self.controller.label()
        );
        if self.shards > 1 {
            format!("S{} {}", self.shards, core)
        } else {
            core
        }
    }
}

/// What the request loop should do after a handled line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flow {
    Continue,
    /// hard stop, no drain (crash simulation)
    Quit,
    /// graceful drain then stop
    Shutdown,
}

/// How a pump session ended (one stdin session, or one TCP connection).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionEnd {
    /// input exhausted (stdin EOF → drain; TCP connection close → keep
    /// serving)
    Eof,
    Quit,
    Shutdown,
}

/// One epoch's realized outcome, ready for emission.
struct EpochOutcome {
    label: String,
    log: Vec<SimLogEntry>,
    metrics: MetricRow,
    n_replans: usize,
    n_straggler_replans: usize,
    n_reverted: usize,
    sched_runtime_s: f64,
    replan_wall_s: f64,
    refresh_wall_s: f64,
    bookkeep_wall_s: f64,
}

/// The daemon's resumable state: the regenerated instance plus the
/// admission journal.  [`handle_line`](Self::handle_line) is pure with
/// respect to I/O (response lines land in the caller's buffer), which
/// is what the property suites drive directly.
pub struct ServeServer {
    cfg: ServeConfig,
    instance: DynamicProblem,
    /// per-graph admitted flag (duplicate detection)
    arrived: Vec<bool>,
    /// admitted-not-yet-run global graph indices, in admission order
    pending: Vec<usize>,
    /// completed epochs' global graph lists
    epochs: Vec<Vec<usize>>,
    /// non-empty request lines handled (1-based error-line numbering;
    /// snapshot-carried)
    lines_handled: u64,
    requests: u64,
    errors: u64,
    arrivals: u64,
    snapshots: u64,
    /// one telemetry span per completed epoch (`--telemetry` export)
    epoch_spans: Vec<CellSpan>,
    /// set by `{"op":"snapshot"}`; the I/O loop takes it and writes
    snapshot_requested: bool,
    /// whether a `--snapshot` path is configured (ops reject otherwise)
    can_snapshot: bool,
}

impl ServeServer {
    /// Fresh server: regenerate the instance and start an empty journal.
    pub fn new(cfg: ServeConfig) -> ServeServer {
        let instance = cfg.dataset.instance_scenario(
            cfg.n_graphs,
            cfg.seed,
            cfg.load,
            None,
            &cfg.scenario,
        );
        let n = instance.graphs.len();
        ServeServer {
            cfg,
            instance,
            arrived: vec![false; n],
            pending: Vec::new(),
            epochs: Vec::new(),
            lines_handled: 0,
            requests: 0,
            errors: 0,
            arrivals: 0,
            snapshots: 0,
            epoch_spans: Vec::new(),
            snapshot_requested: false,
            can_snapshot: false,
        }
    }

    /// Resume from a `dts-serve-snapshot-v1` document: re-mark the
    /// journal, restore the line counter, and seed the telemetry
    /// registry with the stored counter block (so final totals equal an
    /// uninterrupted session's).  Fails on config mismatch or a journal
    /// inconsistent with the instance.
    pub fn restore(cfg: ServeConfig, doc: &Value) -> Result<ServeServer, String> {
        let st = snapshot::parse(doc, &cfg)?;
        let mut server = ServeServer::new(cfg);
        for (ei, epoch) in st.epochs.iter().enumerate() {
            for &gi in epoch {
                server.mark_arrived(gi, &format!("epoch {ei}"))?;
            }
        }
        for &gi in &st.pending {
            server.mark_arrived(gi, "pending")?;
        }
        server.epochs = st.epochs;
        server.pending = st.pending;
        server.lines_handled = st.lines_handled;
        for &(c, v) in &st.counters {
            telemetry::counter_add(c, v);
            match c {
                Counter::ServeRequests => server.requests = v,
                Counter::ServeErrors => server.errors = v,
                Counter::ServeArrivals => server.arrivals = v,
                Counter::ServeSnapshots => server.snapshots = v,
                _ => {}
            }
        }
        Ok(server)
    }

    fn mark_arrived(&mut self, gi: usize, what: &str) -> Result<(), String> {
        if gi >= self.arrived.len() {
            return Err(format!(
                "snapshot {what}: graph {gi} out of range (instance has {})",
                self.arrived.len()
            ));
        }
        if self.arrived[gi] {
            return Err(format!("snapshot {what}: graph {gi} listed twice"));
        }
        self.arrived[gi] = true;
        Ok(())
    }

    /// Enable `{"op":"snapshot"}` (a `--snapshot` path is configured).
    pub fn set_can_snapshot(&mut self, on: bool) {
        self.can_snapshot = on;
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn n_graphs(&self) -> usize {
        self.arrived.len()
    }

    pub fn lines_handled(&self) -> u64 {
        self.lines_handled
    }

    pub fn pending(&self) -> &[usize] {
        &self.pending
    }

    pub fn epochs(&self) -> &[Vec<usize>] {
        &self.epochs
    }

    pub fn epoch_spans(&self) -> &[CellSpan] {
        &self.epoch_spans
    }

    /// Take-and-clear the `{"op":"snapshot"}` request flag.
    pub fn take_snapshot_requested(&mut self) -> bool {
        std::mem::take(&mut self.snapshot_requested)
    }

    /// Deterministic digest of the coordinator-relevant state — the
    /// "state untouched on error" oracle of the ingest property suite.
    pub fn state_fingerprint(&self) -> String {
        format!(
            "epochs={:?} pending={:?} arrivals={} lines_handled_excl_errors={}",
            self.epochs,
            self.pending,
            self.arrivals,
            self.requests - self.errors
        )
    }

    /// The session-opening line.
    pub fn hello_line(&self) -> String {
        json::obj(vec![
            ("kind", json::s("hello")),
            ("format", json::s(FORMAT)),
            ("dataset", json::s(self.cfg.dataset.name())),
            ("graphs", json::num(self.n_graphs() as f64)),
            ("n_nodes", json::num(self.instance.network.n_nodes() as f64)),
            ("label", json::s(&self.cfg.label())),
            ("epochs", json::num(self.epochs.len() as f64)),
            ("pending", json::num(self.pending.len() as f64)),
            ("line", json::num(self.lines_handled as f64)),
        ])
        .to_string()
    }

    /// Handle one raw input line: parse, apply, and append every
    /// response line to `out`.  Whitespace-only lines are ignored;
    /// every other line is counted, timed under the `serve_request`
    /// span, and yields at least one response line (ack, error, or a
    /// decision stream + summary).
    pub fn handle_line(&mut self, raw: &str, out: &mut Vec<String>) -> Flow {
        let line = raw.trim();
        if line.is_empty() {
            return Flow::Continue;
        }
        let span = Span::start(Hist::ServeRequestNs);
        self.lines_handled += 1;
        self.requests += 1;
        telemetry::counter_inc(Counter::ServeRequests);
        let flow = match parse_request(line) {
            Err(rej) => {
                self.reject(&rej, out);
                Flow::Continue
            }
            Ok(req) => self.apply(req, out),
        };
        span.finish();
        flow
    }

    fn reject(&mut self, rej: &Reject, out: &mut Vec<String>) {
        self.errors += 1;
        telemetry::counter_inc(Counter::ServeErrors);
        out.push(error_line(self.lines_handled, rej));
    }

    /// Reject a line the I/O loop refused to buffer (longer than
    /// `--max-line-bytes`).  Counted and numbered exactly like any other
    /// handled request so that error-line numbering and the
    /// `requests - errors` fingerprint stay consistent: one oversized
    /// line yields exactly one `{"kind":"error","code":"range"}` and
    /// leaves the journal state untouched.
    pub fn reject_oversized(&mut self, n_bytes: usize, limit: usize, out: &mut Vec<String>) {
        let span = Span::start(Hist::ServeRequestNs);
        self.lines_handled += 1;
        self.requests += 1;
        telemetry::counter_inc(Counter::ServeRequests);
        self.reject(
            &Reject::new(
                "range",
                format!("request line of {n_bytes} bytes exceeds --max-line-bytes {limit}"),
            ),
            out,
        );
        span.finish();
    }

    fn apply(&mut self, req: Request, out: &mut Vec<String>) -> Flow {
        match req {
            Request::Arrive { graph } => {
                if let Err(rej) = self.admit(graph) {
                    self.reject(&rej, out);
                } else {
                    out.push(
                        json::obj(vec![
                            ("kind", json::s("ack")),
                            ("op", json::s("arrive")),
                            ("graph", json::num(graph as f64)),
                            ("pending", json::num(self.pending.len() as f64)),
                        ])
                        .to_string(),
                    );
                }
                Flow::Continue
            }
            Request::Trace(doc) => {
                match self.admit_trace(&doc) {
                    Err(rej) => self.reject(&rej, out),
                    Ok(admitted) => out.push(
                        json::obj(vec![
                            ("kind", json::s("ack")),
                            ("op", json::s("trace")),
                            ("admitted", json::num(admitted as f64)),
                            ("pending", json::num(self.pending.len() as f64)),
                        ])
                        .to_string(),
                    ),
                }
                Flow::Continue
            }
            Request::Run => {
                self.run_epoch(out);
                Flow::Continue
            }
            Request::Snapshot => {
                if !self.can_snapshot {
                    self.reject(
                        &Reject::new("snapshot", "no --snapshot path configured"),
                        out,
                    );
                } else {
                    self.snapshot_requested = true;
                    out.push(
                        json::obj(vec![
                            ("kind", json::s("ack")),
                            ("op", json::s("snapshot")),
                            ("epochs", json::num(self.epochs.len() as f64)),
                            ("pending", json::num(self.pending.len() as f64)),
                        ])
                        .to_string(),
                    );
                }
                Flow::Continue
            }
            Request::Inject { mtbf, mttr, seed } => {
                let model = crate::sim::FaultModel::Crash { mtbf, mttr };
                match model.validate() {
                    Err(e) => self.reject(&Reject::new("range", e), out),
                    Ok(()) => {
                        // Applies to every epoch run after this line;
                        // already-completed epochs are untouched (the
                        // journal records which graphs ran, not under
                        // which fault model — the config block carries
                        // the *current* model for the restore check).
                        self.cfg.faults = crate::sim::FaultConfig {
                            model,
                            seed: seed.unwrap_or(crate::sim::faults::DEFAULT_FAULT_SEED),
                            node_base: 0,
                        };
                        out.push(
                            json::obj(vec![
                                ("kind", json::s("ack")),
                                ("op", json::s("inject")),
                                ("model", json::s(&model.label())),
                                ("seed", json::num(self.cfg.faults.seed as f64)),
                            ])
                            .to_string(),
                        );
                    }
                }
                Flow::Continue
            }
            Request::Stats => {
                out.push(self.stats_line());
                Flow::Continue
            }
            Request::Quit => Flow::Quit,
            Request::Shutdown => Flow::Shutdown,
        }
    }

    fn admit(&mut self, graph: usize) -> Result<(), Reject> {
        if graph >= self.arrived.len() {
            return Err(Reject::new(
                "range",
                format!(
                    "graph {graph} out of range (instance has {} graphs)",
                    self.arrived.len()
                ),
            ));
        }
        if self.arrived[graph] {
            return Err(Reject::new(
                "duplicate",
                format!("graph {graph} already admitted"),
            ));
        }
        self.arrived[graph] = true;
        self.pending.push(graph);
        self.arrivals += 1;
        telemetry::counter_inc(Counter::ServeArrivals);
        Ok(())
    }

    /// Validate a recorded trace against this server's instance, then
    /// admit every graph (all-or-nothing: any mismatch or duplicate
    /// leaves the journal untouched).
    fn admit_trace(&mut self, doc: &Value) -> Result<usize, Reject> {
        trace::sim_from_json(doc).map_err(|e| Reject::new("trace", e))?;
        let tn = doc
            .get("n_nodes")
            .and_then(|x| x.as_usize())
            .unwrap_or(usize::MAX);
        if tn != self.instance.network.n_nodes() {
            return Err(Reject::new(
                "trace",
                format!(
                    "trace has {tn} nodes, instance has {}",
                    self.instance.network.n_nodes()
                ),
            ));
        }
        let graphs = doc
            .get("graphs")
            .and_then(|g| g.as_array())
            .ok_or_else(|| Reject::new("trace", "missing graphs array"))?;
        if graphs.len() != self.instance.graphs.len() {
            return Err(Reject::new(
                "trace",
                format!(
                    "trace has {} graphs, instance has {}",
                    graphs.len(),
                    self.instance.graphs.len()
                ),
            ));
        }
        for (i, tg) in graphs.iter().enumerate() {
            let (arrival, g) = &self.instance.graphs[i];
            let ta = tg.get("arrival").and_then(|x| x.as_f64());
            if ta != Some(*arrival) {
                return Err(Reject::new(
                    "trace",
                    format!(
                        "graph {i}: trace arrival {ta:?} != instance arrival {arrival}"
                    ),
                ));
            }
            let tt = tg.get("n_tasks").and_then(|x| x.as_usize());
            if tt != Some(g.n_tasks()) {
                return Err(Reject::new(
                    "trace",
                    format!(
                        "graph {i}: trace n_tasks {tt:?} != instance n_tasks {}",
                        g.n_tasks()
                    ),
                ));
            }
            if self.arrived[i] {
                return Err(Reject::new(
                    "duplicate",
                    format!("graph {i} already admitted; trace replay needs a fresh session"),
                ));
            }
        }
        for i in 0..graphs.len() {
            self.arrived[i] = true;
            self.pending.push(i);
        }
        self.arrivals += graphs.len() as u64;
        telemetry::counter_add(Counter::ServeArrivals, graphs.len() as u64);
        Ok(graphs.len())
    }

    /// Close the pending batch and run it as one epoch, streaming the
    /// decision lines and the 18-metric summary into `out`.
    fn run_epoch(&mut self, out: &mut Vec<String>) {
        if self.pending.is_empty() {
            out.push(
                json::obj(vec![
                    ("kind", json::s("ack")),
                    ("op", json::s("run")),
                    ("pending", json::num(0.0)),
                ])
                .to_string(),
            );
            return;
        }
        let mut idxs = std::mem::take(&mut self.pending);
        // Epoch problem in ascending global index = recorded-arrival
        // order (instances are arrival-sorted), so a full-instance epoch
        // is field-for-field the offline problem.
        idxs.sort_unstable();
        let sub = self.subproblem(&idxs);
        let o = self.run_coordinator(&sub);
        for e in &o.log {
            let remapped = remap_entry(e, &idxs);
            out.push(trace::sim_event_json(&remapped).to_string());
        }
        let epoch = self.epochs.len();
        out.push(
            json::obj(vec![
                ("kind", json::s("summary")),
                ("epoch", json::num(epoch as f64)),
                ("label", json::s(&o.label)),
                (
                    "graphs",
                    json::arr(idxs.iter().map(|&i| json::num(i as f64)).collect()),
                ),
                ("n_events", json::num(o.log.len() as f64)),
                ("n_replans", json::num(o.n_replans as f64)),
                (
                    "n_straggler_replans",
                    json::num(o.n_straggler_replans as f64),
                ),
                ("n_reverted", json::num(o.n_reverted as f64)),
                ("metrics", metric_row_json(&o.metrics)),
            ])
            .to_string(),
        );
        self.epoch_spans.push(CellSpan {
            label: o.label,
            dataset: self.cfg.dataset.name().to_string(),
            replans: o.n_replans,
            refresh_s: o.refresh_wall_s,
            heuristic_s: o.sched_runtime_s,
            bookkeep_s: o.bookkeep_wall_s,
            wall_s: o.replan_wall_s,
        });
        self.epochs.push(idxs);
    }

    fn subproblem(&self, idxs: &[usize]) -> DynamicProblem {
        let graphs = idxs
            .iter()
            .map(|&i| self.instance.graphs[i].clone())
            .collect();
        DynamicProblem::new(self.instance.network.clone(), graphs)
    }

    /// Build and run the epoch coordinator — the exact offline
    /// construction (`run_sim_cell` / `run_policy_cell`), which is the
    /// whole replay contract.
    fn run_coordinator(&self, sub: &DynamicProblem) -> EpochOutcome {
        let sim_cfg = self.cfg.sim_config();
        let sched_seed = self.cfg.seed ^ 0x5EED;
        if self.cfg.shards > 1 {
            let mut fed = FederatedCoordinator::new(
                self.cfg.variant.policy,
                self.cfg.variant.kind,
                sched_seed,
                sim_cfg,
                self.cfg.shards,
            )
            .with_jobs(self.cfg.jobs);
            if let Controller::Spec(spec) = &self.cfg.controller {
                fed = fed.with_controller(spec.clone());
            }
            let label = fed.label();
            let res = fed.run(sub);
            let metrics = res.metrics(sub);
            EpochOutcome {
                label,
                n_replans: res.n_replans(),
                n_straggler_replans: res.n_straggler_replans(),
                n_reverted: res.n_reverted_total(),
                sched_runtime_s: res.sched_runtime_s,
                replan_wall_s: res.replan_wall_s,
                refresh_wall_s: res.refresh_wall_s,
                bookkeep_wall_s: res.bookkeep_wall_s,
                log: res.log,
                metrics,
            }
        } else {
            let scheduler = self.cfg.variant.kind.make(sched_seed);
            let mut rc = match &self.cfg.controller {
                Controller::Spec(spec) => ReactiveCoordinator::with_policy(
                    self.cfg.variant.policy,
                    scheduler,
                    sim_cfg,
                    spec.make(),
                ),
                Controller::Reaction(_) => {
                    ReactiveCoordinator::new(self.cfg.variant.policy, scheduler, sim_cfg)
                }
            };
            let label = rc.label();
            let res: SimResult = rc.run(sub);
            let metrics = res.metrics(sub);
            EpochOutcome {
                label,
                n_replans: res.n_replans(),
                n_straggler_replans: res.n_straggler_replans(),
                n_reverted: res.n_reverted_total(),
                sched_runtime_s: res.sched_runtime_s,
                replan_wall_s: res.replan_wall_s,
                refresh_wall_s: res.refresh_wall_s,
                bookkeep_wall_s: res.bookkeep_wall_s,
                log: res.log,
                metrics,
            }
        }
    }

    /// One-line JSON snapshot of the telemetry registry + session state.
    fn stats_line(&self) -> String {
        let t = telemetry::snapshot();
        let counters: Vec<(&str, Value)> = Counter::ALL
            .iter()
            .map(|&c| (c.key(), json::num(t.counter(c) as f64)))
            .collect();
        json::obj(vec![
            ("kind", json::s("stats")),
            ("epochs", json::num(self.epochs.len() as f64)),
            ("pending", json::num(self.pending.len() as f64)),
            ("line", json::num(self.lines_handled as f64)),
            ("counters", json::obj(counters)),
        ])
        .to_string()
    }

    /// Graceful drain: flush the pending epoch (decisions + summary),
    /// then the session-closing `bye` line.
    pub fn drain(&mut self, out: &mut Vec<String>) {
        if !self.pending.is_empty() {
            self.run_epoch(out);
        }
        out.push(
            json::obj(vec![
                ("kind", json::s("bye")),
                ("epochs", json::num(self.epochs.len() as f64)),
                ("requests", json::num(self.requests as f64)),
                ("errors", json::num(self.errors as f64)),
            ])
            .to_string(),
        );
    }

    /// The journal document.  The `serve_snapshots` counter is bumped
    /// *after* serialization (see [`write_snapshot`]), so the stored
    /// block never counts the write in flight — which is exactly what
    /// makes an interrupted+restored session's counter totals equal an
    /// uninterrupted one's.
    pub fn snapshot_json(&self) -> Value {
        let t = telemetry::snapshot();
        let counters: Vec<(&str, Value)> = Counter::ALL
            .iter()
            .map(|&c| (c.key(), json::num(t.counter(c) as f64)))
            .collect();
        json::obj(vec![
            ("format", json::s(snapshot::FORMAT)),
            ("config", snapshot::config_json(&self.cfg)),
            (
                "epochs",
                json::arr(
                    self.epochs
                        .iter()
                        .map(|e| json::arr(e.iter().map(|&i| json::num(i as f64)).collect()))
                        .collect(),
                ),
            ),
            (
                "pending",
                json::arr(self.pending.iter().map(|&i| json::num(i as f64)).collect()),
            ),
            ("lines_handled", json::num(self.lines_handled as f64)),
            ("counters", json::obj(counters)),
        ])
    }

    /// Record one journal write (counter mirror + registry).
    pub fn note_snapshot_written(&mut self) {
        self.snapshots += 1;
        telemetry::counter_inc(Counter::ServeSnapshots);
    }
}

/// Remap an epoch-local log entry into the client's global graph
/// indices (identity for a full-instance epoch — the replay case).
fn remap_entry(e: &SimLogEntry, orig: &[usize]) -> SimLogEntry {
    use crate::graph::Gid;
    let rg = |gid: Gid| Gid::new(orig[gid.graph as usize], gid.task as usize);
    let kind = match e.kind {
        SimLogKind::Arrival { graph } => SimLogKind::Arrival { graph: orig[graph] },
        SimLogKind::Start { gid, node } => SimLogKind::Start { gid: rg(gid), node },
        SimLogKind::Finish {
            gid,
            node,
            lateness,
        } => SimLogKind::Finish {
            gid: rg(gid),
            node,
            lateness,
        },
        k @ SimLogKind::Replan { .. } => k,
        // node ids are global (the epoch runs on the full network), so
        // fault events only need the graph-id remap on Kill
        k @ SimLogKind::NodeDown { .. } => k,
        k @ SimLogKind::NodeUp { .. } => k,
        SimLogKind::Kill { gid, node, wasted } => SimLogKind::Kill {
            gid: rg(gid),
            node,
            wasted,
        },
    };
    SimLogEntry { time: e.time, kind }
}

// ----------------------------------------------------------- I/O loops

/// Daemon options that live outside the resumable state: where the
/// journal and telemetry export go, and the optional TCP listener.
/// Default NDJSON request-line cap: 1 MiB.  Covers any realistic trace
/// document while bounding the buffer a hostile (or merely broken)
/// client can make the daemon hold.
pub const DEFAULT_MAX_LINE_BYTES: usize = 1 << 20;

#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub snapshot_path: Option<String>,
    /// journal after every N handled request lines (0 = only on
    /// `{"op":"snapshot"}` and at graceful exit)
    pub snapshot_every: u64,
    pub telemetry_path: Option<String>,
    pub listen: Option<String>,
    /// longest accepted request line in bytes (`--max-line-bytes`);
    /// longer lines are dropped with one `code:"range"` error line
    pub max_line_bytes: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            snapshot_path: None,
            snapshot_every: 0,
            telemetry_path: None,
            listen: None,
            max_line_bytes: DEFAULT_MAX_LINE_BYTES,
        }
    }
}

/// Write `contents` to `path` atomically: temp file in the same
/// directory, fsync, rename.  A reader (or a restore after a hard kill
/// mid-write) sees either the previous journal or the new one in full —
/// never a truncated or interleaved document.
fn write_atomic(path: &str, contents: &str) -> std::io::Result<()> {
    let tmp = format!("{path}.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

/// Serialize the journal, write it atomically, then count the write.
fn write_snapshot(server: &mut ServeServer, path: &str) -> bool {
    let doc = server.snapshot_json().to_string();
    match write_atomic(path, &(doc + "\n")) {
        Ok(()) => {
            server.note_snapshot_written();
            true
        }
        Err(e) => {
            eprintln!("dts serve: cannot write snapshot {path}: {e}");
            false
        }
    }
}

/// One bounded line read off the session input.
enum LineRead {
    Line(String),
    /// the line ran past the cap; it was drained and dropped —
    /// `.0` is its full byte length (without the terminator)
    Oversized(usize),
    Eof,
}

/// Read one `\n`-terminated line of at most `limit` content bytes.
/// Never buffers more than `limit + 1` bytes of an oversized line: the
/// rest is drained chunk-by-chunk through the `BufRead` window so a
/// gigabyte line costs a bounded allocation.  Invalid UTF-8 is an
/// `InvalidData` I/O error, exactly as `BufRead::lines` reported it.
fn read_bounded_line<R: BufRead>(reader: &mut R, limit: usize) -> std::io::Result<LineRead> {
    let mut buf = Vec::new();
    let n = reader
        .by_ref()
        .take(limit as u64 + 1)
        .read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(LineRead::Eof);
    }
    if buf.last() == Some(&b'\n') {
        buf.pop();
        if buf.last() == Some(&b'\r') {
            buf.pop();
        }
    }
    if buf.len() > limit {
        let mut dropped = buf.len();
        loop {
            let chunk = reader.fill_buf()?;
            if chunk.is_empty() {
                break;
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(p) => {
                    dropped += p;
                    reader.consume(p + 1);
                    break;
                }
                None => {
                    let len = chunk.len();
                    dropped += len;
                    reader.consume(len);
                }
            }
        }
        return Ok(LineRead::Oversized(dropped));
    }
    let s = String::from_utf8(buf).map_err(|e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, e.utf8_error().to_string())
    })?;
    Ok(LineRead::Line(s))
}

/// Drive one line-delimited session (stdin, or one TCP connection):
/// responses stream out per request, the journal writes on its cadence.
/// Public so the ingest property suite can drive the bounded-read I/O
/// loop directly over an in-memory reader.
pub fn pump<R: BufRead, W: Write>(
    server: &mut ServeServer,
    mut reader: R,
    w: &mut W,
    opts: &ServeOptions,
) -> std::io::Result<SessionEnd> {
    let limit = opts.max_line_bytes.max(1);
    let mut out = Vec::new();
    loop {
        let read = read_bounded_line(&mut reader, limit)?;
        out.clear();
        let before = server.lines_handled();
        let flow = match read {
            LineRead::Eof => return Ok(SessionEnd::Eof),
            LineRead::Oversized(n) => {
                server.reject_oversized(n, limit, &mut out);
                Flow::Continue
            }
            LineRead::Line(line) => server.handle_line(&line, &mut out),
        };
        for l in &out {
            writeln!(w, "{l}")?;
        }
        w.flush()?;
        let handled = server.lines_handled() != before;
        let requested = server.take_snapshot_requested();
        if let Some(path) = &opts.snapshot_path {
            let periodic = handled
                && opts.snapshot_every > 0
                && server.lines_handled() % opts.snapshot_every == 0;
            if requested || periodic {
                write_snapshot(server, &path.clone());
            }
        }
        match flow {
            Flow::Continue => {}
            Flow::Quit => return Ok(SessionEnd::Quit),
            Flow::Shutdown => return Ok(SessionEnd::Shutdown),
        }
    }
}

/// Graceful-exit tail: drain, final journal write, telemetry export.
fn graceful_finish<W: Write>(
    server: &mut ServeServer,
    w: &mut W,
    opts: &ServeOptions,
) -> std::io::Result<()> {
    let mut out = Vec::new();
    server.drain(&mut out);
    for l in &out {
        writeln!(w, "{l}")?;
    }
    w.flush()?;
    if let Some(path) = &opts.snapshot_path {
        write_snapshot(server, &path.clone());
    }
    if let Some(path) = &opts.telemetry_path {
        let doc = telemetry::export::to_ndjson(
            "serve",
            server.epoch_spans(),
            &telemetry::snapshot(),
        );
        if let Err(e) = std::fs::write(path, doc) {
            eprintln!("dts serve: cannot write telemetry {path}: {e}");
        }
    }
    Ok(())
}

/// Run the daemon to completion; returns the process exit code.
pub fn run(mut server: ServeServer, opts: &ServeOptions) -> i32 {
    server.set_can_snapshot(opts.snapshot_path.is_some());
    match &opts.listen {
        None => run_stdio(&mut server, opts),
        Some(addr) => run_tcp(&mut server, &addr.clone(), opts),
    }
}

fn run_stdio(server: &mut ServeServer, opts: &ServeOptions) -> i32 {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut w = stdout.lock();
    if writeln!(w, "{}", server.hello_line()).and_then(|_| w.flush()).is_err() {
        return 1;
    }
    match pump(server, stdin.lock(), &mut w, opts) {
        Ok(SessionEnd::Quit) => 0,
        Ok(SessionEnd::Eof) | Ok(SessionEnd::Shutdown) => {
            match graceful_finish(server, &mut w, opts) {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("dts serve: {e}");
                    1
                }
            }
        }
        Err(e) => {
            eprintln!("dts serve: {e}");
            1
        }
    }
}

/// TCP mode: sequential connections share one server state.  A
/// connection close is *not* a drain (the journal persists across
/// clients); `{"op":"shutdown"}` drains to the requesting connection
/// and stops the listener, `{"op":"quit"}` hard-stops.
fn run_tcp(server: &mut ServeServer, addr: &str, opts: &ServeOptions) -> i32 {
    let listener = match TcpListener::bind(addr) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("dts serve: cannot bind {addr}: {e}");
            return 1;
        }
    };
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("dts serve: accept failed: {e}");
                continue;
            }
        };
        let mut w = match stream.try_clone() {
            Ok(c) => c,
            Err(e) => {
                eprintln!("dts serve: cannot clone stream: {e}");
                continue;
            }
        };
        if writeln!(w, "{}", server.hello_line()).and_then(|_| w.flush()).is_err() {
            continue;
        }
        match pump(server, BufReader::new(stream), &mut w, opts) {
            Ok(SessionEnd::Eof) | Err(_) => continue,
            Ok(SessionEnd::Quit) => return 0,
            Ok(SessionEnd::Shutdown) => {
                return match graceful_finish(server, &mut w, opts) {
                    Ok(()) => 0,
                    Err(e) => {
                        eprintln!("dts serve: {e}");
                        1
                    }
                };
            }
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::DEFAULT_LOAD;

    fn cfg(shards: usize) -> ServeConfig {
        ServeConfig {
            dataset: Dataset::Synthetic,
            n_graphs: 4,
            seed: 7,
            variant: Variant::parse("5P-HEFT").unwrap(),
            noise_std: 0.3,
            controller: Controller::Reaction(Reaction::LastK {
                k: 3,
                threshold: 0.25,
            }),
            shards,
            jobs: 1,
            load: DEFAULT_LOAD,
            scenario: Scenario::default(),
            faults: crate::sim::FaultConfig::NONE,
        }
    }

    fn lines_of(server: &mut ServeServer, input: &str) -> Vec<String> {
        let mut out = Vec::new();
        for l in input.lines() {
            server.handle_line(l, &mut out);
        }
        out
    }

    #[test]
    fn arrive_run_summary_roundtrip() {
        let mut s = ServeServer::new(cfg(1));
        let out = lines_of(
            &mut s,
            "{\"op\":\"arrive\",\"graph\":0}\n{\"op\":\"arrive\",\"graph\":1}\n\
             {\"op\":\"arrive\",\"graph\":2}\n{\"op\":\"arrive\",\"graph\":3}\n{\"op\":\"run\"}",
        );
        // 4 acks, then events, then exactly one summary
        assert!(out[0].contains("\"kind\":\"ack\""));
        let summaries: Vec<&String> =
            out.iter().filter(|l| l.contains("\"kind\":\"summary\"")).collect();
        assert_eq!(summaries.len(), 1);
        let v = Value::from_str(summaries[0]).unwrap();
        assert_eq!(v.get("epoch").and_then(|x| x.as_usize()), Some(0));
        let m = v.get("metrics").unwrap().as_object().unwrap();
        assert_eq!(m.len(), 18, "the 18-metric block");
        assert_eq!(s.epochs().len(), 1);
        assert!(s.pending().is_empty());
    }

    #[test]
    fn label_matches_coordinator_label() {
        let c = cfg(1);
        assert_eq!(c.label(), "5P-HEFT σ0.30 L3@0.25");
        let c4 = cfg(4);
        assert_eq!(c4.label(), "S4 5P-HEFT σ0.30 L3@0.25");
    }

    #[test]
    fn errors_leave_state_untouched() {
        let mut s = ServeServer::new(cfg(1));
        let mut out = Vec::new();
        s.handle_line("{\"op\":\"arrive\",\"graph\":0}", &mut out);
        let fp = s.state_fingerprint();
        for bad in [
            "garbage",
            "{\"op\":\"arrive\",\"graph\":99}",
            "{\"op\":\"arrive\",\"graph\":0}",
            "{\"op\":\"nope\"}",
        ] {
            let mut eout = Vec::new();
            s.handle_line(bad, &mut eout);
            assert_eq!(eout.len(), 1, "{bad}");
            assert!(eout[0].contains("\"kind\":\"error\""), "{bad} → {eout:?}");
            assert_eq!(s.state_fingerprint(), fp, "{bad}");
        }
    }

    #[test]
    fn empty_run_is_an_idempotent_ack() {
        let mut s = ServeServer::new(cfg(1));
        let mut out = Vec::new();
        assert_eq!(s.handle_line("{\"op\":\"run\"}", &mut out), Flow::Continue);
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("\"op\":\"run\""));
        assert!(s.epochs().is_empty());
    }

    #[test]
    fn quit_and_shutdown_flow() {
        let mut s = ServeServer::new(cfg(1));
        let mut out = Vec::new();
        assert_eq!(s.handle_line("{\"op\":\"quit\"}", &mut out), Flow::Quit);
        assert_eq!(s.handle_line("{\"op\":\"shutdown\"}", &mut out), Flow::Shutdown);
    }

    #[test]
    fn drain_flushes_pending_and_says_bye() {
        let mut s = ServeServer::new(cfg(1));
        let mut out = Vec::new();
        s.handle_line("{\"op\":\"arrive\",\"graph\":2}", &mut out);
        out.clear();
        s.drain(&mut out);
        assert!(out.iter().any(|l| l.contains("\"kind\":\"summary\"")));
        assert!(out.last().unwrap().contains("\"kind\":\"bye\""));
        // events of the partial epoch report the client's graph id
        assert!(out.iter().any(|l| l.contains("\"graph\":2")));
    }

    #[test]
    fn inject_arms_faults_for_later_epochs() {
        let mut s = ServeServer::new(cfg(1));
        assert!(!s.config().faults.enabled());
        let mut out = Vec::new();
        s.handle_line(
            "{\"op\":\"inject\",\"mtbf\":50,\"mttr\":5,\"seed\":9}",
            &mut out,
        );
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("\"kind\":\"ack\""), "{out:?}");
        assert!(out[0].contains("crash(50,5)"), "{out:?}");
        assert!(s.config().faults.enabled());
        assert_eq!(s.config().faults.seed, 9);
        assert_eq!(
            s.config().faults.model,
            crate::sim::FaultModel::Crash { mtbf: 50.0, mttr: 5.0 }
        );
        // invalid parameters are a range reject, state untouched
        let fp = s.state_fingerprint();
        let before = s.config().faults;
        let mut eout = Vec::new();
        s.handle_line("{\"op\":\"inject\",\"mtbf\":0,\"mttr\":5}", &mut eout);
        assert_eq!(eout.len(), 1);
        assert!(eout[0].contains("\"code\":\"range\""), "{eout:?}");
        assert_eq!(s.state_fingerprint(), fp);
        assert_eq!(s.config().faults, before);
    }

    #[test]
    fn bounded_reader_caps_lines_and_recovers() {
        use std::io::BufReader;
        let limit = 8;
        // exactly at the cap passes, one byte over is dropped whole,
        // and the next line is still read intact
        let input = b"12345678\n123456789\nok\n";
        let mut r = BufReader::with_capacity(4, &input[..]);
        match read_bounded_line(&mut r, limit).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "12345678"),
            _ => panic!("exact-limit line must pass"),
        }
        match read_bounded_line(&mut r, limit).unwrap() {
            LineRead::Oversized(n) => assert_eq!(n, 9),
            _ => panic!("limit+1 line must be oversized"),
        }
        match read_bounded_line(&mut r, limit).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "ok"),
            _ => panic!("stream must recover after an oversized line"),
        }
        assert!(matches!(
            read_bounded_line(&mut r, limit).unwrap(),
            LineRead::Eof
        ));
    }

    #[test]
    fn oversized_reject_is_one_range_error() {
        let mut s = ServeServer::new(cfg(1));
        let mut out = Vec::new();
        s.handle_line("{\"op\":\"arrive\",\"graph\":0}", &mut out);
        let fp = s.state_fingerprint();
        out.clear();
        s.reject_oversized(2048, 1024, &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("\"kind\":\"error\""));
        assert!(out[0].contains("\"code\":\"range\""));
        assert_eq!(s.state_fingerprint(), fp);
    }

    #[test]
    fn snapshot_restore_roundtrips_journal() {
        let mut s = ServeServer::new(cfg(1));
        s.set_can_snapshot(true);
        let mut out = Vec::new();
        s.handle_line("{\"op\":\"arrive\",\"graph\":1}", &mut out);
        s.handle_line("{\"op\":\"arrive\",\"graph\":0}", &mut out);
        s.handle_line("{\"op\":\"run\"}", &mut out);
        s.handle_line("{\"op\":\"arrive\",\"graph\":3}", &mut out);
        let doc = s.snapshot_json();
        let r = ServeServer::restore(cfg(1), &doc).unwrap();
        assert_eq!(r.epochs(), s.epochs());
        assert_eq!(r.pending(), s.pending());
        assert_eq!(r.lines_handled(), s.lines_handled());
        // config divergence is refused
        assert!(ServeServer::restore(cfg(4), &doc).is_err());
    }
}
