//! `dts-serve-snapshot-v1`: periodic journal of the server's resumable
//! state.
//!
//! The server is event-sourced: its entire state is (a) the instance
//! configuration — regenerable bit-exactly from
//! `dataset × n_graphs × seed × scenario` — and (b) the admission
//! journal (which graphs each completed epoch ran, which are pending),
//! plus the session line counter and the telemetry counter block.  A
//! snapshot therefore stores *no* coordinator internals: restore
//! regenerates the instance, replays the journal bookkeeping, seeds the
//! telemetry counters, and the next `run` proceeds bit-identically to
//! an uninterrupted session (pinned by `rust/tests/serve_snapshot.rs`
//! across the dataset × controller × shards grid).
//!
//! Restore refuses a snapshot whose `config` block differs from the
//! CLI-resolved configuration (exit 2) — the journal is only meaningful
//! against the exact same instance.  Wall-clock histograms are *not*
//! carried (they vary run-to-run by nature); the counter block is, so
//! restored counter totals equal the uninterrupted run's.

use super::{Controller, ServeConfig};
use crate::json::{self, Value};
use crate::sim::Reaction;
use crate::telemetry::Counter;

/// Snapshot format tag.
pub const FORMAT: &str = "dts-serve-snapshot-v1";

/// The controller knob as JSON — compared by `Value` equality on
/// restore, so every expressible controller round-trips without a
/// bespoke deserializer.
pub fn controller_json(c: &Controller) -> Value {
    match c {
        Controller::Reaction(Reaction::None) => {
            json::obj(vec![("type", json::s("reaction-none"))])
        }
        Controller::Reaction(Reaction::LastK { k, threshold }) => json::obj(vec![
            ("type", json::s("lastk")),
            ("k", json::num(*k as f64)),
            ("threshold", json::num(*threshold)),
        ]),
        // PolicySpec labels encode every parameter of every controller
        // family distinctly (L/A/B/C/D prefixes + parameter lists), so
        // label equality is configuration equality here.
        Controller::Spec(spec) => json::obj(vec![
            ("type", json::s("policy")),
            ("label", json::s(&spec.label())),
        ]),
    }
}

/// The full configuration block.  Every field that shapes the instance
/// or the coordinator construction is present; restore requires the
/// stored block to equal the CLI-resolved one field-for-field.
pub fn config_json(cfg: &ServeConfig) -> Value {
    let mut fields = vec![
        ("dataset", json::s(cfg.dataset.name())),
        ("graphs", json::num(cfg.n_graphs as f64)),
        ("seed", json::num(cfg.seed as f64)),
        ("variant", json::s(&cfg.variant.label())),
        ("noise", json::num(cfg.noise_std)),
        ("controller", controller_json(&cfg.controller)),
        ("shards", json::num(cfg.shards as f64)),
        ("jobs", json::num(cfg.jobs as f64)),
        ("load", json::num(cfg.load)),
        ("scenario", json::s(&cfg.scenario.label())),
    ];
    // Only fault sessions carry fault fields, so zero-fault snapshots
    // stay byte-identical to the pre-fault format (old journals restore
    // unchanged).  The model label encodes every parameter distinctly
    // (`crash(50,5)`), mirroring the controller-label convention above.
    if cfg.faults.enabled() {
        fields.push(("fault_model", json::s(&cfg.faults.model.label())));
        fields.push(("fault_seed", json::num(cfg.faults.seed as f64)));
    }
    json::obj(fields)
}

/// The restorable state parsed out of a snapshot document.
#[derive(Clone, Debug, Default)]
pub struct SnapshotState {
    /// completed epochs' global graph lists, in epoch order
    pub epochs: Vec<Vec<usize>>,
    /// pending (admitted, not yet run) graphs in admission order
    pub pending: Vec<usize>,
    /// request lines handled before the snapshot (error-line numbering
    /// continues from here)
    pub lines_handled: u64,
    /// telemetry counter block as of the snapshot (pre-increment for
    /// the snapshot being written, so an interrupted+restored session
    /// totals exactly like an uninterrupted one)
    pub counters: Vec<(Counter, u64)>,
}

fn usize_array(v: &Value, what: &str) -> Result<Vec<usize>, String> {
    v.as_array()
        .ok_or_else(|| format!("{what} must be an array"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| format!("{what} entries must be integers")))
        .collect()
}

/// Parse and validate a snapshot against the expected configuration.
pub fn parse(doc: &Value, expect: &ServeConfig) -> Result<SnapshotState, String> {
    match doc.get("format").and_then(|f| f.as_str()) {
        Some(f) if f == FORMAT => {}
        other => return Err(format!("not a {FORMAT} document (format = {other:?})")),
    }
    let stored = doc.get("config").ok_or("missing config block")?;
    let expected = config_json(expect);
    if *stored != expected {
        return Err(format!(
            "snapshot config mismatch: snapshot was taken with {stored}, \
             but the command line resolves to {expected}"
        ));
    }
    let epochs = doc
        .get("epochs")
        .ok_or("missing epochs")?
        .as_array()
        .ok_or("epochs must be an array")?
        .iter()
        .map(|e| usize_array(e, "epoch"))
        .collect::<Result<Vec<_>, _>>()?;
    let pending = usize_array(doc.get("pending").ok_or("missing pending")?, "pending")?;
    let lines_handled = doc
        .get("lines_handled")
        .and_then(|x| x.as_f64())
        .ok_or("missing lines_handled")? as u64;
    let cobj = doc
        .get("counters")
        .and_then(|c| c.as_object())
        .ok_or("missing counters")?;
    let mut counters = Vec::new();
    for c in Counter::ALL {
        if let Some(v) = cobj.get(c.key()).and_then(|x| x.as_f64()) {
            counters.push((c, v as u64));
        }
    }
    Ok(SnapshotState {
        epochs,
        pending,
        lines_handled,
        counters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Variant;
    use crate::policy::PolicySpec;
    use crate::workloads::{Dataset, Scenario, DEFAULT_LOAD};

    fn cfg() -> ServeConfig {
        ServeConfig {
            dataset: Dataset::Synthetic,
            n_graphs: 4,
            seed: 42,
            variant: Variant::parse("5P-HEFT").unwrap(),
            noise_std: 0.3,
            controller: Controller::Reaction(Reaction::LastK {
                k: 3,
                threshold: 0.25,
            }),
            shards: 1,
            jobs: 1,
            load: DEFAULT_LOAD,
            scenario: Scenario::default(),
            faults: crate::sim::FaultConfig::NONE,
        }
    }

    #[test]
    fn config_value_roundtrips_and_detects_mismatch() {
        let a = cfg();
        let doc = json::obj(vec![
            ("format", json::s(FORMAT)),
            ("config", config_json(&a)),
            ("epochs", json::arr(vec![json::arr(vec![json::num(0.0)])])),
            ("pending", json::arr(vec![json::num(2.0)])),
            ("lines_handled", json::num(5.0)),
            (
                "counters",
                json::obj(vec![("serve_requests", json::num(5.0))]),
            ),
        ]);
        let st = parse(&doc, &a).unwrap();
        assert_eq!(st.epochs, vec![vec![0]]);
        assert_eq!(st.pending, vec![2]);
        assert_eq!(st.lines_handled, 5);
        assert_eq!(st.counters, vec![(Counter::ServeRequests, 5)]);

        // any config divergence is refused
        let mut b = cfg();
        b.seed = 43;
        assert!(parse(&doc, &b).unwrap_err().contains("mismatch"));
        let mut c = cfg();
        c.controller = Controller::Spec(PolicySpec::DeadlineAware {
            k: 3,
            threshold: 0.25,
        });
        assert!(parse(&doc, &c).unwrap_err().contains("mismatch"));
        // a fault session refuses a fault-free journal (and vice versa):
        // the decision stream depends on the fault model
        let mut f = cfg();
        f.faults.model = crate::sim::FaultModel::Crash {
            mtbf: 50.0,
            mttr: 5.0,
        };
        assert!(parse(&doc, &f).unwrap_err().contains("mismatch"));
    }

    #[test]
    fn fault_config_round_trips_and_gates_fields() {
        let plain = cfg();
        let plain_doc = config_json(&plain).to_string();
        assert!(!plain_doc.contains("fault_model"), "{plain_doc}");
        let mut f = cfg();
        f.faults.model = crate::sim::FaultModel::Crash {
            mtbf: 50.0,
            mttr: 5.0,
        };
        f.faults.seed = 9;
        let fdoc = config_json(&f).to_string();
        assert!(fdoc.contains("\"fault_model\":\"crash(50,5)\""), "{fdoc}");
        assert!(fdoc.contains("\"fault_seed\":9"), "{fdoc}");
        // differing fault seeds are a mismatch too
        let mut g = f.clone();
        g.faults.seed = 10;
        assert_ne!(config_json(&f), config_json(&g));
        assert_eq!(config_json(&f), {
            let h = f.clone();
            config_json(&h)
        });
    }

    #[test]
    fn controller_encodings_are_distinct() {
        let lastk = controller_json(&Controller::Reaction(Reaction::LastK {
            k: 3,
            threshold: 0.25,
        }));
        let fixed = controller_json(&Controller::Spec(PolicySpec::FixedLastK {
            k: 3,
            threshold: 0.25,
        }));
        let dl = controller_json(&Controller::Spec(PolicySpec::DeadlineAware {
            k: 3,
            threshold: 0.25,
        }));
        let none = controller_json(&Controller::Reaction(Reaction::None));
        assert_ne!(lastk, fixed);
        assert_ne!(fixed, dl);
        assert_ne!(lastk, none);
    }
}
