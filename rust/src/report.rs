//! Table/CSV emitters for the experiment harness: the figures of the
//! paper are bar charts; we print them as sorted markdown tables (one row
//! per scheduler variant) plus machine-readable CSV.

use std::fmt::Write as _;

/// Render a GitHub-markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut width: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            width[i] = width[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], width: &[usize]| {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            let _ = write!(line, " {:<w$} |", c, w = width[i]);
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &width,
    ));
    let mut sep = String::from("|");
    for w in &width {
        let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
    }
    sep.push('\n');
    out.push_str(&sep);
    for row in rows {
        out.push_str(&fmt_row(row, &width));
    }
    out
}

/// Render CSV (minimal quoting: fields containing comma/quote/newline are
/// double-quoted).
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    fn field(s: &str) -> String {
        if s.contains([',', '"', '\n']) {
            format!("\"{}\"", s.replace('"', "\"\""))
        } else {
            s.to_string()
        }
    }
    let mut out = String::new();
    out.push_str(
        &headers
            .iter()
            .map(|h| field(h))
            .collect::<Vec<_>>()
            .join(","),
    );
    out.push('\n');
    for row in rows {
        out.push_str(&row.iter().map(|c| field(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    out
}

/// Format a float with sensible figure precision.
pub fn fmt(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 1.0 {
        format!("{x:.3}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let t = markdown_table(
            &["name", "value"],
            &[
                vec!["a".into(), "1.0".into()],
                vec!["longer-name".into(), "2".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        // all rows same width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(lines[0].contains("name"));
        assert!(lines[1].starts_with("|--"));
    }

    #[test]
    fn csv_quoting() {
        let c = csv(
            &["a", "b"],
            &[vec!["x,y".into(), "he said \"hi\"".into()]],
        );
        assert_eq!(c, "a,b\n\"x,y\",\"he said \"\"hi\"\"\"\n");
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(1234.4), "1234");
        assert_eq!(fmt(1.23456), "1.235");
        assert_eq!(fmt(0.012345), "0.0123");
    }
}
