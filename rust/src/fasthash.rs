//! Small fast non-cryptographic hasher (FxHash construction, as used by
//! rustc) for the hot-path `Gid`-keyed maps.  The default SipHash showed
//! up in the §Perf pass on `Schedule::get`/`assign` and the composite
//! builder; scheduling workloads are not adversarial, so DoS hardening
//! buys nothing here.

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash: multiply-xor over 8-byte chunks.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add(i as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashSet` keyed by the same fast hasher (used for the simulator's
/// completed/reverted task sets).
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

/// `HashMap` with the fast hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Gid;

    #[test]
    fn distinct_gids_hash_distinctly_enough() {
        let mut set = std::collections::HashSet::new();
        for g in 0..200u32 {
            for t in 0..50u32 {
                let mut h = FxHasher::default();
                std::hash::Hash::hash(&Gid { graph: g, task: t }, &mut h);
                set.insert(h.finish());
            }
        }
        // 10_000 keys: no catastrophic collision collapse
        assert!(set.len() > 9_990, "{}", set.len());
    }

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<Gid, usize> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(Gid::new(i % 7, i), i);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m.get(&Gid::new(3, 10)), Some(&10));
    }

    #[test]
    fn hasher_handles_unaligned_bytes() {
        let mut h = FxHasher::default();
        h.write(&[1, 2, 3]);
        let a = h.finish();
        let mut h = FxHasher::default();
        h.write(&[1, 2, 4]);
        assert_ne!(a, h.finish());
    }
}
