//! `dts` binary: see usage (any unknown subcommand prints it).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(dts::cli::main_with(&argv));
}
