//! # dts — Dynamic Task-graph Scheduling with controlled preemption
//!
//! Reproduction of *"Studying the Effect of Schedule Preemption on Dynamic
//! Task Graph Scheduling"* (Khodabandehlou, Coleman, Suri, Krishnamachari —
//! MILCOM 2025, DOI 10.1109/MILCOM64451.2025.11310446).
//!
//! The library implements the paper's full evaluation stack:
//!
//! * the **problem model** — weighted task DAGs ([`graph`]) arriving over
//!   time onto a heterogeneous related-machines network ([`network`]);
//! * **schedules** with per-node timelines, insertion-based gap finding
//!   and an independent validity checker ([`schedule`]);
//! * the five **base heuristics** HEFT / CPOP / MinMin / MaxMin / Random
//!   over composite multi-DAG problems ([`schedulers`]);
//! * the paper's contribution, the **dynamic coordinator** with
//!   preemptive, non-preemptive and Last-K-preemptive policies
//!   ([`coordinator`]);
//! * the §V **metric suite** incl. the fairness axes (per-graph
//!   stretch, max-stretch, Jain's index) and the deadline axes (miss
//!   rate, mean/max/weighted tardiness) ([`metrics`]) and the §VI
//!   **workload generators** ([`workloads`]);
//! * the **scenario axis** layered over any dataset — heavy-tail /
//!   class-based importance weights, critical-path×slack deadlines,
//!   bursty arrivals ([`workloads::scenario`]); the default scenario is
//!   bit-identical to the paper's setting;
//! * the **reactive runtime simulator** — a discrete-event loop where
//!   realized durations deviate from the estimates and straggler-
//!   triggered rescheduling closes the loop ([`sim`]);
//! * the **preemption policy engine** — pluggable straggler controllers
//!   (fixed Last-K, AIMD-adaptive, token-budgeted, cooldown-wrapped,
//!   deadline-urgency-scoped) driving the reactive coordinator
//!   ([`policy`]);
//! * **federated multi-cluster sharding** — the node pool partitioned
//!   into clusters, one reactive coordinator per shard, a deterministic
//!   best-fit admission layer and cross-shard work-stealing migration;
//!   one shard reproduces the monolithic coordinator bit-exactly
//!   ([`federation`]);
//! * an allocation-free **telemetry layer** — deterministic counter /
//!   log₂-histogram registry, phase-timed replan spans, NDJSON export
//!   and a Prometheus-style text exposition ([`telemetry`]);
//! * a **streaming scheduler daemon** — NDJSON graph-arrival requests
//!   in (stdin or TCP), dispatch/replan/finish decisions out, periodic
//!   snapshot/restore, pinned bit-exact against the offline sim
//!   ([`serve`]);
//! * an **XLA/PJRT runtime** that executes the AOT-compiled JAX+Pallas
//!   rank kernels from `artifacts/` on the scheduling hot path
//!   ([`runtime`]);
//! * the **experiment harness** regenerating every figure of the paper
//!   ([`experiments`]).
//!
//! Start with `examples/quickstart.rs`; the figure pipeline lives behind
//! `cargo bench` and the `dts` CLI (`dts experiment` / `dts simulate` /
//! `dts policy` — see the top-level `README.md` for the full CLI
//! reference and `docs/METRICS.md` for the metric glossary).

pub mod alloc_count;
pub mod analysis;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dense;
pub mod experiments;
pub mod fasthash;
pub mod federation;
pub mod gantt;
pub mod graph;
pub mod json;
pub mod metrics;
pub mod network;
pub mod policy;
pub mod prng;
pub mod report;
pub mod robustness;
pub mod runtime;
pub mod schedule;
pub mod schedulers;
pub mod serve;
pub mod sim;
pub mod stats;
pub mod telemetry;
pub mod trace;
pub mod workloads;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
