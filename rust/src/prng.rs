//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! [`SplitMix64`] seeds [`Xoshiro256pp`] (xoshiro256++ 1.0, Blackman &
//! Vigna), the same construction the reference `rand_xoshiro` crate uses.
//! Every stochastic component of the library (workload generators, network
//! generators, the Random scheduler, property tests) draws from this so
//! that experiments are exactly reproducible from a `u64` seed.

/// SplitMix64 — used to expand a single `u64` seed into a xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the library-wide generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform usize in [0, n). Uses Lemire-style rejection to kill modulo
    /// bias (visible at large n; cheap insurance everywhere).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.next_f64()).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index according to (unnormalized, non-negative) weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Derive an independent child stream (for per-instance seeding).
    pub fn fork(&mut self) -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vector_xoshiro256pp() {
        // Reference values from the rand_xoshiro crate (seed_from_u64(0)).
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let expected: [u64; 4] = [
            0x53175d61490b23df,
            0x61da6f3dc380d507,
            0x5c0fdf91ec9a7bfc,
            0x02eebf8c3bbe5e1a,
        ];
        for e in expected {
            assert_eq!(rng.next_u64(), e);
        }
    }

    #[test]
    fn determinism_same_seed_same_stream() {
        let mut a = Xoshiro256pp::seed_from_u64(123);
        let mut b = Xoshiro256pp::seed_from_u64(123);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough_and_in_range() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for c in counts {
            // each bucket expects 10_000; allow ±6%
            assert!((9_400..=10_600).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Xoshiro256pp::seed_from_u64(13);
        let n = 100_000;
        let rate = 2.5;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256pp::seed_from_u64(17);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Xoshiro256pp::seed_from_u64(19);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Xoshiro256pp::seed_from_u64(23);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let a: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
