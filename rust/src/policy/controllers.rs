//! The stock [`PreemptionPolicy`] controllers: the PR-2 fixed trigger,
//! an AIMD adaptive window, a token-bucket budget, a cooldown
//! (hysteresis) wrapper, and the deadline-urgency scoped
//! [`DeadlineAware`] controller.  All controllers are deterministic
//! functions of their observation history, so any sweep that drives
//! them is bit-identical at any thread count.

use super::{
    Decision, FailureObservation, FinishObservation, PreemptionPolicy, Scope, ScopeOrder,
};

/// The no-reaction baseline: never preempts on stragglers (arrival-time
/// preemption still runs per the §IV policy).  Equivalent to the PR-2
/// `Reaction::None`.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoPreemption;

impl PreemptionPolicy for NoPreemption {
    fn label(&self) -> String {
        "none".to_string()
    }

    fn on_finish(&mut self, _obs: &FinishObservation) -> Decision {
        Decision::Hold
    }
}

/// Bit-exact port of the PR-2 `Reaction::LastK { k, threshold }`: when a
/// task finishes later than `threshold ×` its estimated duration, revert
/// the pending tasks of the `k` most recently arrived graphs, uncapped.
#[derive(Clone, Copy, Debug)]
pub struct FixedLastK {
    k: usize,
    threshold: f64,
}

impl FixedLastK {
    pub fn new(k: usize, threshold: f64) -> Self {
        Self { k, threshold }
    }
}

impl PreemptionPolicy for FixedLastK {
    /// `L{k}@{θ}` — identical to the PR-2 `Reaction::LastK` label.
    fn label(&self) -> String {
        format!("L{}@{}", self.k, self.threshold)
    }

    fn on_finish(&mut self, obs: &FinishObservation) -> Decision {
        if obs.is_straggler(self.threshold) {
            Decision::Reschedule(Scope::last_k(self.k))
        } else {
            Decision::Hold
        }
    }
}

/// AIMD feedback controller over the Last-K window: each completed graph
/// reports its observed stretch; above `target_stretch` the window widens
/// additively (`k + 1`, service is degrading — preempt more), at or below
/// it halves (`k / 2`, integer — back off toward non-preemptive).  `k` is
/// clamped to `0..=k_max`; at `k = 0` the controller holds until a late
/// completion widens it again.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveK {
    k0: usize,
    k: usize,
    k_max: usize,
    threshold: f64,
    target_stretch: f64,
}

impl AdaptiveK {
    pub fn new(k0: usize, k_max: usize, threshold: f64, target_stretch: f64) -> Self {
        // clamp the seed before storing it, so the label always names
        // the window the controller actually starts with
        let k0 = k0.min(k_max);
        Self {
            k0,
            k: k0,
            k_max,
            threshold,
            target_stretch,
        }
    }

    /// Current window width (test/diagnostic hook).
    pub fn current_k(&self) -> usize {
        self.k
    }
}

impl PreemptionPolicy for AdaptiveK {
    /// `A{k0}-{k_max}@{θ}τ{target}` — every parameter is in the label so
    /// scenarios differing in any of them stay distinguishable in
    /// tables/CSV/JSON.
    fn label(&self) -> String {
        format!(
            "A{}-{}@{}τ{}",
            self.k0, self.k_max, self.threshold, self.target_stretch
        )
    }

    fn on_finish(&mut self, obs: &FinishObservation) -> Decision {
        if self.k >= 1 && obs.is_straggler(self.threshold) {
            Decision::Reschedule(Scope::last_k(self.k))
        } else {
            Decision::Hold
        }
    }

    fn on_graph_complete(&mut self, _graph: usize, stretch: f64) {
        if stretch > self.target_stretch {
            self.k = (self.k + 1).min(self.k_max);
        } else {
            self.k /= 2;
        }
    }
}

/// Token bucket on **reverted tasks per unit simulated time** — the
/// parsimonious-preemption knob.  Tokens accrue at `rate` up to `burst`
/// (the bucket starts full); a straggler fires only while at least one
/// whole token is banked, and the resulting replan may revert at most
/// `⌊tokens⌋` tasks (the coordinator keeps the most recently arrived
/// graphs' tasks when it must truncate).  Each actually-reverted task
/// consumes one token, so over any run the controller can never revert
/// more than `burst + rate × elapsed` tasks.
#[derive(Clone, Copy, Debug)]
pub struct Budgeted {
    k: usize,
    threshold: f64,
    rate: f64,
    burst: f64,
    tokens: f64,
    last_refill: f64,
}

impl Budgeted {
    pub fn new(k: usize, threshold: f64, rate: f64, burst: f64) -> Self {
        Self {
            k,
            threshold,
            rate,
            burst,
            tokens: burst,
            last_refill: 0.0,
        }
    }

    fn refill(&mut self, now: f64) {
        // event times are non-decreasing; guard anyway so a same-instant
        // pair can never drain the bucket via a negative dt
        let dt = (now - self.last_refill).max(0.0);
        self.tokens = (self.tokens + self.rate * dt).min(self.burst);
        self.last_refill = now;
    }

    /// Current token balance (test/diagnostic hook).
    pub fn tokens(&self) -> f64 {
        self.tokens
    }
}

impl PreemptionPolicy for Budgeted {
    /// `B{k}@{θ}r{rate}b{burst}` — every parameter is in the label so
    /// scenarios differing in any of them stay distinguishable in
    /// tables/CSV/JSON.
    fn label(&self) -> String {
        format!(
            "B{}@{}r{}b{}",
            self.k, self.threshold, self.rate, self.burst
        )
    }

    fn on_finish(&mut self, obs: &FinishObservation) -> Decision {
        self.refill(obs.time);
        if obs.is_straggler(self.threshold) && self.tokens >= 1.0 {
            Decision::Reschedule(Scope {
                last_k: self.k,
                max_reverted: self.tokens.floor() as usize,
                order: ScopeOrder::Recency,
            })
        } else {
            Decision::Hold
        }
    }

    fn on_replan(&mut self, _time: f64, n_reverted: usize) {
        // a replan this controller fired was capped at ⌊tokens⌋, so its
        // charge keeps the balance non-negative — but crash-forced
        // failure replans are uncapped and charged too (the
        // parsimonious accounting of arXiv:2605.23255: forced reverts
        // are still preemption work), so the bucket may overdraw.  A
        // negative balance simply suppresses fires until the refill
        // repays the debt; `burst + rate × elapsed` stays the hard
        // ceiling on *voluntary* reverts.
        self.tokens -= n_reverted as f64;
    }
}

/// The deadline-scenario controller: the same straggler trigger as
/// [`FixedLastK`] (`lateness > θ × estimate`), but the replan scope is
/// **deadline urgency** ([`ScopeOrder::DeadlineUrgency`]) — the
/// coordinator reverts the pending work of the `k` incomplete graphs
/// whose belief slack (deadline minus predicted completion) is smallest,
/// spending the preemption where a miss is most imminent instead of on
/// whatever arrived last.  On a workload without deadlines the urgency
/// order degrades to recency over the incomplete graphs, so the
/// controller stays usable (though [`FixedLastK`] is then the natural
/// choice).
#[derive(Clone, Copy, Debug)]
pub struct DeadlineAware {
    k: usize,
    threshold: f64,
}

impl DeadlineAware {
    pub fn new(k: usize, threshold: f64) -> Self {
        Self { k, threshold }
    }
}

impl PreemptionPolicy for DeadlineAware {
    /// `D{k}@{θ}` — the deadline-urgency twin of `L{k}@{θ}`.
    fn label(&self) -> String {
        format!("D{}@{}", self.k, self.threshold)
    }

    fn on_finish(&mut self, obs: &FinishObservation) -> Decision {
        if obs.is_straggler(self.threshold) {
            Decision::Reschedule(Scope::deadline_urgent(self.k))
        } else {
            Decision::Hold
        }
    }
}

/// The failure-recovery controller: straggler behavior identical to
/// [`FixedLastK`] (`lateness > θ × estimate`, Last-K recency scope), and
/// on every node crash — after the coordinator's forced replan already
/// recovered the orphaned work — it reverts the `k` most
/// **deadline-endangered** incomplete graphs as *extra* scope
/// ([`ScopeOrder::DeadlineUrgency`]): losing a node shrinks capacity,
/// so the graphs closest to a miss are re-placed against the reduced
/// cluster immediately instead of waiting for the next straggler to
/// fire.  On a deadline-free workload the urgency order degrades to
/// recency over the incomplete graphs (see [`DeadlineAware`]).
#[derive(Clone, Copy, Debug)]
pub struct FailureAware {
    k: usize,
    threshold: f64,
}

impl FailureAware {
    pub fn new(k: usize, threshold: f64) -> Self {
        Self { k, threshold }
    }
}

impl PreemptionPolicy for FailureAware {
    /// `F{k}@{θ}` — the failure-recovery twin of `L{k}@{θ}`.
    fn label(&self) -> String {
        format!("F{}@{}", self.k, self.threshold)
    }

    fn on_finish(&mut self, obs: &FinishObservation) -> Decision {
        if obs.is_straggler(self.threshold) {
            Decision::Reschedule(Scope::last_k(self.k))
        } else {
            Decision::Hold
        }
    }

    fn on_failure(&mut self, _obs: &FailureObservation) -> Decision {
        Decision::Reschedule(Scope::deadline_urgent(self.k))
    }
}

/// Hysteresis wrapper: after any replan the inner controller fired,
/// suppress further straggler triggers until `cooldown` simulated time
/// has passed, so a burst of late finishes from one slow node cannot
/// thrash the planner with back-to-back replans.  The inner controller
/// still observes *every* finish and completion during the window (its
/// trait contract; adaptation and statistics continue) — only its
/// fire decisions are discarded.  `cooldown = 0` is bit-identical to
/// the bare inner controller.
pub struct Cooldown {
    inner: Box<dyn PreemptionPolicy>,
    cooldown: f64,
    ready_at: f64,
}

impl Cooldown {
    pub fn new(inner: Box<dyn PreemptionPolicy>, cooldown: f64) -> Self {
        Self {
            inner,
            cooldown,
            ready_at: f64::NEG_INFINITY,
        }
    }
}

impl PreemptionPolicy for Cooldown {
    fn label(&self) -> String {
        format!("{}+cd{}", self.inner.label(), self.cooldown)
    }

    fn on_finish(&mut self, obs: &FinishObservation) -> Decision {
        // the inner controller observes every finish (stateful
        // controllers need the full history); a fire inside the window
        // is discarded — discarded fires are never charged, because a
        // decision only reaches on_replan when the coordinator ran it
        let inner = self.inner.on_finish(obs);
        if obs.time < self.ready_at {
            return Decision::Hold;
        }
        inner
    }

    fn on_replan(&mut self, time: f64, n_reverted: usize) {
        self.ready_at = time + self.cooldown;
        self.inner.on_replan(time, n_reverted);
    }

    fn on_graph_complete(&mut self, graph: usize, stretch: f64) {
        self.inner.on_graph_complete(graph, stretch);
    }

    fn on_failure(&mut self, obs: &FailureObservation) -> Decision {
        // failures bypass the cooldown gate: a crash-forced recovery is
        // not straggler thrash, and the inner controller's extra scope
        // answers a capacity loss the hysteresis was never meant to
        // dampen
        self.inner.on_failure(obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Gid;

    fn obs_at(time: f64, lateness: f64) -> FinishObservation {
        FinishObservation {
            gid: Gid::new(0, 0),
            time,
            est: 1.0,
            lateness,
            arrived: 10,
        }
    }

    #[test]
    fn no_preemption_always_holds() {
        let mut p = NoPreemption;
        assert_eq!(p.on_finish(&obs_at(1.0, 100.0)), Decision::Hold);
    }

    #[test]
    fn fixed_lastk_fires_on_strict_threshold() {
        let mut p = FixedLastK::new(3, 0.25);
        assert_eq!(p.on_finish(&obs_at(1.0, 0.25)), Decision::Hold);
        assert_eq!(
            p.on_finish(&obs_at(1.0, 0.26)),
            Decision::Reschedule(Scope::last_k(3))
        );
    }

    #[test]
    fn adaptive_k_aimd_transitions() {
        let mut p = AdaptiveK::new(2, 6, 0.1, 1.5);
        assert_eq!(p.current_k(), 2);
        // slow graphs widen additively
        p.on_graph_complete(0, 3.0);
        p.on_graph_complete(1, 3.0);
        assert_eq!(p.current_k(), 4);
        // clamped at k_max
        for g in 2..10 {
            p.on_graph_complete(g, 3.0);
        }
        assert_eq!(p.current_k(), 6);
        // healthy graphs halve
        p.on_graph_complete(10, 1.0);
        assert_eq!(p.current_k(), 3);
        p.on_graph_complete(11, 1.0);
        p.on_graph_complete(12, 1.0);
        assert_eq!(p.current_k(), 0);
        // at k = 0 the controller holds even on blatant stragglers...
        assert_eq!(p.on_finish(&obs_at(1.0, 50.0)), Decision::Hold);
        // ...and recovers once service degrades again
        p.on_graph_complete(13, 3.0);
        assert_eq!(
            p.on_finish(&obs_at(2.0, 50.0)),
            Decision::Reschedule(Scope::last_k(1))
        );
    }

    #[test]
    fn budgeted_caps_and_refills() {
        let mut p = Budgeted::new(5, 0.0, 1.0, 3.0);
        // bucket starts full (3 tokens): fire with cap 3
        match p.on_finish(&obs_at(0.0, 1.0)) {
            Decision::Reschedule(s) => assert_eq!(s.max_reverted, 3),
            d => panic!("expected fire, got {d:?}"),
        }
        p.on_replan(0.0, 3);
        assert!(p.tokens().abs() < 1e-12);
        // empty bucket holds even for stragglers
        assert_eq!(p.on_finish(&obs_at(0.5, 1.0)), Decision::Hold);
        // refill at 1 token per time unit: 0.5 banked at t=0.5, so 2.0
        // tokens by t=2 → cap ⌊2.0⌋ = 2
        match p.on_finish(&obs_at(2.0, 1.0)) {
            Decision::Reschedule(s) => assert_eq!(s.max_reverted, 2),
            d => panic!("expected fire, got {d:?}"),
        }
        // a fire that reverted nothing is not reported; the balance keeps
        // accruing and is clamped at burst
        match p.on_finish(&obs_at(100.0, 1.0)) {
            Decision::Reschedule(s) => assert_eq!(s.max_reverted, 3),
            d => panic!("expected fire, got {d:?}"),
        }
        assert!((p.tokens() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn deadline_aware_fires_with_urgency_scope() {
        let mut p = DeadlineAware::new(4, 0.25);
        assert_eq!(p.label(), "D4@0.25");
        assert_eq!(p.on_finish(&obs_at(1.0, 0.2)), Decision::Hold);
        match p.on_finish(&obs_at(1.0, 0.5)) {
            Decision::Reschedule(s) => {
                assert_eq!(s.last_k, 4);
                assert_eq!(s.max_reverted, usize::MAX);
                assert_eq!(s.order, ScopeOrder::DeadlineUrgency);
            }
            d => panic!("expected fire, got {d:?}"),
        }
    }

    #[test]
    fn budgeted_non_straggler_never_fires() {
        let mut p = Budgeted::new(5, 0.25, 10.0, 10.0);
        assert_eq!(p.on_finish(&obs_at(1.0, 0.1)), Decision::Hold);
    }

    #[test]
    fn cooldown_gates_fires_but_not_adaptation() {
        let mut p = Cooldown::new(Box::new(FixedLastK::new(2, 0.0)), 10.0);
        assert_eq!(
            p.on_finish(&obs_at(1.0, 1.0)),
            Decision::Reschedule(Scope::last_k(2))
        );
        p.on_replan(1.0, 4);
        // suppressed inside the window...
        assert_eq!(p.on_finish(&obs_at(5.0, 1.0)), Decision::Hold);
        assert_eq!(p.on_finish(&obs_at(10.9, 1.0)), Decision::Hold);
        // ...open again at ready_at (>=, so cd=0 is bit-identical to bare)
        assert_eq!(
            p.on_finish(&obs_at(11.0, 1.0)),
            Decision::Reschedule(Scope::last_k(2))
        );
    }

    #[test]
    fn zero_cooldown_is_transparent() {
        let mut bare = FixedLastK::new(3, 0.2);
        let mut wrapped = Cooldown::new(Box::new(FixedLastK::new(3, 0.2)), 0.0);
        for (t, late) in [(1.0, 0.5), (1.0, 0.5), (2.0, 0.1), (3.0, 0.9)] {
            let o = obs_at(t, late);
            let a = bare.on_finish(&o);
            let b = wrapped.on_finish(&o);
            assert_eq!(a, b, "t={t} late={late}");
            if let Decision::Reschedule(_) = a {
                bare.on_replan(t, 2);
                wrapped.on_replan(t, 2);
            }
        }
    }
}
