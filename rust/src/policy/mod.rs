//! The **preemption policy engine**: pluggable controllers that decide,
//! from observed runtime facts, *when* the reactive coordinator should
//! preempt (revert and re-place pending work) and *how much*.
//!
//! PR 2 hardwired one straggler reaction into the event loop
//! ([`crate::sim::Reaction::LastK`]); this module makes the decision a
//! first-class subsystem.  A [`PreemptionPolicy`] observes every task
//! finish ([`FinishObservation`]: realized lateness vs the estimate) and
//! every graph completion (observed per-graph stretch) and answers with a
//! [`Decision`]: hold the plan, or reschedule a [`Scope`] — the pending
//! tasks of the `last_k` most recently arrived graphs, optionally capped
//! at `max_reverted` tasks.  The coordinator then runs the base heuristic
//! in place through the PR-1 insertion-journal transactions exactly as
//! before and reports the outcome back ([`PreemptionPolicy::on_replan`]),
//! closing the feedback loop stateful controllers need.
//!
//! Four controllers ship with the engine ([`controllers`]):
//!
//! * [`FixedLastK`] — bit-exact port of the PR-2 `Reaction::LastK{k,θ}`
//!   trigger (fire when `lateness > θ × estimate`, scope = last `k`
//!   graphs, no cap).  Its label matches PR-2's `L{k}@{θ}` so sweep rows
//!   line up column-for-column.
//! * [`AdaptiveK`] — AIMD feedback controller: each graph completion
//!   compares observed stretch against a target; too slow ⇒ widen `k`
//!   additively, healthy ⇒ halve it.  Probes how much preemption the
//!   workload *currently* needs instead of fixing it a priori.
//! * [`Budgeted`] — a token bucket on **reverted tasks per unit simulated
//!   time** (the parsimonious-preemption knob of arXiv:2605.23255): fires
//!   only while tokens remain and caps each replan's revert count at the
//!   integral token balance.
//! * [`Cooldown`] — hysteresis wrapper: after a replan fires, suppress
//!   further straggler triggers for a fixed window so a burst of late
//!   finishes cannot thrash the planner.
//! * [`DeadlineAware`] — the deadline-scenario controller: fires on the
//!   same straggler predicate but scopes the replan by **deadline
//!   urgency** ([`ScopeOrder::DeadlineUrgency`]) — the most endangered
//!   graphs are reverted first, instead of the most recent.
//!
//! The engine governs **straggler** preemption only; arrival-time
//! preemption remains the §IV [`crate::coordinator::Policy`]
//! (NP / Last-K / P), unchanged.
//!
//! [`PolicySpec`] is the serializable description used by the experiment
//! harness: it labels a scenario and [`PolicySpec::make`]s a fresh
//! controller per run, so sweep cells never share mutable state and the
//! joint k×θ×budget sweep stays bit-identical at any `--jobs`.

pub mod controllers;

pub use controllers::{
    AdaptiveK, Budgeted, Cooldown, DeadlineAware, FailureAware, FixedLastK, NoPreemption,
};

use crate::graph::Gid;

/// What the coordinator observed when a task finished — everything a
/// controller may condition its straggler decision on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FinishObservation {
    /// the task that finished
    pub gid: Gid,
    /// simulation time of the (realized) finish
    pub time: f64,
    /// the task's estimated duration when it was dispatched
    pub est: f64,
    /// realized finish minus expected finish (negative = early)
    pub lateness: f64,
    /// graphs arrived so far — upper bound of any Last-K window
    pub arrived: usize,
}

impl FinishObservation {
    /// The PR-2 straggler predicate: finished more than
    /// `threshold × estimate` later than expected.
    pub fn is_straggler(&self, threshold: f64) -> bool {
        self.lateness > threshold * self.est
    }
}

/// What the coordinator observed when a node crashed — delivered
/// **after** the forced failure replan already reverted the crashed
/// node's orphaned work, so a controller decides only how much *extra*
/// scope to add on top of the forced one
/// ([`PreemptionPolicy::on_failure`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureObservation {
    /// the node that crashed
    pub node: usize,
    /// simulation time of the crash
    pub time: f64,
    /// planned-but-undispatched tasks the forced failure replan
    /// reverted off the crashed node (0 when the node held no pending
    /// work and the forced pass was skipped)
    pub n_orphaned: usize,
    /// whether a running attempt was killed (its partial work wasted)
    pub killed: bool,
    /// graphs arrived so far — upper bound of any Last-K window
    pub arrived: usize,
}

/// How the coordinator picks *which* graphs a
/// [`Decision::Reschedule`]'s window of `last_k` graphs contains.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ScopeOrder {
    /// The `last_k` most recently **arrived** graphs — the paper's
    /// Last-K recency window (PR-2 semantics, the default).
    #[default]
    Recency,
    /// The `last_k` most **deadline-endangered** incomplete graphs:
    /// ranked by belief slack (deadline minus the coordinator's
    /// predicted completion), smallest slack first.  Graphs without
    /// deadlines rank last; ties break toward recency, so on a
    /// deadline-free workload the order degrades to recency over the
    /// incomplete graphs.
    ///
    /// The predicted completions are belief finishes **as of the last
    /// refresh** — under the incremental dirty-cone refresh these are
    /// bit-identical to the full-refresh oracle's (pinned by
    /// `rust/tests/refresh_incremental.rs`), so urgency selections, and
    /// with them whole sweep trajectories, are independent of the
    /// refresh mode.
    DeadlineUrgency,
}

/// How much a [`Decision::Reschedule`] may preempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Scope {
    /// revert pending tasks of a window of `last_k` graphs, selected
    /// per [`Scope::order`]
    pub last_k: usize,
    /// cap on how many tasks this replan may revert; when the revertible
    /// set is larger, the coordinator keeps whole per-graph blocks in
    /// priority order (most recent / most endangered first, per
    /// [`Scope::order`]) and leaves the rest in place.
    /// `usize::MAX` = uncapped.
    pub max_reverted: usize,
    /// graph-selection order of the window
    pub order: ScopeOrder,
}

impl Scope {
    /// Uncapped Last-K recency scope (PR-2 semantics).
    pub fn last_k(k: usize) -> Self {
        Scope {
            last_k: k,
            max_reverted: usize::MAX,
            order: ScopeOrder::Recency,
        }
    }

    /// Uncapped deadline-urgency scope: the `k` most endangered graphs.
    pub fn deadline_urgent(k: usize) -> Self {
        Scope {
            last_k: k,
            max_reverted: usize::MAX,
            order: ScopeOrder::DeadlineUrgency,
        }
    }
}

/// A controller's answer to one [`FinishObservation`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Keep executing the current plan.
    Hold,
    /// Revert and re-place the given [`Scope`] of pending work.
    Reschedule(Scope),
}

/// A stateful straggler-preemption controller driven by the reactive
/// coordinator ([`crate::sim::ReactiveCoordinator::with_policy`]).
///
/// Contract:
/// * [`on_finish`](Self::on_finish) is called for **every** realized task
///   finish, in event order (times are non-decreasing).
/// * [`on_replan`](Self::on_replan) is called after a straggler replan
///   this policy fired actually ran, with the number of tasks it
///   reverted.  A fire that found nothing revertible is *not* reported
///   (no replan happened — same as PR-2, which recorded no
///   `ReplanRecord`); budgets are only charged for real work.
///   Arrival-time replans (the §IV policy) are never reported.
/// * [`on_graph_complete`](Self::on_graph_complete) is called when the
///   last task of a graph finishes, **before** the same finish event's
///   `on_finish` decision, so adaptation sees the freshest stretch.
pub trait PreemptionPolicy {
    /// Short scenario label for tables/CSV (`L3@0.25`, `B3@0.25r1`, ...).
    fn label(&self) -> String;

    /// Decide on one observed task finish.
    fn on_finish(&mut self, obs: &FinishObservation) -> Decision;

    /// Feedback: a straggler replan this policy fired reverted
    /// `n_reverted` tasks at simulated time `time`.  Also called for the
    /// crash-forced failure replan (the controller did not fire it, but
    /// its reverts are real preemption work — [`Budgeted`] charges them
    /// against the bucket, overdrawing if necessary).
    fn on_replan(&mut self, time: f64, n_reverted: usize) {
        let _ = (time, n_reverted);
    }

    /// Decide on one observed node crash, **after** the forced failure
    /// replan already recovered the orphaned work.  A
    /// [`Decision::Reschedule`] adds extra scope (e.g. endangered
    /// neighbor graphs) on top of the forced reverts; the default holds
    /// — crash recovery itself never depends on the controller.
    fn on_failure(&mut self, obs: &FailureObservation) -> Decision {
        let _ = obs;
        Decision::Hold
    }

    /// Feedback: graph `graph` completed with observed stretch `stretch`
    /// (response time over the best-exec critical-path lower bound).
    fn on_graph_complete(&mut self, graph: usize, stretch: f64) {
        let _ = (graph, stretch);
    }
}

/// Serializable description of a controller — the unit the experiment
/// harness sweeps.  [`make`](Self::make) builds a fresh controller (no
/// state shared between runs); [`label`](Self::label) matches the
/// controller's own label so scenario names are stable.
#[derive(Clone, Debug, PartialEq)]
pub enum PolicySpec {
    /// No straggler reaction (the arXiv:1802.10309 baseline; identical
    /// to `Reaction::None`).
    None,
    /// PR-2 `Reaction::LastK` semantics.
    FixedLastK { k: usize, threshold: f64 },
    /// AIMD controller seeded at `k0`, clamped to `0..=k_max`, widening
    /// when observed per-graph stretch exceeds `target_stretch`.
    AdaptiveK {
        k0: usize,
        k_max: usize,
        threshold: f64,
        target_stretch: f64,
    },
    /// Token bucket: `rate` revert-tokens per unit simulated time, cap
    /// `burst`, Last-K window `k`, trigger threshold `threshold`.
    Budgeted {
        k: usize,
        threshold: f64,
        rate: f64,
        burst: f64,
    },
    /// Hysteresis wrapper: suppress the inner controller's fires for
    /// `cooldown` simulated time after each replan.
    Cooldown {
        cooldown: f64,
        inner: Box<PolicySpec>,
    },
    /// Deadline-urgency scoping: fire like `FixedLastK` but revert the
    /// `k` most deadline-endangered incomplete graphs instead of the
    /// `k` most recent.
    DeadlineAware { k: usize, threshold: f64 },
    /// Failure-aware recovery: straggler behavior of `FixedLastK`, plus
    /// on every node crash it reverts the `k` most deadline-endangered
    /// incomplete graphs *in addition to* the crash-forced scope, so
    /// work endangered by the capacity loss moves off the critical path
    /// immediately instead of waiting for the next straggler.
    FailureAware { k: usize, threshold: f64 },
}

impl PolicySpec {
    /// Build a fresh controller for one run.
    pub fn make(&self) -> Box<dyn PreemptionPolicy> {
        match self {
            PolicySpec::None => Box::new(NoPreemption),
            PolicySpec::FixedLastK { k, threshold } => {
                Box::new(FixedLastK::new(*k, *threshold))
            }
            PolicySpec::AdaptiveK {
                k0,
                k_max,
                threshold,
                target_stretch,
            } => Box::new(AdaptiveK::new(*k0, *k_max, *threshold, *target_stretch)),
            PolicySpec::Budgeted {
                k,
                threshold,
                rate,
                burst,
            } => Box::new(Budgeted::new(*k, *threshold, *rate, *burst)),
            PolicySpec::Cooldown { cooldown, inner } => {
                Box::new(Cooldown::new(inner.make(), *cooldown))
            }
            PolicySpec::DeadlineAware { k, threshold } => {
                Box::new(DeadlineAware::new(*k, *threshold))
            }
            PolicySpec::FailureAware { k, threshold } => {
                Box::new(FailureAware::new(*k, *threshold))
            }
        }
    }

    /// Scenario label; identical to the built controller's
    /// [`PreemptionPolicy::label`].
    pub fn label(&self) -> String {
        match self {
            PolicySpec::None => "none".to_string(),
            _ => self.make().label(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(lateness: f64, est: f64, arrived: usize) -> FinishObservation {
        FinishObservation {
            gid: Gid::new(0, 0),
            time: 10.0,
            est,
            lateness,
            arrived,
        }
    }

    #[test]
    fn straggler_predicate_matches_pr2() {
        // PR-2: fire iff lateness > threshold * est (strict)
        assert!(obs(0.26, 1.0, 1).is_straggler(0.25));
        assert!(!obs(0.25, 1.0, 1).is_straggler(0.25));
        assert!(!obs(-0.5, 1.0, 1).is_straggler(0.25));
        // zero threshold: any positive lateness fires
        assert!(obs(1e-9, 1.0, 1).is_straggler(0.0));
        assert!(!obs(0.0, 1.0, 1).is_straggler(0.0));
    }

    #[test]
    fn spec_labels_match_controllers() {
        let specs = [
            PolicySpec::None,
            PolicySpec::FixedLastK {
                k: 3,
                threshold: 0.25,
            },
            PolicySpec::AdaptiveK {
                k0: 3,
                k_max: 10,
                threshold: 0.25,
                target_stretch: 2.0,
            },
            PolicySpec::Budgeted {
                k: 3,
                threshold: 0.25,
                rate: 1.0,
                burst: 4.0,
            },
            PolicySpec::Cooldown {
                cooldown: 5.0,
                inner: Box::new(PolicySpec::FixedLastK {
                    k: 2,
                    threshold: 0.1,
                }),
            },
            PolicySpec::DeadlineAware {
                k: 3,
                threshold: 0.25,
            },
            PolicySpec::FailureAware {
                k: 3,
                threshold: 0.25,
            },
        ];
        let labels: Vec<String> = specs.iter().map(|s| s.label()).collect();
        assert_eq!(labels[0], "none");
        assert_eq!(labels[1], "L3@0.25");
        assert_eq!(labels[2], "A3-10@0.25τ2");
        assert_eq!(labels[3], "B3@0.25r1b4");
        assert_eq!(labels[4], "L2@0.1+cd5");
        assert_eq!(labels[5], "D3@0.25");
        assert_eq!(labels[6], "F3@0.25");
        for (spec, label) in specs.iter().zip(&labels) {
            assert_eq!(&spec.make().label(), label, "{spec:?}");
        }
    }

    #[test]
    fn fixed_lastk_label_matches_pr2_reaction() {
        // the sweep acceptance: FixedLastK rows must line up with PR-2's
        // `L{k}@{θ}` reaction labels, Display-formatted the same way
        let spec = PolicySpec::FixedLastK {
            k: 3,
            threshold: 0.25,
        };
        let reaction = crate::sim::Reaction::LastK {
            k: 3,
            threshold: 0.25,
        };
        assert_eq!(spec.label(), reaction.label());
    }

    #[test]
    fn scope_helpers() {
        let s = Scope::last_k(4);
        assert_eq!(s.last_k, 4);
        assert_eq!(s.max_reverted, usize::MAX);
        assert_eq!(s.order, ScopeOrder::Recency);
        let d = Scope::deadline_urgent(2);
        assert_eq!(d.last_k, 2);
        assert_eq!(d.max_reverted, usize::MAX);
        assert_eq!(d.order, ScopeOrder::DeadlineUrgency);
        assert_eq!(ScopeOrder::default(), ScopeOrder::Recency);
    }
}
