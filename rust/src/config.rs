//! Experiment configuration: JSON-serializable descriptions of a
//! (dataset × variants × trials) sweep, the unit the CLI and the figure
//! benches operate on.

use crate::coordinator::Variant;
use crate::json::{self, Value};
use crate::workloads::Dataset;

/// One experiment sweep: `trials` seeded instances of `dataset`, each run
/// under every variant in `variants`.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub dataset: Dataset,
    /// graphs per instance (paper defaults per dataset when None in JSON)
    pub n_graphs: usize,
    /// independent seeded instances to average over
    pub trials: usize,
    /// base seed; trial `t` uses `seed + t`
    pub seed: u64,
    /// offered-load factor (see workloads::DEFAULT_LOAD)
    pub load: f64,
    pub variants: Vec<Variant>,
}

impl ExperimentConfig {
    /// Paper-shaped default: full 30-variant grid, 5 trials.
    pub fn paper_default(dataset: Dataset) -> Self {
        Self {
            dataset,
            n_graphs: dataset.default_n_graphs(),
            trials: 5,
            seed: 0xD75,
            load: crate::workloads::DEFAULT_LOAD,
            variants: crate::coordinator::paper_grid(),
        }
    }

    /// Smaller sweep for tests / smoke runs.
    pub fn quick(dataset: Dataset) -> Self {
        Self {
            dataset,
            n_graphs: 16,
            trials: 2,
            seed: 7,
            load: crate::workloads::DEFAULT_LOAD,
            variants: crate::coordinator::paper_grid(),
        }
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("dataset", json::s(self.dataset.name())),
            ("n_graphs", json::num(self.n_graphs as f64)),
            ("trials", json::num(self.trials as f64)),
            ("seed", json::num(self.seed as f64)),
            ("load", json::num(self.load)),
            (
                "variants",
                json::arr(self.variants.iter().map(|v| json::s(&v.label())).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self, String> {
        let dataset = v
            .get("dataset")
            .and_then(|d| d.as_str())
            .and_then(Dataset::parse)
            .ok_or("missing/bad 'dataset'")?;
        let n_graphs = v
            .get("n_graphs")
            .and_then(|x| x.as_usize())
            .unwrap_or_else(|| dataset.default_n_graphs());
        let trials = v.get("trials").and_then(|x| x.as_usize()).unwrap_or(5);
        let seed = v.get("seed").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        let load = v
            .get("load")
            .and_then(|x| x.as_f64())
            .unwrap_or(crate::workloads::DEFAULT_LOAD);
        let variants = match v.get("variants") {
            None => crate::coordinator::paper_grid(),
            Some(arr) => {
                let items = arr.as_array().ok_or("'variants' must be an array")?;
                let mut out = Vec::new();
                for it in items {
                    let s = it.as_str().ok_or("variant must be a string")?;
                    out.push(Variant::parse(s).ok_or_else(|| format!("bad variant '{s}'"))?);
                }
                out
            }
        };
        Ok(Self {
            dataset,
            n_graphs,
            trials,
            seed,
            load,
            variants,
        })
    }

    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let v = Value::from_str(&text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_json() {
        let cfg = ExperimentConfig::paper_default(Dataset::RiotBench);
        let v = cfg.to_json();
        let back = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn defaults_fill_in() {
        let v = Value::from_str(r#"{"dataset": "synthetic"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.n_graphs, 100);
        assert_eq!(cfg.variants.len(), 30);
    }

    #[test]
    fn bad_variant_is_an_error() {
        let v = Value::from_str(r#"{"dataset": "synthetic", "variants": ["XQ-HEFT"]}"#).unwrap();
        assert!(ExperimentConfig::from_json(&v).is_err());
    }

    #[test]
    fn explicit_variants_parse() {
        let v = Value::from_str(
            r#"{"dataset": "adv", "variants": ["P-HEFT", "NP-HEFT", "5P-CPOP"], "trials": 2}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.variants.len(), 3);
        assert_eq!(cfg.variants[2].label(), "5P-CPOP");
        assert_eq!(cfg.dataset, Dataset::Adversarial);
    }
}
