//! §VI.D adversarial instances: out-trees whose root carries a huge
//! computation followed by many shallow, lightweight successors.
//!
//! The root must finish before any successor can run, so a non-preemptive
//! scheduler that has packed small tasks from earlier graphs around it
//! cannot clear machines for the successors — the Fig. 1 blocking
//! pathology.  The caller pins CCR to 0.2 (communication negligible)
//! via [`super::set_ccr`], as the paper does.

use crate::graph::{GraphBuilder, TaskGraph};
use crate::prng::Xoshiro256pp;
use crate::stats::TruncatedGaussian;

/// Ratio between the root's cost and the mean successor cost.
pub const ROOT_FACTOR: f64 = 30.0;

/// One adversarial out-tree: heavy root, `width` light leaves.
pub fn instance(idx: usize, rng: &mut Xoshiro256pp) -> TaskGraph {
    let width = rng.int_range(8, 16);
    let leaf_dist = TruncatedGaussian::new(1.0, 0.3, 0.3, 2.0);
    let mut b = GraphBuilder::new(format!("adversarial_{idx}"));
    let root_cost = ROOT_FACTOR * 1.0 * rng.uniform(0.8, 1.2);
    let root = b.task(root_cost);
    for _ in 0..width {
        let t = b.task(leaf_dist.sample(rng));
        // data sizes are placeholders — set_ccr rescales them to CCR 0.2
        b.edge(root, t, 1.0);
    }
    b.build().expect("adversarial instance is a DAG")
}

/// Generate `n` adversarial instances.
pub fn generate(n: usize, rng: &mut Xoshiro256pp) -> Vec<TaskGraph> {
    (0..n).map(|i| instance(i, rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::Network;
    use crate::workloads::{measure_ccr, set_ccr};

    #[test]
    fn root_dominates_leaves() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let g = instance(0, &mut rng);
        let root_cost = g.cost(0);
        for t in 1..g.n_tasks() {
            assert!(root_cost > 10.0 * g.cost(t));
            assert_eq!(g.predecessors(t).len(), 1);
            assert!(g.is_sink(t));
        }
        assert!(g.is_source(0));
        assert_eq!(g.height(), 2);
    }

    #[test]
    fn ccr_pins_to_0_2() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let net = Network::default_eval(&mut rng);
        let mut g = instance(0, &mut rng);
        set_ccr(&mut g, &net, 0.2);
        assert!((measure_ccr(&g, &net) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn widths_vary_across_instances() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let gs = generate(20, &mut rng);
        let widths: std::collections::HashSet<usize> =
            gs.iter().map(|g| g.n_tasks()).collect();
        assert!(widths.len() > 3);
    }
}
