//! §VI.B RIoTBench IoT streaming pipelines (Shukla, Chaturvedi & Simmhan,
//! 2017): ETL, Predict (PRED), Statistical summarization (STATS) and
//! model Training (TRAIN).
//!
//! The paper instantiates the original dataflow topologies; we encode
//! those operator graphs directly (operator list + wiring + a relative
//! cost class per operator, reflecting the benchmark's published
//! heterogeneity: parsing/filtering is cheap, ML scoring/training and
//! I/O-heavy sinks are expensive).  Edge data sizes model the SenML tuple
//! streams flowing between operators.

use crate::graph::{GraphBuilder, TaskGraph};
use crate::prng::Xoshiro256pp;
use crate::stats::TruncatedGaussian;

/// The four pipelines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pipeline {
    Etl,
    Pred,
    Stats,
    Train,
}

impl Pipeline {
    pub const ALL: [Pipeline; 4] = [
        Pipeline::Etl,
        Pipeline::Pred,
        Pipeline::Stats,
        Pipeline::Train,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Pipeline::Etl => "riot_etl",
            Pipeline::Pred => "riot_pred",
            Pipeline::Stats => "riot_stats",
            Pipeline::Train => "riot_train",
        }
    }
}

/// Operator cost classes (relative compute weight of one window of
/// tuples).  Sampled around the class mean with 25% spread.
#[derive(Clone, Copy, Debug)]
enum Class {
    Light,  // parse, filter, annotate
    Medium, // interpolate, join, aggregate, window regression
    Heavy,  // ML train/score, batched I/O sinks
}

impl Class {
    fn mean(&self) -> f64 {
        match self {
            Class::Light => 4.0,
            Class::Medium => 12.0,
            Class::Heavy => 40.0,
        }
    }
}

struct Gen<'a> {
    b: GraphBuilder,
    rng: &'a mut Xoshiro256pp,
}

impl<'a> Gen<'a> {
    fn new(name: &str, rng: &'a mut Xoshiro256pp) -> Self {
        Self {
            b: GraphBuilder::new(name),
            rng,
        }
    }

    fn op(&mut self, class: Class) -> usize {
        let m = class.mean();
        let d = TruncatedGaussian::new(m, 0.25 * m, 0.3 * m, 3.0 * m);
        self.b.task(d.sample(self.rng))
    }

    /// Tuple-stream edge: data size around 5 with mild spread.
    fn wire(&mut self, u: usize, v: usize) {
        let d = TruncatedGaussian::new(5.0, 1.5, 0.5, 12.0);
        let data = d.sample(self.rng);
        self.b.edge(u, v, data);
    }

    fn finish(self) -> TaskGraph {
        self.b.build().expect("riotbench pipelines are DAGs")
    }
}

/// ETL: SenMLParse → RangeFilter → BloomFilter → Interpolate → Join →
/// Annotate → CsvToSenML → {MQTTPublish, AzureTableInsert}.
pub fn etl(rng: &mut Xoshiro256pp) -> TaskGraph {
    let mut g = Gen::new("riot_etl", rng);
    let parse = g.op(Class::Light);
    let range = g.op(Class::Light);
    let bloom = g.op(Class::Light);
    let interp = g.op(Class::Medium);
    let join = g.op(Class::Medium);
    let annotate = g.op(Class::Light);
    let csv = g.op(Class::Light);
    let mqtt = g.op(Class::Heavy);
    let azure = g.op(Class::Heavy);
    for w in [
        (parse, range),
        (range, bloom),
        (bloom, interp),
        (interp, join),
        (join, annotate),
        (annotate, csv),
        (csv, mqtt),
        (csv, azure),
    ] {
        g.wire(w.0, w.1);
    }
    g.finish()
}

/// PRED: {SenMLParse, BlobModelRead} → {DecisionTreeClassify,
/// MultiVarLinearReg} → ErrorEstimate → MQTTPublish.
pub fn pred(rng: &mut Xoshiro256pp) -> TaskGraph {
    let mut g = Gen::new("riot_pred", rng);
    let parse = g.op(Class::Light);
    let blob = g.op(Class::Heavy); // model fetch
    let dtree = g.op(Class::Heavy);
    let mlr = g.op(Class::Heavy);
    let avg = g.op(Class::Medium); // error estimation / average
    let mqtt = g.op(Class::Heavy);
    for w in [
        (parse, dtree),
        (parse, mlr),
        (blob, dtree),
        (blob, mlr),
        (dtree, avg),
        (mlr, avg),
        (avg, mqtt),
    ] {
        g.wire(w.0, w.1);
    }
    g.finish()
}

/// STATS: SenMLParse fans into {Average, KalmanFilter→SlidingWindowReg,
/// DistinctApproxCount}, all joining at GroupViz.
pub fn stats(rng: &mut Xoshiro256pp) -> TaskGraph {
    let mut g = Gen::new("riot_stats", rng);
    let parse = g.op(Class::Light);
    let avg = g.op(Class::Medium);
    let kalman = g.op(Class::Medium);
    let swlr = g.op(Class::Medium);
    let count = g.op(Class::Medium);
    let viz = g.op(Class::Heavy);
    for w in [
        (parse, avg),
        (parse, kalman),
        (kalman, swlr),
        (parse, count),
        (avg, viz),
        (swlr, viz),
        (count, viz),
    ] {
        g.wire(w.0, w.1);
    }
    g.finish()
}

/// TRAIN: AzureTableRead → {MultiVarLinearRegTrain, DecisionTreeTrain} →
/// BlobWrite → MQTTPublish, with an Annotate stage feeding the trainers.
pub fn train(rng: &mut Xoshiro256pp) -> TaskGraph {
    let mut g = Gen::new("riot_train", rng);
    let read = g.op(Class::Heavy);
    let annotate = g.op(Class::Light);
    let mlr = g.op(Class::Heavy);
    let dtree = g.op(Class::Heavy);
    let blob = g.op(Class::Heavy);
    let mqtt = g.op(Class::Medium);
    for w in [
        (read, annotate),
        (annotate, mlr),
        (annotate, dtree),
        (mlr, blob),
        (dtree, blob),
        (blob, mqtt),
    ] {
        g.wire(w.0, w.1);
    }
    g.finish()
}

/// Generate `n` pipeline instances with equal type probability (§VI.B).
pub fn generate(n: usize, rng: &mut Xoshiro256pp) -> Vec<TaskGraph> {
    (0..n)
        .map(|_| match Pipeline::ALL[rng.below(4)] {
            Pipeline::Etl => etl(rng),
            Pipeline::Pred => pred(rng),
            Pipeline::Stats => stats(rng),
            Pipeline::Train => train(rng),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(21)
    }

    #[test]
    fn etl_topology() {
        let g = etl(&mut rng());
        assert_eq!(g.n_tasks(), 9);
        assert_eq!(g.n_edges(), 8);
        // single source (parse), two sinks (mqtt, azure)
        let sources: Vec<_> = (0..9).filter(|&t| g.is_source(t)).collect();
        let sinks: Vec<_> = (0..9).filter(|&t| g.is_sink(t)).collect();
        assert_eq!(sources.len(), 1);
        assert_eq!(sinks.len(), 2);
        assert_eq!(g.height(), 8);
    }

    #[test]
    fn pred_topology() {
        let g = pred(&mut rng());
        assert_eq!(g.n_tasks(), 6);
        // two sources (parse + model read), one sink
        assert_eq!((0..6).filter(|&t| g.is_source(t)).count(), 2);
        assert_eq!((0..6).filter(|&t| g.is_sink(t)).count(), 1);
    }

    #[test]
    fn stats_topology_has_three_branches() {
        let g = stats(&mut rng());
        assert_eq!(g.n_tasks(), 6);
        assert_eq!(g.successors(0).len(), 3);
        // viz joins three branches
        assert_eq!(g.predecessors(5).len(), 3);
    }

    #[test]
    fn train_topology() {
        let g = train(&mut rng());
        assert_eq!(g.n_tasks(), 6);
        assert_eq!(g.height(), 5);
    }

    #[test]
    fn heavy_ops_cost_more_than_light_on_average() {
        let mut r = rng();
        let mut light = 0.0;
        let mut heavy = 0.0;
        for _ in 0..200 {
            let g = pred(&mut r);
            light += g.cost(0); // parse
            heavy += g.cost(2); // dtree
        }
        assert!(heavy > 3.0 * light, "heavy {heavy} vs light {light}");
    }

    #[test]
    fn generate_mixes_all_pipelines() {
        let gs = generate(100, &mut rng());
        let mut seen = std::collections::HashSet::new();
        for g in &gs {
            seen.insert(g.name().to_string());
        }
        assert_eq!(seen.len(), 4, "{seen:?}");
    }
}
