//! Workload generators — the paper's §VI evaluation suite.
//!
//! Four datasets:
//! * [`synthetic`] — 100 graphs split evenly among OutTree / InTree /
//!   ForkJoin / Chain, weights from a 5-component truncated Gaussian
//!   mixture (§VI.A);
//! * [`riotbench`] — the four RIoTBench IoT streaming pipelines ETL /
//!   Predict / Stats / Train with their published operator topologies
//!   (§VI.B);
//! * [`wfcommons`] — nine scientific workflows (Epigenomics, Montage,
//!   Cycles, Seismology, SoyKB, SRA Search, Genome, Blast, BWA) as
//!   recipe-style generators (§VI.C);
//! * [`adversarial`] — the big-root out-tree instance with CCR 0.2
//!   (§VI.D).
//!
//! Substitution note (DESIGN.md §3): the paper instantiates RIoTBench /
//! WFCommons DAGs from trace files; those files are not redistributable,
//! so the generators here encode the published topologies and cost
//! heterogeneity parametrically.  Every figure depends only on topology
//! shape + weight spread, which are preserved.
//!
//! Orthogonal to the dataset choice, the [`scenario`] module layers a
//! **scenario axis** over any dataset: per-graph importance weights
//! (heavy-tail or class-based), completion deadlines (critical-path ×
//! slack), and a bursty arrival process — see
//! [`Dataset::instance_scenario`].  The default [`Scenario`] reproduces the
//! paper's setting bit-exactly.

pub mod adversarial;
pub mod riotbench;
pub mod scenario;
pub mod synthetic;
pub mod wfcommons;

pub use scenario::{ArrivalModel, DeadlineModel, Scenario, WeightModel};

use crate::coordinator::DynamicProblem;
use crate::graph::TaskGraph;
use crate::network::Network;
use crate::prng::Xoshiro256pp;
use crate::stats::poisson_arrivals;

/// Dataset selector for the experiment harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    Synthetic,
    RiotBench,
    WfCommons,
    Adversarial,
}

impl Dataset {
    pub const ALL: [Dataset; 4] = [
        Dataset::Synthetic,
        Dataset::RiotBench,
        Dataset::WfCommons,
        Dataset::Adversarial,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Synthetic => "synthetic",
            Dataset::RiotBench => "riotbench",
            Dataset::WfCommons => "wfcommons",
            Dataset::Adversarial => "adversarial",
        }
    }

    pub fn parse(s: &str) -> Option<Dataset> {
        match s.to_ascii_lowercase().as_str() {
            "synthetic" => Some(Dataset::Synthetic),
            "riotbench" | "riot" => Some(Dataset::RiotBench),
            "wfcommons" | "wf" => Some(Dataset::WfCommons),
            "adversarial" | "adv" => Some(Dataset::Adversarial),
            _ => None,
        }
    }

    /// Paper-default graph count for this dataset (§VI).
    pub fn default_n_graphs(&self) -> usize {
        match self {
            Dataset::Synthetic => 100,
            Dataset::RiotBench => 100,
            Dataset::WfCommons => 50,
            Dataset::Adversarial => 30,
        }
    }

    /// Generate the bare graph sequence (no arrivals).
    pub fn graphs(&self, n: usize, rng: &mut Xoshiro256pp) -> Vec<TaskGraph> {
        match self {
            Dataset::Synthetic => synthetic::generate(n, rng),
            Dataset::RiotBench => riotbench::generate(n, rng),
            Dataset::WfCommons => wfcommons::generate(n, rng),
            Dataset::Adversarial => adversarial::generate(n, rng),
        }
    }

    /// Full dynamic instance: graphs + Poisson arrivals + network.
    pub fn instance(&self, n_graphs: usize, seed: u64) -> DynamicProblem {
        self.instance_opts(n_graphs, seed, DEFAULT_LOAD, None)
    }

    /// [`Dataset::instance`] with explicit offered load and an optional
    /// CCR override (applied to every graph; the adversarial dataset
    /// defaults to the paper's CCR 0.2 when no override is given).
    pub fn instance_opts(
        &self,
        n_graphs: usize,
        seed: u64,
        load: f64,
        ccr: Option<f64>,
    ) -> DynamicProblem {
        self.instance_scenario(n_graphs, seed, load, ccr, &Scenario::default())
    }

    /// [`Dataset::instance_opts`] with a [`Scenario`] layered on top:
    /// the arrival process is drawn per [`ArrivalModel`], then per-graph
    /// weights and deadlines are stamped by the scenario's models.
    ///
    /// The weight/deadline stamping consumes no RNG and the Poisson
    /// arrival path is the pre-scenario generator verbatim, so at
    /// the default [`Scenario`] the returned instance is **bit-identical**
    /// to [`Dataset::instance_opts`] (differential-tested in
    /// `rust/tests/scenario_deadline.rs`).
    pub fn instance_scenario(
        &self,
        n_graphs: usize,
        seed: u64,
        load: f64,
        ccr: Option<f64>,
        scenario: &Scenario,
    ) -> DynamicProblem {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let network = Network::default_eval(&mut rng);
        let mut graphs = self.graphs(n_graphs, &mut rng);
        let effective_ccr = ccr.or(if *self == Dataset::Adversarial {
            // §VI.D: CCR pinned to 0.2 so communication is negligible.
            Some(0.2)
        } else {
            None
        });
        if let Some(c) = effective_ccr {
            for g in &mut graphs {
                set_ccr(g, &network, c);
            }
        }
        let arrivals = match scenario.arrivals {
            ArrivalModel::Poisson => arrivals_for(&graphs, &network, &mut rng, load),
            ArrivalModel::Bursty { burst } => {
                scenario::bursty_arrivals(&graphs, &network, &mut rng, load, burst)
            }
        };
        let mut paired: Vec<(f64, TaskGraph)> = arrivals.into_iter().zip(graphs).collect();
        scenario.apply(seed, &mut paired, &network);
        DynamicProblem::new(network, paired)
    }
}

/// Default offered-load factor: mean inter-arrival time = `LOAD` × the
/// mean per-graph serial service time spread over the whole network.
/// < 1 means graphs overlap (the dynamic regime the paper studies).
pub const DEFAULT_LOAD: f64 = 0.5;

/// Mean per-graph service demand: total cost × mean inverse speed,
/// spread over the whole network.  The time unit of every arrival
/// process ([`arrivals_for`], [`scenario::bursty_arrivals`]) — one
/// definition so the processes stay load-matched by construction.
pub fn mean_service_demand(graphs: &[TaskGraph], net: &Network) -> f64 {
    if graphs.is_empty() {
        return 0.0;
    }
    graphs
        .iter()
        .map(|g| g.total_cost() * net.mean_inv_speed() / net.n_nodes() as f64)
        .sum::<f64>()
        / graphs.len() as f64
}

/// Poisson arrivals scaled to the workload: the mean service demand of a
/// graph ([`mean_service_demand`]) sets the time unit.
pub fn arrivals_for(
    graphs: &[TaskGraph],
    net: &Network,
    rng: &mut Xoshiro256pp,
    load: f64,
) -> Vec<f64> {
    if graphs.is_empty() {
        return Vec::new();
    }
    let mean_gap = (load * mean_service_demand(graphs, net)).max(1e-9);
    poisson_arrivals(rng, graphs.len(), 1.0 / mean_gap)
}

/// Rescale a graph's edge data sizes so its Communication-to-Computation
/// Ratio on `net` equals `ccr`: mean per-edge communication time over
/// mean per-task execution time.
pub fn set_ccr(g: &mut TaskGraph, net: &Network, ccr: f64) {
    let n_tasks = g.n_tasks().max(1) as f64;
    let n_edges = g.n_edges() as f64;
    if n_edges == 0.0 {
        return;
    }
    let mean_exec = g.total_cost() * net.mean_inv_speed() / n_tasks;
    let mean_comm = g.total_data() * net.mean_inv_link() / n_edges;
    if mean_comm <= 0.0 {
        return;
    }
    g.scale_edges(ccr * mean_exec / mean_comm);
}

/// Measured CCR of a graph on a network (test/debug helper).
pub fn measure_ccr(g: &TaskGraph, net: &Network) -> f64 {
    let n_edges = g.n_edges() as f64;
    if n_edges == 0.0 {
        return 0.0;
    }
    let mean_exec = g.total_cost() * net.mean_inv_speed() / g.n_tasks() as f64;
    let mean_comm = g.total_data() * net.mean_inv_link() / n_edges;
    mean_comm / mean_exec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_parse_and_names() {
        for d in Dataset::ALL {
            assert_eq!(Dataset::parse(d.name()), Some(d));
        }
        assert_eq!(Dataset::parse("wf"), Some(Dataset::WfCommons));
        assert_eq!(Dataset::parse("nope"), None);
    }

    #[test]
    fn instance_is_reproducible_and_sized() {
        let p1 = Dataset::Synthetic.instance(20, 7);
        let p2 = Dataset::Synthetic.instance(20, 7);
        assert_eq!(p1.graphs.len(), 20);
        assert_eq!(p1.total_tasks(), p2.total_tasks());
        let a1: Vec<f64> = p1.graphs.iter().map(|(a, _)| *a).collect();
        let a2: Vec<f64> = p2.graphs.iter().map(|(a, _)| *a).collect();
        assert_eq!(a1, a2);
        // arrivals sorted, starting at 0
        assert_eq!(a1[0], 0.0);
        assert!(a1.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn different_seeds_differ() {
        let p1 = Dataset::Synthetic.instance(10, 1);
        let p2 = Dataset::Synthetic.instance(10, 2);
        let a1: Vec<f64> = p1.graphs.iter().map(|(a, _)| *a).collect();
        let a2: Vec<f64> = p2.graphs.iter().map(|(a, _)| *a).collect();
        assert_ne!(a1, a2);
    }

    #[test]
    fn set_ccr_hits_target() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let net = Network::default_eval(&mut rng);
        let mut graphs = synthetic::generate(8, &mut rng);
        for g in graphs.iter_mut() {
            if g.n_edges() == 0 {
                continue;
            }
            set_ccr(g, &net, 0.2);
            assert!((measure_ccr(g, &net) - 0.2).abs() < 1e-9, "g={}", g.name());
        }
    }

    #[test]
    fn arrivals_scale_with_load() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let net = Network::homogeneous(4);
        let graphs = synthetic::generate(50, &mut rng);
        let mut r1 = Xoshiro256pp::seed_from_u64(9);
        let slow = arrivals_for(&graphs, &net, &mut r1, 2.0);
        let mut r2 = Xoshiro256pp::seed_from_u64(9);
        let fast = arrivals_for(&graphs, &net, &mut r2, 0.1);
        assert!(slow.last().unwrap() > fast.last().unwrap());
    }
}
