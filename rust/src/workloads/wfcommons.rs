//! §VI.C WFCommons scientific workflows (Coleman, Casanova & Ferreira da
//! Silva, 2023): recipe-style generators for the nine workflows the paper
//! selects — Epigenomics, Montage, Cycles, Seismology, SoyKB, SRA Search,
//! Genome (1000Genome), Blast and BWA.
//!
//! Each generator reproduces the workflow's published level structure
//! (parallel lanes, split/merge phases, long sequential tails) with a
//! randomized width parameter, and samples task runtimes from
//! heavy-tailed per-stage distributions — the properties (long critical
//! paths, wide fan-outs, imbalanced stage costs) the paper's evaluation
//! exercises.

use crate::graph::{GraphBuilder, TaskGraph};
use crate::prng::Xoshiro256pp;
use crate::stats::TruncatedGaussian;

/// The nine selected workflows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workflow {
    Epigenomics,
    Montage,
    Cycles,
    Seismology,
    SoyKb,
    SraSearch,
    Genome,
    Blast,
    Bwa,
}

impl Workflow {
    pub const ALL: [Workflow; 9] = [
        Workflow::Epigenomics,
        Workflow::Montage,
        Workflow::Cycles,
        Workflow::Seismology,
        Workflow::SoyKb,
        Workflow::SraSearch,
        Workflow::Genome,
        Workflow::Blast,
        Workflow::Bwa,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Workflow::Epigenomics => "wf_epigenomics",
            Workflow::Montage => "wf_montage",
            Workflow::Cycles => "wf_cycles",
            Workflow::Seismology => "wf_seismology",
            Workflow::SoyKb => "wf_soykb",
            Workflow::SraSearch => "wf_srasearch",
            Workflow::Genome => "wf_genome",
            Workflow::Blast => "wf_blast",
            Workflow::Bwa => "wf_bwa",
        }
    }

    pub fn build(&self, rng: &mut Xoshiro256pp) -> TaskGraph {
        match self {
            Workflow::Epigenomics => epigenomics(rng),
            Workflow::Montage => montage(rng),
            Workflow::Cycles => cycles(rng),
            Workflow::Seismology => seismology(rng),
            Workflow::SoyKb => soykb(rng),
            Workflow::SraSearch => sra_search(rng),
            Workflow::Genome => genome(rng),
            Workflow::Blast => blast(rng),
            Workflow::Bwa => bwa(rng),
        }
    }
}

/// Stage cost classes: scientific workflows are far more imbalanced than
/// streaming operators — `Long` tasks dominate (heavy-tailed).
#[derive(Clone, Copy)]
enum C {
    Short,
    Mid,
    Long,
}

struct Gen<'a> {
    b: GraphBuilder,
    rng: &'a mut Xoshiro256pp,
}

impl<'a> Gen<'a> {
    fn new(name: &str, rng: &'a mut Xoshiro256pp) -> Self {
        Self {
            b: GraphBuilder::new(name),
            rng,
        }
    }

    fn t(&mut self, c: C) -> usize {
        let (mean, spread, hi) = match c {
            C::Short => (5.0, 2.0, 20.0),
            C::Mid => (25.0, 10.0, 80.0),
            C::Long => (90.0, 45.0, 400.0),
        };
        let d = TruncatedGaussian::new(mean, spread, 1.0, hi);
        self.b.task(d.sample(self.rng))
    }

    fn e(&mut self, u: usize, v: usize) {
        // file-transfer edges: wide spread (KBs to GBs, rescaled)
        let d = TruncatedGaussian::new(10.0, 8.0, 0.5, 60.0);
        let data = d.sample(self.rng);
        self.b.edge(u, v, data);
    }

    fn finish(self) -> TaskGraph {
        self.b.build().expect("wfcommons recipes are DAGs")
    }
}

/// Epigenomics: `lanes` parallel 4-stage chains (split → filter →
/// sol2sanger → map) merging into mapMerge → maqIndex → pileup.
pub fn epigenomics(rng: &mut Xoshiro256pp) -> TaskGraph {
    let lanes = rng.int_range(2, 4);
    let mut g = Gen::new("wf_epigenomics", rng);
    let split = g.t(C::Mid);
    let merge = g.t(C::Mid);
    for _ in 0..lanes {
        let filter = g.t(C::Short);
        let sol = g.t(C::Short);
        let fq2bfq = g.t(C::Short);
        let map = g.t(C::Long);
        g.e(split, filter);
        g.e(filter, sol);
        g.e(sol, fq2bfq);
        g.e(fq2bfq, map);
        g.e(map, merge);
    }
    let index = g.t(C::Mid);
    let pileup = g.t(C::Mid);
    g.e(merge, index);
    g.e(index, pileup);
    g.finish()
}

/// Montage: mProject ×N → mDiffFit ×(N-1 pairwise) → mConcatFit →
/// mBgModel → mBackground ×N → mImgtbl → mAdd → mShrink → mJPEG.
pub fn montage(rng: &mut Xoshiro256pp) -> TaskGraph {
    let n = rng.int_range(3, 6);
    let mut g = Gen::new("wf_montage", rng);
    let projects: Vec<_> = (0..n).map(|_| g.t(C::Mid)).collect();
    let diffs: Vec<_> = (0..n - 1).map(|_| g.t(C::Short)).collect();
    for i in 0..n - 1 {
        g.e(projects[i], diffs[i]);
        g.e(projects[i + 1], diffs[i]);
    }
    let concat = g.t(C::Short);
    for &d in &diffs {
        g.e(d, concat);
    }
    let bgmodel = g.t(C::Mid);
    g.e(concat, bgmodel);
    let backgrounds: Vec<_> = (0..n).map(|_| g.t(C::Short)).collect();
    for (i, &bg) in backgrounds.iter().enumerate() {
        g.e(bgmodel, bg);
        g.e(projects[i], bg);
    }
    let imgtbl = g.t(C::Short);
    for &bg in &backgrounds {
        g.e(bg, imgtbl);
    }
    let add = g.t(C::Long);
    let shrink = g.t(C::Short);
    let jpeg = g.t(C::Short);
    g.e(imgtbl, add);
    g.e(add, shrink);
    g.e(shrink, jpeg);
    g.finish()
}

/// Cycles: baseline_cycles ×N → cycles ×N → output parser → summary.
pub fn cycles(rng: &mut Xoshiro256pp) -> TaskGraph {
    let n = rng.int_range(3, 7);
    let mut g = Gen::new("wf_cycles", rng);
    let parser = g.t(C::Mid);
    for _ in 0..n {
        let base = g.t(C::Mid);
        let cyc = g.t(C::Long);
        let fert = g.t(C::Short);
        g.e(base, cyc);
        g.e(cyc, fert);
        g.e(fert, parser);
    }
    let summary = g.t(C::Short);
    g.e(parser, summary);
    g.finish()
}

/// Seismology: sG1IterDecon ×N all merging into wrapper_siftSTFByMisfit.
pub fn seismology(rng: &mut Xoshiro256pp) -> TaskGraph {
    let n = rng.int_range(4, 10);
    let mut g = Gen::new("wf_seismology", rng);
    let merge = g.t(C::Mid);
    for _ in 0..n {
        let d = g.t(C::Mid);
        g.e(d, merge);
    }
    g.finish()
}

/// SoyKB: per-sample chains (align → sort → dedup → realign →
/// haplotype_caller) → combine_variants → select/filter chain.
pub fn soykb(rng: &mut Xoshiro256pp) -> TaskGraph {
    let samples = rng.int_range(2, 4);
    let mut g = Gen::new("wf_soykb", rng);
    let combine = g.t(C::Mid);
    for _ in 0..samples {
        let align = g.t(C::Long);
        let sort = g.t(C::Short);
        let dedup = g.t(C::Short);
        let realign = g.t(C::Mid);
        let hap = g.t(C::Long);
        g.e(align, sort);
        g.e(sort, dedup);
        g.e(dedup, realign);
        g.e(realign, hap);
        g.e(hap, combine);
    }
    let select_snp = g.t(C::Short);
    let filter_snp = g.t(C::Short);
    g.e(combine, select_snp);
    g.e(select_snp, filter_snp);
    g.finish()
}

/// SRA Search: N parallel (prefetch → fasterq_dump → bowtie2) lanes →
/// merge.
pub fn sra_search(rng: &mut Xoshiro256pp) -> TaskGraph {
    let n = rng.int_range(2, 5);
    let mut g = Gen::new("wf_srasearch", rng);
    let merge = g.t(C::Short);
    for _ in 0..n {
        let prefetch = g.t(C::Mid);
        let dump = g.t(C::Mid);
        let bowtie = g.t(C::Long);
        g.e(prefetch, dump);
        g.e(dump, bowtie);
        g.e(bowtie, merge);
    }
    g.finish()
}

/// 1000Genome: individuals ×N → individuals_merge → sifting, then
/// {mutation_overlap, frequency} per population.
pub fn genome(rng: &mut Xoshiro256pp) -> TaskGraph {
    let n = rng.int_range(3, 6);
    let pops = rng.int_range(1, 3);
    let mut g = Gen::new("wf_genome", rng);
    let merge = g.t(C::Mid);
    for _ in 0..n {
        let ind = g.t(C::Long);
        g.e(ind, merge);
    }
    let sifting = g.t(C::Mid);
    g.e(merge, sifting);
    for _ in 0..pops {
        let overlap = g.t(C::Mid);
        let freq = g.t(C::Mid);
        g.e(sifting, overlap);
        g.e(sifting, freq);
    }
    g.finish()
}

/// Blast: split_fasta → blastall ×N → cat_blast → cleanup.
pub fn blast(rng: &mut Xoshiro256pp) -> TaskGraph {
    let n = rng.int_range(3, 8);
    let mut g = Gen::new("wf_blast", rng);
    let split = g.t(C::Short);
    let cat = g.t(C::Short);
    for _ in 0..n {
        let b = g.t(C::Long);
        g.e(split, b);
        g.e(b, cat);
    }
    let cleanup = g.t(C::Short);
    g.e(cat, cleanup);
    g.finish()
}

/// BWA: bwa_index → bwa_aln ×N (paired) → concat.
pub fn bwa(rng: &mut Xoshiro256pp) -> TaskGraph {
    let n = rng.int_range(3, 8);
    let mut g = Gen::new("wf_bwa", rng);
    let index = g.t(C::Mid);
    let concat = g.t(C::Short);
    for _ in 0..n {
        let aln = g.t(C::Long);
        g.e(index, aln);
        g.e(aln, concat);
    }
    g.finish()
}

/// Generate `n` workflows evenly distributed by type (§VI.C: 50 graphs
/// over nine types — round-robin keeps every prefix balanced).
pub fn generate(n: usize, rng: &mut Xoshiro256pp) -> Vec<TaskGraph> {
    (0..n)
        .map(|i| Workflow::ALL[i % Workflow::ALL.len()].build(rng))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(31)
    }

    #[test]
    fn all_workflows_build_valid_dags() {
        let mut r = rng();
        for wf in Workflow::ALL {
            for _ in 0..5 {
                let g = wf.build(&mut r);
                assert!(g.n_tasks() >= 5, "{} too small", wf.name());
                assert!(g.n_edges() >= g.n_tasks() - 2, "{} too sparse", wf.name());
                assert_eq!(g.topo_order().len(), g.n_tasks());
            }
        }
    }

    #[test]
    fn epigenomics_has_parallel_lanes_and_long_tail() {
        let g = epigenomics(&mut rng());
        // split fans out to `lanes` filters
        assert!(g.successors(0).len() >= 2);
        assert!(g.height() >= 7, "height {}", g.height());
    }

    #[test]
    fn montage_has_pairwise_diff_structure() {
        let g = montage(&mut rng());
        // find a diff task with exactly two project parents
        let has_pairwise = (0..g.n_tasks()).any(|t| g.predecessors(t).len() == 2);
        assert!(has_pairwise);
        assert!(g.height() >= 7);
    }

    #[test]
    fn seismology_is_star_merge() {
        let g = seismology(&mut rng());
        assert_eq!(g.height(), 2);
        assert_eq!(g.predecessors(0).len(), g.n_tasks() - 1);
    }

    #[test]
    fn blast_split_merge_counts() {
        let g = blast(&mut rng());
        let n_par = g.successors(0).len();
        assert!(n_par >= 3);
        assert_eq!(g.predecessors(1).len(), n_par);
    }

    #[test]
    fn generate_covers_all_nine_types() {
        let gs = generate(50, &mut rng());
        let names: std::collections::HashSet<_> =
            gs.iter().map(|g| g.name().to_string()).collect();
        assert_eq!(names.len(), 9, "{names:?}");
        // round-robin balance: each type appears 5 or 6 times in 50
        for wf in Workflow::ALL {
            let c = gs.iter().filter(|g| g.name() == wf.name()).count();
            assert!((5..=6).contains(&c), "{} appears {c} times", wf.name());
        }
    }

    #[test]
    fn long_tasks_are_heavy_tailed() {
        let mut r = rng();
        let mut maxc: f64 = 0.0;
        let mut minc = f64::INFINITY;
        for _ in 0..30 {
            let g = blast(&mut r);
            for t in 0..g.n_tasks() {
                maxc = maxc.max(g.cost(t));
                minc = minc.min(g.cost(t));
            }
        }
        assert!(maxc / minc > 10.0, "spread {maxc}/{minc}");
    }
}
