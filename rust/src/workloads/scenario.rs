//! The **scenario axis**: per-graph importance weights, completion
//! deadlines, and arrival-process shape layered over the §VI dataset
//! generators.
//!
//! The paper evaluates every workload with unit-importance graphs, no
//! deadlines, and Poisson arrivals.  A [`Scenario`] widens each of those
//! knobs independently:
//!
//! * [`WeightModel`] — non-unit importance weights for the weighted
//!   fairness metrics ([`crate::metrics`]): a truncated-Pareto
//!   heavy-tail sampler (a few graphs matter a lot) or a class-based
//!   sampler (gold/silver/bronze service tiers);
//! * [`DeadlineModel`] — per-graph completion deadlines: the best-exec
//!   critical-path lower bound ([`crate::metrics::ideal_response`])
//!   times a configurable slack factor, anchored at the graph's arrival;
//! * [`ArrivalModel`] — Poisson arrivals (the paper's process) or a
//!   bursty process in which graphs arrive in simultaneous batches,
//!   stressing the admission path the way arXiv:1802.10309's adversarial
//!   online instances do.
//!
//! **Determinism.**  Weight draws are a pure function of
//! `(instance seed, graph index)` — the same SplitMix-style mixing as
//! [`crate::robustness::StableNoise`] — never of the sampling sequence,
//! so turning weights on cannot perturb the graph structures or the
//! arrival stream.  Deadlines are derived (no randomness).  The Poisson
//! arrival path is byte-for-byte the pre-scenario generator, so at
//! default knobs (the default [`Scenario`]) every instance, schedule and
//! metric in the repo is **bit-identical** to its pre-scenario value
//! (pinned by `rust/tests/scenario_deadline.rs`).

use crate::graph::TaskGraph;
use crate::metrics::ideal_response;
use crate::network::Network;
use crate::prng::Xoshiro256pp;

/// Heavy-tail weights are clipped here so one astronomically important
/// graph cannot reduce every weighted mean to a single-graph readout.
pub const WEIGHT_CAP: f64 = 100.0;

/// Per-graph RNG stream for the weight samplers: a pure function of
/// `(seed, graph)`, independent of how many graphs the instance has and
/// of every other random draw in the generator.
fn graph_rng(seed: u64, graph: usize) -> Xoshiro256pp {
    let mix = (graph as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ seed.rotate_left(21);
    Xoshiro256pp::seed_from_u64(mix)
}

/// How per-graph importance weights are assigned.
#[derive(Clone, Debug, PartialEq, Default)]
pub enum WeightModel {
    /// Every graph weighs 1.0 (the paper's setting; weights untouched).
    #[default]
    Unit,
    /// Truncated Pareto (`x_m = 1`, shape `alpha`, clipped at
    /// [`WEIGHT_CAP`]): most graphs near weight 1, a heavy tail of
    /// far more important ones.  Smaller `alpha` = heavier tail.
    HeavyTail { alpha: f64 },
    /// Service-tier classes: each graph is assigned one of the listed
    /// weights uniformly at random (e.g. `[1, 4, 16]` for
    /// bronze/silver/gold).
    Classes { weights: Vec<f64> },
}

impl WeightModel {
    /// The weight of graph `graph` under instance seed `seed`, or `None`
    /// for [`WeightModel::Unit`] (the graph's default 1.0 is left
    /// untouched, keeping default-knob instances bit-identical).
    pub fn weight_of(&self, seed: u64, graph: usize) -> Option<f64> {
        match self {
            WeightModel::Unit => None,
            WeightModel::HeavyTail { alpha } => {
                assert!(*alpha > 0.0 && alpha.is_finite(), "bad pareto alpha {alpha}");
                let u = graph_rng(seed, graph).next_f64();
                Some((1.0 - u).powf(-1.0 / alpha).min(WEIGHT_CAP))
            }
            WeightModel::Classes { weights } => {
                assert!(!weights.is_empty(), "empty class list");
                let i = graph_rng(seed, graph).below(weights.len());
                Some(weights[i])
            }
        }
    }
}

/// How per-graph completion deadlines are assigned.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum DeadlineModel {
    /// No deadlines (the paper's setting; the deadline metrics read
    /// vacuously on-time).
    #[default]
    None,
    /// `deadline = arrival + slack × ideal_response(g)`: the best-exec
    /// critical-path lower bound times a slack factor.  `slack = 1` is
    /// the (unreachable under contention) ideal; `slack = 0` makes the
    /// deadline the arrival instant itself, so every graph with any work
    /// is tardy by exactly its response time.
    CritPathSlack { slack: f64 },
}

impl DeadlineModel {
    /// Absolute deadline of a graph arriving at `arrival`, or `None`.
    pub fn deadline_of(&self, arrival: f64, g: &TaskGraph, net: &Network) -> Option<f64> {
        match self {
            DeadlineModel::None => None,
            DeadlineModel::CritPathSlack { slack } => {
                assert!(*slack >= 0.0 && slack.is_finite(), "bad deadline slack {slack}");
                Some(arrival + slack * ideal_response(g, net))
            }
        }
    }
}

/// Shape of the arrival process.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum ArrivalModel {
    /// Poisson arrivals scaled to the offered load (the paper's process;
    /// byte-identical to the pre-scenario generator).
    #[default]
    Poisson,
    /// Bursty arrivals: graphs arrive in simultaneous batches of
    /// `burst`, batches separated by exponential gaps whose mean is
    /// scaled by `burst` so the **offered load matches the Poisson
    /// process** — same long-run pressure, far lumpier admission.
    Bursty { burst: usize },
}

/// Bursty counterpart of [`super::arrivals_for`]: `burst`-sized batches
/// of simultaneous arrivals, exponential inter-batch gaps with mean
/// `burst × load × mean demand` (load-matched to the Poisson process).
pub fn bursty_arrivals(
    graphs: &[TaskGraph],
    net: &Network,
    rng: &mut Xoshiro256pp,
    load: f64,
    burst: usize,
) -> Vec<f64> {
    if graphs.is_empty() {
        return Vec::new();
    }
    let burst = burst.max(1);
    let mean_demand = super::mean_service_demand(graphs, net);
    let mean_batch_gap = (load * mean_demand * burst as f64).max(1e-9);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(graphs.len());
    for i in 0..graphs.len() {
        if i > 0 && i % burst == 0 {
            t += rng.exponential(1.0 / mean_batch_gap);
        }
        out.push(t);
    }
    out
}

/// One point of the scenario axis: a weight model, a deadline model and
/// an arrival model, applied on top of any [`super::Dataset`] by
/// [`super::Dataset::instance_scenario`].  The default [`Scenario`] is the
/// paper's setting (unit weights, no deadlines, Poisson arrivals) and is
/// bit-transparent: instances are identical to [`super::Dataset::instance_opts`].
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Scenario {
    pub weights: WeightModel,
    pub deadlines: DeadlineModel,
    pub arrivals: ArrivalModel,
}

impl Scenario {
    /// True iff every knob is at the paper's default.
    pub fn is_default(&self) -> bool {
        *self == Scenario::default()
    }

    /// Compact scenario label for tables/CSV/JSON: `default`, or a `+`
    /// join of the non-default knobs (`w:pareto1.5+d:s2+a:burst4`).
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        match &self.weights {
            WeightModel::Unit => {}
            WeightModel::HeavyTail { alpha } => parts.push(format!("w:pareto{alpha}")),
            WeightModel::Classes { weights } => {
                parts.push(format!("w:classes{}", weights.len()))
            }
        }
        match self.deadlines {
            DeadlineModel::None => {}
            DeadlineModel::CritPathSlack { slack } => parts.push(format!("d:s{slack}")),
        }
        match self.arrivals {
            ArrivalModel::Poisson => {}
            ArrivalModel::Bursty { burst } => parts.push(format!("a:burst{burst}")),
        }
        if parts.is_empty() {
            "default".to_string()
        } else {
            parts.join("+")
        }
    }

    /// Stamp weights and deadlines onto an arrival-paired graph
    /// sequence (index = generation order).  Weight draws depend only on
    /// `(seed, index)`; deadlines only on the pair's arrival and the
    /// graph's best-exec critical path — no RNG stream is consumed, so
    /// applying the default scenario is a no-op.
    pub fn apply(&self, seed: u64, graphs: &mut [(f64, TaskGraph)], net: &Network) {
        for (gi, (arrival, g)) in graphs.iter_mut().enumerate() {
            if let Some(w) = self.weights.weight_of(seed, gi) {
                g.set_weight(w);
            }
            if let Some(d) = self.deadlines.deadline_of(*arrival, g, net) {
                g.set_deadline(d);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{synthetic, Dataset, DEFAULT_LOAD};

    #[test]
    fn default_scenario_is_transparent() {
        let s = Scenario::default();
        assert!(s.is_default());
        assert_eq!(s.label(), "default");
        assert_eq!(s.weights.weight_of(1, 0), None);
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let net = Network::default_eval(&mut rng);
        let g = synthetic::generate(1, &mut rng).remove(0);
        assert_eq!(s.deadlines.deadline_of(5.0, &g, &net), None);
    }

    #[test]
    fn heavy_tail_weights_are_pure_and_bounded() {
        let m = WeightModel::HeavyTail { alpha: 1.5 };
        for gi in 0..200 {
            let w = m.weight_of(42, gi).unwrap();
            assert!((1.0..=WEIGHT_CAP).contains(&w), "g{gi}: {w}");
            // pure function: same (seed, index) → same weight, whatever
            // else was sampled in between
            assert_eq!(w.to_bits(), m.weight_of(42, gi).unwrap().to_bits());
        }
        // different seeds decorrelate
        assert_ne!(m.weight_of(1, 0).unwrap(), m.weight_of(2, 0).unwrap());
        // the tail is actually heavy: some draw in 200 exceeds 4× median
        let ws: Vec<f64> = (0..200).map(|gi| m.weight_of(42, gi).unwrap()).collect();
        let hi = ws.iter().cloned().fold(0.0, f64::max);
        assert!(hi > 4.0, "no tail in {hi}");
    }

    #[test]
    fn class_weights_come_from_the_class_list() {
        let classes = vec![1.0, 4.0, 16.0];
        let m = WeightModel::Classes {
            weights: classes.clone(),
        };
        let mut seen = std::collections::HashSet::new();
        for gi in 0..100 {
            let w = m.weight_of(7, gi).unwrap();
            assert!(classes.contains(&w), "g{gi}: {w}");
            seen.insert(w.to_bits());
        }
        assert_eq!(seen.len(), 3, "all classes visited");
    }

    #[test]
    fn crit_path_slack_deadlines() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let net = Network::default_eval(&mut rng);
        let g = synthetic::generate(1, &mut rng).remove(0);
        let ideal = ideal_response(&g, &net);
        assert!(ideal > 0.0);
        let d2 = DeadlineModel::CritPathSlack { slack: 2.0 }
            .deadline_of(10.0, &g, &net)
            .unwrap();
        assert!((d2 - (10.0 + 2.0 * ideal)).abs() < 1e-12);
        // zero slack: the deadline is the arrival itself
        let d0 = DeadlineModel::CritPathSlack { slack: 0.0 }
            .deadline_of(10.0, &g, &net)
            .unwrap();
        assert_eq!(d0, 10.0);
    }

    #[test]
    fn bursty_arrivals_batch_and_load_match() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let net = Network::default_eval(&mut rng);
        let graphs = synthetic::generate(40, &mut rng);
        let mut r1 = Xoshiro256pp::seed_from_u64(9);
        let arr = bursty_arrivals(&graphs, &net, &mut r1, DEFAULT_LOAD, 4);
        assert_eq!(arr.len(), 40);
        assert_eq!(arr[0], 0.0);
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        // every batch of 4 shares one arrival instant
        for b in arr.chunks(4) {
            assert!(b.iter().all(|&t| t == b[0]), "{b:?}");
        }
        // distinct batches are separated (exponential gaps are a.s. > 0)
        assert!(arr[0] < arr[4]);
        // deterministic in the rng seed
        let mut r2 = Xoshiro256pp::seed_from_u64(9);
        assert_eq!(arr, bursty_arrivals(&graphs, &net, &mut r2, DEFAULT_LOAD, 4));
        // empty input stays empty; burst 0 is clamped to 1
        assert!(bursty_arrivals(&[], &net, &mut r2, DEFAULT_LOAD, 4).is_empty());
        let solo = bursty_arrivals(&graphs, &net, &mut r2, DEFAULT_LOAD, 0);
        assert_eq!(solo.len(), 40);
    }

    #[test]
    fn scenario_apply_stamps_weights_and_deadlines() {
        let scen = Scenario {
            weights: WeightModel::Classes {
                weights: vec![2.0],
            },
            deadlines: DeadlineModel::CritPathSlack { slack: 3.0 },
            arrivals: ArrivalModel::Poisson,
        };
        assert_eq!(scen.label(), "w:classes1+d:s3");
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let net = Network::default_eval(&mut rng);
        let graphs = synthetic::generate(6, &mut rng);
        let mut paired: Vec<(f64, TaskGraph)> = (0..6)
            .map(|i| (i as f64 * 10.0, graphs[i].clone()))
            .collect();
        scen.apply(11, &mut paired, &net);
        for (arrival, g) in &paired {
            assert_eq!(g.weight(), 2.0);
            let d = g.deadline().unwrap();
            assert!((d - (arrival + 3.0 * ideal_response(g, &net))).abs() < 1e-12);
        }
    }

    #[test]
    fn labels_cover_every_knob() {
        let s = Scenario {
            weights: WeightModel::HeavyTail { alpha: 1.5 },
            deadlines: DeadlineModel::CritPathSlack { slack: 2.0 },
            arrivals: ArrivalModel::Bursty { burst: 4 },
        };
        assert_eq!(s.label(), "w:pareto1.5+d:s2+a:burst4");
        assert!(!s.is_default());
    }

    #[test]
    fn dataset_instance_scenario_default_matches_instance() {
        // the bit-identity contract at default knobs, at the entry point
        let a = Dataset::Synthetic.instance(12, 3);
        let b = Dataset::Synthetic.instance_scenario(
            12,
            3,
            DEFAULT_LOAD,
            None,
            &Scenario::default(),
        );
        assert_eq!(a.graphs.len(), b.graphs.len());
        for ((aa, ga), (ab, gb)) in a.graphs.iter().zip(b.graphs.iter()) {
            assert_eq!(aa.to_bits(), ab.to_bits());
            assert_eq!(ga.n_tasks(), gb.n_tasks());
            assert_eq!(ga.weight().to_bits(), gb.weight().to_bits());
            assert_eq!(ga.deadline(), gb.deadline());
            for t in 0..ga.n_tasks() {
                assert_eq!(ga.cost(t).to_bits(), gb.cost(t).to_bits());
            }
        }
    }
}
