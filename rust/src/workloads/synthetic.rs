//! §VI.A synthetic task graphs: OutTree, InTree, ForkJoin, Chain, with
//! task/edge weights from the 5-component truncated Gaussian mixture and
//! structure parameters drawn per instance.

use crate::graph::{GraphBuilder, TaskGraph};
use crate::prng::Xoshiro256pp;
use crate::stats::GaussianMixture;

/// The four §VI.A structures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Structure {
    OutTree,
    InTree,
    ForkJoin,
    Chain,
}

impl Structure {
    pub const ALL: [Structure; 4] = [
        Structure::OutTree,
        Structure::InTree,
        Structure::ForkJoin,
        Structure::Chain,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Structure::OutTree => "out_tree",
            Structure::InTree => "in_tree",
            Structure::ForkJoin => "fork_join",
            Structure::Chain => "chain",
        }
    }
}

/// Weight priors of the paper: 5-component truncated GMM over [1, 100]
/// for task costs, [1, 50] for edge data.
pub fn cost_mixture() -> GaussianMixture {
    GaussianMixture::five_component(1.0, 100.0)
}

pub fn data_mixture() -> GaussianMixture {
    GaussianMixture::five_component(1.0, 50.0)
}

/// Generate `n` graphs evenly split among the four structures
/// (round-robin so every prefix is balanced too).
pub fn generate(n: usize, rng: &mut Xoshiro256pp) -> Vec<TaskGraph> {
    let cost = cost_mixture();
    let data = data_mixture();
    (0..n)
        .map(|i| {
            let s = Structure::ALL[i % 4];
            build(s, i, &cost, &data, rng)
        })
        .collect()
}

/// Build one graph of the given structure with randomized shape params.
pub fn build(
    s: Structure,
    idx: usize,
    cost: &GaussianMixture,
    data: &GaussianMixture,
    rng: &mut Xoshiro256pp,
) -> TaskGraph {
    match s {
        Structure::OutTree => {
            let depth = rng.int_range(2, 3);
            let branch = rng.int_range(2, 3);
            out_tree(&format!("out_tree_{idx}"), depth, branch, cost, data, rng)
        }
        Structure::InTree => {
            let depth = rng.int_range(2, 3);
            let branch = rng.int_range(2, 3);
            in_tree(&format!("in_tree_{idx}"), depth, branch, cost, data, rng)
        }
        Structure::ForkJoin => {
            let stages = rng.int_range(1, 3);
            let width = rng.int_range(2, 4);
            fork_join(&format!("fork_join_{idx}"), stages, width, cost, data, rng)
        }
        Structure::Chain => {
            let len = rng.int_range(4, 10);
            chain(&format!("chain_{idx}"), len, cost, data, rng)
        }
    }
}

/// Complete `branch`-ary out-tree of the given depth (depth 0 = root only).
pub fn out_tree(
    name: &str,
    depth: usize,
    branch: usize,
    cost: &GaussianMixture,
    data: &GaussianMixture,
    rng: &mut Xoshiro256pp,
) -> TaskGraph {
    let mut b = GraphBuilder::new(name);
    let root = b.task(cost.sample(rng));
    let mut frontier = vec![root];
    for _ in 0..depth {
        let mut next = Vec::new();
        for &p in &frontier {
            for _ in 0..branch {
                let t = b.task(cost.sample(rng));
                b.edge(p, t, data.sample(rng));
                next.push(t);
            }
        }
        frontier = next;
    }
    b.build().expect("out_tree is a DAG by construction")
}

/// Mirror image: leaves feed upward into a single sink.
pub fn in_tree(
    name: &str,
    depth: usize,
    branch: usize,
    cost: &GaussianMixture,
    data: &GaussianMixture,
    rng: &mut Xoshiro256pp,
) -> TaskGraph {
    let mut b = GraphBuilder::new(name);
    let sink = b.task(cost.sample(rng));
    let mut frontier = vec![sink];
    for _ in 0..depth {
        let mut next = Vec::new();
        for &c in &frontier {
            for _ in 0..branch {
                let t = b.task(cost.sample(rng));
                b.edge(t, c, data.sample(rng));
                next.push(t);
            }
        }
        frontier = next;
    }
    b.build().expect("in_tree is a DAG by construction")
}

/// `stages` fork/join diamonds in sequence, each of the given width.
pub fn fork_join(
    name: &str,
    stages: usize,
    width: usize,
    cost: &GaussianMixture,
    data: &GaussianMixture,
    rng: &mut Xoshiro256pp,
) -> TaskGraph {
    let mut b = GraphBuilder::new(name);
    let mut join = b.task(cost.sample(rng));
    for _ in 0..stages {
        let mids: Vec<_> = (0..width).map(|_| b.task(cost.sample(rng))).collect();
        let next_join = b.task(cost.sample(rng));
        for &m in &mids {
            b.edge(join, m, data.sample(rng));
            b.edge(m, next_join, data.sample(rng));
        }
        join = next_join;
    }
    b.build().expect("fork_join is a DAG by construction")
}

/// Linear chain of `len` tasks.
pub fn chain(
    name: &str,
    len: usize,
    cost: &GaussianMixture,
    data: &GaussianMixture,
    rng: &mut Xoshiro256pp,
) -> TaskGraph {
    let mut b = GraphBuilder::new(name);
    let ids: Vec<_> = (0..len.max(1)).map(|_| b.task(cost.sample(rng))).collect();
    for w in ids.windows(2) {
        b.edge(w[0], w[1], data.sample(rng));
    }
    b.build().expect("chain is a DAG by construction")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(11)
    }

    #[test]
    fn out_tree_shape() {
        let g = out_tree("t", 2, 2, &cost_mixture(), &data_mixture(), &mut rng());
        assert_eq!(g.n_tasks(), 1 + 2 + 4);
        assert_eq!(g.n_edges(), 6);
        assert!(g.is_source(0));
        assert_eq!(g.height(), 3);
        // every non-root has exactly one parent
        for t in 1..g.n_tasks() {
            assert_eq!(g.predecessors(t).len(), 1);
        }
    }

    #[test]
    fn in_tree_shape() {
        let g = in_tree("t", 2, 3, &cost_mixture(), &data_mixture(), &mut rng());
        assert_eq!(g.n_tasks(), 1 + 3 + 9);
        assert!(g.is_sink(0));
        for t in 1..g.n_tasks() {
            assert_eq!(g.successors(t).len(), 1);
        }
    }

    #[test]
    fn fork_join_shape() {
        let g = fork_join("t", 2, 3, &cost_mixture(), &data_mixture(), &mut rng());
        // 1 + (3 + 1) * 2 tasks
        assert_eq!(g.n_tasks(), 9);
        assert_eq!(g.height(), 5);
        assert!(g.is_source(0));
    }

    #[test]
    fn chain_shape() {
        let g = chain("t", 6, &cost_mixture(), &data_mixture(), &mut rng());
        assert_eq!(g.n_tasks(), 6);
        assert_eq!(g.n_edges(), 5);
        assert_eq!(g.height(), 6);
    }

    #[test]
    fn generate_round_robins_structures() {
        let gs = generate(8, &mut rng());
        assert_eq!(gs.len(), 8);
        assert!(gs[0].name().starts_with("out_tree"));
        assert!(gs[1].name().starts_with("in_tree"));
        assert!(gs[2].name().starts_with("fork_join"));
        assert!(gs[3].name().starts_with("chain"));
        assert!(gs[4].name().starts_with("out_tree"));
    }

    #[test]
    fn weights_within_mixture_bounds() {
        let gs = generate(20, &mut rng());
        for g in &gs {
            for t in 0..g.n_tasks() {
                assert!((1.0..=100.0).contains(&g.cost(t)));
                for &(_, d) in g.successors(t) {
                    assert!((1.0..=50.0).contains(&d));
                }
            }
        }
    }

    #[test]
    fn reproducible() {
        let a = generate(12, &mut Xoshiro256pp::seed_from_u64(5));
        let b = generate(12, &mut Xoshiro256pp::seed_from_u64(5));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.n_tasks(), y.n_tasks());
            for t in 0..x.n_tasks() {
                assert_eq!(x.cost(t), y.cost(t));
            }
        }
    }
}
