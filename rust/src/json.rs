//! Minimal JSON substrate (no `serde` offline): a value model, a strict
//! recursive-descent parser, and a writer.
//!
//! Used by the config system, the artifact manifest reader
//! (`artifacts/manifest.json`), and the experiment result dumps.  Covers
//! the full JSON grammar except surrogate-pair escapes in strings
//! (sufficient for every file this library reads or writes).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    pub fn from_str(s: &str) -> Result<Value, ParseError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Convenience constructors for building result dumps.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}
pub fn num(x: f64) -> Value {
    Value::Number(x)
}
pub fn s(x: &str) -> Value {
    Value::String(x.to_string())
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("bad utf-8")),
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("bad utf-8"))?;
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(chunk);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("bad number"))
    }
}

// ----------------------------------------------------------------- writer

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_value(f, self)
    }
}

fn write_value(f: &mut fmt::Formatter<'_>, v: &Value) -> fmt::Result {
    match v {
        Value::Null => write!(f, "null"),
        Value::Bool(b) => write!(f, "{b}"),
        Value::Number(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                write!(f, "{}", *x as i64)
            } else {
                write!(f, "{x}")
            }
        }
        Value::String(s) => write_string(f, s),
        Value::Array(items) => {
            write!(f, "[")?;
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write_value(f, item)?;
            }
            write!(f, "]")
        }
        Value::Object(map) => {
            write!(f, "{{")?;
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write_string(f, k)?;
                write!(f, ":")?;
                write_value(f, val)?;
            }
            write!(f, "}}")
        }
    }
}

fn write_string(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::from_str("null").unwrap(), Value::Null);
        assert_eq!(Value::from_str("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::from_str("false").unwrap(), Value::Bool(false));
        assert_eq!(Value::from_str("42").unwrap(), Value::Number(42.0));
        assert_eq!(Value::from_str("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(
            Value::from_str("\"hi\\nthere\"").unwrap(),
            Value::String("hi\nthere".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::from_str(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parse_unicode_escapes_and_utf8() {
        let v = Value::from_str(r#""é café""#).unwrap();
        assert_eq!(v.as_str(), Some("é café"));
        let v = Value::from_str("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → wörld"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "\"abc", "{\"a\" 1}", "1 2", "{,}"] {
            assert!(Value::from_str(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"num":-7,"obj":{"k":"v"}}"#;
        let v = Value::from_str(src).unwrap();
        let out = v.to_string();
        assert_eq!(Value::from_str(&out).unwrap(), v);
        assert_eq!(out, src); // BTreeMap ordering makes this deterministic
    }

    #[test]
    fn roundtrip_escaped() {
        let v = obj(vec![("k\n", s("a\"b\\c\t"))]);
        let round = Value::from_str(&v.to_string()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"ranks":[{"n":32,"file":"ranks_n32.hlo.txt"}],"eft":[],"format":"hlo-text","neg":-1e30}"#;
        let v = Value::from_str(src).unwrap();
        assert_eq!(
            v.get("ranks").unwrap().as_array().unwrap()[0]
                .get("n")
                .unwrap()
                .as_usize(),
            Some(32)
        );
        assert_eq!(v.get("neg").unwrap().as_f64(), Some(-1e30));
    }
}
