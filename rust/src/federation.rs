//! Federated multi-cluster sharding: the 10⁴ → 10⁶-task scale layer.
//!
//! The monolithic reactive coordinator ([`crate::sim`]) replans a single
//! composite over the whole node pool — at 10⁶ tasks even the dirty-cone
//! refresh pays for one global belief.  This module partitions the node
//! pool into `S` clusters ("shards"), runs **one reactive coordinator
//! per shard**, and places each arriving graph on a shard through a
//! deterministic **admission layer** (best-fit on projected belief
//! load).  Straggler preemption and dirty-cone replans stay shard-local,
//! so the shards execute independently and parallelize across the
//! existing `--jobs` work queue; an admission-time **rebalancing pass**
//! may migrate a whole *pending* graph from the most loaded shard to the
//! least loaded one — a new preemption scope with its own cost
//! accounting ([`crate::metrics::PreemptionCost::migrations`]).
//!
//! ## The 1-shard differential oracle
//!
//! Every fast path in this repo keeps a reference implementation it must
//! match bit-for-bit; for the federation layer that oracle is the
//! monolithic coordinator itself.  With `shards = 1` the admission layer
//! places every graph on the single shard in arrival order, the
//! sub-network over all nodes in order *is* the original network
//! ([`Network::subnetwork`] copies speeds/links verbatim), and the
//! shard's [`DynamicProblem`] is field-for-field the original problem —
//! so the one shard coordinator reproduces the monolithic run
//! **bit-exactly**: schedules, event logs, every metric axis
//! (`rust/tests/federation.rs` pins this on all four datasets ×
//! [`SchedulerKind::EXTENDED`]).
//!
//! ## Determinism at `S > 1`
//!
//! Admission and migration are pure functions of the instance (arrival
//! order, graph costs, node speeds); shard runs are independent and each
//! is deterministic; the merged schedule/log remap is order-preserving
//! with ties broken by shard index.  The result is bit-identical at any
//! `jobs` count — same discipline as every sweep in
//! [`crate::experiments`].  Note that at `S > 1` realized durations
//! *differ* from the monolithic run (the [`crate::robustness`] noise is
//! keyed by shard-local graph index), which is fine: cross-shard A/B
//! comparisons are statistical, only the 1-shard pin is bitwise.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::coordinator::{DynamicProblem, Policy};
use crate::graph::Gid;
use crate::metrics::{MetricRow, PreemptionCost};
use crate::network::Network;
use crate::policy::PolicySpec;
use crate::schedule::{Assignment, Schedule};
use crate::schedulers::SchedulerKind;
use crate::sim::{
    FaultConfig, Faults, ReactiveCoordinator, SimConfig, SimLogEntry, SimLogKind, SimResult,
};
use crate::telemetry;

/// Default rebalancing trigger: migrate only when the most loaded
/// shard's remaining backlog exceeds `MIGRATE_FACTOR ×` the least loaded
/// shard's (hysteresis — near-balanced pools never churn).
pub const MIGRATE_FACTOR: f64 = 2.0;

/// One cross-shard rebalancing action: a whole pending graph moved from
/// an overloaded shard to an underloaded one at admission time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MigrationRecord {
    /// global graph index (into [`DynamicProblem::graphs`])
    pub graph: usize,
    pub from: usize,
    pub to: usize,
    /// admission instant that triggered the rebalance (the arrival time
    /// of the graph whose admission exposed the imbalance)
    pub time: f64,
}

/// Where the admission layer put every graph, and why-sized accounting.
#[derive(Clone, Debug, Default)]
pub struct AdmissionOutcome {
    /// `shard_of[gi]` = shard that ultimately runs global graph `gi`
    pub shard_of: Vec<usize>,
    /// every rebalancing action, in admission order
    pub migrations: Vec<MigrationRecord>,
}

/// A federated run of `S` shard-local reactive coordinators.
///
/// Construction mirrors the monolithic
/// [`ReactiveCoordinator::new`]`(policy, kind.make(sched_seed), cfg)` —
/// the same `(policy, kind, sched_seed, cfg)` with `shards = 1`
/// reproduces that coordinator bit-exactly (module docs).
#[derive(Clone, Debug)]
pub struct FederatedCoordinator {
    pub policy: Policy,
    pub kind: SchedulerKind,
    sched_seed: u64,
    cfg: SimConfig,
    shards: usize,
    jobs: usize,
    /// Optional preemption-policy controller.  When set, each shard
    /// coordinator is built through
    /// [`ReactiveCoordinator::with_policy`]`(…, spec.make())` instead of
    /// the built-in `cfg.reaction` trigger — the federated counterpart
    /// of the `dts policy` engine cells, and the construction the
    /// 1-shard oracle in `rust/tests/serve_snapshot.rs` pins against
    /// the monolithic `with_policy` run.
    spec: Option<PolicySpec>,
}

impl FederatedCoordinator {
    /// `shards` must be ≥ 1; it is further clamped to the node count at
    /// run time (a shard needs at least one node).
    pub fn new(
        policy: Policy,
        kind: SchedulerKind,
        sched_seed: u64,
        cfg: SimConfig,
        shards: usize,
    ) -> Self {
        assert!(shards >= 1, "need at least one shard");
        Self {
            policy,
            kind,
            sched_seed,
            cfg,
            shards,
            jobs: 1,
            spec: None,
        }
    }

    /// Drive every shard through a [`PolicySpec`] controller instead of
    /// the built-in `cfg.reaction` trigger.  Each shard gets a fresh
    /// controller instance (`spec.make()`), so controller state —
    /// AIMD windows, budget tokens, cooldowns — stays shard-local,
    /// matching the shard-local replan discipline.
    pub fn with_controller(mut self, spec: PolicySpec) -> Self {
        self.spec = Some(spec);
        self
    }

    /// Worker threads for the shard fan-out (default 1 = serial).  The
    /// result is bit-identical at any value — shards are independent and
    /// collected in shard order.
    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs.max(1);
        self
    }

    /// `S4 5P-HEFT σ0.30 L3@0.25` style label.  With a
    /// [`Self::with_controller`] spec the reaction slot shows the
    /// controller's label instead (`S4 5P-HEFT σ0.30 D3@0.25`).
    pub fn label(&self) -> String {
        let reaction = match &self.spec {
            Some(spec) => spec.label(),
            None => self.cfg.reaction.label(),
        };
        format!(
            "S{} {}-{} σ{:.2} {}",
            self.shards,
            self.policy.label(),
            self.kind.name(),
            self.cfg.noise_std,
            reaction
        )
    }

    /// Contiguous node partition: shard `i` of `s` gets global nodes
    /// `[i·n/s, (i+1)·n/s)` — every node in exactly one shard, sizes
    /// differing by at most one.
    pub fn partition_nodes(n_nodes: usize, shards: usize) -> Vec<Vec<usize>> {
        let s = shards.clamp(1, n_nodes.max(1));
        (0..s)
            .map(|i| (i * n_nodes / s..(i + 1) * n_nodes / s).collect())
            .collect()
    }

    /// The deterministic admission + rebalancing pass (pure planning —
    /// runs before any shard simulation, so a migrated graph has never
    /// executed anything and no realized task is ever re-executed).
    ///
    /// Best-fit placement: each shard keeps a projected **backlog**
    /// clock (the finish time of all admitted work under an ideal
    /// capacity model, `est = Σ cost / Σ speed`); an arriving graph goes
    /// to the shard minimizing `max(backlog, arrival) + est`, ties to
    /// the lowest shard index.  Heavy graphs therefore land on whichever
    /// cluster frees up first (effectively dedicating it), light ones
    /// pack into the gaps.
    ///
    /// Rebalancing is **work stealing**: after each admission, if the
    /// most loaded shard's *remaining* backlog exceeds
    /// [`MIGRATE_FACTOR`] × the least loaded shard's, the overloaded
    /// shard's most recently admitted graph migrates — provided it is
    /// still **pending** (projected start ≥ now) and would *start
    /// strictly earlier* on the drained shard.  Best-fit already
    /// minimized each graph's projected finish at admission, so the
    /// stolen graph trades a possibly later finish (the drained cluster
    /// may be slower) for an earlier start — a responsiveness move, the
    /// same trade the dispatched-prefix rule makes shard-locally.  At
    /// most one move per arrival, so the pass is O(graphs × shards).
    pub fn admit(prob: &DynamicProblem, shard_nodes: &[Vec<usize>]) -> AdmissionOutcome {
        Self::admit_with_faults(prob, shard_nodes, &FaultConfig::NONE)
    }

    /// [`Self::admit`] with a fault model in view: under a crash model
    /// each shard's projected capacity is discounted by its nodes'
    /// availability, computed from the **same pure crash/recovery
    /// windows the shard simulators will draw** — so a cluster facing
    /// long outages attracts proportionally less work and sheds graphs
    /// to its peers at admission time.  A pure function of the instance
    /// and `(fault_seed, node)`: deterministic, `--jobs`-independent,
    /// and with [`FaultConfig::NONE`] (or a Degrade model, which costs
    /// time but not whole nodes) every discount is exactly 1.0 — the
    /// placement is then bit-identical to [`Self::admit`].
    pub fn admit_with_faults(
        prob: &DynamicProblem,
        shard_nodes: &[Vec<usize>],
        fc: &FaultConfig,
    ) -> AdmissionOutcome {
        let s = shard_nodes.len();
        let faults = Faults::new(*fc);
        let capacity: Vec<f64> = shard_nodes
            .iter()
            .map(|nodes| {
                nodes
                    .iter()
                    .map(|&v| prob.network.speed(v) * node_availability(&faults, v))
                    .sum()
            })
            .collect();
        // per-shard admitted stack: (global graph idx, est_start, est_time)
        let mut admitted: Vec<Vec<(usize, f64, f64)>> = vec![Vec::new(); s];
        let mut backlog = vec![0.0f64; s];
        let mut out = AdmissionOutcome {
            shard_of: vec![0; prob.graphs.len()],
            migrations: Vec::new(),
        };
        for (gi, (arrival, g)) in prob.graphs.iter().enumerate() {
            let arrival = *arrival;
            // best fit on projected finish
            let mut best = 0usize;
            let mut best_fin = f64::INFINITY;
            for (si, cap) in capacity.iter().enumerate() {
                let fin = backlog[si].max(arrival) + g.total_cost() / cap;
                if fin < best_fin {
                    best_fin = fin;
                    best = si;
                }
            }
            let est_start = backlog[best].max(arrival);
            admitted[best].push((gi, est_start, g.total_cost() / capacity[best]));
            backlog[best] = best_fin;
            out.shard_of[gi] = best;
            telemetry::counter_inc(telemetry::Counter::FedAdmissions);

            if s < 2 {
                continue;
            }
            // rebalance: remaining backlog = work not yet started under
            // the projection
            let rem = |si: usize| (backlog[si] - arrival).max(0.0);
            let (mut hi, mut lo) = (0usize, 0usize);
            for si in 1..s {
                if rem(si) > rem(hi) {
                    hi = si;
                }
                if rem(si) < rem(lo) {
                    lo = si;
                }
            }
            if hi == lo || rem(hi) <= MIGRATE_FACTOR * rem(lo) {
                continue;
            }
            // a concrete steal candidate pair (overloaded → drained) is
            // evaluated from here on, whether or not the move happens
            telemetry::counter_inc(telemetry::Counter::FedStealAttempts);
            // the most recent admission on `hi` migrates iff still
            // pending (projected start not yet reached — it has executed
            // nothing, so nothing realized is ever re-run) and it gains
            // a strictly earlier start on the drained shard
            let Some(&(mg, est_start, est_time)) = admitted[hi].last() else {
                continue;
            };
            if est_start < arrival {
                continue;
            }
            let new_est = prob.graphs[mg].1.total_cost() / capacity[lo];
            let new_start = backlog[lo].max(arrival);
            if new_start >= est_start {
                continue;
            }
            admitted[hi].pop();
            backlog[hi] -= est_time;
            admitted[lo].push((mg, new_start, new_est));
            backlog[lo] = new_start + new_est;
            out.shard_of[mg] = lo;
            telemetry::counter_inc(telemetry::Counter::FedMigrations);
            out.migrations.push(MigrationRecord {
                graph: mg,
                from: hi,
                to: lo,
                time: arrival,
            });
        }
        out
    }

    /// Run the federated simulation: partition → admit → one reactive
    /// coordinator per shard (fanned over `jobs` threads) → merge the
    /// shard schedules/logs back into the global index space.
    pub fn run(&self, prob: &DynamicProblem) -> FederationResult {
        let n_nodes = prob.network.n_nodes();
        let shard_nodes = Self::partition_nodes(n_nodes, self.shards);
        let s = shard_nodes.len();
        let admission = Self::admit_with_faults(prob, &shard_nodes, &self.cfg.faults);

        // Per-shard problems.  Graphs are pushed in global arrival order
        // (prob.graphs is arrival-sorted and gi ascends), so the stable
        // re-sort inside DynamicProblem::new is the identity and
        // shard_graphs[s][local] is the global index of local graph
        // `local` — at S = 1 the problem is field-for-field the original.
        let mut shard_graphs: Vec<Vec<usize>> = vec![Vec::new(); s];
        let mut shard_lists: Vec<Vec<(f64, crate::graph::TaskGraph)>> = vec![Vec::new(); s];
        for (gi, (arrival, g)) in prob.graphs.iter().enumerate() {
            let si = admission.shard_of[gi];
            shard_graphs[si].push(gi);
            shard_lists[si].push((*arrival, g.clone()));
        }
        let shard_probs: Vec<DynamicProblem> = shard_nodes
            .iter()
            .zip(shard_lists)
            .map(|(nodes, graphs)| DynamicProblem::new(prob.network.subnetwork(nodes), graphs))
            .collect();

        // Shard fan-out: same deterministic work-queue construction as
        // the sweeps — an atomic cursor, results re-collected in shard
        // order, so any jobs count yields the identical result.  Each
        // shard's coordinator records into its own (thread-local)
        // telemetry registry; `run_shard` snapshots it, and the
        // registries travel with the results to be merged shard-ordered
        // in [`merge`].
        let mut results: Vec<Option<(SimResult, telemetry::Telemetry)>> =
            (0..s).map(|_| None).collect();
        let workers = self.jobs.min(s).max(1);
        if workers == 1 {
            // serial shards share this thread's registry — park what the
            // admission layer (and any caller) already recorded so each
            // shard's take() isolates exactly its own activity
            let parked = telemetry::take();
            for (si, sp) in shard_probs.iter().enumerate() {
                results[si] = Some(self.run_shard(sp, shard_nodes[si][0]));
            }
            telemetry::absorb(&parked);
        } else {
            let tele_on = telemetry::enabled();
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..workers)
                    .map(|_| {
                        scope.spawn(|| {
                            // fresh thread, fresh registry; inherit the
                            // spawner's enable gate
                            telemetry::set_enabled(tele_on);
                            let mut done: Vec<(usize, (SimResult, telemetry::Telemetry))> =
                                Vec::new();
                            loop {
                                let si = next.fetch_add(1, Ordering::Relaxed);
                                if si >= s {
                                    break;
                                }
                                done.push((si, self.run_shard(&shard_probs[si], shard_nodes[si][0])));
                            }
                            done
                        })
                    })
                    .collect();
                for h in handles {
                    for (si, r) in h.join().expect("federation shard worker panicked") {
                        results[si] = Some(r);
                    }
                }
            });
        }
        let (per_shard, shard_tele): (Vec<SimResult>, Vec<telemetry::Telemetry>) = results
            .into_iter()
            .map(|r| r.expect("shard not simulated"))
            .unzip();

        merge(prob, shard_nodes, shard_graphs, admission, per_shard, shard_tele)
    }

    /// `node_base` is the shard's first **global** node id (partitions
    /// are contiguous): shifting the fault identity space by it makes
    /// the shard draw, for its local node `v`, exactly the windows the
    /// monolithic run draws for global node `base + v` — crash instants
    /// stay a pure function of `(fault_seed, global node)` however the
    /// pool is sharded.
    fn run_shard(&self, sp: &DynamicProblem, node_base: usize) -> (SimResult, telemetry::Telemetry) {
        let mut cfg = self.cfg;
        cfg.faults.node_base += node_base;
        let mut rc = match &self.spec {
            Some(spec) => ReactiveCoordinator::with_policy(
                self.policy,
                self.kind.make(self.sched_seed),
                cfg,
                spec.make(),
            ),
            None => {
                ReactiveCoordinator::new(self.policy, self.kind.make(self.sched_seed), cfg)
            }
        };
        let res = rc.run(sp);
        // snapshot-and-reset: the shard's registry delta rides back with
        // its result for the deterministic shard-ordered merge
        (res, telemetry::take())
    }
}

/// Remap one shard-local log entry into the global index space.
fn remap_kind(kind: SimLogKind, nodes: &[usize], graphs: &[usize]) -> SimLogKind {
    let rg = |gid: Gid| Gid::new(graphs[gid.graph as usize], gid.task as usize);
    match kind {
        SimLogKind::Arrival { graph } => SimLogKind::Arrival {
            graph: graphs[graph],
        },
        SimLogKind::Start { gid, node } => SimLogKind::Start {
            gid: rg(gid),
            node: nodes[node],
        },
        SimLogKind::Finish {
            gid,
            node,
            lateness,
        } => SimLogKind::Finish {
            gid: rg(gid),
            node: nodes[node],
            lateness,
        },
        SimLogKind::Replan {
            straggler,
            n_reverted,
            n_pending,
        } => SimLogKind::Replan {
            straggler,
            n_reverted,
            n_pending,
        },
        SimLogKind::NodeDown { node, wasted } => SimLogKind::NodeDown {
            node: nodes[node],
            wasted,
        },
        SimLogKind::NodeUp { node, downtime } => SimLogKind::NodeUp {
            node: nodes[node],
            downtime,
        },
        SimLogKind::Kill { gid, node, wasted } => SimLogKind::Kill {
            gid: rg(gid),
            node: nodes[node],
            wasted,
        },
    }
}

/// Long-run healthy fraction of a **global** node under the drawn crash
/// windows: 1.0 without a crash model, otherwise measured over the
/// node's first few jittered windows — a pure function of
/// `(fault_seed, node)`, so admission stays deterministic at any
/// `--jobs` count.
fn node_availability(faults: &Faults, node: usize) -> f64 {
    const WINDOWS: usize = 4;
    let Some((_, horizon)) = faults.window(node, WINDOWS - 1) else {
        return 1.0; // None / Degrade: whole nodes are never lost
    };
    let mut downtime = 0.0;
    for k in 0..WINDOWS {
        let (down, up) = faults.window(node, k).expect("window below horizon");
        downtime += up - down;
    }
    if horizon > 0.0 {
        ((horizon - downtime) / horizon).max(0.0)
    } else {
        1.0
    }
}

/// Merge shard results into the global index space: schedule assignments
/// and log entries remap `(local graph, local node)` →
/// `(global graph, global node)` with start/finish bits untouched; logs
/// k-way-merge by `(time, shard index)`, preserving each shard's
/// internal order — at S = 1 both are the shard's own values verbatim.
fn merge(
    prob: &DynamicProblem,
    shard_nodes: Vec<Vec<usize>>,
    shard_graphs: Vec<Vec<usize>>,
    admission: AdmissionOutcome,
    per_shard: Vec<SimResult>,
    shard_tele: Vec<telemetry::Telemetry>,
) -> FederationResult {
    // Deterministic telemetry merge: element-wise addition in fixed
    // enum-key order, shards absorbed in shard order into the calling
    // thread's registry.  Counter totals are independent of the worker
    // fan-out (addition commutes and per-shard counts are
    // deterministic); the fixed order makes the *process* reproducible
    // too, which is what the merge-determinism test pins.
    for t in &shard_tele {
        telemetry::absorb(t);
    }
    let mut schedule = Schedule::new(prob.network.n_nodes());
    for (si, res) in per_shard.iter().enumerate() {
        let nodes = &shard_nodes[si];
        let graphs = &shard_graphs[si];
        for (gid, a) in res.schedule.iter() {
            schedule.assign(
                Gid::new(graphs[gid.graph as usize], gid.task as usize),
                Assignment {
                    node: nodes[a.node],
                    start: a.start,
                    finish: a.finish,
                },
            );
        }
    }

    // stable k-way merge of the (time-ordered) shard logs
    let total_len: usize = per_shard.iter().map(|r| r.log.len()).sum();
    let mut log: Vec<SimLogEntry> = Vec::with_capacity(total_len);
    let mut cursors = vec![0usize; per_shard.len()];
    for _ in 0..total_len {
        let mut best: Option<(f64, usize)> = None;
        for (si, res) in per_shard.iter().enumerate() {
            if cursors[si] >= res.log.len() {
                continue;
            }
            let t = res.log[cursors[si]].time;
            // strict < keeps ties on the lowest shard index
            let better = match best {
                Some((bt, _)) => t < bt,
                None => true,
            };
            if better {
                best = Some((t, si));
            }
        }
        let (_, si) = best.expect("log merge exhausted early");
        let e = per_shard[si].log[cursors[si]];
        cursors[si] += 1;
        log.push(SimLogEntry {
            time: e.time,
            kind: remap_kind(e.kind, &shard_nodes[si], &shard_graphs[si]),
        });
    }

    FederationResult {
        schedule,
        log,
        shard_nodes,
        shard_graphs,
        admission,
        sched_runtime_s: per_shard.iter().map(|r| r.sched_runtime_s).sum(),
        replan_wall_s: per_shard.iter().map(|r| r.replan_wall_s).sum(),
        refresh_wall_s: per_shard.iter().map(|r| r.refresh_wall_s).sum(),
        bookkeep_wall_s: per_shard.iter().map(|r| r.bookkeep_wall_s).sum(),
        per_shard,
    }
}

/// Outcome of a federated run: the merged global execution plus the
/// per-shard [`SimResult`]s and the admission/migration record.
#[derive(Clone, Debug)]
pub struct FederationResult {
    /// realized execution in **global** graph/node indices — replay- and
    /// metric-compatible with the original [`DynamicProblem`]
    pub schedule: Schedule,
    /// merged realized-event trace, `(time, shard)`-ordered, remapped to
    /// global indices
    pub log: Vec<SimLogEntry>,
    /// global node ids of each shard's cluster
    pub shard_nodes: Vec<Vec<usize>>,
    /// global graph ids of each shard's admitted graphs, in shard-local
    /// graph order (`shard_graphs[s][local] = global`)
    pub shard_graphs: Vec<Vec<usize>>,
    /// where admission put every graph + the migration trail
    pub admission: AdmissionOutcome,
    /// Σ shard base-heuristic wall time (the §V.E runtime axis)
    pub sched_runtime_s: f64,
    /// Σ shard replan-pass wall time
    pub replan_wall_s: f64,
    /// Σ shard belief-refresh phase wall time
    pub refresh_wall_s: f64,
    /// Σ shard bookkeeping-remainder phase wall time
    pub bookkeep_wall_s: f64,
    /// each shard coordinator's own result, in shard order
    pub per_shard: Vec<SimResult>,
}

impl FederationResult {
    /// Metric row of the merged global execution (same computation the
    /// monolithic [`SimResult::metrics`] performs).
    pub fn metrics(&self, prob: &DynamicProblem) -> MetricRow {
        let mut row = MetricRow::compute(
            &self.schedule,
            &prob.graphs,
            &prob.network,
            self.sched_runtime_s,
        );
        // fault accounting is runtime state (killed attempts leave no
        // slot in the merged schedule) — summed across shards, all-zero
        // when faults are off
        row.wasted_work_s = self.wasted_work_s();
        row.n_reexecuted = self.n_reexecuted() as f64;
        row.mean_recovery_latency = self.mean_recovery_latency();
        row
    }

    /// Σ shard simulated seconds lost to crash-killed attempts.
    pub fn wasted_work_s(&self) -> f64 {
        self.per_shard.iter().map(|r| r.wasted_work_s).sum()
    }

    /// Σ shard running attempts killed by crashes.
    pub fn n_killed(&self) -> usize {
        self.per_shard.iter().map(|r| r.n_killed).sum()
    }

    /// Σ shard tasks that completed on a retry after a kill.
    pub fn n_reexecuted(&self) -> usize {
        self.per_shard.iter().map(|r| r.n_reexecuted).sum()
    }

    /// Σ shard failure-triggered replans.
    pub fn n_failure_replans(&self) -> usize {
        self.per_shard.iter().map(|r| r.n_failure_replans()).sum()
    }

    /// Mean node downtime per recovery across the whole pool (0.0 when
    /// no node ever recovered).
    pub fn mean_recovery_latency(&self) -> f64 {
        let n: usize = self.per_shard.iter().map(|r| r.n_recoveries).sum();
        if n == 0 {
            0.0
        } else {
            self.per_shard
                .iter()
                .map(|r| r.recovery_total_s)
                .sum::<f64>()
                / n as f64
        }
    }

    pub fn n_replans(&self) -> usize {
        self.per_shard.iter().map(|r| r.n_replans()).sum()
    }

    pub fn n_straggler_replans(&self) -> usize {
        self.per_shard.iter().map(|r| r.n_straggler_replans()).sum()
    }

    pub fn n_reverted_total(&self) -> usize {
        self.per_shard.iter().map(|r| r.n_reverted_total()).sum()
    }

    /// Peak event-queue length across shards (each shard has its own
    /// queue; the max is the binding reservation).
    pub fn events_peak(&self) -> usize {
        self.per_shard.iter().map(|r| r.events_peak).max().unwrap_or(0)
    }

    /// Σ heap allocations inside replan passes across shards.
    pub fn replan_allocs(&self) -> u64 {
        self.per_shard.iter().map(|r| r.replan_allocs).sum()
    }

    /// Preemption-cost accounting summed across shards, plus the
    /// federation layer's own scope: cross-shard graph migrations.
    pub fn preemption_cost(&self) -> PreemptionCost {
        PreemptionCost {
            replans: self.n_replans(),
            straggler_replans: self.n_straggler_replans(),
            reverted_tasks: self.n_reverted_total(),
            migrations: self.admission.migrations.len(),
            replan_wall_s: self.replan_wall_s,
            refresh_wall_s: self.refresh_wall_s,
            heuristic_wall_s: self.sched_runtime_s,
            bookkeep_wall_s: self.bookkeep_wall_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn one_task(name: &str, cost: f64) -> crate::graph::TaskGraph {
        let mut b = GraphBuilder::new(name);
        b.task(cost);
        b.build().unwrap()
    }

    #[test]
    fn partition_covers_every_node_once() {
        for (n, s) in [(6usize, 1usize), (6, 2), (6, 4), (7, 3), (3, 8), (1, 1)] {
            let parts = FederatedCoordinator::partition_nodes(n, s);
            assert!(parts.len() <= s.max(1));
            let mut seen = vec![false; n];
            for part in &parts {
                assert!(!part.is_empty(), "n={n} s={s}: empty shard");
                for &v in part {
                    assert!(!seen[v], "node {v} in two shards");
                    seen[v] = true;
                }
            }
            assert!(seen.iter().all(|&b| b), "n={n} s={s}: node uncovered");
        }
    }

    #[test]
    fn admission_is_best_fit_and_conserving() {
        // 4 homogeneous nodes, 2 shards of capacity 2 each; three graphs
        // arriving together: the heavy one gets a shard to itself.
        let prob = DynamicProblem::new(
            Network::homogeneous(4),
            vec![
                (0.0, one_task("heavy", 40.0)),
                (0.0, one_task("light-a", 1.0)),
                (0.0, one_task("light-b", 1.0)),
            ],
        );
        let nodes = FederatedCoordinator::partition_nodes(4, 2);
        let adm = FederatedCoordinator::admit(&prob, &nodes);
        assert_eq!(adm.shard_of.len(), 3);
        assert_eq!(adm.shard_of[0], 0, "first graph takes the first shard");
        assert_eq!(adm.shard_of[1], 1, "light work avoids the loaded shard");
        assert_eq!(adm.shard_of[2], 1, "shard 1 still finishes far earlier");
    }

    #[test]
    fn migration_steals_pending_graph_for_idle_shard() {
        // Fast shard (speed 4) vs slow shard (speed 1): best fit stacks
        // both heavies on the fast cluster, leaving the slow one idle —
        // the rebalancer steals the still-pending second heavy so it
        // starts at 0 instead of queueing to 10.
        let net = Network::new(vec![4.0, 1.0], vec![0.0, 1.0, 1.0, 0.0]);
        let prob = DynamicProblem::new(
            net,
            vec![(0.0, one_task("h0", 40.0)), (0.0, one_task("h1", 40.0))],
        );
        let nodes = FederatedCoordinator::partition_nodes(2, 2);
        let adm = FederatedCoordinator::admit(&prob, &nodes);
        assert_eq!(adm.shard_of, vec![0, 1]);
        assert_eq!(adm.migrations.len(), 1);
        let m = adm.migrations[0];
        assert_eq!((m.graph, m.from, m.to), (1, 0, 1));
        assert_eq!(m.time, 0.0);
    }

    #[test]
    fn migration_never_steals_started_work() {
        // Same pool, but the second heavy arrives after the first one's
        // projected span: nothing is pending on the loaded shard when
        // the imbalance shows, so no migration fires.
        let net = Network::new(vec![4.0, 1.0], vec![0.0, 1.0, 1.0, 0.0]);
        let prob = DynamicProblem::new(
            net,
            vec![(0.0, one_task("h0", 40.0)), (5.0, one_task("h1", 40.0))],
        );
        let nodes = FederatedCoordinator::partition_nodes(2, 2);
        let adm = FederatedCoordinator::admit(&prob, &nodes);
        // h1 lands on the fast shard behind h0 (fin 20 < 45 on slow);
        // at now = 5, h0 has started (est_start 0 < 5) and h1 is the
        // stack top with est_start 10 ≥ 5 — but stealing it would start
        // it at max(0, 5) = 5 on the slow shard only if that beats 10:
        // it does, so exactly the pending graph moves, never h0.
        for m in &adm.migrations {
            assert_ne!(m.graph, 0, "started work is never migrated");
            assert_eq!(adm.shard_of[m.graph], m.to);
        }
        // conservation: every graph on exactly one shard
        assert!(adm.shard_of.iter().all(|&s| s < 2));
    }

    #[test]
    fn federated_run_covers_all_tasks_and_replays() {
        use crate::workloads::Dataset;
        let prob = Dataset::Synthetic.instance(10, 3);
        let cfg = SimConfig {
            noise_std: 0.3,
            noise_seed: 7,
            reaction: crate::sim::Reaction::LastK {
                k: 3,
                threshold: 0.25,
            },
            record_frozen: false,
            full_refresh: false,
            faults: crate::sim::FaultConfig::NONE,
        };
        let fed = FederatedCoordinator::new(Policy::LastK(5), SchedulerKind::Heft, 1, cfg, 3)
            .with_jobs(2);
        assert_eq!(fed.label(), "S3 5P-HEFT σ0.30 L3@0.25");
        let res = fed.run(&prob);
        assert_eq!(res.schedule.n_assigned(), prob.total_tasks());
        let rep = crate::sim::replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(rep.errors.is_empty(), "{:?}", &rep.errors[..rep.errors.len().min(3)]);
        let cost = res.preemption_cost();
        assert_eq!(cost.migrations, res.admission.migrations.len());
        assert_eq!(cost.replans, res.n_replans());
    }
}
