//! Task graphs: the `G_i = (T_i, D_i)` of the paper's problem definition.
//!
//! A [`TaskGraph`] is a DAG whose vertices carry compute costs `c(t)` and
//! whose edges carry data sizes `c(t, t')`.  Graphs are immutable after
//! construction via [`GraphBuilder`], which validates acyclicity.  In the
//! dynamic problem many graphs coexist; a task is globally identified by a
//! [`Gid`] (graph index, task index).

use std::fmt;

/// Task index within one graph.
pub type TaskId = usize;

/// Global task identity across the dynamic problem's graph collection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Gid {
    pub graph: u32,
    pub task: u32,
}

impl Gid {
    pub fn new(graph: usize, task: usize) -> Self {
        Self {
            graph: graph as u32,
            task: task as u32,
        }
    }
}

impl fmt::Display for Gid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "g{}t{}", self.graph, self.task)
    }
}

/// An immutable weighted DAG.
#[derive(Clone, Debug)]
pub struct TaskGraph {
    name: String,
    cost: Vec<f64>,
    /// successor adjacency: `succ[t] = [(child, data_size), ...]`
    succ: Vec<Vec<(TaskId, f64)>>,
    /// predecessor adjacency (mirror of `succ`)
    pred: Vec<Vec<(TaskId, f64)>>,
    /// cached topological order (tasks were validated acyclic at build)
    topo: Vec<TaskId>,
    /// graph-level importance weight for the weighted fairness metrics
    /// (default 1.0 = every graph counts equally)
    weight: f64,
    /// absolute completion deadline for the deadline metrics
    /// (`None` = no deadline; the paper's setting)
    deadline: Option<f64>,
}

impl TaskGraph {
    pub fn name(&self) -> &str {
        &self.name
    }
    /// Graph-level importance weight (see
    /// [`crate::metrics::weighted_mean`]); 1.0 unless set.
    pub fn weight(&self) -> f64 {
        self.weight
    }
    /// Override the importance weight (`> 0`, finite); used by scenario
    /// builders that prioritize some arrivals over others.
    pub fn set_weight(&mut self, w: f64) {
        assert!(w > 0.0 && w.is_finite(), "graph weight must be positive: {w}");
        self.weight = w;
    }
    /// Absolute completion deadline, if one was assigned (see
    /// [`crate::metrics::deadline_summary`] and
    /// [`crate::workloads::DeadlineModel`]).
    pub fn deadline(&self) -> Option<f64> {
        self.deadline
    }
    /// Assign an absolute completion deadline (finite); used by scenario
    /// builders — the deadline metrics treat the graph as tardy by
    /// `max(0, finish − deadline)`.
    pub fn set_deadline(&mut self, d: f64) {
        assert!(d.is_finite(), "graph deadline must be finite: {d}");
        self.deadline = Some(d);
    }
    pub fn n_tasks(&self) -> usize {
        self.cost.len()
    }
    pub fn n_edges(&self) -> usize {
        self.succ.iter().map(Vec::len).sum()
    }
    /// Compute cost `c(t)`.
    pub fn cost(&self, t: TaskId) -> f64 {
        self.cost[t]
    }
    pub fn successors(&self, t: TaskId) -> &[(TaskId, f64)] {
        &self.succ[t]
    }
    pub fn predecessors(&self, t: TaskId) -> &[(TaskId, f64)] {
        &self.pred[t]
    }
    pub fn is_source(&self, t: TaskId) -> bool {
        self.pred[t].is_empty()
    }
    pub fn is_sink(&self, t: TaskId) -> bool {
        self.succ[t].is_empty()
    }
    /// A valid topological order (cached at construction).
    pub fn topo_order(&self) -> &[TaskId] {
        &self.topo
    }
    /// Sum of all task compute costs.
    pub fn total_cost(&self) -> f64 {
        self.cost.iter().sum()
    }
    /// Sum of all edge data sizes.
    pub fn total_data(&self) -> f64 {
        self.succ
            .iter()
            .flat_map(|es| es.iter().map(|&(_, d)| d))
            .sum()
    }

    /// Length (in vertices) of the longest path — bounds the rank
    /// fixed-point iteration count.
    pub fn height(&self) -> usize {
        let mut h = vec![1usize; self.n_tasks()];
        for &t in self.topo.iter().rev() {
            for &(c, _) in &self.succ[t] {
                h[t] = h[t].max(1 + h[c]);
            }
        }
        h.into_iter().max().unwrap_or(0)
    }

    /// Scale every edge's data size by `factor` (used for CCR control).
    pub fn scale_edges(&mut self, factor: f64) {
        for es in &mut self.succ {
            for e in es.iter_mut() {
                e.1 *= factor;
            }
        }
        for es in &mut self.pred {
            for e in es.iter_mut() {
                e.1 *= factor;
            }
        }
    }

    /// Graphviz DOT rendering (debugging / docs).
    pub fn to_dot(&self) -> String {
        let mut out = format!("digraph \"{}\" {{\n", self.name);
        for t in 0..self.n_tasks() {
            out.push_str(&format!("  t{} [label=\"t{} ({:.1})\"];\n", t, t, self.cost[t]));
        }
        for t in 0..self.n_tasks() {
            for &(c, d) in &self.succ[t] {
                out.push_str(&format!("  t{} -> t{} [label=\"{:.1}\"];\n", t, c, d));
            }
        }
        out.push_str("}\n");
        out
    }
}

/// Compressed-sparse-row adjacency arena (§Perf, PR 6): one flat
/// `edges`/`comm` pair shared by every row, with `offsets[i]..offsets[i+1]`
/// delimiting row `i`.  Rebuilding is clear-and-push, so a warm arena
/// reaches a steady state where refills allocate nothing — the
/// `CompositeWorkspace` keeps three of these (pending preds, fixed preds
/// via [`FixedArena`], and succs) alive across arrivals/replans.
///
/// Rows are closed explicitly: push the row's edges, then `close_row()`.
/// `offsets` therefore has `n_rows + 1` entries and `offsets[0] == 0`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GraphArena {
    /// row boundaries: row `i` spans `offsets[i]..offsets[i+1]`
    pub offsets: Vec<u32>,
    /// flat endpoint column (task indices)
    pub edges: Vec<u32>,
    /// flat data-size / comm-cost column, parallel to `edges`
    pub comm: Vec<f64>,
}

impl GraphArena {
    /// Reset to an empty arena with zero rows, retaining capacity.
    pub fn reset(&mut self) {
        self.offsets.clear();
        self.offsets.push(0);
        self.edges.clear();
        self.comm.clear();
    }

    /// Append one edge to the currently open row.
    #[inline]
    pub fn push(&mut self, edge: u32, comm: f64) {
        self.edges.push(edge);
        self.comm.push(comm);
    }

    /// Close the current row (must be called once per row, in row order).
    #[inline]
    pub fn close_row(&mut self) {
        self.offsets.push(self.edges.len() as u32);
    }

    pub fn n_rows(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Number of edges in row `i`.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        (self.offsets[i + 1] - self.offsets[i]) as usize
    }

    /// Row `i` as parallel `(endpoints, comm)` slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let a = self.offsets[i] as usize;
        let b = self.offsets[i + 1] as usize;
        (&self.edges[a..b], &self.comm[a..b])
    }
}

/// CSR arena for *fixed* (committed) predecessors: each entry carries the
/// committed parent's `(node, finish, data)` triple instead of a task
/// index.  Same row protocol as [`GraphArena`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FixedArena {
    pub offsets: Vec<u32>,
    pub node: Vec<u32>,
    pub finish: Vec<f64>,
    pub data: Vec<f64>,
}

impl FixedArena {
    /// Reset to an empty arena with zero rows, retaining capacity.
    pub fn reset(&mut self) {
        self.offsets.clear();
        self.offsets.push(0);
        self.node.clear();
        self.finish.clear();
        self.data.clear();
    }

    #[inline]
    pub fn push(&mut self, node: u32, finish: f64, data: f64) {
        self.node.push(node);
        self.finish.push(finish);
        self.data.push(data);
    }

    #[inline]
    pub fn close_row(&mut self) {
        self.offsets.push(self.node.len() as u32);
    }

    pub fn n_rows(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Row `i` as parallel `(node, finish, data)` slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64], &[f64]) {
        let a = self.offsets[i] as usize;
        let b = self.offsets[i + 1] as usize;
        (
            &self.node[a..b],
            &self.finish[a..b],
            &self.data[a..b],
        )
    }
}

/// Builder enforcing DAG validity.
#[derive(Clone, Debug)]
pub struct GraphBuilder {
    name: String,
    cost: Vec<f64>,
    edges: Vec<(TaskId, TaskId, f64)>,
    weight: f64,
}

/// Errors surfaced while assembling a graph.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphError {
    Cycle,
    BadTask(TaskId),
    NonPositiveCost(f64),
    NegativeData(f64),
    SelfLoop(TaskId),
    DuplicateEdge(TaskId, TaskId),
    NonPositiveWeight(f64),
}

impl fmt::Display for GraphError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphError::Cycle => write!(f, "graph contains a cycle"),
            GraphError::BadTask(t) => write!(f, "unknown task id {t}"),
            GraphError::NonPositiveCost(c) => write!(f, "non-positive task cost {c}"),
            GraphError::NegativeData(d) => write!(f, "negative edge data size {d}"),
            GraphError::SelfLoop(t) => write!(f, "self-loop on task {t}"),
            GraphError::DuplicateEdge(u, v) => write!(f, "duplicate edge {u}->{v}"),
            GraphError::NonPositiveWeight(w) => write!(f, "non-positive graph weight {w}"),
        }
    }
}
impl std::error::Error for GraphError {}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            cost: Vec::new(),
            edges: Vec::new(),
            weight: 1.0,
        }
    }

    /// Set the graph-level importance weight (`> 0`, finite; default 1.0).
    pub fn weight(&mut self, w: f64) -> &mut Self {
        self.weight = w;
        self
    }

    /// Add a task with compute cost `c(t) > 0`; returns its id.
    pub fn task(&mut self, cost: f64) -> TaskId {
        self.cost.push(cost);
        self.cost.len() - 1
    }

    /// Add a dependency `(u, v)` with data size `data >= 0`.
    pub fn edge(&mut self, u: TaskId, v: TaskId, data: f64) -> &mut Self {
        self.edges.push((u, v, data));
        self
    }

    pub fn build(self) -> Result<TaskGraph, GraphError> {
        let n = self.cost.len();
        if !(self.weight > 0.0 && self.weight.is_finite()) {
            return Err(GraphError::NonPositiveWeight(self.weight));
        }
        for &c in &self.cost {
            if !(c > 0.0) {
                return Err(GraphError::NonPositiveCost(c));
            }
        }
        let mut succ: Vec<Vec<(TaskId, f64)>> = vec![Vec::new(); n];
        let mut pred: Vec<Vec<(TaskId, f64)>> = vec![Vec::new(); n];
        let mut seen = std::collections::HashSet::new();
        for (u, v, d) in self.edges {
            if u >= n {
                return Err(GraphError::BadTask(u));
            }
            if v >= n {
                return Err(GraphError::BadTask(v));
            }
            if u == v {
                return Err(GraphError::SelfLoop(u));
            }
            if d < 0.0 || d.is_nan() {
                return Err(GraphError::NegativeData(d));
            }
            if !seen.insert((u, v)) {
                return Err(GraphError::DuplicateEdge(u, v));
            }
            succ[u].push((v, d));
            pred[v].push((u, d));
        }
        // Kahn's algorithm: topological order + cycle detection.
        let mut indeg: Vec<usize> = pred.iter().map(Vec::len).collect();
        let mut queue: Vec<TaskId> = (0..n).filter(|&t| indeg[t] == 0).collect();
        let mut topo = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let t = queue[head];
            head += 1;
            topo.push(t);
            for &(c, _) in &succ[t] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        if topo.len() != n {
            return Err(GraphError::Cycle);
        }
        Ok(TaskGraph {
            name: self.name,
            cost: self.cost,
            succ,
            pred,
            topo,
            weight: self.weight,
            deadline: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> TaskGraph {
        // 0 -> {1, 2} -> 3
        let mut b = GraphBuilder::new("diamond");
        let t0 = b.task(10.0);
        let t1 = b.task(5.0);
        let t2 = b.task(7.0);
        let t3 = b.task(3.0);
        b.edge(t0, t1, 2.0)
            .edge(t0, t2, 4.0)
            .edge(t1, t3, 1.0)
            .edge(t2, t3, 1.5);
        b.build().unwrap()
    }

    #[test]
    fn builds_and_queries() {
        let g = diamond();
        assert_eq!(g.n_tasks(), 4);
        assert_eq!(g.n_edges(), 4);
        assert_eq!(g.cost(0), 10.0);
        assert!(g.is_source(0) && !g.is_source(1));
        assert!(g.is_sink(3) && !g.is_sink(2));
        assert_eq!(g.successors(0).len(), 2);
        assert_eq!(g.predecessors(3).len(), 2);
        assert_eq!(g.total_cost(), 25.0);
        assert_eq!(g.total_data(), 8.5);
        assert_eq!(g.height(), 3);
    }

    #[test]
    fn topo_order_is_valid() {
        let g = diamond();
        let topo = g.topo_order();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (i, &t) in topo.iter().enumerate() {
                p[t] = i;
            }
            p
        };
        for t in 0..4 {
            for &(c, _) in g.successors(t) {
                assert!(pos[t] < pos[c]);
            }
        }
    }

    #[test]
    fn rejects_cycle() {
        let mut b = GraphBuilder::new("cyc");
        let a = b.task(1.0);
        let c = b.task(1.0);
        b.edge(a, c, 0.0).edge(c, a, 0.0);
        assert_eq!(b.build().unwrap_err(), GraphError::Cycle);
    }

    #[test]
    fn rejects_self_loop_bad_ids_bad_weights() {
        let mut b = GraphBuilder::new("x");
        let a = b.task(1.0);
        b.edge(a, a, 0.0);
        assert_eq!(b.build().unwrap_err(), GraphError::SelfLoop(a));

        let mut b = GraphBuilder::new("x");
        let a = b.task(1.0);
        b.edge(a, 7, 0.0);
        assert_eq!(b.build().unwrap_err(), GraphError::BadTask(7));

        let mut b = GraphBuilder::new("x");
        b.task(-1.0);
        assert!(matches!(b.build(), Err(GraphError::NonPositiveCost(_))));

        let mut b = GraphBuilder::new("x");
        let a = b.task(1.0);
        let c = b.task(1.0);
        b.edge(a, c, -2.0);
        assert!(matches!(b.build(), Err(GraphError::NegativeData(_))));
    }

    #[test]
    fn rejects_duplicate_edge() {
        let mut b = GraphBuilder::new("dup");
        let a = b.task(1.0);
        let c = b.task(1.0);
        b.edge(a, c, 1.0).edge(a, c, 2.0);
        assert_eq!(b.build().unwrap_err(), GraphError::DuplicateEdge(a, c));
    }

    #[test]
    fn scale_edges_scales_both_adjacencies() {
        let mut g = diamond();
        g.scale_edges(2.0);
        assert_eq!(g.total_data(), 17.0);
        assert_eq!(g.predecessors(3)[0].1, 2.0);
    }

    #[test]
    fn empty_graph_is_fine() {
        let g = GraphBuilder::new("empty").build().unwrap();
        assert_eq!(g.n_tasks(), 0);
        assert_eq!(g.height(), 0);
    }

    #[test]
    fn dot_export_mentions_everything() {
        let d = diamond().to_dot();
        assert!(d.contains("t0 -> t1"));
        assert!(d.contains("digraph"));
    }

    #[test]
    fn graph_deadline_defaults_and_overrides() {
        let mut g = diamond();
        assert_eq!(g.deadline(), None);
        g.set_deadline(42.5);
        assert_eq!(g.deadline(), Some(42.5));
        // deadlines may sit anywhere on the time axis, including 0
        g.set_deadline(0.0);
        assert_eq!(g.deadline(), Some(0.0));
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_non_finite_deadline() {
        diamond().set_deadline(f64::NAN);
    }

    #[test]
    fn graph_weight_defaults_and_overrides() {
        let mut g = diamond();
        assert_eq!(g.weight(), 1.0);
        g.set_weight(2.5);
        assert_eq!(g.weight(), 2.5);

        let mut b = GraphBuilder::new("weighted");
        b.task(1.0);
        b.weight(4.0);
        assert_eq!(b.build().unwrap().weight(), 4.0);
    }

    #[test]
    fn rejects_bad_weight() {
        let mut b = GraphBuilder::new("w");
        b.task(1.0);
        b.weight(0.0);
        assert_eq!(b.build().unwrap_err(), GraphError::NonPositiveWeight(0.0));

        let mut b = GraphBuilder::new("w");
        b.task(1.0);
        b.weight(f64::INFINITY);
        assert!(matches!(b.build(), Err(GraphError::NonPositiveWeight(_))));
    }

    #[test]
    fn graph_arena_rows_round_trip() {
        let mut a = GraphArena::default();
        a.reset();
        a.push(1, 2.0);
        a.push(2, 4.0);
        a.close_row();
        a.close_row(); // empty row
        a.push(0, 1.5);
        a.close_row();
        assert_eq!(a.n_rows(), 3);
        assert_eq!(a.degree(0), 2);
        assert_eq!(a.degree(1), 0);
        assert_eq!(a.row(0), (&[1u32, 2][..], &[2.0, 4.0][..]));
        assert_eq!(a.row(2), (&[0u32][..], &[1.5][..]));
        // reset retains nothing visible but starts a fresh row set
        a.reset();
        assert_eq!(a.n_rows(), 0);
    }

    #[test]
    fn fixed_arena_rows_round_trip() {
        let mut a = FixedArena::default();
        a.reset();
        a.close_row(); // task 0: no fixed preds
        a.push(3, 10.0, 0.5);
        a.push(1, 7.0, 0.0);
        a.close_row();
        assert_eq!(a.n_rows(), 2);
        let (nodes, fin, data) = a.row(1);
        assert_eq!(nodes, &[3, 1]);
        assert_eq!(fin, &[10.0, 7.0]);
        assert_eq!(data, &[0.5, 0.0]);
        assert_eq!(a.row(0).0.len(), 0);
    }

    #[test]
    fn gid_ordering_and_display() {
        let a = Gid::new(1, 2);
        let b = Gid::new(1, 3);
        let c = Gid::new(2, 0);
        assert!(a < b && b < c);
        assert_eq!(a.to_string(), "g1t2");
    }
}
