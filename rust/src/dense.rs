//! Dense task ids and epoch-stamped dense containers (§Perf, PR 6).
//!
//! The dynamic problem identifies tasks by [`Gid`] (graph, task) pairs,
//! which the hot paths used to hash on every probe.  A [`DenseIds`]
//! bijection assigns every task of a [`crate::coordinator::DynamicProblem`]
//! a contiguous `u32` — `id = offsets[graph] + task` — built **once** per
//! problem, after which the coordinator, simulator, and schedule layers
//! index flat arrays instead of hashing.  `FxHashMap` survives only at
//! API boundaries (trace I/O, metrics, golden fixtures).
//!
//! [`DenseMap`] / [`DenseSet`] are the companion scratch containers: a
//! value array plus a `u32` stamp array, where "present" means
//! `stamp[i] == epoch`.  Clearing is a single epoch bump (O(1)), so the
//! per-replan scratch state (revert sets, cone entries, composite index)
//! resets without touching memory — and without allocating.

use crate::graph::Gid;

/// Dense per-problem task id (see [`DenseIds`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DenseId(pub u32);

/// The `Gid ↔ DenseId` bijection for one dynamic problem: graphs are laid
/// out back-to-back in arrival order, tasks in graph order, so
/// `id(gid) = offsets[gid.graph] + gid.task` and `gid(id)` is a flat
/// array read.
#[derive(Clone, Debug, Default)]
pub struct DenseIds {
    /// per-graph base offset; `offsets[n_graphs]` == total task count
    offsets: Vec<u32>,
    /// inverse map: dense id → Gid
    gids: Vec<Gid>,
}

impl DenseIds {
    /// Build from per-graph task counts (in graph-index order).
    pub fn from_counts<I: IntoIterator<Item = usize>>(counts: I) -> Self {
        let mut offsets = Vec::new();
        let mut gids = Vec::new();
        let mut base = 0u32;
        offsets.push(0);
        for (g, n) in counts.into_iter().enumerate() {
            for t in 0..n {
                gids.push(Gid::new(g, t));
            }
            base += n as u32;
            offsets.push(base);
        }
        Self { offsets, gids }
    }

    /// Total number of tasks in the bijection.
    pub fn len(&self) -> usize {
        self.gids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gids.is_empty()
    }

    pub fn n_graphs(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Dense id of `gid` (panics if `gid` is outside the problem).
    #[inline]
    pub fn id(&self, gid: Gid) -> DenseId {
        let d = self.offsets[gid.graph as usize] + gid.task;
        debug_assert!(
            (d as usize) < self.gids.len()
                && (gid.graph as usize + 1) < self.offsets.len()
                && d < self.offsets[gid.graph as usize + 1],
            "gid {gid} outside the dense bijection"
        );
        DenseId(d)
    }

    /// Dense id of `gid` as a raw index.
    #[inline]
    pub fn ix(&self, gid: Gid) -> usize {
        self.id(gid).0 as usize
    }

    /// Gid of dense id `d`.
    #[inline]
    pub fn gid(&self, d: DenseId) -> Gid {
        self.gids[d.0 as usize]
    }

    /// Borrowed Gid of raw dense index `d` (for iterators that must yield
    /// `&Gid`).
    #[inline]
    pub fn gid_ref(&self, d: usize) -> &Gid {
        &self.gids[d]
    }

    /// All gids in dense order.
    pub fn gids(&self) -> &[Gid] {
        &self.gids
    }

    /// Does this bijection cover exactly the given per-graph task counts?
    pub fn matches<I: IntoIterator<Item = usize>>(&self, counts: I) -> bool {
        let mut g = 0usize;
        for n in counts {
            if g + 1 >= self.offsets.len()
                || (self.offsets[g + 1] - self.offsets[g]) as usize != n
            {
                return false;
            }
            g += 1;
        }
        g + 1 == self.offsets.len()
    }
}

/// Epoch-stamped dense set over dense ids: O(1) clear via epoch bump,
/// zero steady-state allocations once sized.
#[derive(Clone, Debug, Default)]
pub struct DenseSet {
    stamp: Vec<u32>,
    epoch: u32,
}

impl DenseSet {
    /// Clear and (re)size for a universe of `len` ids.
    pub fn reset(&mut self, len: usize) {
        if self.stamp.len() != len {
            self.stamp.clear();
            self.stamp.resize(len, 0);
            self.epoch = 1;
            return;
        }
        if self.epoch == u32::MAX {
            for s in &mut self.stamp {
                *s = 0;
            }
            self.epoch = 1;
        } else {
            self.epoch += 1;
        }
    }

    /// Insert; returns true if newly inserted.
    #[inline]
    pub fn insert(&mut self, i: usize) -> bool {
        let s = &mut self.stamp[i];
        let fresh = *s != self.epoch;
        *s = self.epoch;
        fresh
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        self.stamp[i] == self.epoch
    }
}

/// Epoch-stamped dense map over dense ids (same discipline as
/// [`DenseSet`]; values are only meaningful where the stamp matches).
#[derive(Clone, Debug, Default)]
pub struct DenseMap<T> {
    stamp: Vec<u32>,
    vals: Vec<T>,
    epoch: u32,
}

impl<T: Clone + Default> DenseMap<T> {
    /// Clear and (re)size for a universe of `len` ids.
    pub fn reset(&mut self, len: usize) {
        if self.stamp.len() != len {
            self.stamp.clear();
            self.stamp.resize(len, 0);
            self.vals.clear();
            self.vals.resize(len, T::default());
            self.epoch = 1;
            return;
        }
        if self.epoch == u32::MAX {
            for s in &mut self.stamp {
                *s = 0;
            }
            self.epoch = 1;
        } else {
            self.epoch += 1;
        }
    }

    #[inline]
    pub fn insert(&mut self, i: usize, v: T) {
        self.stamp[i] = self.epoch;
        self.vals[i] = v;
    }

    #[inline]
    pub fn get(&self, i: usize) -> Option<&T> {
        if self.stamp[i] == self.epoch {
            Some(&self.vals[i])
        } else {
            None
        }
    }

    #[inline]
    pub fn get_mut(&mut self, i: usize) -> Option<&mut T> {
        if self.stamp[i] == self.epoch {
            Some(&mut self.vals[i])
        } else {
            None
        }
    }

    #[inline]
    pub fn contains_key(&self, i: usize) -> bool {
        self.stamp[i] == self.epoch
    }

    /// Remove; returns true if it was present.
    #[inline]
    pub fn remove(&mut self, i: usize) -> bool {
        let present = self.stamp[i] == self.epoch;
        if present {
            // epoch 0 is never current (reset starts at 1)
            self.stamp[i] = 0;
        }
        present
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bijection_round_trips() {
        let ids = DenseIds::from_counts([3, 0, 2]);
        assert_eq!(ids.len(), 5);
        assert_eq!(ids.n_graphs(), 3);
        for d in 0..ids.len() {
            let gid = ids.gid(DenseId(d as u32));
            assert_eq!(ids.id(gid), DenseId(d as u32));
            assert_eq!(*ids.gid_ref(d), gid);
        }
        assert_eq!(ids.id(Gid::new(2, 1)), DenseId(4));
        assert_eq!(ids.gid(DenseId(2)), Gid::new(0, 2));
        assert!(ids.matches([3, 0, 2]));
        assert!(!ids.matches([3, 1, 2]));
        assert!(!ids.matches([3, 0]));
        assert!(!ids.matches([3, 0, 2, 1]));
    }

    #[test]
    fn dense_set_epoch_clear() {
        let mut s = DenseSet::default();
        s.reset(4);
        assert!(s.insert(1));
        assert!(!s.insert(1));
        assert!(s.contains(1) && !s.contains(0));
        s.reset(4);
        assert!(!s.contains(1), "epoch bump clears");
        assert!(s.insert(1));
        s.reset(8);
        assert!(!s.contains(1), "resize clears");
    }

    #[test]
    fn dense_map_insert_get_remove() {
        let mut m: DenseMap<u32> = DenseMap::default();
        m.reset(3);
        assert_eq!(m.get(0), None);
        m.insert(0, 7);
        m.insert(2, 9);
        assert_eq!(m.get(0), Some(&7));
        assert!(m.contains_key(2));
        if let Some(v) = m.get_mut(2) {
            *v += 1;
        }
        assert_eq!(m.get(2), Some(&10));
        assert!(m.remove(0));
        assert!(!m.remove(0));
        assert_eq!(m.get(0), None);
        m.reset(3);
        assert_eq!(m.get(2), None, "epoch bump clears");
    }

    #[test]
    fn dense_set_epoch_wrap_is_safe() {
        let mut s = DenseSet::default();
        s.reset(2);
        s.insert(0);
        // force the wrap path
        s.epoch = u32::MAX;
        s.stamp[1] = u32::MAX; // pretend 1 was inserted at MAX epoch
        assert!(s.contains(1));
        s.reset(2);
        assert!(!s.contains(0) && !s.contains(1));
        assert_eq!(s.epoch, 1);
    }
}
