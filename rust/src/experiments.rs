//! The figure-regeneration harness: runs a (dataset × variants × trials)
//! sweep, normalizes per trial exactly like the paper's "Normalized ..."
//! figures, and emits markdown/CSV tables — one table per paper figure.
//!
//! Figure map (see DESIGN.md §4):
//! * Fig 3 — normalized total makespan, per dataset
//! * Fig 4 — normalized mean makespan
//! * Fig 5 — normalized mean flowtime
//! * Fig 6 — normalized scheduler runtime
//! * Fig 7 — (raw) mean node utilization
//! * Fig 8 — all five metrics on the adversarial dataset

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::config::ExperimentConfig;
use crate::coordinator::{DynamicProblem, Variant};
use crate::json::{self, Value};
use crate::metrics::{normalize, Metric, MetricRow};
use crate::report;
use crate::schedule::validate;
use crate::stats::mean;

/// Raw sweep output: `rows[trial][variant]`.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub config: ExperimentConfig,
    pub labels: Vec<String>,
    pub rows: Vec<Vec<MetricRow>>,
}

/// Run the full sweep described by `cfg` on one thread.  Every produced
/// schedule is checked by the §II validator; a violation is a hard panic
/// (the harness must never report numbers from an invalid schedule).
pub fn run_sweep(cfg: &ExperimentConfig) -> SweepResult {
    run_sweep_with(cfg, |_trial, _variant| {})
}

/// Generate trial `trial`'s instance, honouring the config's offered
/// load (the one generation path shared by the serial and parallel
/// sweeps — `Dataset::instance` would silently pin `DEFAULT_LOAD`).
fn make_instance(cfg: &ExperimentConfig, trial: usize) -> DynamicProblem {
    cfg.dataset
        .instance_opts(cfg.n_graphs, cfg.seed + trial as u64, cfg.load, None)
}

/// Run one (trial, variant) cell against its trial's shared instance.
fn run_cell(
    cfg: &ExperimentConfig,
    prob: &DynamicProblem,
    trial: usize,
    variant: &Variant,
) -> MetricRow {
    let seed = cfg.seed + trial as u64;
    let mut coord = variant.coordinator(seed ^ 0x5EED);
    let res = coord.run(prob);
    let viol = validate(&res.schedule, &prob.graphs, &prob.network);
    assert!(
        viol.is_empty(),
        "invalid schedule from {} on {} trial {trial}: {:?}",
        variant.label(),
        cfg.dataset.name(),
        &viol[..viol.len().min(3)]
    );
    res.metrics(prob)
}

/// Like [`run_sweep`] but with a progress callback `(trial, variant_label)`.
pub fn run_sweep_with(
    cfg: &ExperimentConfig,
    mut progress: impl FnMut(usize, &str),
) -> SweepResult {
    let labels: Vec<String> = cfg.variants.iter().map(|v| v.label()).collect();
    let mut rows = Vec::with_capacity(cfg.trials);
    for trial in 0..cfg.trials {
        let prob = make_instance(cfg, trial);
        let mut row = Vec::with_capacity(cfg.variants.len());
        for v in &cfg.variants {
            progress(trial, &v.label());
            row.push(run_cell(cfg, &prob, trial, v));
        }
        rows.push(row);
    }
    SweepResult {
        config: cfg.clone(),
        labels,
        rows,
    }
}

/// Parallel sweep: fans the (trial × variant) cells out over `jobs`
/// worker threads and collects the rows **in cell order**, so every
/// schedule-derived metric is bit-identical to the serial [`run_sweep`]
/// at any thread count (instances are derived from `cfg.seed + trial`
/// alone, every variant run is seeded, and each trial's instance is
/// generated once through a `OnceLock` shared by its cells); only the
/// measured wall-clock `runtime_s` naturally varies between runs.
/// The §V.E `sched_runtime_s` metric
/// stays meaningful under parallelism because the coordinator measures
/// its own `Instant` span on whichever worker runs the cell — per
/// coordinator wall time, never wall time of the whole pool.
///
/// Std-only by design: the offline build environment has no rayon, so
/// the fan-out is a `std::thread::scope` work queue over an atomic cell
/// counter (work-stealing granularity = one cell).
pub fn run_sweep_parallel(cfg: &ExperimentConfig, jobs: usize) -> SweepResult {
    let jobs = jobs.max(1);
    let n_variants = cfg.variants.len();
    let n_cells = cfg.trials * n_variants;
    if jobs == 1 || n_cells <= 1 {
        return run_sweep(cfg);
    }

    let instances: Vec<OnceLock<DynamicProblem>> =
        (0..cfg.trials).map(|_| OnceLock::new()).collect();
    let next_cell = AtomicUsize::new(0);
    let mut flat: Vec<Option<MetricRow>> = vec![None; n_cells];

    std::thread::scope(|scope| {
        let workers: Vec<_> = (0..jobs.min(n_cells))
            .map(|_| {
                scope.spawn(|| {
                    let mut done: Vec<(usize, MetricRow)> = Vec::new();
                    loop {
                        let cell = next_cell.fetch_add(1, Ordering::Relaxed);
                        if cell >= n_cells {
                            break;
                        }
                        let trial = cell / n_variants;
                        let vi = cell % n_variants;
                        let prob =
                            instances[trial].get_or_init(|| make_instance(cfg, trial));
                        done.push((cell, run_cell(cfg, prob, trial, &cfg.variants[vi])));
                    }
                    done
                })
            })
            .collect();
        for w in workers {
            for (cell, row) in w.join().expect("sweep worker panicked") {
                flat[cell] = Some(row);
            }
        }
    });

    let mut rows = Vec::with_capacity(cfg.trials);
    let mut it = flat.into_iter();
    for _ in 0..cfg.trials {
        rows.push(
            (&mut it)
                .take(n_variants)
                .map(|r| r.expect("cell not computed"))
                .collect(),
        );
    }
    SweepResult {
        config: cfg.clone(),
        labels: cfg.variants.iter().map(|v| v.label()).collect(),
        rows,
    }
}

impl SweepResult {
    /// Paper-style normalized values for one metric: normalize within
    /// each trial across variants (best = 1.0 for lower-is-better
    /// metrics), then average across trials.  Utilization is reported
    /// raw, as in Fig 7/8e.
    pub fn figure_values(&self, metric: Metric) -> Vec<f64> {
        match metric {
            Metric::Utilization => self.raw_mean(metric),
            _ => {
                let mut acc = vec![0.0; self.labels.len()];
                for row in &self.rows {
                    let vals: Vec<f64> = row.iter().map(|r| r.get(metric)).collect();
                    for (i, v) in normalize(metric, &vals).iter().enumerate() {
                        acc[i] += v;
                    }
                }
                acc.iter().map(|v| v / self.rows.len() as f64).collect()
            }
        }
    }

    /// Raw per-variant mean of a metric across trials.
    pub fn raw_mean(&self, metric: Metric) -> Vec<f64> {
        (0..self.labels.len())
            .map(|i| mean(&self.rows.iter().map(|r| r[i].get(metric)).collect::<Vec<_>>()))
            .collect()
    }

    /// Figure table for one metric, sorted ascending (descending for
    /// utilization) — mirrors the bar ordering in the paper's plots.
    pub fn figure_table(&self, metric: Metric) -> String {
        let vals = self.figure_values(metric);
        let raw = self.raw_mean(metric);
        let mut idx: Vec<usize> = (0..vals.len()).collect();
        if metric.lower_is_better() {
            idx.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
        } else {
            idx.sort_by(|&a, &b| vals[b].partial_cmp(&vals[a]).unwrap());
        }
        let header_val = if metric == Metric::Utilization {
            "utilization".to_string()
        } else {
            format!("normalized {}", metric.name())
        };
        let rows: Vec<Vec<String>> = idx
            .iter()
            .map(|&i| {
                vec![
                    self.labels[i].clone(),
                    report::fmt(vals[i]),
                    report::fmt(raw[i]),
                ]
            })
            .collect();
        report::markdown_table(&["variant", &header_val, "raw mean"], &rows)
    }

    /// CSV with every metric per variant (figure-ready).
    pub fn to_csv(&self) -> String {
        let mut rows = Vec::new();
        for (i, label) in self.labels.iter().enumerate() {
            let mut row = vec![self.config.dataset.name().to_string(), label.clone()];
            for m in Metric::ALL {
                row.push(format!("{}", self.figure_values(m)[i]));
                row.push(format!("{}", self.raw_mean(m)[i]));
            }
            rows.push(row);
        }
        let headers = vec![
            "dataset",
            "variant",
            "total_makespan_norm",
            "total_makespan_raw",
            "mean_makespan_norm",
            "mean_makespan_raw",
            "mean_flowtime_norm",
            "mean_flowtime_raw",
            "utilization",
            "utilization_raw",
            "runtime_norm",
            "runtime_raw",
        ];
        report::csv(&headers, &rows)
    }

    /// JSON dump (config + per-trial raw metric rows).
    pub fn to_json(&self) -> Value {
        let rows = self
            .rows
            .iter()
            .map(|trial| {
                json::arr(
                    trial
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("total_makespan", json::num(r.total_makespan)),
                                ("mean_makespan", json::num(r.mean_makespan)),
                                ("mean_flowtime", json::num(r.mean_flowtime)),
                                ("utilization", json::num(r.mean_utilization)),
                                ("runtime_s", json::num(r.runtime_s)),
                            ])
                        })
                        .collect(),
                )
            })
            .collect();
        json::obj(vec![
            ("config", self.config.to_json()),
            (
                "labels",
                json::arr(self.labels.iter().map(|l| json::s(l)).collect()),
            ),
            ("trials", json::arr(rows)),
        ])
    }

    /// Value of a labelled variant for one metric (figure scale).
    pub fn value_of(&self, label: &str, metric: Metric) -> Option<f64> {
        let i = self.labels.iter().position(|l| l == label)?;
        Some(self.figure_values(metric)[i])
    }
}

/// Convenience used by benches: a reduced variant set that still spans
/// the paper's qualitative story (all policies × HEFT/CPOP + extremes of
/// the other heuristics) — 14 variants instead of 30.
pub fn core_variants() -> Vec<Variant> {
    use crate::coordinator::Policy::*;
    use crate::schedulers::SchedulerKind::*;
    let mut out = Vec::new();
    for kind in [Heft, Cpop] {
        for p in [
            NonPreemptive,
            LastK(2),
            LastK(5),
            LastK(10),
            LastK(20),
            Preemptive,
        ] {
            out.push(Variant { policy: p, kind });
        }
    }
    out.push(Variant { policy: NonPreemptive, kind: MinMin });
    out.push(Variant { policy: Preemptive, kind: MinMin });
    out.push(Variant { policy: NonPreemptive, kind: MaxMin });
    out.push(Variant { policy: Preemptive, kind: MaxMin });
    out.push(Variant { policy: NonPreemptive, kind: Random });
    out.push(Variant { policy: Preemptive, kind: Random });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Dataset;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            dataset: Dataset::Synthetic,
            n_graphs: 8,
            trials: 2,
            seed: 3,
            load: 0.5,
            variants: vec![
                Variant::parse("NP-HEFT").unwrap(),
                Variant::parse("P-HEFT").unwrap(),
                Variant::parse("2P-HEFT").unwrap(),
            ],
        }
    }

    #[test]
    fn sweep_shape_and_validity() {
        let r = run_sweep(&tiny_cfg());
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.rows[0].len(), 3);
        assert_eq!(r.labels, vec!["NP-HEFT", "P-HEFT", "2P-HEFT"]);
    }

    #[test]
    fn parallel_sweep_is_deterministic_across_thread_counts() {
        // Every schedule-derived metric must be bit-identical whether the
        // (trial × variant) cells run on 1 thread or many; only the
        // wall-clock runtime_s measurement may differ.
        let mut cfg = tiny_cfg();
        cfg.trials = 3;
        cfg.variants.push(Variant::parse("P-MinMin").unwrap());
        cfg.variants.push(Variant::parse("5P-Random").unwrap());
        let serial = run_sweep_parallel(&cfg, 1);
        for jobs in [2, 4, 7] {
            let parallel = run_sweep_parallel(&cfg, jobs);
            assert_eq!(serial.labels, parallel.labels);
            assert_eq!(serial.rows.len(), parallel.rows.len());
            for (trial, (rs, rp)) in
                serial.rows.iter().zip(parallel.rows.iter()).enumerate()
            {
                assert_eq!(rs.len(), rp.len());
                for (vi, (s, p)) in rs.iter().zip(rp.iter()).enumerate() {
                    let sig = |m: &MetricRow| {
                        (
                            m.total_makespan.to_bits(),
                            m.mean_makespan.to_bits(),
                            m.mean_flowtime.to_bits(),
                            m.mean_utilization.to_bits(),
                        )
                    };
                    assert_eq!(
                        sig(s),
                        sig(p),
                        "jobs={jobs}, trial {trial}, variant {}",
                        serial.labels[vi]
                    );
                    assert!(p.runtime_s > 0.0, "per-coordinator runtime recorded");
                }
            }
        }
    }

    #[test]
    fn normalization_minimum_is_one() {
        let r = run_sweep(&tiny_cfg());
        for m in [Metric::TotalMakespan, Metric::MeanMakespan, Metric::MeanFlowtime] {
            let vals = r.figure_values(m);
            // averaged normalized values: every variant >= 1, and in each
            // trial someone was exactly 1, so the min is >= 1 but close.
            assert!(vals.iter().all(|&v| v >= 1.0 - 1e-12), "{m:?}: {vals:?}");
            let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(lo < 2.0, "{m:?}: implausible normalization {vals:?}");
        }
    }

    #[test]
    fn utilization_is_raw_and_bounded() {
        let r = run_sweep(&tiny_cfg());
        for &u in &r.figure_values(Metric::Utilization) {
            assert!(u > 0.0 && u <= 1.0);
        }
    }

    #[test]
    fn tables_and_csv_render() {
        let r = run_sweep(&tiny_cfg());
        let t = r.figure_table(Metric::TotalMakespan);
        assert!(t.contains("NP-HEFT") && t.contains("P-HEFT"));
        let c = r.to_csv();
        assert_eq!(c.lines().count(), 4); // header + 3 variants
        let j = r.to_json();
        assert!(j.get("labels").is_some());
        // json roundtrips through the parser
        let round = Value::from_str(&j.to_string()).unwrap();
        assert_eq!(round.get("labels"), j.get("labels"));
    }

    #[test]
    fn value_of_lookup() {
        let r = run_sweep(&tiny_cfg());
        assert!(r.value_of("P-HEFT", Metric::Runtime).is_some());
        assert!(r.value_of("nope", Metric::Runtime).is_none());
    }

    #[test]
    fn core_variants_cover_policy_axis() {
        let vs = core_variants();
        assert_eq!(vs.len(), 18);
        let labels: Vec<String> = vs.iter().map(|v| v.label()).collect();
        assert!(labels.contains(&"5P-HEFT".to_string()));
        assert!(labels.contains(&"P-Random".to_string()));
    }
}
