//! The figure-regeneration harness: runs a (dataset × variants × trials)
//! sweep, normalizes per trial exactly like the paper's "Normalized ..."
//! figures, and emits markdown/CSV tables — one table per paper figure.
//!
//! Figure map (see DESIGN.md §4):
//! * Fig 3 — normalized total makespan, per dataset
//! * Fig 4 — normalized mean makespan
//! * Fig 5 — normalized mean flowtime
//! * Fig 6 — normalized scheduler runtime
//! * Fig 7 — (raw) mean node utilization
//! * Fig 8 — all five metrics on the adversarial dataset

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::config::ExperimentConfig;
use crate::coordinator::{DynamicProblem, Variant};
use crate::json::{self, Value};
use crate::metrics::{normalize, Metric, MetricRow, PreemptionCost};
use crate::policy::PolicySpec;
use crate::report;
use crate::schedule::validate;
use crate::sim::{Reaction, ReactiveCoordinator, SimConfig};
use crate::stats::mean;
use crate::workloads::{Dataset, Scenario};

/// Raw sweep output: `rows[trial][variant]`.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub config: ExperimentConfig,
    pub labels: Vec<String>,
    pub rows: Vec<Vec<MetricRow>>,
}

/// Run the full sweep described by `cfg` on one thread.  Every produced
/// schedule is checked by the §II validator; a violation is a hard panic
/// (the harness must never report numbers from an invalid schedule).
pub fn run_sweep(cfg: &ExperimentConfig) -> SweepResult {
    run_sweep_with(cfg, |_trial, _variant| {})
}

/// Generate trial `trial`'s instance, honouring the config's offered
/// load (the one generation path shared by the serial and parallel
/// sweeps — `Dataset::instance` would silently pin `DEFAULT_LOAD`).
fn make_instance(cfg: &ExperimentConfig, trial: usize) -> DynamicProblem {
    cfg.dataset
        .instance_opts(cfg.n_graphs, cfg.seed + trial as u64, cfg.load, None)
}

/// Run one (trial, variant) cell against its trial's shared instance.
fn run_cell(
    cfg: &ExperimentConfig,
    prob: &DynamicProblem,
    trial: usize,
    variant: &Variant,
) -> MetricRow {
    let seed = cfg.seed + trial as u64;
    let mut coord = variant.coordinator(seed ^ 0x5EED);
    let res = coord.run(prob);
    let viol = validate(&res.schedule, &prob.graphs, &prob.network);
    assert!(
        viol.is_empty(),
        "invalid schedule from {} on {} trial {trial}: {:?}",
        variant.label(),
        cfg.dataset.name(),
        &viol[..viol.len().min(3)]
    );
    res.metrics(prob)
}

/// Like [`run_sweep`] but with a progress callback `(trial, variant_label)`.
pub fn run_sweep_with(
    cfg: &ExperimentConfig,
    mut progress: impl FnMut(usize, &str),
) -> SweepResult {
    let labels: Vec<String> = cfg.variants.iter().map(|v| v.label()).collect();
    let mut rows = Vec::with_capacity(cfg.trials);
    for trial in 0..cfg.trials {
        let prob = make_instance(cfg, trial);
        let mut row = Vec::with_capacity(cfg.variants.len());
        for v in &cfg.variants {
            progress(trial, &v.label());
            row.push(run_cell(cfg, &prob, trial, v));
        }
        rows.push(row);
    }
    SweepResult {
        config: cfg.clone(),
        labels,
        rows,
    }
}

/// Parallel sweep: fans the (trial × variant) cells out over `jobs`
/// worker threads and collects the rows **in cell order**, so every
/// schedule-derived metric is bit-identical to the serial [`run_sweep`]
/// at any thread count (instances are derived from `cfg.seed + trial`
/// alone, every variant run is seeded, and each trial's instance is
/// generated once through a `OnceLock` shared by its cells); only the
/// measured wall-clock `runtime_s` naturally varies between runs.
/// The §V.E `sched_runtime_s` metric
/// stays meaningful under parallelism because the coordinator measures
/// its own `Instant` span on whichever worker runs the cell — per
/// coordinator wall time, never wall time of the whole pool.
///
/// Std-only by design: the offline build environment has no rayon, so
/// the fan-out is a `std::thread::scope` work queue over an atomic cell
/// counter (work-stealing granularity = one cell).
pub fn run_sweep_parallel(cfg: &ExperimentConfig, jobs: usize) -> SweepResult {
    let jobs = jobs.max(1);
    let n_variants = cfg.variants.len();
    let n_cells = cfg.trials * n_variants;
    if jobs == 1 || n_cells <= 1 {
        return run_sweep(cfg);
    }

    let instances: Vec<OnceLock<DynamicProblem>> =
        (0..cfg.trials).map(|_| OnceLock::new()).collect();
    let next_cell = AtomicUsize::new(0);
    let mut flat: Vec<Option<MetricRow>> = vec![None; n_cells];

    let tele_on = crate::telemetry::enabled();
    std::thread::scope(|scope| {
        let workers: Vec<_> = (0..jobs.min(n_cells))
            .map(|_| {
                scope.spawn(|| {
                    crate::telemetry::set_enabled(tele_on);
                    let mut done: Vec<(usize, MetricRow)> = Vec::new();
                    loop {
                        let cell = next_cell.fetch_add(1, Ordering::Relaxed);
                        if cell >= n_cells {
                            break;
                        }
                        let trial = cell / n_variants;
                        let vi = cell % n_variants;
                        let prob =
                            instances[trial].get_or_init(|| make_instance(cfg, trial));
                        done.push((cell, run_cell(cfg, prob, trial, &cfg.variants[vi])));
                    }
                    (done, crate::telemetry::take())
                })
            })
            .collect();
        for w in workers {
            let (cells, tele) = w.join().expect("sweep worker panicked");
            for (cell, row) in cells {
                flat[cell] = Some(row);
            }
            crate::telemetry::absorb(&tele);
        }
    });

    let mut rows = Vec::with_capacity(cfg.trials);
    let mut it = flat.into_iter();
    for _ in 0..cfg.trials {
        rows.push(
            (&mut it)
                .take(n_variants)
                .map(|r| r.expect("cell not computed"))
                .collect(),
        );
    }
    SweepResult {
        config: cfg.clone(),
        labels: cfg.variants.iter().map(|v| v.label()).collect(),
        rows,
    }
}

impl SweepResult {
    /// Paper-style normalized values for one metric: normalize within
    /// each trial across variants (best = 1.0 for lower-is-better
    /// metrics), then average across trials.  Bounded absolute-scale
    /// metrics (utilization, Jain fairness) are reported raw, as in
    /// Fig 7/8e.
    pub fn figure_values(&self, metric: Metric) -> Vec<f64> {
        if metric.reported_raw() {
            self.raw_mean(metric)
        } else {
            let mut acc = vec![0.0; self.labels.len()];
            for row in &self.rows {
                let vals: Vec<f64> = row.iter().map(|r| r.get(metric)).collect();
                for (i, v) in normalize(metric, &vals).iter().enumerate() {
                    acc[i] += v;
                }
            }
            acc.iter().map(|v| v / self.rows.len() as f64).collect()
        }
    }

    /// Raw per-variant mean of a metric across trials.
    pub fn raw_mean(&self, metric: Metric) -> Vec<f64> {
        (0..self.labels.len())
            .map(|i| mean(&self.rows.iter().map(|r| r[i].get(metric)).collect::<Vec<_>>()))
            .collect()
    }

    /// Figure table for one metric, sorted ascending (descending for
    /// utilization) — mirrors the bar ordering in the paper's plots.
    pub fn figure_table(&self, metric: Metric) -> String {
        let vals = self.figure_values(metric);
        let raw = self.raw_mean(metric);
        let mut idx: Vec<usize> = (0..vals.len()).collect();
        if metric.lower_is_better() {
            idx.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
        } else {
            idx.sort_by(|&a, &b| vals[b].partial_cmp(&vals[a]).unwrap());
        }
        let header_val = if metric.reported_raw() {
            metric.name().to_string()
        } else {
            format!("normalized {}", metric.name())
        };
        let rows: Vec<Vec<String>> = idx
            .iter()
            .map(|&i| {
                vec![
                    self.labels[i].clone(),
                    report::fmt(vals[i]),
                    report::fmt(raw[i]),
                ]
            })
            .collect();
        report::markdown_table(&["variant", &header_val, "raw mean"], &rows)
    }

    /// CSV with every metric per variant (figure-ready).
    pub fn to_csv(&self) -> String {
        let mut rows = Vec::new();
        for (i, label) in self.labels.iter().enumerate() {
            let mut row = vec![self.config.dataset.name().to_string(), label.clone()];
            for m in Metric::ALL {
                row.push(format!("{}", self.figure_values(m)[i]));
                row.push(format!("{}", self.raw_mean(m)[i]));
            }
            rows.push(row);
        }
        let headers = vec![
            "dataset",
            "variant",
            "total_makespan_norm",
            "total_makespan_raw",
            "mean_makespan_norm",
            "mean_makespan_raw",
            "mean_flowtime_norm",
            "mean_flowtime_raw",
            "utilization",
            "utilization_raw",
            "mean_stretch_norm",
            "mean_stretch_raw",
            "max_stretch_norm",
            "max_stretch_raw",
            "jain_fairness",
            "jain_fairness_raw",
            "weighted_mean_stretch_norm",
            "weighted_mean_stretch_raw",
            "weighted_max_stretch_norm",
            "weighted_max_stretch_raw",
            "weighted_jain",
            "weighted_jain_raw",
            "deadline_miss_rate",
            "deadline_miss_rate_raw",
            "mean_tardiness_norm",
            "mean_tardiness_raw",
            "max_tardiness_norm",
            "max_tardiness_raw",
            "weighted_tardiness_norm",
            "weighted_tardiness_raw",
            "runtime_norm",
            "runtime_raw",
            "wasted_work_s_norm",
            "wasted_work_s_raw",
            "n_reexecuted_norm",
            "n_reexecuted_raw",
            "mean_recovery_latency_norm",
            "mean_recovery_latency_raw",
        ];
        report::csv(&headers, &rows)
    }

    /// JSON dump (config + per-trial raw metric rows).
    pub fn to_json(&self) -> Value {
        let rows = self
            .rows
            .iter()
            .map(|trial| {
                json::arr(
                    trial
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("total_makespan", json::num(r.total_makespan)),
                                ("mean_makespan", json::num(r.mean_makespan)),
                                ("mean_flowtime", json::num(r.mean_flowtime)),
                                ("utilization", json::num(r.mean_utilization)),
                                ("mean_stretch", json::num(r.mean_stretch)),
                                ("max_stretch", json::num(r.max_stretch)),
                                ("jain_fairness", json::num(r.jain_fairness)),
                                (
                                    "weighted_mean_stretch",
                                    json::num(r.weighted_mean_stretch),
                                ),
                                ("weighted_max_stretch", json::num(r.weighted_max_stretch)),
                                ("weighted_jain", json::num(r.weighted_jain)),
                                (
                                    "deadline_miss_rate",
                                    json::num(r.deadline_miss_rate),
                                ),
                                ("mean_tardiness", json::num(r.mean_tardiness)),
                                ("max_tardiness", json::num(r.max_tardiness)),
                                ("weighted_tardiness", json::num(r.weighted_tardiness)),
                                ("runtime_s", json::num(r.runtime_s)),
                            ])
                        })
                        .collect(),
                )
            })
            .collect();
        json::obj(vec![
            ("config", self.config.to_json()),
            (
                "labels",
                json::arr(self.labels.iter().map(|l| json::s(l)).collect()),
            ),
            ("trials", json::arr(rows)),
        ])
    }

    /// Value of a labelled variant for one metric (figure scale).
    pub fn value_of(&self, label: &str, metric: Metric) -> Option<f64> {
        let i = self.labels.iter().position(|l| l == label)?;
        Some(self.figure_values(metric)[i])
    }
}

/// Convenience used by benches: a reduced variant set that still spans
/// the paper's qualitative story (all policies × HEFT/CPOP + extremes of
/// the other heuristics) — 14 variants instead of 30.
pub fn core_variants() -> Vec<Variant> {
    use crate::coordinator::Policy::*;
    use crate::schedulers::SchedulerKind::*;
    let mut out = Vec::new();
    for kind in [Heft, Cpop] {
        for p in [
            NonPreemptive,
            LastK(2),
            LastK(5),
            LastK(10),
            LastK(20),
            Preemptive,
        ] {
            out.push(Variant { policy: p, kind });
        }
    }
    out.push(Variant { policy: NonPreemptive, kind: MinMin });
    out.push(Variant { policy: Preemptive, kind: MinMin });
    out.push(Variant { policy: NonPreemptive, kind: MaxMin });
    out.push(Variant { policy: Preemptive, kind: MaxMin });
    out.push(Variant { policy: NonPreemptive, kind: Random });
    out.push(Variant { policy: Preemptive, kind: Random });
    out
}

// ----------------------------------------------------- reactive sweeps

/// The `--scale` axis of `dts simulate` / `dts policy`: a composite
/// size multiplier layered on `--graphs`, so production-scale sweeps
/// (the 10⁴-task composites the dirty-cone refresh targets — e.g.
/// `--graphs 100 --scale 12`) are one flag away from the paper-default
/// instances instead of a hand-computed graph count.  `scale` 0 is
/// treated as 1 (the unscaled sweep); the product saturates rather than
/// overflowing on absurd inputs.
pub fn scaled_graphs(n_graphs: usize, scale: usize) -> usize {
    n_graphs.saturating_mul(scale.max(1))
}

/// One point of the noise × reaction grid evaluated by `dts simulate`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimScenario {
    pub noise_std: f64,
    pub reaction: Reaction,
}

impl SimScenario {
    pub fn label(&self) -> String {
        format!("σ{:.2}/{}", self.noise_std, self.reaction.label())
    }
}

/// A reactive-runtime sweep: `trials` seeded instances of `dataset`,
/// each executed by the reactive simulator under every scenario, with
/// the same policy × heuristic `variant` throughout.  `scenario` is the
/// workload-shaping axis (weights / deadlines / arrival process); with
/// the default [`Scenario`] instances are bit-identical to the
/// pre-scenario sweeps.
#[derive(Clone, Debug)]
pub struct SimSweepConfig {
    pub dataset: Dataset,
    pub n_graphs: usize,
    pub trials: usize,
    pub seed: u64,
    pub load: f64,
    pub variant: Variant,
    pub scenario: Scenario,
    pub scenarios: Vec<SimScenario>,
    /// Node-pool shard count for the federated runtime
    /// ([`crate::federation`]); 1 = the monolithic reactive coordinator
    /// (bit-identical to pre-federation sweeps).
    pub shards: usize,
    /// Fault injection ([`crate::sim::FaultConfig::NONE`] = bit-identical
    /// to pre-fault sweeps); applied to every cell of the sweep.
    pub faults: crate::sim::FaultConfig,
}

/// One (trial, scenario) cell: realized metrics of the reactive run
/// next to the planned metrics of the same variant under perfect
/// estimates (the static coordinator's plan for the same instance).
#[derive(Clone, Copy, Debug)]
pub struct SimCell {
    pub realized: MetricRow,
    pub planned: MetricRow,
    pub n_replans: usize,
    pub n_straggler_replans: usize,
    pub n_reverted: usize,
    /// Full preemption-cost snapshot of the run, including the PR-8
    /// phase decomposition (refresh / heuristic / bookkeep wall time)
    /// and, for federated cells, cross-shard migrations.
    pub cost: PreemptionCost,
}

impl SimCell {
    /// Realized-over-planned total makespan — the robustness
    /// degradation ratio, now under reactive control instead of the
    /// post-hoc [`crate::robustness::degradation`].
    pub fn degradation(&self) -> f64 {
        degradation_ratio(self.realized.total_makespan, self.planned.total_makespan)
    }
}

/// Realized-over-planned makespan ratio.  A zero planned makespan means
/// an empty/degenerate instance (nothing was scheduled), where "executed
/// as planned" is the only sensible reading: the ratio-neutral 1.0 —
/// not 0.0, which would average into summary means as "infinitely better
/// than planned".
fn degradation_ratio(realized: f64, planned: f64) -> f64 {
    if planned > 0.0 {
        realized / planned
    } else {
        1.0
    }
}

/// The full [`MetricRow`] as a JSON object — shared by the sim and
/// policy sweep dumps and by the `dts serve` epoch summary (the
/// 18-metric block replay tests compare bit-for-bit; the last three are
/// the fault axes, 0.0 on fault-free runs).
pub fn metric_row_json(r: &MetricRow) -> Value {
    json::obj(vec![
        ("total_makespan", json::num(r.total_makespan)),
        ("mean_makespan", json::num(r.mean_makespan)),
        ("mean_flowtime", json::num(r.mean_flowtime)),
        ("utilization", json::num(r.mean_utilization)),
        ("mean_stretch", json::num(r.mean_stretch)),
        ("max_stretch", json::num(r.max_stretch)),
        ("jain_fairness", json::num(r.jain_fairness)),
        ("weighted_mean_stretch", json::num(r.weighted_mean_stretch)),
        ("weighted_max_stretch", json::num(r.weighted_max_stretch)),
        ("weighted_jain", json::num(r.weighted_jain)),
        ("deadline_miss_rate", json::num(r.deadline_miss_rate)),
        ("mean_tardiness", json::num(r.mean_tardiness)),
        ("max_tardiness", json::num(r.max_tardiness)),
        ("weighted_tardiness", json::num(r.weighted_tardiness)),
        ("runtime_s", json::num(r.runtime_s)),
        ("wasted_work_s", json::num(r.wasted_work_s)),
        ("n_reexecuted", json::num(r.n_reexecuted)),
        ("mean_recovery_latency", json::num(r.mean_recovery_latency)),
    ])
}

fn sim_instance(cfg: &SimSweepConfig, trial: usize) -> DynamicProblem {
    cfg.dataset.instance_scenario(
        cfg.n_graphs,
        cfg.seed + trial as u64,
        cfg.load,
        None,
        &cfg.scenario,
    )
}

/// Planned-baseline metrics for one trial: the static coordinator's
/// plan, which is exactly what the reactive runtime would realize at
/// zero noise (modulo the causal re-placement semantics).
fn planned_row(cfg: &SimSweepConfig, prob: &DynamicProblem, trial: usize) -> MetricRow {
    let seed = cfg.seed + trial as u64;
    let mut coord = cfg.variant.coordinator(seed ^ 0x5EED);
    let res = coord.run(prob);
    res.metrics(prob)
}

/// Run one (trial, scenario) cell.  Every realized schedule is checked
/// operationally by [`crate::sim::replay`]; an error is a hard panic —
/// the harness must never report numbers from an invalid execution.
/// With `cfg.shards > 1` the cell runs the federated runtime
/// ([`crate::federation::FederatedCoordinator`]) instead of the
/// monolithic coordinator; the merged global schedule is replay-checked
/// against the **original** problem (sub-networks copy speeds/links
/// verbatim, so shard-local validity implies global validity — the
/// replay proves it rather than assuming it).  The planned baseline
/// stays the monolithic static coordinator either way: realized-vs-
/// planned degradation then reads as the full sharding A/B.
fn run_sim_cell(
    cfg: &SimSweepConfig,
    prob: &DynamicProblem,
    trial: usize,
    scenario: &SimScenario,
    planned: &MetricRow,
) -> SimCell {
    let seed = cfg.seed + trial as u64;
    let sim_cfg = SimConfig {
        noise_std: scenario.noise_std,
        noise_seed: seed ^ 0xA11CE,
        reaction: scenario.reaction,
        record_frozen: false,
        full_refresh: false,
        faults: cfg.faults,
    };
    let (realized, n_replans, n_straggler_replans, n_reverted, n_assigned, cost) = if cfg.shards > 1
    {
        let fed = crate::federation::FederatedCoordinator::new(
            cfg.variant.policy,
            cfg.variant.kind,
            seed ^ 0x5EED,
            sim_cfg,
            cfg.shards,
        );
        let res = fed.run(prob);
        let row = res.metrics(prob);
        let rep = crate::sim::replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(
            rep.errors.is_empty(),
            "invalid federated schedule ({} shards) from {} under {} on {} trial {trial}: {:?}",
            cfg.shards,
            cfg.variant.label(),
            scenario.label(),
            cfg.dataset.name(),
            &rep.errors[..rep.errors.len().min(3)]
        );
        (
            row,
            res.n_replans(),
            res.n_straggler_replans(),
            res.n_reverted_total(),
            res.schedule.n_assigned(),
            res.preemption_cost(),
        )
    } else {
        let mut rc = ReactiveCoordinator::new(
            cfg.variant.policy,
            cfg.variant.kind.make(seed ^ 0x5EED),
            sim_cfg,
        );
        let res = rc.run(prob);
        let rep = crate::sim::replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(
            rep.errors.is_empty(),
            "invalid realized schedule from {} under {} on {} trial {trial}: {:?}",
            cfg.variant.label(),
            scenario.label(),
            cfg.dataset.name(),
            &rep.errors[..rep.errors.len().min(3)]
        );
        (
            res.metrics(prob),
            res.n_replans(),
            res.n_straggler_replans(),
            res.n_reverted_total(),
            res.schedule.n_assigned(),
            res.preemption_cost(),
        )
    };
    assert_eq!(n_assigned, prob.total_tasks());
    SimCell {
        realized,
        planned: *planned,
        n_replans,
        n_straggler_replans,
        n_reverted,
        cost,
    }
}

/// Raw sim-sweep output: `rows[trial][scenario]`.
#[derive(Clone, Debug)]
pub struct SimSweepResult {
    pub config: SimSweepConfig,
    pub labels: Vec<String>,
    pub rows: Vec<Vec<SimCell>>,
}

/// Serial reference implementation of the sim sweep.
pub fn run_sim_sweep(cfg: &SimSweepConfig) -> SimSweepResult {
    let labels: Vec<String> = cfg.scenarios.iter().map(|s| s.label()).collect();
    let mut rows = Vec::with_capacity(cfg.trials);
    for trial in 0..cfg.trials {
        let prob = sim_instance(cfg, trial);
        let planned = planned_row(cfg, &prob, trial);
        rows.push(
            cfg.scenarios
                .iter()
                .map(|s| run_sim_cell(cfg, &prob, trial, s, &planned))
                .collect(),
        );
    }
    SimSweepResult {
        config: cfg.clone(),
        labels,
        rows,
    }
}

/// Parallel sim sweep, deterministic at any thread count: (trial ×
/// scenario) cells fan out over a `std::thread::scope` work queue,
/// instances and planned baselines derive from `seed + trial` alone and
/// are shared per trial through a `OnceLock`, noise factors are a pure
/// function of `(noise_std, seed, gid)`, and results are collected in
/// cell order — same construction as [`run_sweep_parallel`].
pub fn run_sim_sweep_parallel(cfg: &SimSweepConfig, jobs: usize) -> SimSweepResult {
    let jobs = jobs.max(1);
    let n_sc = cfg.scenarios.len();
    let n_cells = cfg.trials * n_sc;
    if jobs == 1 || n_cells <= 1 {
        return run_sim_sweep(cfg);
    }

    let instances: Vec<OnceLock<(DynamicProblem, MetricRow)>> =
        (0..cfg.trials).map(|_| OnceLock::new()).collect();
    let next_cell = AtomicUsize::new(0);
    let mut flat: Vec<Option<SimCell>> = vec![None; n_cells];

    let tele_on = crate::telemetry::enabled();
    std::thread::scope(|scope| {
        let workers: Vec<_> = (0..jobs.min(n_cells))
            .map(|_| {
                scope.spawn(|| {
                    // fresh worker thread, fresh telemetry registry;
                    // inherit the spawner's enable gate and hand the
                    // accumulated registry back with the results
                    crate::telemetry::set_enabled(tele_on);
                    let mut done: Vec<(usize, SimCell)> = Vec::new();
                    loop {
                        let cell = next_cell.fetch_add(1, Ordering::Relaxed);
                        if cell >= n_cells {
                            break;
                        }
                        let trial = cell / n_sc;
                        let si = cell % n_sc;
                        let pair = instances[trial].get_or_init(|| {
                            let prob = sim_instance(cfg, trial);
                            let planned = planned_row(cfg, &prob, trial);
                            (prob, planned)
                        });
                        done.push((
                            cell,
                            run_sim_cell(cfg, &pair.0, trial, &cfg.scenarios[si], &pair.1),
                        ));
                    }
                    (done, crate::telemetry::take())
                })
            })
            .collect();
        // Counters are additive over cells and each cell's counts are
        // deterministic, so the absorbed totals are independent of the
        // work-queue assignment; absorbing in worker order keeps the
        // process itself reproducible.
        for w in workers {
            let (cells, tele) = w.join().expect("sim sweep worker panicked");
            for (cell, c) in cells {
                flat[cell] = Some(c);
            }
            crate::telemetry::absorb(&tele);
        }
    });

    let mut rows = Vec::with_capacity(cfg.trials);
    let mut it = flat.into_iter();
    for _ in 0..cfg.trials {
        rows.push(
            (&mut it)
                .take(n_sc)
                .map(|r| r.expect("cell not computed"))
                .collect(),
        );
    }
    SimSweepResult {
        config: cfg.clone(),
        labels: cfg.scenarios.iter().map(|s| s.label()).collect(),
        rows,
    }
}

impl SimSweepResult {
    /// Mean across trials of one realized metric for scenario `si`.
    pub fn realized_mean(&self, si: usize, metric: Metric) -> f64 {
        mean(
            &self
                .rows
                .iter()
                .map(|r| r[si].realized.get(metric))
                .collect::<Vec<_>>(),
        )
    }

    /// Mean realized-over-planned total-makespan ratio for scenario `si`.
    pub fn degradation_mean(&self, si: usize) -> f64 {
        mean(
            &self
                .rows
                .iter()
                .map(|r| r[si].degradation())
                .collect::<Vec<_>>(),
        )
    }

    /// Mean (total, straggler-triggered) replan counts for scenario `si`.
    pub fn replans_mean(&self, si: usize) -> (f64, f64) {
        let total = mean(
            &self
                .rows
                .iter()
                .map(|r| r[si].n_replans as f64)
                .collect::<Vec<_>>(),
        );
        let straggler = mean(
            &self
                .rows
                .iter()
                .map(|r| r[si].n_straggler_replans as f64)
                .collect::<Vec<_>>(),
        );
        (total, straggler)
    }

    /// Markdown summary: one row per scenario, the key realized metrics
    /// (incl. the deadline axes) plus degradation and replan activity.
    pub fn summary_table(&self) -> String {
        let rows: Vec<Vec<String>> = (0..self.labels.len())
            .map(|si| {
                let (replans, stragglers) = self.replans_mean(si);
                vec![
                    self.labels[si].clone(),
                    report::fmt(self.realized_mean(si, Metric::TotalMakespan)),
                    report::fmt(self.realized_mean(si, Metric::MeanStretch)),
                    report::fmt(self.realized_mean(si, Metric::MaxStretch)),
                    report::fmt(self.realized_mean(si, Metric::JainFairness)),
                    report::fmt(self.realized_mean(si, Metric::DeadlineMissRate)),
                    report::fmt(self.realized_mean(si, Metric::MeanTardiness)),
                    report::fmt(self.degradation_mean(si)),
                    report::fmt(replans),
                    report::fmt(stragglers),
                ]
            })
            .collect();
        report::markdown_table(
            &[
                "scenario",
                "makespan",
                "mean stretch",
                "max stretch",
                "jain",
                "miss",
                "tardiness",
                "degradation",
                "replans",
                "straggler",
            ],
            &rows,
        )
    }

    /// CSV with the full realized metric suite per scenario (means
    /// across trials), plus the planned baseline and replan activity.
    pub fn to_csv(&self) -> String {
        let mut rows = Vec::new();
        for (si, label) in self.labels.iter().enumerate() {
            let sc = &self.config.scenarios[si];
            let mut row = vec![
                self.config.dataset.name().to_string(),
                self.config.variant.label(),
                self.config.scenario.label(),
                label.clone(),
                format!("{}", sc.noise_std),
                sc.reaction.label(),
                format!("{}", self.config.shards),
            ];
            for m in Metric::ALL {
                row.push(format!("{}", self.realized_mean(si, m)));
            }
            let planned_mk = mean(
                &self
                    .rows
                    .iter()
                    .map(|r| r[si].planned.total_makespan)
                    .collect::<Vec<_>>(),
            );
            let (replans, stragglers) = self.replans_mean(si);
            let reverted = mean(
                &self
                    .rows
                    .iter()
                    .map(|r| r[si].n_reverted as f64)
                    .collect::<Vec<_>>(),
            );
            row.push(format!("{planned_mk}"));
            row.push(format!("{}", self.degradation_mean(si)));
            row.push(format!("{replans}"));
            row.push(format!("{stragglers}"));
            row.push(format!("{reverted}"));
            let phase = |f: &dyn Fn(&SimCell) -> f64| {
                mean(&self.rows.iter().map(|r| f(&r[si])).collect::<Vec<_>>())
            };
            row.push(format!("{}", phase(&|c| c.cost.replan_wall_s)));
            row.push(format!("{}", phase(&|c| c.cost.refresh_wall_s)));
            row.push(format!("{}", phase(&|c| c.cost.heuristic_wall_s)));
            row.push(format!("{}", phase(&|c| c.cost.bookkeep_wall_s)));
            rows.push(row);
        }
        let headers = vec![
            "dataset",
            "variant",
            "workload",
            "scenario",
            "noise_std",
            "reaction",
            "shards",
            "total_makespan",
            "mean_makespan",
            "mean_flowtime",
            "utilization",
            "mean_stretch",
            "max_stretch",
            "jain_fairness",
            "weighted_mean_stretch",
            "weighted_max_stretch",
            "weighted_jain",
            "deadline_miss_rate",
            "mean_tardiness",
            "max_tardiness",
            "weighted_tardiness",
            "runtime_s",
            "wasted_work_s",
            "n_reexecuted",
            "mean_recovery_latency",
            "planned_total_makespan",
            "degradation",
            "replans",
            "straggler_replans",
            "reverted_tasks",
            "replan_wall_s",
            "refresh_wall_s",
            "heuristic_wall_s",
            "bookkeep_wall_s",
        ];
        report::csv(&headers, &rows)
    }

    /// JSON dump: config + per-trial realized/planned rows per scenario.
    pub fn to_json(&self) -> Value {
        let metric_obj = metric_row_json;
        let trials = self
            .rows
            .iter()
            .map(|trial| {
                json::arr(
                    trial
                        .iter()
                        .map(|c| {
                            json::obj(vec![
                                ("realized", metric_obj(&c.realized)),
                                ("planned", metric_obj(&c.planned)),
                                ("degradation", json::num(c.degradation())),
                                ("replans", json::num(c.n_replans as f64)),
                                (
                                    "straggler_replans",
                                    json::num(c.n_straggler_replans as f64),
                                ),
                                ("reverted", json::num(c.n_reverted as f64)),
                                (
                                    "replan_wall_s",
                                    json::num(c.cost.replan_wall_s),
                                ),
                                (
                                    "refresh_wall_s",
                                    json::num(c.cost.refresh_wall_s),
                                ),
                                (
                                    "heuristic_wall_s",
                                    json::num(c.cost.heuristic_wall_s),
                                ),
                                (
                                    "bookkeep_wall_s",
                                    json::num(c.cost.bookkeep_wall_s),
                                ),
                            ])
                        })
                        .collect(),
                )
            })
            .collect();
        json::obj(vec![
            (
                "config",
                json::obj(vec![
                    ("dataset", json::s(self.config.dataset.name())),
                    ("variant", json::s(&self.config.variant.label())),
                    ("workload", json::s(&self.config.scenario.label())),
                    ("n_graphs", json::num(self.config.n_graphs as f64)),
                    ("trials", json::num(self.config.trials as f64)),
                    ("seed", json::num(self.config.seed as f64)),
                    ("load", json::num(self.config.load)),
                    ("shards", json::num(self.config.shards as f64)),
                ]),
            ),
            (
                "scenarios",
                json::arr(self.labels.iter().map(|l| json::s(l)).collect()),
            ),
            ("trials", json::arr(trials)),
        ])
    }

    /// One NDJSON [`CellSpan`](crate::telemetry::export::CellSpan) per
    /// scenario: replan counts and the phase-decomposed replan wall
    /// time summed across trials (`dts simulate --telemetry`).
    pub fn telemetry_spans(&self) -> Vec<crate::telemetry::export::CellSpan> {
        self.labels
            .iter()
            .enumerate()
            .map(|(si, label)| {
                let mut sp = crate::telemetry::export::CellSpan {
                    label: format!("{} {}", self.config.variant.label(), label),
                    dataset: self.config.dataset.name().to_string(),
                    ..Default::default()
                };
                for trial in &self.rows {
                    let c = &trial[si];
                    sp.replans += c.n_replans;
                    sp.refresh_s += c.cost.refresh_wall_s;
                    sp.heuristic_s += c.cost.heuristic_wall_s;
                    sp.bookkeep_s += c.cost.bookkeep_wall_s;
                    sp.wall_s += c.cost.replan_wall_s;
                }
                sp
            })
            .collect()
    }
}

// ------------------------------------------------ policy-engine sweeps

/// One point of the joint k × θ × budget grid evaluated by `dts policy`:
/// a noise level plus a [`PolicySpec`] controller description.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyScenario {
    pub noise_std: f64,
    pub spec: PolicySpec,
}

impl PolicyScenario {
    pub fn label(&self) -> String {
        format!("σ{:.2}/{}", self.noise_std, self.spec.label())
    }
}

/// A policy-engine sweep: `trials` seeded instances of `dataset`, each
/// executed by the reactive simulator under every scenario, with the
/// same arrival policy × heuristic `variant` throughout.  Instances,
/// noise and heuristic seeds match [`SimSweepConfig`]'s construction
/// exactly, so a [`PolicySpec::FixedLastK`] scenario reproduces the
/// PR-2 `Reaction::LastK` sim-sweep cell bit-for-bit.
#[derive(Clone, Debug)]
pub struct PolicySweepConfig {
    pub dataset: Dataset,
    pub n_graphs: usize,
    pub trials: usize,
    pub seed: u64,
    pub load: f64,
    pub variant: Variant,
    /// workload-shaping axis (weights / deadlines / arrival process);
    /// the default [`Scenario`] reproduces the pre-scenario instances
    /// bit-exactly
    pub scenario: Scenario,
    pub scenarios: Vec<PolicyScenario>,
    /// Fault injection ([`crate::sim::FaultConfig::NONE`] = bit-identical
    /// to pre-fault sweeps); applied to every cell of the sweep.
    pub faults: crate::sim::FaultConfig,
}

/// One (trial, scenario) cell of the policy sweep: realized metrics,
/// the planned baseline, and what the controller *spent* to earn them.
#[derive(Clone, Copy, Debug)]
pub struct PolicyCell {
    pub realized: MetricRow,
    pub planned: MetricRow,
    pub cost: PreemptionCost,
}

impl PolicyCell {
    /// Realized-over-planned total makespan (1.0-neutral on degenerate
    /// instances, like [`SimCell::degradation`]).
    pub fn degradation(&self) -> f64 {
        degradation_ratio(self.realized.total_makespan, self.planned.total_makespan)
    }
}

fn policy_instance(cfg: &PolicySweepConfig, trial: usize) -> DynamicProblem {
    cfg.dataset.instance_scenario(
        cfg.n_graphs,
        cfg.seed + trial as u64,
        cfg.load,
        None,
        &cfg.scenario,
    )
}

fn policy_planned_row(
    cfg: &PolicySweepConfig,
    prob: &DynamicProblem,
    trial: usize,
) -> MetricRow {
    let seed = cfg.seed + trial as u64;
    let mut coord = cfg.variant.coordinator(seed ^ 0x5EED);
    let res = coord.run(prob);
    res.metrics(prob)
}

/// Run one (trial, scenario) policy cell.  Same replay-or-panic contract
/// as [`run_sim_cell`]; the controller is built fresh per cell
/// ([`PolicySpec::make`]), so no mutable state crosses cells and the
/// sweep stays bit-identical at any `--jobs`.
fn run_policy_cell(
    cfg: &PolicySweepConfig,
    prob: &DynamicProblem,
    trial: usize,
    scenario: &PolicyScenario,
    planned: &MetricRow,
) -> PolicyCell {
    let seed = cfg.seed + trial as u64;
    let sim_cfg = SimConfig {
        noise_std: scenario.noise_std,
        noise_seed: seed ^ 0xA11CE,
        reaction: Reaction::None,
        record_frozen: false,
        full_refresh: false,
        faults: cfg.faults,
    };
    let mut rc = ReactiveCoordinator::with_policy(
        cfg.variant.policy,
        cfg.variant.kind.make(seed ^ 0x5EED),
        sim_cfg,
        scenario.spec.make(),
    );
    let res = rc.run(prob);
    assert_eq!(res.schedule.n_assigned(), prob.total_tasks());
    let rep = crate::sim::replay(&res.schedule, &prob.graphs, &prob.network);
    assert!(
        rep.errors.is_empty(),
        "invalid realized schedule from {} under {} on {} trial {trial}: {:?}",
        cfg.variant.label(),
        scenario.label(),
        cfg.dataset.name(),
        &rep.errors[..rep.errors.len().min(3)]
    );
    PolicyCell {
        realized: res.metrics(prob),
        planned: *planned,
        cost: res.preemption_cost(),
    }
}

/// Raw policy-sweep output: `rows[trial][scenario]`.
#[derive(Clone, Debug)]
pub struct PolicySweepResult {
    pub config: PolicySweepConfig,
    pub labels: Vec<String>,
    pub rows: Vec<Vec<PolicyCell>>,
}

/// Serial reference implementation of the policy sweep.
pub fn run_policy_sweep(cfg: &PolicySweepConfig) -> PolicySweepResult {
    let labels: Vec<String> = cfg.scenarios.iter().map(|s| s.label()).collect();
    let mut rows = Vec::with_capacity(cfg.trials);
    for trial in 0..cfg.trials {
        let prob = policy_instance(cfg, trial);
        let planned = policy_planned_row(cfg, &prob, trial);
        rows.push(
            cfg.scenarios
                .iter()
                .map(|s| run_policy_cell(cfg, &prob, trial, s, &planned))
                .collect(),
        );
    }
    PolicySweepResult {
        config: cfg.clone(),
        labels,
        rows,
    }
}

/// Parallel policy sweep, deterministic at any thread count: (trial ×
/// scenario) cells fan out over a `std::thread::scope` work queue,
/// instances and planned baselines derive from `seed + trial` alone and
/// are shared per trial through a `OnceLock`, each cell builds its own
/// controller from the scenario's [`PolicySpec`], and results are
/// collected in cell order — the same construction as
/// [`run_sweep_parallel`] / [`run_sim_sweep_parallel`].  Only the
/// measured wall-clock quantities (`runtime_s`, `replan_wall_s`) vary
/// between runs; every schedule-derived metric and every replan/revert
/// count is bit-identical.
pub fn run_policy_sweep_parallel(cfg: &PolicySweepConfig, jobs: usize) -> PolicySweepResult {
    let jobs = jobs.max(1);
    let n_sc = cfg.scenarios.len();
    let n_cells = cfg.trials * n_sc;
    if jobs == 1 || n_cells <= 1 {
        return run_policy_sweep(cfg);
    }

    let instances: Vec<OnceLock<(DynamicProblem, MetricRow)>> =
        (0..cfg.trials).map(|_| OnceLock::new()).collect();
    let next_cell = AtomicUsize::new(0);
    let mut flat: Vec<Option<PolicyCell>> = vec![None; n_cells];

    let tele_on = crate::telemetry::enabled();
    std::thread::scope(|scope| {
        let workers: Vec<_> = (0..jobs.min(n_cells))
            .map(|_| {
                scope.spawn(|| {
                    crate::telemetry::set_enabled(tele_on);
                    let mut done: Vec<(usize, PolicyCell)> = Vec::new();
                    loop {
                        let cell = next_cell.fetch_add(1, Ordering::Relaxed);
                        if cell >= n_cells {
                            break;
                        }
                        let trial = cell / n_sc;
                        let si = cell % n_sc;
                        let pair = instances[trial].get_or_init(|| {
                            let prob = policy_instance(cfg, trial);
                            let planned = policy_planned_row(cfg, &prob, trial);
                            (prob, planned)
                        });
                        done.push((
                            cell,
                            run_policy_cell(cfg, &pair.0, trial, &cfg.scenarios[si], &pair.1),
                        ));
                    }
                    (done, crate::telemetry::take())
                })
            })
            .collect();
        for w in workers {
            let (cells, tele) = w.join().expect("policy sweep worker panicked");
            for (cell, c) in cells {
                flat[cell] = Some(c);
            }
            crate::telemetry::absorb(&tele);
        }
    });

    let mut rows = Vec::with_capacity(cfg.trials);
    let mut it = flat.into_iter();
    for _ in 0..cfg.trials {
        rows.push(
            (&mut it)
                .take(n_sc)
                .map(|r| r.expect("cell not computed"))
                .collect(),
        );
    }
    PolicySweepResult {
        config: cfg.clone(),
        labels: cfg.scenarios.iter().map(|s| s.label()).collect(),
        rows,
    }
}

impl PolicySweepResult {
    /// Mean across trials of one realized metric for scenario `si`.
    pub fn realized_mean(&self, si: usize, metric: Metric) -> f64 {
        mean(
            &self
                .rows
                .iter()
                .map(|r| r[si].realized.get(metric))
                .collect::<Vec<_>>(),
        )
    }

    /// Mean realized-over-planned total-makespan ratio for scenario `si`.
    pub fn degradation_mean(&self, si: usize) -> f64 {
        mean(
            &self
                .rows
                .iter()
                .map(|r| r[si].degradation())
                .collect::<Vec<_>>(),
        )
    }

    /// Mean preemption cost for scenario `si` (counts as f64 means).
    pub fn cost_mean(&self, si: usize) -> (f64, f64, f64, f64) {
        let of = |f: &dyn Fn(&PreemptionCost) -> f64| {
            mean(&self.rows.iter().map(|r| f(&r[si].cost)).collect::<Vec<_>>())
        };
        (
            of(&|c| c.replans as f64),
            of(&|c| c.straggler_replans as f64),
            of(&|c| c.reverted_tasks as f64),
            of(&|c| c.replan_wall_s),
        )
    }

    /// Mean replan-wall phase decomposition for scenario `si`:
    /// `(refresh_wall_s, heuristic_wall_s, bookkeep_wall_s)` means.
    pub fn phase_mean(&self, si: usize) -> (f64, f64, f64) {
        let of = |f: &dyn Fn(&PreemptionCost) -> f64| {
            mean(&self.rows.iter().map(|r| f(&r[si].cost)).collect::<Vec<_>>())
        };
        (
            of(&|c| c.refresh_wall_s),
            of(&|c| c.heuristic_wall_s),
            of(&|c| c.bookkeep_wall_s),
        )
    }

    /// Markdown summary: one row per scenario — the quality axes next to
    /// the preemption-cost axes, the figure of the parsimonious-
    /// preemption study (quality bought vs budget spent).
    pub fn summary_table(&self) -> String {
        let rows: Vec<Vec<String>> = (0..self.labels.len())
            .map(|si| {
                let (replans, stragglers, reverted, wall) = self.cost_mean(si);
                vec![
                    self.labels[si].clone(),
                    report::fmt(self.realized_mean(si, Metric::TotalMakespan)),
                    report::fmt(self.realized_mean(si, Metric::MeanStretch)),
                    report::fmt(self.realized_mean(si, Metric::MaxStretch)),
                    report::fmt(self.realized_mean(si, Metric::JainFairness)),
                    report::fmt(self.realized_mean(si, Metric::DeadlineMissRate)),
                    report::fmt(self.realized_mean(si, Metric::WeightedTardiness)),
                    report::fmt(self.degradation_mean(si)),
                    report::fmt(replans),
                    report::fmt(stragglers),
                    report::fmt(reverted),
                    format!("{:.3}", wall * 1e3),
                ]
            })
            .collect();
        report::markdown_table(
            &[
                "scenario",
                "makespan",
                "mean stretch",
                "max stretch",
                "jain",
                "miss",
                "w-tardiness",
                "degradation",
                "replans",
                "straggler",
                "reverted",
                "replan ms",
            ],
            &rows,
        )
    }

    /// CSV: the full realized metric suite per scenario (means across
    /// trials) plus the planned baseline, degradation and the
    /// preemption-cost columns.
    pub fn to_csv(&self) -> String {
        let mut rows = Vec::new();
        for (si, label) in self.labels.iter().enumerate() {
            let sc = &self.config.scenarios[si];
            let mut row = vec![
                self.config.dataset.name().to_string(),
                self.config.variant.label(),
                self.config.scenario.label(),
                label.clone(),
                format!("{}", sc.noise_std),
                sc.spec.label(),
            ];
            for m in Metric::ALL {
                row.push(format!("{}", self.realized_mean(si, m)));
            }
            let planned_mk = mean(
                &self
                    .rows
                    .iter()
                    .map(|r| r[si].planned.total_makespan)
                    .collect::<Vec<_>>(),
            );
            let (replans, stragglers, reverted, wall) = self.cost_mean(si);
            let (refresh, heuristic, bookkeep) = self.phase_mean(si);
            row.push(format!("{planned_mk}"));
            row.push(format!("{}", self.degradation_mean(si)));
            row.push(format!("{replans}"));
            row.push(format!("{stragglers}"));
            row.push(format!("{reverted}"));
            row.push(format!("{wall}"));
            row.push(format!("{refresh}"));
            row.push(format!("{heuristic}"));
            row.push(format!("{bookkeep}"));
            rows.push(row);
        }
        let headers = vec![
            "dataset",
            "variant",
            "workload",
            "scenario",
            "noise_std",
            "policy",
            "total_makespan",
            "mean_makespan",
            "mean_flowtime",
            "utilization",
            "mean_stretch",
            "max_stretch",
            "jain_fairness",
            "weighted_mean_stretch",
            "weighted_max_stretch",
            "weighted_jain",
            "deadline_miss_rate",
            "mean_tardiness",
            "max_tardiness",
            "weighted_tardiness",
            "runtime_s",
            "wasted_work_s",
            "n_reexecuted",
            "mean_recovery_latency",
            "planned_total_makespan",
            "degradation",
            "replans",
            "straggler_replans",
            "reverted_tasks",
            "replan_wall_s",
            "refresh_wall_s",
            "heuristic_wall_s",
            "bookkeep_wall_s",
        ];
        report::csv(&headers, &rows)
    }

    /// JSON dump: config + per-trial realized/planned/cost per scenario.
    pub fn to_json(&self) -> Value {
        let trials = self
            .rows
            .iter()
            .map(|trial| {
                json::arr(
                    trial
                        .iter()
                        .map(|c| {
                            json::obj(vec![
                                ("realized", metric_row_json(&c.realized)),
                                ("planned", metric_row_json(&c.planned)),
                                ("degradation", json::num(c.degradation())),
                                ("replans", json::num(c.cost.replans as f64)),
                                (
                                    "straggler_replans",
                                    json::num(c.cost.straggler_replans as f64),
                                ),
                                (
                                    "reverted_tasks",
                                    json::num(c.cost.reverted_tasks as f64),
                                ),
                                ("replan_wall_s", json::num(c.cost.replan_wall_s)),
                                (
                                    "refresh_wall_s",
                                    json::num(c.cost.refresh_wall_s),
                                ),
                                (
                                    "heuristic_wall_s",
                                    json::num(c.cost.heuristic_wall_s),
                                ),
                                (
                                    "bookkeep_wall_s",
                                    json::num(c.cost.bookkeep_wall_s),
                                ),
                            ])
                        })
                        .collect(),
                )
            })
            .collect();
        json::obj(vec![
            (
                "config",
                json::obj(vec![
                    ("dataset", json::s(self.config.dataset.name())),
                    ("variant", json::s(&self.config.variant.label())),
                    ("workload", json::s(&self.config.scenario.label())),
                    ("n_graphs", json::num(self.config.n_graphs as f64)),
                    ("trials", json::num(self.config.trials as f64)),
                    ("seed", json::num(self.config.seed as f64)),
                    ("load", json::num(self.config.load)),
                ]),
            ),
            (
                "scenarios",
                json::arr(self.labels.iter().map(|l| json::s(l)).collect()),
            ),
            ("trials", json::arr(trials)),
        ])
    }

    /// One NDJSON [`CellSpan`](crate::telemetry::export::CellSpan) per
    /// controller scenario: replan counts and the phase-decomposed
    /// replan wall time summed across trials (`dts policy --telemetry`).
    pub fn telemetry_spans(&self) -> Vec<crate::telemetry::export::CellSpan> {
        self.labels
            .iter()
            .enumerate()
            .map(|(si, label)| {
                let mut sp = crate::telemetry::export::CellSpan {
                    label: format!("{} {}", self.config.variant.label(), label),
                    dataset: self.config.dataset.name().to_string(),
                    ..Default::default()
                };
                for trial in &self.rows {
                    let c = &trial[si];
                    sp.replans += c.cost.replans;
                    sp.refresh_s += c.cost.refresh_wall_s;
                    sp.heuristic_s += c.cost.heuristic_wall_s;
                    sp.bookkeep_s += c.cost.bookkeep_wall_s;
                    sp.wall_s += c.cost.replan_wall_s;
                }
                sp
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            dataset: Dataset::Synthetic,
            n_graphs: 8,
            trials: 2,
            seed: 3,
            load: 0.5,
            variants: vec![
                Variant::parse("NP-HEFT").unwrap(),
                Variant::parse("P-HEFT").unwrap(),
                Variant::parse("2P-HEFT").unwrap(),
            ],
        }
    }

    #[test]
    fn sweep_shape_and_validity() {
        let r = run_sweep(&tiny_cfg());
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.rows[0].len(), 3);
        assert_eq!(r.labels, vec!["NP-HEFT", "P-HEFT", "2P-HEFT"]);
    }

    #[test]
    fn parallel_sweep_is_deterministic_across_thread_counts() {
        // Every schedule-derived metric must be bit-identical whether the
        // (trial × variant) cells run on 1 thread or many; only the
        // wall-clock runtime_s measurement may differ.
        let mut cfg = tiny_cfg();
        cfg.trials = 3;
        cfg.variants.push(Variant::parse("P-MinMin").unwrap());
        cfg.variants.push(Variant::parse("5P-Random").unwrap());
        let serial = run_sweep_parallel(&cfg, 1);
        for jobs in [2, 4, 7] {
            let parallel = run_sweep_parallel(&cfg, jobs);
            assert_eq!(serial.labels, parallel.labels);
            assert_eq!(serial.rows.len(), parallel.rows.len());
            for (trial, (rs, rp)) in
                serial.rows.iter().zip(parallel.rows.iter()).enumerate()
            {
                assert_eq!(rs.len(), rp.len());
                for (vi, (s, p)) in rs.iter().zip(rp.iter()).enumerate() {
                    let sig = |m: &MetricRow| {
                        (
                            m.total_makespan.to_bits(),
                            m.mean_makespan.to_bits(),
                            m.mean_flowtime.to_bits(),
                            m.mean_utilization.to_bits(),
                        )
                    };
                    assert_eq!(
                        sig(s),
                        sig(p),
                        "jobs={jobs}, trial {trial}, variant {}",
                        serial.labels[vi]
                    );
                    assert!(p.runtime_s > 0.0, "per-coordinator runtime recorded");
                }
            }
        }
    }

    #[test]
    fn normalization_minimum_is_one() {
        let r = run_sweep(&tiny_cfg());
        for m in [Metric::TotalMakespan, Metric::MeanMakespan, Metric::MeanFlowtime] {
            let vals = r.figure_values(m);
            // averaged normalized values: every variant >= 1, and in each
            // trial someone was exactly 1, so the min is >= 1 but close.
            assert!(vals.iter().all(|&v| v >= 1.0 - 1e-12), "{m:?}: {vals:?}");
            let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(lo < 2.0, "{m:?}: implausible normalization {vals:?}");
        }
    }

    #[test]
    fn utilization_is_raw_and_bounded() {
        let r = run_sweep(&tiny_cfg());
        for &u in &r.figure_values(Metric::Utilization) {
            assert!(u > 0.0 && u <= 1.0);
        }
    }

    #[test]
    fn tables_and_csv_render() {
        let r = run_sweep(&tiny_cfg());
        let t = r.figure_table(Metric::TotalMakespan);
        assert!(t.contains("NP-HEFT") && t.contains("P-HEFT"));
        let c = r.to_csv();
        assert_eq!(c.lines().count(), 4); // header + 3 variants
        let j = r.to_json();
        assert!(j.get("labels").is_some());
        // json roundtrips through the parser
        let round = Value::from_str(&j.to_string()).unwrap();
        assert_eq!(round.get("labels"), j.get("labels"));
    }

    #[test]
    fn value_of_lookup() {
        let r = run_sweep(&tiny_cfg());
        assert!(r.value_of("P-HEFT", Metric::Runtime).is_some());
        assert!(r.value_of("nope", Metric::Runtime).is_none());
    }

    #[test]
    fn core_variants_cover_policy_axis() {
        let vs = core_variants();
        assert_eq!(vs.len(), 18);
        let labels: Vec<String> = vs.iter().map(|v| v.label()).collect();
        assert!(labels.contains(&"5P-HEFT".to_string()));
        assert!(labels.contains(&"P-Random".to_string()));
    }

    fn tiny_sim_cfg() -> SimSweepConfig {
        SimSweepConfig {
            dataset: Dataset::Synthetic,
            n_graphs: 6,
            trials: 2,
            seed: 5,
            load: 0.5,
            variant: Variant::parse("5P-HEFT").unwrap(),
            scenario: Scenario::default(),
            scenarios: vec![
                SimScenario {
                    noise_std: 0.0,
                    reaction: Reaction::None,
                },
                SimScenario {
                    noise_std: 0.4,
                    reaction: Reaction::None,
                },
                SimScenario {
                    noise_std: 0.4,
                    reaction: Reaction::LastK {
                        k: 3,
                        threshold: 0.2,
                    },
                },
            ],
            shards: 1,
            faults: crate::sim::FaultConfig::NONE,
        }
    }

    /// A sharded sweep produces complete, replay-valid cells (the
    /// federated branch of [`run_sim_cell`]) and stays bit-identical
    /// across thread counts — migrations and all.
    #[test]
    fn sharded_sim_sweep_runs_and_is_jobs_deterministic() {
        let mut cfg = tiny_sim_cfg();
        cfg.shards = 3;
        let serial = run_sim_sweep_parallel(&cfg, 1);
        let parallel = run_sim_sweep_parallel(&cfg, 4);
        assert_eq!(serial.rows.len(), 2);
        let sig = |c: &SimCell| {
            (
                c.realized.total_makespan.to_bits(),
                c.realized.mean_stretch.to_bits(),
                c.n_replans,
                c.n_straggler_replans,
                c.n_reverted,
            )
        };
        for (a, b) in serial.rows.iter().flatten().zip(parallel.rows.iter().flatten()) {
            assert_eq!(sig(a), sig(b));
        }
        assert!(serial.to_csv().lines().next().unwrap().contains("shards"));
    }

    #[test]
    fn scaled_graphs_multiplies_and_saturates() {
        assert_eq!(scaled_graphs(16, 1), 16);
        assert_eq!(scaled_graphs(100, 12), 1200);
        assert_eq!(scaled_graphs(16, 0), 16, "scale 0 means unscaled");
        assert_eq!(scaled_graphs(usize::MAX, 2), usize::MAX);
    }

    #[test]
    fn sim_sweep_shape_and_sanity() {
        let r = run_sim_sweep(&tiny_sim_cfg());
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.rows[0].len(), 3);
        assert_eq!(r.labels.len(), 3);
        for row in &r.rows {
            for c in row {
                assert!(c.realized.total_makespan > 0.0);
                assert!(c.planned.total_makespan > 0.0);
                assert!(c.degradation() > 0.0);
                assert!(
                    c.realized.jain_fairness > 0.0
                        && c.realized.jain_fairness <= 1.0 + 1e-12
                );
                assert!(c.realized.max_stretch + 1e-12 >= c.realized.mean_stretch);
            }
            // the reactive scenario (threshold armed) may replan more,
            // never less, than its no-reaction twin at the same noise
            assert!(row[2].n_replans >= row[1].n_replans);
        }
    }

    #[test]
    fn sim_sweep_parallel_is_deterministic_across_thread_counts() {
        let cfg = tiny_sim_cfg();
        let serial = run_sim_sweep_parallel(&cfg, 1);
        let sig = |c: &SimCell| {
            (
                c.realized.total_makespan.to_bits(),
                c.realized.mean_makespan.to_bits(),
                c.realized.mean_flowtime.to_bits(),
                c.realized.mean_utilization.to_bits(),
                c.realized.mean_stretch.to_bits(),
                c.realized.max_stretch.to_bits(),
                c.realized.jain_fairness.to_bits(),
                c.planned.total_makespan.to_bits(),
                c.n_replans,
                c.n_straggler_replans,
                c.n_reverted,
            )
        };
        for jobs in [2, 5] {
            let par = run_sim_sweep_parallel(&cfg, jobs);
            assert_eq!(serial.labels, par.labels);
            for (trial, (rs, rp)) in serial.rows.iter().zip(par.rows.iter()).enumerate() {
                for (si, (a, b)) in rs.iter().zip(rp.iter()).enumerate() {
                    assert_eq!(sig(a), sig(b), "jobs={jobs}, trial {trial}, scenario {si}");
                }
            }
        }
    }

    #[test]
    fn sim_csv_json_and_table_render() {
        let r = run_sim_sweep(&tiny_sim_cfg());
        let c = r.to_csv();
        assert_eq!(c.lines().count(), 4); // header + 3 scenarios
        assert!(c.lines().next().unwrap().contains("jain_fairness"));
        assert!(c.lines().next().unwrap().contains("weighted_jain"));
        assert!(c.lines().next().unwrap().contains("deadline_miss_rate"));
        assert!(c.lines().next().unwrap().contains("weighted_tardiness"));
        assert!(c.lines().next().unwrap().contains("workload"));
        assert!(c.contains("5P-HEFT"));
        assert!(c.contains("default"));
        let t = r.summary_table();
        assert!(t.contains("σ0.40/L3@0.2"), "{t}");
        assert!(t.contains("degradation"));
        assert!(t.contains("miss"));
        let j = r.to_json();
        let round = Value::from_str(&j.to_string()).unwrap();
        assert_eq!(round.get("scenarios"), j.get("scenarios"));
        let workload = j
            .get("config")
            .and_then(|c| c.get("workload"))
            .and_then(|w| w.as_str());
        assert_eq!(workload, Some("default"));
    }

    /// A non-default scenario flows end-to-end through the sim sweep:
    /// deadlines populate the deadline axes, weights skew the weighted
    /// axes, and the parallel path stays bit-identical.
    #[test]
    fn sim_sweep_with_deadline_scenario() {
        use crate::workloads::{ArrivalModel, DeadlineModel, WeightModel};
        let mut cfg = tiny_sim_cfg();
        cfg.scenario = Scenario {
            weights: WeightModel::HeavyTail { alpha: 1.5 },
            deadlines: DeadlineModel::CritPathSlack { slack: 1.0 },
            arrivals: ArrivalModel::Bursty { burst: 3 },
        };
        let serial = run_sim_sweep_parallel(&cfg, 1);
        // slack 1.0 is the (contention-free) ideal: under load at least
        // one graph misses, so the deadline axes are live
        let any_tardy = (0..serial.labels.len())
            .any(|si| serial.realized_mean(si, Metric::MeanTardiness) > 0.0);
        assert!(any_tardy, "slack-1 deadlines should produce tardiness");
        for si in 0..serial.labels.len() {
            let miss = serial.realized_mean(si, Metric::DeadlineMissRate);
            assert!((0.0..=1.0).contains(&miss));
            let mean_t = serial.realized_mean(si, Metric::MeanTardiness);
            let max_t = serial.realized_mean(si, Metric::MaxTardiness);
            assert!(max_t + 1e-12 >= mean_t);
        }
        let par = run_sim_sweep_parallel(&cfg, 5);
        for (rs, rp) in serial.rows.iter().zip(par.rows.iter()) {
            for (a, b) in rs.iter().zip(rp.iter()) {
                assert_eq!(
                    a.realized.mean_tardiness.to_bits(),
                    b.realized.mean_tardiness.to_bits()
                );
                assert_eq!(
                    a.realized.weighted_tardiness.to_bits(),
                    b.realized.weighted_tardiness.to_bits()
                );
                assert_eq!(
                    a.realized.total_makespan.to_bits(),
                    b.realized.total_makespan.to_bits()
                );
            }
        }
        // the workload label round-trips into CSV and JSON
        let csv = serial.to_csv();
        assert!(csv.contains("w:pareto1.5+d:s1+a:burst3"), "{csv}");
    }

    #[test]
    fn degradation_degenerate_is_ratio_neutral() {
        // an empty/degenerate instance has planned makespan 0; the ratio
        // must read "executed as planned" (1.0), not "infinitely better"
        let empty = SimCell {
            realized: MetricRow::default(),
            planned: MetricRow::default(),
            n_replans: 0,
            n_straggler_replans: 0,
            n_reverted: 0,
            cost: PreemptionCost::default(),
        };
        assert_eq!(empty.degradation(), 1.0);
        let pc = PolicyCell {
            realized: MetricRow::default(),
            planned: MetricRow::default(),
            cost: PreemptionCost::default(),
        };
        assert_eq!(pc.degradation(), 1.0);
        // the ordinary case is untouched
        assert_eq!(degradation_ratio(3.0, 2.0), 1.5);
    }

    fn tiny_policy_cfg() -> PolicySweepConfig {
        PolicySweepConfig {
            dataset: Dataset::Synthetic,
            n_graphs: 6,
            trials: 2,
            seed: 5,
            load: 0.5,
            variant: Variant::parse("5P-HEFT").unwrap(),
            scenario: Scenario::default(),
            scenarios: vec![
                PolicyScenario {
                    noise_std: 0.4,
                    spec: PolicySpec::None,
                },
                PolicyScenario {
                    noise_std: 0.4,
                    spec: PolicySpec::FixedLastK {
                        k: 3,
                        threshold: 0.2,
                    },
                },
                PolicyScenario {
                    noise_std: 0.4,
                    spec: PolicySpec::Budgeted {
                        k: 3,
                        threshold: 0.2,
                        rate: 0.05,
                        burst: 3.0,
                    },
                },
                PolicyScenario {
                    noise_std: 0.4,
                    spec: PolicySpec::AdaptiveK {
                        k0: 3,
                        k_max: 8,
                        threshold: 0.2,
                        target_stretch: 1.5,
                    },
                },
            ],
            faults: crate::sim::FaultConfig::NONE,
        }
    }

    #[test]
    fn policy_sweep_shape_and_cost_sanity() {
        let r = run_policy_sweep(&tiny_policy_cfg());
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.rows[0].len(), 4);
        assert_eq!(r.labels[1], "σ0.40/L3@0.2");
        for row in &r.rows {
            for c in row {
                assert!(c.realized.total_makespan > 0.0);
                assert!(c.degradation() > 0.0);
                assert!(c.cost.replans >= c.cost.straggler_replans);
                assert!(c.cost.replan_wall_s >= 0.0);
            }
            // the no-reaction baseline never fires a straggler replan
            assert_eq!(row[0].cost.straggler_replans, 0);
        }
    }

    #[test]
    fn policy_sweep_parallel_is_deterministic_across_thread_counts() {
        let cfg = tiny_policy_cfg();
        let serial = run_policy_sweep_parallel(&cfg, 1);
        let sig = |c: &PolicyCell| {
            (
                c.realized.total_makespan.to_bits(),
                c.realized.mean_stretch.to_bits(),
                c.realized.weighted_jain.to_bits(),
                c.planned.total_makespan.to_bits(),
                c.cost.replans,
                c.cost.straggler_replans,
                c.cost.reverted_tasks,
            )
        };
        for jobs in [2, 5] {
            let par = run_policy_sweep_parallel(&cfg, jobs);
            assert_eq!(serial.labels, par.labels);
            for (trial, (rs, rp)) in serial.rows.iter().zip(par.rows.iter()).enumerate() {
                for (si, (a, b)) in rs.iter().zip(rp.iter()).enumerate() {
                    assert_eq!(sig(a), sig(b), "jobs={jobs}, trial {trial}, scenario {si}");
                }
            }
        }
    }

    #[test]
    fn policy_csv_json_and_table_render() {
        let r = run_policy_sweep(&tiny_policy_cfg());
        let c = r.to_csv();
        assert_eq!(c.lines().count(), 5); // header + 4 scenarios
        let header = c.lines().next().unwrap();
        for col in [
            "replans",
            "reverted_tasks",
            "replan_wall_s",
            "weighted_mean_stretch",
            "jain_fairness",
        ] {
            assert!(header.contains(col), "missing {col} in {header}");
        }
        let t = r.summary_table();
        assert!(t.contains("σ0.40/B3@0.2r0.05b3"), "{t}");
        assert!(t.contains("reverted"));
        let j = r.to_json();
        let round = Value::from_str(&j.to_string()).unwrap();
        assert_eq!(round.get("scenarios"), j.get("scenarios"));
    }
}
