//! The figure-regeneration harness: runs a (dataset × variants × trials)
//! sweep, normalizes per trial exactly like the paper's "Normalized ..."
//! figures, and emits markdown/CSV tables — one table per paper figure.
//!
//! Figure map (see DESIGN.md §4):
//! * Fig 3 — normalized total makespan, per dataset
//! * Fig 4 — normalized mean makespan
//! * Fig 5 — normalized mean flowtime
//! * Fig 6 — normalized scheduler runtime
//! * Fig 7 — (raw) mean node utilization
//! * Fig 8 — all five metrics on the adversarial dataset

use crate::config::ExperimentConfig;
use crate::coordinator::Variant;
use crate::json::{self, Value};
use crate::metrics::{normalize, Metric, MetricRow};
use crate::report;
use crate::schedule::validate;
use crate::stats::mean;

/// Raw sweep output: `rows[trial][variant]`.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub config: ExperimentConfig,
    pub labels: Vec<String>,
    pub rows: Vec<Vec<MetricRow>>,
}

/// Run the full sweep described by `cfg`.  Every produced schedule is
/// checked by the §II validator; a violation is a hard panic (the harness
/// must never report numbers from an invalid schedule).
pub fn run_sweep(cfg: &ExperimentConfig) -> SweepResult {
    run_sweep_with(cfg, |_trial, _variant| {})
}

/// Like [`run_sweep`] but with a progress callback `(trial, variant_label)`.
pub fn run_sweep_with(
    cfg: &ExperimentConfig,
    mut progress: impl FnMut(usize, &str),
) -> SweepResult {
    let labels: Vec<String> = cfg.variants.iter().map(|v| v.label()).collect();
    let mut rows = Vec::with_capacity(cfg.trials);
    for trial in 0..cfg.trials {
        let seed = cfg.seed + trial as u64;
        let prob = cfg.dataset.instance(cfg.n_graphs, seed);
        let mut row = Vec::with_capacity(cfg.variants.len());
        for v in &cfg.variants {
            progress(trial, &v.label());
            let mut coord = v.coordinator(seed ^ 0x5EED);
            let res = coord.run(&prob);
            let viol = validate(&res.schedule, &prob.graphs, &prob.network);
            assert!(
                viol.is_empty(),
                "invalid schedule from {} on {} trial {trial}: {:?}",
                v.label(),
                cfg.dataset.name(),
                &viol[..viol.len().min(3)]
            );
            row.push(res.metrics(&prob));
        }
        rows.push(row);
    }
    SweepResult {
        config: cfg.clone(),
        labels,
        rows,
    }
}

impl SweepResult {
    /// Paper-style normalized values for one metric: normalize within
    /// each trial across variants (best = 1.0 for lower-is-better
    /// metrics), then average across trials.  Utilization is reported
    /// raw, as in Fig 7/8e.
    pub fn figure_values(&self, metric: Metric) -> Vec<f64> {
        match metric {
            Metric::Utilization => self.raw_mean(metric),
            _ => {
                let mut acc = vec![0.0; self.labels.len()];
                for row in &self.rows {
                    let vals: Vec<f64> = row.iter().map(|r| r.get(metric)).collect();
                    for (i, v) in normalize(metric, &vals).iter().enumerate() {
                        acc[i] += v;
                    }
                }
                acc.iter().map(|v| v / self.rows.len() as f64).collect()
            }
        }
    }

    /// Raw per-variant mean of a metric across trials.
    pub fn raw_mean(&self, metric: Metric) -> Vec<f64> {
        (0..self.labels.len())
            .map(|i| mean(&self.rows.iter().map(|r| r[i].get(metric)).collect::<Vec<_>>()))
            .collect()
    }

    /// Figure table for one metric, sorted ascending (descending for
    /// utilization) — mirrors the bar ordering in the paper's plots.
    pub fn figure_table(&self, metric: Metric) -> String {
        let vals = self.figure_values(metric);
        let raw = self.raw_mean(metric);
        let mut idx: Vec<usize> = (0..vals.len()).collect();
        if metric.lower_is_better() {
            idx.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
        } else {
            idx.sort_by(|&a, &b| vals[b].partial_cmp(&vals[a]).unwrap());
        }
        let header_val = if metric == Metric::Utilization {
            "utilization".to_string()
        } else {
            format!("normalized {}", metric.name())
        };
        let rows: Vec<Vec<String>> = idx
            .iter()
            .map(|&i| {
                vec![
                    self.labels[i].clone(),
                    report::fmt(vals[i]),
                    report::fmt(raw[i]),
                ]
            })
            .collect();
        report::markdown_table(&["variant", &header_val, "raw mean"], &rows)
    }

    /// CSV with every metric per variant (figure-ready).
    pub fn to_csv(&self) -> String {
        let mut rows = Vec::new();
        for (i, label) in self.labels.iter().enumerate() {
            let mut row = vec![self.config.dataset.name().to_string(), label.clone()];
            for m in Metric::ALL {
                row.push(format!("{}", self.figure_values(m)[i]));
                row.push(format!("{}", self.raw_mean(m)[i]));
            }
            rows.push(row);
        }
        let headers = vec![
            "dataset",
            "variant",
            "total_makespan_norm",
            "total_makespan_raw",
            "mean_makespan_norm",
            "mean_makespan_raw",
            "mean_flowtime_norm",
            "mean_flowtime_raw",
            "utilization",
            "utilization_raw",
            "runtime_norm",
            "runtime_raw",
        ];
        report::csv(&headers, &rows)
    }

    /// JSON dump (config + per-trial raw metric rows).
    pub fn to_json(&self) -> Value {
        let rows = self
            .rows
            .iter()
            .map(|trial| {
                json::arr(
                    trial
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("total_makespan", json::num(r.total_makespan)),
                                ("mean_makespan", json::num(r.mean_makespan)),
                                ("mean_flowtime", json::num(r.mean_flowtime)),
                                ("utilization", json::num(r.mean_utilization)),
                                ("runtime_s", json::num(r.runtime_s)),
                            ])
                        })
                        .collect(),
                )
            })
            .collect();
        json::obj(vec![
            ("config", self.config.to_json()),
            (
                "labels",
                json::arr(self.labels.iter().map(|l| json::s(l)).collect()),
            ),
            ("trials", json::arr(rows)),
        ])
    }

    /// Value of a labelled variant for one metric (figure scale).
    pub fn value_of(&self, label: &str, metric: Metric) -> Option<f64> {
        let i = self.labels.iter().position(|l| l == label)?;
        Some(self.figure_values(metric)[i])
    }
}

/// Convenience used by benches: a reduced variant set that still spans
/// the paper's qualitative story (all policies × HEFT/CPOP + extremes of
/// the other heuristics) — 14 variants instead of 30.
pub fn core_variants() -> Vec<Variant> {
    use crate::coordinator::Policy::*;
    use crate::schedulers::SchedulerKind::*;
    let mut out = Vec::new();
    for kind in [Heft, Cpop] {
        for p in [
            NonPreemptive,
            LastK(2),
            LastK(5),
            LastK(10),
            LastK(20),
            Preemptive,
        ] {
            out.push(Variant { policy: p, kind });
        }
    }
    out.push(Variant { policy: NonPreemptive, kind: MinMin });
    out.push(Variant { policy: Preemptive, kind: MinMin });
    out.push(Variant { policy: NonPreemptive, kind: MaxMin });
    out.push(Variant { policy: Preemptive, kind: MaxMin });
    out.push(Variant { policy: NonPreemptive, kind: Random });
    out.push(Variant { policy: Preemptive, kind: Random });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Dataset;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            dataset: Dataset::Synthetic,
            n_graphs: 8,
            trials: 2,
            seed: 3,
            load: 0.5,
            variants: vec![
                Variant::parse("NP-HEFT").unwrap(),
                Variant::parse("P-HEFT").unwrap(),
                Variant::parse("2P-HEFT").unwrap(),
            ],
        }
    }

    #[test]
    fn sweep_shape_and_validity() {
        let r = run_sweep(&tiny_cfg());
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.rows[0].len(), 3);
        assert_eq!(r.labels, vec!["NP-HEFT", "P-HEFT", "2P-HEFT"]);
    }

    #[test]
    fn normalization_minimum_is_one() {
        let r = run_sweep(&tiny_cfg());
        for m in [Metric::TotalMakespan, Metric::MeanMakespan, Metric::MeanFlowtime] {
            let vals = r.figure_values(m);
            // averaged normalized values: every variant >= 1, and in each
            // trial someone was exactly 1, so the min is >= 1 but close.
            assert!(vals.iter().all(|&v| v >= 1.0 - 1e-12), "{m:?}: {vals:?}");
            let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(lo < 2.0, "{m:?}: implausible normalization {vals:?}");
        }
    }

    #[test]
    fn utilization_is_raw_and_bounded() {
        let r = run_sweep(&tiny_cfg());
        for &u in &r.figure_values(Metric::Utilization) {
            assert!(u > 0.0 && u <= 1.0);
        }
    }

    #[test]
    fn tables_and_csv_render() {
        let r = run_sweep(&tiny_cfg());
        let t = r.figure_table(Metric::TotalMakespan);
        assert!(t.contains("NP-HEFT") && t.contains("P-HEFT"));
        let c = r.to_csv();
        assert_eq!(c.lines().count(), 4); // header + 3 variants
        let j = r.to_json();
        assert!(j.get("labels").is_some());
        // json roundtrips through the parser
        let round = Value::from_str(&j.to_string()).unwrap();
        assert_eq!(round.get("labels"), j.get("labels"));
    }

    #[test]
    fn value_of_lookup() {
        let r = run_sweep(&tiny_cfg());
        assert!(r.value_of("P-HEFT", Metric::Runtime).is_some());
        assert!(r.value_of("nope", Metric::Runtime).is_none());
    }

    #[test]
    fn core_variants_cover_policy_axis() {
        let vs = core_variants();
        assert_eq!(vs.len(), 18);
        let labels: Vec<String> = vs.iter().map(|v| v.label()).collect();
        assert!(labels.contains(&"5P-HEFT".to_string()));
        assert!(labels.contains(&"P-Random".to_string()));
    }
}
