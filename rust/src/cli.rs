//! `dts` command-line launcher (hand-rolled arg parsing — no clap in the
//! offline vendored set).
//!
//! Subcommands:
//! * `run`        — run one scheduler variant on one dataset instance
//! * `experiment` — full sweep, printing every figure table
//! * `simulate`   — reactive runtime sweep (noise × reaction)
//! * `policy`     — preemption-policy-engine sweep (k × θ × budget)
//! * `serve`      — streaming scheduler daemon (NDJSON in/out, stdin or TCP)
//! * `trace`      — trace-file utilities (`--events` prints the event NDJSON)
//! * `generate`   — emit workload statistics (and optional DOT dumps)
//! * `validate`   — run + §II-validate + discrete-event replay
//! * `info`       — version, artifact/bucket status

use std::collections::HashMap;

use crate::config::ExperimentConfig;
use crate::coordinator::{Coordinator, Variant};
use crate::experiments::{
    run_policy_sweep_parallel, run_sim_sweep_parallel, run_sweep_parallel, PolicyScenario,
    PolicySweepConfig, SimScenario, SimSweepConfig,
};
use crate::metrics::Metric;
use crate::policy::PolicySpec;
use crate::schedule::validate;
use crate::schedulers::{Cpop, Heft};
use crate::serve::{Controller, ServeConfig, ServeOptions, ServeServer};
use crate::sim::{replay, Reaction};
use crate::workloads::{ArrivalModel, Dataset, DeadlineModel, Scenario, WeightModel};
use crate::{report, runtime};

/// Parsed flags: `--key value` pairs plus positional words.
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

pub fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(key) = a.strip_prefix("--") {
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(key.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                flags.insert(key.to_string(), "true".to_string());
            }
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    Args { positional, flags }
}

impl Args {
    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
    pub fn usize_flag(&self, key: &str, default: usize) -> usize {
        self.flag(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn u64_flag(&self, key: &str, default: u64) -> u64 {
        self.flag(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1") | Some("yes"))
    }
}

/// Strict count flag: absent → `default`, present → must parse as an
/// integer `>= min`.  [`Args::usize_flag`] silently falls back to the
/// default on garbage, which masks typos (`--scale 1O` would quietly
/// run unscaled); every count-like flag on the sweep commands goes
/// through here instead.
fn strict_usize_flag(args: &Args, key: &str, default: usize, min: usize) -> Result<usize, i32> {
    match args.flag(key) {
        None => Ok(default),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= min => Ok(n),
            _ => {
                eprintln!("error: --{key} must be an integer >= {min}, got '{s}'");
                Err(2)
            }
        },
    }
}

/// Strict float flag: absent → `default`, present → must parse as a
/// finite f64 satisfying `ok`.  The `.and_then(parse).unwrap_or(default)`
/// idiom silently falls back on garbage, which masks typos (`--burst 4O`
/// would quietly run at the default burst); every float-valued knob goes
/// through here instead — same contract as [`strict_usize_flag`].
fn strict_f64_flag(
    args: &Args,
    key: &str,
    default: f64,
    constraint: &str,
    ok: impl Fn(f64) -> bool,
) -> Result<f64, i32> {
    match args.flag(key) {
        None => Ok(default),
        Some(s) => match s.parse::<f64>() {
            Ok(x) if x.is_finite() && ok(x) => Ok(x),
            _ => {
                eprintln!("error: --{key} must be {constraint}, got '{s}'");
                Err(2)
            }
        },
    }
}

/// Resolve the shared fault-injection flags (`--mtbf`, `--mttr`,
/// `--fault-seed`) of `dts simulate`, `dts policy`, and `dts serve`.
/// No flags = [`FaultConfig::NONE`] (bit-identical to pre-fault runs).
/// `--mtbf` and `--mttr` must come together and satisfy
/// [`FaultModel::validate`]; a lone `--fault-seed` is a typo (it would
/// silently run fault-free), so it aborts too.
fn fault_config_of(args: &Args) -> Result<crate::sim::FaultConfig, i32> {
    use crate::sim::{FaultConfig, FaultModel, DEFAULT_FAULT_SEED};
    let mtbf = args.flag("mtbf");
    let mttr = args.flag("mttr");
    let seed = args.flag("fault-seed");
    if mtbf.is_none() && mttr.is_none() {
        if seed.is_some() {
            eprintln!("error: --fault-seed requires --mtbf and --mttr");
            return Err(2);
        }
        return Ok(FaultConfig::NONE);
    }
    if mtbf.is_none() || mttr.is_none() {
        eprintln!("error: --mtbf and --mttr must be given together");
        return Err(2);
    }
    let mtbf = strict_f64_flag(args, "mtbf", 0.0, "finite and > 0", |x| x > 0.0)?;
    let mttr = strict_f64_flag(args, "mttr", 0.0, "finite and > 0", |x| x > 0.0)?;
    let model = FaultModel::Crash { mtbf, mttr };
    if let Err(e) = model.validate() {
        eprintln!("error: {e}");
        return Err(2);
    }
    let seed = match seed {
        None => DEFAULT_FAULT_SEED,
        Some(s) => match s.parse::<u64>() {
            Ok(v) => v,
            Err(_) => {
                eprintln!("error: --fault-seed must be a non-negative integer, got '{s}'");
                return Err(2);
            }
        },
    };
    Ok(FaultConfig {
        model,
        seed,
        node_base: 0,
    })
}

const USAGE: &str = "\
dts — dynamic task-graph scheduling with controlled preemption

USAGE:
  dts run        --dataset <d> [--graphs N] [--seed S] [--variant 5P-HEFT] [--xla]
  dts experiment [--config cfg.json | --dataset <d>] [--quick] [--csv out.csv]
                 [--jobs N]   (N worker threads; deterministic at any N)
  dts simulate   --dataset <d|all> [--graphs N] [--scale M] [--trials T] [--seed S]
                 [--variant 5P-HEFT] [--noise 0.0,0.3] [--threshold 0.25,none]
                 [--k 3] [--shards S] [--weighted [pareto|classes]]
                 [--deadline-slack F] [--arrival poisson|bursty] [--burst-size 4]
                 [--jobs N] [--csv out.csv] [--json out.json]
                 [--trace out.json] [--telemetry out.ndjson]
                 [--mtbf S --mttr S [--fault-seed N]]
                 (reactive runtime: realized durations, straggler Last-K;
                  --shards S > 1 federates the node pool into S clusters;
                  --telemetry dumps the dts-telemetry-v1 NDJSON snapshot;
                  --mtbf/--mttr inject deterministic node crash/restart
                  faults — docs/FAULTS.md)
  dts policy     --dataset <d|all> [--graphs N] [--scale M] [--trials T] [--seed S]
                 [--variant 5P-HEFT] [--noise 0.3] [--k 1,3,5]
                 [--threshold 0.25] [--budget none,1.0] [--burst 4]
                 [--adaptive] [--target-stretch 2.0] [--kmax 20]
                 [--cooldown 0] [--deadline-aware]
                 [--weighted [pareto|classes]] [--deadline-slack F]
                 [--arrival poisson|bursty] [--burst-size 4]
                 [--jobs N] [--csv out.csv] [--json out.json]
                 [--telemetry out.ndjson]
                 [--mtbf S --mttr S [--fault-seed N]]
                 (policy engine: joint k × θ × budget sweep with
                  preemption-cost accounting; --deadline-aware adds the
                  urgency-scoped D{k}@{θ} controllers)
  dts serve      --dataset <d> [--graphs N] [--seed S] [--variant 5P-HEFT]
                 [--noise 0.3] [--k 3] [--threshold 0.25|none]
                 [--deadline-aware] [--shards S] [--jobs N]
                 [--listen addr:port] [--snapshot path] [--snapshot-every N]
                 [--restore path] [--telemetry out.ndjson]
                 [--max-line-bytes N] [--mtbf S --mttr S [--fault-seed N]]
                 (streaming daemon: dts-serve-v1 NDJSON requests on stdin
                  or the TCP socket, decision stream out; replaying a
                  recorded dts-sim-trace-v1 document reproduces the
                  offline `dts simulate` cell bit-exactly — docs/SERVE.md)
  dts trace      --events trace.json   (print a recorded trace's events
                  as NDJSON, one line per event — the serve byte-diff aid)
  dts generate   --dataset <d> [--graphs N] [--seed S] [--dot]
  dts validate   --dataset <d> [--graphs N] [--seed S] [--variant V]
  dts analyze    --dataset <d> [--graphs N] [--seed S] [--variant V]
                 [--svg out.svg] [--trace out.json] [--width 100]
  dts info       [--artifacts DIR]

datasets: synthetic | riotbench | wfcommons | adversarial
variants: {P,NP,<k>P}-{HEFT,CPOP,MinMin,MaxMin,Random,MET,OLB,ETF}
";

/// CLI entry point; returns the process exit code.
pub fn main_with(argv: &[String]) -> i32 {
    let args = parse_args(argv);
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("policy") => cmd_policy(&args),
        Some("serve") => cmd_serve(&args),
        Some("trace") => cmd_trace(&args),
        Some("generate") => cmd_generate(&args),
        Some("validate") => cmd_validate(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprint!("{USAGE}");
            2
        }
    }
}

fn dataset_of(args: &Args) -> Result<Dataset, i32> {
    match args.flag("dataset").and_then(Dataset::parse) {
        Some(d) => Ok(d),
        None => {
            eprintln!("error: --dataset required (synthetic|riotbench|wfcommons|adversarial)");
            Err(2)
        }
    }
}

fn cmd_run(args: &Args) -> i32 {
    let Ok(dataset) = dataset_of(args) else { return 2 };
    let n = args.usize_flag("graphs", dataset.default_n_graphs());
    let seed = args.u64_flag("seed", 0);
    let label = args.flag("variant").unwrap_or("5P-HEFT");
    let Some(variant) = Variant::parse(label) else {
        eprintln!("error: bad --variant '{label}'");
        return 2;
    };
    let prob = dataset.instance(n, seed);

    let res = if args.bool_flag("xla") {
        let rt = match runtime::XlaRuntime::load(args.flag("artifacts").unwrap_or("artifacts")) {
            Ok(rt) => std::rc::Rc::new(rt),
            Err(e) => {
                eprintln!("error: cannot load artifacts: {e}");
                return 1;
            }
        };
        let ranks = runtime::XlaRanks::new(rt);
        use crate::schedulers::SchedulerKind as K;
        let sched: Box<dyn crate::schedulers::Scheduler> = match variant.kind {
            K::Heft => Box::new(Heft::new(ranks)),
            K::Cpop => Box::new(Cpop::new(ranks)),
            other => {
                eprintln!("note: --xla only affects HEFT/CPOP; using native {other:?}");
                other.make(seed)
            }
        };
        Coordinator::new(variant.policy, sched).run(&prob)
    } else {
        variant.coordinator(seed).run(&prob)
    };

    let m = res.metrics(&prob);
    println!("dataset           : {} ({} graphs, seed {seed})", dataset.name(), n);
    println!("variant           : {}", variant.label());
    println!("total makespan    : {}", report::fmt(m.total_makespan));
    println!("mean makespan     : {}", report::fmt(m.mean_makespan));
    println!("mean flowtime     : {}", report::fmt(m.mean_flowtime));
    println!("mean utilization  : {}", report::fmt(m.mean_utilization));
    println!("scheduler runtime : {:.6} s over {} events", m.runtime_s, res.events.len());
    0
}

fn cmd_experiment(args: &Args) -> i32 {
    let cfg = if let Some(path) = args.flag("config") {
        match ExperimentConfig::from_file(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("error: {e}");
                return 2;
            }
        }
    } else {
        let Ok(dataset) = dataset_of(args) else { return 2 };
        let mut c = if args.bool_flag("quick") {
            ExperimentConfig::quick(dataset)
        } else {
            ExperimentConfig::paper_default(dataset)
        };
        let (Ok(graphs), Ok(trials)) = (
            strict_usize_flag(args, "graphs", c.n_graphs, 1),
            strict_usize_flag(args, "trials", c.trials, 1),
        ) else {
            return 2;
        };
        c.n_graphs = graphs;
        c.trials = trials;
        c.seed = args.u64_flag("seed", c.seed);
        c
    };

    let n_cells = cfg.trials * cfg.variants.len();
    let Ok(jobs_cap) = strict_usize_flag(args, "jobs", 1, 1) else {
        return 2;
    };
    let jobs = jobs_cap.clamp(1, n_cells.max(1));
    eprintln!(
        "sweep: {} × {} variants × {} trials ({} graphs, {} job{})",
        cfg.dataset.name(),
        cfg.variants.len(),
        cfg.trials,
        cfg.n_graphs,
        jobs,
        if jobs == 1 { "" } else { "s" }
    );
    let result = run_sweep_parallel(&cfg, jobs);
    for metric in Metric::ALL {
        println!("\n## {} — {}\n", cfg.dataset.name(), metric.name());
        println!("{}", result.figure_table(metric));
    }
    if let Some(path) = args.flag("csv") {
        if let Err(e) = std::fs::write(path, result.to_csv()) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.flag("json") {
        if let Err(e) = std::fs::write(path, result.to_json().to_string()) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }
    0
}

/// Append one dataset's CSV to a multi-dataset dump: the first dataset
/// keeps its header, later ones contribute data rows only.
fn append_csv(out: &mut String, csv: &str, first: bool) {
    if first {
        out.push_str(csv);
    } else {
        for line in csv.lines().skip(1) {
            out.push_str(line);
            out.push('\n');
        }
    }
}

/// Comma-separated f64 list (`"0.0,0.3"`).
fn parse_f64_list(s: &str) -> Option<Vec<f64>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        out.push(p.parse::<f64>().ok()?);
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Comma-separated straggler thresholds; `none` selects the no-reaction
/// baseline (`"0.25,none"`).
fn parse_threshold_list(s: &str) -> Option<Vec<Option<f64>>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        if p.eq_ignore_ascii_case("none") {
            out.push(None);
        } else {
            out.push(Some(p.parse::<f64>().ok()?));
        }
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Build the workload [`Scenario`] from the shared `--weighted` /
/// `--deadline-slack` / `--arrival` (+`--burst-size`) flags of
/// `dts simulate` and `dts policy`.  No flags = the default [`Scenario`]
/// (bit-identical to the pre-scenario sweeps).
fn scenario_of(args: &Args) -> Result<Scenario, i32> {
    let weights = match args.flag("weighted") {
        None => WeightModel::Unit,
        // bare `--weighted` parses as "true": the heavy-tail default
        Some("true") | Some("pareto") => WeightModel::HeavyTail { alpha: 1.5 },
        Some("classes") => WeightModel::Classes {
            weights: vec![1.0, 4.0, 16.0],
        },
        Some(other) => {
            eprintln!("error: bad --weighted '{other}' (want pareto|classes)");
            return Err(2);
        }
    };
    let deadlines = match args.flag("deadline-slack") {
        None => DeadlineModel::None,
        Some(s) => match s.parse::<f64>() {
            Ok(slack) if slack.is_finite() && slack >= 0.0 => {
                DeadlineModel::CritPathSlack { slack }
            }
            _ => {
                eprintln!("error: --deadline-slack must be finite and >= 0");
                return Err(2);
            }
        },
    };
    let arrivals = match args.flag("arrival") {
        None | Some("poisson") => ArrivalModel::Poisson,
        Some("bursty") => {
            // strict parse: a typo must not silently fall back to the
            // default and change the experiment's arrival process
            let burst = match args.flag("burst-size") {
                None => 4,
                Some(s) => match s.parse::<usize>() {
                    Ok(b) if b >= 1 => b,
                    _ => {
                        eprintln!("error: --burst-size must be an integer >= 1");
                        return Err(2);
                    }
                },
            };
            ArrivalModel::Bursty { burst }
        }
        Some(other) => {
            eprintln!("error: bad --arrival '{other}' (want poisson|bursty)");
            return Err(2);
        }
    };
    Ok(Scenario {
        weights,
        deadlines,
        arrivals,
    })
}

fn cmd_simulate(args: &Args) -> i32 {
    let datasets: Vec<Dataset> = match args.flag("dataset") {
        Some("all") => Dataset::ALL.to_vec(),
        Some(s) => match Dataset::parse(s) {
            Some(d) => vec![d],
            None => {
                eprintln!("error: bad --dataset '{s}'");
                return 2;
            }
        },
        None => {
            eprintln!(
                "error: --dataset required (synthetic|riotbench|wfcommons|adversarial|all)"
            );
            return 2;
        }
    };
    let label = args.flag("variant").unwrap_or("5P-HEFT");
    let Some(variant) = Variant::parse(label) else {
        eprintln!("error: bad --variant '{label}'");
        return 2;
    };
    let Some(noise) = parse_f64_list(args.flag("noise").unwrap_or("0.0,0.3")) else {
        eprintln!("error: bad --noise list (want e.g. 0.0,0.3)");
        return 2;
    };
    if noise.iter().any(|x| !x.is_finite() || *x < 0.0) {
        eprintln!("error: --noise values must be finite and >= 0");
        return 2;
    }
    let Some(thresholds) = parse_threshold_list(args.flag("threshold").unwrap_or("0.25,none"))
    else {
        eprintln!("error: bad --threshold list (want e.g. 0.25,none)");
        return 2;
    };
    if thresholds.iter().flatten().any(|t| !t.is_finite() || *t < 0.0) {
        eprintln!("error: --threshold values must be finite and >= 0 (or 'none')");
        return 2;
    }
    let Ok(k) = strict_usize_flag(args, "k", 3, 1) else {
        return 2;
    };
    let Ok(shards) = strict_usize_flag(args, "shards", 1, 1) else {
        return 2;
    };
    let Ok(scenario) = scenario_of(args) else {
        return 2;
    };
    let Ok(faults) = fault_config_of(args) else {
        return 2;
    };
    let mut scenarios = Vec::new();
    for &sigma in &noise {
        for th in &thresholds {
            scenarios.push(SimScenario {
                noise_std: sigma,
                reaction: match th {
                    None => Reaction::None,
                    Some(t) => Reaction::LastK { k, threshold: *t },
                },
            });
        }
    }
    let Ok(trials) = strict_usize_flag(args, "trials", 2, 1) else {
        return 2;
    };
    let seed = args.u64_flag("seed", 0);
    // --scale multiplies --graphs: the large-composite stress axis the
    // incremental belief refresh unlocks (e.g. --graphs 100 --scale 12
    // ≈ a 10⁴-task composite at synthetic task counts)
    let (Ok(base_graphs), Ok(scale)) = (
        strict_usize_flag(args, "graphs", 16, 1),
        strict_usize_flag(args, "scale", 1, 1),
    ) else {
        return 2;
    };
    let graphs = crate::experiments::scaled_graphs(base_graphs, scale);
    let Ok(jobs_cap) = strict_usize_flag(args, "jobs", 1, 1) else {
        return 2;
    };
    // --telemetry: reset the registry so the NDJSON snapshot covers
    // exactly this invocation's sweeps
    let telemetry_path = args.flag("telemetry");
    if telemetry_path.is_some() {
        crate::telemetry::reset();
    }
    let mut tele_spans = Vec::new();

    let mut csv_out = String::new();
    let mut json_parts = Vec::new();
    for (di, dataset) in datasets.iter().enumerate() {
        let cfg = SimSweepConfig {
            dataset: *dataset,
            n_graphs: graphs,
            trials,
            seed,
            load: crate::workloads::DEFAULT_LOAD,
            variant,
            scenario: scenario.clone(),
            scenarios: scenarios.clone(),
            shards,
            faults,
        };
        let n_cells = cfg.trials * cfg.scenarios.len();
        let jobs = jobs_cap.clamp(1, n_cells.max(1));
        eprintln!(
            "simulate: {} × {} scenarios × {} trials ({} graphs, {}, workload {}, {} shard{}, {} job{})",
            dataset.name(),
            cfg.scenarios.len(),
            cfg.trials,
            cfg.n_graphs,
            variant.label(),
            cfg.scenario.label(),
            shards,
            if shards == 1 { "" } else { "s" },
            jobs,
            if jobs == 1 { "" } else { "s" }
        );
        let result = run_sim_sweep_parallel(&cfg, jobs);
        println!("\n## {} — reactive runtime, {}\n", dataset.name(), variant.label());
        println!("{}", result.summary_table());
        append_csv(&mut csv_out, &result.to_csv(), di == 0);
        if telemetry_path.is_some() {
            tele_spans.extend(result.telemetry_spans());
        }
        json_parts.push(result.to_json());
    }

    if let Some(path) = telemetry_path {
        let snap = crate::telemetry::snapshot();
        let doc = crate::telemetry::export::to_ndjson("simulate", &tele_spans, &snap);
        if let Err(e) = std::fs::write(path, doc) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }

    if let Some(path) = args.flag("csv") {
        if let Err(e) = std::fs::write(path, &csv_out) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.flag("json") {
        let v = crate::json::arr(json_parts);
        if let Err(e) = std::fs::write(path, v.to_string()) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.flag("trace") {
        // one representative realized-event trace: the first dataset
        // under the noisiest reactive scenario (or the first scenario
        // when none reacts)
        let sc = scenarios
            .iter()
            .filter(|s| s.reaction != Reaction::None && s.noise_std > 0.0)
            .cloned()
            .fold(None::<SimScenario>, |best, s| match best {
                Some(b) if b.noise_std >= s.noise_std => Some(b),
                _ => Some(s),
            })
            .unwrap_or(scenarios[0]);
        let prob = datasets[0].instance_scenario(
            graphs,
            seed,
            crate::workloads::DEFAULT_LOAD,
            None,
            &scenario,
        );
        let sim_cfg = crate::sim::SimConfig {
            noise_std: sc.noise_std,
            noise_seed: seed ^ 0xA11CE,
            reaction: sc.reaction,
            record_frozen: false,
            full_refresh: false,
            faults,
        };
        let mut rc = crate::sim::ReactiveCoordinator::new(
            variant.policy,
            variant.kind.make(seed ^ 0x5EED),
            sim_cfg,
        );
        let res = rc.run(&prob);
        let v = crate::trace::sim_to_json(&prob, &res);
        if let Err(e) = std::fs::write(path, v.to_string()) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        eprintln!(
            "wrote {path} ({} events, {} replans under {})",
            res.log.len(),
            res.n_replans(),
            sc.label()
        );
    }
    0
}

/// Comma-separated usize list (`"1,3,5"`).
fn parse_usize_list(s: &str) -> Option<Vec<usize>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        out.push(p.parse::<usize>().ok()?);
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Build the joint k × θ × budget scenario grid for one noise list: per
/// noise level one no-reaction baseline, then every (θ, k, budget)
/// combination — an unbudgeted [`PolicySpec::FixedLastK`] when the
/// budget slot is `none`, a [`PolicySpec::Budgeted`] token bucket
/// otherwise — plus, with `--adaptive`, one [`PolicySpec::AdaptiveK`]
/// per θ, and, with `--deadline-aware`, one urgency-scoped
/// [`PolicySpec::DeadlineAware`] per (θ, k).  A positive `--cooldown`
/// wraps every reactive controller in hysteresis.
#[allow(clippy::too_many_arguments)]
fn policy_grid(
    noise: &[f64],
    ks: &[usize],
    thresholds: &[f64],
    budgets: &[Option<f64>],
    burst: f64,
    adaptive: Option<(usize, f64)>, // (k_max, target_stretch)
    deadline_aware: bool,
    cooldown: f64,
) -> Vec<PolicyScenario> {
    let wrap = |spec: PolicySpec| {
        if cooldown > 0.0 {
            PolicySpec::Cooldown {
                cooldown,
                inner: Box::new(spec),
            }
        } else {
            spec
        }
    };
    let mut out = Vec::new();
    for &sigma in noise {
        out.push(PolicyScenario {
            noise_std: sigma,
            spec: PolicySpec::None,
        });
        for &threshold in thresholds {
            for &k in ks {
                for budget in budgets {
                    let spec = match budget {
                        None => PolicySpec::FixedLastK { k, threshold },
                        Some(rate) => PolicySpec::Budgeted {
                            k,
                            threshold,
                            rate: *rate,
                            burst,
                        },
                    };
                    out.push(PolicyScenario {
                        noise_std: sigma,
                        spec: wrap(spec),
                    });
                }
                if deadline_aware {
                    out.push(PolicyScenario {
                        noise_std: sigma,
                        spec: wrap(PolicySpec::DeadlineAware { k, threshold }),
                    });
                }
            }
            if let Some((k_max, target_stretch)) = adaptive {
                out.push(PolicyScenario {
                    noise_std: sigma,
                    spec: wrap(PolicySpec::AdaptiveK {
                        k0: ks[0],
                        k_max,
                        threshold,
                        target_stretch,
                    }),
                });
            }
        }
    }
    out
}

fn cmd_policy(args: &Args) -> i32 {
    let datasets: Vec<Dataset> = match args.flag("dataset") {
        Some("all") => Dataset::ALL.to_vec(),
        Some(s) => match Dataset::parse(s) {
            Some(d) => vec![d],
            None => {
                eprintln!("error: bad --dataset '{s}'");
                return 2;
            }
        },
        None => {
            eprintln!(
                "error: --dataset required (synthetic|riotbench|wfcommons|adversarial|all)"
            );
            return 2;
        }
    };
    let label = args.flag("variant").unwrap_or("5P-HEFT");
    let Some(variant) = Variant::parse(label) else {
        eprintln!("error: bad --variant '{label}'");
        return 2;
    };
    let Some(noise) = parse_f64_list(args.flag("noise").unwrap_or("0.3")) else {
        eprintln!("error: bad --noise list (want e.g. 0.3 or 0.0,0.3)");
        return 2;
    };
    if noise.iter().any(|x| !x.is_finite() || *x < 0.0) {
        eprintln!("error: --noise values must be finite and >= 0");
        return 2;
    }
    let Some(ks) = parse_usize_list(args.flag("k").unwrap_or("1,3,5")) else {
        eprintln!("error: bad --k list (want e.g. 1,3,5)");
        return 2;
    };
    if ks.iter().any(|&k| k == 0) {
        eprintln!("error: --k values must be >= 1");
        return 2;
    }
    let Some(thresholds) = parse_f64_list(args.flag("threshold").unwrap_or("0.25")) else {
        eprintln!("error: bad --threshold list (want e.g. 0.1,0.25)");
        return 2;
    };
    if thresholds.iter().any(|t| !t.is_finite() || *t < 0.0) {
        eprintln!("error: --threshold values must be finite and >= 0");
        return 2;
    }
    // budget slots: 'none' = unbudgeted FixedLastK, a number = token
    // rate (reverted tasks per unit simulated time)
    let Some(budgets) = parse_threshold_list(args.flag("budget").unwrap_or("none,1.0")) else {
        eprintln!("error: bad --budget list (want e.g. none,0.5,2.0)");
        return 2;
    };
    if budgets.iter().flatten().any(|b| !b.is_finite() || *b <= 0.0) {
        eprintln!("error: --budget rates must be finite and > 0 (or 'none')");
        return 2;
    }
    let Ok(burst) = strict_f64_flag(args, "burst", 4.0, "finite and >= 1", |x| x >= 1.0) else {
        return 2;
    };
    let Ok(cooldown) = strict_f64_flag(args, "cooldown", 0.0, "finite and >= 0", |x| x >= 0.0)
    else {
        return 2;
    };
    let adaptive = if args.bool_flag("adaptive") {
        let Ok(k_max) = strict_usize_flag(args, "kmax", 20, 1) else {
            return 2;
        };
        let Ok(target) =
            strict_f64_flag(args, "target-stretch", 2.0, "finite and > 0", |x| x > 0.0)
        else {
            return 2;
        };
        Some((k_max, target))
    } else {
        None
    };
    let deadline_aware = args.bool_flag("deadline-aware");
    let Ok(scenario) = scenario_of(args) else {
        return 2;
    };
    let Ok(faults) = fault_config_of(args) else {
        return 2;
    };
    let scenarios = policy_grid(
        &noise,
        &ks,
        &thresholds,
        &budgets,
        burst,
        adaptive,
        deadline_aware,
        cooldown,
    );
    let Ok(trials) = strict_usize_flag(args, "trials", 2, 1) else {
        return 2;
    };
    let seed = args.u64_flag("seed", 0);
    // same --scale semantics as `dts simulate`
    let (Ok(base_graphs), Ok(scale)) = (
        strict_usize_flag(args, "graphs", 16, 1),
        strict_usize_flag(args, "scale", 1, 1),
    ) else {
        return 2;
    };
    let graphs = crate::experiments::scaled_graphs(base_graphs, scale);
    let Ok(jobs_cap) = strict_usize_flag(args, "jobs", 1, 1) else {
        return 2;
    };
    // --telemetry: same NDJSON export as `dts simulate`
    let telemetry_path = args.flag("telemetry");
    if telemetry_path.is_some() {
        crate::telemetry::reset();
    }
    let mut tele_spans = Vec::new();

    let mut csv_out = String::new();
    let mut json_parts = Vec::new();
    for (di, dataset) in datasets.iter().enumerate() {
        let cfg = PolicySweepConfig {
            dataset: *dataset,
            n_graphs: graphs,
            trials,
            seed,
            load: crate::workloads::DEFAULT_LOAD,
            variant,
            scenario: scenario.clone(),
            scenarios: scenarios.clone(),
            faults,
        };
        let n_cells = cfg.trials * cfg.scenarios.len();
        let jobs = jobs_cap.clamp(1, n_cells.max(1));
        eprintln!(
            "policy: {} × {} scenarios × {} trials ({} graphs, {}, workload {}, {} job{})",
            dataset.name(),
            cfg.scenarios.len(),
            cfg.trials,
            cfg.n_graphs,
            variant.label(),
            cfg.scenario.label(),
            jobs,
            if jobs == 1 { "" } else { "s" }
        );
        let result = run_policy_sweep_parallel(&cfg, jobs);
        println!(
            "\n## {} — preemption policy engine, {}\n",
            dataset.name(),
            variant.label()
        );
        println!("{}", result.summary_table());
        append_csv(&mut csv_out, &result.to_csv(), di == 0);
        if telemetry_path.is_some() {
            tele_spans.extend(result.telemetry_spans());
        }
        json_parts.push(result.to_json());
    }

    if let Some(path) = telemetry_path {
        let snap = crate::telemetry::snapshot();
        let doc = crate::telemetry::export::to_ndjson("policy", &tele_spans, &snap);
        if let Err(e) = std::fs::write(path, doc) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.flag("csv") {
        if let Err(e) = std::fs::write(path, &csv_out) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.flag("json") {
        let v = crate::json::arr(json_parts);
        if let Err(e) = std::fs::write(path, v.to_string()) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }
    0
}

/// Resolve the `dts serve` configuration from the shared flags.  Every
/// knob goes through the strict parsers — the daemon's config is the
/// replay-identity contract, so a typo must abort, never silently run a
/// different instance.
fn serve_config_of(args: &Args) -> Result<ServeConfig, i32> {
    let dataset = dataset_of(args)?;
    let n_graphs = strict_usize_flag(args, "graphs", 16, 1)?;
    let seed = args.u64_flag("seed", 0);
    let label = args.flag("variant").unwrap_or("5P-HEFT");
    let Some(variant) = Variant::parse(label) else {
        eprintln!("error: bad --variant '{label}'");
        return Err(2);
    };
    let noise_std = strict_f64_flag(args, "noise", 0.3, "finite and >= 0", |x| x >= 0.0)?;
    let k = strict_usize_flag(args, "k", 3, 1)?;
    let no_reaction = matches!(args.flag("threshold"), Some(s) if s.eq_ignore_ascii_case("none"));
    let threshold = if no_reaction {
        0.0
    } else {
        strict_f64_flag(args, "threshold", 0.25, "finite and >= 0 (or 'none')", |x| {
            x >= 0.0
        })?
    };
    let controller = if args.bool_flag("deadline-aware") {
        if no_reaction {
            eprintln!("error: --deadline-aware conflicts with --threshold none");
            return Err(2);
        }
        Controller::Spec(PolicySpec::DeadlineAware { k, threshold })
    } else if no_reaction {
        Controller::Reaction(Reaction::None)
    } else {
        Controller::Reaction(Reaction::LastK { k, threshold })
    };
    let shards = strict_usize_flag(args, "shards", 1, 1)?;
    let jobs = strict_usize_flag(args, "jobs", 1, 1)?;
    let scenario = scenario_of(args)?;
    let faults = fault_config_of(args)?;
    Ok(ServeConfig {
        dataset,
        n_graphs,
        seed,
        variant,
        noise_std,
        controller,
        shards,
        jobs,
        load: crate::workloads::DEFAULT_LOAD,
        scenario,
        faults,
    })
}

fn cmd_serve(args: &Args) -> i32 {
    let Ok(cfg) = serve_config_of(args) else {
        return 2;
    };
    let Ok(snapshot_every) = strict_usize_flag(args, "snapshot-every", 0, 0) else {
        return 2;
    };
    let Ok(max_line_bytes) = strict_usize_flag(
        args,
        "max-line-bytes",
        crate::serve::DEFAULT_MAX_LINE_BYTES,
        1,
    ) else {
        return 2;
    };
    let opts = ServeOptions {
        snapshot_path: args.flag("snapshot").map(|s| s.to_string()),
        snapshot_every: snapshot_every as u64,
        telemetry_path: args.flag("telemetry").map(|s| s.to_string()),
        listen: args.flag("listen").map(|s| s.to_string()),
        max_line_bytes,
    };
    // session-scoped registry: serve counters start at zero, so the
    // snapshot counter block (and a later restore's seed) is exactly
    // this session's activity
    crate::telemetry::reset();
    let server = match args.flag("restore") {
        None => ServeServer::new(cfg),
        Some(path) => {
            let doc = match std::fs::read_to_string(path) {
                Ok(d) => d,
                Err(e) => {
                    eprintln!("error: cannot read --restore {path}: {e}");
                    return 2;
                }
            };
            let v = match crate::json::Value::from_str(&doc) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("error: --restore {path} is not valid JSON: {e}");
                    return 2;
                }
            };
            match ServeServer::restore(cfg, &v) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("error: --restore {path}: {e}");
                    return 2;
                }
            }
        }
    };
    crate::serve::run(server, &opts)
}

/// `dts trace --events file.json`: print a recorded `dts-sim-trace-v1`
/// document's `events` array as NDJSON, one event per line — the exact
/// bytes `dts serve` streams for the same cell, so
/// `cmp <(dts trace --events t.json) <(grep decision-lines)` is the
/// whole CI replay check.
fn cmd_trace(args: &Args) -> i32 {
    let Some(path) = args.flag("events") else {
        eprintln!("error: dts trace requires --events <trace.json>");
        return 2;
    };
    let doc = match std::fs::read_to_string(path) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: cannot read {path}: {e}");
            return 1;
        }
    };
    let v = match crate::json::Value::from_str(&doc) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {path} is not valid JSON: {e}");
            return 2;
        }
    };
    if v.get("format").and_then(|f| f.as_str()) != Some("dts-sim-trace-v1") {
        eprintln!("error: {path} is not a dts-sim-trace-v1 document");
        return 2;
    }
    let Some(events) = v.get("events").and_then(|e| e.as_array()) else {
        eprintln!("error: {path} has no events array");
        return 2;
    };
    for e in events {
        println!("{e}");
    }
    0
}

fn cmd_generate(args: &Args) -> i32 {
    let Ok(dataset) = dataset_of(args) else { return 2 };
    let n = args.usize_flag("graphs", dataset.default_n_graphs());
    let seed = args.u64_flag("seed", 0);
    let prob = dataset.instance(n, seed);
    println!("dataset  : {}", dataset.name());
    println!("graphs   : {}", prob.graphs.len());
    println!("tasks    : {}", prob.total_tasks());
    println!("nodes    : {}", prob.network.n_nodes());
    let span = prob.graphs.last().map(|(a, _)| *a).unwrap_or(0.0);
    println!("arrivals : 0.0 .. {:.2}", span);
    if args.bool_flag("dot") {
        for (i, (_, g)) in prob.graphs.iter().take(3).enumerate() {
            println!("# graph {i}: {}\n{}", g.name(), g.to_dot());
        }
    }
    0
}

fn cmd_validate(args: &Args) -> i32 {
    let Ok(dataset) = dataset_of(args) else { return 2 };
    let n = args.usize_flag("graphs", 30);
    let seed = args.u64_flag("seed", 0);
    let label = args.flag("variant").unwrap_or("5P-HEFT");
    let Some(variant) = Variant::parse(label) else {
        eprintln!("error: bad --variant '{label}'");
        return 2;
    };
    let prob = dataset.instance(n, seed);
    let res = variant.coordinator(seed).run(&prob);
    let viol = validate(&res.schedule, &prob.graphs, &prob.network);
    let rep = replay(&res.schedule, &prob.graphs, &prob.network);
    println!("variant {} on {} ({n} graphs):", variant.label(), dataset.name());
    println!("  §II validator : {} violations", viol.len());
    println!("  replay        : {} errors", rep.errors.len());
    println!("  busy fraction : {:.4}", rep.avg_busy_fraction);
    for v in viol.iter().take(5) {
        println!("    {}", v.0);
    }
    for e in rep.errors.iter().take(5) {
        println!("    {e}");
    }
    if viol.is_empty() && rep.errors.is_empty() {
        println!("  OK");
        0
    } else {
        1
    }
}

fn cmd_analyze(args: &Args) -> i32 {
    let Ok(dataset) = dataset_of(args) else { return 2 };
    let n = args.usize_flag("graphs", 12);
    let seed = args.u64_flag("seed", 0);
    let label = args.flag("variant").unwrap_or("5P-HEFT");
    let Some(variant) = Variant::parse(label) else {
        eprintln!("error: bad --variant '{label}'");
        return 2;
    };
    let prob = dataset.instance(n, seed);
    let res = variant.coordinator(seed).run(&prob);
    let m = res.metrics(&prob);

    println!("{} on {} ({n} graphs, seed {seed}):\n", variant.label(), dataset.name());
    print!("{}", crate::gantt::ascii(&res.schedule, &prob, args.usize_flag("width", 100)));
    println!(
        "\nmakespan {}  mean-makespan {}  flowtime {}  util {}  sched {:.3} ms",
        report::fmt(m.total_makespan),
        report::fmt(m.mean_makespan),
        report::fmt(m.mean_flowtime),
        report::fmt(m.mean_utilization),
        m.runtime_s * 1e3
    );
    // preemption activity summary
    let reverted: usize = res.events.iter().map(|e| e.n_reverted).sum();
    let peak = res.events.iter().map(|e| e.n_pending).max().unwrap_or(0);
    println!("reverted tasks total: {reverted}   peak composite: {peak} tasks");

    // slack analysis of the whole workload as one composite (what-if view)
    let all: Vec<crate::graph::Gid> = prob
        .graphs
        .iter()
        .enumerate()
        .flat_map(|(gi, (_, g))| (0..g.n_tasks()).map(move |t| crate::graph::Gid::new(gi, t)))
        .collect();
    let composite = crate::coordinator::composite_of(&all, &prob);
    let slack = crate::analysis::slack_analysis(&composite, &prob.network);
    let crit = slack.critical_tasks(1e-9);
    println!("critical tasks (top 5 by remaining work):");
    for &i in crit.iter().take(5) {
        println!(
            "  {}  cp {:.1}  from {:.1}",
            composite.tasks[i].gid, slack.cp_of[i], slack.from[i]
        );
    }

    if let Some(path) = args.flag("svg") {
        let svg = crate::gantt::svg(&res.schedule, &prob, 1000);
        if let Err(e) = std::fs::write(path, svg) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    if let Some(path) = args.flag("trace") {
        let v = crate::trace::to_json(&prob, &res);
        if let Err(e) = std::fs::write(path, v.to_string()) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

fn cmd_info(args: &Args) -> i32 {
    println!("dts {}", crate::version());
    let dir = args.flag("artifacts").unwrap_or("artifacts");
    match runtime::XlaRuntime::load(dir) {
        Ok(rt) => {
            println!("artifacts: {dir} (loaded)");
            println!("rank buckets: {:?}", rt.rank_buckets());
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags_and_positionals() {
        let a = parse_args(&argv("run --dataset synthetic --graphs 10 --xla"));
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.flag("dataset"), Some("synthetic"));
        assert_eq!(a.usize_flag("graphs", 0), 10);
        assert!(a.bool_flag("xla"));
        assert!(!a.bool_flag("other"));
    }

    #[test]
    fn parse_key_equals_value() {
        let a = parse_args(&argv("experiment --dataset=adv --trials=2"));
        assert_eq!(a.flag("dataset"), Some("adv"));
        assert_eq!(a.usize_flag("trials", 0), 2);
    }

    #[test]
    fn jobs_flag_parses() {
        let a = parse_args(&argv("experiment --dataset synthetic --jobs 4"));
        assert_eq!(a.usize_flag("jobs", 1), 4);
        let a = parse_args(&argv("experiment --dataset synthetic"));
        assert_eq!(a.usize_flag("jobs", 1), 1);
    }

    #[test]
    fn unknown_subcommand_usage() {
        assert_eq!(main_with(&argv("bogus")), 2);
        assert_eq!(main_with(&[]), 2);
    }

    #[test]
    fn run_and_validate_smoke() {
        assert_eq!(
            main_with(&argv(
                "run --dataset synthetic --graphs 6 --seed 1 --variant 2P-HEFT"
            )),
            0
        );
        assert_eq!(
            main_with(&argv(
                "validate --dataset adversarial --graphs 6 --seed 1 --variant P-CPOP"
            )),
            0
        );
        assert_eq!(main_with(&argv("generate --dataset riotbench --graphs 5")), 0);
    }

    #[test]
    fn simulate_smoke() {
        assert_eq!(
            main_with(&argv(
                "simulate --dataset synthetic --graphs 5 --trials 1 \
                 --noise 0.0,0.4 --threshold 0.2,none --k 2 --jobs 2"
            )),
            0
        );
    }

    #[test]
    fn simulate_scale_smoke() {
        // --scale multiplies --graphs (the large-composite stress axis);
        // an 8-graph scaled run must complete like its unscaled twin
        assert_eq!(
            main_with(&argv(
                "simulate --dataset synthetic --graphs 4 --scale 2 --trials 1 \
                 --noise 0.3 --threshold 0.25 --k 2 --jobs 2"
            )),
            0
        );
        assert_eq!(
            main_with(&argv(
                "policy --dataset synthetic --graphs 3 --scale 2 --trials 1 \
                 --noise 0.3 --k 2 --threshold 0.25 --budget none --jobs 2"
            )),
            0
        );
    }

    #[test]
    fn simulate_shards_smoke() {
        // federated path: the node pool split across 2 clusters, cells
        // fanned out over 2 workers
        assert_eq!(
            main_with(&argv(
                "simulate --dataset synthetic --graphs 5 --trials 1 \
                 --noise 0.3 --threshold 0.25 --k 2 --shards 2 --jobs 2"
            )),
            0
        );
    }

    #[test]
    fn simulate_rejects_bad_input() {
        assert_eq!(main_with(&argv("simulate --dataset nope")), 2);
        assert_eq!(main_with(&argv("simulate")), 2);
        assert_eq!(
            main_with(&argv("simulate --dataset synthetic --noise abc")),
            2
        );
        assert_eq!(
            main_with(&argv("simulate --dataset synthetic --threshold wat")),
            2
        );
        assert_eq!(
            main_with(&argv("simulate --dataset synthetic --noise -0.3")),
            2
        );
        assert_eq!(
            main_with(&argv("simulate --dataset synthetic --threshold nan")),
            2
        );
        assert_eq!(
            main_with(&argv("simulate --dataset synthetic --variant WAT")),
            2
        );
        // --shards must be an explicit positive integer (usize_flag's
        // silent default would otherwise mask both of these)
        assert_eq!(
            main_with(&argv("simulate --dataset synthetic --shards 0")),
            2
        );
        assert_eq!(
            main_with(&argv("simulate --dataset synthetic --shards two")),
            2
        );
    }

    #[test]
    fn count_flags_reject_garbage() {
        // the strict parse covers every count-like flag, not just
        // --shards: a typo'd value must abort, never silently fall back
        // to the default and change the experiment
        for bad in [
            "simulate --dataset synthetic --scale 1O",
            "simulate --dataset synthetic --scale 0",
            "simulate --dataset synthetic --jobs x",
            "simulate --dataset synthetic --jobs 0",
            "simulate --dataset synthetic --k 0",
            "simulate --dataset synthetic --k two",
            "simulate --dataset synthetic --trials 0",
            "simulate --dataset synthetic --graphs -4",
            "policy --dataset synthetic --scale 1O",
            "policy --dataset synthetic --jobs 0",
            "policy --dataset synthetic --k 0,2",
            "policy --dataset synthetic --trials x",
            "policy --dataset synthetic --graphs 0",
            "policy --dataset synthetic --adaptive --kmax 0",
            "experiment --dataset synthetic --jobs wat",
            "experiment --dataset synthetic --graphs 0",
            "experiment --dataset synthetic --trials -1",
        ] {
            assert_eq!(main_with(&argv(bad)), 2, "{bad}");
        }
    }

    #[test]
    fn float_flags_reject_garbage() {
        // strict parsing extends to every float-valued knob: a typo'd
        // `--noise 0.3O` (or a silent `--burst 4O` fallback) must abort
        // with exit 2, never quietly change the experiment
        for bad in [
            "simulate --dataset synthetic --noise 0.3O",
            "policy --dataset synthetic --noise 0.3O",
            "simulate --dataset synthetic --threshold 0.2S",
            "policy --dataset synthetic --burst 4O",
            "policy --dataset synthetic --burst x",
            "policy --dataset synthetic --cooldown 1O",
            "policy --dataset synthetic --cooldown wat",
            "policy --dataset synthetic --adaptive --target-stretch 2O",
            "policy --dataset synthetic --adaptive --target-stretch inf",
            "policy --dataset synthetic --deadline-slack 1.5x",
        ] {
            assert_eq!(main_with(&argv(bad)), 2, "{bad}");
        }
    }

    #[test]
    fn fault_flags_parse_strictly() {
        use crate::sim::{FaultConfig, FaultModel, DEFAULT_FAULT_SEED};
        // no flags: disabled, bit-identical to pre-fault runs
        let a = parse_args(&argv("simulate --dataset synthetic"));
        assert_eq!(fault_config_of(&a).unwrap(), FaultConfig::NONE);
        // both flags arm the crash model, default jitter seed
        let a = parse_args(&argv("simulate --dataset synthetic --mtbf 50 --mttr 5"));
        let fc = fault_config_of(&a).unwrap();
        assert_eq!(fc.model, FaultModel::Crash { mtbf: 50.0, mttr: 5.0 });
        assert_eq!(fc.seed, DEFAULT_FAULT_SEED);
        assert_eq!(fc.node_base, 0);
        let a = parse_args(&argv(
            "simulate --dataset synthetic --mtbf 50 --mttr 5 --fault-seed 9",
        ));
        assert_eq!(fault_config_of(&a).unwrap().seed, 9);
        // strict rejects: lone flags, garbage, non-positive parameters
        for bad in [
            "simulate --dataset synthetic --mtbf 50",
            "simulate --dataset synthetic --mttr 5",
            "simulate --dataset synthetic --fault-seed 9",
            "simulate --dataset synthetic --mtbf 5O --mttr 5",
            "simulate --dataset synthetic --mtbf 50 --mttr 0",
            "simulate --dataset synthetic --mtbf -50 --mttr 5",
            "simulate --dataset synthetic --mtbf 50 --mttr 5 --fault-seed -1",
            "simulate --dataset synthetic --mtbf 50 --mttr 5 --fault-seed x",
        ] {
            let a = parse_args(&argv(bad));
            assert!(fault_config_of(&a).is_err(), "{bad}");
            assert_eq!(main_with(&argv(bad)), 2, "{bad}");
        }
        // the reject propagates on policy and serve too
        assert_eq!(main_with(&argv("policy --dataset synthetic --mtbf 50")), 2);
        assert_eq!(main_with(&argv("serve --dataset synthetic --mttr 5")), 2);
        assert_eq!(
            main_with(&argv("serve --dataset synthetic --max-line-bytes 0")),
            2
        );
    }

    #[test]
    fn simulate_faults_smoke() {
        assert_eq!(
            main_with(&argv(
                "simulate --dataset synthetic --graphs 5 --trials 1 \
                 --noise 0.3 --threshold 0.25 --k 2 --mtbf 50 --mttr 5"
            )),
            0
        );
        assert_eq!(
            main_with(&argv(
                "policy --dataset synthetic --graphs 4 --trials 1 --noise 0.3 \
                 --k 2 --threshold 0.25 --budget none --mtbf 40 --mttr 4"
            )),
            0
        );
    }

    #[test]
    fn serve_rejects_bad_flags() {
        // every serve flag resolves strictly before any stdin is read,
        // so the reject paths are testable without a session
        for bad in [
            "serve",
            "serve --dataset nope",
            "serve --dataset synthetic --noise 0.3O",
            "serve --dataset synthetic --noise -0.1",
            "serve --dataset synthetic --threshold wat",
            "serve --dataset synthetic --k 0",
            "serve --dataset synthetic --k two",
            "serve --dataset synthetic --graphs 0",
            "serve --dataset synthetic --shards two",
            "serve --dataset synthetic --jobs 0",
            "serve --dataset synthetic --snapshot-every x",
            "serve --dataset synthetic --variant WAT",
            "serve --dataset synthetic --deadline-aware --threshold none",
            "serve --dataset synthetic --restore /nonexistent/snapshot.json",
        ] {
            assert_eq!(main_with(&argv(bad)), 2, "{bad}");
        }
    }

    #[test]
    fn trace_subcommand_requires_events() {
        assert_eq!(main_with(&argv("trace")), 2);
        assert_eq!(main_with(&argv("trace --events /nonexistent.json")), 1);
    }

    #[test]
    fn trace_events_prints_trace_event_lines() {
        // record a trace, then `dts trace --events` must print its
        // events array verbatim, one JSON object per line — the helper
        // the CI serve-smoke byte-diff is built on
        let path = std::env::temp_dir().join("dts_cli_trace_events_test.json");
        let path_s = path.to_str().unwrap();
        let cmd = format!(
            "simulate --dataset synthetic --graphs 4 --trials 1 \
             --noise 0.3 --threshold 0.25 --k 2 --trace {path_s}"
        );
        assert_eq!(main_with(&argv(&cmd)), 0);
        let doc = std::fs::read_to_string(&path).unwrap();
        let v = crate::json::Value::from_str(&doc).unwrap();
        let n_events = v.get("events").unwrap().as_array().unwrap().len();
        assert!(n_events > 0);
        assert_eq!(main_with(&argv(&format!("trace --events {path_s}"))), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn simulate_telemetry_flag_writes_ndjson() {
        let path = std::env::temp_dir().join("dts_cli_tele_test.ndjson");
        let path_s = path.to_str().unwrap();
        let cmd = format!(
            "simulate --dataset synthetic --graphs 4 --trials 1 \
             --noise 0.3 --threshold 0.25 --k 2 --telemetry {path_s}"
        );
        assert_eq!(main_with(&argv(&cmd)), 0);
        let doc = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let first = doc.lines().next().unwrap();
        assert!(first.contains("dts-telemetry-v1"), "{first}");
        // meta + 1 span per scenario + full registry snapshot
        assert!(doc.lines().count() > 1 + 1);
        assert!(doc.contains("\"kind\":\"span\""));
        assert!(doc.contains("\"key\":\"replans\""));
        assert!(doc.contains("\"key\":\"replan_wall_ns\""));
    }

    #[test]
    fn policy_smoke() {
        assert_eq!(
            main_with(&argv(
                "policy --dataset synthetic --graphs 5 --trials 1 --noise 0.3 \
                 --k 2,4 --threshold 0.2 --budget none,1.0 --adaptive --jobs 2"
            )),
            0
        );
    }

    #[test]
    fn policy_rejects_bad_input() {
        assert_eq!(main_with(&argv("policy")), 2);
        assert_eq!(main_with(&argv("policy --dataset nope")), 2);
        assert_eq!(main_with(&argv("policy --dataset synthetic --k x")), 2);
        assert_eq!(
            main_with(&argv("policy --dataset synthetic --noise -1")),
            2
        );
        assert_eq!(
            main_with(&argv("policy --dataset synthetic --threshold wat")),
            2
        );
        assert_eq!(
            main_with(&argv("policy --dataset synthetic --budget -2")),
            2
        );
        assert_eq!(
            main_with(&argv("policy --dataset synthetic --burst 0.2")),
            2
        );
        assert_eq!(
            main_with(&argv("policy --dataset synthetic --cooldown -5")),
            2
        );
        assert_eq!(
            main_with(&argv("policy --dataset synthetic --variant WAT")),
            2
        );
        assert_eq!(
            main_with(&argv(
                "policy --dataset synthetic --adaptive --target-stretch -1"
            )),
            2
        );
    }

    #[test]
    fn policy_grid_shape() {
        // 2 noise × (1 baseline + 2θ × (2k × 2budgets + 1 adaptive))
        let grid = policy_grid(
            &[0.0, 0.3],
            &[2, 5],
            &[0.1, 0.25],
            &[None, Some(1.0)],
            4.0,
            Some((10, 2.0)),
            false,
            0.0,
        );
        assert_eq!(grid.len(), 2 * (1 + 2 * (2 * 2 + 1)));
        // cooldown wraps every reactive spec but never the baseline
        let wrapped = policy_grid(&[0.3], &[3], &[0.25], &[None], 4.0, None, false, 5.0);
        assert_eq!(wrapped.len(), 2);
        assert_eq!(wrapped[0].spec, PolicySpec::None);
        assert!(matches!(wrapped[1].spec, PolicySpec::Cooldown { .. }));
        assert_eq!(wrapped[1].label(), "σ0.30/L3@0.25+cd5");
        // --deadline-aware adds one D{k}@{θ} per (θ, k)
        let with_da =
            policy_grid(&[0.3], &[2, 5], &[0.1, 0.25], &[None], 4.0, None, true, 0.0);
        // 1 baseline + 2θ × 2k × (1 fixed + 1 deadline-aware)
        assert_eq!(with_da.len(), 1 + 2 * 2 * 2);
        let labels: Vec<String> = with_da.iter().map(|s| s.label()).collect();
        assert!(labels.contains(&"σ0.30/D2@0.1".to_string()), "{labels:?}");
        assert!(labels.contains(&"σ0.30/D5@0.25".to_string()), "{labels:?}");
    }

    #[test]
    fn scenario_flags_parse() {
        let a = parse_args(&argv(
            "policy --dataset synthetic --weighted --deadline-slack 2.0 \
             --arrival bursty --burst-size 3",
        ));
        let s = scenario_of(&a).unwrap();
        assert_eq!(s.weights, WeightModel::HeavyTail { alpha: 1.5 });
        assert_eq!(s.deadlines, DeadlineModel::CritPathSlack { slack: 2.0 });
        assert_eq!(s.arrivals, ArrivalModel::Bursty { burst: 3 });
        assert_eq!(s.label(), "w:pareto1.5+d:s2+a:burst3");

        let a = parse_args(&argv("simulate --dataset synthetic --weighted classes"));
        let s = scenario_of(&a).unwrap();
        assert!(matches!(s.weights, WeightModel::Classes { .. }));
        assert_eq!(s.deadlines, DeadlineModel::None);
        assert_eq!(s.arrivals, ArrivalModel::Poisson);

        // no flags: the paper-default scenario
        let a = parse_args(&argv("simulate --dataset synthetic"));
        assert!(scenario_of(&a).unwrap().is_default());

        // rejects
        for bad in [
            "simulate --dataset synthetic --weighted wat",
            "simulate --dataset synthetic --deadline-slack -1",
            "simulate --dataset synthetic --deadline-slack nan",
            "simulate --dataset synthetic --arrival wat",
            "simulate --dataset synthetic --arrival bursty --burst-size 0",
            "simulate --dataset synthetic --arrival bursty --burst-size 3x",
            "simulate --dataset synthetic --arrival bursty --burst-size -3",
        ] {
            let a = parse_args(&argv(bad));
            assert!(scenario_of(&a).is_err(), "{bad}");
        }
    }

    #[test]
    fn simulate_scenario_smoke() {
        assert_eq!(
            main_with(&argv(
                "simulate --dataset synthetic --graphs 5 --trials 1 \
                 --noise 0.3 --threshold 0.2,none --k 2 --jobs 2 \
                 --weighted --deadline-slack 1.5 --arrival bursty --burst-size 2"
            )),
            0
        );
    }

    #[test]
    fn policy_deadline_scenario_smoke() {
        assert_eq!(
            main_with(&argv(
                "policy --dataset synthetic --graphs 5 --trials 1 --noise 0.3 \
                 --k 2 --threshold 0.2 --budget none --deadline-aware \
                 --weighted --deadline-slack 2.0 --jobs 2"
            )),
            0
        );
    }

    #[test]
    fn scenario_rejects_propagate_to_exit_code() {
        assert_eq!(
            main_with(&argv("simulate --dataset synthetic --deadline-slack -2")),
            2
        );
        assert_eq!(
            main_with(&argv("policy --dataset synthetic --arrival wat")),
            2
        );
    }

    #[test]
    fn append_csv_keeps_one_header() {
        let mut out = String::new();
        append_csv(&mut out, "h1,h2\na,1\n", true);
        append_csv(&mut out, "h1,h2\nb,2\nc,3\n", false);
        assert_eq!(out, "h1,h2\na,1\nb,2\nc,3\n");
    }

    #[test]
    fn usize_lists_parse() {
        assert_eq!(parse_usize_list("1,3,5"), Some(vec![1, 3, 5]));
        assert_eq!(parse_usize_list(" 2 , 4 "), Some(vec![2, 4]));
        assert!(parse_usize_list("x").is_none());
        assert!(parse_usize_list("").is_none());
        assert!(parse_usize_list("1,-2").is_none());
    }

    #[test]
    fn scenario_lists_parse() {
        assert_eq!(
            parse_threshold_list("0.25,none"),
            Some(vec![Some(0.25), None])
        );
        assert_eq!(parse_threshold_list("NONE"), Some(vec![None]));
        assert!(parse_threshold_list("x").is_none());
        assert_eq!(parse_f64_list("0.1, 0.2"), Some(vec![0.1, 0.2]));
        assert!(parse_f64_list("").is_none());
        assert!(parse_f64_list("1.0,zz").is_none());
    }

    #[test]
    fn run_rejects_bad_variant() {
        assert_eq!(
            main_with(&argv("run --dataset synthetic --variant WAT")),
            2
        );
    }
}
