//! Critical-path / slack analysis of composite problems — the analysis
//! tool behind `dts analyze`, and the consumer of the `allpairs_n{N}`
//! XLA artifact (all-pairs tropical longest path; native DP here is the
//! reference implementation the artifact is parity-tested against).
//!
//! Definitions over mean costs (`w̄`, `c̄`, as in the rank computations):
//! * `to(t)`   — longest path ending at t (excluding t's own cost)
//! * `from(t)` — longest path starting at t (including t's own cost)
//! * `cp`      — the component's critical-path length `max_t to(t)+from(t)`
//! * `slack(t)`— `cp − (to(t) + from(t))`: 0 ⇔ t is on the critical path

use crate::network::Network;
use crate::schedulers::common::{mean_costs, topo_order};
use crate::schedulers::Problem;

/// Per-task slack report.
#[derive(Clone, Debug)]
pub struct SlackReport {
    /// longest path into each task (mean-cost weighted, excl. own cost)
    pub to: Vec<f64>,
    /// longest path out of each task (incl. own cost)
    pub from: Vec<f64>,
    /// critical path length of each task's component
    pub cp_of: Vec<f64>,
    /// slack per task (0 = critical)
    pub slack: Vec<f64>,
}

impl SlackReport {
    /// Indices of critical tasks (slack ≤ tol), most critical first by
    /// descending `from`.
    pub fn critical_tasks(&self, tol: f64) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.slack.len())
            .filter(|&i| self.slack[i] <= tol)
            .collect();
        idx.sort_by(|&a, &b| self.from[b].partial_cmp(&self.from[a]).unwrap());
        idx
    }
}

/// Native O(E) slack analysis over the pending composite graph.
pub fn slack_analysis(prob: &Problem, net: &Network) -> SlackReport {
    let n = prob.n_tasks();
    let (w, succ_costs) = mean_costs(prob, net);
    let order = topo_order(prob);

    // from(t): DP over reverse topological order
    let mut from = vec![0.0f64; n];
    for &t in order.iter().rev() {
        let mut best = 0.0f64;
        for &(c, cbar) in &succ_costs[t] {
            best = best.max(cbar + from[c]);
        }
        from[t] = w[t] + best;
    }
    // to(t): DP over topological order
    let mut to = vec![0.0f64; n];
    for &t in order.iter() {
        for &(c, cbar) in &succ_costs[t] {
            to[c] = to[c].max(to[t] + w[t] + cbar);
        }
    }
    // per-component critical path
    let comp = crate::schedulers::common::components(prob);
    let n_comp = comp.iter().copied().max().map_or(0, |m| m + 1);
    let mut cp = vec![0.0f64; n_comp];
    for t in 0..n {
        cp[comp[t]] = cp[comp[t]].max(to[t] + from[t]);
    }
    let cp_of: Vec<f64> = (0..n).map(|t| cp[comp[t]]).collect();
    let slack: Vec<f64> = (0..n).map(|t| cp_of[t] - (to[t] + from[t])).collect();
    SlackReport {
        to,
        from,
        cp_of,
        slack,
    }
}

/// Native all-pairs longest path over the pending composite graph, with
/// edge weight `c̄(u,v) + w̄(v)` (so `d[u][v]` is the extra completion
/// depth v adds after u).  `NEG_D` marks unreachable pairs.  This is the
/// semantic the `allpairs_n{N}` artifact computes (parity-tested in
/// `integration_runtime`).
pub const NEG_D: f64 = -1e30;

pub fn allpairs_longest_native(prob: &Problem, net: &Network) -> Vec<Vec<f64>> {
    let n = prob.n_tasks();
    let (w, succ_costs) = mean_costs(prob, net);
    let order = topo_order(prob);
    let mut d = vec![vec![NEG_D; n]; n];
    for t in 0..n {
        d[t][t] = 0.0;
    }
    // process in reverse topo: d[u] = max over edges (u,c) of
    // edge + d[c] (shifted by c's own cost on entry)
    for &u in order.iter().rev() {
        for &(c, cbar) in &succ_costs[u] {
            let edge = cbar + w[c];
            for v in 0..n {
                if d[c][v] > NEG_D / 2.0 {
                    let cand = edge + d[c][v];
                    if cand > d[u][v] {
                        d[u][v] = cand;
                    }
                }
            }
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedulers::testutil::problem_from_graph;

    fn chain_prob() -> Problem {
        let mut b = GraphBuilder::new("chain");
        let t0 = b.task(2.0);
        let t1 = b.task(4.0);
        let t2 = b.task(6.0);
        b.edge(t0, t1, 0.0).edge(t1, t2, 0.0);
        problem_from_graph(&b.build().unwrap(), 0, 0.0)
    }

    #[test]
    fn chain_is_fully_critical() {
        let net = Network::homogeneous(2);
        let r = slack_analysis(&chain_prob(), &net);
        for s in &r.slack {
            assert!(s.abs() < 1e-9, "{:?}", r.slack);
        }
        assert_eq!(r.critical_tasks(1e-9).len(), 3);
        assert!((r.cp_of[0] - 12.0).abs() < 1e-9);
    }

    #[test]
    fn diamond_light_branch_has_slack() {
        let mut b = GraphBuilder::new("d");
        let t0 = b.task(1.0);
        let heavy = b.task(10.0);
        let light = b.task(2.0);
        let t3 = b.task(1.0);
        b.edge(t0, heavy, 0.0)
            .edge(t0, light, 0.0)
            .edge(heavy, t3, 0.0)
            .edge(light, t3, 0.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(1);
        let r = slack_analysis(&prob, &net);
        assert!(r.slack[2] > 7.9, "light branch slack {:?}", r.slack);
        assert!(r.slack[1].abs() < 1e-9);
        let crit = r.critical_tasks(1e-9);
        assert_eq!(crit, vec![0, 1, 3]);
    }

    #[test]
    fn allpairs_native_chain_values() {
        let net = Network::homogeneous(1);
        let d = allpairs_longest_native(&chain_prob(), &net);
        // d[0][1] = w(1) = 4 (no comm on homogeneous single? comm 0 data)
        assert!((d[0][1] - 4.0).abs() < 1e-9);
        assert!((d[0][2] - 10.0).abs() < 1e-9);
        assert!(d[2][0] <= NEG_D / 2.0);
        assert_eq!(d[1][1], 0.0);
    }

    #[test]
    fn slack_consistent_with_allpairs() {
        // from(t) − w(t) must equal max_v d[t][v]
        use crate::prng::Xoshiro256pp;
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut b = GraphBuilder::new("rand");
        let n = 18;
        let ids: Vec<_> = (0..n).map(|_| b.task(rng.uniform(1.0, 9.0))).collect();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.next_f64() < 0.25 {
                    b.edge(ids[i], ids[j], rng.uniform(0.0, 5.0));
                }
            }
        }
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(3);
        let r = slack_analysis(&prob, &net);
        let d = allpairs_longest_native(&prob, &net);
        let (w, _) = crate::schedulers::common::mean_costs(&prob, &net);
        for t in 0..n {
            let reach_max = d[t].iter().cloned().fold(NEG_D, f64::max).max(0.0);
            assert!(
                ((r.from[t] - w[t]) - reach_max).abs() < 1e-9,
                "task {t}: from-w {} vs allpairs {}",
                r.from[t] - w[t],
                reach_max
            );
        }
    }
}
