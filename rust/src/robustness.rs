//! Plan-robustness evaluation: what happens to a schedule when actual
//! execution times deviate from the cost estimates the scheduler used?
//!
//! Mission-critical settings (the paper's IoBT motivation) rarely have
//! exact cost knowledge.  We keep every *decision* the coordinator made —
//! task-to-node assignment and the per-node execution order — and
//! re-derive start/finish times under perturbed durations with
//! work-conserving left-shift semantics:
//!
//!   start(t) = max( a_i, finish(prev task on t's node),
//!                   max_p finish(p) + comm(p, t) )
//!
//! The realized schedule is §II-valid by construction; comparing its
//! makespan to the plan's quantifies how brittle each preemption policy's
//! plans are.

use crate::coordinator::DynamicProblem;
use crate::graph::Gid;
use crate::prng::Xoshiro256pp;
use crate::schedule::{Assignment, Schedule};
use crate::stats::TruncatedGaussian;

/// Re-derive a schedule under perturbed durations, preserving assignments
/// and per-node order.  `factor(gid)` scales each task's planned duration
/// (1.0 = as planned).
pub fn realize(
    planned: &Schedule,
    problem: &DynamicProblem,
    mut factor: impl FnMut(Gid) -> f64,
) -> Schedule {
    let n_nodes = problem.network.n_nodes();
    // per-node execution order = planned start order
    let mut order: Vec<Vec<Gid>> = vec![Vec::new(); n_nodes];
    for v in 0..n_nodes {
        order[v] = planned.timelines().slot_gids(v).to_vec();
    }
    let factors: crate::fasthash::FxHashMap<Gid, f64> = planned
        .iter()
        .map(|(g, _)| (*g, factor(*g).max(1e-6)))
        .collect();

    // iterate: a task is placeable once its node-predecessor and graph
    // predecessors are all placed.  Worklist over nodes round-robin.
    let mut realized = Schedule::new(n_nodes);
    let mut next_idx = vec![0usize; n_nodes];
    let mut placed_any = true;
    while placed_any {
        placed_any = false;
        for v in 0..n_nodes {
            'node: while next_idx[v] < order[v].len() {
                let gid = order[v][next_idx[v]];
                let (arrival, g) = &problem.graphs[gid.graph as usize];
                // all graph predecessors realized?
                let mut ready = *arrival;
                for &(p, data) in g.predecessors(gid.task as usize) {
                    let pgid = Gid::new(gid.graph as usize, p);
                    match realized.get(pgid) {
                        None => break 'node,
                        Some(pa) => {
                            ready = ready
                                .max(pa.finish + problem.network.comm_time(data, pa.node, v));
                        }
                    }
                }
                // node predecessor
                if next_idx[v] > 0 {
                    let prev = order[v][next_idx[v] - 1];
                    ready = ready.max(realized.get(prev).unwrap().finish);
                }
                let planned_a = planned.get(gid).unwrap();
                let dur = (planned_a.finish - planned_a.start) * factors[&gid];
                realized.assign(
                    gid,
                    Assignment {
                        node: v,
                        start: ready,
                        finish: ready + dur,
                    },
                );
                next_idx[v] += 1;
                placed_any = true;
            }
        }
    }
    assert_eq!(
        realized.n_assigned(),
        planned.n_assigned(),
        "realization deadlocked — planned order inconsistent with deps"
    );
    realized
}

/// Truncation bounds of the multiplicative noise factor distributions.
pub const NOISE_LO: f64 = 0.25;
pub const NOISE_HI: f64 = 4.0;

/// Multiplicative noise model: factors ~ TruncatedGaussian(1, std | lo, hi).
pub fn noise_factors(
    std: f64,
    seed: u64,
) -> impl FnMut(Gid) -> f64 {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let dist = TruncatedGaussian::new(1.0, std, NOISE_LO, NOISE_HI);
    move |_gid| dist.sample(&mut rng)
}

/// **Call-order-independent** noise: the factor of a task is a pure
/// function of `(std, seed, gid)`, not of the sampling sequence.
///
/// [`noise_factors`] draws sequentially, so the factor a task receives
/// depends on how many tasks were sampled before it — fine for the
/// post-hoc [`realize`] pass (which samples every task once, in map
/// order), but wrong for the reactive runtime simulator, where the
/// *dispatch* order depends on the policy and straggler threshold under
/// test.  `StableNoise` guarantees that two simulations of the same
/// instance with the same `(std, seed)` expose every task to the same
/// realized duration, whatever the coordinator decides — the apples-to-
/// apples requirement for comparing reaction policies under noise.
#[derive(Clone, Copy, Debug)]
pub struct StableNoise {
    std: f64,
    seed: u64,
}

impl StableNoise {
    pub fn new(std: f64, seed: u64) -> Self {
        assert!(std >= 0.0, "negative noise std {std}");
        Self { std, seed }
    }

    /// The multiplicative duration factor for `gid`.
    pub fn factor(&self, gid: Gid) -> f64 {
        if self.std == 0.0 {
            return 1.0;
        }
        // SplitMix-style mix of (seed, gid) into an independent stream
        let packed = ((gid.graph as u64) << 32) | (gid.task as u64);
        let mix = packed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ self.seed.rotate_left(17);
        let mut rng = Xoshiro256pp::seed_from_u64(mix);
        TruncatedGaussian::new(1.0, self.std, NOISE_LO, NOISE_HI).sample(&mut rng)
    }
}

/// Realized-vs-planned makespan ratio under noise (≥ ~1 for brittle
/// plans; can dip below 1 when left-shift reclaims planned slack).
pub fn degradation(
    planned: &Schedule,
    problem: &DynamicProblem,
    noise_std: f64,
    seed: u64,
) -> f64 {
    let realized = realize(planned, problem, noise_factors(noise_std, seed));
    let plan_mk = crate::metrics::total_makespan(planned, &problem.graphs);
    let real_mk = crate::metrics::total_makespan(&realized, &problem.graphs);
    real_mk / plan_mk
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, Policy};
    use crate::schedulers::SchedulerKind;
    use crate::workloads::Dataset;

    fn plan(policy: Policy) -> (DynamicProblem, Schedule) {
        let prob = Dataset::Synthetic.instance(10, 8);
        let mut c = Coordinator::new(policy, SchedulerKind::Heft.make(0));
        let res = c.run(&prob);
        (prob, res.schedule)
    }

    /// §II validity of a realized schedule, ignoring the duration-matches-
    /// cost constraint (durations are intentionally perturbed).
    fn check_realized(realized: &Schedule, prob: &DynamicProblem) {
        // replay checks ordering/overlap/deps/arrivals operationally and
        // does not assume durations equal c/s.
        let rep = crate::sim::replay(realized, &prob.graphs, &prob.network);
        assert!(rep.errors.is_empty(), "{:?}", &rep.errors[..rep.errors.len().min(3)]);
    }

    #[test]
    fn unit_noise_left_shifts_but_stays_valid() {
        for policy in [Policy::Preemptive, Policy::NonPreemptive, Policy::LastK(3)] {
            let (prob, planned) = plan(policy);
            let realized = realize(&planned, &prob, |_| 1.0);
            check_realized(&realized, &prob);
            let plan_mk = crate::metrics::total_makespan(&planned, &prob.graphs);
            let real_mk = crate::metrics::total_makespan(&realized, &prob.graphs);
            assert!(
                real_mk <= plan_mk + 1e-9,
                "left-shift can only improve: {real_mk} vs {plan_mk}"
            );
        }
    }

    #[test]
    fn realized_durations_scale_with_factors() {
        let (prob, planned) = plan(Policy::LastK(5));
        let realized = realize(&planned, &prob, |_| 2.0);
        check_realized(&realized, &prob);
        for (gid, a) in planned.iter() {
            let r = realized.get(*gid).unwrap();
            let want = 2.0 * (a.finish - a.start);
            assert!(((r.finish - r.start) - want).abs() < 1e-9);
        }
    }

    #[test]
    fn noise_degrades_makespan_on_average() {
        let (prob, planned) = plan(Policy::Preemptive);
        let mut worse = 0;
        for seed in 0..10 {
            if degradation(&planned, &prob, 0.4, seed) > 1.0 {
                worse += 1;
            }
        }
        assert!(worse >= 6, "heavy noise should usually hurt ({worse}/10)");
    }

    #[test]
    fn noise_model_is_seeded_and_bounded() {
        let mut f1 = noise_factors(0.3, 7);
        let mut f2 = noise_factors(0.3, 7);
        for i in 0..100 {
            let g = Gid::new(0, i);
            let a = f1(g);
            assert_eq!(a, f2(g));
            assert!((0.25..=4.0).contains(&a));
        }
    }

    #[test]
    fn uniform_speedup_beats_the_plan() {
        // factor < 1 left-shifts every task; the realized makespan must
        // be at most the proportionally shrunk plan — and strictly beat
        // the plan itself.
        let (prob, planned) = plan(Policy::LastK(3));
        let realized = realize(&planned, &prob, |_| 0.5);
        check_realized(&realized, &prob);
        let plan_mk = crate::metrics::total_makespan(&planned, &prob.graphs);
        let real_mk = crate::metrics::total_makespan(&realized, &prob.graphs);
        assert!(real_mk < plan_mk, "speedup must improve: {real_mk} vs {plan_mk}");
        for (gid, a) in planned.iter() {
            let r = realized.get(*gid).unwrap();
            let want = 0.5 * (a.finish - a.start);
            assert!(((r.finish - r.start) - want).abs() < 1e-9);
        }
    }

    #[test]
    fn empty_schedule_realizes_to_empty() {
        let prob = Dataset::Synthetic.instance(3, 1);
        let planned = Schedule::new(prob.network.n_nodes());
        let realized = realize(&planned, &prob, |_| 2.0);
        assert_eq!(realized.n_assigned(), 0);
    }

    #[test]
    fn single_node_serialization_preserves_order_and_closes_gaps() {
        // All work on one node: realization must keep the planned
        // execution order and run back-to-back wherever the plan had
        // slack (no dependencies between consecutive slots required).
        use crate::graph::GraphBuilder;
        use crate::network::Network;

        let mut b = GraphBuilder::new("chain");
        let t0 = b.task(2.0);
        let t1 = b.task(3.0);
        let t2 = b.task(1.0);
        b.edge(t0, t1, 0.0);
        b.edge(t1, t2, 0.0);
        let g = b.build().unwrap();
        let prob = DynamicProblem::new(Network::homogeneous(1), vec![(0.0, g)]);
        let mut planned = Schedule::new(1);
        // deliberate slack between the planned slots
        planned.assign(Gid::new(0, 0), Assignment { node: 0, start: 0.0, finish: 2.0 });
        planned.assign(Gid::new(0, 1), Assignment { node: 0, start: 5.0, finish: 8.0 });
        planned.assign(Gid::new(0, 2), Assignment { node: 0, start: 11.0, finish: 12.0 });
        let realized = realize(&planned, &prob, |_| 1.0);
        // order preserved, gaps closed: [0,2], [2,5], [5,6]
        assert_eq!(realized.get(Gid::new(0, 0)), Some(&Assignment { node: 0, start: 0.0, finish: 2.0 }));
        assert_eq!(realized.get(Gid::new(0, 1)), Some(&Assignment { node: 0, start: 2.0, finish: 5.0 }));
        assert_eq!(realized.get(Gid::new(0, 2)), Some(&Assignment { node: 0, start: 5.0, finish: 6.0 }));
    }

    #[test]
    fn stable_noise_is_order_independent_and_bounded() {
        let noise = StableNoise::new(0.4, 99);
        // forward and reverse sampling orders give identical factors
        let fwd: Vec<f64> = (0..200).map(|i| noise.factor(Gid::new(i % 5, i))).collect();
        let rev: Vec<f64> = (0..200)
            .rev()
            .map(|i| noise.factor(Gid::new(i % 5, i)))
            .collect();
        for (a, b) in fwd.iter().zip(rev.iter().rev()) {
            assert_eq!(a, b);
        }
        for &f in &fwd {
            assert!((NOISE_LO..=NOISE_HI).contains(&f));
        }
        // distinct tasks get distinct draws (not one global factor)
        assert!(fwd.windows(2).any(|w| w[0] != w[1]));
        // zero std is exactly 1
        let clean = StableNoise::new(0.0, 7);
        assert_eq!(clean.factor(Gid::new(3, 14)), 1.0);
        // different seeds decorrelate
        let other = StableNoise::new(0.4, 100);
        assert_ne!(noise.factor(Gid::new(0, 0)), other.factor(Gid::new(0, 0)));
    }

    #[test]
    fn realization_valid_under_noise_for_all_policies() {
        for policy in [Policy::Preemptive, Policy::NonPreemptive, Policy::LastK(2)] {
            let (prob, planned) = plan(policy);
            for seed in 0..5 {
                let realized = realize(&planned, &prob, noise_factors(0.5, seed));
                check_realized(&realized, &prob);
            }
        }
    }
}
