//! Plan-robustness evaluation: what happens to a schedule when actual
//! execution times deviate from the cost estimates the scheduler used?
//!
//! Mission-critical settings (the paper's IoBT motivation) rarely have
//! exact cost knowledge.  We keep every *decision* the coordinator made —
//! task-to-node assignment and the per-node execution order — and
//! re-derive start/finish times under perturbed durations with
//! work-conserving left-shift semantics:
//!
//!   start(t) = max( a_i, finish(prev task on t's node),
//!                   max_p finish(p) + comm(p, t) )
//!
//! The realized schedule is §II-valid by construction; comparing its
//! makespan to the plan's quantifies how brittle each preemption policy's
//! plans are.

use crate::coordinator::DynamicProblem;
use crate::graph::Gid;
use crate::prng::Xoshiro256pp;
use crate::schedule::{Assignment, Schedule};
use crate::stats::TruncatedGaussian;

/// Re-derive a schedule under perturbed durations, preserving assignments
/// and per-node order.  `factor(gid)` scales each task's planned duration
/// (1.0 = as planned).
pub fn realize(
    planned: &Schedule,
    problem: &DynamicProblem,
    mut factor: impl FnMut(Gid) -> f64,
) -> Schedule {
    let n_nodes = problem.network.n_nodes();
    // per-node execution order = planned start order
    let mut order: Vec<Vec<Gid>> = vec![Vec::new(); n_nodes];
    for v in 0..n_nodes {
        order[v] = planned
            .timelines()
            .node_slots(v)
            .iter()
            .map(|s| s.gid)
            .collect();
    }
    let factors: crate::fasthash::FxHashMap<Gid, f64> = planned
        .iter()
        .map(|(g, _)| (*g, factor(*g).max(1e-6)))
        .collect();

    // iterate: a task is placeable once its node-predecessor and graph
    // predecessors are all placed.  Worklist over nodes round-robin.
    let mut realized = Schedule::new(n_nodes);
    let mut next_idx = vec![0usize; n_nodes];
    let mut placed_any = true;
    while placed_any {
        placed_any = false;
        for v in 0..n_nodes {
            'node: while next_idx[v] < order[v].len() {
                let gid = order[v][next_idx[v]];
                let (arrival, g) = &problem.graphs[gid.graph as usize];
                // all graph predecessors realized?
                let mut ready = *arrival;
                for &(p, data) in g.predecessors(gid.task as usize) {
                    let pgid = Gid::new(gid.graph as usize, p);
                    match realized.get(pgid) {
                        None => break 'node,
                        Some(pa) => {
                            ready = ready
                                .max(pa.finish + problem.network.comm_time(data, pa.node, v));
                        }
                    }
                }
                // node predecessor
                if next_idx[v] > 0 {
                    let prev = order[v][next_idx[v] - 1];
                    ready = ready.max(realized.get(prev).unwrap().finish);
                }
                let planned_a = planned.get(gid).unwrap();
                let dur = (planned_a.finish - planned_a.start) * factors[&gid];
                realized.assign(
                    gid,
                    Assignment {
                        node: v,
                        start: ready,
                        finish: ready + dur,
                    },
                );
                next_idx[v] += 1;
                placed_any = true;
            }
        }
    }
    assert_eq!(
        realized.n_assigned(),
        planned.n_assigned(),
        "realization deadlocked — planned order inconsistent with deps"
    );
    realized
}

/// Multiplicative noise model: factors ~ TruncatedGaussian(1, std | lo, hi).
pub fn noise_factors(
    std: f64,
    seed: u64,
) -> impl FnMut(Gid) -> f64 {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let dist = TruncatedGaussian::new(1.0, std, 0.25, 4.0);
    move |_gid| dist.sample(&mut rng)
}

/// Realized-vs-planned makespan ratio under noise (≥ ~1 for brittle
/// plans; can dip below 1 when left-shift reclaims planned slack).
pub fn degradation(
    planned: &Schedule,
    problem: &DynamicProblem,
    noise_std: f64,
    seed: u64,
) -> f64 {
    let realized = realize(planned, problem, noise_factors(noise_std, seed));
    let plan_mk = crate::metrics::total_makespan(planned, &problem.graphs);
    let real_mk = crate::metrics::total_makespan(&realized, &problem.graphs);
    real_mk / plan_mk
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, Policy};
    use crate::schedulers::SchedulerKind;
    use crate::workloads::Dataset;

    fn plan(policy: Policy) -> (DynamicProblem, Schedule) {
        let prob = Dataset::Synthetic.instance(10, 8);
        let mut c = Coordinator::new(policy, SchedulerKind::Heft.make(0));
        let res = c.run(&prob);
        (prob, res.schedule)
    }

    /// §II validity of a realized schedule, ignoring the duration-matches-
    /// cost constraint (durations are intentionally perturbed).
    fn check_realized(realized: &Schedule, prob: &DynamicProblem) {
        // replay checks ordering/overlap/deps/arrivals operationally and
        // does not assume durations equal c/s.
        let rep = crate::sim::replay(realized, &prob.graphs, &prob.network);
        assert!(rep.errors.is_empty(), "{:?}", &rep.errors[..rep.errors.len().min(3)]);
    }

    #[test]
    fn unit_noise_left_shifts_but_stays_valid() {
        for policy in [Policy::Preemptive, Policy::NonPreemptive, Policy::LastK(3)] {
            let (prob, planned) = plan(policy);
            let realized = realize(&planned, &prob, |_| 1.0);
            check_realized(&realized, &prob);
            let plan_mk = crate::metrics::total_makespan(&planned, &prob.graphs);
            let real_mk = crate::metrics::total_makespan(&realized, &prob.graphs);
            assert!(
                real_mk <= plan_mk + 1e-9,
                "left-shift can only improve: {real_mk} vs {plan_mk}"
            );
        }
    }

    #[test]
    fn realized_durations_scale_with_factors() {
        let (prob, planned) = plan(Policy::LastK(5));
        let realized = realize(&planned, &prob, |_| 2.0);
        check_realized(&realized, &prob);
        for (gid, a) in planned.iter() {
            let r = realized.get(*gid).unwrap();
            let want = 2.0 * (a.finish - a.start);
            assert!(((r.finish - r.start) - want).abs() < 1e-9);
        }
    }

    #[test]
    fn noise_degrades_makespan_on_average() {
        let (prob, planned) = plan(Policy::Preemptive);
        let mut worse = 0;
        for seed in 0..10 {
            if degradation(&planned, &prob, 0.4, seed) > 1.0 {
                worse += 1;
            }
        }
        assert!(worse >= 6, "heavy noise should usually hurt ({worse}/10)");
    }

    #[test]
    fn noise_model_is_seeded_and_bounded() {
        let mut f1 = noise_factors(0.3, 7);
        let mut f2 = noise_factors(0.3, 7);
        for i in 0..100 {
            let g = Gid::new(0, i);
            let a = f1(g);
            assert_eq!(a, f2(g));
            assert!((0.25..=4.0).contains(&a));
        }
    }

    #[test]
    fn realization_valid_under_noise_for_all_policies() {
        for policy in [Policy::Preemptive, Policy::NonPreemptive, Policy::LastK(2)] {
            let (prob, planned) = plan(policy);
            for seed in 0..5 {
                let realized = realize(&planned, &prob, noise_factors(0.5, seed));
                check_realized(&realized, &prob);
            }
        }
    }
}
