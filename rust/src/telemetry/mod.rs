//! Telemetry registry (§Observability, PR 8).
//!
//! A deterministic, allocation-free counter/histogram registry that the
//! hot layers (belief refresh, base heuristics, timeline transactions,
//! the federation admission layer) record into.  Three design rules:
//!
//! 1. **Bit-transparency** — recording never feeds a scheduling
//!    decision.  Schedules, event logs and every schedule-derived
//!    metric are bit-identical with telemetry enabled or disabled
//!    (pinned by `rust/tests/telemetry.rs`); wall-clock readings land
//!    only in telemetry and the `*_wall_s` reporting fields.
//! 2. **Zero steady-state allocations** — keys are enum-indexed fixed
//!    arrays (no maps, no `String`s), histograms are pre-allocated
//!    log₂-binned arrays, and the whole registry lives in const-
//!    initialized thread-local storage.  The PR-6 pin
//!    `workspace_steady_state_allocates_nothing` runs with telemetry
//!    *enabled*.
//! 3. **Deterministic merge** — a registry is a pair of fixed arrays,
//!    so merging is element-wise addition in the fixed enum-key order:
//!    per-shard registries absorbed shard-ordered produce the same
//!    totals on every run (counters are additive over deterministic
//!    per-cell work, so even work-stealing sweep workers merge to
//!    reproducible counts; only the wall-time histograms vary).
//!
//! The registry is **thread-local**: each federation shard worker and
//! each sweep worker accumulates privately and the coordinator absorbs
//! the snapshots ([`take`] / [`absorb`]) in deterministic order — no
//! locks on the hot path, ever.
//!
//! Export surfaces: NDJSON (`dts-telemetry-v1`, [`export`]) behind
//! `dts simulate|policy|serve --telemetry PATH`, and a Prometheus-style
//! text exposition ([`Telemetry::render_text`]); `dts serve`
//! additionally answers `{"op":"stats"}` with a single-line JSON
//! snapshot of the same registry.  `python/telemetry_report.py`
//! renders the phase table and histogram percentiles from the NDJSON.

pub mod export;
pub mod spans;

pub use spans::Span;

use std::cell::{Cell, RefCell};

/// Monotonic event counters, one per instrumented site.  The variant
/// order is the canonical key order of every export and merge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// replan passes that ran (arrival + straggler)
    Replans,
    /// straggler-triggered subset of [`Counter::Replans`]
    StragglerReplans,
    /// dirty-cone seeds: tasks reverted by the straggler policy
    SeedRevert,
    /// dirty-cone seeds: dispatched tasks whose belief diverged from truth
    SeedDivergence,
    /// dirty-cone seeds: belief starts that slid under the replan instant
    SeedMovedFloor,
    /// belief slots evicted by a refresh (full or incremental)
    ConeEvicted,
    /// belief slots re-derived by a refresh
    ConeRederived,
    /// timeline insertion-journal transactions opened
    TxnBegin,
    /// transactions committed (insertions kept)
    TxnCommit,
    /// transactions rolled back (insertions undone newest-first)
    TxnRollback,
    /// min-EFT placement decisions (one per task placed, not per candidate)
    EftPlacements,
    /// graphs admitted to a shard by the federation best-fit layer
    FedAdmissions,
    /// rebalance iterations that evaluated a steal candidate pair
    FedStealAttempts,
    /// pending graphs actually migrated across shards
    FedMigrations,
    /// NDJSON request lines handled by `dts serve` (valid or not)
    ServeRequests,
    /// malformed/rejected serve request lines (structured error records)
    ServeErrors,
    /// graph arrivals admitted by the serve ingest path
    ServeArrivals,
    /// snapshot files written by the serve journal
    ServeSnapshots,
    /// node crashes injected by the fault model ([`crate::sim::faults`])
    NodeFailures,
    /// running attempts killed by a crash (≤ one per failure)
    TaskKills,
    /// node recoveries (NodeUp events processed)
    NodeRecoveries,
    /// failure-triggered replans (forced orphan recovery + controller
    /// extra-scope passes)
    FailureReplans,
}

impl Counter {
    /// Every counter, in canonical key order.
    pub const ALL: [Counter; 22] = [
        Counter::Replans,
        Counter::StragglerReplans,
        Counter::SeedRevert,
        Counter::SeedDivergence,
        Counter::SeedMovedFloor,
        Counter::ConeEvicted,
        Counter::ConeRederived,
        Counter::TxnBegin,
        Counter::TxnCommit,
        Counter::TxnRollback,
        Counter::EftPlacements,
        Counter::FedAdmissions,
        Counter::FedStealAttempts,
        Counter::FedMigrations,
        Counter::ServeRequests,
        Counter::ServeErrors,
        Counter::ServeArrivals,
        Counter::ServeSnapshots,
        Counter::NodeFailures,
        Counter::TaskKills,
        Counter::NodeRecoveries,
        Counter::FailureReplans,
    ];

    /// Stable export key.
    pub const fn key(self) -> &'static str {
        match self {
            Counter::Replans => "replans",
            Counter::StragglerReplans => "straggler_replans",
            Counter::SeedRevert => "seed_revert",
            Counter::SeedDivergence => "seed_divergence",
            Counter::SeedMovedFloor => "seed_moved_floor",
            Counter::ConeEvicted => "cone_evicted",
            Counter::ConeRederived => "cone_rederived",
            Counter::TxnBegin => "txn_begin",
            Counter::TxnCommit => "txn_commit",
            Counter::TxnRollback => "txn_rollback",
            Counter::EftPlacements => "eft_placements",
            Counter::FedAdmissions => "fed_admissions",
            Counter::FedStealAttempts => "fed_steal_attempts",
            Counter::FedMigrations => "fed_migrations",
            Counter::ServeRequests => "serve_requests",
            Counter::ServeErrors => "serve_errors",
            Counter::ServeArrivals => "serve_arrivals",
            Counter::ServeSnapshots => "serve_snapshots",
            Counter::NodeFailures => "node_failures",
            Counter::TaskKills => "task_kills",
            Counter::NodeRecoveries => "node_recoveries",
            Counter::FailureReplans => "failure_replans",
        }
    }
}

/// Pre-allocated log₂-binned histograms.  Durations are recorded in
/// nanoseconds; sizes/depths in their natural unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hist {
    /// whole replan pass wall time (ns)
    ReplanWallNs,
    /// belief-refresh phase wall time (ns)
    RefreshWallNs,
    /// base-heuristic phase wall time (ns)
    HeuristicWallNs,
    /// bookkeeping remainder wall time (ns)
    BookkeepWallNs,
    /// dirty-cone size per replan (slots re-derived)
    ConeSize,
    /// event-queue depth sampled after each event pop
    EventQueueDepth,
    /// per-request decision latency in `dts serve` (ns, wall)
    ServeRequestNs,
    /// node downtime per recovery in **simulated** nanoseconds (a
    /// deterministic work count, not a wall reading)
    RecoveryNs,
}

impl Hist {
    /// Every histogram, in canonical key order.
    pub const ALL: [Hist; 8] = [
        Hist::ReplanWallNs,
        Hist::RefreshWallNs,
        Hist::HeuristicWallNs,
        Hist::BookkeepWallNs,
        Hist::ConeSize,
        Hist::EventQueueDepth,
        Hist::ServeRequestNs,
        Hist::RecoveryNs,
    ];

    /// Stable export key.
    pub const fn key(self) -> &'static str {
        match self {
            Hist::ReplanWallNs => "replan_wall_ns",
            Hist::RefreshWallNs => "refresh_wall_ns",
            Hist::HeuristicWallNs => "heuristic_wall_ns",
            Hist::BookkeepWallNs => "bookkeep_wall_ns",
            Hist::ConeSize => "cone_size",
            Hist::EventQueueDepth => "event_queue_depth",
            Hist::ServeRequestNs => "serve_request_ns",
            Hist::RecoveryNs => "recovery_ns",
        }
    }

    /// Wall-clock histograms vary run-to-run by nature; everything else
    /// is deterministic (work counts).  Determinism tests compare only
    /// the non-wall histograms bin-for-bin.
    pub const fn is_wall(self) -> bool {
        matches!(
            self,
            Hist::ReplanWallNs
                | Hist::RefreshWallNs
                | Hist::HeuristicWallNs
                | Hist::BookkeepWallNs
                | Hist::ServeRequestNs
        )
    }
}

/// Number of log₂ bins: bin 0 holds the exact value 0, bin `k`
/// (1 ≤ k ≤ 40) holds values of bit-length `k` — the half-open range
/// `[2^(k-1), 2^k)` — and the last bin is the +∞ overflow bucket for
/// values ≥ 2^40 (≈ 18 wall-clock minutes in ns).
pub const HIST_BINS: usize = 42;

/// A fixed log₂-binned histogram over `u64` samples.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Histogram {
    pub bins: [u64; HIST_BINS],
    pub count: u64,
    pub sum: u64,
}

impl Histogram {
    pub const fn new() -> Self {
        Histogram {
            bins: [0; HIST_BINS],
            count: 0,
            sum: 0,
        }
    }

    /// Bin index of `v`: 0 for 0, bit-length for 1..2^40, the overflow
    /// bucket above.
    #[inline]
    pub fn bin_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            let bits = (64 - v.leading_zeros()) as usize;
            bits.min(HIST_BINS - 1)
        }
    }

    /// Inclusive upper edge of bin `b` (`None` = +∞ overflow bucket).
    pub fn upper_edge(b: usize) -> Option<u64> {
        if b == 0 {
            Some(0)
        } else if b < HIST_BINS - 1 {
            Some((1u64 << b) - 1)
        } else {
            None
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.bins[Self::bin_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.bins.iter_mut().zip(other.bins.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A full registry snapshot: one slot per [`Counter`] and [`Hist`]
/// variant.  Plain fixed arrays — cloning is a memcpy, merging is
/// element-wise addition, and the key order is the enum order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Telemetry {
    counters: [u64; Counter::ALL.len()],
    hists: [Histogram; Hist::ALL.len()],
}

impl Telemetry {
    pub const fn new() -> Self {
        Telemetry {
            counters: [0; Counter::ALL.len()],
            hists: [Histogram::new(); Hist::ALL.len()],
        }
    }

    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    pub fn hist(&self, h: Hist) -> &Histogram {
        &self.hists[h as usize]
    }

    /// Element-wise addition in fixed key order — the deterministic
    /// merge used for per-shard and per-worker registries.
    pub fn merge(&mut self, other: &Telemetry) {
        for (a, b) in self.counters.iter_mut().zip(other.counters.iter()) {
            *a += b;
        }
        for (a, b) in self.hists.iter_mut().zip(other.hists.iter()) {
            a.merge(b);
        }
    }

    /// True iff nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.iter().all(|&c| c == 0) && self.hists.iter().all(|h| h.count == 0)
    }

    /// Prometheus-style text exposition — the scrape surface a `dts
    /// serve` deployment mounts.  Keys are emitted in canonical enum
    /// order; histogram buckets are cumulative with inclusive integer
    /// upper edges and a final `+Inf` bucket.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for c in Counter::ALL {
            let key = c.key();
            out.push_str(&format!("# TYPE dts_{key} counter\n"));
            out.push_str(&format!("dts_{key} {}\n", self.counter(c)));
        }
        for h in Hist::ALL {
            let key = h.key();
            let hist = self.hist(h);
            out.push_str(&format!("# TYPE dts_{key} histogram\n"));
            let mut cum = 0u64;
            for b in 0..HIST_BINS {
                cum += hist.bins[b];
                match Histogram::upper_edge(b) {
                    Some(le) => {
                        out.push_str(&format!("dts_{key}_bucket{{le=\"{le}\"}} {cum}\n"));
                    }
                    None => {
                        out.push_str(&format!("dts_{key}_bucket{{le=\"+Inf\"}} {cum}\n"));
                    }
                }
            }
            out.push_str(&format!("dts_{key}_sum {}\n", hist.sum));
            out.push_str(&format!("dts_{key}_count {}\n", hist.count));
        }
        out
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new()
    }
}

thread_local! {
    // const-initialized: lives in .tbss, no lazy heap allocation.
    static REGISTRY: RefCell<Telemetry> = const { RefCell::new(Telemetry::new()) };
    static ENABLED: Cell<bool> = const { Cell::new(true) };
}

/// Enable/disable recording on the current thread (default: enabled).
/// Purely an accounting switch — scheduling behaviour is identical
/// either way (the bit-identity pin).
pub fn set_enabled(on: bool) {
    let _ = ENABLED.try_with(|e| e.set(on));
}

/// Whether recording is enabled on the current thread.
pub fn enabled() -> bool {
    ENABLED.try_with(|e| e.get()).unwrap_or(false)
}

/// Bump counter `c` by `n` (no-op when disabled).  Allocation-free.
#[inline]
pub fn counter_add(c: Counter, n: u64) {
    if !enabled() {
        return;
    }
    let _ = REGISTRY.try_with(|r| {
        if let Ok(mut t) = r.try_borrow_mut() {
            t.counters[c as usize] += n;
        }
    });
}

/// Bump counter `c` by one (no-op when disabled).
#[inline]
pub fn counter_inc(c: Counter) {
    counter_add(c, 1);
}

/// Record sample `v` into histogram `h` (no-op when disabled).
/// Allocation-free.
#[inline]
pub fn hist_record(h: Hist, v: u64) {
    if !enabled() {
        return;
    }
    let _ = REGISTRY.try_with(|r| {
        if let Ok(mut t) = r.try_borrow_mut() {
            t.hists[h as usize].record(v);
        }
    });
}

/// Clone the current thread's registry.
pub fn snapshot() -> Telemetry {
    REGISTRY
        .try_with(|r| r.borrow().clone())
        .unwrap_or_else(|_| Telemetry::new())
}

/// Snapshot **and reset** the current thread's registry — how shard and
/// sweep workers hand their private registry back to the coordinator.
pub fn take() -> Telemetry {
    REGISTRY
        .try_with(|r| std::mem::replace(&mut *r.borrow_mut(), Telemetry::new()))
        .unwrap_or_else(|_| Telemetry::new())
}

/// Merge a snapshot into the current thread's registry (element-wise
/// addition in fixed key order).
pub fn absorb(other: &Telemetry) {
    let _ = REGISTRY.try_with(|r| r.borrow_mut().merge(other));
}

/// Zero the current thread's registry.
pub fn reset() {
    let _ = REGISTRY.try_with(|r| *r.borrow_mut() = Telemetry::new());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_edges_zero_powers_of_two_and_overflow() {
        // 0 lands in the dedicated zero bin.
        assert_eq!(Histogram::bin_of(0), 0);
        // 1 = bit-length 1.
        assert_eq!(Histogram::bin_of(1), 1);
        // exact powers of two open their own bin: 2^k is the *first*
        // value of bin k+1 (half-open [2^k, 2^(k+1))).
        for k in 1..40u32 {
            let v = 1u64 << k;
            assert_eq!(Histogram::bin_of(v), (k + 1) as usize, "2^{k}");
            assert_eq!(Histogram::bin_of(v - 1), k as usize, "2^{k}-1");
        }
        // the overflow bucket catches everything from 2^40 up.
        assert_eq!(Histogram::bin_of(1u64 << 40), HIST_BINS - 1);
        assert_eq!(Histogram::bin_of(u64::MAX), HIST_BINS - 1);
        // inclusive upper edges agree with bin_of.
        assert_eq!(Histogram::upper_edge(0), Some(0));
        assert_eq!(Histogram::upper_edge(1), Some(1));
        assert_eq!(Histogram::upper_edge(2), Some(3));
        assert_eq!(Histogram::upper_edge(HIST_BINS - 1), None);
    }

    #[test]
    fn histogram_records_and_merges() {
        let mut a = Histogram::new();
        a.record(0);
        a.record(5);
        let mut b = Histogram::new();
        b.record(5);
        b.record(1u64 << 50);
        a.merge(&b);
        assert_eq!(a.count, 4);
        assert_eq!(a.bins[0], 1);
        assert_eq!(a.bins[3], 2); // 5 twice: [4, 8)
        assert_eq!(a.bins[HIST_BINS - 1], 1);
        assert_eq!(a.sum, 10 + (1u64 << 50));
    }

    #[test]
    fn registry_roundtrip_and_enable_gate() {
        reset();
        counter_inc(Counter::Replans);
        counter_add(Counter::ConeEvicted, 7);
        hist_record(Hist::ConeSize, 3);
        set_enabled(false);
        counter_inc(Counter::Replans); // swallowed
        hist_record(Hist::ConeSize, 3); // swallowed
        set_enabled(true);
        let snap = take();
        assert_eq!(snap.counter(Counter::Replans), 1);
        assert_eq!(snap.counter(Counter::ConeEvicted), 7);
        assert_eq!(snap.hist(Hist::ConeSize).count, 1);
        // take() reset the registry
        assert!(snapshot().is_empty());
        // absorb merges back
        absorb(&snap);
        absorb(&snap);
        assert_eq!(snapshot().counter(Counter::ConeEvicted), 14);
        reset();
    }

    #[test]
    fn recording_is_allocation_free() {
        reset();
        // warm the TLS slots
        counter_inc(Counter::TxnBegin);
        hist_record(Hist::EventQueueDepth, 4);
        let before = crate::alloc_count::alloc_count();
        for i in 0..1000u64 {
            counter_add(Counter::EftPlacements, 1);
            hist_record(Hist::ConeSize, i);
        }
        let after = crate::alloc_count::alloc_count();
        assert_eq!(after - before, 0, "hot-path recording must not allocate");
        reset();
    }

    #[test]
    fn render_text_lists_keys_in_canonical_order() {
        let mut t = Telemetry::new();
        t.counters[Counter::Replans as usize] = 3;
        t.hists[Hist::ConeSize as usize].record(4);
        let text = t.render_text();
        // counters precede histograms; enum order within each block
        let pos = |needle: &str| text.find(needle).unwrap_or_else(|| panic!("missing {needle}"));
        assert!(pos("dts_replans 3") < pos("dts_straggler_replans 0"));
        assert!(pos("dts_fed_migrations") < pos("dts_replan_wall_ns_bucket"));
        assert!(pos("dts_cone_size_sum 4") < pos("dts_cone_size_count 1"));
        assert!(text.contains("dts_cone_size_bucket{le=\"+Inf\"} 1\n"));
    }
}
