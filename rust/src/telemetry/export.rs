//! NDJSON export (`dts-telemetry-v1`).
//!
//! One self-describing JSON object per line:
//!
//! * a **meta** line — `{"format":"dts-telemetry-v1","command":…}`;
//! * **span** lines — one per sweep cell group (dataset × variant):
//!   replan count plus the phase-decomposed wall totals
//!   (`refresh_s + heuristic_s + bookkeep_s` reconciles with `wall_s`);
//! * **counter** lines — `{"kind":"counter","key":…,"value":…}` in
//!   canonical key order;
//! * **hist** lines — `{"kind":"hist","key":…,"count":…,"sum":…,
//!   "bins":[…]}` with the log₂ bin layout of
//!   [`Histogram`](super::Histogram).
//!
//! The stream is append-friendly and cheap to parse with nothing but a
//! line splitter — `python/telemetry_report.py` (stdlib-only) renders
//! the phase table and percentile summaries from it.

use super::{Counter, Hist, Telemetry};
use crate::json::{self, Value};

/// One aggregate span line: the phase-decomposed replan wall time of a
/// sweep cell group (a dataset × variant row).
#[derive(Clone, Debug, Default)]
pub struct CellSpan {
    /// variant / controller label, e.g. `"5P-HEFT σ0.30 L3@0.25"`
    pub label: String,
    /// dataset the cells ran on
    pub dataset: String,
    /// replan passes across the group's cells
    pub replans: usize,
    /// belief-refresh phase wall seconds
    pub refresh_s: f64,
    /// base-heuristic phase wall seconds
    pub heuristic_s: f64,
    /// bookkeeping remainder wall seconds
    pub bookkeep_s: f64,
    /// whole-pass wall seconds (`≈ refresh + heuristic + bookkeep`)
    pub wall_s: f64,
}

fn span_line(s: &CellSpan) -> Value {
    json::obj(vec![
        ("kind", json::s("span")),
        ("label", json::s(&s.label)),
        ("dataset", json::s(&s.dataset)),
        ("replans", json::num(s.replans as f64)),
        ("refresh_s", json::num(s.refresh_s)),
        ("heuristic_s", json::num(s.heuristic_s)),
        ("bookkeep_s", json::num(s.bookkeep_s)),
        ("wall_s", json::num(s.wall_s)),
    ])
}

fn counter_line(c: Counter, value: u64) -> Value {
    json::obj(vec![
        ("kind", json::s("counter")),
        ("key", json::s(c.key())),
        ("value", json::num(value as f64)),
    ])
}

fn hist_line(h: Hist, t: &Telemetry) -> Value {
    let hist = t.hist(h);
    let bins = hist.bins.iter().map(|&b| json::num(b as f64)).collect();
    json::obj(vec![
        ("kind", json::s("hist")),
        ("key", json::s(h.key())),
        ("count", json::num(hist.count as f64)),
        ("sum", json::num(hist.sum as f64)),
        ("bins", json::arr(bins)),
    ])
}

/// Render the full NDJSON document: meta line, span lines, then the
/// registry snapshot (counters then histograms, canonical key order).
pub fn to_ndjson(command: &str, spans: &[CellSpan], telemetry: &Telemetry) -> String {
    let mut out = String::new();
    let meta = json::obj(vec![
        ("format", json::s("dts-telemetry-v1")),
        ("command", json::s(command)),
    ]);
    out.push_str(&meta.to_string());
    out.push('\n');
    for s in spans {
        out.push_str(&span_line(s).to_string());
        out.push('\n');
    }
    for c in Counter::ALL {
        out.push_str(&counter_line(c, telemetry.counter(c)).to_string());
        out.push('\n');
    }
    for h in Hist::ALL {
        out.push_str(&hist_line(h, telemetry).to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::HIST_BINS;

    #[test]
    fn ndjson_parses_line_by_line_and_keeps_key_order() {
        let mut t = Telemetry::new();
        t.merge(&Telemetry::new()); // no-op, keeps t plain
        let spans = vec![CellSpan {
            label: "5P-HEFT σ0.30 L3@0.25".into(),
            dataset: "gaussian".into(),
            replans: 4,
            refresh_s: 0.25,
            heuristic_s: 0.5,
            bookkeep_s: 0.25,
            wall_s: 1.0,
        }];
        let doc = to_ndjson("simulate", &spans, &t);
        let lines: Vec<&str> = doc.lines().collect();
        // meta + 1 span + counters + hists
        assert_eq!(lines.len(), 1 + 1 + Counter::ALL.len() + Hist::ALL.len());
        let meta = Value::from_str(lines[0]).unwrap();
        assert_eq!(meta.get("format").and_then(|v| v.as_str()), Some("dts-telemetry-v1"));
        assert_eq!(meta.get("command").and_then(|v| v.as_str()), Some("simulate"));
        let span = Value::from_str(lines[1]).unwrap();
        assert_eq!(span.get("kind").and_then(|v| v.as_str()), Some("span"));
        assert_eq!(span.get("replans").and_then(|v| v.as_usize()), Some(4));
        // counters come out in canonical order
        let first_counter = Value::from_str(lines[2]).unwrap();
        assert_eq!(first_counter.get("key").and_then(|v| v.as_str()), Some("replans"));
        // every line parses; histograms carry the full bin array
        for line in &lines[2..] {
            let v = Value::from_str(line).unwrap();
            if v.get("kind").and_then(|k| k.as_str()) == Some("hist") {
                assert_eq!(v.get("bins").and_then(|b| b.as_array()).unwrap().len(), HIST_BINS);
            }
        }
    }
}
