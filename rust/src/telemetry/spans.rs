//! Scoped span timers over the [`Hist`](super::Hist) registry.
//!
//! A [`Span`] measures one wall-clock region and lands the elapsed
//! nanoseconds in a log₂ histogram.  The reading is *returned* to the
//! caller as seconds so existing `*_wall_s` reporting fields keep their
//! values from the same clock read — wall time is measured once, used
//! twice, and never feeds a scheduling decision (the bit-identity
//! rule in the module docs).

use super::{hist_record, Hist};
use std::time::Instant;

/// An open span: started at construction, recorded at [`Span::finish`].
/// Deliberately not `Drop`-based — every instrumented region wants the
/// elapsed seconds back, so an explicit `finish` keeps the clock read
/// single and the control flow visible.
#[derive(Debug)]
pub struct Span {
    t0: Instant,
    hist: Hist,
}

impl Span {
    /// Start timing a region destined for histogram `hist`.
    #[inline]
    pub fn start(hist: Hist) -> Span {
        Span {
            t0: Instant::now(),
            hist,
        }
    }

    /// Stop the clock, record the elapsed nanoseconds into the span's
    /// histogram (subject to the thread's enable gate), and return the
    /// elapsed wall seconds from the *same* clock read.
    #[inline]
    pub fn finish(self) -> f64 {
        let elapsed = self.t0.elapsed();
        hist_record(self.hist, elapsed.as_nanos() as u64);
        elapsed.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{reset, snapshot};

    #[test]
    fn span_records_into_its_histogram() {
        reset();
        let s = Span::start(Hist::HeuristicWallNs);
        let secs = s.finish();
        assert!(secs >= 0.0);
        let snap = snapshot();
        assert_eq!(snap.hist(Hist::HeuristicWallNs).count, 1);
        reset();
    }
}
