//! Schedule visualization: ASCII Gantt charts for terminals and SVG
//! export for reports — the Fig 1-style pictures of the paper.
//!
//! Colors/letters encode the owning *graph*, making preemption effects
//! (interleaving, displaced blocks, idle gaps) visible at a glance.

use std::fmt::Write as _;

use crate::coordinator::DynamicProblem;
use crate::schedule::Schedule;

/// ASCII Gantt: one row per node, `width` characters across the span.
/// Graphs are labelled A–Z (cycling), idle time is `.`.
pub fn ascii(schedule: &Schedule, problem: &DynamicProblem, width: usize) -> String {
    let span = schedule
        .iter()
        .map(|(_, a)| a.finish)
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let mut out = String::new();
    for v in 0..problem.network.n_nodes() {
        let mut row = vec![b'.'; width];
        for (gid, a) in schedule.iter() {
            if a.node != v {
                continue;
            }
            let s = ((a.start / span) * width as f64) as usize;
            let e = (((a.finish / span) * width as f64).ceil() as usize).min(width);
            let ch = b'A' + (gid.graph as u8 % 26);
            for c in row.iter_mut().take(e).skip(s.min(width)) {
                *c = ch;
            }
        }
        let _ = writeln!(
            out,
            "node {v:>2} |{}| busy {:>5.1}%",
            String::from_utf8_lossy(&row),
            100.0 * schedule.timelines().busy_time(v) / span
        );
    }
    let _ = writeln!(out, "span: 0 .. {span:.2}");
    out
}

/// Distinct fill colors for up to 16 graphs (cycling).
const PALETTE: [&str; 16] = [
    "#4e79a7", "#f28e2b", "#e15759", "#76b7b2", "#59a14f", "#edc948",
    "#b07aa1", "#ff9da7", "#9c755f", "#bab0ac", "#1f77b4", "#ff7f0e",
    "#2ca02c", "#d62728", "#9467bd", "#8c564b",
];

/// SVG Gantt chart (self-contained, no external CSS).
pub fn svg(schedule: &Schedule, problem: &DynamicProblem, width_px: usize) -> String {
    let n_nodes = problem.network.n_nodes();
    let row_h = 28usize;
    let label_w = 64usize;
    let height = n_nodes * row_h + 40;
    let span = schedule
        .iter()
        .map(|(_, a)| a.finish)
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let scale = (width_px - label_w - 10) as f64 / span;

    let mut s = String::new();
    let _ = write!(
        s,
        r##"<svg xmlns="http://www.w3.org/2000/svg" width="{width_px}" height="{height}" font-family="monospace" font-size="11">"##
    );
    let _ = write!(
        s,
        r##"<rect width="{width_px}" height="{height}" fill="white"/>"##
    );
    for v in 0..n_nodes {
        let y = 20 + v * row_h;
        let _ = write!(
            s,
            r##"<text x="4" y="{}" fill="#333">node {v}</text>"##,
            y + row_h / 2 + 4
        );
        let _ = write!(
            s,
            r##"<rect x="{label_w}" y="{y}" width="{}" height="{}" fill="#f4f4f4"/>"##,
            width_px - label_w - 10,
            row_h - 4
        );
    }
    // slots, sorted for deterministic output
    let mut slots: Vec<_> = schedule.iter().collect();
    slots.sort_by_key(|(g, _)| **g);
    for (gid, a) in slots {
        let x = label_w as f64 + a.start * scale;
        let w = ((a.finish - a.start) * scale).max(1.0);
        let y = 20 + a.node * row_h;
        let color = PALETTE[(gid.graph as usize) % PALETTE.len()];
        let _ = write!(
            s,
            r##"<rect x="{x:.1}" y="{y}" width="{w:.1}" height="{}" fill="{color}" stroke="#333" stroke-width="0.4"><title>{gid} [{:.2}, {:.2}]</title></rect>"##,
            row_h - 4,
            a.start,
            a.finish
        );
    }
    // time axis
    let _ = write!(
        s,
        r##"<text x="{label_w}" y="{}" fill="#333">0</text><text x="{}" y="{}" fill="#333" text-anchor="end">{span:.1}</text>"##,
        height - 8,
        width_px - 10,
        height - 8
    );
    s.push_str("</svg>");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, Policy};
    use crate::schedulers::SchedulerKind;
    use crate::workloads::Dataset;

    fn run() -> (DynamicProblem, Schedule) {
        let prob = Dataset::Synthetic.instance(4, 3);
        let mut c = Coordinator::new(Policy::LastK(2), SchedulerKind::Heft.make(0));
        let res = c.run(&prob);
        (prob, res.schedule)
    }

    #[test]
    fn ascii_rows_match_nodes_and_width() {
        let (prob, sched) = run();
        let a = ascii(&sched, &prob, 80);
        let rows: Vec<&str> = a.lines().collect();
        assert_eq!(rows.len(), prob.network.n_nodes() + 1);
        for r in &rows[..prob.network.n_nodes()] {
            assert!(r.contains('|'));
            let bar = r.split('|').nth(1).unwrap();
            assert_eq!(bar.len(), 80);
        }
        assert!(rows.last().unwrap().starts_with("span:"));
    }

    #[test]
    fn ascii_shows_multiple_graphs() {
        let (prob, sched) = run();
        let a = ascii(&sched, &prob, 120);
        assert!(a.contains('A') && a.contains('B'));
    }

    #[test]
    fn svg_is_well_formed_and_complete() {
        let (prob, sched) = run();
        let s = svg(&sched, &prob, 900);
        assert!(s.starts_with("<svg") && s.ends_with("</svg>"));
        // one rect per slot + one background per node + canvas
        let n_rects = s.matches("<rect").count();
        assert_eq!(n_rects, 1 + prob.network.n_nodes() + sched.n_assigned());
        // every task's tooltip present
        assert_eq!(s.matches("<title>").count(), sched.n_assigned());
    }

    #[test]
    fn svg_deterministic() {
        let (prob, sched) = run();
        assert_eq!(svg(&sched, &prob, 640), svg(&sched, &prob, 640));
    }
}
