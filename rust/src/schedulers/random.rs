//! Random baseline (as in SAGA): tasks are released in dependency order
//! with uniformly random tie-breaking, each placed on a uniformly random
//! node at its earliest insertion start.  Seeded — the same seed yields
//! the same schedule.

use crate::network::Network;
use crate::prng::Xoshiro256pp;
use crate::schedule::{Assignment, Slot, Timelines};

use super::common::{eft_on_node_cached, EftScratch};
#[cfg(test)]
use super::Pred;
use super::{Problem, Scheduler};

pub struct RandomScheduler {
    rng: Xoshiro256pp,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256pp::seed_from_u64(seed),
        }
    }
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> String {
        "Random".to_string()
    }

    fn schedule(
        &mut self,
        prob: &Problem,
        net: &Network,
        timelines: &mut Timelines,
    ) -> Vec<Assignment> {
        let n = prob.n_tasks();
        let mut partial: Vec<Option<Assignment>> = vec![None; n];
        let mut missing: Vec<usize> = (0..n).map(|i| prob.n_pending_preds(i)).collect();
        let mut ready: Vec<usize> = (0..n).filter(|&i| missing[i] == 0).collect();

        let mut placed = 0;
        let mut scratch = EftScratch::new();
        while !ready.is_empty() {
            let pick = self.rng.below(ready.len());
            let i = ready.swap_remove(pick);
            let v = self.rng.below(net.n_nodes());
            // cached scratch path — bit-identical to the reference
            // `eft_on_node` (see `cached_eft_matches_reference`)
            scratch.load(prob, i, net, &partial);
            let a = eft_on_node_cached(&scratch, prob, i, v, net, timelines);
            timelines.insert(
                a.node,
                Slot {
                    start: a.start,
                    finish: a.finish,
                    gid: prob.gid_col[i],
                },
            );
            partial[i] = Some(a);
            placed += 1;
            for &c in prob.succs_of(i).0 {
                let c = c as usize;
                missing[c] -= 1;
                if missing[c] == 0 {
                    ready.push(c);
                }
            }
        }
        assert_eq!(placed, n, "Random failed to place every task");
        partial.into_iter().map(Option::unwrap).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedulers::testutil::problem_from_graph;

    fn fan_prob() -> Problem {
        let mut b = GraphBuilder::new("fan");
        let root = b.task(2.0);
        for _ in 0..10 {
            let t = b.task(3.0);
            b.edge(root, t, 1.0);
        }
        problem_from_graph(&b.build().unwrap(), 0, 0.0)
    }

    #[test]
    fn seeded_determinism() {
        let prob = fan_prob();
        let net = Network::homogeneous(3);
        let run = |seed| {
            let mut tl = Timelines::new(3);
            RandomScheduler::new(seed)
                .schedule(&prob, &net, &mut tl)
                .iter()
                .map(|a| (a.node, a.start.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(1));
        assert_ne!(run(1), run(2), "different seeds explore different schedules");
    }

    #[test]
    fn dependencies_hold() {
        let prob = fan_prob();
        let net = Network::homogeneous(3);
        let mut tl = Timelines::new(3);
        let out = RandomScheduler::new(9).schedule(&prob, &net, &mut tl);
        for (i, t) in prob.tasks.iter().enumerate() {
            for p in &t.preds {
                if let Pred::Pending { idx, data } = *p {
                    let comm = net.comm_time(data, out[idx].node, out[i].node);
                    assert!(out[idx].finish + comm <= out[i].start + 1e-9);
                }
            }
        }
    }

    #[test]
    fn uses_multiple_nodes_eventually() {
        let prob = fan_prob();
        let net = Network::homogeneous(3);
        let mut tl = Timelines::new(3);
        let out = RandomScheduler::new(5).schedule(&prob, &net, &mut tl);
        let distinct: std::collections::HashSet<usize> =
            out.iter().map(|a| a.node).collect();
        assert!(distinct.len() > 1);
    }
}
