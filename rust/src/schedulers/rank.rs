//! Upward/downward rank computation with a pluggable provider.
//!
//! [`NativeRanks`] is the pure-Rust topological DP; the XLA-accelerated
//! provider (`runtime::XlaRanks`) executes the AOT-compiled Pallas
//! max-plus fixed point instead, and is parity-tested against this one.

use crate::network::Network;

use super::common::{mean_costs, topo_order};
use super::Problem;

/// Rank vectors for a composite problem (indexed like `Problem::tasks`).
#[derive(Clone, Debug, Default)]
pub struct Ranks {
    /// HEFT's `rank_u`: critical-path-to-exit length including self.
    pub up: Vec<f64>,
    /// CPOP's `rank_d`: critical-path-from-entry length excluding self.
    pub down: Vec<f64>,
}

/// Strategy interface: how HEFT/CPOP obtain their priority ranks.
pub trait RankProvider {
    fn ranks(&mut self, prob: &Problem, net: &Network) -> Ranks;
    fn provider_name(&self) -> &'static str {
        "native"
    }
}

/// Pure-Rust topological dynamic program (the reference provider).
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeRanks;

impl RankProvider for NativeRanks {
    fn ranks(&mut self, prob: &Problem, net: &Network) -> Ranks {
        let n = prob.n_tasks();
        let (w, succ_costs) = mean_costs(prob, net);
        let order = topo_order(prob);

        let mut up = vec![0.0f64; n];
        for &t in order.iter().rev() {
            let mut best = 0.0f64;
            for &(c, cbar) in &succ_costs[t] {
                best = best.max(cbar + up[c]);
            }
            up[t] = w[t] + best;
        }

        let mut down = vec![0.0f64; n];
        for &t in order.iter() {
            for &(c, cbar) in &succ_costs[t] {
                down[c] = down[c].max(down[t] + w[t] + cbar);
            }
        }
        // Note: Fixed (committed) parents deliberately do not contribute
        // to ranks — only the remaining-work subgraph is re-prioritized.
        Ranks { up, down }
    }
}

/// Convenience: upward rank only (used by tests and the Random baseline's
/// sanity checks).
pub fn upward_rank(prob: &Problem, net: &Network) -> Vec<f64> {
    NativeRanks.ranks(prob, net).up
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::network::Network;
    use crate::schedulers::testutil::problem_from_graph;
    use crate::schedulers::Pred;

    /// The classic HEFT paper example would be overkill; a chain and a
    /// diamond pin the arithmetic.
    #[test]
    fn chain_ranks() {
        let mut b = GraphBuilder::new("chain");
        let t0 = b.task(2.0);
        let t1 = b.task(4.0);
        let t2 = b.task(6.0);
        b.edge(t0, t1, 3.0).edge(t1, t2, 9.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        // 2 nodes speeds 1,2 → mean inv speed 0.75; one link strength 3 →
        // mean inv link 1/3.
        let net = Network::new(vec![1.0, 2.0], vec![0.0, 3.0, 3.0, 0.0]);
        let r = NativeRanks.ranks(&prob, &net);
        let w = [1.5, 3.0, 4.5];
        let c = [1.0, 3.0];
        assert!((r.up[2] - w[2]).abs() < 1e-12);
        assert!((r.up[1] - (w[1] + c[1] + w[2])).abs() < 1e-12);
        assert!((r.up[0] - (w[0] + c[0] + w[1] + c[1] + w[2])).abs() < 1e-12);
        assert!((r.down[0] - 0.0).abs() < 1e-12);
        assert!((r.down[1] - (w[0] + c[0])).abs() < 1e-12);
        assert!((r.down[2] - (w[0] + c[0] + w[1] + c[1])).abs() < 1e-12);
        // up + down constant along a chain (it IS the critical path)
        let pri: Vec<f64> = (0..3).map(|i| r.up[i] + r.down[i]).collect();
        assert!((pri[0] - pri[1]).abs() < 1e-12 && (pri[1] - pri[2]).abs() < 1e-12);
    }

    #[test]
    fn diamond_up_rank_takes_max_branch() {
        let mut b = GraphBuilder::new("d");
        let t0 = b.task(1.0);
        let t1 = b.task(10.0); // heavy branch
        let t2 = b.task(1.0);
        let t3 = b.task(1.0);
        b.edge(t0, t1, 0.0)
            .edge(t0, t2, 0.0)
            .edge(t1, t3, 0.0)
            .edge(t2, t3, 0.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(2);
        let r = NativeRanks.ranks(&prob, &net);
        assert!((r.up[0] - 12.0).abs() < 1e-12); // 1 + 10 + 1 through t1
        assert!(r.up[1] > r.up[2]);
        assert!((r.down[3] - 11.0).abs() < 1e-12);
    }

    #[test]
    fn multi_component_ranks_are_independent() {
        let mut b1 = GraphBuilder::new("a");
        let x = b1.task(5.0);
        let y = b1.task(5.0);
        b1.edge(x, y, 0.0);
        let g1 = b1.build().unwrap();
        let mut prob = problem_from_graph(&g1, 0, 0.0);
        // second, disconnected component
        let mut b2 = GraphBuilder::new("b");
        b2.task(7.0);
        let g2 = b2.build().unwrap();
        let p2 = problem_from_graph(&g2, 1, 0.0);
        prob.tasks.extend(p2.tasks);
        prob.rebuild_views();
        let net = Network::homogeneous(1);
        let r = NativeRanks.ranks(&prob, &net);
        assert!((r.up[0] - 10.0).abs() < 1e-12);
        assert!((r.up[2] - 7.0).abs() < 1e-12);
        assert_eq!(r.down[2], 0.0);
    }

    #[test]
    fn fixed_preds_do_not_inflate_ranks() {
        let mut b = GraphBuilder::new("s");
        b.task(3.0);
        let mut prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        prob.tasks[0].preds.push(Pred::Fixed {
            node: 0,
            finish: 1000.0,
            data: 50.0,
        });
        prob.rebuild_views();
        let net = Network::homogeneous(2);
        let r = NativeRanks.ranks(&prob, &net);
        assert!((r.up[0] - 3.0).abs() < 1e-12);
        assert_eq!(r.down[0], 0.0);
    }
}
