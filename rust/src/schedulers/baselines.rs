//! Extension baselines beyond the paper's five: three more classics from
//! Braun et al. (2001) / the ETF literature, lifted to DAGs the same way
//! Min-Min is.  They are not part of `paper_grid()` (the paper's §VII
//! grid) but are available to the CLI/config system for ablations.
//!
//! * **MET** — Minimum Execution Time: each ready task goes to the node
//!   executing it fastest, ignoring availability (classic pathological
//!   load-collapse baseline).
//! * **OLB** — Opportunistic Load Balancing: each ready task goes to the
//!   node that becomes *available* earliest, ignoring execution time.
//! * **ETF** — Earliest Time First: among all (ready task, node) pairs,
//!   schedule the pair with the earliest possible *start* time.

use crate::network::Network;
use crate::schedule::{Assignment, Slot, Timelines};

use super::common::{EftRows, EftScratch};
#[cfg(test)]
use super::Pred;
use super::{Problem, Scheduler};

/// Shared ready-queue driver: `place` picks the (task, assignment) to
/// commit from the current ready set.  Ready-time rows are cached in a
/// shared [`EftRows`] — §Perf: the baselines' inner loops previously
/// re-walked predecessor lists per (ready task × node) per round.
fn drive(
    prob: &Problem,
    net: &Network,
    timelines: &mut Timelines,
    mut place: impl FnMut(&[usize], &Problem, &Network, &Timelines, &EftRows) -> (usize, Assignment),
) -> Vec<Assignment> {
    let n = prob.n_tasks();
    let mut partial: Vec<Option<Assignment>> = vec![None; n];
    let mut missing: Vec<usize> = (0..n).map(|i| prob.n_pending_preds(i)).collect();
    let mut ready: Vec<usize> = (0..n).filter(|&i| missing[i] == 0).collect();
    let mut rows = EftRows::new(n, net.n_nodes());
    let mut scratch = EftScratch::new();
    for &i in &ready {
        rows.fill(prob, i, net, &partial, &mut scratch);
    }
    let mut placed = 0;
    while !ready.is_empty() {
        let (i, a) = place(&ready, prob, net, timelines, &rows);
        timelines.insert(
            a.node,
            Slot {
                start: a.start,
                finish: a.finish,
                gid: prob.gid_col[i],
            },
        );
        partial[i] = Some(a);
        placed += 1;
        ready.retain(|&x| x != i);
        for &c in prob.succs_of(i).0 {
            let c = c as usize;
            missing[c] -= 1;
            if missing[c] == 0 {
                rows.fill(prob, c, net, &partial, &mut scratch);
                ready.push(c);
            }
        }
    }
    assert_eq!(placed, n, "baseline failed to place every task");
    partial.into_iter().map(Option::unwrap).collect()
}

/// Minimum Execution Time.
pub struct Met;

impl Scheduler for Met {
    fn name(&self) -> String {
        "MET".to_string()
    }

    fn schedule(
        &mut self,
        prob: &Problem,
        net: &Network,
        timelines: &mut Timelines,
    ) -> Vec<Assignment> {
        drive(prob, net, timelines, |ready, prob, net, tl, rows| {
            // first ready task (FIFO by gid for determinism), fastest node
            let &i = ready
                .iter()
                .min_by_key(|&&i| prob.gid_col[i])
                .unwrap();
            let v = (0..net.n_nodes())
                .min_by(|&a, &b| {
                    net.exec_time(prob.cost_col[i], a)
                        .partial_cmp(&net.exec_time(prob.cost_col[i], b))
                        .unwrap()
                        .then(a.cmp(&b))
                })
                .unwrap();
            (i, rows.eft(prob, net, tl, i, v))
        })
    }
}

/// Opportunistic Load Balancing.
pub struct Olb;

impl Scheduler for Olb {
    fn name(&self) -> String {
        "OLB".to_string()
    }

    fn schedule(
        &mut self,
        prob: &Problem,
        net: &Network,
        timelines: &mut Timelines,
    ) -> Vec<Assignment> {
        drive(prob, net, timelines, |ready, prob, net, tl, rows| {
            let &i = ready
                .iter()
                .min_by_key(|&&i| prob.gid_col[i])
                .unwrap();
            // node where the task can *start* soonest (availability only —
            // execution speed deliberately ignored when choosing)
            let a = (0..net.n_nodes())
                .map(|v| rows.eft(prob, net, tl, i, v))
                .min_by(|x, y| {
                    x.start
                        .partial_cmp(&y.start)
                        .unwrap()
                        .then(x.node.cmp(&y.node))
                })
                .unwrap();
            (i, a)
        })
    }
}

/// Earliest Time First: globally earliest start among ready × nodes.
pub struct Etf;

impl Scheduler for Etf {
    fn name(&self) -> String {
        "ETF".to_string()
    }

    fn schedule(
        &mut self,
        prob: &Problem,
        net: &Network,
        timelines: &mut Timelines,
    ) -> Vec<Assignment> {
        drive(prob, net, timelines, |ready, prob, net, tl, rows| {
            let mut best: Option<(usize, Assignment)> = None;
            for &i in ready {
                for v in 0..net.n_nodes() {
                    let a = rows.eft(prob, net, tl, i, v);
                    let better = match &best {
                        None => true,
                        Some((bi, ba)) => {
                            a.start < ba.start
                                || (a.start == ba.start
                                    && prob.gid_col[i] < prob.gid_col[*bi])
                        }
                    };
                    if better {
                        best = Some((i, a));
                    }
                }
            }
            best.unwrap()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedulers::testutil::problem_from_graph;

    fn two_node_net() -> Network {
        Network::new(vec![1.0, 4.0], vec![0.0, 1.0, 1.0, 0.0])
    }

    #[test]
    fn met_always_picks_fastest_node_even_when_busy() {
        let mut b = GraphBuilder::new("bag");
        b.task(8.0);
        b.task(8.0);
        b.task(8.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = two_node_net();
        let mut tl = Timelines::new(2);
        let out = Met.schedule(&prob, &net, &mut tl);
        // all three queue on node 1 (4× faster): 2, 4, 6
        assert!(out.iter().all(|a| a.node == 1));
        let mut finishes: Vec<f64> = out.iter().map(|a| a.finish).collect();
        finishes.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(finishes, vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn olb_spreads_regardless_of_speed() {
        let mut b = GraphBuilder::new("bag");
        b.task(8.0);
        b.task(8.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = two_node_net();
        let mut tl = Timelines::new(2);
        let out = Olb.schedule(&prob, &net, &mut tl);
        // both nodes idle at t=0 → tie broken to node 0 for the first
        // task, node 1 for the second
        let nodes: std::collections::HashSet<usize> = out.iter().map(|a| a.node).collect();
        assert_eq!(nodes.len(), 2, "OLB must load-balance: {out:?}");
    }

    #[test]
    fn etf_schedules_earliest_start_pair_first() {
        let mut b = GraphBuilder::new("bag");
        b.task(2.0);
        b.task(50.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = two_node_net();
        let mut tl = Timelines::new(2);
        let out = Etf.schedule(&prob, &net, &mut tl);
        // both can start at 0; gid tie-break gives task 0 first, node 0
        assert_eq!(out[0].start, 0.0);
        assert_eq!(out[1].start, 0.0);
        assert_ne!(out[0].node, out[1].node);
    }

    #[test]
    fn all_baselines_respect_dependencies() {
        use crate::prng::Xoshiro256pp;
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut b = GraphBuilder::new("rand");
        let n = 20;
        let ids: Vec<_> = (0..n).map(|_| b.task(rng.uniform(1.0, 9.0))).collect();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.next_f64() < 0.2 {
                    b.edge(ids[i], ids[j], rng.uniform(0.0, 4.0));
                }
            }
        }
        let g = b.build().unwrap();
        let prob = problem_from_graph(&g, 0, 0.0);
        let net = two_node_net();
        let scheds: Vec<Box<dyn Scheduler>> =
            vec![Box::new(Met), Box::new(Olb), Box::new(Etf)];
        for mut s in scheds {
            let mut tl = Timelines::new(2);
            let out = s.schedule(&prob, &net, &mut tl);
            for (i, t) in prob.tasks.iter().enumerate() {
                for p in &t.preds {
                    if let Pred::Pending { idx, data } = *p {
                        let comm = net.comm_time(data, out[idx].node, out[i].node);
                        assert!(
                            out[idx].finish + comm <= out[i].start + 1e-9,
                            "{} violates dependency",
                            s.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn met_is_worse_than_etf_under_contention() {
        // the classic result: MET collapses load onto the fast machine.
        // With only a 2× speed gap, hogging the fast node (8×4 = 32)
        // loses to spreading (ETF ≈ 24).
        let mut b = GraphBuilder::new("bag");
        for _ in 0..8 {
            b.task(8.0);
        }
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::new(vec![1.0, 2.0], vec![0.0, 1.0, 1.0, 0.0]);
        let mut tl1 = Timelines::new(2);
        let met = Met.schedule(&prob, &net, &mut tl1);
        let mut tl2 = Timelines::new(2);
        let etf = Etf.schedule(&prob, &net, &mut tl2);
        let mk = |out: &[Assignment]| {
            out.iter().map(|a| a.finish).fold(0.0f64, f64::max)
        };
        assert!(mk(&met) > mk(&etf));
    }
}
