//! Min-Min (Braun et al., 2001), lifted to DAGs the way SAGA does:
//! among *ready* tasks (all pending parents placed), compute each task's
//! best completion time across nodes; schedule the task whose best
//! completion time is **smallest**; repeat.

use crate::network::Network;
use crate::schedule::{Assignment, Slot, Timelines};

use super::common::{EftRows, EftScratch};
#[cfg(test)]
use super::common::min_eft;
#[cfg(test)]
use super::Pred;
use super::{Problem, Scheduler};

pub struct MinMin;

impl Scheduler for MinMin {
    fn name(&self) -> String {
        "MinMin".to_string()
    }

    fn schedule(
        &mut self,
        prob: &Problem,
        net: &Network,
        timelines: &mut Timelines,
    ) -> Vec<Assignment> {
        schedule_mct(prob, net, timelines, /*pick_max=*/ false)
    }
}

/// Shared Min-Min / Max-Min engine (they differ only in the argmin/argmax
/// over ready tasks' best completion times).
///
/// EFT caching (§Perf): `EFT(t, v)` of a ready task depends only on node
/// `v`'s timeline (its pending parents are already placed when it becomes
/// ready, and fixed parents never move), so after each assignment to node
/// `v*` only the `v*` column of the ready×node EFT matrix can change.
/// The cache preserves exact semantics — verified by the
/// `cached_engine_matches_naive` test below — and drops the inner loop
/// from O(R·V·insertion) to O(R·insertion) per placement.
pub(super) fn schedule_mct(
    prob: &Problem,
    net: &Network,
    timelines: &mut Timelines,
    pick_max: bool,
) -> Vec<Assignment> {
    let n = prob.n_tasks();
    let n_nodes = net.n_nodes();
    let mut partial: Vec<Option<Assignment>> = vec![None; n];
    let mut missing: Vec<usize> = (0..n).map(|i| prob.n_pending_preds(i)).collect();

    // flattened ready×node EFT cache + per-task best placement, plus the
    // per-task ready-time rows (parents are final once a task is ready,
    // so its row is computed exactly once via EftRows and reused by
    // every later column refresh)
    let mut eft: Vec<Assignment> = vec![
        Assignment { node: 0, start: 0.0, finish: 0.0 };
        n * n_nodes
    ];
    let mut best: Vec<Assignment> = vec![Assignment { node: 0, start: 0.0, finish: 0.0 }; n];
    let mut rows = EftRows::new(n, n_nodes);
    let mut scratch = EftScratch::new();

    #[allow(clippy::too_many_arguments)]
    fn fill_row(
        prob: &Problem,
        net: &Network,
        i: usize,
        timelines: &Timelines,
        partial: &[Option<Assignment>],
        scratch: &mut EftScratch,
        rows: &mut EftRows,
        eft: &mut [Assignment],
        best: &mut [Assignment],
    ) {
        let n_nodes = net.n_nodes();
        rows.fill(prob, i, net, partial, scratch);
        let mut b: Option<Assignment> = None;
        for v in 0..n_nodes {
            let a = rows.eft(prob, net, timelines, i, v);
            eft[i * n_nodes + v] = a;
            if b.map_or(true, |x| a.finish < x.finish) {
                b = Some(a);
            }
        }
        best[i] = b.expect("network has no nodes");
    }

    let mut ready: Vec<usize> = (0..n).filter(|&i| missing[i] == 0).collect();
    for &i in &ready {
        fill_row(
            prob, net, i, timelines, &partial, &mut scratch, &mut rows, &mut eft, &mut best,
        );
    }

    let mut placed = 0;
    while !ready.is_empty() {
        // pick the ready task with the min (Min-Min) / max (Max-Min)
        // best completion time; ties broken by Gid for determinism
        let mut pick = 0usize;
        for (k, &i) in ready.iter().enumerate() {
            let (a, c) = (best[i], best[ready[pick]]);
            let better = if pick_max {
                a.finish > c.finish
                    || (a.finish == c.finish && prob.gid_col[i] < prob.gid_col[ready[pick]])
            } else {
                a.finish < c.finish
                    || (a.finish == c.finish && prob.gid_col[i] < prob.gid_col[ready[pick]])
            };
            if better {
                pick = k;
            }
        }
        let i = ready.swap_remove(pick);
        let a = best[i];
        timelines.insert(
            a.node,
            Slot {
                start: a.start,
                finish: a.finish,
                gid: prob.gid_col[i],
            },
        );
        partial[i] = Some(a);
        placed += 1;

        // newly ready successors get full rows
        for &c in prob.succs_of(i).0 {
            let c = c as usize;
            missing[c] -= 1;
            if missing[c] == 0 {
                ready.push(c);
                fill_row(
                    prob, net, c, timelines, &partial, &mut scratch, &mut rows, &mut eft,
                    &mut best,
                );
            }
        }

        // only the column of the assigned node is stale for the rest;
        // the cached ready row makes the refresh a pure gap-finder probe
        let vstar = a.node;
        for &j in &ready {
            let fresh = rows.eft(prob, net, timelines, j, vstar);
            eft[j * n_nodes + vstar] = fresh;
            if best[j].node == vstar {
                // previous best may have been displaced: re-min the row
                let row = &eft[j * n_nodes..(j + 1) * n_nodes];
                let mut b = row[0];
                for &x in &row[1..] {
                    if x.finish < b.finish {
                        b = x;
                    }
                }
                best[j] = b;
            } else if fresh.finish < best[j].finish {
                best[j] = fresh;
            }
        }
    }
    assert_eq!(placed, n, "MCT scheduler failed to place every task");
    partial.into_iter().map(Option::unwrap).collect()
}

/// Reference (uncached) engine kept for differential testing.
#[cfg(test)]
pub(super) fn schedule_mct_naive(
    prob: &Problem,
    net: &Network,
    timelines: &mut Timelines,
    pick_max: bool,
) -> Vec<Assignment> {
    let n = prob.n_tasks();
    let mut partial: Vec<Option<Assignment>> = vec![None; n];
    let mut missing: Vec<usize> = prob
        .tasks
        .iter()
        .map(|t| {
            t.preds
                .iter()
                .filter(|p| matches!(p, Pred::Pending { .. }))
                .count()
        })
        .collect();
    let mut ready: Vec<usize> = (0..n).filter(|&i| missing[i] == 0).collect();

    while !ready.is_empty() {
        let mut chosen: Option<(usize, Assignment)> = None;
        for &i in &ready {
            let a = min_eft(prob, i, net, timelines, &partial);
            let better = match &chosen {
                None => true,
                Some((ci, ca)) => {
                    if pick_max {
                        a.finish > ca.finish
                            || (a.finish == ca.finish && prob.tasks[i].gid < prob.tasks[*ci].gid)
                    } else {
                        a.finish < ca.finish
                            || (a.finish == ca.finish && prob.tasks[i].gid < prob.tasks[*ci].gid)
                    }
                }
            };
            if better {
                chosen = Some((i, a));
            }
        }
        let (i, a) = chosen.unwrap();
        timelines.insert(
            a.node,
            Slot {
                start: a.start,
                finish: a.finish,
                gid: prob.tasks[i].gid,
            },
        );
        partial[i] = Some(a);
        ready.retain(|&x| x != i);
        for &(c, _) in &prob.tasks[i].succs {
            missing[c] -= 1;
            if missing[c] == 0 {
                ready.push(c);
            }
        }
    }
    partial.into_iter().map(Option::unwrap).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedulers::testutil::problem_from_graph;

    #[test]
    fn minmin_places_short_task_first() {
        // Two independent tasks, one node: the short one must be first.
        let mut b = GraphBuilder::new("two");
        b.task(10.0);
        b.task(2.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(1);
        let mut tl = Timelines::new(1);
        let out = MinMin.schedule(&prob, &net, &mut tl);
        assert_eq!(out[1].start, 0.0, "short task scheduled first");
        assert_eq!(out[0].start, 2.0);
    }

    #[test]
    fn respects_dependencies() {
        let mut b = GraphBuilder::new("chain");
        let t0 = b.task(5.0);
        let t1 = b.task(1.0);
        b.edge(t0, t1, 2.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(2);
        let mut tl = Timelines::new(2);
        let out = MinMin.schedule(&prob, &net, &mut tl);
        // t1 can only run after t0 (+comm if cross-node)
        let comm = net.comm_time(2.0, out[0].node, out[1].node);
        assert!(out[0].finish + comm <= out[1].start + 1e-9);
    }

    #[test]
    fn cached_engine_matches_naive() {
        use crate::prng::Xoshiro256pp;
        let mut rng = Xoshiro256pp::seed_from_u64(99);
        for case in 0..30 {
            let n = rng.int_range(2, 30);
            let mut b = GraphBuilder::new("rand");
            let ids: Vec<_> = (0..n).map(|_| b.task(rng.uniform(0.5, 20.0))).collect();
            for i in 0..n {
                for j in (i + 1)..n {
                    if rng.next_f64() < 0.2 {
                        b.edge(ids[i], ids[j], rng.uniform(0.0, 8.0));
                    }
                }
            }
            let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
            let net = Network::new(
                vec![1.0, 2.0, 0.5],
                vec![0.0, 2.0, 1.0, 2.0, 0.0, 3.0, 1.0, 3.0, 0.0],
            );
            for pick_max in [false, true] {
                let mut tl1 = Timelines::new(3);
                let fast = schedule_mct(&prob, &net, &mut tl1, pick_max);
                let mut tl2 = Timelines::new(3);
                let slow = schedule_mct_naive(&prob, &net, &mut tl2, pick_max);
                assert_eq!(fast, slow, "case {case} pick_max={pick_max}");
            }
        }
    }

    #[test]
    fn all_tasks_placed_on_wide_fanout() {
        let mut b = GraphBuilder::new("fan");
        let root = b.task(1.0);
        for _ in 0..20 {
            let t = b.task(2.0);
            b.edge(root, t, 1.0);
        }
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(4);
        let mut tl = Timelines::new(4);
        let out = MinMin.schedule(&prob, &net, &mut tl);
        assert_eq!(out.len(), 21);
    }
}
