//! Max-Min (Braun et al., 2001): like Min-Min, but the ready task with
//! the **largest** best completion time is scheduled first — front-loading
//! long tasks to avoid them straggling at the end.

use crate::network::Network;
use crate::schedule::{Assignment, Timelines};

use super::minmin::schedule_mct;
use super::{Problem, Scheduler};

pub struct MaxMin;

impl Scheduler for MaxMin {
    fn name(&self) -> String {
        "MaxMin".to_string()
    }

    fn schedule(
        &mut self,
        prob: &Problem,
        net: &Network,
        timelines: &mut Timelines,
    ) -> Vec<Assignment> {
        schedule_mct(prob, net, timelines, /*pick_max=*/ true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedulers::testutil::problem_from_graph;

    #[test]
    fn maxmin_places_long_task_first() {
        let mut b = GraphBuilder::new("two");
        b.task(10.0);
        b.task(2.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(1);
        let mut tl = Timelines::new(1);
        let out = MaxMin.schedule(&prob, &net, &mut tl);
        assert_eq!(out[0].start, 0.0, "long task scheduled first");
        assert_eq!(out[1].start, 10.0);
    }

    #[test]
    fn differs_from_minmin_on_mixed_bag() {
        use crate::schedulers::MinMin;
        let mut b = GraphBuilder::new("bag");
        for c in [9.0, 1.0, 7.0, 2.0] {
            b.task(c);
        }
        let g = b.build().unwrap();
        let net = Network::homogeneous(2);
        let prob = problem_from_graph(&g, 0, 0.0);
        let mut tl1 = Timelines::new(2);
        let mm = MinMin.schedule(&prob, &net, &mut tl1);
        let mut tl2 = Timelines::new(2);
        let xm = MaxMin.schedule(&prob, &net, &mut tl2);
        // MinMin starts the 1-cost task at 0; MaxMin starts the 9-cost.
        assert_eq!(mm[1].start, 0.0);
        assert_eq!(xm[0].start, 0.0);
    }

    #[test]
    fn dependency_safety() {
        let mut b = GraphBuilder::new("d");
        let a = b.task(3.0);
        let c = b.task(4.0);
        let d = b.task(5.0);
        b.edge(a, c, 2.0).edge(a, d, 2.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(2);
        let mut tl = Timelines::new(2);
        let out = MaxMin.schedule(&prob, &net, &mut tl);
        for i in [1usize, 2] {
            let comm = net.comm_time(2.0, out[0].node, out[i].node);
            assert!(out[0].finish + comm <= out[i].start + 1e-9);
        }
    }
}
