//! Machinery shared by all list-scheduling heuristics: topological order
//! over composite problems, connected-component labelling, the
//! insertion-based EFT evaluation, and a total-order f64 wrapper.

use crate::network::Network;
use crate::schedule::{Assignment, Timelines};
use crate::telemetry;

use super::{Pred, Problem};

/// f64 with a total order (no NaNs expected in schedule arithmetic) for
/// use in heaps and sorts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN in scheduler ordering")
    }
}

/// Kahn topological order over the *pending* dependency structure
/// (reads the CSR views — callers that mutate `Problem::tasks` must
/// `rebuild_views()` first).
pub fn topo_order(prob: &Problem) -> Vec<usize> {
    let n = prob.n_tasks();
    let mut indeg: Vec<usize> = (0..n).map(|i| prob.n_pending_preds(i)).collect();
    let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut out = Vec::with_capacity(n);
    let mut head = 0;
    while head < queue.len() {
        let i = queue[head];
        head += 1;
        out.push(i);
        for &c in prob.succs_of(i).0 {
            let c = c as usize;
            indeg[c] -= 1;
            if indeg[c] == 0 {
                queue.push(c);
            }
        }
    }
    debug_assert_eq!(out.len(), n, "composite problem contains a cycle");
    out
}

/// Label weakly-connected components of the pending graph (CPOP computes
/// one critical path per component).
pub fn components(prob: &Problem) -> Vec<usize> {
    let n = prob.n_tasks();
    let mut label = vec![usize::MAX; n];
    let mut next = 0;
    let mut stack = Vec::new();
    for s in 0..n {
        if label[s] != usize::MAX {
            continue;
        }
        label[s] = next;
        stack.push(s);
        while let Some(i) = stack.pop() {
            for &c in prob.succs_of(i).0 {
                let c = c as usize;
                if label[c] == usize::MAX {
                    label[c] = next;
                    stack.push(c);
                }
            }
            for &p in prob.pending_preds_of(i).0 {
                let p = p as usize;
                if label[p] == usize::MAX {
                    label[p] = next;
                    stack.push(p);
                }
            }
        }
        next += 1;
    }
    label
}

/// Data-ready time of pending task `i` on node `v`, given the partial
/// assignment vector (pending parents must already be placed).
pub fn ready_time(
    prob: &Problem,
    i: usize,
    v: usize,
    net: &Network,
    partial: &[Option<Assignment>],
) -> f64 {
    let t = &prob.tasks[i];
    let mut ready = t.ready;
    for p in &t.preds {
        let arrival = match *p {
            Pred::Pending { idx, data } => {
                let a = partial[idx].expect("pending parent not yet placed");
                a.finish + net.comm_time(data, a.node, v)
            }
            Pred::Fixed { node, finish, data } => finish + net.comm_time(data, node, v),
        };
        ready = ready.max(arrival);
    }
    ready
}

/// Insertion-based EFT on node `v` of a task with compute cost `cost`
/// whose data-ready time there is already known — the single shared
/// assembly of the paper's EFT formula (every scheduler path routes
/// through here, so the insertion policy lives in exactly one place).
#[inline]
pub fn eft_at(
    ready: f64,
    cost: f64,
    v: usize,
    net: &Network,
    timelines: &Timelines,
) -> Assignment {
    let dur = net.exec_time(cost, v);
    let start = timelines.earliest_start(v, ready, dur);
    Assignment {
        node: v,
        start,
        finish: start + dur,
    }
}

/// Insertion-based EFT of pending task `i` on node `v`.
pub fn eft_on_node(
    prob: &Problem,
    i: usize,
    v: usize,
    net: &Network,
    timelines: &Timelines,
    partial: &[Option<Assignment>],
) -> Assignment {
    let ready = ready_time(prob, i, v, net, partial);
    eft_at(ready, prob.tasks[i].cost, v, net, timelines)
}

/// Minimum-EFT placement of task `i` across all nodes (ties: lowest node
/// id, for determinism).
///
/// This is the uncached reference formulation: it re-walks `i`'s
/// predecessor list once **per candidate node** (preds × nodes work).
/// The hot paths use [`EftScratch`] + [`min_eft_cached`] instead, which
/// produce bit-identical assignments (see the
/// `cached_eft_matches_reference` test) at preds + nodes cost.
pub fn min_eft(
    prob: &Problem,
    i: usize,
    net: &Network,
    timelines: &Timelines,
    partial: &[Option<Assignment>],
) -> Assignment {
    let mut best: Option<Assignment> = None;
    for v in 0..net.n_nodes() {
        let a = eft_on_node(prob, i, v, net, timelines, partial);
        if best.map_or(true, |b| a.finish < b.finish) {
            best = Some(a);
        }
    }
    // one bump per placement *decision* (not per candidate node), to
    // bound the hot-path accounting cost
    telemetry::counter_inc(telemetry::Counter::EftPlacements);
    best.expect("network has no nodes")
}

/// Reusable EFT workspace (§Perf): a task's data-ready time on node `v`
/// depends only on its parents' placements — which are final by the time
/// the task is evaluated (list schedulers only evaluate *ready* tasks) —
/// never on the timelines.  So the parent `(node, finish, data)` triples
/// are gathered **once** per task, and the per-node ready times are
/// computed parent-major with the parent's cached [`Network::comm_row`],
/// instead of re-walking the predecessor list for every candidate node.
/// Both buffers are reused across tasks: steady state allocates nothing.
#[derive(Debug, Default)]
pub struct EftScratch {
    /// parent placements `(node, finish, data)` of the loaded task
    parents: Vec<(usize, f64, f64)>,
    /// data-ready time of the loaded task per node
    ready: Vec<f64>,
}

impl EftScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Gather task `i`'s parent triples and compute its ready time on
    /// every node.  Pending parents must already be placed in `partial`.
    ///
    /// Reads the CSR views (pending preds, then fixed preds) — a
    /// different parent order than the reference interleaved walk, which
    /// is bit-safe because the per-node ready time is a max over finite
    /// non-negative arrivals (see [`Problem`] docs) and pinned by the
    /// `cached_eft_matches_reference` test.
    pub fn load(
        &mut self,
        prob: &Problem,
        i: usize,
        net: &Network,
        partial: &[Option<Assignment>],
    ) {
        self.parents.clear();
        let (pidx, pdata) = prob.pending_preds_of(i);
        for (&p, &data) in pidx.iter().zip(pdata) {
            let a = partial[p as usize].expect("pending parent not yet placed");
            self.parents.push((a.node, a.finish, data));
        }
        let (fnode, ffinish, fdata) = prob.fixed_preds_of(i);
        for k in 0..fnode.len() {
            self.parents.push((fnode[k] as usize, ffinish[k], fdata[k]));
        }
        let n = net.n_nodes();
        self.ready.clear();
        self.ready.resize(n, prob.ready_col[i]);
        for &(u, finish, data) in &self.parents {
            let row = net.comm_row(u);
            for (v, r) in self.ready.iter_mut().enumerate() {
                let arrival = finish + if u == v { 0.0 } else { data / row[v] };
                if arrival > *r {
                    *r = arrival;
                }
            }
        }
    }

    /// Ready time of the loaded task on node `v` (bit-identical to
    /// [`ready_time`], which is max-folded from the same values).
    #[inline]
    pub fn ready_on(&self, v: usize) -> f64 {
        self.ready[v]
    }

    /// All per-node ready times of the loaded task.
    #[inline]
    pub fn ready_row(&self) -> &[f64] {
        &self.ready
    }
}

/// Flattened per-task ready-time rows for schedulers that keep many
/// tasks "ready" at once (MinMin/MaxMin, MET/OLB/ETF): row `i` is
/// filled exactly once — when task `i` becomes ready, its parents being
/// final from then on — and probed as `ready_on(i, v)` by every later
/// EFT evaluation.  One buffer per `schedule()` call, like the
/// schedulers' other per-call vectors (`partial`, heaps, EFT caches).
///
/// Tradeoff: filling a row costs O(preds × nodes) up front; schedulers
/// that probe a single node per task (MET) pay slightly more here than
/// a one-node `ready_time` walk, in exchange for every multi-node
/// scheduler sharing one implementation.
pub struct EftRows {
    ready: Vec<f64>,
    n_nodes: usize,
}

impl EftRows {
    pub fn new(n_tasks: usize, n_nodes: usize) -> Self {
        Self {
            ready: vec![0.0; n_tasks * n_nodes],
            n_nodes,
        }
    }

    /// Fill task `i`'s row from its (final) parents via `scratch`.
    pub fn fill(
        &mut self,
        prob: &Problem,
        i: usize,
        net: &Network,
        partial: &[Option<Assignment>],
        scratch: &mut EftScratch,
    ) {
        scratch.load(prob, i, net, partial);
        self.ready[i * self.n_nodes..(i + 1) * self.n_nodes]
            .copy_from_slice(scratch.ready_row());
    }

    /// Cached data-ready time of task `i` on node `v`.
    #[inline]
    pub fn ready_on(&self, i: usize, v: usize) -> f64 {
        self.ready[i * self.n_nodes + v]
    }

    /// Insertion-based EFT of ready task `i` on node `v`.
    #[inline]
    pub fn eft(
        &self,
        prob: &Problem,
        net: &Network,
        timelines: &Timelines,
        i: usize,
        v: usize,
    ) -> Assignment {
        eft_at(self.ready_on(i, v), prob.cost_col[i], v, net, timelines)
    }
}

/// Insertion-based EFT of the task loaded into `scratch` on node `v`.
#[inline]
pub fn eft_on_node_cached(
    scratch: &EftScratch,
    prob: &Problem,
    i: usize,
    v: usize,
    net: &Network,
    timelines: &Timelines,
) -> Assignment {
    eft_at(scratch.ready_on(v), prob.cost_col[i], v, net, timelines)
}

/// Minimum-EFT placement of the task loaded into `scratch` across all
/// nodes — the cached counterpart of [`min_eft`] (same tie-break: lowest
/// node id wins).
pub fn min_eft_cached(
    scratch: &EftScratch,
    prob: &Problem,
    i: usize,
    net: &Network,
    timelines: &Timelines,
) -> Assignment {
    let mut best: Option<Assignment> = None;
    for v in 0..net.n_nodes() {
        let a = eft_on_node_cached(scratch, prob, i, v, net, timelines);
        if best.map_or(true, |b| a.finish < b.finish) {
            best = Some(a);
        }
    }
    // one bump per placement decision, mirroring [`min_eft`]
    telemetry::counter_inc(telemetry::Counter::EftPlacements);
    best.expect("network has no nodes")
}

/// Mean execution cost `w̄(t)` and mean communication cost `c̄(e)` vectors
/// used by the rank computations (HEFT Eq. definitions).
pub fn mean_costs(prob: &Problem, net: &Network) -> (Vec<f64>, Vec<Vec<(usize, f64)>>) {
    let inv_speed = net.mean_inv_speed();
    let inv_link = net.mean_inv_link();
    let w: Vec<f64> = prob.cost_col.iter().map(|&c| c * inv_speed).collect();
    let succ_costs: Vec<Vec<(usize, f64)>> = (0..prob.n_tasks())
        .map(|i| {
            let (sidx, sdata) = prob.succs_of(i);
            sidx.iter()
                .zip(sdata)
                .map(|(&c, &data)| (c as usize, data * inv_link))
                .collect()
        })
        .collect();
    (w, succ_costs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedulers::testutil::problem_from_graph;

    fn diamond_prob() -> Problem {
        let mut b = GraphBuilder::new("d");
        let t0 = b.task(10.0);
        let t1 = b.task(5.0);
        let t2 = b.task(7.0);
        let t3 = b.task(3.0);
        b.edge(t0, t1, 2.0)
            .edge(t0, t2, 4.0)
            .edge(t1, t3, 1.0)
            .edge(t2, t3, 1.5);
        problem_from_graph(&b.build().unwrap(), 0, 0.0)
    }

    #[test]
    fn topo_order_respects_pending_deps() {
        let p = diamond_prob();
        let order = topo_order(&p);
        let pos: Vec<usize> = {
            let mut v = vec![0; 4];
            for (i, &t) in order.iter().enumerate() {
                v[t] = i;
            }
            v
        };
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    fn components_label_connected_parts() {
        let mut p = diamond_prob();
        let q = diamond_prob();
        let off = p.tasks.len();
        // merge q as a second component with shifted indices
        for mut t in q.tasks {
            t.succs = t.succs.iter().map(|&(c, d)| (c + off, d)).collect();
            t.preds = t
                .preds
                .iter()
                .map(|pr| match *pr {
                    Pred::Pending { idx, data } => Pred::Pending { idx: idx + off, data },
                    f => f,
                })
                .collect();
            p.tasks.push(t);
        }
        p.rebuild_views();
        let labels = components(&p);
        assert_eq!(labels[0], labels[3]);
        assert_eq!(labels[4], labels[7]);
        assert_ne!(labels[0], labels[4]);
    }

    #[test]
    fn ready_time_includes_fixed_and_pending_parents() {
        use crate::network::Network;
        let mut p = diamond_prob();
        // give t3 an extra fixed parent finishing at 100 on node 0, data 6
        p.tasks[3].preds.push(Pred::Fixed {
            node: 0,
            finish: 100.0,
            data: 6.0,
        });
        let net = Network::new(vec![1.0, 2.0], vec![0.0, 3.0, 3.0, 0.0]);
        let mut partial = vec![None; 4];
        partial[0] = Some(Assignment { node: 0, start: 0.0, finish: 10.0 });
        partial[1] = Some(Assignment { node: 0, start: 10.0, finish: 15.0 });
        partial[2] = Some(Assignment { node: 1, start: 12.0, finish: 15.5 });
        // on node 1: pending t1 from node0: 15 + 2/3; t2 local: 15.5;
        // fixed parent: 100 + 6/3 = 102 → dominates
        let r = ready_time(&p, 3, 1, &net, &partial);
        assert!((r - 102.0).abs() < 1e-12);
        // on node 0 fixed parent is local: 100
        let r0 = ready_time(&p, 3, 0, &net, &partial);
        assert!((r0 - 100.0).abs() < 1e-12);
    }

    #[test]
    fn min_eft_prefers_faster_node_when_free() {
        use crate::network::Network;
        let p = {
            let mut b = GraphBuilder::new("single");
            b.task(8.0);
            problem_from_graph(&b.build().unwrap(), 0, 0.0)
        };
        let net = Network::new(vec![1.0, 4.0], vec![0.0, 1.0, 1.0, 0.0]);
        let tl = Timelines::new(2);
        let a = min_eft(&p, 0, &net, &tl, &[None]);
        assert_eq!(a.node, 1);
        assert_eq!(a.finish, 2.0);
    }

    #[test]
    fn mean_costs_match_network_means() {
        use crate::network::Network;
        let p = diamond_prob();
        let net = Network::new(vec![1.0, 2.0], vec![0.0, 4.0, 4.0, 0.0]);
        let (w, sc) = mean_costs(&p, &net);
        assert!((w[0] - 10.0 * 0.75).abs() < 1e-12);
        assert!((sc[0][0].1 - 2.0 * 0.25).abs() < 1e-12);
    }

    #[test]
    fn cached_eft_matches_reference() {
        // Property test: on random DAGs (with random Fixed preds mixed
        // in), placing tasks in topo order via the cached EFT path must
        // be bit-identical to the reference preds×nodes formulation.
        use crate::network::Network;
        use crate::prng::Xoshiro256pp;
        use crate::schedule::Slot;
        let mut rng = Xoshiro256pp::seed_from_u64(2024);
        for case in 0..40 {
            let n = rng.int_range(1, 25);
            let mut b = GraphBuilder::new("rand");
            let ids: Vec<_> = (0..n).map(|_| b.task(rng.uniform(0.5, 15.0))).collect();
            for i in 0..n {
                for j in (i + 1)..n {
                    if rng.next_f64() < 0.2 {
                        b.edge(ids[i], ids[j], rng.uniform(0.0, 6.0));
                    }
                }
            }
            let mut prob = problem_from_graph(&b.build().unwrap(), 0, rng.uniform(0.0, 4.0));
            let n_nodes = rng.int_range(1, 6);
            let dist = crate::stats::TruncatedGaussian::new(1.0, 0.3, 0.4, 2.0);
            let net = Network::generate(n_nodes, &dist, &dist, &mut rng);
            // sprinkle committed parents
            for t in prob.tasks.iter_mut() {
                if rng.next_f64() < 0.3 {
                    t.preds.push(Pred::Fixed {
                        node: rng.below(n_nodes),
                        finish: rng.uniform(0.0, 20.0),
                        data: rng.uniform(0.0, 5.0),
                    });
                }
            }
            prob.rebuild_views();

            let order = topo_order(&prob);
            let mut tl_ref = Timelines::new(n_nodes);
            let mut tl_new = Timelines::new(n_nodes);
            let mut partial_ref: Vec<Option<Assignment>> = vec![None; prob.n_tasks()];
            let mut partial_new: Vec<Option<Assignment>> = vec![None; prob.n_tasks()];
            let mut scratch = EftScratch::new();
            for &i in &order {
                let a_ref = min_eft(&prob, i, &net, &tl_ref, &partial_ref);
                scratch.load(&prob, i, &net, &partial_new);
                let a_new = min_eft_cached(&scratch, &prob, i, &net, &tl_new);
                assert_eq!(
                    (a_ref.node, a_ref.start.to_bits(), a_ref.finish.to_bits()),
                    (a_new.node, a_new.start.to_bits(), a_new.finish.to_bits()),
                    "case {case}, task {i}"
                );
                // also the per-node ready times must agree bit-exactly
                for v in 0..n_nodes {
                    let r = ready_time(&prob, i, v, &net, &partial_ref);
                    assert_eq!(
                        r.to_bits(),
                        scratch.ready_on(v).to_bits(),
                        "case {case}, task {i}, node {v}"
                    );
                }
                let slot = Slot {
                    start: a_ref.start,
                    finish: a_ref.finish,
                    gid: prob.tasks[i].gid,
                };
                tl_ref.insert(a_ref.node, slot);
                tl_new.insert(a_new.node, slot);
                partial_ref[i] = Some(a_ref);
                partial_new[i] = Some(a_new);
            }
        }
    }

    #[test]
    fn ordf64_total_order() {
        let mut v = vec![OrdF64(3.0), OrdF64(1.0), OrdF64(2.0)];
        v.sort();
        assert_eq!(v, vec![OrdF64(1.0), OrdF64(2.0), OrdF64(3.0)]);
    }
}
