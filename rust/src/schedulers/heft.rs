//! HEFT — Heterogeneous Earliest Finish Time (Topcuoglu et al., 2002).
//!
//! Phase 1: priority = upward rank over mean costs (via the pluggable
//! [`RankProvider`], so the XLA-compiled Pallas fixed point can stand in).
//! Phase 2: in priority order, place each task on the node minimizing its
//! **insertion-based** EFT.
//!
//! On composite problems the priority queue naturally interleaves the
//! components; dependency safety does not rely on rank strict monotonicity
//! — a task only enters the queue once all its pending parents are placed.

use std::collections::BinaryHeap;

use crate::network::Network;
use crate::schedule::{Assignment, Slot, Timelines};

use super::common::{min_eft_cached, EftScratch, OrdF64};
use super::rank::RankProvider;
#[cfg(test)]
use super::Pred;
use super::{Problem, Scheduler};

pub struct Heft<R: RankProvider> {
    ranks: R,
}

impl<R: RankProvider> Heft<R> {
    pub fn new(ranks: R) -> Self {
        Self { ranks }
    }
}

impl<R: RankProvider> Scheduler for Heft<R> {
    fn name(&self) -> String {
        if self.ranks.provider_name() == "native" {
            "HEFT".to_string()
        } else {
            format!("HEFT[{}]", self.ranks.provider_name())
        }
    }

    fn schedule(
        &mut self,
        prob: &Problem,
        net: &Network,
        timelines: &mut Timelines,
    ) -> Vec<Assignment> {
        let n = prob.n_tasks();
        let ranks = self.ranks.ranks(prob, net);
        let mut partial: Vec<Option<Assignment>> = vec![None; n];

        // pending-parent counters; ready tasks enter the priority heap.
        let mut missing: Vec<usize> = (0..n).map(|i| prob.n_pending_preds(i)).collect();
        // max-heap on (rank, reversed gid) → deterministic tie-break.
        let mut heap: BinaryHeap<(OrdF64, std::cmp::Reverse<crate::graph::Gid>, usize)> =
            BinaryHeap::new();
        for i in 0..n {
            if missing[i] == 0 {
                heap.push((OrdF64(ranks.up[i]), std::cmp::Reverse(prob.gid_col[i]), i));
            }
        }

        let mut placed = 0;
        let mut scratch = EftScratch::new();
        while let Some((_, _, i)) = heap.pop() {
            scratch.load(prob, i, net, &partial);
            let a = min_eft_cached(&scratch, prob, i, net, timelines);
            timelines.insert(
                a.node,
                Slot {
                    start: a.start,
                    finish: a.finish,
                    gid: prob.gid_col[i],
                },
            );
            partial[i] = Some(a);
            placed += 1;
            for &c in prob.succs_of(i).0 {
                let c = c as usize;
                missing[c] -= 1;
                if missing[c] == 0 {
                    heap.push((OrdF64(ranks.up[c]), std::cmp::Reverse(prob.gid_col[c]), c));
                }
            }
        }
        assert_eq!(placed, n, "HEFT failed to place every task");
        partial.into_iter().map(Option::unwrap).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Gid, GraphBuilder};
    use crate::schedulers::rank::NativeRanks;
    use crate::schedulers::testutil::problem_from_graph;

    fn heft() -> Heft<NativeRanks> {
        Heft::new(NativeRanks)
    }

    #[test]
    fn single_task_picks_fastest_node() {
        let mut b = GraphBuilder::new("one");
        b.task(12.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::new(vec![1.0, 3.0], vec![0.0, 1.0, 1.0, 0.0]);
        let mut tl = Timelines::new(2);
        let out = heft().schedule(&prob, &net, &mut tl);
        assert_eq!(out[0].node, 1);
        assert_eq!(out[0].finish, 4.0);
    }

    #[test]
    fn chain_local_placement_avoids_comm() {
        // Heavy comm: HEFT should co-locate the chain on the fast node.
        let mut b = GraphBuilder::new("chain");
        let t0 = b.task(4.0);
        let t1 = b.task(4.0);
        b.edge(t0, t1, 100.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::new(vec![1.0, 2.0], vec![0.0, 1.0, 1.0, 0.0]);
        let mut tl = Timelines::new(2);
        let out = heft().schedule(&prob, &net, &mut tl);
        assert_eq!(out[0].node, out[1].node);
        assert_eq!(out[1].node, 1);
        assert_eq!(out[1].finish, 4.0);
    }

    #[test]
    fn parallel_tasks_spread_across_nodes() {
        let mut b = GraphBuilder::new("par");
        for _ in 0..4 {
            b.task(10.0);
        }
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(4);
        let mut tl = Timelines::new(4);
        let out = heft().schedule(&prob, &net, &mut tl);
        let mut nodes: Vec<usize> = out.iter().map(|a| a.node).collect();
        nodes.sort_unstable();
        assert_eq!(nodes, vec![0, 1, 2, 3], "independent equal tasks spread");
    }

    #[test]
    fn respects_ready_time_and_fixed_parent() {
        let mut b = GraphBuilder::new("g");
        b.task(2.0);
        let mut prob = problem_from_graph(&b.build().unwrap(), 0, 5.0);
        prob.tasks[0].preds.push(Pred::Fixed {
            node: 0,
            finish: 9.0,
            data: 0.0,
        });
        prob.rebuild_views();
        let net = Network::homogeneous(2);
        let mut tl = Timelines::new(2);
        let out = heft().schedule(&prob, &net, &mut tl);
        assert!(out[0].start >= 9.0);
    }

    #[test]
    fn insertion_fills_gap_left_by_committed_slot() {
        let mut b = GraphBuilder::new("g");
        b.task(2.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(1);
        let mut tl = Timelines::new(1);
        // committed slots [0,1] and [4,9]: a 2-long task fits at 1.
        tl.insert(0, Slot { start: 0.0, finish: 1.0, gid: Gid::new(9, 0) });
        tl.insert(0, Slot { start: 4.0, finish: 9.0, gid: Gid::new(9, 1) });
        let out = heft().schedule(&prob, &net, &mut tl);
        assert_eq!(out[0].start, 1.0);
        assert_eq!(out[0].finish, 3.0);
    }

    #[test]
    fn diamond_produces_valid_schedule() {
        let mut b = GraphBuilder::new("d");
        let t0 = b.task(10.0);
        let t1 = b.task(5.0);
        let t2 = b.task(7.0);
        let t3 = b.task(3.0);
        b.edge(t0, t1, 2.0)
            .edge(t0, t2, 4.0)
            .edge(t1, t3, 1.0)
            .edge(t2, t3, 1.5);
        let g = b.build().unwrap();
        let prob = problem_from_graph(&g, 0, 0.0);
        let net = Network::new(
            vec![1.0, 2.0, 0.5],
            vec![0.0, 2.0, 1.0, 2.0, 0.0, 3.0, 1.0, 3.0, 0.0],
        );
        let mut tl = Timelines::new(3);
        let out = heft().schedule(&prob, &net, &mut tl);
        // root first, sink last; all dependency constraints hold
        for (i, t) in prob.tasks.iter().enumerate() {
            for p in &t.preds {
                if let Pred::Pending { idx, data } = *p {
                    let pa = out[idx];
                    let comm = net.comm_time(data, pa.node, out[i].node);
                    assert!(pa.finish + comm <= out[i].start + 1e-9);
                }
            }
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let mut b = GraphBuilder::new("d");
        let mut prev = None;
        for _ in 0..3 {
            b = GraphBuilder::new("d");
            let t0 = b.task(3.0);
            let t1 = b.task(3.0);
            let t2 = b.task(3.0);
            b.edge(t0, t2, 1.0).edge(t1, t2, 1.0);
            let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
            let net = Network::homogeneous(2);
            let mut tl = Timelines::new(2);
            let out = heft().schedule(&prob, &net, &mut tl);
            let sig: Vec<(usize, u64)> = out
                .iter()
                .map(|a| (a.node, a.start.to_bits()))
                .collect();
            if let Some(p) = &prev {
                assert_eq!(*p, sig);
            }
            prev = Some(sig);
        }
    }
}
