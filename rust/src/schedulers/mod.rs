//! Static scheduling heuristics over *composite* problems.
//!
//! The dynamic coordinator (§IV of the paper) repeatedly builds a
//! [`Problem`] — the merged multi-component graph of every task that is
//! currently *Unscheduled* — and hands it to one of the base heuristics
//! (HEFT, CPOP, MinMin, MaxMin, Random).  Committed placements appear in
//! two ways: as occupied intervals inside the [`Timelines`] the scheduler
//! packs around, and as [`Pred::Fixed`] dependency constraints carrying
//! the committed parent's node and finish time.

use crate::graph::{FixedArena, Gid, GraphArena};
use crate::network::Network;
use crate::schedule::{Assignment, Timelines};

pub mod baselines;
pub mod common;
pub mod cpop;
pub mod heft;
pub mod maxmin;
pub mod minmin;
pub mod random;
pub mod rank;

pub use baselines::{Etf, Met, Olb};
pub use cpop::Cpop;
pub use heft::Heft;
pub use maxmin::MaxMin;
pub use minmin::MinMin;
pub use random::RandomScheduler;
pub use rank::{NativeRanks, RankProvider, Ranks};

/// A dependency of a pending task.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Pred {
    /// Parent is also pending: `idx` into [`Problem::tasks`].
    Pending { idx: usize, data: f64 },
    /// Parent is committed (Executing/Completed or frozen Scheduled):
    /// its placement is a constant of the problem.
    Fixed { node: usize, finish: f64, data: f64 },
}

/// One pending task of a composite problem.
#[derive(Clone, Debug, PartialEq)]
pub struct PTask {
    pub gid: Gid,
    /// compute cost `c(t)`
    pub cost: f64,
    /// earliest permissible start (its graph's arrival time `a_i`)
    pub ready: f64,
    pub preds: Vec<Pred>,
    /// pending successors: (idx into tasks, data size)
    pub succs: Vec<(usize, f64)>,
}

/// The merged multi-component instance handed to a heuristic.
///
/// Two representations coexist (§Perf, PR 6):
///
/// * the **builder/reference view** `tasks` — per-task `preds`/`succs`
///   Vecs, walked by the retained reference implementations
///   (`ready_time`, `min_eft`, `schedule_mct_naive`) that pin the fast
///   paths bit-exact;
/// * the **CSR/SoA view** — flat [`GraphArena`]s for pending preds and
///   succs, a [`FixedArena`] for committed parents, and
///   cost/ready/gid columns — derived from `tasks` by
///   [`Problem::rebuild_views`] and read by every hot scheduler loop.
///
/// Construct via [`Problem::from_tasks`] (or call `rebuild_views()`
/// after mutating `tasks` directly); the derived views are rebuilt
/// clear-and-push, so a warm `CompositeWorkspace` refills them without
/// allocating.
///
/// Splitting each task's interleaved pred list into a pending CSR and a
/// fixed CSR reorders the parents a hot path visits — which is
/// bit-safe: data-ready times are `max`-folds over finite, non-negative
/// arrival times (no NaN, no -0.0), and `f64::max` over such a multiset
/// is order-independent.  The `cached_eft_matches_reference` property
/// test pins this against the interleaved reference walk.
#[derive(Clone, Debug, Default)]
pub struct Problem {
    pub tasks: Vec<PTask>,
    /// CSR of pending predecessors: row `i` = (parent idx, data) pairs.
    pub pending_preds: GraphArena,
    /// CSR of pending successors: row `i` = (child idx, data) pairs.
    pub succs: GraphArena,
    /// CSR of fixed (committed) predecessors: row `i` = (node, finish,
    /// data) triples.
    pub fixed: FixedArena,
    /// SoA column of compute costs `c(t)`.
    pub cost_col: Vec<f64>,
    /// SoA column of earliest permissible starts (graph arrivals).
    pub ready_col: Vec<f64>,
    /// SoA column of global task ids.
    pub gid_col: Vec<Gid>,
}

/// Equality is defined on the builder view only — the CSR/SoA views are
/// derived state (and deliberately don't affect comparisons between a
/// freshly-built reference problem and a warm workspace one).
impl PartialEq for Problem {
    fn eq(&self, other: &Self) -> bool {
        self.tasks == other.tasks
    }
}

impl Problem {
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Build a problem from tasks, deriving the CSR/SoA views.
    pub fn from_tasks(tasks: Vec<PTask>) -> Self {
        let mut p = Self {
            tasks,
            ..Self::default()
        };
        p.rebuild_views();
        p
    }

    /// Re-derive the CSR/SoA views from `tasks`.  Clear-and-push: a warm
    /// problem (the `CompositeWorkspace` one) refills without allocating
    /// once capacities have grown to the composite's high-water mark.
    pub fn rebuild_views(&mut self) {
        self.pending_preds.reset();
        self.succs.reset();
        self.fixed.reset();
        self.cost_col.clear();
        self.ready_col.clear();
        self.gid_col.clear();
        for t in &self.tasks {
            self.cost_col.push(t.cost);
            self.ready_col.push(t.ready);
            self.gid_col.push(t.gid);
            for p in &t.preds {
                match *p {
                    Pred::Pending { idx, data } => self.pending_preds.push(idx as u32, data),
                    Pred::Fixed { node, finish, data } => {
                        self.fixed.push(node as u32, finish, data)
                    }
                }
            }
            self.pending_preds.close_row();
            self.fixed.close_row();
            for &(c, d) in &t.succs {
                self.succs.push(c as u32, d);
            }
            self.succs.close_row();
        }
    }

    /// Number of *pending* predecessors of task `i` (O(1) via the CSR).
    #[inline]
    pub fn n_pending_preds(&self, i: usize) -> usize {
        self.pending_preds.degree(i)
    }

    /// Pending predecessors of task `i` as parallel (idx, data) slices.
    #[inline]
    pub fn pending_preds_of(&self, i: usize) -> (&[u32], &[f64]) {
        self.pending_preds.row(i)
    }

    /// Fixed predecessors of task `i` as parallel (node, finish, data)
    /// slices.
    #[inline]
    pub fn fixed_preds_of(&self, i: usize) -> (&[u32], &[f64], &[f64]) {
        self.fixed.row(i)
    }

    /// Pending successors of task `i` as parallel (idx, data) slices.
    #[inline]
    pub fn succs_of(&self, i: usize) -> (&[u32], &[f64]) {
        self.succs.row(i)
    }
}

/// A base scheduling heuristic.  Must place **every** pending task,
/// inserting the corresponding slots into `timelines` and returning the
/// assignment vector parallel to `prob.tasks`.
pub trait Scheduler {
    fn name(&self) -> String;
    fn schedule(
        &mut self,
        prob: &Problem,
        net: &Network,
        timelines: &mut Timelines,
    ) -> Vec<Assignment>;
}

/// Base heuristic selector (the paper's five).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchedulerKind {
    Heft,
    Cpop,
    MinMin,
    MaxMin,
    Random,
    /// extension baseline (not in the paper's grid): Minimum Execution Time
    Met,
    /// extension baseline: Opportunistic Load Balancing
    Olb,
    /// extension baseline: Earliest Time First
    Etf,
}

impl SchedulerKind {
    /// The paper's five heuristics (§VI).
    pub const ALL: [SchedulerKind; 5] = [
        SchedulerKind::Heft,
        SchedulerKind::Cpop,
        SchedulerKind::MinMin,
        SchedulerKind::MaxMin,
        SchedulerKind::Random,
    ];

    /// Paper heuristics + extension baselines (MET/OLB/ETF).
    pub const EXTENDED: [SchedulerKind; 8] = [
        SchedulerKind::Heft,
        SchedulerKind::Cpop,
        SchedulerKind::MinMin,
        SchedulerKind::MaxMin,
        SchedulerKind::Random,
        SchedulerKind::Met,
        SchedulerKind::Olb,
        SchedulerKind::Etf,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Heft => "HEFT",
            SchedulerKind::Cpop => "CPOP",
            SchedulerKind::MinMin => "MinMin",
            SchedulerKind::MaxMin => "MaxMin",
            SchedulerKind::Random => "Random",
            SchedulerKind::Met => "MET",
            SchedulerKind::Olb => "OLB",
            SchedulerKind::Etf => "ETF",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "heft" => Some(SchedulerKind::Heft),
            "cpop" => Some(SchedulerKind::Cpop),
            "minmin" | "min-min" => Some(SchedulerKind::MinMin),
            "maxmin" | "max-min" => Some(SchedulerKind::MaxMin),
            "random" => Some(SchedulerKind::Random),
            "met" => Some(SchedulerKind::Met),
            "olb" => Some(SchedulerKind::Olb),
            "etf" => Some(SchedulerKind::Etf),
            _ => None,
        }
    }

    /// Instantiate with the default (native) rank provider.
    pub fn make(&self, seed: u64) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Heft => Box::new(Heft::new(NativeRanks)),
            SchedulerKind::Cpop => Box::new(Cpop::new(NativeRanks)),
            SchedulerKind::MinMin => Box::new(MinMin),
            SchedulerKind::MaxMin => Box::new(MaxMin),
            SchedulerKind::Random => Box::new(RandomScheduler::new(seed)),
            SchedulerKind::Met => Box::new(Met),
            SchedulerKind::Olb => Box::new(Olb),
            SchedulerKind::Etf => Box::new(Etf),
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::graph::TaskGraph;

    /// Build a single-graph problem (no fixed preds) with arrival time 0.
    pub fn problem_from_graph(g: &TaskGraph, graph_idx: usize, arrival: f64) -> Problem {
        let mut tasks: Vec<PTask> = (0..g.n_tasks())
            .map(|t| PTask {
                gid: Gid::new(graph_idx, t),
                cost: g.cost(t),
                ready: arrival,
                preds: Vec::new(),
                succs: Vec::new(),
            })
            .collect();
        for t in 0..g.n_tasks() {
            for &(c, d) in g.successors(t) {
                tasks[t].succs.push((c, d));
                tasks[c].preds.push(Pred::Pending { idx: t, data: d });
            }
        }
        Problem::from_tasks(tasks)
    }
}
