//! Static scheduling heuristics over *composite* problems.
//!
//! The dynamic coordinator (§IV of the paper) repeatedly builds a
//! [`Problem`] — the merged multi-component graph of every task that is
//! currently *Unscheduled* — and hands it to one of the base heuristics
//! (HEFT, CPOP, MinMin, MaxMin, Random).  Committed placements appear in
//! two ways: as occupied intervals inside the [`Timelines`] the scheduler
//! packs around, and as [`Pred::Fixed`] dependency constraints carrying
//! the committed parent's node and finish time.

use crate::graph::Gid;
use crate::network::Network;
use crate::schedule::{Assignment, Timelines};

pub mod baselines;
pub mod common;
pub mod cpop;
pub mod heft;
pub mod maxmin;
pub mod minmin;
pub mod random;
pub mod rank;

pub use baselines::{Etf, Met, Olb};
pub use cpop::Cpop;
pub use heft::Heft;
pub use maxmin::MaxMin;
pub use minmin::MinMin;
pub use random::RandomScheduler;
pub use rank::{NativeRanks, RankProvider, Ranks};

/// A dependency of a pending task.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Pred {
    /// Parent is also pending: `idx` into [`Problem::tasks`].
    Pending { idx: usize, data: f64 },
    /// Parent is committed (Executing/Completed or frozen Scheduled):
    /// its placement is a constant of the problem.
    Fixed { node: usize, finish: f64, data: f64 },
}

/// One pending task of a composite problem.
#[derive(Clone, Debug, PartialEq)]
pub struct PTask {
    pub gid: Gid,
    /// compute cost `c(t)`
    pub cost: f64,
    /// earliest permissible start (its graph's arrival time `a_i`)
    pub ready: f64,
    pub preds: Vec<Pred>,
    /// pending successors: (idx into tasks, data size)
    pub succs: Vec<(usize, f64)>,
}

/// The merged multi-component instance handed to a heuristic.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Problem {
    pub tasks: Vec<PTask>,
}

impl Problem {
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }
}

/// A base scheduling heuristic.  Must place **every** pending task,
/// inserting the corresponding slots into `timelines` and returning the
/// assignment vector parallel to `prob.tasks`.
pub trait Scheduler {
    fn name(&self) -> String;
    fn schedule(
        &mut self,
        prob: &Problem,
        net: &Network,
        timelines: &mut Timelines,
    ) -> Vec<Assignment>;
}

/// Base heuristic selector (the paper's five).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchedulerKind {
    Heft,
    Cpop,
    MinMin,
    MaxMin,
    Random,
    /// extension baseline (not in the paper's grid): Minimum Execution Time
    Met,
    /// extension baseline: Opportunistic Load Balancing
    Olb,
    /// extension baseline: Earliest Time First
    Etf,
}

impl SchedulerKind {
    /// The paper's five heuristics (§VI).
    pub const ALL: [SchedulerKind; 5] = [
        SchedulerKind::Heft,
        SchedulerKind::Cpop,
        SchedulerKind::MinMin,
        SchedulerKind::MaxMin,
        SchedulerKind::Random,
    ];

    /// Paper heuristics + extension baselines (MET/OLB/ETF).
    pub const EXTENDED: [SchedulerKind; 8] = [
        SchedulerKind::Heft,
        SchedulerKind::Cpop,
        SchedulerKind::MinMin,
        SchedulerKind::MaxMin,
        SchedulerKind::Random,
        SchedulerKind::Met,
        SchedulerKind::Olb,
        SchedulerKind::Etf,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Heft => "HEFT",
            SchedulerKind::Cpop => "CPOP",
            SchedulerKind::MinMin => "MinMin",
            SchedulerKind::MaxMin => "MaxMin",
            SchedulerKind::Random => "Random",
            SchedulerKind::Met => "MET",
            SchedulerKind::Olb => "OLB",
            SchedulerKind::Etf => "ETF",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "heft" => Some(SchedulerKind::Heft),
            "cpop" => Some(SchedulerKind::Cpop),
            "minmin" | "min-min" => Some(SchedulerKind::MinMin),
            "maxmin" | "max-min" => Some(SchedulerKind::MaxMin),
            "random" => Some(SchedulerKind::Random),
            "met" => Some(SchedulerKind::Met),
            "olb" => Some(SchedulerKind::Olb),
            "etf" => Some(SchedulerKind::Etf),
            _ => None,
        }
    }

    /// Instantiate with the default (native) rank provider.
    pub fn make(&self, seed: u64) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Heft => Box::new(Heft::new(NativeRanks)),
            SchedulerKind::Cpop => Box::new(Cpop::new(NativeRanks)),
            SchedulerKind::MinMin => Box::new(MinMin),
            SchedulerKind::MaxMin => Box::new(MaxMin),
            SchedulerKind::Random => Box::new(RandomScheduler::new(seed)),
            SchedulerKind::Met => Box::new(Met),
            SchedulerKind::Olb => Box::new(Olb),
            SchedulerKind::Etf => Box::new(Etf),
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::graph::TaskGraph;

    /// Build a single-graph problem (no fixed preds) with arrival time 0.
    pub fn problem_from_graph(g: &TaskGraph, graph_idx: usize, arrival: f64) -> Problem {
        let mut tasks: Vec<PTask> = (0..g.n_tasks())
            .map(|t| PTask {
                gid: Gid::new(graph_idx, t),
                cost: g.cost(t),
                ready: arrival,
                preds: Vec::new(),
                succs: Vec::new(),
            })
            .collect();
        for t in 0..g.n_tasks() {
            for &(c, d) in g.successors(t) {
                tasks[t].succs.push((c, d));
                tasks[c].preds.push(Pred::Pending { idx: t, data: d });
            }
        }
        Problem { tasks }
    }
}
