//! CPOP — Critical Path On a Processor (Topcuoglu et al., 2002).
//!
//! Priority is `rank_u + rank_d`; the tasks whose priority equals the
//! entry task's (the critical path) are all pinned to the single node
//! minimizing the CP's total execution time; every other task takes its
//! min-EFT node.  On composite (multi-component) problems each component
//! gets its own critical path and its own CP node — the natural
//! generalization used here (documented in DESIGN.md §6).

use std::collections::BinaryHeap;

use crate::network::Network;
use crate::schedule::{Assignment, Slot, Timelines};

use super::common::{components, eft_on_node_cached, min_eft_cached, EftScratch, OrdF64};
use super::rank::RankProvider;
#[cfg(test)]
use super::Pred;
use super::{Problem, Scheduler};

/// Relative tolerance when testing priority equality along the CP.
/// Wide enough to absorb the f32 round-trip of the XLA rank provider
/// (ranks are bit-exact in f64 native mode, ~1e-7 relative in f32).
const CP_TOL: f64 = 1e-4;

pub struct Cpop<R: RankProvider> {
    ranks: R,
}

impl<R: RankProvider> Cpop<R> {
    pub fn new(ranks: R) -> Self {
        Self { ranks }
    }

    /// Mark the critical path of every component; returns (is_cp, cp_node
    /// per component).
    ///
    /// CP-node choice is load-aware across components: classic CPOP is a
    /// single-DAG algorithm, and naively taking the per-component argmin
    /// would pin *every* component's CP to the same node on homogeneous
    /// networks.  We process components by descending CP value and charge
    /// each chosen node with the CP's execution load (seeded with the
    /// committed busy time already on the timelines).
    fn critical_paths(
        &self,
        prob: &Problem,
        net: &Network,
        timelines: &Timelines,
        priority: &[f64],
        comp: &[usize],
    ) -> (Vec<bool>, Vec<usize>) {
        let n = prob.n_tasks();
        let n_comp = comp.iter().copied().max().map_or(0, |m| m + 1);
        let mut is_cp = vec![false; n];

        for c in 0..n_comp {
            // entry task of the component with the max priority
            let mut entry: Option<usize> = None;
            for i in 0..n {
                if comp[i] != c {
                    continue;
                }
                if prob.n_pending_preds(i) == 0 {
                    if entry.map_or(true, |e| priority[i] > priority[e]) {
                        entry = Some(i);
                    }
                }
            }
            let Some(mut cur) = entry else { continue };
            let cp_val = priority[cur];
            is_cp[cur] = true;
            // walk down through successors whose priority equals cp_val
            loop {
                let mut next: Option<usize> = None;
                for &s in prob.succs_of(cur).0 {
                    let s = s as usize;
                    if (priority[s] - cp_val).abs() <= CP_TOL * (1.0 + cp_val.abs()) {
                        next = Some(s);
                        break;
                    }
                }
                match next {
                    Some(s) => {
                        is_cp[s] = true;
                        cur = s;
                    }
                    None => break,
                }
            }
        }

        // CP node per component: argmin of summed exec time of CP tasks,
        // load-aware across components (largest CP first).
        //
        // §Perf: group CP tasks and cache per-component CP values/costs up
        // front — the earlier formulation rescanned all n tasks inside the
        // sort comparator and per (component × node), which dominated
        // P-CPOP runs on many-component composites.
        let mut cp_tasks: Vec<Vec<usize>> = vec![Vec::new(); n_comp];
        let mut cp_value = vec![0.0f64; n_comp];
        let mut cp_cost = vec![0.0f64; n_comp];
        for i in 0..n {
            if is_cp[i] {
                cp_tasks[comp[i]].push(i);
                cp_value[comp[i]] = cp_value[comp[i]].max(priority[i]);
                cp_cost[comp[i]] += prob.cost_col[i];
            }
        }
        let mut cp_node = vec![0usize; n_comp];
        let mut load: Vec<f64> = (0..net.n_nodes()).map(|v| timelines.busy_time(v)).collect();
        let mut comp_order: Vec<usize> = (0..n_comp).collect();
        comp_order.sort_by(|&a, &b| {
            cp_value[b].partial_cmp(&cp_value[a]).unwrap().then(a.cmp(&b))
        });
        for &c in &comp_order {
            let mut best = (f64::INFINITY, 0usize, 0.0f64);
            for v in 0..net.n_nodes() {
                // related machines: sum of c(t)/s(v) = cp_cost / s(v)
                let total = cp_cost[c] / net.speed(v);
                if load[v] + total < best.0 {
                    best = (load[v] + total, v, total);
                }
            }
            cp_node[c] = best.1;
            load[best.1] += best.2;
        }
        (is_cp, cp_node)
    }
}

impl<R: RankProvider> Scheduler for Cpop<R> {
    fn name(&self) -> String {
        if self.ranks.provider_name() == "native" {
            "CPOP".to_string()
        } else {
            format!("CPOP[{}]", self.ranks.provider_name())
        }
    }

    fn schedule(
        &mut self,
        prob: &Problem,
        net: &Network,
        timelines: &mut Timelines,
    ) -> Vec<Assignment> {
        let n = prob.n_tasks();
        let ranks = self.ranks.ranks(prob, net);
        let priority: Vec<f64> = (0..n).map(|i| ranks.up[i] + ranks.down[i]).collect();
        let comp = components(prob);
        let (is_cp, cp_node) = self.critical_paths(prob, net, timelines, &priority, &comp);

        let mut partial: Vec<Option<Assignment>> = vec![None; n];
        let mut missing: Vec<usize> = (0..n).map(|i| prob.n_pending_preds(i)).collect();
        let mut heap: BinaryHeap<(OrdF64, std::cmp::Reverse<crate::graph::Gid>, usize)> =
            BinaryHeap::new();
        for i in 0..n {
            if missing[i] == 0 {
                heap.push((OrdF64(priority[i]), std::cmp::Reverse(prob.gid_col[i]), i));
            }
        }

        let mut placed = 0;
        let mut scratch = EftScratch::new();
        while let Some((_, _, i)) = heap.pop() {
            scratch.load(prob, i, net, &partial);
            let a = if is_cp[i] {
                eft_on_node_cached(&scratch, prob, i, cp_node[comp[i]], net, timelines)
            } else {
                min_eft_cached(&scratch, prob, i, net, timelines)
            };
            timelines.insert(
                a.node,
                Slot {
                    start: a.start,
                    finish: a.finish,
                    gid: prob.gid_col[i],
                },
            );
            partial[i] = Some(a);
            placed += 1;
            for &c in prob.succs_of(i).0 {
                let c = c as usize;
                missing[c] -= 1;
                if missing[c] == 0 {
                    heap.push((OrdF64(priority[c]), std::cmp::Reverse(prob.gid_col[c]), c));
                }
            }
        }
        assert_eq!(placed, n, "CPOP failed to place every task");
        partial.into_iter().map(Option::unwrap).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedulers::rank::NativeRanks;
    use crate::schedulers::testutil::problem_from_graph;

    fn cpop() -> Cpop<NativeRanks> {
        Cpop::new(NativeRanks)
    }

    #[test]
    fn chain_is_fully_critical_and_pinned() {
        // A pure chain IS the critical path → every task lands on the
        // node minimizing total chain execution (the fast one), with zero
        // communication delay.
        let mut b = GraphBuilder::new("chain");
        let t0 = b.task(4.0);
        let t1 = b.task(6.0);
        let t2 = b.task(2.0);
        b.edge(t0, t1, 5.0).edge(t1, t2, 5.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::new(vec![1.0, 2.0], vec![0.0, 1.0, 1.0, 0.0]);
        let mut tl = Timelines::new(2);
        let out = cpop().schedule(&prob, &net, &mut tl);
        assert!(out.iter().all(|a| a.node == 1));
        assert_eq!(out[2].finish, 6.0); // (4+6+2)/2
    }

    #[test]
    fn off_path_tasks_use_min_eft() {
        // Diamond with one heavy branch: the light branch is off-CP and
        // should be placed by min-EFT (possibly another node).
        let mut b = GraphBuilder::new("d");
        let t0 = b.task(2.0);
        let heavy = b.task(20.0);
        let light = b.task(1.0);
        let t3 = b.task(2.0);
        b.edge(t0, heavy, 0.0)
            .edge(t0, light, 0.0)
            .edge(heavy, t3, 0.0)
            .edge(light, t3, 0.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(2);
        let mut tl = Timelines::new(2);
        let out = cpop().schedule(&prob, &net, &mut tl);
        // CP = {t0, heavy, t3} all on one node; light elsewhere (its EFT
        // there is earlier than queueing behind heavy).
        assert_eq!(out[0].node, out[1].node);
        assert_eq!(out[1].node, out[3].node);
        assert_ne!(out[2].node, out[1].node);
    }

    #[test]
    fn per_component_critical_paths() {
        // Two disconnected chains: each gets its own CP node; with a
        // 2-node network both chains can run in parallel.
        let mut b = GraphBuilder::new("two");
        let a0 = b.task(4.0);
        let a1 = b.task(4.0);
        b.edge(a0, a1, 10.0);
        let b0 = b.task(4.0);
        let b1 = b.task(4.0);
        b.edge(b0, b1, 10.0);
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::homogeneous(2);
        let mut tl = Timelines::new(2);
        let out = cpop().schedule(&prob, &net, &mut tl);
        assert_eq!(out[0].node, out[1].node);
        assert_eq!(out[2].node, out[3].node);
        // both chains finish at 8 — truly parallel
        assert!((out[1].finish - 8.0).abs() < 1e-9);
        assert!((out[3].finish - 8.0).abs() < 1e-9);
    }

    #[test]
    fn dependencies_hold_on_random_dag() {
        use crate::prng::Xoshiro256pp;
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        let mut b = GraphBuilder::new("rand");
        let n = 24;
        let ids: Vec<_> = (0..n).map(|_| b.task(rng.uniform(1.0, 10.0))).collect();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.next_f64() < 0.15 {
                    b.edge(ids[i], ids[j], rng.uniform(0.0, 5.0));
                }
            }
        }
        let prob = problem_from_graph(&b.build().unwrap(), 0, 0.0);
        let net = Network::new(
            vec![1.0, 2.0, 0.5],
            vec![0.0, 2.0, 1.0, 2.0, 0.0, 3.0, 1.0, 3.0, 0.0],
        );
        let mut tl = Timelines::new(3);
        let out = cpop().schedule(&prob, &net, &mut tl);
        for (i, t) in prob.tasks.iter().enumerate() {
            for p in &t.preds {
                if let Pred::Pending { idx, data } = *p {
                    let pa = out[idx];
                    let comm = net.comm_time(data, pa.node, out[i].node);
                    assert!(pa.finish + comm <= out[i].start + 1e-9);
                }
            }
        }
    }
}
