//! The dynamic scheduling coordinator — the paper's contribution (§IV).
//!
//! Task graphs arrive over (virtual) time.  On each arrival the
//! coordinator decides, per the configured [`Policy`], which previously
//! *Scheduled* (but not yet started) tasks are reverted to *Unscheduled*,
//! merges them with the new graph into a composite [`Problem`], and hands
//! it to the configured base heuristic.  Tasks whose start time precedes
//! the arrival are *Executing/Completed* and are never moved (Fig. 2 of
//! the paper: only `Scheduled -> Unscheduled` transitions exist).
//!
//! * [`Policy::Preemptive`] — revert every pending task (P-NAME).
//! * [`Policy::NonPreemptive`] — revert nothing (NP-NAME).
//! * [`Policy::LastK`] — revert pending tasks of the K most recently
//!   arrived graphs only (KP-NAME, the paper's Last-K model).

use std::sync::Arc;

use crate::dense::{DenseIds, DenseMap};
use crate::graph::{Gid, TaskGraph};
use crate::metrics::MetricRow;
use crate::network::Network;
use crate::schedule::{Schedule, EPS};
use crate::schedulers::{PTask, Pred, Problem, Scheduler, SchedulerKind};
use crate::telemetry;

/// Preemption policy (§IV).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Policy {
    NonPreemptive,
    Preemptive,
    /// Revert pending tasks of the `K` most recent earlier graphs.
    LastK(usize),
}

impl Policy {
    /// Paper notation: `NP`, `P`, `5P`, ...
    pub fn label(&self) -> String {
        match self {
            Policy::NonPreemptive => "NP".to_string(),
            Policy::Preemptive => "P".to_string(),
            Policy::LastK(k) => format!("{k}P"),
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "NP" | "np" => Some(Policy::NonPreemptive),
            "P" | "p" => Some(Policy::Preemptive),
            _ => {
                let t = s.strip_suffix(['P', 'p'])?;
                t.parse::<usize>().ok().map(Policy::LastK)
            }
        }
    }

    /// How many of the most recent earlier graphs are revertible on the
    /// arrival of graph `i` (0-based).  Shared with the reactive runtime
    /// simulator's arrival replans.
    pub(crate) fn window(&self, i: usize) -> usize {
        match self {
            Policy::NonPreemptive => 0,
            Policy::Preemptive => i,
            Policy::LastK(k) => (*k).min(i),
        }
    }
}

/// Observable lifecycle state of a task at a given instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    Unscheduled,
    Scheduled,
    Executing,
    Completed,
}

/// State of `gid` at time `now` under the current global schedule.
pub fn task_state(schedule: &Schedule, gid: Gid, now: f64) -> TaskState {
    match schedule.get(gid) {
        None => TaskState::Unscheduled,
        Some(a) if a.finish <= now + EPS => TaskState::Completed,
        Some(a) if a.start < now - EPS => TaskState::Executing,
        Some(_) => TaskState::Scheduled,
    }
}

/// A dynamic instance: graphs with sorted arrival times on a network.
#[derive(Clone, Debug)]
pub struct DynamicProblem {
    pub network: Network,
    pub graphs: Vec<(f64, TaskGraph)>,
}

impl DynamicProblem {
    pub fn new(network: Network, mut graphs: Vec<(f64, TaskGraph)>) -> Self {
        graphs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        Self { network, graphs }
    }

    pub fn total_tasks(&self) -> usize {
        self.graphs.iter().map(|(_, g)| g.n_tasks()).sum()
    }

    /// The `Gid ↔ DenseId` bijection over every task of every graph
    /// (§Perf, PR 6): built once per problem; the coordinator and the
    /// reactive runtime index flat arrays with it instead of hashing
    /// gids on the hot path.
    pub fn dense_ids(&self) -> Arc<DenseIds> {
        Arc::new(DenseIds::from_counts(
            self.graphs.iter().map(|(_, g)| g.n_tasks()),
        ))
    }
}

/// Per-arrival trace record.
#[derive(Clone, Copy, Debug)]
pub struct EventLog {
    pub graph_idx: usize,
    pub time: f64,
    /// tasks handed to the base heuristic at this event
    pub n_pending: usize,
    /// how many previously scheduled tasks were reverted
    pub n_reverted: usize,
    /// wall-clock seconds spent inside the base heuristic
    pub sched_runtime_s: f64,
}

/// Outcome of a full dynamic run.
#[derive(Clone, Debug)]
pub struct DynamicResult {
    pub schedule: Schedule,
    pub events: Vec<EventLog>,
    /// §V.E runtime: total scheduler wall time across all arrivals.
    pub sched_runtime_s: f64,
}

impl DynamicResult {
    pub fn metrics(&self, prob: &DynamicProblem) -> MetricRow {
        MetricRow::compute(
            &self.schedule,
            &prob.graphs,
            &prob.network,
            self.sched_runtime_s,
        )
    }
}

/// Reusable buffers for composite-problem assembly (§Perf).
///
/// [`Coordinator::run`] fires one composite build per arrival; with the
/// paper's 100-graph instances under full preemption that is 100 builds
/// of up-to-thousands-of-task problems.  The workspace keeps the task
/// vector (including every task's `preds`/`succs` allocations), the
/// pending-set buffer and the `Gid → index` map alive across arrivals,
/// so steady-state builds perform no heap allocation at all (pinned by
/// the `workspace_steady_state_allocates_nothing` test against the
/// counting allocator).  The produced [`Problem`] is bit-identical to
/// [`build_composite`]'s (see the `workspace_builder_matches_reference`
/// test).
///
/// §Perf (PR 6): the `Gid → composite index` lookup is an epoch-stamped
/// [`DenseMap`] over the problem's [`DenseIds`] universe instead of a
/// hash map — clearing it per arrival is one epoch bump, and each parent
/// probe is a flat array read.
#[derive(Default)]
pub struct CompositeWorkspace {
    pending: Vec<Gid>,
    ids: Arc<DenseIds>,
    index: DenseMap<u32>,
    problem: Problem,
}

impl CompositeWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// (Re)bind the dense-id universe to `prob` if the cached one does
    /// not already cover exactly its graphs.  Steady state (same problem
    /// across arrivals) is a cheap `matches` scan, no allocation.
    fn ensure_ids(&mut self, prob: &DynamicProblem) {
        if !self.ids.matches(prob.graphs.iter().map(|(_, g)| g.n_tasks())) {
            self.ids = Arc::new(DenseIds::from_counts(
                prob.graphs.iter().map(|(_, g)| g.n_tasks()),
            ));
        }
    }

    /// Assemble the composite [`Problem`] for `pending` in place: pending
    /// parents become [`Pred::Pending`], committed parents become
    /// [`Pred::Fixed`] constraints carrying their placement.
    pub fn build(
        &mut self,
        pending: &[Gid],
        prob: &DynamicProblem,
        schedule: &Schedule,
    ) -> &Problem {
        self.build_floored(pending, prob, schedule, f64::NEG_INFINITY)
    }

    /// [`build`](Self::build) with a **ready-time floor**: every pending
    /// task's ready time becomes `max(arrival, floor)`.  The reactive
    /// runtime passes the replan instant so the base heuristic can never
    /// place work in the (simulated) past; `build` passes `-∞`, which
    /// leaves the plan-time semantics bit-identical.
    pub fn build_floored(
        &mut self,
        pending: &[Gid],
        prob: &DynamicProblem,
        schedule: &Schedule,
        floor: f64,
    ) -> &Problem {
        self.ensure_ids(prob);
        self.index.reset(self.ids.len());
        for (i, &g) in pending.iter().enumerate() {
            self.index.insert(self.ids.ix(g), i as u32);
        }

        let tasks = &mut self.problem.tasks;
        tasks.truncate(pending.len());
        while tasks.len() < pending.len() {
            tasks.push(PTask {
                gid: Gid::new(0, 0),
                cost: 0.0,
                ready: 0.0,
                preds: Vec::new(),
                succs: Vec::new(),
            });
        }
        for (i, &gid) in pending.iter().enumerate() {
            let (arrival, g) = &prob.graphs[gid.graph as usize];
            let t = &mut tasks[i];
            t.gid = gid;
            t.cost = g.cost(gid.task as usize);
            t.ready = arrival.max(floor);
            t.preds.clear();
            t.succs.clear();
        }

        for ci in 0..pending.len() {
            let gid = pending[ci];
            let g = &prob.graphs[gid.graph as usize].1;
            for &(p, data) in g.predecessors(gid.task as usize) {
                let pgid = Gid::new(gid.graph as usize, p);
                if let Some(&pidx) = self.index.get(self.ids.ix(pgid)) {
                    let pidx = pidx as usize;
                    tasks[ci].preds.push(Pred::Pending { idx: pidx, data });
                    tasks[pidx].succs.push((ci, data));
                } else {
                    let a = schedule
                        .get(pgid)
                        .expect("parent neither pending nor committed");
                    tasks[ci].preds.push(Pred::Fixed {
                        node: a.node,
                        finish: a.finish,
                        data,
                    });
                }
            }
        }

        // refresh the derived CSR/SoA views (clear-and-push into retained
        // capacity — no steady-state allocation)
        self.problem.rebuild_views();
        &self.problem
    }
}

/// The dynamic coordinator: a policy wrapped around a base heuristic.
pub struct Coordinator {
    pub policy: Policy,
    scheduler: Box<dyn Scheduler>,
    ws: CompositeWorkspace,
}

impl Coordinator {
    pub fn new(policy: Policy, scheduler: Box<dyn Scheduler>) -> Self {
        Self {
            policy,
            scheduler,
            ws: CompositeWorkspace::new(),
        }
    }

    pub fn label(&self) -> String {
        format!("{}-{}", self.policy.label(), self.scheduler.name())
    }

    /// Run the arrival loop over the whole problem.
    ///
    /// §Perf hot path: the composite problem is assembled into the
    /// coordinator's persistent [`CompositeWorkspace`], and the base
    /// heuristic runs **in place** on the master schedule's timelines
    /// inside an insertion-journal transaction ([`Timelines::begin_txn`])
    /// instead of on a full clone — an NP/Last-K arrival therefore pays
    /// O(slots it touches), not O(every slot scheduled so far).  The
    /// §V.E timed region still covers the base heuristic's own work
    /// (slot insertion included, as before) and none of the build/merge
    /// bookkeeping; the only addition inside it is one journal push per
    /// inserted slot (a `Vec` append into a buffer retained across
    /// arrivals — the price of keeping [`Timelines::rollback_txn`]
    /// available to speculative/what-if callers and of the debug guard
    /// against removals mid-schedule).
    pub fn run(&mut self, prob: &DynamicProblem) -> DynamicResult {
        let n_nodes = prob.network.n_nodes();
        // dense-backed schedule: assignment lookups on the revert scan and
        // the Fixed-parent probes are flat array reads, not gid hashes
        let mut schedule = Schedule::new_dense(n_nodes, prob.dense_ids());
        let mut events = Vec::with_capacity(prob.graphs.len());
        let mut total_rt = 0.0;

        for i in 0..prob.graphs.len() {
            let (arrival, _) = prob.graphs[i];

            // 1. revert pending tasks of graphs inside the policy window
            let window = self.policy.window(i);
            self.ws.pending.clear();
            let mut pending = std::mem::take(&mut self.ws.pending);
            for j in (i - window)..i {
                let g = &prob.graphs[j].1;
                for t in 0..g.n_tasks() {
                    let gid = Gid::new(j, t);
                    if let Some(a) = schedule.get(gid) {
                        // strictly-started tasks are committed
                        if a.start >= arrival - EPS {
                            schedule.unassign(gid);
                            pending.push(gid);
                        }
                    }
                }
            }
            let n_reverted = pending.len();

            // 2. the new graph's tasks are all pending
            let g_new = &prob.graphs[i].1;
            for t in 0..g_new.n_tasks() {
                pending.push(Gid::new(i, t));
            }

            // 3. build the composite problem into the reusable workspace
            let problem = self.ws.build(&pending, prob, &schedule);

            // 4. run the base heuristic in place, timed (§V.E); the span
            // lands the reading in the telemetry histogram too
            schedule.timelines_mut().begin_txn();
            let span = telemetry::Span::start(telemetry::Hist::HeuristicWallNs);
            let assignments =
                self.scheduler
                    .schedule(problem, &prob.network, schedule.timelines_mut());
            let dt = span.finish();
            total_rt += dt;

            // 5. record the new placements (their slots are already in the
            // timelines) and keep them
            for (idx, a) in assignments.iter().enumerate() {
                schedule.record(problem.tasks[idx].gid, *a);
            }
            let n_pending = problem.n_tasks();
            schedule.timelines_mut().commit_txn();
            self.ws.pending = pending;

            events.push(EventLog {
                graph_idx: i,
                time: arrival,
                n_pending,
                n_reverted,
                sched_runtime_s: dt,
            });
        }

        DynamicResult {
            schedule,
            events,
            sched_runtime_s: total_rt,
        }
    }
}

/// Public variant of [`build_composite`] for analysis tools: treat the
/// given task set as entirely pending (no committed placements).
pub fn composite_of(pending: &[Gid], prob: &DynamicProblem) -> Problem {
    let empty = Schedule::new(prob.network.n_nodes());
    build_composite(pending, prob, &empty)
}

/// Assemble a fresh composite [`Problem`] for the given pending set:
/// pending parents become [`Pred::Pending`], committed parents become
/// [`Pred::Fixed`] constraints carrying their placement.
///
/// This is the allocating reference builder, kept for cold paths
/// ([`composite_of`]) and as the differential-testing oracle for
/// [`CompositeWorkspace::build`], which produces identical problems
/// without reallocating per arrival.  `pub` (hidden) so integration
/// tests can differential-test the dense layout against it.
#[doc(hidden)]
pub fn build_composite(pending: &[Gid], prob: &DynamicProblem, schedule: &Schedule) -> Problem {
    let index: crate::fasthash::FxHashMap<Gid, usize> =
        pending.iter().enumerate().map(|(i, &g)| (g, i)).collect();

    let mut tasks: Vec<PTask> = pending
        .iter()
        .map(|&gid| {
            let (arrival, g) = &prob.graphs[gid.graph as usize];
            PTask {
                gid,
                cost: g.cost(gid.task as usize),
                ready: *arrival,
                preds: Vec::new(),
                succs: Vec::new(),
            }
        })
        .collect();

    for ci in 0..pending.len() {
        let gid = pending[ci];
        let g = &prob.graphs[gid.graph as usize].1;
        let preds: Vec<(usize, f64)> = g.predecessors(gid.task as usize).to_vec();
        for (p, data) in preds {
            let pgid = Gid::new(gid.graph as usize, p);
            if let Some(&pidx) = index.get(&pgid) {
                tasks[ci].preds.push(Pred::Pending { idx: pidx, data });
                tasks[pidx].succs.push((ci, data));
            } else {
                let a = schedule
                    .get(pgid)
                    .expect("parent neither pending nor committed");
                tasks[ci].preds.push(Pred::Fixed {
                    node: a.node,
                    finish: a.finish,
                    data,
                });
            }
        }
    }

    Problem::from_tasks(tasks)
}

/// The pre-workspace coordinator loop (fresh composite allocation + full
/// timeline clone + map-backed schedule + assign-based merge), kept
/// verbatim as the differential oracle for the zero-realloc in-place hot
/// path and for the dense-id/CSR layout (`layout_dense` integration
/// test, `layout` bench A/B rows).  Returns the final schedule plus
/// `(n_pending, n_reverted)` per arrival.
#[doc(hidden)]
pub fn run_reference(
    policy: Policy,
    mut scheduler: Box<dyn Scheduler>,
    prob: &DynamicProblem,
) -> (Schedule, Vec<(usize, usize)>) {
    let mut schedule = Schedule::new(prob.network.n_nodes());
    let mut events = Vec::new();
    for i in 0..prob.graphs.len() {
        let (arrival, _) = prob.graphs[i];
        let window = policy.window(i);
        let mut pending: Vec<Gid> = Vec::new();
        for j in (i - window)..i {
            let g = &prob.graphs[j].1;
            for t in 0..g.n_tasks() {
                let gid = Gid::new(j, t);
                if let Some(a) = schedule.get(gid) {
                    if a.start >= arrival - EPS {
                        schedule.unassign(gid);
                        pending.push(gid);
                    }
                }
            }
        }
        let n_reverted = pending.len();
        let g_new = &prob.graphs[i].1;
        for t in 0..g_new.n_tasks() {
            pending.push(Gid::new(i, t));
        }
        let problem = build_composite(&pending, prob, &schedule);
        let mut scratch = schedule.timelines().clone();
        let assignments = scheduler.schedule(&problem, &prob.network, &mut scratch);
        for (idx, a) in assignments.iter().enumerate() {
            schedule.assign(problem.tasks[idx].gid, *a);
        }
        events.push((problem.n_tasks(), n_reverted));
    }
    (schedule, events)
}

// --------------------------------------------------------------- variants

/// One cell of the paper's scheduler grid, e.g. `5P-HEFT`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Variant {
    pub policy: Policy,
    pub kind: SchedulerKind,
}

impl Variant {
    pub fn label(&self) -> String {
        format!("{}-{}", self.policy.label(), self.kind.name())
    }

    /// Parse labels like `NP-HEFT`, `P-CPOP`, `5P-MinMin`.
    pub fn parse(s: &str) -> Option<Variant> {
        let (pol, kind) = s.split_once('-')?;
        Some(Variant {
            policy: Policy::parse(pol)?,
            kind: SchedulerKind::parse(kind)?,
        })
    }

    pub fn coordinator(&self, seed: u64) -> Coordinator {
        Coordinator::new(self.policy, self.kind.make(seed))
    }
}

/// The grid evaluated throughout §VII: {NP, 2P, 5P, 10P, 20P, P} × the
/// five base heuristics.
pub fn paper_grid() -> Vec<Variant> {
    let policies = [
        Policy::NonPreemptive,
        Policy::LastK(2),
        Policy::LastK(5),
        Policy::LastK(10),
        Policy::LastK(20),
        Policy::Preemptive,
    ];
    let mut out = Vec::new();
    for kind in SchedulerKind::ALL {
        for p in policies {
            out.push(Variant { policy: p, kind });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedule::{validate, Assignment};

    fn chain_graph(name: &str, costs: &[f64], data: f64) -> TaskGraph {
        let mut b = GraphBuilder::new(name);
        let ids: Vec<_> = costs.iter().map(|&c| b.task(c)).collect();
        for w in ids.windows(2) {
            b.edge(w[0], w[1], data);
        }
        b.build().unwrap()
    }

    fn two_graph_problem() -> DynamicProblem {
        DynamicProblem::new(
            Network::homogeneous(2),
            vec![
                (0.0, chain_graph("g0", &[4.0, 4.0, 4.0], 0.0)),
                (2.0, chain_graph("g1", &[1.0, 1.0], 0.0)),
            ],
        )
    }

    fn run(policy: Policy, prob: &DynamicProblem) -> DynamicResult {
        let mut c = Coordinator::new(policy, SchedulerKind::Heft.make(0));
        c.run(prob)
    }

    #[test]
    fn all_policies_produce_valid_schedules() {
        let prob = two_graph_problem();
        for policy in [
            Policy::NonPreemptive,
            Policy::Preemptive,
            Policy::LastK(1),
            Policy::LastK(5),
        ] {
            let res = run(policy, &prob);
            assert_eq!(res.schedule.n_assigned(), prob.total_tasks());
            let viol = validate(&res.schedule, &prob.graphs, &prob.network);
            assert!(viol.is_empty(), "{policy:?}: {viol:?}");
        }
    }

    #[test]
    fn np_never_moves_earlier_assignments() {
        let prob = two_graph_problem();
        // run g0 alone to know its undisturbed placement
        let solo = run(
            Policy::NonPreemptive,
            &DynamicProblem::new(prob.network.clone(), vec![prob.graphs[0].clone()]),
        );
        let both = run(Policy::NonPreemptive, &prob);
        for t in 0..prob.graphs[0].1.n_tasks() {
            let gid = Gid::new(0, t);
            assert_eq!(
                solo.schedule.get(gid),
                both.schedule.get(gid),
                "NP must keep g0's placement"
            );
        }
    }

    #[test]
    fn preemptive_reverts_unstarted_only() {
        // g0: 3-task chain on 2 nodes; second arrival at t=2 means g0's
        // first task (start 0) is executing, the rest are revertible.
        let prob = two_graph_problem();
        let res = run(Policy::Preemptive, &prob);
        assert_eq!(res.events.len(), 2);
        let e1 = res.events[1];
        assert!(e1.n_reverted <= 2, "only unstarted tasks revert: {e1:?}");
        // g0 t0 must still start at 0 (it was executing)
        assert_eq!(res.schedule.get(Gid::new(0, 0)).unwrap().start, 0.0);
    }

    #[test]
    fn last0_equals_np_and_large_k_equals_p() {
        // exhaustive equality of final schedules across several workloads
        for seed in 0..5u64 {
            let mut rng = crate::prng::Xoshiro256pp::seed_from_u64(seed);
            let graphs: Vec<(f64, TaskGraph)> = (0..6)
                .map(|i| {
                    let costs: Vec<f64> =
                        (0..4).map(|_| rng.uniform(1.0, 8.0)).collect();
                    (i as f64 * 1.5, chain_graph(&format!("g{i}"), &costs, 1.0))
                })
                .collect();
            let prob = DynamicProblem::new(Network::homogeneous(3), graphs);

            let sig = |r: &DynamicResult| {
                let mut v: Vec<(Gid, usize, u64)> = r
                    .schedule
                    .iter()
                    .map(|(g, a)| (*g, a.node, a.start.to_bits()))
                    .collect();
                v.sort();
                v
            };
            let np = run(Policy::NonPreemptive, &prob);
            let k0 = run(Policy::LastK(0), &prob);
            assert_eq!(sig(&np), sig(&k0), "K=0 ≡ NP (seed {seed})");

            let p = run(Policy::Preemptive, &prob);
            let kbig = run(Policy::LastK(100), &prob);
            assert_eq!(sig(&p), sig(&kbig), "K≥i ≡ P (seed {seed})");
        }
    }

    #[test]
    fn dependencies_hold_under_every_policy() {
        let prob = two_graph_problem();
        for policy in [Policy::Preemptive, Policy::LastK(1), Policy::NonPreemptive] {
            let res = run(policy, &prob);
            for (gi, (_, g)) in prob.graphs.iter().enumerate() {
                for t in 0..g.n_tasks() {
                    for &(c, _) in g.successors(t) {
                        let at = res.schedule.get(Gid::new(gi, t)).unwrap();
                        let ac = res.schedule.get(Gid::new(gi, c)).unwrap();
                        assert!(at.finish <= ac.start + EPS);
                    }
                }
            }
        }
    }

    #[test]
    fn tasks_never_start_before_arrival() {
        let prob = two_graph_problem();
        for policy in [Policy::NonPreemptive, Policy::Preemptive, Policy::LastK(1)] {
            let res = run(policy, &prob);
            for (gi, (arrival, g)) in prob.graphs.iter().enumerate() {
                for t in 0..g.n_tasks() {
                    let a = res.schedule.get(Gid::new(gi, t)).unwrap();
                    assert!(a.start >= arrival - EPS);
                }
            }
        }
    }

    /// Random DAG collection with Poisson-ish arrivals for property tests.
    fn random_problem(seed: u64, n_graphs: usize, n_nodes: usize) -> DynamicProblem {
        let mut rng = crate::prng::Xoshiro256pp::seed_from_u64(seed);
        let graphs: Vec<(f64, TaskGraph)> = (0..n_graphs)
            .map(|i| {
                let n = rng.int_range(2, 8);
                let mut b = GraphBuilder::new(&format!("g{i}"));
                let ids: Vec<_> = (0..n).map(|_| b.task(rng.uniform(0.5, 9.0))).collect();
                for a in 0..n {
                    for c in (a + 1)..n {
                        if rng.next_f64() < 0.3 {
                            b.edge(ids[a], ids[c], rng.uniform(0.0, 4.0));
                        }
                    }
                }
                (i as f64 * rng.uniform(0.5, 2.5), b.build().unwrap())
            })
            .collect();
        let dist = crate::stats::TruncatedGaussian::new(1.0, 0.3, 0.4, 2.0);
        let net = Network::generate(n_nodes, &dist, &dist, &mut rng);
        DynamicProblem::new(net, graphs)
    }

    fn assignment_sig(s: &Schedule) -> Vec<(Gid, usize, u64, u64)> {
        let mut v: Vec<(Gid, usize, u64, u64)> = s
            .iter()
            .map(|(g, a)| (*g, a.node, a.start.to_bits(), a.finish.to_bits()))
            .collect();
        v.sort();
        v
    }

    #[test]
    fn workspace_builder_matches_reference() {
        // Bit-identical composite problems from the reusable workspace,
        // including across rebuilds that shrink and grow the task set.
        let prob = random_problem(11, 6, 3);
        let mut schedule = Schedule::new(3);
        // commit graph 0 entirely at fabricated placements so later
        // graphs see Fixed parents
        for t in 0..prob.graphs[0].1.n_tasks() {
            schedule.assign(
                Gid::new(0, t),
                Assignment {
                    node: t % 3,
                    start: 10.0 * t as f64,
                    finish: 10.0 * t as f64 + 1.0,
                },
            );
        }
        let all_of = |j: usize| -> Vec<Gid> {
            (0..prob.graphs[j].1.n_tasks())
                .map(|t| Gid::new(j, t))
                .collect()
        };
        let mut ws = CompositeWorkspace::new();
        // large pending set (graphs 1..6), then a smaller one (graph 2),
        // then large again — exercises truncate + regrow reuse
        let big: Vec<Gid> = (1..6).flat_map(|j| all_of(j)).collect();
        let small: Vec<Gid> = all_of(2);
        for pending in [&big, &small, &big] {
            let reference = build_composite(pending, &prob, &schedule);
            let fast = ws.build(pending, &prob, &schedule);
            assert_eq!(fast, &reference);
        }
    }

    #[test]
    fn workspace_steady_state_allocates_nothing() {
        // Satellite pin (PR 6): once warm, a composite rebuild on the
        // workspace path performs ZERO heap allocations — the arenas,
        // SoA columns, pred/succ vectors, and the epoch-stamped index
        // all reuse retained capacity.  Counted by the thread-local
        // counting allocator registered under cfg(test).
        use crate::alloc_count::alloc_count;
        let prob = random_problem(7, 6, 3);
        let schedule = Schedule::new(3);
        let pending: Vec<Gid> = (0..prob.graphs.len())
            .flat_map(|j| {
                (0..prob.graphs[j].1.n_tasks()).map(move |t| Gid::new(j, t))
            })
            .collect();
        let mut ws = CompositeWorkspace::new();
        // warm builds: grow every retained buffer to its high-water mark
        ws.build(&pending, &prob, &schedule);
        ws.build(&pending, &prob, &schedule);
        let before = alloc_count();
        ws.build(&pending, &prob, &schedule);
        let delta = alloc_count() - before;
        assert_eq!(delta, 0, "steady-state composite build allocated {delta}x");
    }

    #[test]
    fn inplace_run_matches_reference_coordinator() {
        // Full-run differential test: the zero-realloc in-place hot path
        // must produce bit-identical schedules and event shapes to the
        // old clone-per-arrival coordinator, for every policy × base
        // heuristic (extension baselines included) on random workloads.
        let policies = [
            Policy::NonPreemptive,
            Policy::LastK(1),
            Policy::LastK(3),
            Policy::Preemptive,
        ];
        for seed in 0..3u64 {
            let prob = random_problem(100 + seed, 7, 3);
            for kind in SchedulerKind::EXTENDED {
                for policy in policies {
                    let (ref_schedule, ref_events) =
                        run_reference(policy, kind.make(42), &prob);
                    let mut c = Coordinator::new(policy, kind.make(42));
                    let res = c.run(&prob);
                    assert_eq!(
                        assignment_sig(&res.schedule),
                        assignment_sig(&ref_schedule),
                        "schedule diverged: seed {seed}, {policy:?}-{}",
                        kind.name()
                    );
                    let new_events: Vec<(usize, usize)> = res
                        .events
                        .iter()
                        .map(|e| (e.n_pending, e.n_reverted))
                        .collect();
                    assert_eq!(
                        new_events,
                        ref_events,
                        "events diverged: seed {seed}, {policy:?}-{}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn policy_parse_and_labels() {
        assert_eq!(Policy::parse("NP"), Some(Policy::NonPreemptive));
        assert_eq!(Policy::parse("P"), Some(Policy::Preemptive));
        assert_eq!(Policy::parse("5P"), Some(Policy::LastK(5)));
        assert_eq!(Policy::parse("20p"), Some(Policy::LastK(20)));
        assert_eq!(Policy::parse("xP"), None);
        assert_eq!(Policy::LastK(5).label(), "5P");
        let v = Variant::parse("5P-MinMin").unwrap();
        assert_eq!(v.label(), "5P-MinMin");
        assert_eq!(Variant::parse("NP-HEFT").unwrap().label(), "NP-HEFT");
        assert_eq!(Variant::parse("banana"), None);
    }

    #[test]
    fn paper_grid_is_30_variants() {
        let grid = paper_grid();
        assert_eq!(grid.len(), 30);
        let labels: std::collections::HashSet<String> =
            grid.iter().map(|v| v.label()).collect();
        assert_eq!(labels.len(), 30);
        assert!(labels.contains("5P-HEFT"));
        assert!(labels.contains("NP-Random"));
    }

    #[test]
    fn task_state_transitions() {
        let mut s = Schedule::new(1);
        let gid = Gid::new(0, 0);
        assert_eq!(task_state(&s, gid, 0.0), TaskState::Unscheduled);
        s.assign(gid, Assignment { node: 0, start: 5.0, finish: 8.0 });
        assert_eq!(task_state(&s, gid, 1.0), TaskState::Scheduled);
        assert_eq!(task_state(&s, gid, 6.0), TaskState::Executing);
        assert_eq!(task_state(&s, gid, 9.0), TaskState::Completed);
    }

    #[test]
    fn runtime_accounting_accumulates() {
        let prob = two_graph_problem();
        let res = run(Policy::Preemptive, &prob);
        let sum: f64 = res.events.iter().map(|e| e.sched_runtime_s).sum();
        assert!((res.sched_runtime_s - sum).abs() < 1e-12);
        assert!(res.sched_runtime_s > 0.0);
    }

    #[test]
    fn preemption_can_improve_makespan_on_blocking_pattern() {
        // The paper's Fig. 1 story: small tasks from an earlier graph
        // block a later graph's huge root under NP.
        let mut rng = crate::prng::Xoshiro256pp::seed_from_u64(3);
        // g0: many small independent tasks
        let mut b = GraphBuilder::new("small");
        for _ in 0..12 {
            b.task(rng.uniform(0.5, 1.5));
        }
        let g0 = b.build().unwrap();
        // g1: huge root then small successors
        let mut b = GraphBuilder::new("spiky");
        let root = b.task(30.0);
        for _ in 0..8 {
            let t = b.task(0.5);
            b.edge(root, t, 0.1);
        }
        let g1 = b.build().unwrap();
        let prob = DynamicProblem::new(
            Network::homogeneous(3),
            vec![(0.0, g0), (0.5, g1)],
        );
        let p = run(Policy::Preemptive, &prob).metrics(&prob);
        let np = run(Policy::NonPreemptive, &prob).metrics(&prob);
        assert!(
            p.total_makespan <= np.total_makespan + 1e-9,
            "P ({}) should not lose to NP ({}) here",
            p.total_makespan,
            np.total_makespan
        );
    }
}
