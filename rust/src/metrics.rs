//! The paper's §V evaluation metrics.
//!
//! All metrics are computed from a finished global [`Schedule`] plus the
//! graph collection with arrival times; scheduler *runtime* is measured by
//! the dynamic coordinator and carried in its result struct.

use crate::graph::{Gid, TaskGraph};
use crate::network::Network;
use crate::schedule::Schedule;

/// §V.A — time from the first graph's arrival to the last task's finish:
/// `max e(t) - min a_i`.
pub fn total_makespan(schedule: &Schedule, problem: &[(f64, TaskGraph)]) -> f64 {
    let first_arrival = problem
        .iter()
        .map(|(a, _)| *a)
        .fold(f64::INFINITY, f64::min);
    let max_finish = schedule
        .iter()
        .map(|(_, a)| a.finish)
        .fold(f64::NEG_INFINITY, f64::max);
    if max_finish.is_finite() && first_arrival.is_finite() {
        max_finish - first_arrival
    } else {
        0.0
    }
}

/// §V.B — per-graph responsiveness:
/// `(1/K) Σ_i ( max_{t∈T_i} e(t) − a_i )`.
pub fn mean_makespan(schedule: &Schedule, problem: &[(f64, TaskGraph)]) -> f64 {
    if problem.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for (gi, (arrival, g)) in problem.iter().enumerate() {
        let finish = (0..g.n_tasks())
            .filter_map(|t| schedule.get(Gid::new(gi, t)))
            .map(|a| a.finish)
            .fold(f64::NEG_INFINITY, f64::max);
        if finish.is_finite() {
            acc += finish - arrival;
        }
    }
    acc / problem.len() as f64
}

/// §V.C — fairness / compactness:
/// `(1/K) Σ_i ( max_{t∈T_i} e(t) − min_{t'∈T_i} r(t') )`.
pub fn mean_flowtime(schedule: &Schedule, problem: &[(f64, TaskGraph)]) -> f64 {
    if problem.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for (gi, (_, g)) in problem.iter().enumerate() {
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for t in 0..g.n_tasks() {
            if let Some(a) = schedule.get(Gid::new(gi, t)) {
                lo = lo.min(a.start);
                hi = hi.max(a.finish);
            }
        }
        if hi.is_finite() && lo.is_finite() {
            acc += hi - lo;
        }
    }
    acc / problem.len() as f64
}

/// §V.D — per-node utilization `u(v) = busy(v) / max e(t)` (the paper
/// normalizes by the latest completion over all tasks).
pub fn node_utilization(
    schedule: &Schedule,
    problem: &[(f64, TaskGraph)],
    network: &Network,
) -> Vec<f64> {
    let span = schedule
        .iter()
        .map(|(_, a)| a.finish)
        .fold(f64::NEG_INFINITY, f64::max);
    let mut out = vec![0.0; network.n_nodes()];
    if !span.is_finite() || span <= 0.0 {
        return out;
    }
    let _ = problem; // node busy time already lives in the timelines
    for v in 0..network.n_nodes() {
        out[v] = schedule.timelines().busy_time(v) / span;
    }
    out
}

/// Mean of [`node_utilization`] across nodes — the Figure 7/8e quantity.
pub fn mean_utilization(
    schedule: &Schedule,
    problem: &[(f64, TaskGraph)],
    network: &Network,
) -> f64 {
    let u = node_utilization(schedule, problem, network);
    if u.is_empty() {
        0.0
    } else {
        u.iter().sum::<f64>() / u.len() as f64
    }
}

/// A full metric row for one (workload, scheduler) run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricRow {
    pub total_makespan: f64,
    pub mean_makespan: f64,
    pub mean_flowtime: f64,
    pub mean_utilization: f64,
    /// scheduler wall-clock runtime in seconds (§V.E), filled by the
    /// dynamic coordinator.
    pub runtime_s: f64,
}

impl MetricRow {
    pub fn compute(
        schedule: &Schedule,
        problem: &[(f64, TaskGraph)],
        network: &Network,
        runtime_s: f64,
    ) -> Self {
        Self {
            total_makespan: total_makespan(schedule, problem),
            mean_makespan: mean_makespan(schedule, problem),
            mean_flowtime: mean_flowtime(schedule, problem),
            mean_utilization: mean_utilization(schedule, problem, network),
            runtime_s,
        }
    }

    pub fn get(&self, metric: Metric) -> f64 {
        match metric {
            Metric::TotalMakespan => self.total_makespan,
            Metric::MeanMakespan => self.mean_makespan,
            Metric::MeanFlowtime => self.mean_flowtime,
            Metric::Utilization => self.mean_utilization,
            Metric::Runtime => self.runtime_s,
        }
    }
}

/// Metric selector used by the experiment harness / normalization.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    TotalMakespan,
    MeanMakespan,
    MeanFlowtime,
    Utilization,
    Runtime,
}

impl Metric {
    pub const ALL: [Metric; 5] = [
        Metric::TotalMakespan,
        Metric::MeanMakespan,
        Metric::MeanFlowtime,
        Metric::Utilization,
        Metric::Runtime,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Metric::TotalMakespan => "total_makespan",
            Metric::MeanMakespan => "mean_makespan",
            Metric::MeanFlowtime => "mean_flowtime",
            Metric::Utilization => "utilization",
            Metric::Runtime => "runtime",
        }
    }

    /// Whether *smaller* is better (normalization divides by the best).
    pub fn lower_is_better(&self) -> bool {
        !matches!(self, Metric::Utilization)
    }
}

/// Normalize a set of values for one metric: divide by the best value
/// (min for lower-is-better, max for utilization), so the best variant
/// reads 1.0 — the convention of the paper's "Normalized ..." figures.
pub fn normalize(metric: Metric, values: &[f64]) -> Vec<f64> {
    if values.is_empty() {
        return Vec::new();
    }
    let best = if metric.lower_is_better() {
        values.iter().copied().fold(f64::INFINITY, f64::min)
    } else {
        values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    };
    if best == 0.0 || !best.is_finite() {
        return values.to_vec();
    }
    if metric.lower_is_better() {
        values.iter().map(|v| v / best).collect()
    } else {
        values.iter().map(|v| v / best).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedule::Assignment;

    /// Two single-task graphs arriving at 0 and 10, on a 2-node
    /// homogeneous network.
    fn setup() -> (Schedule, Vec<(f64, TaskGraph)>, Network) {
        let g1 = {
            let mut b = GraphBuilder::new("g1");
            b.task(4.0);
            b.build().unwrap()
        };
        let g2 = {
            let mut b = GraphBuilder::new("g2");
            let a = b.task(2.0);
            let c = b.task(2.0);
            b.edge(a, c, 0.0);
            b.build().unwrap()
        };
        let net = Network::homogeneous(2);
        let mut s = Schedule::new(2);
        // g1 t0 on node 0: [0, 4]
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 0.0, finish: 4.0 });
        // g2 t0 on node 1: [10, 12], t1 on node 1: [14, 16] (gap of 2)
        s.assign(Gid::new(1, 0), Assignment { node: 1, start: 10.0, finish: 12.0 });
        s.assign(Gid::new(1, 1), Assignment { node: 1, start: 14.0, finish: 16.0 });
        (s, vec![(0.0, g1), (10.0, g2)], net)
    }

    #[test]
    fn total_makespan_spans_first_arrival_to_last_finish() {
        let (s, p, _) = setup();
        assert_eq!(total_makespan(&s, &p), 16.0);
    }

    #[test]
    fn mean_makespan_is_arrival_relative() {
        let (s, p, _) = setup();
        // g1: 4 - 0 = 4; g2: 16 - 10 = 6 → mean 5
        assert_eq!(mean_makespan(&s, &p), 5.0);
    }

    #[test]
    fn mean_flowtime_is_start_relative() {
        let (s, p, _) = setup();
        // g1: 4 - 0 = 4; g2: 16 - 10 = 6 → 5 (same here because g2's first
        // start equals its arrival)
        assert_eq!(mean_flowtime(&s, &p), 5.0);
    }

    #[test]
    fn utilization_counts_busy_over_span() {
        let (s, p, net) = setup();
        let u = node_utilization(&s, &p, &net);
        // span = 16; node0 busy 4, node1 busy 4
        assert!((u[0] - 0.25).abs() < 1e-12);
        assert!((u[1] - 0.25).abs() < 1e-12);
        assert!((mean_utilization(&s, &p, &net) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_schedule_yields_zeroes() {
        let s = Schedule::new(2);
        let p: Vec<(f64, TaskGraph)> = Vec::new();
        assert_eq!(total_makespan(&s, &p), 0.0);
        assert_eq!(mean_makespan(&s, &p), 0.0);
        assert_eq!(mean_flowtime(&s, &p), 0.0);
    }

    #[test]
    fn metric_row_and_selectors() {
        let (s, p, net) = setup();
        let row = MetricRow::compute(&s, &p, &net, 0.5);
        assert_eq!(row.get(Metric::TotalMakespan), 16.0);
        assert_eq!(row.get(Metric::Runtime), 0.5);
        assert_eq!(Metric::Utilization.lower_is_better(), false);
        assert_eq!(Metric::TotalMakespan.lower_is_better(), true);
        assert_eq!(Metric::ALL.len(), 5);
    }

    #[test]
    fn normalization_best_is_one() {
        let vals = vec![10.0, 20.0, 15.0];
        let n = normalize(Metric::TotalMakespan, &vals);
        assert_eq!(n, vec![1.0, 2.0, 1.5]);
        // utilization: higher is better → max maps to 1, others < 1
        let u = normalize(Metric::Utilization, &[0.5, 0.25]);
        assert_eq!(u, vec![1.0, 0.5]);
    }
}
