//! The paper's §V evaluation metrics.
//!
//! All metrics are computed from a finished global [`Schedule`] plus the
//! graph collection with arrival times; scheduler *runtime* is measured by
//! the dynamic coordinator and carried in its result struct.

use crate::graph::{Gid, TaskGraph};
use crate::network::Network;
use crate::schedule::Schedule;

/// §V.A — time from the first graph's arrival to the last task's finish:
/// `max e(t) - min a_i`.
pub fn total_makespan(schedule: &Schedule, problem: &[(f64, TaskGraph)]) -> f64 {
    let first_arrival = problem
        .iter()
        .map(|(a, _)| *a)
        .fold(f64::INFINITY, f64::min);
    let max_finish = schedule
        .iter()
        .map(|(_, a)| a.finish)
        .fold(f64::NEG_INFINITY, f64::max);
    if max_finish.is_finite() && first_arrival.is_finite() {
        max_finish - first_arrival
    } else {
        0.0
    }
}

/// Latest finish among graph `gi`'s scheduled tasks (`None` when none
/// of its tasks is scheduled) — the `f_i` shared by every per-graph
/// axis, defined once so the makespan, stretch and deadline metrics can
/// never disagree on which graphs contribute.
fn graph_finish(schedule: &Schedule, gi: usize, g: &TaskGraph) -> Option<f64> {
    let fin = (0..g.n_tasks())
        .filter_map(|t| schedule.get(Gid::new(gi, t)))
        .map(|a| a.finish)
        .fold(f64::NEG_INFINITY, f64::max);
    fin.is_finite().then_some(fin)
}

/// §V.B — per-graph responsiveness:
/// `(1/K) Σ_i ( max_{t∈T_i} e(t) − a_i )`.
pub fn mean_makespan(schedule: &Schedule, problem: &[(f64, TaskGraph)]) -> f64 {
    if problem.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for (gi, (arrival, g)) in problem.iter().enumerate() {
        if let Some(finish) = graph_finish(schedule, gi, g) {
            acc += finish - arrival;
        }
    }
    acc / problem.len() as f64
}

/// §V.C — fairness / compactness:
/// `(1/K) Σ_i ( max_{t∈T_i} e(t) − min_{t'∈T_i} r(t') )`.
pub fn mean_flowtime(schedule: &Schedule, problem: &[(f64, TaskGraph)]) -> f64 {
    if problem.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for (gi, (_, g)) in problem.iter().enumerate() {
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for t in 0..g.n_tasks() {
            if let Some(a) = schedule.get(Gid::new(gi, t)) {
                lo = lo.min(a.start);
                hi = hi.max(a.finish);
            }
        }
        if hi.is_finite() && lo.is_finite() {
            acc += hi - lo;
        }
    }
    acc / problem.len() as f64
}

/// §V.D — per-node utilization `u(v) = busy(v) / max e(t)` (the paper
/// normalizes by the latest completion over all tasks).
pub fn node_utilization(
    schedule: &Schedule,
    problem: &[(f64, TaskGraph)],
    network: &Network,
) -> Vec<f64> {
    let span = schedule
        .iter()
        .map(|(_, a)| a.finish)
        .fold(f64::NEG_INFINITY, f64::max);
    let mut out = vec![0.0; network.n_nodes()];
    if !span.is_finite() || span <= 0.0 {
        return out;
    }
    let _ = problem; // node busy time already lives in the timelines
    for v in 0..network.n_nodes() {
        out[v] = schedule.timelines().busy_time(v) / span;
    }
    out
}

/// Mean of [`node_utilization`] across nodes — the Figure 7/8e quantity.
pub fn mean_utilization(
    schedule: &Schedule,
    problem: &[(f64, TaskGraph)],
    network: &Network,
) -> f64 {
    let u = node_utilization(schedule, problem, network);
    if u.is_empty() {
        0.0
    } else {
        u.iter().sum::<f64>() / u.len() as f64
    }
}

/// Best-case response-time lower bound of one graph on `network`: the
/// longest path of per-task best-node execution times, communication
/// ignored.  Every §II-valid execution of the graph alone or in company
/// responds in at least this time (each relaxation — free choice of the
/// fastest node per task, zero communication, no contention — only
/// shrinks the bound), so it is the natural stretch denominator.
pub fn ideal_response(g: &TaskGraph, network: &Network) -> f64 {
    let n = g.n_tasks();
    if n == 0 {
        return 0.0;
    }
    let best: Vec<f64> = (0..n)
        .map(|t| {
            (0..network.n_nodes())
                .map(|v| network.exec_time(g.cost(t), v))
                .fold(f64::INFINITY, f64::min)
        })
        .collect();
    let mut down = vec![0.0f64; n];
    for &t in g.topo_order().iter().rev() {
        let tail = g
            .successors(t)
            .iter()
            .map(|&(c, _)| down[c])
            .fold(0.0, f64::max);
        down[t] = best[t] + tail;
    }
    down.into_iter().fold(0.0, f64::max)
}

/// §V fairness — per-graph **stretch** (slowdown) paired with the
/// graph's importance weight ([`TaskGraph::weight`], 1.0 unless set):
/// observed response time over the [`ideal_response`] lower bound; one
/// entry per graph with at least one scheduled task.  The two vectors
/// are index-aligned.
pub fn graph_stretch_weights(
    schedule: &Schedule,
    problem: &[(f64, TaskGraph)],
    network: &Network,
) -> (Vec<f64>, Vec<f64>) {
    let mut stretches = Vec::new();
    let mut weights = Vec::new();
    for (gi, (arrival, g)) in problem.iter().enumerate() {
        let Some(finish) = graph_finish(schedule, gi, g) else {
            continue;
        };
        let ideal = ideal_response(g, network);
        stretches.push(if ideal > 0.0 {
            (finish - arrival) / ideal
        } else {
            1.0
        });
        weights.push(g.weight());
    }
    (stretches, weights)
}

/// §V fairness — per-graph **stretch** (slowdown): observed response
/// time over the [`ideal_response`] lower bound; one entry per graph
/// with at least one scheduled task.  Plans have stretch ≥ 1; realized
/// schedules under speed-up noise may dip below 1.
pub fn graph_stretches(
    schedule: &Schedule,
    problem: &[(f64, TaskGraph)],
    network: &Network,
) -> Vec<f64> {
    graph_stretch_weights(schedule, problem, network).0
}

/// Weighted mean `Σ wᵢxᵢ / Σ wᵢ` (0.0 on empty or degenerate weights).
/// With all weights 1.0 this is bit-identical to the plain mean.
pub fn weighted_mean(xs: &[f64], ws: &[f64]) -> f64 {
    debug_assert_eq!(xs.len(), ws.len());
    if xs.is_empty() {
        return 0.0;
    }
    let wsum: f64 = ws.iter().sum();
    if !(wsum > 0.0) {
        return 0.0;
    }
    let acc: f64 = xs.iter().zip(ws).map(|(x, w)| w * x).sum();
    acc / wsum
}

/// Weighted max `maxᵢ wᵢxᵢ` — the weighted-max-stretch unfairness axis:
/// a graph's slowdown counts in proportion to its importance.  With all
/// weights 1.0 this is bit-identical to the plain max (0.0 on empty).
pub fn weighted_max(xs: &[f64], ws: &[f64]) -> f64 {
    debug_assert_eq!(xs.len(), ws.len());
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter()
        .zip(ws)
        .map(|(x, w)| w * x)
        .fold(f64::NEG_INFINITY, f64::max)
}

/// Jain's fairness index over per-graph slowdowns:
/// `(Σ s_i)² / (K · Σ s_i²)` ∈ (0, 1], where 1 means every graph is
/// slowed down equally.  Empty input is vacuously fair (1.0).
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 <= 0.0 {
        return 1.0;
    }
    (s * s) / (xs.len() as f64 * s2)
}

/// Weighted Jain's index `(Σ wᵢxᵢ)² / (Σ wᵢ · Σ wᵢxᵢ²)` ∈ (0, 1]: each
/// graph's slowdown counts in proportion to its importance weight.  With
/// all weights 1.0 this is bit-identical to [`jain_fairness`]; empty or
/// degenerate input is vacuously fair (1.0).
pub fn weighted_jain(xs: &[f64], ws: &[f64]) -> f64 {
    debug_assert_eq!(xs.len(), ws.len());
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().zip(ws).map(|(x, w)| w * x).sum();
    let s2: f64 = xs.iter().zip(ws).map(|(x, w)| w * x * x).sum();
    let wsum: f64 = ws.iter().sum();
    if s2 <= 0.0 || !(wsum > 0.0) {
        return 1.0;
    }
    (s * s) / (wsum * s2)
}

/// The deadline axes of one run, computed over the **deadline-bearing**
/// graphs only ([`TaskGraph::deadline`]): per-graph tardiness is
/// `max(0, finish − deadline)` where `finish` is the graph's last task
/// completion.  A deadline-bearing graph with **no** finish (dropped or
/// never admitted — possible once an admission layer is in play) counts
/// as **missed**: it joins the miss-rate denominator and numerator, but
/// contributes no tardiness sample (its tardiness is undefined without a
/// finish time).  A workload with no deadlines (the paper's setting) is
/// **vacuously on-time** — every axis reads 0.0 — so turning the axes on
/// never perturbs deadline-free sweeps.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DeadlineSummary {
    /// fraction of deadline-bearing graphs finishing strictly after
    /// their deadline (`tardiness > 0`) **or never finishing at all**
    /// ∈ [0, 1]
    pub miss_rate: f64,
    /// mean per-graph tardiness
    pub mean_tardiness: f64,
    /// worst per-graph tardiness
    pub max_tardiness: f64,
    /// importance-weighted mean tardiness `Σ wᵢtᵢ / Σ wᵢ`; equals
    /// `mean_tardiness` bit-exactly at unit weights
    pub weighted_tardiness: f64,
}

/// Compute the [`DeadlineSummary`] of a finished schedule.  Graphs
/// without a deadline contribute nothing; a deadline-bearing graph with
/// no scheduled task counts as **missed** (it can never meet its
/// deadline) but contributes no tardiness sample — see
/// `docs/METRICS.md` for the convention.  On fully-scheduled input the
/// result is bit-identical to the pre-admission accounting.
pub fn deadline_summary(schedule: &Schedule, problem: &[(f64, TaskGraph)]) -> DeadlineSummary {
    let mut tard = Vec::new();
    let mut weights = Vec::new();
    let mut missed = 0usize;
    let mut n_deadline = 0usize;
    for (gi, (_, g)) in problem.iter().enumerate() {
        let Some(deadline) = g.deadline() else {
            continue;
        };
        n_deadline += 1;
        match graph_finish(schedule, gi, g) {
            Some(finish) => {
                let t = (finish - deadline).max(0.0);
                if t > 0.0 {
                    missed += 1;
                }
                tard.push(t);
                weights.push(g.weight());
            }
            // A deadline-bearing graph that never finishes (dropped or
            // unadmitted) is a miss, not vacuously on-time; its
            // tardiness is undefined, so it joins the miss-rate
            // denominator/numerator but not the tardiness means.
            None => missed += 1,
        }
    }
    if n_deadline == 0 {
        return DeadlineSummary::default();
    }
    DeadlineSummary {
        miss_rate: missed as f64 / n_deadline as f64,
        mean_tardiness: if tard.is_empty() {
            0.0
        } else {
            tard.iter().sum::<f64>() / tard.len() as f64
        },
        max_tardiness: tard.iter().copied().fold(0.0, f64::max),
        weighted_tardiness: weighted_mean(&tard, &weights),
    }
}

/// A full metric row for one (workload, scheduler) run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricRow {
    pub total_makespan: f64,
    pub mean_makespan: f64,
    pub mean_flowtime: f64,
    pub mean_utilization: f64,
    /// mean per-graph stretch (response / best-case lower bound)
    pub mean_stretch: f64,
    /// worst per-graph stretch — the max-stretch unfairness axis
    pub max_stretch: f64,
    /// Jain's index over the per-graph stretches (1 = perfectly fair)
    pub jain_fairness: f64,
    /// importance-weighted mean stretch (`Σ wᵢsᵢ / Σ wᵢ`); equals
    /// `mean_stretch` bit-exactly when every graph weight is 1.0
    pub weighted_mean_stretch: f64,
    /// importance-weighted max stretch (`maxᵢ wᵢsᵢ`)
    pub weighted_max_stretch: f64,
    /// weighted Jain's index over the per-graph stretches
    pub weighted_jain: f64,
    /// fraction of deadline-bearing graphs that missed their deadline
    /// (0.0 when no graph carries a deadline — vacuously on-time)
    pub deadline_miss_rate: f64,
    /// mean tardiness `max(0, finish − deadline)` over deadline-bearing
    /// graphs
    pub mean_tardiness: f64,
    /// worst per-graph tardiness
    pub max_tardiness: f64,
    /// importance-weighted mean tardiness; equals `mean_tardiness`
    /// bit-exactly at unit weights
    pub weighted_tardiness: f64,
    /// scheduler wall-clock runtime in seconds (§V.E), filled by the
    /// dynamic coordinator.
    pub runtime_s: f64,
    /// simulated seconds of partial execution lost to crash-killed
    /// attempts ([`crate::sim::faults`]); 0.0 on fault-free runs —
    /// filled by the reactive coordinator, not derivable from the
    /// finished schedule (killed attempts leave no slot behind)
    pub wasted_work_s: f64,
    /// tasks that completed on a retry after a crash killed an earlier
    /// attempt (stored as f64 so the row stays a flat numeric record;
    /// always integral)
    pub n_reexecuted: f64,
    /// mean node downtime per recovery in simulated seconds (0.0 when
    /// no node recovered)
    pub mean_recovery_latency: f64,
}

impl MetricRow {
    pub fn compute(
        schedule: &Schedule,
        problem: &[(f64, TaskGraph)],
        network: &Network,
        runtime_s: f64,
    ) -> Self {
        let (stretches, weights) = graph_stretch_weights(schedule, problem, network);
        let (mean_stretch, max_stretch) = if stretches.is_empty() {
            (0.0, 0.0)
        } else {
            (
                stretches.iter().sum::<f64>() / stretches.len() as f64,
                stretches.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            )
        };
        let dl = deadline_summary(schedule, problem);
        Self {
            total_makespan: total_makespan(schedule, problem),
            mean_makespan: mean_makespan(schedule, problem),
            mean_flowtime: mean_flowtime(schedule, problem),
            mean_utilization: mean_utilization(schedule, problem, network),
            mean_stretch,
            max_stretch,
            jain_fairness: jain_fairness(&stretches),
            weighted_mean_stretch: weighted_mean(&stretches, &weights),
            weighted_max_stretch: weighted_max(&stretches, &weights),
            weighted_jain: weighted_jain(&stretches, &weights),
            deadline_miss_rate: dl.miss_rate,
            mean_tardiness: dl.mean_tardiness,
            max_tardiness: dl.max_tardiness,
            weighted_tardiness: dl.weighted_tardiness,
            runtime_s,
            // fault accounting is runtime state, not schedule-derived;
            // the reactive coordinator overwrites these after compute()
            wasted_work_s: 0.0,
            n_reexecuted: 0.0,
            mean_recovery_latency: 0.0,
        }
    }

    pub fn get(&self, metric: Metric) -> f64 {
        match metric {
            Metric::TotalMakespan => self.total_makespan,
            Metric::MeanMakespan => self.mean_makespan,
            Metric::MeanFlowtime => self.mean_flowtime,
            Metric::Utilization => self.mean_utilization,
            Metric::MeanStretch => self.mean_stretch,
            Metric::MaxStretch => self.max_stretch,
            Metric::JainFairness => self.jain_fairness,
            Metric::WeightedMeanStretch => self.weighted_mean_stretch,
            Metric::WeightedMaxStretch => self.weighted_max_stretch,
            Metric::WeightedJain => self.weighted_jain,
            Metric::DeadlineMissRate => self.deadline_miss_rate,
            Metric::MeanTardiness => self.mean_tardiness,
            Metric::MaxTardiness => self.max_tardiness,
            Metric::WeightedTardiness => self.weighted_tardiness,
            Metric::Runtime => self.runtime_s,
            Metric::WastedWork => self.wasted_work_s,
            Metric::Reexecuted => self.n_reexecuted,
            Metric::RecoveryLatency => self.mean_recovery_latency,
        }
    }
}

/// Metric selector used by the experiment harness / normalization.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    TotalMakespan,
    MeanMakespan,
    MeanFlowtime,
    Utilization,
    MeanStretch,
    MaxStretch,
    JainFairness,
    WeightedMeanStretch,
    WeightedMaxStretch,
    WeightedJain,
    DeadlineMissRate,
    MeanTardiness,
    MaxTardiness,
    WeightedTardiness,
    Runtime,
    /// simulated seconds lost to crash-killed attempts
    WastedWork,
    /// tasks re-executed after a crash killed an earlier attempt
    Reexecuted,
    /// mean node downtime per recovery (simulated seconds)
    RecoveryLatency,
}

impl Metric {
    pub const ALL: [Metric; 18] = [
        Metric::TotalMakespan,
        Metric::MeanMakespan,
        Metric::MeanFlowtime,
        Metric::Utilization,
        Metric::MeanStretch,
        Metric::MaxStretch,
        Metric::JainFairness,
        Metric::WeightedMeanStretch,
        Metric::WeightedMaxStretch,
        Metric::WeightedJain,
        Metric::DeadlineMissRate,
        Metric::MeanTardiness,
        Metric::MaxTardiness,
        Metric::WeightedTardiness,
        Metric::Runtime,
        Metric::WastedWork,
        Metric::Reexecuted,
        Metric::RecoveryLatency,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Metric::TotalMakespan => "total_makespan",
            Metric::MeanMakespan => "mean_makespan",
            Metric::MeanFlowtime => "mean_flowtime",
            Metric::Utilization => "utilization",
            Metric::MeanStretch => "mean_stretch",
            Metric::MaxStretch => "max_stretch",
            Metric::JainFairness => "jain_fairness",
            Metric::WeightedMeanStretch => "weighted_mean_stretch",
            Metric::WeightedMaxStretch => "weighted_max_stretch",
            Metric::WeightedJain => "weighted_jain",
            Metric::DeadlineMissRate => "deadline_miss_rate",
            Metric::MeanTardiness => "mean_tardiness",
            Metric::MaxTardiness => "max_tardiness",
            Metric::WeightedTardiness => "weighted_tardiness",
            Metric::Runtime => "runtime",
            Metric::WastedWork => "wasted_work_s",
            Metric::Reexecuted => "n_reexecuted",
            Metric::RecoveryLatency => "mean_recovery_latency",
        }
    }

    /// Whether *smaller* is better (normalization divides by the best).
    /// Utilization and the Jain indices are higher-is-better.
    pub fn lower_is_better(&self) -> bool {
        !matches!(
            self,
            Metric::Utilization | Metric::JainFairness | Metric::WeightedJain
        )
    }

    /// Metrics reported raw (already on a bounded absolute scale) rather
    /// than normalized to the per-trial best, per the paper's Fig 7/8e
    /// convention for utilization.  The deadline miss rate is a bounded
    /// fraction, so it joins the raw set; tardiness is an absolute time
    /// and normalizes like the makespan axes.  The fault axes are raw
    /// too: on fault-free sweeps every variant reads 0.0, and dividing
    /// by a zero best would degenerate the normalization.
    pub fn reported_raw(&self) -> bool {
        matches!(
            self,
            Metric::Utilization
                | Metric::JainFairness
                | Metric::WeightedJain
                | Metric::DeadlineMissRate
                | Metric::WastedWork
                | Metric::Reexecuted
                | Metric::RecoveryLatency
        )
    }
}

/// Preemption-cost accounting of one reactive run — what a policy
/// *spent* to earn its schedule-quality metrics.  Filled by
/// [`crate::sim::SimResult::preemption_cost`] and reported alongside the
/// [`MetricRow`] in the policy sweep's tables/CSV/JSON.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PreemptionCost {
    /// rescheduling passes that actually ran (arrival + straggler)
    pub replans: usize,
    /// straggler-triggered subset of `replans`
    pub straggler_replans: usize,
    /// previously scheduled tasks reverted across all replans
    pub reverted_tasks: usize,
    /// whole *pending* graphs migrated across shards by the federation
    /// layer's rebalancing pass ([`crate::federation`]); always 0 for
    /// monolithic (single-coordinator) runs
    pub migrations: usize,
    /// wall-clock seconds inside replan passes (belief refresh + base
    /// heuristic + bookkeeping) — the runtime price of reacting
    pub replan_wall_s: f64,
    /// belief-refresh phase of `replan_wall_s` (seconds)
    pub refresh_wall_s: f64,
    /// base-heuristic phase of `replan_wall_s` (seconds) — equals the
    /// run's `sched_runtime_s`
    pub heuristic_wall_s: f64,
    /// bookkeeping remainder of `replan_wall_s` (seconds); the three
    /// phases reconcile with the total by construction
    /// (`refresh + heuristic + bookkeep ≈ replan_wall_s`)
    pub bookkeep_wall_s: f64,
}

/// Normalize a set of values for one metric: divide by the best value
/// (min for lower-is-better metrics, max for higher-is-better ones), so
/// the best variant reads 1.0 — the convention of the paper's
/// "Normalized ..." figures.  A zero or non-finite best (degenerate
/// trial) returns the values untouched.
pub fn normalize(metric: Metric, values: &[f64]) -> Vec<f64> {
    if values.is_empty() {
        return Vec::new();
    }
    let best = if metric.lower_is_better() {
        values.iter().copied().fold(f64::INFINITY, f64::min)
    } else {
        values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    };
    if best == 0.0 || !best.is_finite() {
        return values.to_vec();
    }
    values.iter().map(|v| v / best).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::schedule::Assignment;

    /// Two single-task graphs arriving at 0 and 10, on a 2-node
    /// homogeneous network.
    fn setup() -> (Schedule, Vec<(f64, TaskGraph)>, Network) {
        let g1 = {
            let mut b = GraphBuilder::new("g1");
            b.task(4.0);
            b.build().unwrap()
        };
        let g2 = {
            let mut b = GraphBuilder::new("g2");
            let a = b.task(2.0);
            let c = b.task(2.0);
            b.edge(a, c, 0.0);
            b.build().unwrap()
        };
        let net = Network::homogeneous(2);
        let mut s = Schedule::new(2);
        // g1 t0 on node 0: [0, 4]
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 0.0, finish: 4.0 });
        // g2 t0 on node 1: [10, 12], t1 on node 1: [14, 16] (gap of 2)
        s.assign(Gid::new(1, 0), Assignment { node: 1, start: 10.0, finish: 12.0 });
        s.assign(Gid::new(1, 1), Assignment { node: 1, start: 14.0, finish: 16.0 });
        (s, vec![(0.0, g1), (10.0, g2)], net)
    }

    #[test]
    fn total_makespan_spans_first_arrival_to_last_finish() {
        let (s, p, _) = setup();
        assert_eq!(total_makespan(&s, &p), 16.0);
    }

    #[test]
    fn mean_makespan_is_arrival_relative() {
        let (s, p, _) = setup();
        // g1: 4 - 0 = 4; g2: 16 - 10 = 6 → mean 5
        assert_eq!(mean_makespan(&s, &p), 5.0);
    }

    #[test]
    fn mean_flowtime_is_start_relative() {
        let (s, p, _) = setup();
        // g1: 4 - 0 = 4; g2: 16 - 10 = 6 → 5 (same here because g2's first
        // start equals its arrival)
        assert_eq!(mean_flowtime(&s, &p), 5.0);
    }

    #[test]
    fn utilization_counts_busy_over_span() {
        let (s, p, net) = setup();
        let u = node_utilization(&s, &p, &net);
        // span = 16; node0 busy 4, node1 busy 4
        assert!((u[0] - 0.25).abs() < 1e-12);
        assert!((u[1] - 0.25).abs() < 1e-12);
        assert!((mean_utilization(&s, &p, &net) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_schedule_yields_zeroes() {
        let s = Schedule::new(2);
        let p: Vec<(f64, TaskGraph)> = Vec::new();
        assert_eq!(total_makespan(&s, &p), 0.0);
        assert_eq!(mean_makespan(&s, &p), 0.0);
        assert_eq!(mean_flowtime(&s, &p), 0.0);
    }

    #[test]
    fn metric_row_and_selectors() {
        let (s, p, net) = setup();
        let row = MetricRow::compute(&s, &p, &net, 0.5);
        assert_eq!(row.get(Metric::TotalMakespan), 16.0);
        assert_eq!(row.get(Metric::Runtime), 0.5);
        assert_eq!(Metric::Utilization.lower_is_better(), false);
        assert_eq!(Metric::JainFairness.lower_is_better(), false);
        assert_eq!(Metric::WeightedJain.lower_is_better(), false);
        assert_eq!(Metric::TotalMakespan.lower_is_better(), true);
        assert_eq!(Metric::MaxStretch.lower_is_better(), true);
        assert_eq!(Metric::WeightedMaxStretch.lower_is_better(), true);
        assert!(Metric::JainFairness.reported_raw());
        assert!(Metric::WeightedJain.reported_raw());
        assert!(!Metric::MeanStretch.reported_raw());
        assert!(!Metric::WeightedMeanStretch.reported_raw());
        // deadline axes: all lower-is-better; only the bounded miss
        // rate is reported raw, tardiness normalizes like makespan
        assert!(Metric::DeadlineMissRate.lower_is_better());
        assert!(Metric::MeanTardiness.lower_is_better());
        assert!(Metric::WeightedTardiness.lower_is_better());
        assert!(Metric::DeadlineMissRate.reported_raw());
        assert!(!Metric::MeanTardiness.reported_raw());
        assert!(!Metric::MaxTardiness.reported_raw());
        assert!(!Metric::WeightedTardiness.reported_raw());
        assert_eq!(Metric::ALL.len(), 18);
        // fault axes: lower is better, reported raw (zero on fault-free
        // sweeps, so per-trial-best normalization would divide by zero)
        assert!(Metric::WastedWork.lower_is_better());
        assert!(Metric::Reexecuted.lower_is_better());
        assert!(Metric::RecoveryLatency.lower_is_better());
        assert!(Metric::WastedWork.reported_raw());
        assert!(Metric::Reexecuted.reported_raw());
        assert!(Metric::RecoveryLatency.reported_raw());
        assert_eq!(row.get(Metric::WastedWork), 0.0);
        assert_eq!(row.get(Metric::Reexecuted), 0.0);
        assert_eq!(row.get(Metric::RecoveryLatency), 0.0);
    }

    #[test]
    fn stretch_and_jain_on_hand_example() {
        let (s, p, net) = setup();
        // g1: single task cost 4, homogeneous speed 1 → ideal 4,
        // response 4 - 0 = 4 → stretch 1.
        // g2: chain 2 + 2 → ideal 4, response 16 - 10 = 6 → stretch 1.5.
        let st = graph_stretches(&s, &p, &net);
        assert_eq!(st, vec![1.0, 1.5]);
        let row = MetricRow::compute(&s, &p, &net, 0.0);
        assert!((row.mean_stretch - 1.25).abs() < 1e-12);
        assert!((row.max_stretch - 1.5).abs() < 1e-12);
        // Jain over {1, 1.5}: (2.5)² / (2 · 3.25)
        assert!((row.jain_fairness - 6.25 / 6.5).abs() < 1e-12);
    }

    #[test]
    fn unit_weights_are_bit_identical_to_unweighted() {
        let (s, p, net) = setup();
        let row = MetricRow::compute(&s, &p, &net, 0.0);
        // every generator leaves weights at 1.0, so the weighted axes
        // must reproduce the unweighted ones bit-exactly
        assert_eq!(
            row.weighted_mean_stretch.to_bits(),
            row.mean_stretch.to_bits()
        );
        assert_eq!(row.weighted_max_stretch.to_bits(), row.max_stretch.to_bits());
        assert_eq!(row.weighted_jain.to_bits(), row.jain_fairness.to_bits());
    }

    #[test]
    fn weights_skew_the_fairness_axes() {
        let (s, mut p, net) = setup();
        // g2 (stretch 1.5) is 3× as important as g1 (stretch 1.0)
        p[1].1.set_weight(3.0);
        let (st, w) = graph_stretch_weights(&s, &p, &net);
        assert_eq!(st, vec![1.0, 1.5]);
        assert_eq!(w, vec![1.0, 3.0]);
        let row = MetricRow::compute(&s, &p, &net, 0.0);
        // weighted mean = (1·1 + 3·1.5) / 4 = 1.375 > unweighted 1.25
        assert!((row.weighted_mean_stretch - 1.375).abs() < 1e-12);
        assert!(row.weighted_mean_stretch > row.mean_stretch);
        // weighted max = 3 · 1.5 = 4.5
        assert!((row.weighted_max_stretch - 4.5).abs() < 1e-12);
        // weighted Jain = (5.5)² / (4 · (1 + 3·2.25))
        assert!((row.weighted_jain - 30.25 / 31.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_helpers_degenerate_inputs() {
        assert_eq!(weighted_mean(&[], &[]), 0.0);
        assert_eq!(weighted_max(&[], &[]), 0.0);
        assert_eq!(weighted_jain(&[], &[]), 1.0);
        assert_eq!(weighted_jain(&[0.0], &[1.0]), 1.0);
        assert_eq!(weighted_mean(&[2.0, 4.0], &[1.0, 1.0]), 3.0);
        assert_eq!(weighted_max(&[2.0, 4.0], &[3.0, 1.0]), 6.0);
    }

    #[test]
    fn no_deadlines_is_vacuously_on_time() {
        // the degenerate-input convention: a deadline-free workload has
        // miss rate 0 and zero tardiness on every axis, so deadline-free
        // sweeps are unperturbed by the new columns
        let (s, p, net) = setup();
        let dl = deadline_summary(&s, &p);
        assert_eq!(dl, DeadlineSummary::default());
        let row = MetricRow::compute(&s, &p, &net, 0.0);
        assert_eq!(row.get(Metric::DeadlineMissRate), 0.0);
        assert_eq!(row.get(Metric::MeanTardiness), 0.0);
        assert_eq!(row.get(Metric::MaxTardiness), 0.0);
        assert_eq!(row.get(Metric::WeightedTardiness), 0.0);
    }

    #[test]
    fn deadline_summary_hand_example() {
        // g1 finishes at 4 (deadline 5: met); g2 finishes at 16
        // (deadline 12: tardy by 4) → miss 1/2, mean 2, max 4
        let (s, mut p, _) = setup();
        p[0].1.set_deadline(5.0);
        p[1].1.set_deadline(12.0);
        let dl = deadline_summary(&s, &p);
        assert_eq!(dl.miss_rate, 0.5);
        assert_eq!(dl.mean_tardiness, 2.0);
        assert_eq!(dl.max_tardiness, 4.0);
        // unit weights: weighted ≡ unweighted bit-exactly
        assert_eq!(dl.weighted_tardiness.to_bits(), dl.mean_tardiness.to_bits());
    }

    #[test]
    fn single_graph_tardiness() {
        // only g2 carries a deadline: the summary is that one graph's
        let (s, mut p, _) = setup();
        p[1].1.set_deadline(13.0);
        let dl = deadline_summary(&s, &p);
        assert_eq!(dl.miss_rate, 1.0);
        assert_eq!(dl.mean_tardiness, 3.0);
        assert_eq!(dl.max_tardiness, 3.0);
        assert_eq!(dl.weighted_tardiness, 3.0);
    }

    #[test]
    fn all_graphs_met_reads_zero_tardiness() {
        let (s, mut p, _) = setup();
        p[0].1.set_deadline(100.0);
        p[1].1.set_deadline(100.0);
        let dl = deadline_summary(&s, &p);
        assert_eq!(dl.miss_rate, 0.0);
        assert_eq!(dl.mean_tardiness, 0.0);
        assert_eq!(dl.max_tardiness, 0.0);
        assert_eq!(dl.weighted_tardiness, 0.0);
        // an exactly-on-time finish is met, not missed (strict miss)
        let mut q = p.clone();
        q[0].1.set_deadline(4.0);
        q[1].1.set_deadline(16.0);
        let exact = deadline_summary(&s, &q);
        assert_eq!(exact.miss_rate, 0.0);
        assert_eq!(exact.mean_tardiness, 0.0);
    }

    #[test]
    fn weights_skew_weighted_tardiness() {
        let (s, mut p, _) = setup();
        p[0].1.set_deadline(0.0); // tardy by 4
        p[1].1.set_deadline(10.0); // tardy by 6
        p[1].1.set_weight(3.0);
        let dl = deadline_summary(&s, &p);
        assert_eq!(dl.miss_rate, 1.0);
        assert_eq!(dl.mean_tardiness, 5.0);
        assert_eq!(dl.max_tardiness, 6.0);
        // (1·4 + 3·6) / 4 = 5.5
        assert!((dl.weighted_tardiness - 5.5).abs() < 1e-12);
    }

    #[test]
    fn deadline_summary_counts_unscheduled_graphs_as_missed() {
        let (mut s, mut p, _) = setup();
        p[0].1.set_deadline(0.0);
        p[1].1.set_deadline(0.0);
        // drop g2 entirely: it still counts as a miss, but only g1
        // contributes a tardiness sample
        s.unassign(Gid::new(1, 0));
        s.unassign(Gid::new(1, 1));
        let dl = deadline_summary(&s, &p);
        assert_eq!(dl.miss_rate, 1.0);
        assert_eq!(dl.mean_tardiness, 4.0);
    }

    #[test]
    fn unscheduled_deadline_graph_is_a_miss_not_vacuously_on_time() {
        // The discriminating case for the dropped-graph convention:
        // g1 meets a generous deadline, g2 never runs.  The old
        // accounting skipped g2 and read 0.0 misses; now it is 1 miss
        // out of 2 deadline-bearing graphs, with no tardiness sample.
        let (mut s, mut p, _) = setup();
        p[0].1.set_deadline(100.0); // finishes at 4 → met
        p[1].1.set_deadline(0.0); // never scheduled → missed
        s.unassign(Gid::new(1, 0));
        s.unassign(Gid::new(1, 1));
        let dl = deadline_summary(&s, &p);
        assert_eq!(dl.miss_rate, 0.5);
        assert_eq!(dl.mean_tardiness, 0.0);
        assert_eq!(dl.max_tardiness, 0.0);
        assert_eq!(dl.weighted_tardiness, 0.0);
    }

    #[test]
    fn all_deadline_graphs_unscheduled_is_total_miss() {
        let (mut s, mut p, _) = setup();
        p[0].1.set_deadline(1.0);
        p[1].1.set_deadline(1.0);
        for gi in 0..2 {
            s.unassign(Gid::new(gi, 0));
            s.unassign(Gid::new(gi, 1));
        }
        let dl = deadline_summary(&s, &p);
        assert_eq!(dl.miss_rate, 1.0);
        assert_eq!(dl.mean_tardiness, 0.0);
        assert_eq!(dl.max_tardiness, 0.0);
        assert_eq!(dl.weighted_tardiness, 0.0);
    }

    #[test]
    fn preemption_cost_defaults_to_zero() {
        let c = PreemptionCost::default();
        assert_eq!(c.replans, 0);
        assert_eq!(c.straggler_replans, 0);
        assert_eq!(c.reverted_tasks, 0);
        assert_eq!(c.migrations, 0);
        assert_eq!(c.replan_wall_s, 0.0);
        assert_eq!(c.refresh_wall_s, 0.0);
        assert_eq!(c.heuristic_wall_s, 0.0);
        assert_eq!(c.bookkeep_wall_s, 0.0);
    }

    #[test]
    fn ideal_response_is_critical_path_of_best_exec() {
        // diamond: a(2) -> {b(3), c(5)} -> d(1); speeds {1, 2} → best
        // exec halves every cost; longest path a-c-d = (2+5+1)/2 = 4.
        let mut b = GraphBuilder::new("diamond");
        let a = b.task(2.0);
        let x = b.task(3.0);
        let y = b.task(5.0);
        let d = b.task(1.0);
        b.edge(a, x, 1.0);
        b.edge(a, y, 1.0);
        b.edge(x, d, 1.0);
        b.edge(y, d, 1.0);
        let g = b.build().unwrap();
        let net = Network::new(vec![1.0, 2.0], vec![0.0, 1.0, 1.0, 0.0]);
        assert!((ideal_response(&g, &net) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn jain_index_properties() {
        assert_eq!(jain_fairness(&[]), 1.0);
        assert_eq!(jain_fairness(&[0.0, 0.0]), 1.0);
        assert!((jain_fairness(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
        // one graph starved: index drops toward 1/K
        let j = jain_fairness(&[1.0, 1.0, 10.0]);
        assert!(j < 0.5, "{j}");
        assert!(j > 1.0 / 3.0, "{j}");
    }

    #[test]
    fn stretch_skips_unscheduled_graphs() {
        let (mut s, p, net) = setup();
        // drop g2's tasks: only g1 contributes a stretch
        s.unassign(Gid::new(1, 0));
        s.unassign(Gid::new(1, 1));
        assert_eq!(graph_stretches(&s, &p, &net), vec![1.0]);
    }

    #[test]
    fn normalization_best_is_one() {
        let vals = vec![10.0, 20.0, 15.0];
        let n = normalize(Metric::TotalMakespan, &vals);
        assert_eq!(n, vec![1.0, 2.0, 1.5]);
        // utilization: higher is better → max maps to 1, others < 1
        let u = normalize(Metric::Utilization, &[0.5, 0.25]);
        assert_eq!(u, vec![1.0, 0.5]);
        // higher-is-better fairness: same max convention
        let j = normalize(Metric::JainFairness, &[0.9, 0.45]);
        assert_eq!(j, vec![1.0, 0.5]);
    }

    #[test]
    fn normalization_degenerate_inputs() {
        // empty input → empty output
        assert_eq!(normalize(Metric::TotalMakespan, &[]), Vec::<f64>::new());
        // zero best (lower-is-better) → values returned untouched
        assert_eq!(
            normalize(Metric::TotalMakespan, &[0.0, 5.0]),
            vec![0.0, 5.0]
        );
        // zero best (higher-is-better)
        assert_eq!(normalize(Metric::Utilization, &[0.0, 0.0]), vec![0.0, 0.0]);
        // non-finite best → values returned untouched
        let inf = f64::INFINITY;
        assert_eq!(normalize(Metric::TotalMakespan, &[inf, inf]), vec![inf, inf]);
        assert_eq!(normalize(Metric::Utilization, &[inf, 3.0]), vec![inf, 3.0]);
    }
}
