//! Deterministic fault injection for the reactive runtime.
//!
//! A [`FaultModel`] describes how nodes fail during a simulated run:
//!
//! * [`FaultModel::Crash`] — nodes alternate between healthy phases of
//!   mean length `mtbf` and down phases of mean length `mttr`.  At the
//!   start of a down phase the running task is **killed** (its partial
//!   work is wasted and counted), the node's pending belief slots are
//!   orphaned, and a failure-triggered replan recovers them; the node
//!   re-admits with an empty backlog when the phase ends.
//! * [`FaultModel::Degrade`] — nodes stay up but alternate healthy and
//!   degraded phases (both mean length `span`); a task *starting* inside
//!   a degraded phase runs `factor`× longer than its noise-perturbed
//!   duration.
//! * [`FaultModel::None`] — the default: nodes are immortal and every
//!   byte of the simulation is identical to a build without this module
//!   (the zero-fault bit-identity pin in `rust/tests/faults.rs`).
//!
//! Phase boundaries are a **pure function of `(fault_seed, node, k)`**
//! in the [`crate::robustness::StableNoise`] style: each phase length is
//! the model mean times a truncated-Gaussian jitter factor drawn from a
//! counter-seeded stream, so the fault pattern is independent of the
//! policy under test, the dispatch order, and `--jobs` — the
//! apples-to-apples requirement for comparing how far beyond the forced
//! scope each controller preempts.  `node` is the **global** node id:
//! federation shards own contiguous global node ranges and carry their
//! offset in [`FaultConfig::node_base`], so sharding cannot change which
//! instants a node fails at.

use crate::prng::Xoshiro256pp;
use crate::robustness::{NOISE_HI, NOISE_LO};
use crate::stats::TruncatedGaussian;

/// Relative jitter (std of the truncated Gaussian factor) applied to
/// every phase length.
const PHASE_JITTER_STD: f64 = 0.25;

/// How nodes fail during a run.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum FaultModel {
    /// Immortal nodes (the default — bit-identical to a fault-free build).
    #[default]
    None,
    /// Crash/restart cycles: healthy phases of mean `mtbf`, down phases
    /// of mean `mttr` (both > 0, finite).
    Crash { mtbf: f64, mttr: f64 },
    /// Degradation cycles: healthy and degraded phases of mean `span`;
    /// tasks starting in a degraded phase run `factor`× longer.
    Degrade { factor: f64, span: f64 },
}

impl FaultModel {
    /// Validate the model parameters (CLI strict-validation hook).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            FaultModel::None => Ok(()),
            FaultModel::Crash { mtbf, mttr } => {
                if !(mtbf.is_finite() && mtbf > 0.0) {
                    Err(format!("mtbf must be a positive finite number, got {mtbf}"))
                } else if !(mttr.is_finite() && mttr > 0.0) {
                    Err(format!("mttr must be a positive finite number, got {mttr}"))
                } else {
                    Ok(())
                }
            }
            FaultModel::Degrade { factor, span } => {
                if !(factor.is_finite() && factor > 0.0) {
                    Err(format!("degrade factor must be positive and finite, got {factor}"))
                } else if !(span.is_finite() && span > 0.0) {
                    Err(format!("degrade span must be positive and finite, got {span}"))
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Human label for traces and sweep rows (`crash(m,r)`, `degrade(f,s)`,
    /// `none`).
    pub fn label(&self) -> String {
        match *self {
            FaultModel::None => "none".to_string(),
            FaultModel::Crash { mtbf, mttr } => format!("crash({mtbf},{mttr})"),
            FaultModel::Degrade { factor, span } => format!("degrade({factor},{span})"),
        }
    }
}

/// Default seed of the fault phase-jitter stream, shared by the CLI
/// (`--fault-seed` unset) and `dts serve`'s `{"op":"inject"}` (no
/// `"seed"` field) so a restored session resolves the same fault
/// pattern the original session ran under.
pub const DEFAULT_FAULT_SEED: u64 = 0xFA17;

/// The full fault knob carried on [`crate::sim::SimConfig`].
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct FaultConfig {
    pub model: FaultModel,
    /// Seed of the phase-jitter stream (independent of the noise seed).
    pub seed: u64,
    /// Global id of this coordinator's node 0 (federation shards pass
    /// their partition offset; monolithic runs pass 0).
    pub node_base: usize,
}

impl FaultConfig {
    /// The disabled configuration (what [`Default`] also yields).
    pub const NONE: FaultConfig = FaultConfig {
        model: FaultModel::None,
        seed: 0,
        node_base: 0,
    };

    /// Whether any fault model is active.
    pub fn enabled(&self) -> bool {
        self.model != FaultModel::None
    }
}

/// Pure fault-instant oracle over a [`FaultConfig`].
///
/// All queries are functions of `(seed, global node, phase index)` only —
/// no mutable state, so any caller (simulator, federation admission,
/// tests) sees the same fault pattern regardless of query order.
#[derive(Clone, Copy, Debug)]
pub struct Faults {
    cfg: FaultConfig,
}

impl Faults {
    pub fn new(cfg: FaultConfig) -> Self {
        cfg.model.validate().expect("invalid fault model");
        Self { cfg }
    }

    /// Whether any fault model is active.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Whether the model kills tasks (Crash) as opposed to only
    /// stretching them (Degrade) or nothing (None).
    pub fn crashes(&self) -> bool {
        matches!(self.cfg.model, FaultModel::Crash { .. })
    }

    /// The degrade stretch factor of the model (1.0 unless Degrade).
    pub fn stretch(&self) -> f64 {
        match self.cfg.model {
            FaultModel::Degrade { factor, .. } => factor,
            _ => 1.0,
        }
    }

    /// Mean lengths (healthy, faulty) of the model's phase cycle.
    fn phase_means(&self) -> Option<(f64, f64)> {
        match self.cfg.model {
            FaultModel::None => None,
            FaultModel::Crash { mtbf, mttr } => Some((mtbf, mttr)),
            FaultModel::Degrade { span, .. } => Some((span, span)),
        }
    }

    /// StableNoise-style jitter factor for phase `k` of `node` — a pure
    /// function of `(seed, node_base + node, k)`.
    fn jitter(&self, node: usize, k: u64) -> f64 {
        let global = (self.cfg.node_base + node) as u64;
        let packed = (global << 32) ^ k;
        let mix = packed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ self.cfg.seed.rotate_left(17);
        let mut rng = Xoshiro256pp::seed_from_u64(mix);
        TruncatedGaussian::new(1.0, PHASE_JITTER_STD, NOISE_LO, NOISE_HI).sample(&mut rng)
    }

    /// The `k`-th (0-based) fault window `[down, up)` of `node`, or
    /// `None` when no model is active.  O(k) prefix-sum of jittered
    /// phase lengths; `k` is small (faults per node per run).
    pub fn window(&self, node: usize, k: u64) -> Option<(f64, f64)> {
        let (healthy, faulty) = self.phase_means()?;
        let mut t = 0.0;
        for j in 0..=k {
            let up_len = healthy * self.jitter(node, 2 * j);
            let down_len = faulty * self.jitter(node, 2 * j + 1);
            if j == k {
                return Some((t + up_len, t + up_len + down_len));
            }
            t += up_len + down_len;
        }
        unreachable!()
    }

    /// Realized-duration multiplier for a task starting on `node` at
    /// time `t` — `factor` inside a Degrade window, 1.0 otherwise.
    pub fn degrade_factor(&self, node: usize, t: f64) -> f64 {
        let FaultModel::Degrade { factor, .. } = self.cfg.model else {
            return 1.0;
        };
        let mut k = 0u64;
        while let Some((down, up)) = self.window(node, k) {
            if t < down {
                return 1.0;
            }
            if t < up {
                return factor;
            }
            k += 1;
        }
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_model_is_inert() {
        let f = Faults::new(FaultConfig::NONE);
        assert!(!f.enabled());
        assert!(!f.crashes());
        assert_eq!(f.window(0, 0), None);
        assert_eq!(f.degrade_factor(3, 100.0), 1.0);
        assert_eq!(f.stretch(), 1.0);
    }

    #[test]
    fn crash_windows_are_ordered_and_positive() {
        let f = Faults::new(FaultConfig {
            model: FaultModel::Crash { mtbf: 50.0, mttr: 5.0 },
            seed: 7,
            node_base: 0,
        });
        for node in 0..4 {
            let mut prev_up = 0.0;
            for k in 0..8 {
                let (down, up) = f.window(node, k).unwrap();
                assert!(down > prev_up, "window {k} of node {node} out of order");
                assert!(up > down);
                // jitter is bounded: phase lengths within [lo, hi] × mean
                assert!(down - prev_up >= 50.0 * NOISE_LO - 1e-9);
                assert!(down - prev_up <= 50.0 * NOISE_HI + 1e-9);
                assert!(up - down >= 5.0 * NOISE_LO - 1e-9);
                assert!(up - down <= 5.0 * NOISE_HI + 1e-9);
                prev_up = up;
            }
        }
    }

    #[test]
    fn windows_are_pure_and_seeded() {
        let cfg = FaultConfig {
            model: FaultModel::Crash { mtbf: 30.0, mttr: 3.0 },
            seed: 42,
            node_base: 0,
        };
        let a = Faults::new(cfg);
        let b = Faults::new(cfg);
        // query order cannot matter
        let fwd: Vec<_> = (0..6).map(|k| a.window(1, k).unwrap()).collect();
        let rev: Vec<_> = (0..6).rev().map(|k| b.window(1, k).unwrap()).collect();
        for (x, y) in fwd.iter().zip(rev.iter().rev()) {
            assert_eq!(x, y);
        }
        // distinct nodes and seeds decorrelate
        assert_ne!(a.window(0, 0), a.window(1, 0));
        let other = Faults::new(FaultConfig { seed: 43, ..cfg });
        assert_ne!(a.window(0, 0), other.window(0, 0));
    }

    #[test]
    fn node_base_shifts_identity_not_offsets() {
        // a shard whose node 0 is global node 5 must see exactly the
        // windows the monolithic run gives node 5
        let cfg = FaultConfig {
            model: FaultModel::Crash { mtbf: 20.0, mttr: 2.0 },
            seed: 9,
            node_base: 0,
        };
        let mono = Faults::new(cfg);
        let shard = Faults::new(FaultConfig { node_base: 5, ..cfg });
        for k in 0..5 {
            assert_eq!(shard.window(0, k), mono.window(5, k));
            assert_eq!(shard.window(2, k), mono.window(7, k));
        }
    }

    #[test]
    fn degrade_factor_matches_windows() {
        let f = Faults::new(FaultConfig {
            model: FaultModel::Degrade { factor: 2.5, span: 10.0 },
            seed: 3,
            node_base: 0,
        });
        assert_eq!(f.stretch(), 2.5);
        for node in 0..3 {
            let (down, up) = f.window(node, 0).unwrap();
            assert_eq!(f.degrade_factor(node, down - 1e-6), 1.0);
            assert_eq!(f.degrade_factor(node, down), 2.5);
            assert_eq!(f.degrade_factor(node, 0.5 * (down + up)), 2.5);
            assert_eq!(f.degrade_factor(node, up), 1.0);
            let (d1, u1) = f.window(node, 1).unwrap();
            assert_eq!(f.degrade_factor(node, d1 + 1e-9), 2.5);
            assert_eq!(f.degrade_factor(node, u1 + 1e-6), 1.0);
        }
    }

    #[test]
    fn validation_rejects_garbage() {
        assert!(FaultModel::Crash { mtbf: 0.0, mttr: 1.0 }.validate().is_err());
        assert!(FaultModel::Crash { mtbf: 1.0, mttr: f64::NAN }.validate().is_err());
        assert!(FaultModel::Crash { mtbf: f64::INFINITY, mttr: 1.0 }.validate().is_err());
        assert!(FaultModel::Degrade { factor: -1.0, span: 1.0 }.validate().is_err());
        assert!(FaultModel::Degrade { factor: 2.0, span: 0.0 }.validate().is_err());
        assert!(FaultModel::Crash { mtbf: 10.0, mttr: 1.0 }.validate().is_ok());
        assert!(FaultModel::None.validate().is_ok());
    }

    #[test]
    fn labels_are_distinct() {
        assert_eq!(FaultModel::None.label(), "none");
        assert_eq!(FaultModel::Crash { mtbf: 10.0, mttr: 1.0 }.label(), "crash(10,1)");
        assert_eq!(
            FaultModel::Degrade { factor: 2.0, span: 5.0 }.label(),
            "degrade(2,5)"
        );
    }
}
