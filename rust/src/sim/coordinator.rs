//! The **reactive runtime coordinator**: a discrete-event simulation in
//! which realized task durations deviate from the cost estimates (the
//! [`crate::robustness`] noise models), the coordinator observes *actual*
//! start/finish events, and — unlike the post-hoc analysis in
//! [`crate::robustness::realize`] — reacts while the workload runs.
//!
//! Two rescheduling triggers exist:
//!
//! * **Graph arrivals** (§IV of the paper): the configured [`Policy`]
//!   decides which pending tasks are reverted, exactly as in the static
//!   [`Coordinator`](crate::coordinator::Coordinator) — except that
//!   "started" is now an *observed* runtime fact, not a planned start
//!   time.
//! * **Stragglers**: when a task finishes later than the coordinator
//!   expected, something decides whether (and how much) to reschedule.
//!   Two drivers exist: the built-in [`Reaction::LastK`] trigger
//!   (PR 2's fixed rule — revert the pending tasks of the `k` most
//!   recently arrived graphs when `lateness > threshold × estimate`),
//!   and, via [`ReactiveCoordinator::with_policy`], any
//!   [`PreemptionPolicy`] controller from the [`crate::policy`] engine
//!   (fixed, AIMD-adaptive, token-budgeted, cooldown-wrapped).  A policy
//!   observes every finish and every graph completion, answers with a
//!   [`crate::policy::Decision`] (hold, or reschedule a scope — a
//!   window of `k` graphs plus an optional cap on reverted tasks), and
//!   receives the replan outcome back for budget/hysteresis accounting.
//!   The scope's [`crate::policy::ScopeOrder`] picks *which* graphs the
//!   window contains: the `k` most recently arrived (the paper's Last-K
//!   recency window), or — for deadline scenarios — the `k` most
//!   **deadline-endangered** incomplete graphs, ranked by belief slack
//!   (deadline minus predicted completion — `Sim::select_urgent`).
//!   [`Reaction::None`] is the no-reaction baseline (the plan is
//!   executed as-is, late or not).
//!
//! Both drivers share the same replan machinery; `FixedLastK` through
//! the policy path is bit-identical to `Reaction::LastK` through the
//! built-in path (pinned by `rust/tests/policy_engine.rs`).
//!
//! §Perf: every replan runs the base heuristic **in place** on the
//! belief schedule's master timelines inside a PR-1 insertion-journal
//! transaction ([`Timelines::begin_txn`](crate::schedule::Timelines::begin_txn)),
//! so reactive replans cost O(slots touched) and allocate nothing in
//! steady state; all refresh scratch buffers live in the simulator and
//! are reused across events.
//!
//! **Incremental belief refresh (dirty-cone replanning).**  The belief
//! refresh at each replan is *output-sensitive*: instead of re-deriving
//! every pending task (the original full refresh, retained verbatim as
//! `Sim::refresh_belief_full` — the differential oracle, selected by
//! [`SimConfig::full_refresh`] or the `DTS_FULL_REFRESH` env var), the
//! simulator seeds a **dirty set** from (a) the reverted tasks, (b) the
//! dispatched tasks whose observed truth diverged from the belief —
//! tracked as the tasks that started/finished since the last refresh
//! plus the currently running set, never a scan of all dispatched work
//! — and (c) the pending tasks whose `max(arrival, now, node tail,
//! preds + comm)` floor actually moved (at most one O(1) probe per
//! node: pending slots are start-sorted, so the stale-floor tasks are a
//! prefix of each node's pending suffix).  Dirtiness propagates through
//! the graphs' successor lists and the per-node slot order — which
//! keeps every node's dirty region a contiguous *suffix* of its pending
//! slots — and only the resulting downstream cone is evicted
//! ([`crate::schedule::Schedule::unassign_tail`], O(1) per slot) and
//! re-derived with a readiness worklist
//! ([`crate::schedule::Schedule::assign_tail`], O(1) per slot),
//! replacing the old O(rounds × nodes) round-robin.  Untouched tasks
//! keep their stored values, which the recurrence would reproduce
//! bit-exactly (their inputs are unchanged and their stored start
//! already clears the new `now` floor), so the incremental refresh is
//! **bit-identical** to the full oracle — pinned across all four
//! datasets × noise × controllers by `rust/tests/refresh_incremental.rs`.
//! [`ReplanRecord::n_refreshed`] counts the re-derived tasks (the cone
//! size), the sublinearity instrumentation of that suite.
//!
//! **Frozen-prefix invariant**: a task that has started executing is
//! never moved by any replan — reverts only ever select tasks the
//! runtime has not dispatched.  [`SimConfig::record_frozen`] makes every
//! replan snapshot the dispatched set so tests can assert the invariant
//! against the final realized schedule.
//!
//! **Causality.**  Unlike the static coordinator — whose plan-time
//! convention may re-place a reverted task into an idle gap *before* the
//! arrival that triggered the replan — the reactive runtime is causal:
//! every replan floors the pending tasks' ready times at the decision
//! instant, so work is only ever placed in the future.  With perfect
//! estimates (zero noise) the two models coincide exactly whenever no
//! task is re-placed (non-preemptive runs, single-graph instances); the
//! unit tests pin both that equivalence and the preemptive divergence
//! semantics.
//!
//! The simulation is deterministic: the event queue breaks timestamp
//! ties by kind and insertion order, and noise factors are a pure
//! function of `(noise_std, noise_seed, gid)`
//! ([`crate::robustness::StableNoise`]), so two runs with the same
//! configuration — or the same run embedded in a parallel sweep — are
//! bit-identical.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{CompositeWorkspace, DynamicProblem, Policy};
use crate::dense::{DenseIds, DenseMap, DenseSet};
use crate::graph::Gid;
use crate::metrics::{ideal_response, MetricRow, PreemptionCost};
use crate::policy::{
    Decision, FailureObservation, FinishObservation, PreemptionPolicy, ScopeOrder,
};
use crate::robustness::StableNoise;
use crate::schedule::{Assignment, Schedule};
use crate::schedulers::Scheduler;
use crate::sim::events::{EventQueue, SimEvent, SimLogEntry, SimLogKind};
use crate::sim::faults::{FaultConfig, Faults};
use crate::telemetry;

/// How the coordinator reacts to observed lateness.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum Reaction {
    /// No-reaction baseline: arrivals still replan per the policy, but
    /// stragglers never trigger rescheduling.
    #[default]
    None,
    /// Straggler-triggered Last-K rescheduling: when a task finishes
    /// later than `(1 + threshold) ×` its estimated duration, revert the
    /// pending tasks of the `k` most recently arrived graphs and re-run
    /// the base heuristic against the observed state.
    LastK { k: usize, threshold: f64 },
}

impl Reaction {
    /// Short label for tables/CSV: `none` or `L3@0.25`.
    pub fn label(&self) -> String {
        match self {
            Reaction::None => "none".to_string(),
            Reaction::LastK { k, threshold } => format!("L{k}@{threshold}"),
        }
    }
}

/// Reactive-runtime configuration.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct SimConfig {
    /// std of the multiplicative truncated-Gaussian duration noise
    /// (0 = perfect estimates; realized ≡ planned).
    pub noise_std: f64,
    /// seed of the per-task noise factors (independent of the instance
    /// seed so the same workload can be re-run under fresh noise).
    pub noise_seed: u64,
    pub reaction: Reaction,
    /// Snapshot the dispatched set at every replan into
    /// [`ReplanRecord::frozen`] (test instrumentation; off by default).
    pub record_frozen: bool,
    /// Use the retained full-plan belief refresh instead of the
    /// incremental dirty-cone refresh (the differential oracle; the
    /// `DTS_FULL_REFRESH` env var forces it process-wide).  Off by
    /// default: the incremental refresh is bit-identical and
    /// output-sensitive.
    pub full_refresh: bool,
    /// Fault injection ([`FaultConfig::NONE`] by default).  With the
    /// model off the simulator enqueues no fault events and touches no
    /// fault state, so every schedule, log, replan record, metric and
    /// trace byte is identical to a faultless build (the zero-fault
    /// bit-identity pin of `rust/tests/faults.rs`).
    pub faults: FaultConfig,
}

/// One rescheduling pass of a simulated run.
#[derive(Clone, Debug)]
pub struct ReplanRecord {
    pub time: f64,
    /// true = straggler-triggered, false = arrival-time policy replan
    pub straggler: bool,
    /// true = failure-triggered (a node crash forced the revert of its
    /// orphaned work); failure replans are also `straggler: true` — they
    /// are reactive, not arrival-driven — so every existing
    /// straggler-side accounting covers them, and this flag carves the
    /// forced subset out
    pub failure: bool,
    /// previously scheduled tasks reverted by this pass
    pub n_reverted: usize,
    /// composite size handed to the base heuristic
    pub n_pending: usize,
    /// pending tasks whose expected times the belief refresh re-derived
    /// (reverted tasks excluded — they go back to the heuristic).  The
    /// full oracle re-derives every kept pending task; the incremental
    /// refresh only its dirty cone, so this is the §V.E sublinearity
    /// counter the operation-count regression tests pin (never compare
    /// it across refresh modes — the schedules are bit-identical, the
    /// work counts intentionally are not).
    pub n_refreshed: usize,
    /// wall-clock seconds this pass spent (belief refresh + base
    /// heuristic + cursor bookkeeping) — the per-replan §V.E cost
    pub wall_s: f64,
    /// belief-refresh phase of `wall_s` (seconds)
    pub refresh_s: f64,
    /// base-heuristic phase of `wall_s` (seconds) — the slice that
    /// accumulates into [`SimResult::sched_runtime_s`]
    pub heuristic_s: f64,
    /// bookkeeping remainder of `wall_s` (seconds): pending collection,
    /// composite build, journal commit, cursor recompute.  Defined as
    /// `max(0, wall_s − refresh_s − heuristic_s)` so the three phases
    /// reconcile with `wall_s` by construction (clamp guards sub-ns
    /// clock jitter).
    pub bookkeep_s: f64,
    /// `(gid, node, start)` of every task already dispatched when the
    /// replan fired (empty unless [`SimConfig::record_frozen`]); the
    /// frozen-prefix invariant says each must equal the final realized
    /// placement.
    pub frozen: Vec<(Gid, usize, f64)>,
}

/// Outcome of a reactive simulated run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// The realized execution: observed starts/finishes of every task.
    /// Durations embed the noise, so §II-validate it with
    /// [`crate::sim::replay`] (which never assumes duration = c/s).
    pub schedule: Schedule,
    /// Timestamped realized-event trace.
    pub log: Vec<SimLogEntry>,
    /// Every rescheduling pass, arrival-time and straggler-triggered.
    pub replans: Vec<ReplanRecord>,
    /// §V.E: total wall time inside the base heuristic across replans.
    pub sched_runtime_s: f64,
    /// Total wall time of whole replan passes (belief refresh + base
    /// heuristic + bookkeeping) — a superset of `sched_runtime_s`
    /// (debug-asserted at run end; see docs/METRICS.md).
    pub replan_wall_s: f64,
    /// Total wall time of the belief-refresh phase across replans.
    pub refresh_wall_s: f64,
    /// Total wall time of the bookkeeping remainder across replans.
    /// `refresh_wall_s + sched_runtime_s + bookkeep_wall_s` reconciles
    /// with `replan_wall_s` (tolerance-tested in
    /// `rust/tests/telemetry.rs`).
    pub bookkeep_wall_s: f64,
    /// Peak event-queue length observed during the run — instrumentation
    /// for the [`EventQueue::with_capacity`] pre-reservation: whenever
    /// this stays within the Σ tasks × 2 + graphs reservation the heap
    /// never reallocated.
    pub events_peak: usize,
    /// Heap allocations performed inside replan passes, summed across
    /// the run — counted by [`crate::alloc_count`]'s thread-local
    /// counting allocator, so it is non-zero only in builds where that
    /// allocator is registered (`cfg(test)` or the `alloc-count`
    /// feature; always 0 otherwise).  The memory-layout observability
    /// counter: `allocs` columns in BENCH_hotpath.json come from here.
    pub replan_allocs: u64,
    /// Partial work lost to crash kills, in simulated seconds (a killed
    /// attempt's progress from its realized start to the crash instant).
    pub wasted_work_s: f64,
    /// Running attempts killed by crashes (one task killed twice counts
    /// twice here, once in `n_reexecuted`).
    pub n_killed: usize,
    /// Tasks that were killed at least once and later re-executed to
    /// completion.  Conservation: with the run complete this equals the
    /// number of distinct killed tasks.
    pub n_reexecuted: usize,
    /// Total simulated downtime across completed crash windows.
    pub recovery_total_s: f64,
    /// Crash windows that completed (the node came back).
    pub n_recoveries: usize,
    /// Whether a fault model was active ([`FaultConfig::enabled`]) —
    /// lets exporters gate fault fields so default traces stay
    /// byte-identical.
    pub faults_enabled: bool,
}

impl SimResult {
    pub fn metrics(&self, prob: &DynamicProblem) -> MetricRow {
        let mut row = MetricRow::compute(
            &self.schedule,
            &prob.graphs,
            &prob.network,
            self.sched_runtime_s,
        );
        // fault accounting cannot be recovered from the realized
        // schedule (killed attempts leave no trace there) — threaded
        // from the run like runtime_s; all-zero when faults are off
        row.wasted_work_s = self.wasted_work_s;
        row.n_reexecuted = self.n_reexecuted as f64;
        row.mean_recovery_latency = self.mean_recovery_latency();
        row
    }

    pub fn n_replans(&self) -> usize {
        self.replans.len()
    }

    pub fn n_straggler_replans(&self) -> usize {
        self.replans.iter().filter(|r| r.straggler).count()
    }

    /// Failure-triggered (crash-forced) replans only.
    pub fn n_failure_replans(&self) -> usize {
        self.replans.iter().filter(|r| r.failure).count()
    }

    /// Mean simulated downtime per completed crash window (0.0 when no
    /// node ever recovered — faultless runs included).
    pub fn mean_recovery_latency(&self) -> f64 {
        if self.n_recoveries == 0 {
            0.0
        } else {
            self.recovery_total_s / self.n_recoveries as f64
        }
    }

    pub fn n_reverted_total(&self) -> usize {
        self.replans.iter().map(|r| r.n_reverted).sum()
    }

    /// Tasks reverted by straggler-triggered replans only (the quantity
    /// a [`crate::policy::Budgeted`] token bucket meters).
    pub fn n_straggler_reverted_total(&self) -> usize {
        self.replans
            .iter()
            .filter(|r| r.straggler)
            .map(|r| r.n_reverted)
            .sum()
    }

    /// Pending tasks re-derived by belief refreshes across all replans
    /// ([`ReplanRecord::n_refreshed`] summed) — the run-level
    /// sublinearity counter of the incremental-refresh tests.
    pub fn n_refreshed_total(&self) -> usize {
        self.replans.iter().map(|r| r.n_refreshed).sum()
    }

    /// Mean heap allocations per replan pass (see
    /// [`SimResult::replan_allocs`]; 0.0 when no replan ever ran or the
    /// counting allocator is not registered).
    pub fn allocs_per_replan(&self) -> f64 {
        if self.replans.is_empty() {
            0.0
        } else {
            self.replan_allocs as f64 / self.replans.len() as f64
        }
    }

    /// The run's preemption-cost accounting (replans, reverted tasks,
    /// replan wall time) for the policy sweep's figure tables.
    /// Migrations are a federation-layer concept
    /// ([`crate::federation::FederationResult::preemption_cost`]); a
    /// monolithic run always reports 0.
    pub fn preemption_cost(&self) -> PreemptionCost {
        PreemptionCost {
            replans: self.n_replans(),
            straggler_replans: self.n_straggler_replans(),
            reverted_tasks: self.n_reverted_total(),
            migrations: 0,
            replan_wall_s: self.replan_wall_s,
            refresh_wall_s: self.refresh_wall_s,
            heuristic_wall_s: self.sched_runtime_s,
            bookkeep_wall_s: self.bookkeep_wall_s,
        }
    }
}

/// Mutable simulation state (belief + truth + scratch), separated from
/// the coordinator so the borrow of the base heuristic and the composite
/// workspace stays disjoint from the event-loop state.
struct Sim<'a> {
    prob: &'a DynamicProblem,
    cfg: SimConfig,
    noise: StableNoise,
    /// The coordinator's **belief**: planned placements for pending
    /// tasks, observed truth for dispatched ones (refreshed at replans).
    plan: Schedule,
    /// The **truth**: realized starts/finishes (durations include noise).
    realized: Schedule,
    /// Dense-id universe of the whole instance, shared with the
    /// dense-backed `plan`/`realized` stores — every per-task state
    /// column below is indexed by `ids.ix(gid)` instead of hashing.
    ids: Arc<DenseIds>,
    /// completion flag per task (dense-indexed)
    completed: Vec<bool>,
    /// finish the coordinator expected when it dispatched each task
    /// (realized start + estimated duration); dense-indexed, meaningful
    /// only for dispatched tasks
    expected_finish: Vec<f64>,
    node_running: Vec<Option<Gid>>,
    /// realized finish of the last task dispatched to each node
    node_free: Vec<f64>,
    /// dispatch-decision epochs; a [`SimEvent::TaskStart`] is valid only
    /// while its epoch matches (replans and newer decisions invalidate)
    node_epoch: Vec<u64>,
    /// the live queued start decision per node, `(gid, start bits)` —
    /// §Perf: between replans a node's computed decision never changes
    /// (completed predecessors' finishes are fixed and event order keeps
    /// `now ≤ start`), so [`Sim::dispatch_all`] skips re-pushing an
    /// identical decision instead of stranding an epoch-stale event in
    /// the queue per event in a comm-wait window.  Cleared per node when
    /// its start fires, and wholesale when a replan bumps the epochs.
    pending_start: Vec<Option<(Gid, u64)>>,
    /// dispatched-prefix length per node in plan slot order
    cursor: Vec<usize>,
    queue: EventQueue,
    /// graphs arrived so far (straggler window base)
    arrived: usize,
    /// unfinished-task countdown per graph (0 = graph complete) — feeds
    /// the policy engine's per-graph stretch observations
    graph_left: Vec<usize>,
    log: Vec<SimLogEntry>,
    replans: Vec<ReplanRecord>,
    sched_runtime_s: f64,
    replan_wall_s: f64,
    refresh_wall_s: f64,
    bookkeep_wall_s: f64,
    /// heap allocations inside replan passes (see
    /// [`SimResult::replan_allocs`])
    replan_allocs: u64,
    /// peak queue length seen so far (pre-reservation instrumentation)
    events_peak: usize,
    /// resolved refresh mode: [`SimConfig::full_refresh`] or the
    /// `DTS_FULL_REFRESH` env var
    full_refresh: bool,
    /// fault injector (inert when the model is [`FaultConfig::NONE`]);
    /// crash/recovery instants are a pure function of
    /// `(fault_seed, node)` — policy-, dispatch-order- and
    /// thread-count-independent
    faults: Faults,
    /// per node: currently inside a crash window
    node_down: Vec<bool>,
    /// per node: recovery instant of the current crash window while
    /// down, else 0.0 — the belief floor every re-derivation targeting
    /// the node applies (guarded, so the zero-fault path is untouched)
    fault_floor: Vec<f64>,
    /// per node: index of the next crash window to draw
    fault_k: Vec<usize>,
    /// per task (dense): execution attempt, bumped when a crash kills
    /// the running attempt so the in-flight `TaskFinish` dies on pop
    attempt: Vec<u32>,
    /// per task (dense): killed at least once (re-execution accounting)
    was_killed: Vec<bool>,
    /// tasks completed so far — crash windows stop re-arming once the
    /// workload drains
    n_done: usize,
    /// a crash reshaped the dispatched truth since the last refresh:
    /// the next refresh runs the full oracle (a killed slot sits inside
    /// the dispatched prefix, which the incremental seeds never touch;
    /// crashes are rare, so the occasional full pass is cheap)
    fault_dirty: bool,
    // --- fault accounting (see the SimResult fields of the same name) ---
    wasted_s: f64,
    n_killed: usize,
    n_reexecuted: usize,
    recovery_total_s: f64,
    n_recoveries: usize,
    /// tasks that started or finished since the last belief refresh —
    /// together with the currently running set, the only dispatched
    /// entries whose observed truth can have diverged from the belief
    /// (dirty-cone seed b; drained by every refresh)
    dirty_dispatched: Vec<Gid>,
    // --- reusable scratch (steady-state replans allocate nothing) ---
    refresh_order: Vec<Vec<Gid>>,
    refresh_next: Vec<usize>,
    node_tail: Vec<f64>,
    to_remove: Vec<Gid>,
    fix: Vec<(Gid, Assignment)>,
    /// epoch-stamped dense membership of the current revert set (reset
    /// is an O(1) epoch bump — no per-refresh clearing walk)
    revert_set: DenseSet,
    /// urgency-ranked `(belief slack, graph)` scratch of the
    /// deadline-urgency scope selection
    urgency: Vec<(f64, usize)>,
    /// per node: first dirty pending-slot index (`usize::MAX` = clean);
    /// the dirty cone on every node is the suffix from this index
    dirty_from: Vec<usize>,
    /// per node: lowest slot index whose graph successors were already
    /// propagated by the closure (avoids re-walking a grown suffix)
    scan_from: Vec<usize>,
    /// closure worklist of nodes whose dirty suffix grew
    node_stack: Vec<usize>,
    /// divergence-candidate scratch (sorted + deduped per refresh)
    cand: Vec<Gid>,
    /// cone membership: task → (node, per-node cone position, unplaced
    /// blockers) for the readiness worklist; epoch-stamped dense map
    /// keyed by `ids.ix(gid)`
    cone: DenseMap<ConeEntry>,
    /// readiness worklist of cone positions `(node, cone index)`
    ready: Vec<(u32, u32)>,
    /// nodes whose slot lists the current replan touched — the cursor
    /// recompute scope (untouched nodes keep their incrementally
    /// maintained cursors)
    touched: Vec<bool>,
}

/// One dirty-cone member during the incremental refresh: where it sits
/// (`node`, position `pos` in that node's captured cone order) and how
/// many unplaced blockers — its in-cone node predecessor plus its
/// in-cone graph predecessors — still gate its re-derivation.
#[derive(Clone, Copy, Default)]
struct ConeEntry {
    node: u32,
    pos: u32,
    blockers: u32,
}

/// `DTS_FULL_REFRESH` (any value but `0`) forces the full-refresh
/// oracle process-wide — the escape hatch / A-B switch of the
/// incremental belief refresh.
fn full_refresh_forced() -> bool {
    static FORCED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FORCED.get_or_init(|| std::env::var_os("DTS_FULL_REFRESH").is_some_and(|v| v != "0"))
}

/// Which graphs a replan pass may revert — the coordinator-side
/// resolution of a [`crate::policy::Scope`].
enum RevertSel {
    /// A contiguous arrival-index window (recency scopes and the §IV
    /// arrival-policy replans).
    Range(std::ops::Range<usize>),
    /// The `k` most deadline-endangered incomplete graphs, ranked by
    /// belief slack ([`ScopeOrder::DeadlineUrgency`]).
    Urgent(usize),
    /// The forced scope of a failure replan: every graph with
    /// planned-but-undispatched work on the crashed node (the killed
    /// attempt is pending again when this is evaluated, so its graph is
    /// captured by the same walk).  Ascending graph index; never
    /// capped.
    Node(usize),
}

impl<'a> Sim<'a> {
    fn new(prob: &'a DynamicProblem, cfg: SimConfig) -> Self {
        let n = prob.network.n_nodes();
        let faults = Faults::new(cfg.faults);
        // §Perf: pre-reserve the event heap from the instance — the
        // up-front arrivals, one in-flight finish per running task, one
        // live start decision per idle node (deduplicated; see
        // `pending_start`), plus headroom for replan-invalidated starts
        // — so the steady-state loop never grows the allocation.  Crash
        // runs add at most one armed down/up pair per node (re-execution
        // starts may still grow the heap there; only the zero-fault
        // reservation is pinned).
        let fault_cap = if faults.crashes() { 2 * n } else { 0 };
        let mut queue = EventQueue::with_capacity(
            prob.total_tasks() * 2 + prob.graphs.len() + fault_cap,
        );
        for (i, (arrival, _)) in prob.graphs.iter().enumerate() {
            queue.push(*arrival, SimEvent::GraphArrival { idx: i });
        }
        if faults.crashes() {
            // arm window 0 of every node; subsequent windows are armed
            // by each NodeUp, keeping ≤ 2 fault events per node queued
            for v in 0..n {
                let (down, _) = faults.window(v, 0).expect("Crash model draws windows");
                queue.push(down, SimEvent::NodeDown { node: v });
            }
        }
        let ids = prob.dense_ids();
        let nt = ids.len();
        Sim {
            prob,
            cfg,
            noise: StableNoise::new(cfg.noise_std, cfg.noise_seed),
            plan: Schedule::new_dense(n, ids.clone()),
            realized: Schedule::new_dense(n, ids.clone()),
            ids,
            completed: vec![false; nt],
            expected_finish: vec![0.0; nt],
            node_running: vec![None; n],
            node_free: vec![0.0; n],
            node_epoch: vec![0; n],
            pending_start: vec![None; n],
            cursor: vec![0; n],
            queue,
            arrived: 0,
            graph_left: prob.graphs.iter().map(|(_, g)| g.n_tasks()).collect(),
            log: Vec::new(),
            replans: Vec::new(),
            sched_runtime_s: 0.0,
            replan_wall_s: 0.0,
            refresh_wall_s: 0.0,
            bookkeep_wall_s: 0.0,
            replan_allocs: 0,
            events_peak: 0,
            full_refresh: cfg.full_refresh || full_refresh_forced(),
            faults,
            node_down: vec![false; n],
            fault_floor: vec![0.0; n],
            fault_k: vec![0; n],
            attempt: vec![0; nt],
            was_killed: vec![false; nt],
            n_done: 0,
            fault_dirty: false,
            wasted_s: 0.0,
            n_killed: 0,
            n_reexecuted: 0,
            recovery_total_s: 0.0,
            n_recoveries: 0,
            dirty_dispatched: Vec::new(),
            refresh_order: vec![Vec::new(); n],
            refresh_next: vec![0; n],
            node_tail: vec![0.0; n],
            to_remove: Vec::new(),
            fix: Vec::new(),
            revert_set: DenseSet::default(),
            urgency: Vec::new(),
            dirty_from: vec![usize::MAX; n],
            scan_from: vec![usize::MAX; n],
            node_stack: Vec::new(),
            cand: Vec::new(),
            cone: DenseMap::default(),
            ready: Vec::new(),
            touched: vec![false; n],
        }
    }

    /// Rank the arrived, incomplete graphs by **deadline urgency** and
    /// keep the `k` most endangered in `self.urgency`, stored
    /// least-endangered first (so callers pushing per-graph revert
    /// blocks in `self.urgency` order put the most endangered at the
    /// tail, where the shared tail-keeping revert cap preserves them).
    ///
    /// Urgency is belief slack: the graph's deadline minus its predicted
    /// completion under the coordinator's current belief schedule
    /// (planned finishes for pending work, observed/expected truth for
    /// dispatched work, as of the last refresh).  Only graphs with at
    /// least one **revertible** (planned but not dispatched) task are
    /// candidates — an endangered graph whose work is all dispatched
    /// cannot be helped by preemption, and letting it occupy a window
    /// slot would silently starve graphs the replan *can* still move.
    /// One exception: a deadline-carrying graph with **zero planned
    /// slots** (possible once admission can defer or drop a graph) has
    /// no predicted completion at all — it is maximally endangered
    /// (`−∞` slack), not deadline-less, and stays a candidate so the
    /// replan that follows can finally place it.  Graphs without a
    /// deadline get `+∞` slack, so they are only selected after every
    /// deadline-bearing candidate; ties (including the all-`∞` case of
    /// a deadline-free workload) break toward recency.  The ranking is
    /// a deterministic function of the belief, so sweeps stay
    /// bit-identical at any thread count.
    fn select_urgent(&mut self, k: usize) {
        self.urgency.clear();
        for gi in 0..self.arrived {
            if self.graph_left[gi] == 0 {
                continue;
            }
            let (_, g) = &self.prob.graphs[gi];
            let mut fin = f64::NEG_INFINITY;
            let mut revertible = false;
            for t in 0..g.n_tasks() {
                let gid = Gid::new(gi, t);
                if let Some(a) = self.plan.get(gid) {
                    fin = fin.max(a.finish);
                    revertible |= self.realized.get(gid).is_none();
                }
            }
            let no_plan = !fin.is_finite();
            if !revertible && !(no_plan && g.deadline().is_some()) {
                continue;
            }
            let slack = match g.deadline() {
                Some(d) if fin.is_finite() => d - fin,
                // zero planned slots: no predicted completion exists,
                // so the graph is maximally endangered, not ∞-slack
                Some(_) => f64::NEG_INFINITY,
                None => f64::INFINITY,
            };
            self.urgency.push((slack, gi));
        }
        // most endangered = smallest slack; ties → most recent first
        self.urgency
            .sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.cmp(&a.1)));
        self.urgency.truncate(k);
        // least endangered first (see above: the cap keeps the tail)
        self.urgency.reverse();
    }

    fn n_nodes(&self) -> usize {
        self.node_free.len()
    }

    fn dispatched(&self, gid: Gid) -> bool {
        self.realized.get(gid).is_some()
    }

    /// Work-conserving dispatch: for every idle node whose next planned
    /// task has all predecessors *actually* finished, enqueue its start
    /// at the earliest legal instant (data arrival is physical: it uses
    /// realized finishes, never estimates).
    fn dispatch_all(&mut self, now: f64) {
        for v in 0..self.n_nodes() {
            if self.node_running[v].is_some() {
                continue;
            }
            if self.node_down[v] {
                continue; // crashed: nothing dispatches until NodeUp
            }
            let c = self.cursor[v];
            if c >= self.plan.timelines().n_slots(v) {
                continue;
            }
            let gid = self.plan.timelines().slot_gids(v)[c];
            debug_assert!(!self.dispatched(gid), "cursor points at a dispatched task");
            let (arrival, g) = &self.prob.graphs[gid.graph as usize];
            let mut start = arrival.max(self.node_free[v]);
            let mut ready = true;
            for &(p, data) in g.predecessors(gid.task as usize) {
                let pgid = Gid::new(gid.graph as usize, p);
                if !self.completed[self.ids.ix(pgid)] {
                    ready = false;
                    break;
                }
                let pa = self.realized.get(pgid).unwrap();
                start = start.max(pa.finish + self.prob.network.comm_time(data, pa.node, v));
            }
            if !ready {
                continue;
            }
            let start = start.max(now);
            // identical live decision already queued → don't strand
            // another epoch-stale event (the computed decision cannot
            // change between replans: predecessors' realized finishes
            // are fixed once complete, and no event pops after `start`
            // before the start itself fires, so the `now` floor never
            // binds differently)
            if self.pending_start[v] == Some((gid, start.to_bits())) {
                continue;
            }
            self.pending_start[v] = Some((gid, start.to_bits()));
            self.node_epoch[v] += 1;
            self.queue.push(
                start,
                SimEvent::TaskStart {
                    gid,
                    node: v,
                    epoch: self.node_epoch[v],
                },
            );
        }
    }

    /// Project observed reality onto the belief schedule: dispatched
    /// tasks snap to their observed truth (running tasks get
    /// `max(expected finish, now)` — the coordinator cannot see a future
    /// realized finish), and every **affected** pending task's expected
    /// start/finish is re-derived in planned per-node order, floored at
    /// `now`.  Tasks in `revert` are dropped from the belief entirely
    /// (the caller hands them back to the base heuristic).  Returns the
    /// number of pending tasks re-derived
    /// ([`ReplanRecord::n_refreshed`]).
    ///
    /// Dispatches between the incremental dirty-cone refresh (default)
    /// and the retained full-plan oracle — the two are bit-identical.
    fn refresh_belief(&mut self, now: f64, revert: &[Gid]) -> usize {
        // a crash since the last refresh voids the incremental seeds'
        // staleness argument (the killed slot sat inside the dispatched
        // prefix): run the full oracle once, then resume incrementally
        let fault_dirty = std::mem::take(&mut self.fault_dirty);
        if self.full_refresh || fault_dirty {
            self.refresh_belief_full(now, revert)
        } else {
            self.refresh_belief_incremental(now, revert)
        }
    }

    /// The original full-plan refresh, retained **verbatim** as the
    /// differential oracle for
    /// [`refresh_belief_incremental`](Self::refresh_belief_incremental):
    /// rescans every node's slot list, re-checks every dispatched entry
    /// and re-derives every kept pending task — O(pending + dispatched)
    /// per replan, with the O(rounds × nodes) round-robin re-derive.
    fn refresh_belief_full(&mut self, now: f64, revert: &[Gid]) -> usize {
        let n = self.n_nodes();
        self.revert_set.reset(self.ids.len());
        for &g in revert {
            self.revert_set.insert(self.ids.ix(g));
        }
        // the incremental seed journal restarts from the refreshed state
        self.dirty_dispatched.clear();
        // every node is rebuilt — recompute every cursor afterwards
        self.touched.iter_mut().for_each(|t| *t = true);

        // 1. capture the pending per-node order; drop all pending slots
        self.to_remove.clear();
        for v in 0..n {
            self.refresh_order[v].clear();
            for &gid in self.plan.timelines().slot_gids(v) {
                if self.realized.get(gid).is_none() {
                    self.to_remove.push(gid);
                    if !self.revert_set.contains(self.ids.ix(gid)) {
                        self.refresh_order[v].push(gid);
                    }
                }
            }
        }
        telemetry::counter_add(telemetry::Counter::ConeEvicted, self.to_remove.len() as u64);
        while let Some(gid) = self.to_remove.pop() {
            self.plan.unassign(gid);
        }

        // 2. snap dispatched entries to observed truth (two-phase:
        // remove every stale slot first, then insert the truths — a
        // one-by-one swap could transiently overlap a neighbour)
        self.fix.clear();
        let mut fix = std::mem::take(&mut self.fix);
        for (gid, pa) in self.plan.iter() {
            let ra = self.realized.get(*gid).unwrap();
            let truth = if self.completed[self.ids.ix(*gid)] {
                *ra
            } else {
                Assignment {
                    node: ra.node,
                    start: ra.start,
                    finish: self.expected_finish[self.ids.ix(*gid)].max(now),
                }
            };
            if *pa != truth {
                fix.push((*gid, truth));
            }
        }
        for &(gid, _) in &fix {
            self.plan.unassign(gid);
        }
        for &(gid, a) in &fix {
            self.plan.assign(gid, a);
        }
        fix.clear();
        self.fix = fix;

        // 3. re-derive expected times for the pending tasks, preserving
        // assignment and per-node order (the realize recurrence:
        // start = max(arrival, now, node predecessor, preds + comm))
        let mut remaining = 0usize;
        for v in 0..n {
            self.refresh_next[v] = 0;
            remaining += self.refresh_order[v].len();
            self.node_tail[v] = self
                .plan
                .timelines()
                .finishes(v)
                .last()
                .copied()
                .unwrap_or(0.0);
        }
        let n_refreshed = remaining;
        let mut placed_any = true;
        while placed_any && remaining > 0 {
            placed_any = false;
            for v in 0..n {
                'node: while self.refresh_next[v] < self.refresh_order[v].len() {
                    let gid = self.refresh_order[v][self.refresh_next[v]];
                    let (arrival, g) = &self.prob.graphs[gid.graph as usize];
                    let mut start = arrival.max(now).max(self.node_tail[v]);
                    // crashed-node belief floor: nothing runs before the
                    // recovery instant (guarded — 0.0 while up, so the
                    // zero-fault path stays bit-identical)
                    if self.fault_floor[v] > start {
                        start = self.fault_floor[v];
                    }
                    for &(p, data) in g.predecessors(gid.task as usize) {
                        let pgid = Gid::new(gid.graph as usize, p);
                        match self.plan.get(pgid) {
                            None => break 'node,
                            Some(pa) => {
                                start = start.max(
                                    pa.finish
                                        + self.prob.network.comm_time(data, pa.node, v),
                                );
                            }
                        }
                    }
                    let dur = self
                        .prob
                        .network
                        .exec_time(g.cost(gid.task as usize), v);
                    self.plan.assign(
                        gid,
                        Assignment {
                            node: v,
                            start,
                            finish: start + dur,
                        },
                    );
                    self.node_tail[v] = start + dur;
                    self.refresh_next[v] += 1;
                    remaining -= 1;
                    placed_any = true;
                }
            }
        }
        assert_eq!(
            remaining, 0,
            "belief refresh deadlocked — pending order inconsistent with deps"
        );
        telemetry::counter_add(telemetry::Counter::ConeRederived, n_refreshed as u64);
        n_refreshed
    }

    /// The observed truth the belief snaps a dispatched task to: the
    /// realized placement once completed; while running, the realized
    /// start with finish `max(expected, now)` (no future-peeking).
    fn truth_of(&self, gid: Gid, now: f64) -> Assignment {
        let ra = self.realized.get(gid).unwrap();
        if self.completed[self.ids.ix(gid)] {
            *ra
        } else {
            Assignment {
                node: ra.node,
                start: ra.start,
                finish: self.expected_finish[self.ids.ix(gid)].max(now),
            }
        }
    }

    /// Incremental dirty-cone refresh — bit-identical to
    /// [`refresh_belief_full`](Self::refresh_belief_full), touching only
    /// the tasks whose derivation inputs actually changed (see the
    /// module docs for the seed/closure construction and the
    /// bit-exactness argument).  O(seeds + cone) per replan instead of
    /// O(pending + dispatched).
    fn refresh_belief_incremental(&mut self, now: f64, revert: &[Gid]) -> usize {
        /// Lower node `v`'s dirty suffix to start at `idx` and requeue
        /// the node for closure propagation.
        fn lower(dirty_from: &mut [usize], stack: &mut Vec<usize>, v: usize, idx: usize) {
            if idx < dirty_from[v] {
                dirty_from[v] = idx;
                stack.push(v);
            }
        }

        let n = self.n_nodes();
        self.revert_set.reset(self.ids.len());
        for &g in revert {
            self.revert_set.insert(self.ids.ix(g));
        }
        let mut dirty_from = std::mem::take(&mut self.dirty_from);
        let mut scan_from = std::mem::take(&mut self.scan_from);
        let mut stack = std::mem::take(&mut self.node_stack);
        for v in 0..n {
            dirty_from[v] = usize::MAX;
            scan_from[v] = usize::MAX;
        }
        debug_assert!(stack.is_empty());

        // --- seed (a): reverted tasks dirty their node suffix from the
        // evicted slot on (their node successors shift up to the gap)
        telemetry::counter_add(telemetry::Counter::SeedRevert, revert.len() as u64);
        for &gid in revert {
            let a = self
                .plan
                .get(gid)
                .expect("reverted task missing from the belief");
            debug_assert!(!self.dispatched(gid), "revert of a dispatched task");
            let idx = self
                .plan
                .timelines()
                .find_idx(a.node, gid, a.start)
                .expect("reverted task has no slot");
            lower(&mut dirty_from, &mut stack, a.node, idx);
        }

        // --- seed (c): pending tasks whose `now` floor moved.  Pending
        // slots are start-sorted, so the stale ones are a prefix of each
        // node's pending suffix: one O(1) probe at the cursor suffices —
        // the suffix-closure covers the rest of the run.
        for v in 0..n {
            let tl = self.plan.timelines();
            let c = self.cursor[v];
            if c < tl.n_slots(v) && tl.starts(v)[c] < now {
                telemetry::counter_inc(telemetry::Counter::SeedMovedFloor);
                lower(&mut dirty_from, &mut stack, v, c);
            }
        }

        // --- seed (b): dispatched divergence.  Only tasks that started
        // or finished since the last refresh, plus the currently running
        // set (their `max(expected, now)` cap moves with `now`), can
        // have drifted from the belief — everything else was snapped to
        // its (immutable) truth by an earlier refresh.
        let mut cand = std::mem::take(&mut self.cand);
        cand.clear();
        cand.append(&mut self.dirty_dispatched);
        cand.extend(self.node_running.iter().flatten().copied());
        cand.sort_unstable();
        cand.dedup();
        self.fix.clear();
        let mut fix = std::mem::take(&mut self.fix);
        for &gid in &cand {
            if !self.dispatched(gid) {
                // a crash killed this attempt since it was recorded; the
                // slot rejoins the pending set through the forced
                // failure replan's full refresh (fault runs only —
                // without faults every candidate is still dispatched)
                continue;
            }
            let truth = self.truth_of(gid, now);
            let pa = self
                .plan
                .get(gid)
                .expect("dispatched task missing from the belief");
            if *pa != truth {
                fix.push((gid, truth));
            }
        }
        telemetry::counter_add(telemetry::Counter::SeedDivergence, fix.len() as u64);
        for &(gid, truth) in &fix {
            let v = truth.node;
            let c = self.cursor[v];
            debug_assert!(c > 0, "fix on a node with no dispatched prefix");
            // dispatched-tail seed: the first pending slot chains off the
            // last dispatched finish; re-derive the suffix if it moved
            let old_tail = self.plan.timelines().finishes(v)[c - 1];
            let new_tail = match self.node_running[v] {
                Some(g) => self.expected_finish[self.ids.ix(g)].max(now),
                None => self.node_free[v],
            };
            if old_tail != new_tail && c < self.plan.timelines().n_slots(v) {
                lower(&mut dirty_from, &mut stack, v, c);
            }
            // graph-successor seeds: only a *finish* change can move a
            // successor (the node never diverges — dispatch follows the
            // plan's placement)
            let pa = self.plan.get(gid).unwrap();
            if pa.finish != truth.finish {
                let g = &self.prob.graphs[gid.graph as usize].1;
                for &(s, _) in g.successors(gid.task as usize) {
                    let sgid = Gid::new(gid.graph as usize, s);
                    if self.revert_set.contains(self.ids.ix(sgid)) || self.dispatched(sgid) {
                        continue;
                    }
                    let Some(sa) = self.plan.get(sgid) else {
                        continue;
                    };
                    let sidx = self
                        .plan
                        .timelines()
                        .find_idx(sa.node, sgid, sa.start)
                        .expect("pending successor has no slot");
                    lower(&mut dirty_from, &mut stack, sa.node, sidx);
                }
            }
        }

        // --- closure: a dirty task can move, so its node successors
        // (the rest of the suffix) and pending graph successors are
        // dirty too.  `scan_from` guarantees each slot's successor list
        // is walked once, however often the suffix grows.
        while let Some(v) = stack.pop() {
            let lo = dirty_from[v];
            let hi = scan_from[v].min(self.plan.timelines().n_slots(v));
            if lo >= hi {
                continue;
            }
            scan_from[v] = lo;
            for idx in lo..hi {
                let gid = self.plan.timelines().slot_gids(v)[idx];
                debug_assert!(
                    !self.dispatched(gid),
                    "dirty cone reached the dispatched prefix on node {v}"
                );
                let g = &self.prob.graphs[gid.graph as usize].1;
                if self.revert_set.contains(self.ids.ix(gid)) {
                    // a reverted task's pending successors are reverted
                    // with it (reverts are graph-granular), so there is
                    // nothing to propagate to
                    debug_assert!(
                        g.successors(gid.task as usize).iter().all(|&(s, _)| {
                            let sgid = Gid::new(gid.graph as usize, s);
                            self.revert_set.contains(self.ids.ix(sgid)) || self.dispatched(sgid)
                        }),
                        "reverted {gid} leaves a kept pending successor"
                    );
                    continue;
                }
                for &(s, _) in g.successors(gid.task as usize) {
                    let sgid = Gid::new(gid.graph as usize, s);
                    if self.revert_set.contains(self.ids.ix(sgid)) || self.dispatched(sgid) {
                        continue;
                    }
                    let Some(sa) = self.plan.get(sgid) else {
                        continue;
                    };
                    let sidx = self
                        .plan
                        .timelines()
                        .find_idx(sa.node, sgid, sa.start)
                        .expect("pending successor has no slot");
                    lower(&mut dirty_from, &mut stack, sa.node, sidx);
                }
            }
        }

        // --- evict the cone (per-node pending suffixes), capturing the
        // kept tasks in slot order; reverted slots leave the belief here
        let mut n_kept = 0usize;
        for v in 0..n {
            self.refresh_order[v].clear();
            let from = dirty_from[v];
            if from >= self.plan.timelines().n_slots(v) {
                continue;
            }
            debug_assert!(from >= self.cursor[v], "cone overlaps dispatched prefix");
            self.touched[v] = true;
            for &gid in &self.plan.timelines().slot_gids(v)[from..] {
                if !self.revert_set.contains(self.ids.ix(gid)) {
                    self.refresh_order[v].push(gid);
                }
            }
            n_kept += self.refresh_order[v].len();
            let evicted = self.plan.timelines().n_slots(v) - from;
            telemetry::counter_add(telemetry::Counter::ConeEvicted, evicted as u64);
            self.plan.unassign_tail(v, from);
        }
        debug_assert!(
            revert.iter().all(|g| self.plan.get(*g).is_none()),
            "a reverted task survived cone eviction"
        );

        // --- apply the dispatched fixes, two-phase like the oracle.
        // Every kept pending slot starts at or after its node's belief
        // tail (else the tail seed or the `now` floor coned it), so the
        // truths can never overlap a kept slot.
        for &(gid, _) in &fix {
            self.plan.unassign(gid);
        }
        for &(gid, a) in &fix {
            self.plan.assign(gid, a);
            self.touched[a.node] = true;
        }

        // --- re-derive the cone with a readiness worklist (replaces the
        // oracle's O(rounds × nodes) round-robin): a task is ready once
        // its in-cone node predecessor and in-cone graph predecessors
        // are placed; everything else reads final values from the plan.
        self.cone.reset(self.ids.len());
        for v in 0..n {
            for (j, &gid) in self.refresh_order[v].iter().enumerate() {
                self.cone.insert(
                    self.ids.ix(gid),
                    ConeEntry {
                        node: v as u32,
                        pos: j as u32,
                        blockers: u32::from(j > 0),
                    },
                );
            }
        }
        for order in &self.refresh_order {
            for &gid in order {
                let g = &self.prob.graphs[gid.graph as usize].1;
                let mut extra = 0u32;
                for &(p, _) in g.predecessors(gid.task as usize) {
                    let pgid = Gid::new(gid.graph as usize, p);
                    if self.cone.contains_key(self.ids.ix(pgid)) {
                        extra += 1;
                    }
                }
                if extra > 0 {
                    self.cone.get_mut(self.ids.ix(gid)).unwrap().blockers += extra;
                }
            }
        }
        self.ready.clear();
        for v in 0..n {
            if self.refresh_order[v].is_empty() {
                continue;
            }
            self.node_tail[v] = self
                .plan
                .timelines()
                .finishes(v)
                .last()
                .copied()
                .unwrap_or(0.0);
            for (j, &gid) in self.refresh_order[v].iter().enumerate() {
                if self.cone.get(self.ids.ix(gid)).unwrap().blockers == 0 {
                    self.ready.push((v as u32, j as u32));
                }
            }
        }
        let mut placed = 0usize;
        while let Some((v, j)) = self.ready.pop() {
            let v = v as usize;
            let gid = self.refresh_order[v][j as usize];
            let (arrival, g) = &self.prob.graphs[gid.graph as usize];
            // same accumulation order as the oracle, for bit-exactness
            let mut start = arrival.max(now).max(self.node_tail[v]);
            // crashed-node belief floor, exactly as in the oracle
            if self.fault_floor[v] > start {
                start = self.fault_floor[v];
            }
            for &(p, data) in g.predecessors(gid.task as usize) {
                let pgid = Gid::new(gid.graph as usize, p);
                let pa = self
                    .plan
                    .get(pgid)
                    .expect("predecessor neither placed nor committed in the belief");
                start =
                    start.max(pa.finish + self.prob.network.comm_time(data, pa.node, v));
            }
            let dur = self.prob.network.exec_time(g.cost(gid.task as usize), v);
            self.plan.assign_tail(
                gid,
                Assignment {
                    node: v,
                    start,
                    finish: start + dur,
                },
            );
            self.node_tail[v] = start + dur;
            placed += 1;
            if (j as usize) + 1 < self.refresh_order[v].len() {
                let ngid = self.refresh_order[v][j as usize + 1];
                let e = self.cone.get_mut(self.ids.ix(ngid)).unwrap();
                e.blockers -= 1;
                if e.blockers == 0 {
                    self.ready.push((e.node, e.pos));
                }
            }
            for &(s, _) in g.successors(gid.task as usize) {
                let sgid = Gid::new(gid.graph as usize, s);
                if let Some(e) = self.cone.get_mut(self.ids.ix(sgid)) {
                    e.blockers -= 1;
                    if e.blockers == 0 {
                        self.ready.push((e.node, e.pos));
                    }
                }
            }
        }
        assert_eq!(
            placed, n_kept,
            "belief refresh deadlocked — dirty cone inconsistent with deps"
        );
        telemetry::counter_add(telemetry::Counter::ConeRederived, n_kept as u64);

        fix.clear();
        self.fix = fix;
        cand.clear();
        self.cand = cand;
        self.dirty_from = dirty_from;
        self.scan_from = scan_from;
        self.node_stack = stack;
        n_kept
    }

    /// Recompute the dispatched-prefix cursors after a replan reshaped
    /// the plan's slot lists — only for the **touched** nodes (belief
    /// refresh evictions/fixes plus heuristic insertions; the callers
    /// stamp [`Sim::touched`]).  An untouched node's slot list did not
    /// change during the replan and `TaskStart` maintains its cursor
    /// incrementally, so its recount — and its share of the prefix
    /// `debug_assert` walk — is skipped.  The full-refresh oracle
    /// touches every node, restoring the old full recompute.
    fn recompute_cursors(&mut self) {
        for v in 0..self.n_nodes() {
            if !self.touched[v] {
                continue;
            }
            self.touched[v] = false;
            let gids = self.plan.timelines().slot_gids(v);
            let mut c = 0;
            while c < gids.len() && self.realized.get(gids[c]).is_some() {
                c += 1;
            }
            debug_assert!(
                gids[c..].iter().all(|&g| self.realized.get(g).is_none()),
                "dispatched tasks are not a slot-order prefix on node {v}"
            );
            self.cursor[v] = c;
        }
    }

    /// Sorted `(gid, node, start)` snapshot of the dispatched set.
    fn frozen_snapshot(&self) -> Vec<(Gid, usize, f64)> {
        let mut out: Vec<(Gid, usize, f64)> = self
            .realized
            .iter()
            .map(|(g, a)| (*g, a.node, a.start))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

/// The reactive coordinator: an arrival [`Policy`] plus a straggler
/// driver — the built-in [`Reaction`] or any [`PreemptionPolicy`]
/// controller — wrapped around a base heuristic, driven by the
/// discrete-event runtime.
pub struct ReactiveCoordinator {
    pub policy: Policy,
    scheduler: Box<dyn Scheduler>,
    cfg: SimConfig,
    /// Straggler controller from the [`crate::policy`] engine; when
    /// `None` the built-in [`SimConfig::reaction`] trigger drives.
    preemption: Option<Box<dyn PreemptionPolicy>>,
    ws: CompositeWorkspace,
    pending: Vec<Gid>,
}

impl ReactiveCoordinator {
    pub fn new(policy: Policy, scheduler: Box<dyn Scheduler>, cfg: SimConfig) -> Self {
        Self {
            policy,
            scheduler,
            cfg,
            preemption: None,
            ws: CompositeWorkspace::new(),
            pending: Vec::new(),
        }
    }

    /// A coordinator whose straggler decisions come from a
    /// [`PreemptionPolicy`] controller instead of the built-in
    /// [`Reaction`].  The controller replaces the built-in reaction
    /// entirely: `cfg.reaction` is normalized to [`Reaction::None`]
    /// (same behavior in debug and release — a configured `LastK` would
    /// otherwise be silently unreachable).
    pub fn with_policy(
        policy: Policy,
        scheduler: Box<dyn Scheduler>,
        mut cfg: SimConfig,
        preemption: Box<dyn PreemptionPolicy>,
    ) -> Self {
        cfg.reaction = Reaction::None;
        Self {
            policy,
            scheduler,
            cfg,
            preemption: Some(preemption),
            ws: CompositeWorkspace::new(),
            pending: Vec::new(),
        }
    }

    /// `5P-HEFT σ0.30 L3@0.25` style label.
    pub fn label(&self) -> String {
        let straggler = match &self.preemption {
            Some(p) => p.label(),
            None => self.cfg.reaction.label(),
        };
        format!(
            "{}-{} σ{:.2} {}",
            self.policy.label(),
            self.scheduler.name(),
            self.cfg.noise_std,
            straggler
        )
    }

    /// Run the reactive event loop over the whole problem.
    pub fn run(&mut self, prob: &DynamicProblem) -> SimResult {
        let mut sim = Sim::new(prob, self.cfg);
        sim.events_peak = sim.queue.len();

        while let Some((t, ev)) = sim.queue.pop() {
            match ev {
                SimEvent::GraphArrival { idx } => {
                    sim.arrived = idx + 1;
                    sim.log.push(SimLogEntry {
                        time: t,
                        kind: SimLogKind::Arrival { graph: idx },
                    });
                    let window = self.policy.window(idx);
                    self.replan(
                        &mut sim,
                        t,
                        RevertSel::Range(idx - window..idx),
                        Some(idx),
                        false,
                    );
                    sim.dispatch_all(t);
                }
                SimEvent::TaskStart { gid, node, epoch } => {
                    if epoch != sim.node_epoch[node] || sim.dispatched(gid) {
                        continue; // invalidated by a replan or newer decision
                    }
                    debug_assert!(sim.node_running[node].is_none());
                    debug_assert!(!sim.node_down[node], "dispatch onto a crashed node");
                    let g = &prob.graphs[gid.graph as usize].1;
                    let est = prob.network.exec_time(g.cost(gid.task as usize), node);
                    let mut rdur = est * sim.noise.factor(gid);
                    if sim.faults.enabled() {
                        // Degrade stretches the realized duration of a
                        // task *starting* inside a slowdown window (the
                        // multiply is gated, not the 1.0 factor, so the
                        // zero-fault event math never runs fault code)
                        rdur *= sim.faults.degrade_factor(node, t);
                    }
                    sim.realized.assign(
                        gid,
                        Assignment {
                            node,
                            start: t,
                            finish: t + rdur,
                        },
                    );
                    sim.expected_finish[sim.ids.ix(gid)] = t + est;
                    sim.node_running[node] = Some(gid);
                    sim.pending_start[node] = None; // decision consumed
                    sim.node_free[node] = t + rdur;
                    sim.cursor[node] += 1;
                    sim.dirty_dispatched.push(gid);
                    let attempt = sim.attempt[sim.ids.ix(gid)];
                    sim.queue.push(t + rdur, SimEvent::TaskFinish { gid, attempt });
                    sim.log.push(SimLogEntry {
                        time: t,
                        kind: SimLogKind::Start { gid, node },
                    });
                }
                SimEvent::TaskFinish { gid, attempt } => {
                    if attempt != sim.attempt[sim.ids.ix(gid)] {
                        continue; // the attempt was killed by a crash
                    }
                    let a = *sim.realized.get(gid).unwrap();
                    sim.completed[sim.ids.ix(gid)] = true;
                    sim.n_done += 1;
                    if sim.was_killed[sim.ids.ix(gid)] {
                        sim.n_reexecuted += 1; // a killed task made it through
                    }
                    debug_assert_eq!(sim.node_running[a.node], Some(gid));
                    sim.node_running[a.node] = None;
                    sim.dirty_dispatched.push(gid);
                    let expected = sim.expected_finish[sim.ids.ix(gid)];
                    let lateness = t - expected;
                    sim.log.push(SimLogEntry {
                        time: t,
                        kind: SimLogKind::Finish {
                            gid,
                            node: a.node,
                            lateness,
                        },
                    });
                    // graph-completion feedback for adaptive controllers
                    // (before this finish's own decision, so adaptation
                    // sees the freshest stretch)
                    let gi = gid.graph as usize;
                    sim.graph_left[gi] -= 1;
                    if sim.graph_left[gi] == 0 {
                        if let Some(p) = self.preemption.as_mut() {
                            let (arrival, g) = &prob.graphs[gi];
                            let ideal = ideal_response(g, &prob.network);
                            let stretch = if ideal > 0.0 {
                                (t - arrival) / ideal
                            } else {
                                1.0
                            };
                            p.on_graph_complete(gi, stretch);
                        }
                    }
                    // straggler decision: policy engine if installed,
                    // else the built-in PR-2 reaction
                    let est = expected - a.start;
                    let decision = self.preemption.as_mut().map(|p| {
                        p.on_finish(&FinishObservation {
                            gid,
                            time: t,
                            est,
                            lateness,
                            arrived: sim.arrived,
                        })
                    });
                    match decision {
                        Some(Decision::Reschedule(scope)) => {
                            let sel = match scope.order {
                                ScopeOrder::Recency => {
                                    let lo = sim.arrived - scope.last_k.min(sim.arrived);
                                    RevertSel::Range(lo..sim.arrived)
                                }
                                ScopeOrder::DeadlineUrgency => {
                                    RevertSel::Urgent(scope.last_k)
                                }
                            };
                            let ran = self.replan_scoped(
                                &mut sim,
                                t,
                                sel,
                                None,
                                true,
                                scope.max_reverted,
                                false,
                            );
                            if let Some(n_reverted) = ran {
                                if let Some(p) = self.preemption.as_mut() {
                                    p.on_replan(t, n_reverted);
                                }
                            }
                        }
                        Some(Decision::Hold) => {}
                        None => {
                            if let Reaction::LastK { k, threshold } = self.cfg.reaction {
                                if lateness > threshold * est {
                                    let lo = sim.arrived - k.min(sim.arrived);
                                    self.replan(
                                        &mut sim,
                                        t,
                                        RevertSel::Range(lo..sim.arrived),
                                        None,
                                        true,
                                    );
                                }
                            }
                        }
                    }
                    sim.dispatch_all(t);
                }
                SimEvent::NodeDown { node } => {
                    // drained workload: remaining armed windows are
                    // inert no-ops (no log, no state — NodeUp stops
                    // re-arming, so the queue empties)
                    if sim.n_done == prob.total_tasks() {
                        continue;
                    }
                    debug_assert!(!sim.node_down[node], "crash windows overlap");
                    let k = sim.fault_k[node];
                    let (down, up) =
                        sim.faults.window(node, k).expect("crash event without window");
                    debug_assert_eq!(down.to_bits(), t.to_bits());
                    sim.node_down[node] = true;
                    sim.fault_floor[node] = up;
                    // EFT mask: the heuristic can keep placing on the
                    // node, but never before the recovery instant
                    sim.plan.timelines_mut().set_avail_floor(node, up);
                    // the node frees at recovery, whatever it was doing
                    // (a running attempt's phantom finish is void — the
                    // kill below voids the attempt itself)
                    sim.node_free[node] = up;
                    let mut wasted = 0.0;
                    let mut killed = false;
                    if let Some(gid) = sim.node_running[node].take() {
                        let a = *sim.realized.get(gid).unwrap();
                        wasted = t - a.start;
                        killed = true;
                        let ix = sim.ids.ix(gid);
                        sim.attempt[ix] += 1; // in-flight finish dies on pop
                        sim.was_killed[ix] = true;
                        sim.realized.unassign(gid);
                        // the killed slot is pending again; it was the
                        // last dispatched slot (one task runs at a
                        // time), so shrinking the prefix by one restores
                        // the cursor invariant
                        sim.cursor[node] -= 1;
                        sim.wasted_s += wasted;
                        sim.n_killed += 1;
                        sim.log.push(SimLogEntry {
                            time: t,
                            kind: SimLogKind::Kill { gid, node, wasted },
                        });
                        telemetry::counter_inc(telemetry::Counter::TaskKills);
                    }
                    sim.node_epoch[node] += 1; // queued start decisions die
                    sim.pending_start[node] = None;
                    sim.fault_dirty = true; // next refresh = full oracle
                    sim.log.push(SimLogEntry {
                        time: t,
                        kind: SimLogKind::NodeDown { node, wasted },
                    });
                    telemetry::counter_inc(telemetry::Counter::NodeFailures);
                    sim.queue.push(up, SimEvent::NodeUp { node });
                    // forced failure replan: revert the orphaned scope,
                    // uncapped (skipped when the node held no planned
                    // undispatched work — then there is nothing to move)
                    let ran = self.replan_scoped(
                        &mut sim,
                        t,
                        RevertSel::Node(node),
                        None,
                        true,
                        usize::MAX,
                        true,
                    );
                    let n_orphaned = ran.unwrap_or(0);
                    if let Some(n_reverted) = ran {
                        if let Some(p) = self.preemption.as_mut() {
                            // Budgeted charges forced reverts against
                            // its bucket (documented overdraw)
                            p.on_replan(t, n_reverted);
                        }
                    }
                    // the controller may extend the recovery with extra
                    // scope of its own (FailureAware reverts endangered
                    // neighbors; the default holds)
                    let decision = self.preemption.as_mut().map(|p| {
                        p.on_failure(&FailureObservation {
                            node,
                            time: t,
                            n_orphaned,
                            killed,
                            arrived: sim.arrived,
                        })
                    });
                    if let Some(Decision::Reschedule(scope)) = decision {
                        let sel = match scope.order {
                            ScopeOrder::Recency => {
                                let lo = sim.arrived - scope.last_k.min(sim.arrived);
                                RevertSel::Range(lo..sim.arrived)
                            }
                            ScopeOrder::DeadlineUrgency => {
                                RevertSel::Urgent(scope.last_k)
                            }
                        };
                        let ran = self.replan_scoped(
                            &mut sim,
                            t,
                            sel,
                            None,
                            true,
                            scope.max_reverted,
                            true,
                        );
                        if let Some(n_reverted) = ran {
                            if let Some(p) = self.preemption.as_mut() {
                                p.on_replan(t, n_reverted);
                            }
                        }
                    }
                    sim.dispatch_all(t);
                }
                SimEvent::NodeUp { node } => {
                    // a NodeUp is only ever armed by a processed
                    // NodeDown, so the node is genuinely down — even if
                    // the workload drained mid-window, recovery
                    // accounting completes the pair
                    debug_assert!(sim.node_down[node], "recovery without a crash");
                    let k = sim.fault_k[node];
                    let (down, up) =
                        sim.faults.window(node, k).expect("recovery event without window");
                    debug_assert_eq!(up.to_bits(), t.to_bits());
                    sim.fault_k[node] = k + 1;
                    sim.node_down[node] = false;
                    sim.fault_floor[node] = 0.0;
                    sim.plan.timelines_mut().clear_avail_floor(node);
                    let downtime = t - down;
                    sim.recovery_total_s += downtime;
                    sim.n_recoveries += 1;
                    sim.log.push(SimLogEntry {
                        time: t,
                        kind: SimLogKind::NodeUp { node, downtime },
                    });
                    telemetry::counter_inc(telemetry::Counter::NodeRecoveries);
                    telemetry::hist_record(
                        telemetry::Hist::RecoveryNs,
                        (downtime * 1e9) as u64,
                    );
                    // re-arm the next crash window while work remains
                    if sim.n_done < prob.total_tasks() {
                        let (next_down, _) = sim
                            .faults
                            .window(node, k + 1)
                            .expect("Crash model draws windows");
                        sim.queue.push(next_down, SimEvent::NodeDown { node });
                    }
                    sim.dispatch_all(t);
                }
            }
            sim.events_peak = sim.events_peak.max(sim.queue.len());
            telemetry::hist_record(
                telemetry::Hist::EventQueueDepth,
                sim.queue.len() as u64,
            );
        }

        assert_eq!(
            sim.realized.n_assigned(),
            prob.total_tasks(),
            "reactive runtime deadlocked before completing the workload"
        );
        // The heuristic phase is a strict sub-region of every replan
        // pass, so its accumulated wall time can never exceed the whole
        // passes' (docs/METRICS.md "⊇ runtime_s"; epsilon covers clock
        // granularity on platforms with coarse Instants).
        debug_assert!(
            sim.sched_runtime_s <= sim.replan_wall_s + 1e-9,
            "sched_runtime_s {} exceeds replan_wall_s {}",
            sim.sched_runtime_s,
            sim.replan_wall_s
        );

        SimResult {
            schedule: sim.realized,
            log: sim.log,
            replans: sim.replans,
            sched_runtime_s: sim.sched_runtime_s,
            replan_wall_s: sim.replan_wall_s,
            refresh_wall_s: sim.refresh_wall_s,
            bookkeep_wall_s: sim.bookkeep_wall_s,
            events_peak: sim.events_peak,
            replan_allocs: sim.replan_allocs,
            wasted_work_s: sim.wasted_s,
            n_killed: sim.n_killed,
            n_reexecuted: sim.n_reexecuted,
            recovery_total_s: sim.recovery_total_s,
            n_recoveries: sim.n_recoveries,
            faults_enabled: sim.faults.enabled(),
        }
    }

    /// [`replan_scoped`](Self::replan_scoped) without a revert cap — the
    /// arrival-time and built-in-reaction paths.
    fn replan(
        &mut self,
        sim: &mut Sim<'_>,
        now: f64,
        sel: RevertSel,
        new_graph: Option<usize>,
        straggler: bool,
    ) -> Option<usize> {
        self.replan_scoped(sim, now, sel, new_graph, straggler, usize::MAX, false)
    }

    /// One rescheduling pass at time `now`: revert the still-pending
    /// tasks of the graphs `sel` selects (plus all tasks of a newly
    /// arrived graph), refresh the belief to the observed state, and run
    /// the base heuristic in place inside a timeline transaction.  At
    /// most `max_reverted` tasks are reverted (a
    /// [`crate::policy::Budgeted`] cap); when the revertible set is
    /// larger, whole per-graph blocks are kept in priority order —
    /// newest arrival first for [`RevertSel::Range`], most
    /// deadline-endangered first for [`RevertSel::Urgent`] — while they
    /// fit the cap (misfit blocks are skipped, not split) and everything
    /// else stays in place.
    /// Returns the number of tasks actually reverted, or `None` when the
    /// pass was skipped because nothing was revertible and no new graph
    /// arrived (no replan happened, nothing is recorded).
    fn replan_scoped(
        &mut self,
        sim: &mut Sim<'_>,
        now: f64,
        sel: RevertSel,
        new_graph: Option<usize>,
        straggler: bool,
        max_reverted: usize,
        failure: bool,
    ) -> Option<usize> {
        let wall0 = Instant::now();
        let allocs0 = crate::alloc_count::alloc_count();
        self.pending.clear();
        let mut pending = std::mem::take(&mut self.pending);
        let push_graph = |sim: &Sim<'_>, pending: &mut Vec<Gid>, j: usize| {
            let g = &sim.prob.graphs[j].1;
            for task in 0..g.n_tasks() {
                let gid = Gid::new(j, task);
                if sim.plan.get(gid).is_some() && !sim.dispatched(gid) {
                    pending.push(gid);
                }
            }
        };
        match sel {
            RevertSel::Range(range) => {
                for j in range {
                    push_graph(sim, &mut pending, j);
                }
            }
            RevertSel::Urgent(k) => {
                // `sim.urgency` holds the k most endangered graphs,
                // least-endangered first, so the most endangered block
                // lands at the tail where the cap keeps it
                sim.select_urgent(k);
                for &(_, j) in &sim.urgency {
                    push_graph(sim, &mut pending, j);
                }
            }
            RevertSel::Node(v) => {
                // every pending slot on the crashed node names an
                // orphaned graph (the walk starts at the cursor: the
                // dispatched prefix stays frozen, crash or not).  Small
                // per-failure allocation — crashes are rare events, the
                // zero-alloc steady-state claim covers the fault-free
                // path only.
                let mut graphs: Vec<usize> = sim.plan.timelines().slot_gids(v)
                    [sim.cursor[v]..]
                    .iter()
                    .map(|g| g.graph as usize)
                    .collect();
                graphs.sort_unstable();
                graphs.dedup();
                for j in graphs {
                    push_graph(sim, &mut pending, j);
                }
            }
        }
        if pending.len() > max_reverted {
            // Budget cap, graph-granular: walking whole per-graph blocks
            // from the tail (highest priority: newest arrival for
            // recency scopes, most endangered for urgency scopes)
            // backwards, keep every block that still fits the remaining
            // budget and skip the ones that don't (a misfit tail block
            // must not abort the revert — a lower-priority, smaller
            // block may still fit).  Partial graphs are never reverted:
            // a kept pending task whose parent was reverted would be
            // underivable in the belief refresh (dependencies are
            // intra-graph).  Kept blocks are compacted to the tail in
            // their original (priority-ascending) order.
            let mut budget = max_reverted;
            let mut write = pending.len();
            let mut read = pending.len();
            while read > 0 {
                let g = pending[read - 1].graph;
                let mut lo = read;
                while lo > 0 && pending[lo - 1].graph == g {
                    lo -= 1;
                }
                let len = read - lo;
                if len <= budget {
                    budget -= len;
                    write -= len;
                    if write != lo {
                        pending.copy_within(lo..read, write);
                    }
                }
                read = lo;
            }
            pending.drain(..write);
        }
        let n_reverted = pending.len();
        if n_reverted == 0 && new_graph.is_none() {
            self.pending = pending;
            return None; // straggler fired but nothing is revertible
        }

        // belief refresh drops the reverted slots and re-derives the
        // expected times of the affected frozen pending tasks (all of
        // them under the full-refresh oracle, the dirty cone otherwise)
        let refresh_span = telemetry::Span::start(telemetry::Hist::RefreshWallNs);
        let n_refreshed = sim.refresh_belief(now, &pending);
        let refresh_s = refresh_span.finish();

        if let Some(i) = new_graph {
            let g = &sim.prob.graphs[i].1;
            for task in 0..g.n_tasks() {
                pending.push(Gid::new(i, task));
            }
        }

        let problem = self
            .ws
            .build_floored(&pending, sim.prob, &sim.plan, now);
        sim.plan.timelines_mut().begin_txn();
        let heuristic_span = telemetry::Span::start(telemetry::Hist::HeuristicWallNs);
        let assignments =
            self.scheduler
                .schedule(problem, &sim.prob.network, sim.plan.timelines_mut());
        let heuristic_s = heuristic_span.finish();
        sim.sched_runtime_s += heuristic_s;
        for (idx, a) in assignments.iter().enumerate() {
            sim.plan.record(problem.tasks[idx].gid, *a);
            sim.touched[a.node] = true;
        }
        let n_pending = problem.n_tasks();
        sim.plan.timelines_mut().commit_txn();

        for v in 0..sim.n_nodes() {
            sim.node_epoch[v] += 1; // stale dispatch decisions die here
            // the queued decisions just went stale: forget them so the
            // next dispatch_all re-pushes under the new epoch (a kept
            // record would dedup against a dead event → deadlock)
            sim.pending_start[v] = None;
        }
        sim.recompute_cursors();

        let wall_s = wall0.elapsed().as_secs_f64();
        // bookkeeping is the remainder of the pass: pending collection,
        // composite build, journal commit, cursor recompute (clamped so
        // the three phases reconcile with `wall_s` by construction)
        let bookkeep_s = (wall_s - refresh_s - heuristic_s).max(0.0);
        sim.replan_wall_s += wall_s;
        sim.refresh_wall_s += refresh_s;
        sim.bookkeep_wall_s += bookkeep_s;
        // counts 0 unless the counting allocator is registered (test
        // builds or `--features alloc-count`)
        sim.replan_allocs += crate::alloc_count::alloc_count() - allocs0;
        telemetry::counter_inc(telemetry::Counter::Replans);
        if straggler {
            telemetry::counter_inc(telemetry::Counter::StragglerReplans);
        }
        if failure {
            telemetry::counter_inc(telemetry::Counter::FailureReplans);
        }
        telemetry::hist_record(telemetry::Hist::ReplanWallNs, (wall_s * 1e9) as u64);
        telemetry::hist_record(telemetry::Hist::BookkeepWallNs, (bookkeep_s * 1e9) as u64);
        telemetry::hist_record(telemetry::Hist::ConeSize, n_refreshed as u64);

        sim.log.push(SimLogEntry {
            time: now,
            kind: SimLogKind::Replan {
                straggler,
                n_reverted,
                n_pending,
            },
        });
        let frozen = if sim.cfg.record_frozen {
            sim.frozen_snapshot()
        } else {
            Vec::new()
        };
        sim.replans.push(ReplanRecord {
            time: now,
            straggler,
            failure,
            n_reverted,
            n_pending,
            n_refreshed,
            wall_s,
            refresh_s,
            heuristic_s,
            bookkeep_s,
            frozen,
        });
        self.pending = pending;
        Some(n_reverted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Coordinator;
    use crate::schedulers::SchedulerKind;
    use crate::sim::replay;
    use crate::workloads::Dataset;

    fn sig(s: &Schedule) -> Vec<(Gid, usize, u64, u64)> {
        let mut v: Vec<(Gid, usize, u64, u64)> = s
            .iter()
            .map(|(g, a)| (*g, a.node, a.start.to_bits(), a.finish.to_bits()))
            .collect();
        v.sort();
        v
    }

    /// With perfect estimates and no preemption the reactive runtime
    /// executes the plan exactly: every placement of a non-preemptive
    /// static plan is causal (nothing is ever re-placed), so the
    /// realized schedule must be bit-identical to the static
    /// coordinator's — with and without a straggler reaction armed (it
    /// can never fire at zero lateness).  Preemptive policies are NOT
    /// expected to match bit-exactly in general: the static coordinator
    /// may re-place a reverted task into an already-past idle gap
    /// (clairvoyant plan-time convention), which a causal runtime
    /// cannot do — see `zero_noise_single_graph_matches_static`.
    #[test]
    fn zero_noise_np_matches_static_coordinator() {
        for dataset in [Dataset::Synthetic, Dataset::RiotBench] {
            let prob = dataset.instance(10, 42);
            for kind in [SchedulerKind::Heft, SchedulerKind::Cpop] {
                let mut st = Coordinator::new(Policy::NonPreemptive, kind.make(0));
                let want = st.run(&prob);
                for reaction in [
                    Reaction::None,
                    Reaction::LastK {
                        k: 2,
                        threshold: 0.25,
                    },
                ] {
                    let cfg = SimConfig {
                        noise_std: 0.0,
                        noise_seed: 9,
                        reaction,
                        record_frozen: false,
                        full_refresh: false,
                        faults: crate::sim::FaultConfig::NONE,
                    };
                    let mut rc =
                        ReactiveCoordinator::new(Policy::NonPreemptive, kind.make(0), cfg);
                    let got = rc.run(&prob);
                    assert_eq!(
                        sig(&got.schedule),
                        sig(&want.schedule),
                        "{dataset:?} NP-{} {reaction:?}",
                        kind.name()
                    );
                    assert_eq!(got.n_straggler_replans(), 0);
                }
            }
        }
    }

    /// A single-graph instance has no later arrival, so no policy ever
    /// reverts anything and the causal runtime matches the static plan
    /// bit-exactly for every policy.
    #[test]
    fn zero_noise_single_graph_matches_static() {
        let full = Dataset::WfCommons.instance(3, 5);
        let prob = DynamicProblem::new(full.network.clone(), full.graphs[..1].to_vec());
        for policy in [Policy::NonPreemptive, Policy::LastK(5), Policy::Preemptive] {
            let mut st = Coordinator::new(policy, SchedulerKind::Heft.make(0));
            let want = st.run(&prob);
            let cfg = SimConfig {
                noise_std: 0.0,
                noise_seed: 0,
                reaction: Reaction::None,
                record_frozen: false,
                full_refresh: false,
                faults: crate::sim::FaultConfig::NONE,
            };
            let mut rc = ReactiveCoordinator::new(policy, SchedulerKind::Heft.make(0), cfg);
            let got = rc.run(&prob);
            assert_eq!(sig(&got.schedule), sig(&want.schedule), "{policy:?}");
        }
    }

    /// Preemptive policies under zero noise: complete, operationally
    /// valid, §II-valid (durations match estimates at zero noise), one
    /// arrival replan per graph, and no straggler ever fires.
    #[test]
    fn zero_noise_preemptive_is_causal_and_valid() {
        let prob = Dataset::Synthetic.instance(10, 42);
        for policy in [Policy::LastK(3), Policy::Preemptive] {
            let cfg = SimConfig {
                noise_std: 0.0,
                noise_seed: 0,
                reaction: Reaction::LastK {
                    k: 2,
                    threshold: 0.25,
                },
                record_frozen: false,
                full_refresh: false,
                faults: crate::sim::FaultConfig::NONE,
            };
            let mut rc = ReactiveCoordinator::new(policy, SchedulerKind::Heft.make(0), cfg);
            let res = rc.run(&prob);
            assert_eq!(res.schedule.n_assigned(), prob.total_tasks());
            assert_eq!(res.n_straggler_replans(), 0, "{policy:?}");
            assert_eq!(res.n_replans(), prob.graphs.len(), "{policy:?}");
            let rep = replay(&res.schedule, &prob.graphs, &prob.network);
            assert!(rep.errors.is_empty(), "{policy:?}: {:?}", &rep.errors[..rep.errors.len().min(3)]);
            let viol =
                crate::schedule::validate(&res.schedule, &prob.graphs, &prob.network);
            assert!(viol.is_empty(), "{policy:?}: {:?}", &viol[..viol.len().min(3)]);
        }
    }

    #[test]
    fn noisy_run_is_complete_and_replay_valid() {
        let prob = Dataset::Synthetic.instance(12, 7);
        for reaction in [
            Reaction::None,
            Reaction::LastK {
                k: 3,
                threshold: 0.2,
            },
        ] {
            let cfg = SimConfig {
                noise_std: 0.5,
                noise_seed: 3,
                reaction,
                record_frozen: false,
                full_refresh: false,
                faults: crate::sim::FaultConfig::NONE,
            };
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
            let res = rc.run(&prob);
            assert_eq!(res.schedule.n_assigned(), prob.total_tasks());
            let rep = replay(&res.schedule, &prob.graphs, &prob.network);
            assert!(
                rep.errors.is_empty(),
                "{reaction:?}: {:?}",
                &rep.errors[..rep.errors.len().min(3)]
            );
        }
    }

    #[test]
    fn stragglers_fire_under_heavy_noise() {
        let prob = Dataset::Synthetic.instance(15, 11);
        let cfg = SimConfig {
            noise_std: 0.6,
            noise_seed: 5,
            reaction: Reaction::LastK {
                k: 3,
                threshold: 0.05,
            },
            record_frozen: false,
            full_refresh: false,
            faults: crate::sim::FaultConfig::NONE,
        };
        let mut rc =
            ReactiveCoordinator::new(Policy::NonPreemptive, SchedulerKind::Heft.make(0), cfg);
        let res = rc.run(&prob);
        assert!(
            res.n_straggler_replans() > 0,
            "heavy noise with a tight threshold must trigger rescheduling"
        );
        // arrival replans happen regardless (one per arrival that had
        // anything to schedule)
        assert!(res.n_replans() >= prob.graphs.len());
        let rep = replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(rep.errors.is_empty(), "{:?}", &rep.errors[..rep.errors.len().min(3)]);
    }

    #[test]
    fn frozen_prefix_survives_every_replan() {
        let prob = Dataset::Adversarial.instance(10, 2);
        let cfg = SimConfig {
            noise_std: 0.5,
            noise_seed: 1,
            reaction: Reaction::LastK {
                k: 4,
                threshold: 0.1,
            },
            record_frozen: true,
            full_refresh: false,
            faults: crate::sim::FaultConfig::NONE,
        };
        let mut rc =
            ReactiveCoordinator::new(Policy::Preemptive, SchedulerKind::Cpop.make(0), cfg);
        let res = rc.run(&prob);
        assert!(!res.replans.is_empty());
        for rec in &res.replans {
            for &(gid, node, start) in &rec.frozen {
                let a = res.schedule.get(gid).unwrap();
                assert_eq!(a.node, node, "replan at {} moved started {gid}", rec.time);
                assert_eq!(a.start, start, "replan at {} shifted started {gid}", rec.time);
            }
        }
    }

    #[test]
    fn simulation_is_deterministic() {
        let prob = Dataset::WfCommons.instance(8, 4);
        let cfg = SimConfig {
            noise_std: 0.4,
            noise_seed: 8,
            reaction: Reaction::LastK {
                k: 2,
                threshold: 0.15,
            },
            record_frozen: false,
            full_refresh: false,
            faults: crate::sim::FaultConfig::NONE,
        };
        let run = || {
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(3), SchedulerKind::Heft.make(0), cfg);
            rc.run(&prob)
        };
        let a = run();
        let b = run();
        assert_eq!(sig(&a.schedule), sig(&b.schedule));
        assert_eq!(a.n_replans(), b.n_replans());
        assert_eq!(a.log.len(), b.log.len());
    }

    /// Quick in-module pin of the dirty-cone refresh: same run, both
    /// refresh modes, bit-identical realized schedules and replan
    /// shapes, and the incremental pass never re-derives more than the
    /// full oracle (the exhaustive dataset × noise × controller matrix
    /// lives in `rust/tests/refresh_incremental.rs`).
    #[test]
    fn incremental_refresh_matches_full_oracle() {
        let prob = Dataset::Synthetic.instance(12, 9);
        let run = |full: bool| {
            let cfg = SimConfig {
                noise_std: 0.5,
                noise_seed: 4,
                reaction: Reaction::LastK {
                    k: 3,
                    threshold: 0.1,
                },
                record_frozen: false,
                full_refresh: full,
                faults: crate::sim::FaultConfig::NONE,
            };
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
            rc.run(&prob)
        };
        let fast = run(false);
        let oracle = run(true);
        assert_eq!(sig(&fast.schedule), sig(&oracle.schedule));
        assert_eq!(fast.n_replans(), oracle.n_replans());
        assert!(fast.n_straggler_replans() > 0, "scenario must exercise stragglers");
        for (a, b) in fast.replans.iter().zip(oracle.replans.iter()) {
            assert_eq!(a.time.to_bits(), b.time.to_bits());
            assert_eq!(
                (a.straggler, a.n_reverted, a.n_pending),
                (b.straggler, b.n_reverted, b.n_pending)
            );
            assert!(a.n_refreshed <= b.n_refreshed, "cone exceeded full refresh");
        }
        assert!(fast.n_refreshed_total() <= oracle.n_refreshed_total());
    }

    /// The event heap is pre-reserved from the instance (Σ tasks × 2 +
    /// graphs); the observed peak queue length must stay inside that
    /// reservation, so the heap never grows mid-run.
    #[test]
    fn event_queue_reservation_survives_run() {
        for (noise, reaction) in [
            (0.0, Reaction::None),
            (
                0.6,
                Reaction::LastK {
                    k: 3,
                    threshold: 0.1,
                },
            ),
        ] {
            let prob = Dataset::Synthetic.instance(15, 11);
            let reserve = prob.total_tasks() * 2 + prob.graphs.len();
            let cfg = SimConfig {
                noise_std: noise,
                noise_seed: 5,
                reaction,
                record_frozen: false,
                full_refresh: false,
                faults: crate::sim::FaultConfig::NONE,
            };
            let mut rc =
                ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
            let res = rc.run(&prob);
            assert!(res.events_peak > 0);
            assert!(
                res.events_peak <= reserve,
                "peak {} exceeds reservation {reserve}",
                res.events_peak
            );
        }
    }

    #[test]
    fn labels_render() {
        let cfg = SimConfig {
            noise_std: 0.3,
            noise_seed: 0,
            reaction: Reaction::LastK {
                k: 3,
                threshold: 0.25,
            },
            record_frozen: false,
            full_refresh: false,
            faults: crate::sim::FaultConfig::NONE,
        };
        let rc = ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
        assert_eq!(rc.label(), "5P-HEFT σ0.30 L3@0.25");
        assert_eq!(Reaction::None.label(), "none");
    }

    /// Unit pin of the deadline-urgency ranking: smallest belief slack
    /// first, deadline-less graphs last, ties toward recency — and the
    /// output stored least-endangered-first for the tail-keeping cap.
    #[test]
    fn select_urgent_ranks_by_belief_slack() {
        use crate::graph::GraphBuilder;
        use crate::network::Network;
        let one_task = |name: &str, deadline: Option<f64>| {
            let mut b = GraphBuilder::new(name);
            b.task(1.0);
            let mut g = b.build().unwrap();
            if let Some(d) = deadline {
                g.set_deadline(d);
            }
            g
        };
        // graph 0: deadline 10, predicted finish 8 → slack 2 (endangered)
        // graph 1: deadline 20, predicted finish 9 → slack 11
        // graph 2: no deadline → ∞ slack
        // graph 3: deadline 10, predicted finish 8 → slack 2 (tie, newer)
        let prob = DynamicProblem::new(
            Network::homogeneous(2),
            vec![
                (0.0, one_task("g0", Some(10.0))),
                (0.0, one_task("g1", Some(20.0))),
                (0.0, one_task("g2", None)),
                (0.0, one_task("g3", Some(10.0))),
            ],
        );
        let mut sim = Sim::new(&prob, SimConfig::default());
        sim.arrived = 4;
        for (gi, fin) in [(0usize, 8.0f64), (1, 9.0), (2, 7.0), (3, 8.0)] {
            sim.plan.assign(
                Gid::new(gi, 0),
                Assignment {
                    node: 0,
                    start: fin - 1.0,
                    finish: fin,
                },
            );
        }
        sim.select_urgent(3);
        // most endangered: g3 (slack 2, newer), g0 (slack 2), g1 (11);
        // stored least-endangered first
        let picked: Vec<usize> = sim.urgency.iter().map(|&(_, g)| g).collect();
        assert_eq!(picked, vec![1, 0, 3]);
        // completed graphs are never candidates
        sim.graph_left[3] = 0;
        sim.select_urgent(3);
        let picked: Vec<usize> = sim.urgency.iter().map(|&(_, g)| g).collect();
        assert_eq!(picked, vec![2, 1, 0], "deadline-less g2 ranks last");
        // k larger than the candidate set is fine
        sim.select_urgent(10);
        assert_eq!(sim.urgency.len(), 3);
        // a graph whose work is all dispatched has nothing revertible
        // and must not occupy a window slot, however endangered
        sim.realized.assign(
            Gid::new(0, 0),
            Assignment {
                node: 0,
                start: 7.0,
                finish: 8.0,
            },
        );
        sim.select_urgent(3);
        let picked: Vec<usize> = sim.urgency.iter().map(|&(_, g)| g).collect();
        assert_eq!(picked, vec![2, 1], "dispatched g0 is not a candidate");

        // A deadline-carrying graph with zero planned slots has no
        // predicted completion: it is maximally endangered (−∞ slack),
        // not deadline-less — even against a tight-slack rival.
        let prob2 = DynamicProblem::new(
            Network::homogeneous(2),
            vec![
                (0.0, one_task("h0", Some(10.0))),
                (0.0, one_task("h1", Some(50.0))), // never planned
            ],
        );
        let mut sim = Sim::new(&prob2, SimConfig::default());
        sim.arrived = 2;
        sim.plan.assign(
            Gid::new(0, 0),
            Assignment {
                node: 0,
                start: 7.0,
                finish: 8.0,
            },
        );
        sim.select_urgent(2);
        let picked: Vec<usize> = sim.urgency.iter().map(|&(_, g)| g).collect();
        assert_eq!(
            picked,
            vec![0, 1],
            "no-plan deadline graph h1 ranks most endangered (stored last)"
        );
    }

    /// End-to-end: a `DeadlineAware` controller on a deadline-laden
    /// noisy workload completes, replays §II-valid, honours the frozen
    /// prefix, and actually fires straggler replans.
    #[test]
    fn deadline_aware_run_is_valid_and_fires() {
        use crate::policy::PolicySpec;
        use crate::workloads::{DeadlineModel, Scenario, WeightModel, DEFAULT_LOAD};
        let scen = Scenario {
            weights: WeightModel::HeavyTail { alpha: 1.5 },
            deadlines: DeadlineModel::CritPathSlack { slack: 1.5 },
            arrivals: Default::default(),
        };
        let prob = Dataset::Synthetic.instance_scenario(15, 21, DEFAULT_LOAD, None, &scen);
        assert!(prob.graphs.iter().all(|(_, g)| g.deadline().is_some()));
        let cfg = SimConfig {
            noise_std: 0.6,
            noise_seed: 3,
            reaction: Reaction::None,
            record_frozen: true,
            full_refresh: false,
            faults: crate::sim::FaultConfig::NONE,
        };
        let spec = PolicySpec::DeadlineAware {
            k: 4,
            threshold: 0.05,
        };
        let mut rc = ReactiveCoordinator::with_policy(
            Policy::LastK(5),
            SchedulerKind::Heft.make(0),
            cfg,
            spec.make(),
        );
        assert_eq!(rc.label(), "5P-HEFT σ0.60 D4@0.05");
        let res = rc.run(&prob);
        assert_eq!(res.schedule.n_assigned(), prob.total_tasks());
        assert!(res.n_straggler_replans() > 0, "tight threshold must fire");
        let rep = replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(rep.errors.is_empty(), "{:?}", &rep.errors[..rep.errors.len().min(3)]);
        for rec in &res.replans {
            for &(gid, node, start) in &rec.frozen {
                let a = res.schedule.get(gid).unwrap();
                assert_eq!((a.node, a.start.to_bits()), (node, start.to_bits()));
            }
        }
    }

    #[test]
    fn policy_driven_label_and_run() {
        use crate::policy::PolicySpec;
        let cfg = SimConfig {
            noise_std: 0.4,
            noise_seed: 2,
            reaction: Reaction::None,
            record_frozen: true,
            full_refresh: false,
            faults: crate::sim::FaultConfig::NONE,
        };
        let spec = PolicySpec::Budgeted {
            k: 3,
            threshold: 0.1,
            rate: 0.5,
            burst: 4.0,
        };
        let mut rc = ReactiveCoordinator::with_policy(
            Policy::LastK(5),
            SchedulerKind::Heft.make(0),
            cfg,
            spec.make(),
        );
        assert_eq!(rc.label(), "5P-HEFT σ0.40 B3@0.1r0.5b4");
        let prob = Dataset::Synthetic.instance(10, 21);
        let res = rc.run(&prob);
        assert_eq!(res.schedule.n_assigned(), prob.total_tasks());
        let rep = replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(rep.errors.is_empty(), "{:?}", &rep.errors[..rep.errors.len().min(3)]);
        // frozen-prefix invariant holds under the policy engine too
        for rec in &res.replans {
            for &(gid, node, start) in &rec.frozen {
                let a = res.schedule.get(gid).unwrap();
                assert_eq!((a.node, a.start.to_bits()), (node, start.to_bits()));
            }
        }
        // cost accounting is internally consistent
        let cost = res.preemption_cost();
        assert_eq!(cost.replans, res.n_replans());
        assert_eq!(cost.reverted_tasks, res.n_reverted_total());
        assert!(cost.replan_wall_s >= res.sched_runtime_s);
        assert!(res.n_straggler_reverted_total() <= res.n_reverted_total());
    }
}
