//! Event types and the deterministic priority queue of the reactive
//! runtime simulator.
//!
//! Three event kinds drive the simulation: a task **finishes** (the only
//! moment the coordinator learns a realized duration), a graph
//! **arrives** (the paper's §IV preemption decision point), and a task
//! **starts** (a dispatch decision previously taken for an idle node).
//! At equal timestamps the queue orders Finish < Arrival < Start: a node
//! hands over at an instant (replay convention), and a task whose start
//! coincides with an arrival is still *Scheduled*, not *Executing*, when
//! the arrival's preemption decision is taken — the same tie the static
//! coordinator breaks with its `start >= arrival - EPS` revert test.
//! Remaining ties fall back to the monotone insertion sequence number,
//! so the pop order is a pure function of the push history and the whole
//! simulation is deterministic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::graph::Gid;

/// One simulator event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SimEvent {
    /// A task's realized execution completed.  `attempt` stamps which
    /// execution attempt scheduled this finish: a crash that kills the
    /// running attempt bumps the task's attempt counter, so the killed
    /// attempt's already-queued finish pops as stale and is dropped
    /// (fault runs only — without faults every task has one attempt).
    TaskFinish { gid: Gid, attempt: u32 },
    /// Graph `idx` of the dynamic problem arrives.
    GraphArrival { idx: usize },
    /// Start `gid` on `node` — valid only while `epoch` matches the
    /// node's current dispatch epoch (replans and newer dispatch
    /// decisions invalidate older ones by bumping the epoch).
    TaskStart { gid: Gid, node: usize, epoch: u64 },
    /// `node` crashes ([`crate::sim::faults::FaultModel::Crash`] only;
    /// never enqueued when faults are off — the zero-fault bit-identity
    /// guarantee rides on the push history being untouched).
    NodeDown { node: usize },
    /// `node` recovers from the crash window that downed it.
    NodeUp { node: usize },
}

impl SimEvent {
    /// Same-timestamp rank: Finish < Arrival < Start < Down < Up (see
    /// module doc).  A task finishing exactly at a crash instant counts
    /// as finished, and a crash window of zero length downs then
    /// restores the node consistently.
    fn rank(&self) -> u8 {
        match self {
            SimEvent::TaskFinish { .. } => 0,
            SimEvent::GraphArrival { .. } => 1,
            SimEvent::TaskStart { .. } => 2,
            SimEvent::NodeDown { .. } => 3,
            SimEvent::NodeUp { .. } => 4,
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    time: f64,
    seq: u64,
    ev: SimEvent,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // inverted: BinaryHeap is a max-heap, we want the earliest entry
        // on top.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.ev.rank().cmp(&self.ev.rank()))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-queue over [`SimEvent`]s.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
}

impl EventQueue {
    /// An empty queue (sequence counter at zero).
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty queue whose heap is pre-reserved for `cap` events.
    /// §Perf: the simulator sizes this from the instance (Σ tasks × 2 +
    /// graphs): the up-front arrivals, at most one in-flight finish per
    /// running task, at most one **live** start decision per idle node
    /// (the simulator deduplicates unchanged decisions instead of
    /// stranding an epoch-stale event per re-evaluation), plus headroom
    /// for the replan-invalidated start events that drain at their pop
    /// times — so the steady-state event loop never grows the heap
    /// allocation ([`crate::sim::SimResult::events_peak`] pins it).
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
        }
    }

    /// Current heap capacity (events the queue can hold without
    /// reallocating) — instrumentation for the pre-reservation tests.
    pub fn capacity(&self) -> usize {
        self.heap.capacity()
    }

    /// Enqueue `ev` at `time` (must be finite).  The push order is
    /// recorded, so equal `(time, kind)` entries pop in push order.
    pub fn push(&mut self, time: f64, ev: SimEvent) {
        debug_assert!(time.is_finite(), "non-finite event time {time}");
        self.heap.push(Entry {
            time,
            seq: self.seq,
            ev,
        });
        self.seq += 1;
    }

    /// Pop the earliest event: smallest time, then Finish < Arrival <
    /// Start, then push order.  `None` when the queue is drained.
    pub fn pop(&mut self) -> Option<(f64, SimEvent)> {
        self.heap.pop().map(|e| (e.time, e.ev))
    }

    /// Whether no events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// What happened at one instant of the simulated run — the realized-event
/// trace exported by [`crate::trace::sim_to_json`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SimLogKind {
    /// Graph `graph` arrived (its §IV replan is logged separately).
    Arrival { graph: usize },
    /// `gid` started executing on `node`.
    Start { gid: Gid, node: usize },
    /// `gid` finished on `node`; `lateness` is realized finish minus the
    /// finish the coordinator expected when it dispatched the task
    /// (negative = finished early).
    Finish { gid: Gid, node: usize, lateness: f64 },
    /// A rescheduling pass ran: `straggler` distinguishes reactive
    /// (lateness-triggered) replans from arrival-time policy replans
    /// (failure-triggered replans log as straggler replans too — they
    /// are reactive, not arrival-driven — and are counted separately in
    /// [`crate::sim::ReplanRecord::failure`]).
    Replan {
        straggler: bool,
        n_reverted: usize,
        n_pending: usize,
    },
    /// `node` crashed; the task it was running (if any) was killed and
    /// `wasted` seconds of partial work were lost (fault runs only).
    NodeDown { node: usize, wasted: f64 },
    /// `node` recovered after `downtime` simulated seconds.
    NodeUp { node: usize, downtime: f64 },
    /// `gid`'s running attempt on `node` was killed by a crash after
    /// `wasted` seconds of partial execution; the task returns to the
    /// pending set and is re-executed later (fault runs only).
    Kill { gid: Gid, node: usize, wasted: f64 },
}

/// One timestamped entry of the realized-event trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimLogEntry {
    pub time: f64,
    pub kind: SimLogKind,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, SimEvent::GraphArrival { idx: 3 });
        q.push(1.0, SimEvent::GraphArrival { idx: 1 });
        q.push(2.0, SimEvent::GraphArrival { idx: 2 });
        let order: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|(t, _)| t).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn equal_time_orders_finish_arrival_start() {
        let g = Gid::new(0, 0);
        let mut q = EventQueue::new();
        q.push(5.0, SimEvent::TaskStart { gid: g, node: 0, epoch: 1 });
        q.push(5.0, SimEvent::GraphArrival { idx: 1 });
        q.push(5.0, SimEvent::TaskFinish { gid: g, attempt: 0 });
        let kinds: Vec<u8> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| e.rank())
            .collect();
        assert_eq!(kinds, vec![0, 1, 2]);
    }

    #[test]
    fn equal_time_and_rank_preserves_push_order() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(1.0, SimEvent::GraphArrival { idx: i });
        }
        let idxs: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| match e {
                SimEvent::GraphArrival { idx } => idx,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(idxs, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn with_capacity_survives_push_pop_waves() {
        // the simulator's access pattern: pre-reserve once, then push
        // and pop in waves that never exceed the reservation — the heap
        // allocation must never grow
        let cap = 64;
        let mut q = EventQueue::with_capacity(cap);
        let initial = q.capacity();
        assert!(initial >= cap);
        for wave in 0..5 {
            for i in 0..cap {
                q.push((wave * cap + i) as f64, SimEvent::GraphArrival { idx: i });
            }
            assert_eq!(q.len(), cap);
            while q.pop().is_some() {}
        }
        assert_eq!(
            q.capacity(),
            initial,
            "heap reallocated despite pre-reservation"
        );
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(0.0, SimEvent::GraphArrival { idx: 0 });
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
