//! Runtime simulation: discrete-event **replay** of a finished schedule
//! (this module) and the **reactive runtime** ([`coordinator`]) in which
//! realized durations deviate from the estimates and the coordinator
//! observes actual finish times and reschedules stragglers.  The
//! coordinator's belief schedule is kept current by an **incremental
//! dirty-cone refresh** (O(seeds + cone) per replan, bit-identical to
//! the retained full-plan oracle behind [`SimConfig::full_refresh`] /
//! `DTS_FULL_REFRESH`), which is what lets the runtime drive 10⁴-task
//! composites at paper-default trial counts — see the [`coordinator`]
//! module docs and docs/PERF.md.
//!
//! The replay walks (start, finish) events in time order, maintaining the
//! set of running tasks per node and asserting the §II invariants as they
//! unfold (at most one task per node; dependencies satisfied with
//! communication delays; starts after arrivals).  Where
//! [`crate::schedule::validate`] checks constraints pairwise, the replay
//! checks them *operationally*, so a bug in the shared interval math
//! cannot hide in both.  Because the replay never assumes a task's
//! duration equals its cost estimate, it is also the validity oracle for
//! *realized* schedules produced under execution-time noise (see
//! [`crate::robustness`] and [`coordinator::ReactiveCoordinator`]).

pub mod coordinator;
pub mod events;
pub mod faults;

pub use coordinator::{Reaction, ReactiveCoordinator, ReplanRecord, SimConfig, SimResult};
pub use events::{SimLogEntry, SimLogKind};
pub use faults::{FaultConfig, FaultModel, Faults, DEFAULT_FAULT_SEED};

use crate::graph::{Gid, TaskGraph};
use crate::network::Network;
use crate::schedule::{Schedule, EPS};

/// One replay event.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Ev {
    Start(Gid),
    Finish(Gid),
}

/// Replay outcome.
#[derive(Clone, Debug, Default)]
pub struct Replay {
    /// errors discovered during the replay (empty = consistent)
    pub errors: Vec<String>,
    /// (time, #busy nodes) step trace, one point per event
    pub busy_trace: Vec<(f64, usize)>,
    /// integral of busy-node-fraction over the event span
    pub avg_busy_fraction: f64,
}

/// Replay `schedule` against the problem it solves.
pub fn replay(schedule: &Schedule, problem: &[(f64, TaskGraph)], network: &Network) -> Replay {
    let mut out = Replay::default();
    let n_nodes = network.n_nodes();

    // gather events; finishes sort before starts at equal times so a node
    // can hand over at an instant.
    let mut events: Vec<(f64, u8, Ev)> = Vec::new();
    for (gid, a) in schedule.iter() {
        events.push((a.start, 1, Ev::Start(*gid)));
        events.push((a.finish, 0, Ev::Finish(*gid)));
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));

    let mut running: Vec<Option<Gid>> = vec![None; n_nodes];
    let mut finished: std::collections::HashMap<Gid, (usize, f64)> =
        std::collections::HashMap::new();

    let span_start = events.first().map(|e| e.0).unwrap_or(0.0);
    let span_end = events.last().map(|e| e.0).unwrap_or(0.0);
    let mut busy_integral = 0.0;
    let mut last_t = span_start;
    let mut busy = 0usize;

    for (t, _, ev) in events {
        busy_integral += busy as f64 * (t - last_t);
        last_t = t;
        match ev {
            Ev::Start(gid) => {
                let a = schedule.get(gid).unwrap();
                // node must be free
                if let Some(prev) = running[a.node] {
                    out.errors.push(format!(
                        "node {} already running {prev} when {gid} starts at {t}",
                        a.node
                    ));
                }
                running[a.node] = Some(gid);
                busy += 1;
                // arrival bound
                let (arrival, g) = &problem[gid.graph as usize];
                if t + EPS < *arrival {
                    out.errors
                        .push(format!("{gid} starts {t} before arrival {arrival}"));
                }
                // every predecessor must have finished early enough for
                // its data to be here
                for &(p, data) in g.predecessors(gid.task as usize) {
                    let pgid = Gid::new(gid.graph as usize, p);
                    match finished.get(&pgid) {
                        None => out
                            .errors
                            .push(format!("{gid} starts before parent {pgid} finished")),
                        Some(&(pnode, pfin)) => {
                            let comm = network.comm_time(data, pnode, a.node);
                            if pfin + comm > t + EPS * (1.0 + comm) {
                                out.errors.push(format!(
                                    "{gid} starts at {t} < parent {pgid} finish {pfin} + comm {comm}"
                                ));
                            }
                        }
                    }
                }
            }
            Ev::Finish(gid) => {
                let a = schedule.get(gid).unwrap();
                if running[a.node] != Some(gid) {
                    out.errors.push(format!(
                        "{gid} finishes on node {} it wasn't running on",
                        a.node
                    ));
                } else {
                    running[a.node] = None;
                    busy -= 1;
                }
                finished.insert(gid, (a.node, a.finish));
            }
        }
        out.busy_trace.push((t, busy));
    }

    let span = span_end - span_start;
    out.avg_busy_fraction = if span > 0.0 {
        busy_integral / (span * n_nodes as f64)
    } else {
        0.0
    };
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, Policy};
    use crate::graph::GraphBuilder;
    use crate::schedule::Assignment;
    use crate::schedulers::SchedulerKind;
    use crate::workloads::Dataset;

    #[test]
    fn replay_accepts_real_coordinator_output() {
        let prob = Dataset::Synthetic.instance(12, 42);
        for policy in [Policy::Preemptive, Policy::NonPreemptive, Policy::LastK(3)] {
            let mut c = Coordinator::new(policy, SchedulerKind::Heft.make(0));
            let res = c.run(&prob);
            let r = replay(&res.schedule, &prob.graphs, &prob.network);
            assert!(
                r.errors.is_empty(),
                "{policy:?}: {:?}",
                &r.errors[..3.min(r.errors.len())]
            );
            assert!(r.avg_busy_fraction > 0.0 && r.avg_busy_fraction <= 1.0);
        }
    }

    #[test]
    fn replay_catches_dependency_violation() {
        let mut b = GraphBuilder::new("chain");
        let t0 = b.task(2.0);
        let t1 = b.task(2.0);
        b.edge(t0, t1, 4.0);
        let g = b.build().unwrap();
        let net = Network::new(vec![1.0, 1.0], vec![0.0, 2.0, 2.0, 0.0]);
        let mut s = Schedule::new(2);
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 0.0, finish: 2.0 });
        // comm time = 4/2 = 2, so earliest legal start on node 1 is 4.0
        s.assign(Gid::new(0, 1), Assignment { node: 1, start: 3.0, finish: 5.0 });
        let r = replay(&s, &[(0.0, g)], &net);
        assert!(r.errors.iter().any(|e| e.contains("comm")), "{:?}", r.errors);
    }

    #[test]
    fn replay_catches_missing_parent() {
        let mut b = GraphBuilder::new("chain");
        let t0 = b.task(2.0);
        let t1 = b.task(2.0);
        b.edge(t0, t1, 0.0);
        let g = b.build().unwrap();
        let net = Network::homogeneous(2);
        let mut s = Schedule::new(2);
        // only the child is scheduled
        s.assign(Gid::new(0, 1), Assignment { node: 1, start: 3.0, finish: 5.0 });
        let r = replay(&s, &[(0.0, g)], &net);
        assert!(r.errors.iter().any(|e| e.contains("parent")));
    }

    #[test]
    fn replay_catches_start_before_arrival() {
        let mut b = GraphBuilder::new("one");
        b.task(1.0);
        let g = b.build().unwrap();
        let net = Network::homogeneous(1);
        let mut s = Schedule::new(1);
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 0.0, finish: 1.0 });
        let r = replay(&s, &[(5.0, g)], &net);
        assert!(r.errors.iter().any(|e| e.contains("arrival")));
    }

    #[test]
    fn same_instant_handover_is_legal() {
        let mut b = GraphBuilder::new("two");
        b.task(2.0);
        b.task(2.0);
        let g = b.build().unwrap();
        let net = Network::homogeneous(1);
        let mut s = Schedule::new(1);
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 0.0, finish: 2.0 });
        s.assign(Gid::new(0, 1), Assignment { node: 0, start: 2.0, finish: 4.0 });
        let r = replay(&s, &[(0.0, g)], &net);
        assert!(r.errors.is_empty(), "{:?}", r.errors);
        // single node busy from 0 to 4 → fraction 1
        assert!((r.avg_busy_fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn busy_fraction_matches_hand_example() {
        let mut b = GraphBuilder::new("one");
        b.task(1.0);
        let g = b.build().unwrap();
        let net = Network::homogeneous(2);
        let mut s = Schedule::new(2);
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 0.0, finish: 1.0 });
        let r = replay(&s, &[(0.0, g)], &net);
        // one of two nodes busy over the whole event span → 0.5
        assert!((r.avg_busy_fraction - 0.5).abs() < 1e-12);
    }
}
