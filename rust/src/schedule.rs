//! Schedules: per-node timelines, insertion-based gap finding, and the
//! §II validity checker.
//!
//! [`Timelines`] is the machine-occupancy structure every scheduler works
//! against: one sorted interval list per node.  [`Schedule`] couples the
//! timelines with the per-task assignment map and is the object the
//! dynamic coordinator mutates as graphs arrive and (partially) preempt.

use std::sync::Arc;

use crate::dense::DenseIds;
use crate::fasthash::FxHashMap;
use crate::graph::{Gid, TaskGraph};
use crate::network::Network;
use crate::telemetry;

/// Numeric slack for interval comparisons (floating-point scheduling).
pub const EPS: f64 = 1e-9;

/// One occupied interval on a node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Slot {
    pub start: f64,
    pub finish: f64,
    pub gid: Gid,
}

/// A task's placement: node, start time `r(t)`, finish time `e(t)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Assignment {
    pub node: usize,
    pub start: f64,
    pub finish: f64,
}

/// Per-node sorted interval lists, stored **structure-of-arrays**
/// (§Perf, PR 6): per node, parallel `starts`/`finishes`/`gids` columns
/// instead of a `Vec<Slot>`.  The cursor probes, `find_idx`/`remove_at`
/// binary searches, and `earliest_start` gap scans each touch only the
/// one or two f64 columns they need — one cache line per step instead
/// of striding over 24-byte AoS slots.  [`Slot`] survives as the value
/// type handed across the API ([`Timelines::slot`], [`Timelines::insert`]).
///
/// §Perf: the structure doubles as its own **undo-log scratch** (the
/// `TimelineScratch` design): [`Timelines::begin_txn`] starts journaling
/// insertions, and [`Timelines::rollback_txn`] removes them again in
/// O(touched · log n) — so speculative composite scheduling costs only
/// the slots it actually touched, never a full clone of every node's
/// slot list.  The dynamic coordinator runs base heuristics directly on
/// the master timelines inside such a transaction instead of cloning.
#[derive(Clone, Debug, Default)]
pub struct Timelines {
    /// per-node slot start times, sorted ascending
    starts: Vec<Vec<f64>>,
    /// per-node slot finish times (monotone too: slots don't overlap)
    finishes: Vec<Vec<f64>>,
    /// per-node slot owners, parallel to `starts`
    gids: Vec<Vec<Gid>>,
    /// insertion journal `(node, gid, start)`; recording only while
    /// `txn_active` (the journal Vec is retained across transactions so
    /// steady-state arrivals allocate nothing).
    journal: Vec<(usize, Gid, f64)>,
    txn_active: bool,
    /// Per-node earliest-availability floor (crash recovery instants,
    /// [`crate::sim::faults`]): while raised, no placement on the node
    /// may start earlier.  The fault-free value 0.0 is the identity —
    /// no placement starts before time zero — so zero-fault runs are
    /// bit-identical to a build without the floor.
    avail_floor: Vec<f64>,
}

impl Timelines {
    pub fn new(n_nodes: usize) -> Self {
        Self {
            starts: vec![Vec::new(); n_nodes],
            finishes: vec![Vec::new(); n_nodes],
            gids: vec![Vec::new(); n_nodes],
            journal: Vec::new(),
            txn_active: false,
            avail_floor: vec![0.0; n_nodes],
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.starts.len()
    }

    /// Number of slots on node `v`.
    #[inline]
    pub fn n_slots(&self, v: usize) -> usize {
        self.starts[v].len()
    }

    /// Slot `i` of node `v`, assembled from the columns.
    #[inline]
    pub fn slot(&self, v: usize, i: usize) -> Slot {
        Slot {
            start: self.starts[v][i],
            finish: self.finishes[v][i],
            gid: self.gids[v][i],
        }
    }

    /// Start-time column of node `v` (sorted ascending).
    #[inline]
    pub fn starts(&self, v: usize) -> &[f64] {
        &self.starts[v]
    }

    /// Finish-time column of node `v` (monotone: slots don't overlap).
    #[inline]
    pub fn finishes(&self, v: usize) -> &[f64] {
        &self.finishes[v]
    }

    /// Owner column of node `v`, parallel to [`starts`](Self::starts).
    #[inline]
    pub fn slot_gids(&self, v: usize) -> &[Gid] {
        &self.gids[v]
    }

    /// Iterate node `v`'s slots as assembled [`Slot`] values.
    pub fn iter_slots(&self, v: usize) -> impl Iterator<Item = Slot> + '_ {
        (0..self.n_slots(v)).map(move |i| self.slot(v, i))
    }

    /// Node `v`'s slots as an owned Vec (tests / tooling; allocates).
    pub fn slots_vec(&self, v: usize) -> Vec<Slot> {
        self.iter_slots(v).collect()
    }

    /// Insert an interval, keeping the node's list sorted by start.
    /// Panics in debug builds if it overlaps an existing slot.
    pub fn insert(&mut self, v: usize, slot: Slot) {
        let idx = self.starts[v].partition_point(|&s| s < slot.start);
        debug_assert!(
            idx == 0 || self.finishes[v][idx - 1] <= slot.start + EPS,
            "overlap with previous slot on node {v}: {:?} vs {:?}",
            self.slot(v, idx - 1),
            slot
        );
        debug_assert!(
            idx == self.starts[v].len() || slot.finish <= self.starts[v][idx] + EPS,
            "overlap with next slot on node {v}: {:?} vs {:?}",
            self.slot(v, idx),
            slot
        );
        self.starts[v].insert(idx, slot.start);
        self.finishes[v].insert(idx, slot.finish);
        self.gids[v].insert(idx, slot.gid);
        if self.txn_active {
            self.journal.push((v, slot.gid, slot.start));
        }
    }

    /// Start journaling insertions (the undo-log scratch).  Nested
    /// transactions are not supported; removals while a transaction is
    /// active are rejected in debug builds (the journal only records
    /// inserts).
    pub fn begin_txn(&mut self) {
        debug_assert!(!self.txn_active, "nested timeline transaction");
        telemetry::counter_inc(telemetry::Counter::TxnBegin);
        self.journal.clear();
        self.txn_active = true;
    }

    /// Keep every insertion made since [`begin_txn`](Self::begin_txn) and
    /// stop journaling.
    pub fn commit_txn(&mut self) {
        debug_assert!(self.txn_active, "commit without begin_txn");
        telemetry::counter_inc(telemetry::Counter::TxnCommit);
        self.journal.clear();
        self.txn_active = false;
    }

    /// Remove every insertion made since [`begin_txn`](Self::begin_txn),
    /// newest first, and stop journaling.  O(touched · log n).
    pub fn rollback_txn(&mut self) {
        debug_assert!(self.txn_active, "rollback without begin_txn");
        telemetry::counter_inc(telemetry::Counter::TxnRollback);
        self.txn_active = false;
        while let Some((v, gid, start)) = self.journal.pop() {
            let removed = self.remove_at(v, gid, start);
            debug_assert!(removed, "journaled slot {gid} missing on node {v}");
        }
    }

    /// Number of insertions journaled by the active transaction.
    pub fn txn_len(&self) -> usize {
        if self.txn_active {
            self.journal.len()
        } else {
            0
        }
    }

    /// Remove the slot owned by `gid` on node `v`; true if found.
    /// O(n) scan — retained only as a test reference; every production
    /// caller knows the slot's start time (it's on the owning
    /// [`Assignment`]) and goes through [`remove_at`](Self::remove_at)
    /// or [`remove_idx`](Self::remove_idx).
    #[cfg(test)]
    pub fn remove(&mut self, v: usize, gid: Gid) -> bool {
        debug_assert!(!self.txn_active, "removal inside a timeline transaction");
        if let Some(i) = self.gids[v].iter().position(|&g| g == gid) {
            self.remove_idx(v, i);
            true
        } else {
            false
        }
    }

    /// Remove the slot owned by `gid` on node `v` whose start time is
    /// `start`, locating it by binary search on the sorted start column —
    /// O(log n + equal-start run) instead of a linear scan.  A `gid`
    /// present at a *different* start is a caller bug (every caller reads
    /// `start` off the owning [`Assignment`]): debug builds assert on it,
    /// release builds report a miss.
    pub fn remove_at(&mut self, v: usize, gid: Gid, start: f64) -> bool {
        debug_assert!(!self.txn_active, "removal inside a timeline transaction");
        // first slot that could share this start (EPS guard for safety;
        // starts are stored bit-exact from the owning Assignment)
        let mut i = self.starts[v].partition_point(|&s| s < start - EPS);
        while i < self.starts[v].len() && self.starts[v][i] <= start + EPS {
            if self.gids[v][i] == gid {
                self.starts[v].remove(i);
                self.finishes[v].remove(i);
                self.gids[v].remove(i);
                return true;
            }
            i += 1;
        }
        debug_assert!(
            !self.gids[v].iter().any(|&g| g == gid),
            "remove_at({v}, {gid}, {start}): slot exists at a different start"
        );
        false
    }

    /// Index of the slot owned by `gid` on node `v` whose start time is
    /// `start`, by binary search on the sorted start column (the lookup
    /// half of [`remove_at`](Self::remove_at)).  The belief refresh uses
    /// it to turn a task's [`Assignment`] into a slot-list position —
    /// the per-gid slot cursor of the dirty-cone seeding — without
    /// scanning the node.
    pub fn find_idx(&self, v: usize, gid: Gid, start: f64) -> Option<usize> {
        let starts = &self.starts[v];
        let mut i = starts.partition_point(|&s| s < start - EPS);
        while i < starts.len() && starts[i] <= start + EPS {
            if self.gids[v][i] == gid {
                return Some(i);
            }
            i += 1;
        }
        debug_assert!(
            !self.gids[v].iter().any(|&g| g == gid),
            "find_idx({v}, {gid}, {start}): slot exists at a different start"
        );
        None
    }

    /// Remove the slot at a **known index** (§Perf: the belief refresh
    /// walks a node's slot list and already holds the position, so the
    /// [`remove_at`](Self::remove_at) binary search would be wasted
    /// work).  Removing a suffix back-to-front through this method costs
    /// O(1) per slot — no interior shift ever happens.
    pub fn remove_idx(&mut self, v: usize, idx: usize) -> Slot {
        debug_assert!(!self.txn_active, "removal inside a timeline transaction");
        let start = self.starts[v].remove(idx);
        let finish = self.finishes[v].remove(idx);
        let gid = self.gids[v].remove(idx);
        Slot { start, finish, gid }
    }

    /// Append a slot at the **tail** of node `v` — O(1), skipping
    /// [`insert`](Self::insert)'s `partition_point`.  The dirty-cone
    /// re-derivation only ever appends (every re-derived start clears
    /// the node's current tail), so the per-slot binary search of the
    /// old full refresh disappears.  Panics in debug builds if the slot
    /// does not belong at the tail.
    pub fn push_tail(&mut self, v: usize, slot: Slot) {
        if let Some(&last_finish) = self.finishes[v].last() {
            debug_assert!(
                last_finish <= slot.start + EPS,
                "push_tail on node {v}: {slot:?} overlaps tail finishing {last_finish}"
            );
        }
        self.starts[v].push(slot.start);
        self.finishes[v].push(slot.finish);
        self.gids[v].push(slot.gid);
        if self.txn_active {
            self.journal.push((v, slot.gid, slot.start));
        }
    }

    /// Earliest start >= `ready` at which a task of length `dur` fits into
    /// node `v`'s timeline — the **insertion-based** policy of HEFT:
    /// interior gaps are eligible, not just the tail.
    ///
    /// §Perf: slots finishing at or before `ready` cannot constrain the
    /// placement (the candidate already clears them), so the scan starts
    /// at the first slot with `finish > ready`, found by binary search.
    /// Slot lists are sorted by start and non-overlapping, so `finish` is
    /// monotone too and `partition_point` applies.  The gap scan reads
    /// only the two f64 columns — the SoA layout keeps it cache-dense.
    pub fn earliest_start(&self, v: usize, ready: f64, dur: f64) -> f64 {
        let floor = self.avail_floor[v];
        let ready = if floor > ready { floor } else { ready };
        let starts = &self.starts[v];
        let finishes = &self.finishes[v];
        let from = finishes.partition_point(|&f| f <= ready);
        let mut candidate = ready;
        for i in from..starts.len() {
            if candidate + dur <= starts[i] + EPS {
                return candidate;
            }
            candidate = candidate.max(finishes[i]);
        }
        candidate
    }

    /// Tail-append start (non-insertion variant): max(ready, last finish).
    pub fn append_start(&self, v: usize, ready: f64) -> f64 {
        let floor = self.avail_floor[v];
        let ready = if floor > ready { floor } else { ready };
        let tail = self.finishes[v].last().copied().unwrap_or(0.0);
        ready.max(tail)
    }

    /// Raise node `v`'s availability floor to `t` (a crash recovery
    /// instant): until cleared, no new placement on `v` starts earlier.
    pub fn set_avail_floor(&mut self, v: usize, t: f64) {
        self.avail_floor[v] = t;
    }

    /// Drop node `v`'s availability floor back to the fault-free
    /// identity (time zero).
    pub fn clear_avail_floor(&mut self, v: usize) {
        self.avail_floor[v] = 0.0;
    }

    /// Node `v`'s current availability floor (0.0 when unfloored).
    pub fn avail_floor(&self, v: usize) -> f64 {
        self.avail_floor[v]
    }

    /// Total busy time on node `v`.
    pub fn busy_time(&self, v: usize) -> f64 {
        self.starts[v]
            .iter()
            .zip(&self.finishes[v])
            .map(|(&s, &f)| f - s)
            .sum()
    }

    /// Latest finish across all nodes (0 when empty).
    pub fn max_finish(&self) -> f64 {
        self.finishes
            .iter()
            .flat_map(|l| l.last())
            .copied()
            .fold(0.0, f64::max)
    }
}

/// Task → placement storage behind [`Schedule`].
///
/// §Perf (PR 6): the coordinator hot path knows the dense-id universe of
/// its composite up front ([`DenseIds`]), so the per-replan schedule uses
/// a flat `Vec<Option<Assignment>>` indexed by dense id — no hashing, no
/// rehash growth, O(1) lookups on the cursor/EFT path.  The map variant
/// survives at API boundaries (hand-built schedules, validators, tests)
/// where no dense universe exists.
#[derive(Clone, Debug)]
enum AssignStore {
    Map(FxHashMap<Gid, Assignment>),
    Dense {
        ids: Arc<DenseIds>,
        slots: Vec<Option<Assignment>>,
        n: usize,
    },
}

impl Default for AssignStore {
    fn default() -> Self {
        AssignStore::Map(FxHashMap::default())
    }
}

/// Iterator over `(gid, assignment)` pairs for either store variant.
enum AssignIter<'a> {
    Map(std::collections::hash_map::Iter<'a, Gid, Assignment>),
    Dense {
        ids: &'a DenseIds,
        iter: std::iter::Enumerate<std::slice::Iter<'a, Option<Assignment>>>,
    },
}

impl<'a> Iterator for AssignIter<'a> {
    type Item = (&'a Gid, &'a Assignment);

    fn next(&mut self) -> Option<Self::Item> {
        match self {
            AssignIter::Map(it) => it.next(),
            AssignIter::Dense { ids, iter } => {
                for (d, s) in iter.by_ref() {
                    if let Some(a) = s.as_ref() {
                        return Some((ids.gid_ref(d), a));
                    }
                }
                None
            }
        }
    }
}

/// Global schedule across all graphs of a dynamic problem.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    timelines: Timelines,
    assign: AssignStore,
}

impl Schedule {
    pub fn new(n_nodes: usize) -> Self {
        Self {
            timelines: Timelines::new(n_nodes),
            assign: AssignStore::default(),
        }
    }

    /// Dense-backed schedule over a known task universe: assignments live
    /// in a flat vector indexed by [`DenseIds`] position.  Lookups and
    /// updates for any gid in the universe are O(1) array probes; a gid
    /// outside the universe panics (debug) — the coordinator constructs
    /// the universe from the same composite it schedules.
    pub fn new_dense(n_nodes: usize, ids: Arc<DenseIds>) -> Self {
        let slots = vec![None; ids.len()];
        Self {
            timelines: Timelines::new(n_nodes),
            assign: AssignStore::Dense { ids, slots, n: 0 },
        }
    }

    fn insert_assign(&mut self, gid: Gid, a: Assignment) -> Option<Assignment> {
        match &mut self.assign {
            AssignStore::Map(map) => map.insert(gid, a),
            AssignStore::Dense { ids, slots, n } => {
                let prev = slots[ids.ix(gid)].replace(a);
                if prev.is_none() {
                    *n += 1;
                }
                prev
            }
        }
    }

    fn remove_assign(&mut self, gid: Gid) -> Option<Assignment> {
        match &mut self.assign {
            AssignStore::Map(map) => map.remove(&gid),
            AssignStore::Dense { ids, slots, n } => {
                let prev = slots[ids.ix(gid)].take();
                if prev.is_some() {
                    *n -= 1;
                }
                prev
            }
        }
    }

    pub fn timelines(&self) -> &Timelines {
        &self.timelines
    }

    /// Mutable timeline access for schedulers running **in place** on the
    /// master schedule (the coordinator hot path: base heuristics insert
    /// their slots directly, inside a timeline transaction, and the
    /// coordinator then [`record`](Self::record)s the returned
    /// assignments).  Callers must keep the map/timeline invariant: every
    /// slot inserted here must be recorded, or rolled back.
    pub fn timelines_mut(&mut self) -> &mut Timelines {
        &mut self.timelines
    }

    /// Record a placement whose slot was **already inserted** into the
    /// timelines by an in-place scheduler (see
    /// [`timelines_mut`](Self::timelines_mut)).  Panics if the task is
    /// already assigned.
    pub fn record(&mut self, gid: Gid, a: Assignment) {
        let prev = self.insert_assign(gid, a);
        assert!(prev.is_none(), "task {gid} assigned twice");
    }

    pub fn get(&self, gid: Gid) -> Option<&Assignment> {
        match &self.assign {
            AssignStore::Map(map) => map.get(&gid),
            AssignStore::Dense { ids, slots, .. } => slots[ids.ix(gid)].as_ref(),
        }
    }

    pub fn n_assigned(&self) -> usize {
        match &self.assign {
            AssignStore::Map(map) => map.len(),
            AssignStore::Dense { n, .. } => *n,
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&Gid, &Assignment)> {
        match &self.assign {
            AssignStore::Map(map) => AssignIter::Map(map.iter()),
            AssignStore::Dense { ids, slots, .. } => AssignIter::Dense {
                ids,
                iter: slots.iter().enumerate(),
            },
        }
    }

    /// Record a placement (task must not already be assigned).
    pub fn assign(&mut self, gid: Gid, a: Assignment) {
        let prev = self.insert_assign(gid, a);
        assert!(prev.is_none(), "task {gid} assigned twice");
        self.timelines.insert(
            a.node,
            Slot {
                start: a.start,
                finish: a.finish,
                gid,
            },
        );
    }

    /// Revert a placement (preemption). Returns the removed assignment.
    /// The slot is located by binary search on its known start time
    /// (§Perf: preemption-heavy policies unassign thousands of tasks per
    /// run; the old linear `position` scan dominated Last-K reverts).
    pub fn unassign(&mut self, gid: Gid) -> Option<Assignment> {
        let a = self.remove_assign(gid)?;
        let removed = self.timelines.remove_at(a.node, gid, a.start);
        debug_assert!(removed, "assignment map and timelines out of sync");
        Some(a)
    }

    /// Drop node `v`'s slot suffix `[from..]` — timelines **and**
    /// assignment map — back-to-front, so each removal pops the current
    /// tail: O(suffix) total, no binary search, no interior shift.
    /// §Perf: the incremental belief refresh evicts its dirty cone
    /// through this (the cone is a per-node suffix by construction);
    /// per-gid [`unassign`](Self::unassign) would pay a `partition_point`
    /// plus an interior `Vec::remove` shift for every evicted slot.
    pub fn unassign_tail(&mut self, v: usize, from: usize) {
        while self.timelines.n_slots(v) > from {
            let slot = self.timelines.remove_idx(v, self.timelines.n_slots(v) - 1);
            let removed = self.remove_assign(slot.gid);
            debug_assert!(
                removed.is_some(),
                "assignment map and timelines out of sync for {}",
                slot.gid
            );
        }
    }

    /// Record a placement whose slot belongs at the **tail** of its
    /// node's timeline — the dirty-cone re-derivation path (every
    /// re-derived start clears the node's running tail), using
    /// [`Timelines::push_tail`] instead of the sorted insert.
    pub fn assign_tail(&mut self, gid: Gid, a: Assignment) {
        let prev = self.insert_assign(gid, a);
        assert!(prev.is_none(), "task {gid} assigned twice");
        self.timelines.push_tail(
            a.node,
            Slot {
                start: a.start,
                finish: a.finish,
                gid,
            },
        );
    }
}

/// One §II validity violation, human-readable.
#[derive(Debug, Clone, PartialEq)]
pub struct Violation(pub String);

/// Check every constraint of the paper's §II against a finished schedule.
///
/// `problem`: the graph collection with arrival times, indexed like the
/// `Gid.graph` values used in the schedule.
pub fn validate(
    schedule: &Schedule,
    problem: &[(f64, TaskGraph)],
    network: &Network,
) -> Vec<Violation> {
    let mut out = Vec::new();

    // 1. all tasks scheduled + 2. execution times valid + 4. arrival bound
    for (gi, (arrival, g)) in problem.iter().enumerate() {
        for t in 0..g.n_tasks() {
            let gid = Gid::new(gi, t);
            let Some(a) = schedule.get(gid) else {
                out.push(Violation(format!("task {gid} not scheduled")));
                continue;
            };
            if a.node >= network.n_nodes() {
                out.push(Violation(format!("task {gid} on unknown node {}", a.node)));
                continue;
            }
            let want = network.exec_time(g.cost(t), a.node);
            if ((a.finish - a.start) - want).abs() > EPS * (1.0 + want) {
                out.push(Violation(format!(
                    "task {gid} duration {} != c/s {want}",
                    a.finish - a.start
                )));
            }
            if a.start + EPS < *arrival {
                out.push(Violation(format!(
                    "task {gid} starts {} before arrival {arrival}",
                    a.start
                )));
            }
        }
    }

    // 3. no overlap per node
    for v in 0..schedule.timelines().n_nodes() {
        let tl = schedule.timelines();
        for i in 1..tl.n_slots(v) {
            if tl.finishes(v)[i - 1] > tl.starts(v)[i] + EPS {
                let (a, b) = (tl.slot(v, i - 1), tl.slot(v, i));
                out.push(Violation(format!(
                    "overlap on node {v}: {} [{}, {}] vs {} [{}, {}]",
                    a.gid, a.start, a.finish, b.gid, b.start, b.finish
                )));
            }
        }
    }

    // 5. dependency + communication constraints
    for (gi, (_, g)) in problem.iter().enumerate() {
        for t in 0..g.n_tasks() {
            let Some(at) = schedule.get(Gid::new(gi, t)) else {
                continue;
            };
            for &(c, data) in g.successors(t) {
                let Some(ac) = schedule.get(Gid::new(gi, c)) else {
                    continue;
                };
                let comm = network.comm_time(data, at.node, ac.node);
                if at.finish + comm > ac.start + EPS * (1.0 + comm.abs()) {
                    out.push(Violation(format!(
                        "dependency g{gi}: t{t}->t{c} violated: {} + {comm} > {}",
                        at.finish, ac.start
                    )));
                }
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn gid(t: usize) -> Gid {
        Gid::new(0, t)
    }

    #[test]
    fn earliest_start_finds_interior_gap() {
        let mut tl = Timelines::new(1);
        tl.insert(0, Slot { start: 0.0, finish: 2.0, gid: gid(0) });
        tl.insert(0, Slot { start: 5.0, finish: 8.0, gid: gid(1) });
        // gap [2, 5] holds a 3-long task
        assert_eq!(tl.earliest_start(0, 0.0, 3.0), 2.0);
        // a 4-long task must go after the tail
        assert_eq!(tl.earliest_start(0, 0.0, 4.0), 8.0);
        // ready time inside the gap
        assert_eq!(tl.earliest_start(0, 3.0, 1.5), 3.0);
        // ready time makes the gap too small
        assert_eq!(tl.earliest_start(0, 4.0, 1.5), 8.0);
    }

    #[test]
    fn earliest_start_empty_node_is_ready_time() {
        let tl = Timelines::new(2);
        assert_eq!(tl.earliest_start(1, 7.5, 100.0), 7.5);
    }

    #[test]
    fn append_start_ignores_gaps() {
        let mut tl = Timelines::new(1);
        tl.insert(0, Slot { start: 4.0, finish: 6.0, gid: gid(0) });
        assert_eq!(tl.append_start(0, 1.0), 6.0);
        assert_eq!(tl.append_start(0, 9.0), 9.0);
    }

    #[test]
    fn insert_keeps_sorted_remove_works() {
        let mut tl = Timelines::new(1);
        tl.insert(0, Slot { start: 5.0, finish: 6.0, gid: gid(1) });
        tl.insert(0, Slot { start: 0.0, finish: 2.0, gid: gid(0) });
        tl.insert(0, Slot { start: 2.0, finish: 4.0, gid: gid(2) });
        assert_eq!(tl.starts(0), &[0.0, 2.0, 5.0]);
        assert!(tl.remove(0, gid(2)));
        assert!(!tl.remove(0, gid(2)));
        assert_eq!(tl.n_slots(0), 2);
        assert!((tl.busy_time(0) - 3.0).abs() < 1e-12);
        assert_eq!(tl.max_finish(), 6.0);
    }

    #[test]
    fn remove_at_finds_slot_by_binary_search() {
        let mut tl = Timelines::new(1);
        for i in 0..100 {
            let t = i as f64 * 2.0;
            tl.insert(0, Slot { start: t, finish: t + 1.0, gid: gid(i) });
        }
        assert!(tl.remove_at(0, gid(37), 74.0));
        assert!(!tl.remove_at(0, gid(37), 74.0), "already removed");
        assert_eq!(tl.n_slots(0), 99);
        // wrong gid at an occupied start: not removed
        assert!(!tl.remove_at(0, gid(999), 10.0));
        assert_eq!(tl.n_slots(0), 99);
    }

    #[test]
    fn remove_at_handles_equal_start_runs() {
        // zero-duration slots sharing a start: each removable by gid
        let mut tl = Timelines::new(1);
        tl.insert(0, Slot { start: 5.0, finish: 5.0, gid: gid(0) });
        tl.insert(0, Slot { start: 5.0, finish: 5.0, gid: gid(1) });
        tl.insert(0, Slot { start: 5.0, finish: 5.0, gid: gid(2) });
        assert!(tl.remove_at(0, gid(1), 5.0));
        assert!(tl.remove_at(0, gid(2), 5.0));
        assert!(tl.remove_at(0, gid(0), 5.0));
        assert_eq!(tl.n_slots(0), 0);
    }

    #[test]
    fn find_idx_and_remove_idx() {
        let mut tl = Timelines::new(1);
        for i in 0..10 {
            let t = i as f64 * 2.0;
            tl.insert(0, Slot { start: t, finish: t + 1.0, gid: gid(i) });
        }
        assert_eq!(tl.find_idx(0, gid(4), 8.0), Some(4));
        assert_eq!(tl.find_idx(0, gid(99), 8.0), None);
        let s = tl.remove_idx(0, 4);
        assert_eq!(s.gid, gid(4));
        assert_eq!(tl.find_idx(0, gid(4), 8.0), None);
        assert_eq!(tl.find_idx(0, gid(5), 10.0), Some(4), "indices shift down");
    }

    #[test]
    fn push_tail_matches_insert_at_tail() {
        let mut a = Timelines::new(1);
        let mut b = Timelines::new(1);
        for i in 0..5 {
            let t = i as f64 * 3.0;
            let slot = Slot { start: t, finish: t + 2.0, gid: gid(i) };
            a.insert(0, slot);
            b.push_tail(0, slot);
        }
        assert_eq!(a.slots_vec(0), b.slots_vec(0));
        // journaling applies to tail pushes too
        b.begin_txn();
        b.push_tail(0, Slot { start: 20.0, finish: 21.0, gid: gid(9) });
        assert_eq!(b.txn_len(), 1);
        b.rollback_txn();
        assert_eq!(b.slots_vec(0), a.slots_vec(0));
    }

    #[test]
    fn unassign_tail_drops_suffix_and_map_entries() {
        let mut s = Schedule::new(2);
        for i in 0..6 {
            let t = i as f64 * 2.0;
            s.assign(gid(i), Assignment { node: 0, start: t, finish: t + 1.0 });
        }
        s.assign(gid(10), Assignment { node: 1, start: 0.0, finish: 4.0 });
        s.unassign_tail(0, 2);
        assert_eq!(s.timelines().n_slots(0), 2);
        assert_eq!(s.n_assigned(), 3);
        for i in 0..2 {
            assert!(s.get(gid(i)).is_some());
        }
        for i in 2..6 {
            assert!(s.get(gid(i)).is_none(), "suffix slot {i} must be gone");
        }
        assert!(s.get(gid(10)).is_some(), "other nodes untouched");
        // from == len is a no-op; re-adding via assign_tail round-trips
        s.unassign_tail(0, 2);
        assert_eq!(s.timelines().n_slots(0), 2);
        s.assign_tail(gid(7), Assignment { node: 0, start: 9.0, finish: 9.5 });
        assert_eq!(*s.timelines().slot_gids(0).last().unwrap(), gid(7));
        assert_eq!(s.get(gid(7)).unwrap().start, 9.0);
    }

    #[test]
    fn txn_rollback_removes_only_journaled_slots() {
        let mut tl = Timelines::new(2);
        tl.insert(0, Slot { start: 0.0, finish: 2.0, gid: gid(0) });
        tl.begin_txn();
        tl.insert(0, Slot { start: 3.0, finish: 4.0, gid: gid(1) });
        tl.insert(1, Slot { start: 0.0, finish: 5.0, gid: gid(2) });
        assert_eq!(tl.txn_len(), 2);
        tl.rollback_txn();
        assert_eq!(tl.txn_len(), 0);
        assert_eq!(tl.n_slots(0), 1, "pre-txn slot survives");
        assert_eq!(tl.slot(0, 0).gid, gid(0));
        assert_eq!(tl.n_slots(1), 0);
        // a fresh transaction can commit
        tl.begin_txn();
        tl.insert(1, Slot { start: 1.0, finish: 2.0, gid: gid(3) });
        tl.commit_txn();
        assert_eq!(tl.n_slots(1), 1);
    }

    #[test]
    fn record_after_inplace_insert_matches_assign() {
        // the coordinator's in-place path: scheduler inserts the slot,
        // coordinator records the assignment — equivalent to assign().
        let a = Assignment { node: 0, start: 1.0, finish: 3.0 };
        let mut s1 = Schedule::new(1);
        s1.assign(gid(0), a);
        let mut s2 = Schedule::new(1);
        s2.timelines_mut().insert(
            0,
            Slot { start: a.start, finish: a.finish, gid: gid(0) },
        );
        s2.record(gid(0), a);
        assert_eq!(s1.get(gid(0)), s2.get(gid(0)));
        assert_eq!(s1.timelines().slots_vec(0), s2.timelines().slots_vec(0));
        assert_eq!(s2.unassign(gid(0)), Some(a));
        assert_eq!(s2.timelines().n_slots(0), 0);
    }

    #[test]
    fn schedule_assign_unassign_roundtrip() {
        let mut s = Schedule::new(2);
        let a = Assignment { node: 1, start: 3.0, finish: 5.0 };
        s.assign(gid(0), a);
        assert_eq!(s.get(gid(0)), Some(&a));
        assert_eq!(s.n_assigned(), 1);
        assert_eq!(s.unassign(gid(0)), Some(a));
        assert_eq!(s.n_assigned(), 0);
        assert_eq!(s.timelines().n_slots(1), 0);
        assert_eq!(s.unassign(gid(0)), None);
    }

    #[test]
    fn dense_store_matches_map_store() {
        // same operation sequence against both backends → same observable
        // state (get / n_assigned / sorted iter / timelines).
        let ids = Arc::new(DenseIds::from_counts([3usize, 2]));
        let mut dense = Schedule::new_dense(2, ids);
        let mut map = Schedule::new(2);
        let tasks = [Gid::new(0, 0), Gid::new(0, 2), Gid::new(1, 1), Gid::new(0, 1)];
        for (k, &g) in tasks.iter().enumerate() {
            let a = Assignment { node: k % 2, start: k as f64, finish: k as f64 + 0.5 };
            dense.assign(g, a);
            map.assign(g, a);
        }
        assert_eq!(dense.n_assigned(), map.n_assigned());
        assert_eq!(dense.get(Gid::new(1, 0)), None);
        for &g in &tasks {
            assert_eq!(dense.get(g), map.get(g));
        }
        let sig = |s: &Schedule| {
            let mut v: Vec<(Gid, usize, u64)> =
                s.iter().map(|(&g, a)| (g, a.node, a.start.to_bits())).collect();
            v.sort();
            v
        };
        assert_eq!(sig(&dense), sig(&map));
        assert_eq!(dense.unassign(Gid::new(0, 2)), map.unassign(Gid::new(0, 2)));
        assert_eq!(dense.unassign(Gid::new(0, 2)), None);
        assert_eq!(dense.n_assigned(), map.n_assigned());
        assert_eq!(sig(&dense), sig(&map));
        for v in 0..2 {
            assert_eq!(dense.timelines().slots_vec(v), map.timelines().slots_vec(v));
        }
    }

    #[test]
    #[should_panic(expected = "assigned twice")]
    fn double_assign_panics() {
        let mut s = Schedule::new(1);
        let a = Assignment { node: 0, start: 0.0, finish: 1.0 };
        s.assign(gid(0), a);
        s.assign(gid(0), a);
    }

    fn chain_problem() -> (Vec<(f64, TaskGraph)>, Network) {
        let mut b = GraphBuilder::new("chain");
        let t0 = b.task(2.0);
        let t1 = b.task(4.0);
        b.edge(t0, t1, 6.0);
        let g = b.build().unwrap();
        // 2 nodes speed 1 & 2; link strength 3.
        let net = Network::new(vec![1.0, 2.0], vec![0.0, 3.0, 3.0, 0.0]);
        (vec![(1.0, g)], net)
    }

    #[test]
    fn validate_accepts_correct_schedule() {
        let (prob, net) = chain_problem();
        let mut s = Schedule::new(2);
        // t0 on node 0: [1, 3]; comm 6/3 = 2; t1 on node 1: [5, 7]
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 1.0, finish: 3.0 });
        s.assign(Gid::new(0, 1), Assignment { node: 1, start: 5.0, finish: 7.0 });
        assert_eq!(validate(&s, &prob, &net), vec![]);
    }

    #[test]
    fn validate_catches_each_violation_kind() {
        let (prob, net) = chain_problem();

        // missing task
        let mut s = Schedule::new(2);
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 1.0, finish: 3.0 });
        let v = validate(&s, &prob, &net);
        assert!(v.iter().any(|x| x.0.contains("not scheduled")));

        // wrong duration
        let mut s = Schedule::new(2);
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 1.0, finish: 2.5 });
        s.assign(Gid::new(0, 1), Assignment { node: 1, start: 6.0, finish: 8.0 });
        assert!(validate(&s, &prob, &net).iter().any(|x| x.0.contains("duration")));

        // before arrival
        let mut s = Schedule::new(2);
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 0.0, finish: 2.0 });
        s.assign(Gid::new(0, 1), Assignment { node: 1, start: 6.0, finish: 8.0 });
        assert!(validate(&s, &prob, &net).iter().any(|x| x.0.contains("arrival")));

        // dependency violated (no comm slack)
        let mut s = Schedule::new(2);
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 1.0, finish: 3.0 });
        s.assign(Gid::new(0, 1), Assignment { node: 1, start: 3.5, finish: 5.5 });
        assert!(validate(&s, &prob, &net).iter().any(|x| x.0.contains("dependency")));

        // co-located dependency needs no comm: start 3.0 on node 0 is fine
        let mut s = Schedule::new(2);
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 1.0, finish: 3.0 });
        s.assign(Gid::new(0, 1), Assignment { node: 0, start: 3.0, finish: 7.0 });
        assert_eq!(validate(&s, &prob, &net), vec![]);
    }

    #[test]
    fn validate_catches_overlap() {
        let (mut prob, net) = chain_problem();
        // two independent tasks overlapping on node 0
        let mut b = GraphBuilder::new("pair");
        b.task(2.0);
        b.task(2.0);
        prob[0].1 = b.build().unwrap();
        let mut s = Schedule::new(2);
        s.assign(Gid::new(0, 0), Assignment { node: 0, start: 1.0, finish: 3.0 });
        // bypass Schedule::assign's debug_assert by constructing directly:
        let mut s2 = s.clone();
        s2.assign(Gid::new(0, 1), Assignment { node: 0, start: 3.0, finish: 5.0 });
        assert_eq!(validate(&s2, &prob, &net), vec![]);
    }
}
