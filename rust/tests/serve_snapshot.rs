//! Snapshot/restore bit-identity: killing a `dts serve` session at any
//! request boundary and restoring from the journal continues
//! **bit-identically** to an uninterrupted session.
//!
//! The grid covers every dataset × {`L3@0.25` reactive trigger,
//! `D3@0.25` deadline-aware policy controller} × shards {1, 4}.  For
//! each cell the canonical request script (two epochs, a stats probe, a
//! graceful drain) is replayed with an interruption at **every** split
//! point: prefix on server #1 → `snapshot_json` → simulated process
//! death (registry reset) → [`ServeServer::restore`] on a fresh server →
//! suffix + drain.  The concatenated output must equal the
//! uninterrupted session's byte-for-byte — including the `stats` line's
//! telemetry counter block, which is why the journal carries the counter
//! snapshot and restore re-seeds the registry.
//!
//! Also pins the federated controller oracle: a 1-shard
//! [`FederatedCoordinator`] with a [`PolicySpec`] controller reproduces
//! the monolithic `ReactiveCoordinator::with_policy` run bit-exactly
//! (the `with_controller` builder is `dts serve --shards --deadline-aware`'s
//! engine, so the oracle anchors the whole federated serve grid).

use dts::coordinator::Variant;
use dts::experiments::metric_row_json;
use dts::federation::FederatedCoordinator;
use dts::policy::PolicySpec;
use dts::serve::{Controller, ServeConfig, ServeServer};
use dts::sim::{Reaction, ReactiveCoordinator, SimConfig};
use dts::telemetry;
use dts::workloads::{Dataset, Scenario, DEFAULT_LOAD};

const SEED: u64 = 5;
const GRAPHS: usize = 6;

fn cfg(dataset: Dataset, controller: Controller, shards: usize) -> ServeConfig {
    ServeConfig {
        dataset,
        n_graphs: GRAPHS,
        seed: SEED,
        variant: Variant::parse("5P-HEFT").unwrap(),
        noise_std: 0.3,
        controller,
        shards,
        jobs: if shards > 1 { 2 } else { 1 },
        load: DEFAULT_LOAD,
        scenario: Scenario::default(),
        faults: dts::sim::FaultConfig::NONE,
    }
}

fn controllers() -> [Controller; 2] {
    [
        Controller::Reaction(Reaction::LastK {
            k: 3,
            threshold: 0.25,
        }),
        Controller::Spec(PolicySpec::DeadlineAware {
            k: 3,
            threshold: 0.25,
        }),
    ]
}

/// The canonical session script: two epochs, a stats probe at the end.
fn script() -> Vec<String> {
    let mut reqs: Vec<String> = (0..3)
        .map(|g| format!("{{\"op\":\"arrive\",\"graph\":{g}}}"))
        .collect();
    reqs.push("{\"op\":\"run\"}".to_string());
    for g in 3..GRAPHS {
        reqs.push(format!("{{\"op\":\"arrive\",\"graph\":{g}}}"));
    }
    reqs.push("{\"op\":\"run\"}".to_string());
    reqs.push("{\"op\":\"stats\"}".to_string());
    reqs
}

fn uninterrupted(cfg: &ServeConfig) -> Vec<String> {
    telemetry::reset();
    let mut server = ServeServer::new(cfg.clone());
    let mut out = Vec::new();
    for r in script() {
        server.handle_line(&r, &mut out);
    }
    server.drain(&mut out);
    out
}

/// Run the script with a kill/restore at request boundary `split`.
fn interrupted(cfg: &ServeConfig, split: usize) -> Vec<String> {
    telemetry::reset();
    let reqs = script();
    let mut server = ServeServer::new(cfg.clone());
    let mut out = Vec::new();
    for r in &reqs[..split] {
        server.handle_line(r, &mut out);
    }
    let journal = server.snapshot_json();
    drop(server);
    // simulated process death: the restored session starts with a fresh
    // telemetry registry, exactly like a new `dts serve --restore`
    telemetry::reset();
    let mut restored = ServeServer::restore(cfg.clone(), &journal)
        .unwrap_or_else(|e| panic!("restore at split {split}: {e}"));
    for r in &reqs[split..] {
        restored.handle_line(r, &mut out);
    }
    restored.drain(&mut out);
    out
}

#[test]
fn restore_is_bit_identical_at_every_split_point() {
    let n_reqs = script().len();
    for dataset in Dataset::ALL {
        for controller in controllers() {
            for shards in [1usize, 4] {
                let c = cfg(dataset, controller.clone(), shards);
                let full = uninterrupted(&c);
                for split in 1..n_reqs {
                    let resumed = interrupted(&c, split);
                    assert_eq!(
                        resumed,
                        full,
                        "{} {} S{shards}: split {split}",
                        dataset.name(),
                        controller.label()
                    );
                }
            }
        }
    }
}

#[test]
fn restore_rejects_mismatched_config() {
    let base = cfg(Dataset::Synthetic, controllers()[0].clone(), 1);
    telemetry::reset();
    let mut server = ServeServer::new(base.clone());
    let mut out = Vec::new();
    server.handle_line("{\"op\":\"arrive\",\"graph\":0}", &mut out);
    let journal = server.snapshot_json();

    // every divergent knob refuses
    let mut other_seed = base.clone();
    other_seed.seed = SEED + 1;
    assert!(ServeServer::restore(other_seed, &journal).is_err());

    let mut other_shards = base.clone();
    other_shards.shards = 4;
    assert!(ServeServer::restore(other_shards, &journal).is_err());

    let mut other_controller = base.clone();
    other_controller.controller = controllers()[1].clone();
    assert!(ServeServer::restore(other_controller, &journal).is_err());

    // the matching config restores
    assert!(ServeServer::restore(base, &journal).is_ok());
}

#[test]
fn snapshot_roundtrips_through_ndjson_text() {
    // the journal travels through a file in production: print → parse →
    // restore must behave identically to restoring the in-memory value
    let c = cfg(Dataset::RiotBench, controllers()[1].clone(), 4);
    telemetry::reset();
    let mut server = ServeServer::new(c.clone());
    let mut out = Vec::new();
    for r in &script()[..5] {
        server.handle_line(r, &mut out);
    }
    let doc = server.snapshot_json();
    let text = doc.to_string();
    let reparsed = dts::json::Value::from_str(&text).unwrap();
    assert_eq!(doc, reparsed, "snapshot print∘parse must be idempotent");
    telemetry::reset();
    let restored = ServeServer::restore(c, &reparsed).unwrap();
    assert_eq!(restored.epochs(), server.epochs());
    assert_eq!(restored.pending(), server.pending());
    assert_eq!(restored.lines_handled(), server.lines_handled());
}

/// A corrupted journal — truncated at any byte, or with a flipped bit —
/// is refused with a structured error, never a panic, and never
/// restores a session from a strict prefix of the document.  (This is
/// the in-memory half of the `--restore` exit-2 contract; the atomic
/// temp+fsync+rename journal write exists precisely so production never
/// sees a torn document, but restore must still survive one.)
#[test]
fn corrupted_journals_are_refused_never_panic() {
    let base = cfg(Dataset::Synthetic, controllers()[0].clone(), 1);
    telemetry::reset();
    let mut server = ServeServer::new(base.clone());
    let mut out = Vec::new();
    for r in &script()[..4] {
        server.handle_line(r, &mut out);
    }
    let text = server.snapshot_json().to_string();
    let bytes = text.as_bytes();

    let try_restore = |raw: &[u8]| -> Result<(), String> {
        let s = std::str::from_utf8(raw).map_err(|e| e.to_string())?;
        let doc = dts::json::Value::from_str(s).map_err(|e| e.to_string())?;
        ServeServer::restore(base.clone(), &doc).map(|_| ())
    };

    // every strict prefix is refused (a truncated journal can never
    // parse as the full document)
    for i in (0..bytes.len()).step_by(7).chain([0, bytes.len() - 1]) {
        assert!(
            try_restore(&bytes[..i]).is_err(),
            "truncation at byte {i} restored a session"
        );
    }
    // the intact document restores
    assert!(try_restore(bytes).is_ok());

    // single-bit flips: parse/restore must never panic; flips are
    // either refused or land in a value field (epoch list, counter)
    // that still forms a well-formed document — count the refusals to
    // make sure the sweep actually hits structure, not just values
    let mut refused = 0usize;
    for i in (0..bytes.len()).step_by(3) {
        for bit in [0u8, 4] {
            let mut flipped = bytes.to_vec();
            flipped[i] ^= 1 << bit;
            if try_restore(&flipped).is_err() {
                refused += 1;
            }
        }
    }
    assert!(refused > 0, "no bit flip was ever refused");

    // garbage documents of every JSON shape are structured errors
    for garbage in [
        "{}",
        "[]",
        "42",
        "\"journal\"",
        "{\"format\":\"dts-serve-snapshot-v2\"}",
        "{\"format\":\"dts-serve-snapshot-v1\"}",
    ] {
        let doc = dts::json::Value::from_str(garbage).unwrap();
        assert!(
            ServeServer::restore(base.clone(), &doc).is_err(),
            "garbage journal {garbage:?} restored"
        );
    }
}

#[test]
fn one_shard_federated_controller_matches_monolithic() {
    // the with_controller oracle: S1 + PolicySpec ≡ monolithic
    // with_policy, bit for bit (events and the 18-metric block)
    let prob = Dataset::Synthetic.instance_scenario(
        GRAPHS,
        SEED,
        DEFAULT_LOAD,
        None,
        &Scenario::default(),
    );
    let variant = Variant::parse("5P-HEFT").unwrap();
    let spec = PolicySpec::DeadlineAware {
        k: 3,
        threshold: 0.25,
    };
    let sim_cfg = SimConfig {
        noise_std: 0.3,
        noise_seed: SEED ^ 0xA11CE,
        reaction: Reaction::None,
        record_frozen: false,
        full_refresh: false,
        faults: dts::sim::FaultConfig::NONE,
    };
    let fed = FederatedCoordinator::new(variant.policy, variant.kind, SEED ^ 0x5EED, sim_cfg, 1)
        .with_controller(spec.clone());
    assert!(fed.label().contains("D3@0.25"), "{}", fed.label());
    let fres = fed.run(&prob);
    let mut rc = ReactiveCoordinator::with_policy(
        variant.policy,
        variant.kind.make(SEED ^ 0x5EED),
        sim_cfg,
        spec.make(),
    );
    let mres = rc.run(&prob);
    assert_eq!(fres.log, mres.log, "event logs diverge");
    assert_eq!(
        metric_row_json(&fres.metrics(&prob)).to_string(),
        metric_row_json(&mres.metrics(&prob)).to_string(),
        "metric rows diverge"
    );
}
